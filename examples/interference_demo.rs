//! Interference demo: watch Swan migrate as a foreground app arrives and
//! leaves, while a real model trains underneath (§4.3 / Fig 4b).
//!
//!     cargo run --release --example interference_demo
//!
//! Timeline: 15 quiet steps → a heavy (2-thread) app session starts →
//! the controller walks down the preference chain → the session ends →
//! the controller probes its way back to the fastest choice. Ends with
//! the PCMark impact comparison (Table 3 in miniature).

use swan::runtime::{ModelExecutor, Registry, RuntimeClient};
use swan::sim::interference::SessionGenerator;
use swan::sim::pcmark::score_impact_percent;
use swan::sim::SimPhone;
use swan::soc::device::{device, DeviceId};
use swan::swan::controller::MigrationEvent;
use swan::swan::{SwanConfig, SwanEngine};
use swan::train::data::SyntheticDataset;
use swan::workload::{load_or_builtin, WorkloadName};

fn main() -> swan::Result<()> {
    let reg = Registry::discover()?;
    let client = RuntimeClient::cpu()?;
    let exec = ModelExecutor::load(&client, &reg.dir, "resnet_s")?;
    let d = device(DeviceId::Pixel3);
    let workload = load_or_builtin(WorkloadName::Resnet34, "artifacts");

    let mut phone = SimPhone::new(d.clone(), 3);
    let mut engine = SwanEngine::explore_and_build(
        &mut phone,
        workload,
        SwanConfig::default(),
    );
    println!(
        "preference chain: {}",
        engine
            .chain()
            .iter()
            .map(|p| p.choice.label())
            .collect::<Vec<_>>()
            .join(" → ")
    );

    let ds = SyntheticDataset::speech(5);
    let part = ds.partition(0);
    let mut state = exec.init_state(0)?;
    let mut step_no = 0usize;
    let mut run_phase = |phone: &mut SimPhone,
                         engine: &mut SwanEngine,
                         state: &mut swan::runtime::TrainState,
                         label: &str,
                         steps: usize|
     -> swan::Result<()> {
        println!("\n== {label} ==");
        for _ in 0..steps {
            let (x, y) = ds.batch(&part, step_no, exec.meta.batch);
            step_no += 1;
            let mut loss = f32::NAN;
            let rep = engine.run_local_step(phone, || {
                loss = exec.train_step(state, &x, &y).expect("step");
            });
            match &rep.migration {
                MigrationEvent::Stay => {}
                MigrationEvent::Downgrade { from, to } => {
                    println!("  ↓ interference inferred: {from} → {to}");
                }
                MigrationEvent::Upgrade { from, to } => {
                    println!("  ↑ quiet again: {from} → {to}");
                }
            }
            if step_no % 5 == 0 {
                println!(
                    "  step {step_no:3}: loss {loss:.3}, choice {}, \
                     {:.0} ms/step (sim)",
                    rep.choice,
                    rep.latency_s * 1e3
                );
            }
        }
        Ok(())
    };

    run_phase(&mut phone, &mut engine, &mut state, "device idle", 15)?;

    phone.sessions = SessionGenerator::new(11, 1e-6, 1e15, 1.0);
    phone.idle(1.0);
    run_phase(
        &mut phone,
        &mut engine,
        &mut state,
        "heavy foreground app running",
        25,
    )?;

    phone.sessions = SessionGenerator::always_idle(12);
    run_phase(&mut phone, &mut engine, &mut state, "app closed", 40)?;

    let (downs, ups) = engine.migrations();
    println!("\nmigrations: {downs} downgrades, {ups} upgrades");

    // Table-3 style comparison: what PCMark sees is the downgraded
    // choice AFTER the within-cluster remap off the contended cores
    let greedy_impact = score_impact_percent(&d, &d.low_latency_cores());
    let settled = &engine.chain()[1.min(engine.chain().len() - 1)];
    let sched = swan::sim::android_sched::Scheduler::new(&d);
    let share = sched.training_share(2);
    let remapped =
        sched.remap_least_contended(&d, &settled.choice.cores, &share);
    let swan_impact = score_impact_percent(&d, &remapped);
    println!(
        "PCMark impact — baseline (greedy): {greedy_impact:.1}%, \
         swan (downgraded {} → cores {remapped:?}): {swan_impact:.1}%",
        settled.choice.label(),
    );
    Ok(())
}
