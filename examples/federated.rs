//! End-to-end driver: the §5.3 federated-learning evaluation, real
//! numerics included. This is the run recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example federated -- \
//!         [--model shufflenet_s] [--rounds 60] [--clients 5] \
//!         [--steps 5] [--traces 4] [--arm both|swan|baseline]
//!
//! Both arms (Swan vs PyTorch-greedy baseline) run the same FedAvg
//! workload over the same trace-driven fleet; per-arm it reports the
//! accuracy-vs-virtual-time curve (Figs 5a/6a/7a), clients-online-per-
//! round (Figs 5b/6b/7b), total fleet energy, and the Table-4 ratios.
//! Curves are persisted as CSV under target/reports/.

use swan::fl::{FlArm, FlConfig, FlOutcome, FlSim};
use swan::runtime::{ModelExecutor, Registry, RuntimeClient};
use swan::train::data::SyntheticDataset;
use swan::util::table::{fmt_ratio, Table};
use swan::workload::{load_or_builtin, WorkloadName};

fn parse_args() -> (String, usize, usize, usize, usize, String) {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |name: &str, default: &str| -> String {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1).cloned())
            .unwrap_or_else(|| default.to_string())
    };
    (
        get("--model", "shufflenet_s"),
        get("--rounds", "60").parse().expect("--rounds"),
        get("--clients", "5").parse().expect("--clients"),
        get("--steps", "5").parse().expect("--steps"),
        get("--traces", "4").parse().expect("--traces"),
        get("--arm", "both"),
    )
}

fn run_arm(
    arm: FlArm,
    model: &str,
    cfg: &FlConfig,
    exec: &ModelExecutor,
) -> swan::Result<FlOutcome> {
    let paper = WorkloadName::paper_scale_of(
        WorkloadName::parse(model).expect("model"),
    );
    let workload = load_or_builtin(paper, "artifacts");
    let ds = if exec.meta.task == "speech" {
        SyntheticDataset::speech(cfg.seed)
    } else {
        SyntheticDataset::vision(cfg.seed)
    };
    let t0 = std::time::Instant::now();
    let mut sim = FlSim::new(cfg.clone(), arm, ds, &workload)?;
    println!(
        "[{}] fleet: {} clients, {} rounds × {} clients/round × {} steps",
        arm.name(),
        sim.clients.len(),
        cfg.rounds,
        cfg.clients_per_round,
        cfg.local_steps
    );
    let out = sim.run(exec)?;
    println!(
        "[{}] done in {:.0}s wall; virtual time {:.1} h; fleet energy {:.1} kJ; \
         best accuracy {:.3}",
        arm.name(),
        t0.elapsed().as_secs_f64(),
        out.total_time_s / 3600.0,
        out.total_energy_j / 1e3,
        out.best_accuracy()
    );
    // persist curves
    std::fs::create_dir_all("target/reports")?;
    std::fs::write(
        format!("target/reports/fl_{}_{}_accuracy.csv", exec.meta.name, arm.name()),
        out.accuracy_curve.to_csv("accuracy"),
    )?;
    let mut online = String::from("round,online\n");
    for (r, n) in &out.online_per_round {
        online.push_str(&format!("{r},{n}\n"));
    }
    std::fs::write(
        format!("target/reports/fl_{}_{}_online.csv", exec.meta.name, arm.name()),
        online,
    )?;
    Ok(out)
}

fn main() -> swan::Result<()> {
    let (model, rounds, clients, steps, traces, arm) = parse_args();
    let reg = Registry::discover()?;
    let client = RuntimeClient::cpu()?;
    let exec = ModelExecutor::load(&client, &reg.dir, &model)?;
    println!(
        "model {} ({} params); artifacts from {}",
        exec.meta.name,
        exec.meta.param_scalars(),
        reg.dir.display()
    );

    let cfg = FlConfig {
        seed: 17,
        raw_traces: traces * 4,
        quality_traces: traces,
        clients_per_round: clients,
        local_steps: steps,
        rounds,
        eval_every: 2,
        eval_batches: 4,
        daily_credit_j: 2_500.0,
        server_overhead_s: 2.0,
    };

    let mut outcomes: Vec<FlOutcome> = Vec::new();
    if arm == "both" || arm == "swan" {
        outcomes.push(run_arm(FlArm::Swan, &model, &cfg, &exec)?);
    }
    if arm == "both" || arm == "baseline" {
        outcomes.push(run_arm(FlArm::Baseline, &model, &cfg, &exec)?);
    }

    if outcomes.len() == 2 {
        let (swan, base) = (&outcomes[0], &outcomes[1]);
        // Table 4: target = best accuracy reached by either arm
        let target = swan.best_accuracy().min(base.best_accuracy());
        let t_swan = swan.time_to_accuracy(target);
        let t_base = base.time_to_accuracy(target);
        let mut t = Table::new(
            &format!("Table-4 style summary — {}", exec.meta.name),
            &["metric", "swan", "baseline", "ratio"],
        );
        if let (Some(a), Some(b)) = (t_swan, t_base) {
            t.row(&[
                format!("time to {:.1}% acc (h)", target * 100.0),
                format!("{:.2}", a / 3600.0),
                format!("{:.2}", b / 3600.0),
                fmt_ratio(b / a.max(1.0)),
            ]);
        }
        t.row(&[
            "fleet energy (kJ)".into(),
            format!("{:.1}", swan.total_energy_j / 1e3),
            format!("{:.1}", base.total_energy_j / 1e3),
            fmt_ratio(base.total_energy_j / swan.total_energy_j.max(1.0)),
        ]);
        let final_online = |o: &FlOutcome| {
            o.online_per_round.last().map(|(_, n)| *n).unwrap_or(0)
        };
        t.row(&[
            "clients online (final round)".into(),
            format!("{}", final_online(swan)),
            format!("{}", final_online(base)),
            "-".into(),
        ]);
        t.emit()?;
    }
    Ok(())
}
