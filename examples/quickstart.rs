//! Quickstart: bring Swan up on a simulated Pixel 3 and train a real
//! model for 20 steps.
//!
//!     cargo run --release --example quickstart
//!
//! Walks the full §4 lifecycle: enumerate execution choices → explore
//! them with battery-drop energy attribution → prune to the preference
//! chain → run real (PJRT-executed) training steps under the fastest
//! choice, printing the simulated cost of each.

use swan::runtime::{ModelExecutor, Registry, RuntimeClient};
use swan::sim::SimPhone;
use swan::soc::device::{device, DeviceId};
use swan::swan::{SwanConfig, SwanEngine};
use swan::train::data::SyntheticDataset;
use swan::util::table::Table;
use swan::workload::{load_or_builtin, WorkloadName};

fn main() -> swan::Result<()> {
    let reg = Registry::discover()?;
    let client = RuntimeClient::cpu()?;
    println!("PJRT platform: {}", client.platform());

    let exec = ModelExecutor::load(&client, &reg.dir, "shufflenet_s")?;
    println!(
        "loaded {} ({} parameters, batch {})",
        exec.meta.name,
        exec.meta.param_scalars(),
        exec.meta.batch
    );

    // a simulated Pixel 3, idle and discharging
    let d = device(DeviceId::Pixel3);
    let mut phone = SimPhone::new(d, 42);
    let workload = load_or_builtin(WorkloadName::ShufflenetV2, "artifacts");

    println!("\nexploring execution choices (§4.2)...");
    let mut engine = SwanEngine::explore_and_build(
        &mut phone,
        workload,
        SwanConfig::default(),
    );

    let mut t = Table::new(
        "explored profiles (pruned preference chain marked *)",
        &["choice", "latency_s", "energy_j", "power_w", "kept"],
    );
    let kept: Vec<String> = engine
        .chain()
        .iter()
        .map(|p| p.choice.label())
        .collect();
    for p in &engine.profiles {
        t.row(&[
            p.choice.label(),
            format!("{:.3}", p.latency_s),
            format!("{:.3}", p.energy_j),
            format!("{:.2}", p.power_w),
            if kept.contains(&p.choice.label()) { "*" } else { "" }
                .to_string(),
        ]);
    }
    println!("{}", t.to_markdown());

    println!(
        "fastest choice: {} ({:.0} ms/step simulated)",
        engine.best_profile().choice.label(),
        engine.best_profile().latency_s * 1e3
    );

    // now really train
    let ds = SyntheticDataset::vision(7);
    let part = ds.partition(0);
    let mut state = exec.init_state(1)?;
    println!("\ntraining 20 real steps under Swan:");
    for step in 0..20 {
        let (x, y) = ds.batch(&part, step, exec.meta.batch);
        let mut loss = f32::NAN;
        let rep = engine.run_local_step(&mut phone, || {
            loss = exec.train_step(&mut state, &x, &y).expect("step");
        });
        println!(
            "step {step:2}: loss {loss:.4}  choice {}  sim {:.0} ms",
            rep.choice,
            rep.latency_s * 1e3
        );
    }
    println!(
        "\nbattery now {:.1}%, temperature {:.1} °C — quickstart done",
        phone.battery.soc() * 100.0,
        phone.thermal.temp_c
    );
    Ok(())
}
