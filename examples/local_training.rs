//! Local evaluation sweep (the Table-2 experiment) on one device.
//!
//!     cargo run --release --example local_training -- [device]
//!
//! devices: pixel3 | s10e | oneplus8 | tabs6 | mi10 (default pixel3)
//!
//! For each of the three paper models: explore every execution choice on
//! a fresh simulated phone, print the full profile table, and compare
//! Swan's best choice against the PyTorch greedy baseline — while also
//! running real training steps for the chosen model variant so the
//! numerics are exercised, not just the simulator.

use swan::runtime::{ModelExecutor, Registry, RuntimeClient};
use swan::sim::SimPhone;
use swan::soc::device::{device, DeviceId};
use swan::swan::choice::ExecutionChoice;
use swan::swan::explorer::Explorer;
use swan::train::data::SyntheticDataset;
use swan::util::table::{fmt_ratio, Table};
use swan::workload::{load_or_builtin, WorkloadName};

fn main() -> swan::Result<()> {
    let dev_arg = std::env::args().nth(1).unwrap_or_else(|| "pixel3".into());
    let dev = DeviceId::parse(&dev_arg)
        .ok_or_else(|| swan::err!("unknown device '{dev_arg}'"))?;
    let d = device(dev);
    println!("device: {} ({})", d.id.name(), d.soc);

    let reg = Registry::discover()?;
    let client = RuntimeClient::cpu()?;

    let pairs = [
        (WorkloadName::Resnet34, "resnet_s"),
        (WorkloadName::ShufflenetV2, "shufflenet_s"),
        (WorkloadName::MobilenetV2, "mobilenet_s"),
    ];
    let mut summary = Table::new(
        &format!("local evaluation on {}", d.id.name()),
        &["model", "swan_choice", "speedup", "energy_eff"],
    );
    for (wl, model) in pairs {
        let workload = load_or_builtin(wl, "artifacts");
        let explorer = Explorer::default();
        let mut phone = SimPhone::new(d.clone(), 7);
        let profiles = explorer.explore_all(&mut phone, &workload);

        let mut t = Table::new(
            &format!("{} profiles", workload.name),
            &["choice", "latency_s", "energy_j", "power_w"],
        );
        for p in &profiles {
            t.row(&[
                p.choice.label(),
                format!("{:.3}", p.latency_s),
                format!("{:.3}", p.energy_j),
                format!("{:.2}", p.power_w),
            ]);
        }
        println!("{}", t.to_markdown());

        let best = profiles
            .iter()
            .min_by(|a, b| a.latency_s.partial_cmp(&b.latency_s).unwrap())
            .unwrap();
        let greedy_choice = ExecutionChoice::new(&d, d.low_latency_cores());
        let mut phone_b = SimPhone::new(d.clone(), 8);
        let greedy = explorer
            .explore_choice(&mut phone_b, &workload, &greedy_choice, 5)
            .profile;
        summary.row(&[
            workload.name.clone(),
            best.choice.label(),
            fmt_ratio(greedy.latency_s / best.latency_s),
            fmt_ratio(greedy.energy_j / best.energy_j.max(1e-12)),
        ]);

        // prove the trainable variant learns on this schedule
        let exec = ModelExecutor::load(&client, &reg.dir, model)?;
        let ds = if exec.meta.task == "speech" {
            SyntheticDataset::speech(1)
        } else {
            SyntheticDataset::vision(1)
        };
        let part = ds.partition(0);
        let mut state = exec.init_state(0)?;
        let mut first = 0.0;
        let mut last = 0.0;
        for step in 0..10 {
            let (x, y) = ds.batch(&part, step, exec.meta.batch);
            let loss = exec.train_step(&mut state, &x, &y)?;
            if step == 0 {
                first = loss;
            }
            last = loss;
        }
        println!(
            "{model}: 10 real steps, loss {first:.3} → {last:.3}\n"
        );
    }
    println!("{}", summary.to_markdown());
    Ok(())
}
