//! Known-bad fixture for `swan lint` — mirrors the module path
//! `fl/selection.rs` (digest scope, NOT in the RNG registry), so RNG
//! discipline applies; the pragma-hygiene cases ride along.
//!
//! Expected findings: rng ×2 (`Rng::new`, `.fork`), pragma ×3 (unused
//! pragma, reason-less pragma, unknown rule name).

use crate::util::rng::Rng;

pub fn fresh_stream_in_selection(seed: u64) -> u64 {
    let mut rng = Rng::new(seed);
    rng.next_u64()
}

pub fn forked_stream(root: &mut Rng) -> Rng {
    root.fork(7)
}

// lint: allow(determinism) — nothing on the next line needs this
pub fn unused_pragma_target() -> u32 {
    41
}

pub fn reasonless_pragma(seed: u64) -> u64 {
    // the pragma below suppresses the rng finding but is itself an
    // error: every allow must carry a reason after an em-dash
    let mut rng = Rng::new(seed ^ 1); // lint: allow(rng)
    rng.next_u64()
}

// lint: allow(vibes) — `vibes` is not a rule the analyzer knows
pub fn unknown_rule_pragma() -> u32 {
    43
}
