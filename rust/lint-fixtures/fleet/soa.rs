//! Known-bad fixture for `swan lint` — this file mirrors the module
//! path `fleet/soa.rs`, so the determinism and panic rules apply, and
//! it must ALWAYS produce findings. CI runs the lint over this tree
//! and fails if the run unexpectedly passes (the must-fail self-test).
//!
//! Expected findings: determinism ×3 (wall clock, hash iteration ×2),
//! panic ×3 (unwrap, expect, panic!), unsafe ×1.

use std::collections::HashMap;
use std::time::Instant;

pub fn wall_clock_in_round_state() -> f64 {
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}

pub fn hash_ordered_fold(m: &HashMap<u64, f64>) -> f64 {
    let mut acc = 0.0;
    for (_gid, v) in m.iter() {
        acc += *v;
    }
    let mut keys = HashMap::new();
    keys.insert(1u64, 2u64);
    for k in &keys {
        acc += k.1.wrapping_mul(3) as f64;
    }
    acc
}

pub fn worker_tears_down(x: Option<u32>, y: Option<u32>) -> u32 {
    if x.is_none() {
        panic!("boom");
    }
    x.unwrap() + y.expect("y must be set")
}

pub fn raw_read(p: *const u8) -> u8 {
    unsafe { *p }
}
