//! `swan lint` — a zero-dependency static analyzer for the crate's
//! own sources.
//!
//! Every guarantee this reproduction makes — bit-identical aggregates
//! at any shard count, digest-neutral telemetry, the pinned batched
//! draw sequence — is otherwise enforced *dynamically*, by property
//! tests that must happen to hit the violating path. This pass rejects
//! the hazards at the source level instead: a hand-rolled Rust lexer
//! ([`lexer`], in the spirit of `util/json.rs`) feeds syntactic rule
//! scans ([`rules`]) with per-site allow pragmas ([`pragma`]).
//!
//! Rule families (scopes live in [`rules`], the table in README):
//!
//! - `determinism` — no `Instant::now()`/`SystemTime`, no
//!   `HashMap`/`HashSet` iteration, in digest-affecting modules
//!   (`fleet`, `fl`, the serve coordinator/wire/cache, `util/rng`,
//!   `util/fnv`); `obs` is exempt per its digest-neutral contract.
//! - `rng` — `Rng` construction/forking only at registered sites
//!   ([`rules::RNG_REGISTRY`]).
//! - `panic` — no `unwrap`/`expect`/`panic!`-family on shard-worker
//!   and serve-IO paths; warn-level, denied under `--deny-all`.
//! - `unsafe` — every `unsafe` needs a nearby `// SAFETY:` comment.
//! - `pragma` — unused, reason-less, or malformed allow pragmas are
//!   themselves errors, so the allowlist can only shrink.
//!
//! Suppression syntax: `// lint: allow(rule) — reason` (own line =
//! next code line; trailing = same line). The CLI surface is
//! `swan lint [--deny-all] [--json] [paths…]`.

pub mod lexer;
pub mod pragma;
pub mod rules;

pub use rules::{Finding, ALLOWABLE, RNG_REGISTRY};

/// Map an on-disk path to the module-relative form the scope tables
/// use (`fleet/engine.rs`): the suffix after the last `src/`, or after
/// `lint-fixtures/` for the known-bad fixture tree.
fn rel_path(name: &str) -> String {
    let norm = name.replace('\\', "/");
    for marker in ["/src/", "lint-fixtures/"] {
        if let Some(pos) = norm.rfind(marker) {
            return norm[pos + marker.len()..].to_string();
        }
    }
    norm.strip_prefix("src/").unwrap_or(&norm).to_string()
}

/// Lint one file's source text. `name` is used both for reporting and
/// (via [`rel_path`]) for rule scoping.
pub fn lint_source(name: &str, src: &str) -> Vec<Finding> {
    let rel = rel_path(name);
    let (tokens, lex_errors) = lexer::lex(src);
    let mut out: Vec<Finding> = lex_errors
        .into_iter()
        .map(|e| Finding {
            file: name.to_string(),
            line: e.line,
            rule: "lex",
            deny: true,
            message: e.message,
        })
        .collect();
    let tests = lexer::test_spans(&tokens);
    let mut malformed = Vec::new();
    let pragmas = pragma::parse(&tokens, &mut malformed);
    for (line, msg) in malformed {
        out.push(Finding {
            file: name.to_string(),
            line,
            rule: "pragma",
            deny: true,
            message: msg,
        });
    }
    let mut raw = Vec::new();
    rules::scan(&rel, &tokens, &tests, &mut raw);
    let mut used = vec![false; pragmas.len()];
    for mut f in raw {
        let mut suppressed = false;
        for (i, p) in pragmas.iter().enumerate() {
            if p.target_line == f.line
                && p.rules.iter().any(|r| r == f.rule)
            {
                used[i] = true;
                suppressed = true;
            }
        }
        if !suppressed {
            f.file = name.to_string();
            out.push(f);
        }
    }
    for (i, p) in pragmas.iter().enumerate() {
        for r in &p.rules {
            if !ALLOWABLE.contains(&r.as_str()) {
                out.push(Finding {
                    file: name.to_string(),
                    line: p.line,
                    rule: "pragma",
                    deny: true,
                    message: format!(
                        "unknown rule `{r}` in allow pragma \
                         (allowable: {})",
                        ALLOWABLE.join(", "),
                    ),
                });
            }
        }
        if p.reason.is_empty() {
            out.push(Finding {
                file: name.to_string(),
                line: p.line,
                rule: "pragma",
                deny: true,
                message: "allow pragma without a reason — every \
                          suppression must say why"
                    .to_string(),
            });
        }
        if !used[i]
            && p.rules.iter().all(|r| ALLOWABLE.contains(&r.as_str()))
        {
            out.push(Finding {
                file: name.to_string(),
                line: p.line,
                rule: "pragma",
                deny: true,
                message: format!(
                    "unused allow pragma for `{}` — it suppresses \
                     nothing; delete it",
                    p.rules.join(", "),
                ),
            });
        }
    }
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// Lint every `.rs` file under `paths` (files or directories),
/// depth-first in sorted order so output is stable.
pub fn lint_paths(paths: &[String]) -> crate::Result<Vec<Finding>> {
    let mut files: Vec<std::path::PathBuf> = Vec::new();
    for p in paths {
        let path = std::path::Path::new(p);
        crate::ensure!(path.exists(), "lint: no such path '{p}'");
        collect_rs(path, &mut files)?;
    }
    files.sort();
    files.dedup();
    crate::ensure!(
        !files.is_empty(),
        "lint: no .rs files under {}",
        paths.join(", ")
    );
    let mut out = Vec::new();
    for f in &files {
        let src = std::fs::read_to_string(f).map_err(|e| {
            crate::err!("lint: reading {}: {e}", f.display())
        })?;
        out.extend(lint_source(&f.display().to_string(), &src));
    }
    Ok(out)
}

fn collect_rs(
    path: &std::path::Path,
    files: &mut Vec<std::path::PathBuf>,
) -> crate::Result<()> {
    if path.is_dir() {
        let rd = std::fs::read_dir(path).map_err(|e| {
            crate::err!("lint: reading dir {}: {e}", path.display())
        })?;
        let mut children: Vec<std::path::PathBuf> = Vec::new();
        for entry in rd {
            let entry = entry.map_err(|e| {
                crate::err!("lint: reading dir {}: {e}", path.display())
            })?;
            children.push(entry.path());
        }
        children.sort();
        for c in children {
            collect_rs(&c, files)?;
        }
    } else if path.extension().map_or(false, |x| x == "rs") {
        files.push(path.to_path_buf());
    }
    Ok(())
}

/// Count the findings that fail the run: every `deny` finding, plus
/// warn findings under `--deny-all`.
pub fn failing(findings: &[Finding], deny_all: bool) -> usize {
    findings.iter().filter(|f| f.deny || deny_all).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_path_strips_src_and_fixture_prefixes() {
        assert_eq!(
            rel_path("rust/src/fleet/engine.rs"),
            "fleet/engine.rs"
        );
        assert_eq!(
            rel_path("/abs/repo/rust/src/serve/wire.rs"),
            "serve/wire.rs"
        );
        assert_eq!(
            rel_path("rust/lint-fixtures/fleet/soa.rs"),
            "fleet/soa.rs"
        );
        assert_eq!(rel_path("fl/sim.rs"), "fl/sim.rs");
    }

    #[test]
    fn failing_separates_warn_from_deny() {
        let fs = vec![
            Finding {
                file: "a".into(),
                line: 1,
                rule: "panic",
                deny: false,
                message: String::new(),
            },
            Finding {
                file: "a".into(),
                line: 2,
                rule: "determinism",
                deny: true,
                message: String::new(),
            },
        ];
        assert_eq!(failing(&fs, false), 1);
        assert_eq!(failing(&fs, true), 2);
    }

    #[test]
    fn clean_source_is_clean() {
        let src = "\
fn add(a: u64, b: u64) -> u64 {\n\
    a.wrapping_add(b)\n\
}\n";
        assert!(lint_source("fleet/soa.rs", src).is_empty());
    }
}
