//! A minimal Rust lexer for `swan lint` — just enough fidelity that
//! the syntactic rules in [`super::rules`] never fire inside string
//! literals, raw strings, char literals, or (nested) block comments.
//!
//! This is not a Rust grammar: the output is a flat token stream with
//! line numbers, hand-rolled in the spirit of `util/json.rs`. The
//! rules only need identifier/punct adjacency (`Instant :: now`,
//! `. unwrap (`), comment text (allow pragmas, `SAFETY:` markers), and
//! balanced-brace scanning (test-span detection), so that is all the
//! lexer models. The genuinely tricky cases it must get right:
//!
//! - raw strings `r"…"` / `r#"…"#` / `br##"…"##` (arbitrary hashes),
//! - raw identifiers `r#type` (an identifier, not a raw string),
//! - nested block comments `/* outer /* inner */ still out */`,
//! - `'a'` char literals vs `'a` lifetimes (including `'\''`, `b'x'`),
//! - multi-line strings, so line numbers stay exact after them.

/// Token classes the rules discriminate on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// Identifier or keyword (including raw identifiers like `r#type`).
    Ident,
    /// Numeric literal (loose: suffixes and float tails are swallowed).
    Num,
    /// `"…"` or `b"…"` string literal.
    Str,
    /// `r"…"` / `r#"…"#` raw string literal (and `br` forms).
    RawStr,
    /// `'x'` / `b'x'` char literal.
    Char,
    /// `'a` lifetime.
    Lifetime,
    /// `// …` line comment (doc comments included).
    LineComment,
    /// `/* … */` block comment, nesting handled.
    BlockComment,
    /// Any other punctuation; `::` is fused into one token.
    Punct,
}

/// One lexed token, borrowing its text from the source.
#[derive(Clone, Copy, Debug)]
pub struct Token<'a> {
    pub kind: Kind,
    pub text: &'a str,
    /// 1-based line the token starts on.
    pub line: u32,
    /// 1-based line the token ends on (multi-line strings/comments).
    pub end_line: u32,
    /// True when no earlier token starts or ends on this token's line.
    pub first_on_line: bool,
}

/// A lexing problem (unterminated literal or comment). The driver
/// reports these as findings instead of panicking.
#[derive(Clone, Debug)]
pub struct LexError {
    pub line: u32,
    pub message: String,
}

fn ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn ident_continue(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Byte length of the UTF-8 codepoint starting with `c`.
fn utf8_len(c: u8) -> usize {
    match c {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Lex `src` into a flat token stream. Never panics: malformed input
/// degrades to single-char punct tokens plus `LexError`s.
pub fn lex(src: &str) -> (Vec<Token<'_>>, Vec<LexError>) {
    let b = src.as_bytes();
    let mut toks: Vec<Token<'_>> = Vec::new();
    let mut errs: Vec<LexError> = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    // Highest line any previous token starts or ends on, for
    // `first_on_line` (pragma own-line vs trailing classification).
    let mut last_line = 0u32;
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        let start = i;
        let start_line = line;
        let kind: Kind;
        if c == b'/' && b.get(i + 1) == Some(&b'/') {
            while i < b.len() && b[i] != b'\n' {
                i += 1;
            }
            kind = Kind::LineComment;
        } else if c == b'/' && b.get(i + 1) == Some(&b'*') {
            i += 2;
            let mut depth = 1usize;
            while i < b.len() && depth > 0 {
                if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            if depth > 0 {
                errs.push(LexError {
                    line: start_line,
                    message: "unterminated block comment".to_string(),
                });
            }
            kind = Kind::BlockComment;
        } else if let Some((quote, hashes)) = raw_str_open(b, i) {
            // r"…" / r#"…"# / br##"…"## — scan for `"` + `hashes` `#`s.
            i = quote + 1;
            let mut closed = false;
            while i < b.len() {
                if b[i] == b'\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == b'"' && tail_hashes(b, i + 1) >= hashes {
                    i += 1 + hashes;
                    closed = true;
                    break;
                } else {
                    i += 1;
                }
            }
            if !closed {
                errs.push(LexError {
                    line: start_line,
                    message: "unterminated raw string".to_string(),
                });
            }
            kind = Kind::RawStr;
        } else if c == b'"' || (c == b'b' && b.get(i + 1) == Some(&b'"'))
        {
            if c == b'b' {
                i += 1;
            }
            i += 1;
            let mut closed = false;
            while i < b.len() {
                match b[i] {
                    b'\\' => i += 2,
                    b'"' => {
                        i += 1;
                        closed = true;
                        break;
                    }
                    b'\n' => {
                        line += 1;
                        i += 1;
                    }
                    _ => i += 1,
                }
            }
            if !closed {
                errs.push(LexError {
                    line: start_line,
                    message: "unterminated string".to_string(),
                });
            }
            kind = Kind::Str;
        } else if c == b'\''
            || (c == b'b' && b.get(i + 1) == Some(&b'\''))
        {
            let byte_prefix = c == b'b';
            if byte_prefix {
                i += 1;
            }
            // i is at the opening quote. `'\…'` and `'X'` are char
            // literals; `'name` (no closing quote after one codepoint)
            // is a lifetime. A `b` prefix always means a byte char.
            if b.get(i + 1) == Some(&b'\\') {
                i += 2;
                // Skip the escaped character itself, so `'\''` and
                // `'\\'` don't close on their own payload.
                i += b.get(i).map_or(0, |&c| utf8_len(c));
                while i < b.len() && b[i] != b'\'' {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
                if i < b.len() {
                    i += 1;
                } else {
                    errs.push(LexError {
                        line: start_line,
                        message: "unterminated char literal".to_string(),
                    });
                }
                kind = Kind::Char;
            } else {
                let cp = b.get(i + 1).map_or(1, |&c| utf8_len(c));
                if b.get(i + 1 + cp) == Some(&b'\'') {
                    i += 2 + cp;
                    kind = Kind::Char;
                } else if byte_prefix {
                    // `b'` with no closing quote: malformed byte char.
                    errs.push(LexError {
                        line: start_line,
                        message: "unterminated byte char".to_string(),
                    });
                    i += 1;
                    kind = Kind::Char;
                } else {
                    i += 1;
                    while i < b.len() && ident_continue(b[i]) {
                        i += 1;
                    }
                    kind = Kind::Lifetime;
                }
            }
        } else if c.is_ascii_digit() {
            i += 1;
            while i < b.len() && ident_continue(b[i]) {
                i += 1;
            }
            // One fractional part, only when a digit follows the dot —
            // keeps `0..n` ranges and `1.max(x)` out of the literal.
            if b.get(i) == Some(&b'.')
                && b.get(i + 1).map_or(false, |d| d.is_ascii_digit())
            {
                i += 1;
                while i < b.len() && ident_continue(b[i]) {
                    i += 1;
                }
            }
            kind = Kind::Num;
        } else if ident_start(c) {
            // `r#type` raw identifier (raw strings were tried above).
            if c == b'r'
                && b.get(i + 1) == Some(&b'#')
                && b.get(i + 2).map_or(false, |&c| ident_start(c))
            {
                i += 2;
            }
            i += 1;
            while i < b.len() && ident_continue(b[i]) {
                i += 1;
            }
            kind = Kind::Ident;
        } else if c == b':' && b.get(i + 1) == Some(&b':') {
            i += 2;
            kind = Kind::Punct;
        } else {
            i += utf8_len(c);
            kind = Kind::Punct;
        }
        let first_on_line = start_line > last_line;
        last_line = last_line.max(line).max(start_line);
        toks.push(Token {
            kind,
            text: &src[start..i],
            line: start_line,
            end_line: line,
            first_on_line,
        });
    }
    (toks, errs)
}

/// If `b[i]` opens a raw string (`r…"` / `br…"`), return the index of
/// the opening quote and the hash count.
fn raw_str_open(b: &[u8], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if b.get(j) == Some(&b'b') {
        j += 1;
    }
    if b.get(j) != Some(&b'r') {
        return None;
    }
    j += 1;
    let hashes = tail_hashes(b, j);
    j += hashes;
    if b.get(j) == Some(&b'"') {
        Some((j, hashes))
    } else {
        None
    }
}

/// Count consecutive `#` bytes starting at `i`.
fn tail_hashes(b: &[u8], i: usize) -> usize {
    let mut n = 0;
    while b.get(i + n) == Some(&b'#') {
        n += 1;
    }
    n
}

fn is_comment(t: &Token<'_>) -> bool {
    matches!(t.kind, Kind::LineComment | Kind::BlockComment)
}

/// Line spans (inclusive) covered by `#[test]`- or `#[cfg(test)]`-
/// attributed items: the attribute line through the item's closing
/// brace. The rules use these to exempt test code.
pub fn test_spans(tokens: &[Token<'_>]) -> Vec<(u32, u32)> {
    let code: Vec<&Token<'_>> =
        tokens.iter().filter(|t| !is_comment(t)).collect();
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i < code.len() {
        let opens_attr = |k: usize| {
            k + 1 < code.len()
                && code[k].text == "#"
                && code[k + 1].text == "["
        };
        if !opens_attr(i) {
            i += 1;
            continue;
        }
        // Collect this attribute; any `test` identifier inside marks
        // the following item as test-only (`#[test]`, `#[cfg(test)]`,
        // `#[cfg(all(test, …))]`).
        let attr_line = code[i].line;
        let mut j = i + 2;
        let mut depth = 1i32;
        let mut is_test = false;
        while j < code.len() && depth > 0 {
            match code[j].text {
                "[" => depth += 1,
                "]" => depth -= 1,
                "test" if code[j].kind == Kind::Ident => is_test = true,
                _ => {}
            }
            j += 1;
        }
        if !is_test {
            i = j;
            continue;
        }
        // Skip further attributes stacked on the same item.
        while opens_attr(j) {
            j += 2;
            let mut d = 1i32;
            while j < code.len() && d > 0 {
                match code[j].text {
                    "[" => d += 1,
                    "]" => d -= 1,
                    _ => {}
                }
                j += 1;
            }
        }
        // Find the item's body: the first `{` at paren/bracket depth
        // 0. A `;` first (e.g. `mod tests;`) means no inline body.
        let mut d = 0i32;
        let mut open = None;
        while j < code.len() {
            match code[j].text {
                "(" | "[" => d += 1,
                ")" | "]" => d -= 1,
                "{" if d == 0 => {
                    open = Some(j);
                    break;
                }
                ";" if d == 0 => break,
                _ => {}
            }
            j += 1;
        }
        let Some(open) = open else {
            let end = j.min(code.len().saturating_sub(1));
            spans.push((attr_line, code[end].end_line));
            i = j + 1;
            continue;
        };
        // Match the body braces. Strings and comments are already
        // tokenized away, so every `{`/`}` punct here is structural.
        let mut bd = 0i32;
        let mut k = open;
        let mut end_line = code[open].end_line;
        while k < code.len() {
            match code[k].text {
                "{" => bd += 1,
                "}" => {
                    bd -= 1;
                    if bd == 0 {
                        end_line = code[k].end_line;
                        break;
                    }
                }
                _ => {}
            }
            k += 1;
        }
        if bd != 0 {
            // Unbalanced (malformed source): exempt to end of file
            // rather than mis-flagging half a test module.
            end_line = code.last().map_or(end_line, |t| t.end_line);
        }
        spans.push((attr_line, end_line));
        i = k + 1;
    }
    spans
}

/// True when `line` falls inside any of `spans` (inclusive).
pub fn in_spans(spans: &[(u32, u32)], line: u32) -> bool {
    spans.iter().any(|&(a, b)| a <= line && line <= b)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(Kind, String)> {
        let (toks, errs) = lex(src);
        assert!(errs.is_empty(), "lex errors: {errs:?}");
        toks.iter().map(|t| (t.kind, t.text.to_string())).collect()
    }

    #[test]
    fn idents_puncts_and_paths() {
        let ks = kinds("let x = Instant::now();");
        let texts: Vec<&str> =
            ks.iter().map(|(_, t)| t.as_str()).collect();
        assert_eq!(
            texts,
            vec!["let", "x", "=", "Instant", "::", "now", "(", ")", ";"]
        );
        assert_eq!(ks[4].0, Kind::Punct, ":: fuses into one token");
    }

    #[test]
    fn strings_hide_their_contents() {
        let ks = kinds(r#"let s = "Instant::now() // not a comment";"#);
        assert!(ks.iter().any(|(k, _)| *k == Kind::Str));
        assert!(
            !ks.iter().any(|(k, t)| *k == Kind::Ident && t == "Instant"),
            "identifier leaked out of a string literal"
        );
        assert!(!ks.iter().any(|(k, _)| *k == Kind::LineComment));
    }

    #[test]
    fn raw_strings_with_hashes() {
        let src = "let s = r##\"has \"# quote and .unwrap()\"## ;";
        let ks = kinds(src);
        assert!(ks.iter().any(|(k, _)| *k == Kind::RawStr));
        assert!(
            !ks.iter().any(|(k, t)| *k == Kind::Ident && t == "unwrap")
        );
        // The `;` after the raw string still lexes.
        assert_eq!(ks.last().map(|(_, t)| t.as_str()), Some(";"));
    }

    #[test]
    fn raw_identifier_is_not_a_raw_string() {
        let ks = kinds("let r#type = 1;");
        assert!(
            ks.iter().any(|(k, t)| *k == Kind::Ident && t == "r#type")
        );
    }

    #[test]
    fn nested_block_comments() {
        let ks =
            kinds("/* outer /* inner .unwrap() */ still */ let a = 1;");
        assert_eq!(ks[0].0, Kind::BlockComment);
        assert!(
            !ks.iter().any(|(k, t)| *k == Kind::Ident && t == "unwrap")
        );
        assert!(ks.iter().any(|(k, t)| *k == Kind::Ident && t == "let"));
    }

    #[test]
    fn char_vs_lifetime() {
        let ks = kinds("fn f<'a>(x: &'a u8) { let c = 'x'; let q = '\\''; }");
        assert_eq!(
            ks.iter().filter(|(k, _)| *k == Kind::Lifetime).count(),
            2,
            "two 'a lifetimes"
        );
        assert_eq!(
            ks.iter().filter(|(k, _)| *k == Kind::Char).count(),
            2,
            "'x' and the escaped quote are char literals"
        );
    }

    #[test]
    fn multiline_string_keeps_line_numbers() {
        let src = "let a = \"line\none\ntwo\";\nlet b = 1;";
        let (toks, errs) = lex(src);
        assert!(errs.is_empty());
        let b_tok = toks
            .iter()
            .find(|t| t.kind == Kind::Ident && t.text == "b")
            .expect("b token");
        assert_eq!(b_tok.line, 4);
        assert!(!b_tok.first_on_line, "`let` starts line 4, not `b`");
        let let_b = toks
            .iter()
            .filter(|t| t.text == "let")
            .nth(1)
            .expect("second let");
        assert!(let_b.first_on_line);
    }

    #[test]
    fn unterminated_string_reports_instead_of_panicking() {
        let (_, errs) = lex("let s = \"never closed");
        assert_eq!(errs.len(), 1);
        assert!(errs[0].message.contains("unterminated"));
    }

    #[test]
    fn test_spans_cover_cfg_test_modules_and_test_fns() {
        let src = "\
fn live() {}\n\
#[test]\n\
fn unit() {\n\
    let x = 1;\n\
}\n\
#[cfg(test)]\n\
mod tests {\n\
    fn helper() {}\n\
}\n\
fn live2() {}\n";
        let (toks, _) = lex(src);
        let spans = test_spans(&toks);
        assert_eq!(spans, vec![(2, 5), (6, 9)]);
        assert!(!in_spans(&spans, 1));
        assert!(in_spans(&spans, 4));
        assert!(in_spans(&spans, 8));
        assert!(!in_spans(&spans, 10));
    }

    #[test]
    fn test_spans_skip_stacked_attributes() {
        let src = "\
#[test]\n\
#[ignore] // microbench\n\
fn bench_like() {\n\
    let t = 0;\n\
}\n";
        let (toks, _) = lex(src);
        let spans = test_spans(&toks);
        assert_eq!(spans, vec![(1, 5)]);
    }
}
