//! Per-site allow pragmas.
//!
//! Syntax (plain `//` comments only — doc comments are never parsed,
//! so rule documentation can quote the form freely):
//!
//! ```text
//! // lint: allow(rule[, rule…]) — reason the suppression is sound
//! ```
//!
//! A pragma on its own line suppresses matching findings on the next
//! code line; a trailing pragma suppresses findings on its own line.
//! The reason is mandatory (after `—`, `--`, or `:`), and the driver
//! rejects pragmas that suppress nothing — the allowlist can only
//! shrink.

use super::lexer::{Kind, Token};

/// One parsed allow pragma.
#[derive(Clone, Debug)]
pub struct Pragma {
    /// Line the pragma comment sits on.
    pub line: u32,
    /// Line whose findings it suppresses (0 when nothing follows it).
    pub target_line: u32,
    /// Rule names inside `allow(…)`.
    pub rules: Vec<String>,
    /// Text after the separator; empty is a hygiene violation.
    pub reason: String,
}

/// Extract pragmas from the token stream. Comments that start with
/// `lint:` but don't parse are pushed onto `malformed` as
/// `(line, message)` for the driver to report.
pub fn parse(
    tokens: &[Token<'_>],
    malformed: &mut Vec<(u32, String)>,
) -> Vec<Pragma> {
    let mut out = Vec::new();
    for (idx, t) in tokens.iter().enumerate() {
        if t.kind != Kind::LineComment {
            continue;
        }
        if t.text.starts_with("///") || t.text.starts_with("//!") {
            continue;
        }
        let body = t.text.trim_start_matches('/').trim_start();
        let Some(rest) = body.strip_prefix("lint:") else {
            continue;
        };
        match parse_body(rest) {
            Ok((rules, reason)) => {
                let target_line = if t.first_on_line {
                    next_code_line(tokens, idx)
                } else {
                    t.line
                };
                out.push(Pragma {
                    line: t.line,
                    target_line,
                    rules,
                    reason,
                });
            }
            Err(msg) => malformed.push((t.line, msg)),
        }
    }
    out
}

fn parse_body(rest: &str) -> Result<(Vec<String>, String), String> {
    let rest = rest.trim_start();
    let Some(rest) = rest.strip_prefix("allow") else {
        return Err(
            "malformed pragma: expected `allow(…)` after `lint:`"
                .to_string(),
        );
    };
    let rest = rest.trim_start();
    let Some(rest) = rest.strip_prefix('(') else {
        return Err(
            "malformed pragma: expected `(` after `allow`".to_string()
        );
    };
    let Some(close) = rest.find(')') else {
        return Err("malformed pragma: unclosed `allow(`".to_string());
    };
    let rules: Vec<String> = rest[..close]
        .split(',')
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty())
        .collect();
    if rules.is_empty() {
        return Err(
            "malformed pragma: empty rule list in `allow()`".to_string()
        );
    }
    let mut reason = rest[close + 1..].trim();
    for sep in ["—", "–", "--", "-", ":"] {
        if let Some(r) = reason.strip_prefix(sep) {
            reason = r.trim_start();
            break;
        }
    }
    Ok((rules, reason.to_string()))
}

/// Line of the first code token after `idx` (0 when none).
fn next_code_line(tokens: &[Token<'_>], idx: usize) -> u32 {
    tokens[idx + 1..]
        .iter()
        .find(|t| {
            !matches!(t.kind, Kind::LineComment | Kind::BlockComment)
        })
        .map_or(0, |t| t.line)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::lex;

    fn pragmas(src: &str) -> (Vec<Pragma>, Vec<(u32, String)>) {
        let (toks, errs) = lex(src);
        assert!(errs.is_empty(), "lex errors: {errs:?}");
        let mut malformed = Vec::new();
        let ps = parse(&toks, &mut malformed);
        (ps, malformed)
    }

    #[test]
    fn own_line_pragma_targets_next_code_line() {
        let src = "\
// lint: allow(determinism) — timing is report-only here\n\
let t = Instant::now();\n";
        let (ps, bad) = pragmas(src);
        assert!(bad.is_empty());
        assert_eq!(ps.len(), 1);
        assert_eq!(ps[0].line, 1);
        assert_eq!(ps[0].target_line, 2);
        assert_eq!(ps[0].rules, vec!["determinism"]);
        assert_eq!(ps[0].reason, "timing is report-only here");
    }

    #[test]
    fn trailing_pragma_targets_its_own_line() {
        let src =
            "let t = now(); // lint: allow(determinism) -- report-only\n";
        let (ps, bad) = pragmas(src);
        assert!(bad.is_empty());
        assert_eq!(ps[0].target_line, 1);
        assert_eq!(ps[0].reason, "report-only");
    }

    #[test]
    fn multiple_rules_and_ascii_separator() {
        let src = "\
// lint: allow(determinism, panic) - both are test-harness-only\n\
x();\n";
        let (ps, _) = pragmas(src);
        assert_eq!(ps[0].rules, vec!["determinism", "panic"]);
        assert_eq!(ps[0].reason, "both are test-harness-only");
    }

    #[test]
    fn missing_reason_parses_as_empty() {
        let (ps, bad) = pragmas("// lint: allow(unsafe)\nx();\n");
        assert!(bad.is_empty());
        assert_eq!(ps[0].reason, "");
    }

    #[test]
    fn malformed_pragmas_are_reported() {
        let (ps, bad) = pragmas("// lint: deny(everything)\nx();\n");
        assert!(ps.is_empty());
        assert_eq!(bad.len(), 1);
        assert!(bad[0].1.contains("allow"));
        let (ps2, bad2) = pragmas("// lint: allow(\nx();\n");
        assert!(ps2.is_empty());
        assert_eq!(bad2.len(), 1);
    }

    #[test]
    fn doc_comments_are_not_pragmas() {
        let (ps, bad) =
            pragmas("/// lint: allow(determinism) — just docs\nx();\n");
        assert!(ps.is_empty());
        assert!(bad.is_empty());
    }

    #[test]
    fn pragma_inside_string_is_inert() {
        let src = "let s = \"// lint: allow(panic) — not real\";\n";
        let (ps, bad) = pragmas(src);
        assert!(ps.is_empty());
        assert!(bad.is_empty());
    }
}
