//! The rule families `swan lint` enforces, as scans over the token
//! stream from [`super::lexer`].
//!
//! Scopes are path-based on the module-relative file name (see
//! `super::rel_path`): the determinism and RNG rules cover the
//! digest-affecting modules, the panic rule covers shard-worker and
//! serve-IO paths, and unsafe hygiene is crate-wide. `#[test]` /
//! `#[cfg(test)]` spans are exempt from everything except unsafe
//! hygiene — a test that needs `unsafe` still needs a `SAFETY:` story.

use super::lexer::{in_spans, Kind, Token};

/// Rule names a pragma may `allow`. `pragma` and `lex` findings are
/// deliberately absent: suppressions and broken lexes can't be
/// suppressed, so the allowlist can only shrink.
pub const ALLOWABLE: &[&str] = &["determinism", "rng", "panic", "unsafe"];

/// One lint finding. `deny` findings fail the run unconditionally;
/// warn findings (the panic family) fail only under `--deny-all`.
#[derive(Clone, Debug)]
pub struct Finding {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub deny: bool,
    pub message: String,
}

/// Modules whose state feeds round/aggregate digests: determinism and
/// RNG-discipline rules apply here. `obs/` is deliberately absent —
/// its contract is digest *neutrality* (enforced by the `obs_stream`
/// tests), and it owns the audited wall-clock chokepoint
/// [`crate::obs::wall_timer`].
fn digest_scope(rel: &str) -> bool {
    rel.starts_with("fleet/")
        || rel.starts_with("fl/")
        || matches!(
            rel,
            "serve/coordinator.rs"
                | "serve/wire.rs"
                | "serve/cache.rs"
                | "util/rng.rs"
                | "util/fnv.rs"
        )
}

/// Shard-worker and serve-IO paths: a panic here tears down a worker
/// mid-round (poisoned mailbox, dead IO lane) instead of surfacing
/// through `error.rs`. `fleet/bench.rs` stays out on purpose — its
/// determinism asserts are deliberate crash-on-divergence gates.
fn panic_scope(rel: &str) -> bool {
    matches!(
        rel,
        "serve/server.rs"
            | "serve/client.rs"
            | "serve/coordinator.rs"
            | "serve/wire.rs"
            | "fleet/engine.rs"
            | "fleet/soa.rs"
            | "fleet/coordinator.rs"
            | "fl/engine.rs"
            | "fl/server.rs"
    )
}

/// Files allowed to construct or fork `Rng` streams, with why.
/// Everything else in the digest scope must thread an existing stream
/// through — a new construction site reorders the draw sequence
/// `tests/fleet_batch_parity.rs` pins.
pub const RNG_REGISTRY: &[(&str, &str)] = &[
    ("util/rng.rs", "the generator's home module"),
    (
        "fleet/engine.rs",
        "round_rng: the (seed, round)-keyed selection stream",
    ),
    (
        "fleet/scenario.rs",
        "build_fleet: per-device trace/charger assignment streams",
    ),
    (
        "fleet/device.rs",
        "envelope_draws: the per-device charger envelope stream",
    ),
    (
        "fl/sim.rs",
        "FlSim::new: per-client credit streams derived from the root seed",
    ),
    (
        "fl/engine.rs",
        "ClientLanes::new band-seed stream + step_order's \
         (seed, client, round)-keyed local-step shuffle",
    ),
];

/// Hash-container methods whose visit order is allocation-dependent.
const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
    "retain",
];

const PANIC_MACROS: &[&str] =
    &["panic", "unreachable", "todo", "unimplemented"];

/// Run every applicable rule family over one file's tokens.
pub fn scan(
    rel: &str,
    tokens: &[Token<'_>],
    tests: &[(u32, u32)],
    out: &mut Vec<Finding>,
) {
    let code: Vec<&Token<'_>> = tokens
        .iter()
        .filter(|t| {
            !matches!(t.kind, Kind::LineComment | Kind::BlockComment)
        })
        .collect();
    if digest_scope(rel) {
        determinism(&code, tests, out);
        rng_discipline(rel, &code, tests, out);
    }
    if panic_scope(rel) {
        panic_safety(&code, tests, out);
    }
    unsafe_hygiene(tokens, out);
}

fn finding(
    rule: &'static str,
    deny: bool,
    line: u32,
    message: String,
) -> Finding {
    Finding {
        file: String::new(),
        line,
        rule,
        deny,
        message,
    }
}

fn text_at(code: &[&Token<'_>], i: usize) -> &str {
    code.get(i).map_or("", |t| t.text)
}

fn ident_at(code: &[&Token<'_>], i: usize, name: &str) -> bool {
    code.get(i)
        .map_or(false, |t| t.kind == Kind::Ident && t.text == name)
}

/// Rule `determinism`: no wall clock, no hash-ordered iteration, in
/// digest-affecting modules.
fn determinism(
    code: &[&Token<'_>],
    tests: &[(u32, u32)],
    out: &mut Vec<Finding>,
) {
    let tracked = hash_bindings(code);
    for i in 0..code.len() {
        let t = code[i];
        if t.kind != Kind::Ident || in_spans(tests, t.line) {
            continue;
        }
        if t.text == "SystemTime" {
            out.push(finding(
                "determinism",
                true,
                t.line,
                "`SystemTime` in a digest-affecting module — wall time \
                 is nondeterministic"
                    .to_string(),
            ));
            continue;
        }
        if t.text == "Instant"
            && text_at(code, i + 1) == "::"
            && ident_at(code, i + 2, "now")
        {
            out.push(finding(
                "determinism",
                true,
                t.line,
                "`Instant::now()` in a digest-affecting module — route \
                 telemetry timing through `obs::wall_timer()`"
                    .to_string(),
            ));
            continue;
        }
        if tracked.binary_search(&t.text).is_err() {
            continue;
        }
        // `name.iter()` / `name.keys()` / … on a hash-typed binding.
        if text_at(code, i + 1) == "."
            && code.get(i + 2).map_or(false, |m| {
                m.kind == Kind::Ident && ITER_METHODS.contains(&m.text)
            })
        {
            out.push(finding(
                "determinism",
                true,
                t.line,
                format!(
                    "iteration over hash-ordered `{}` (`.{}()`) in a \
                     digest-affecting module — fold over a sorted key \
                     list instead",
                    t.text,
                    text_at(code, i + 2),
                ),
            ));
            continue;
        }
        // `for x in name` / `for x in &mut name`.
        let mut p = i;
        while p > 0
            && (text_at(code, p - 1) == "&"
                || ident_at(code, p - 1, "mut"))
        {
            p -= 1;
        }
        if p > 0 && ident_at(code, p - 1, "in") {
            out.push(finding(
                "determinism",
                true,
                t.line,
                format!(
                    "for-loop over hash-ordered `{}` in a \
                     digest-affecting module — fold over a sorted key \
                     list instead",
                    t.text,
                ),
            ));
        }
    }
}

/// Identifiers bound to `HashMap`/`HashSet` in this file, from `let`
/// bindings, type ascriptions, struct fields, and fn params. Coarse
/// (name-based, file-global) by design: a collision with a same-named
/// non-hash binding can be pragma'd with a reason.
fn hash_bindings<'a>(code: &[&Token<'a>]) -> Vec<&'a str> {
    let mut names: Vec<&'a str> = Vec::new();
    for i in 0..code.len() {
        let t = code[i];
        if t.kind != Kind::Ident
            || (t.text != "HashMap" && t.text != "HashSet")
        {
            continue;
        }
        // Walk left past a `std::collections::` path prefix…
        let mut j = i;
        while j >= 2 && text_at(code, j - 1) == "::" {
            j -= 2;
        }
        if j == 0 {
            continue;
        }
        // …then past `&`, `mut`, and lifetimes to the `:` or `=` that
        // links the type to its binder.
        let mut k = j - 1;
        while k > 0
            && (text_at(code, k) == "&"
                || ident_at(code, k, "mut")
                || code[k].kind == Kind::Lifetime)
        {
            k -= 1;
        }
        let sep = text_at(code, k);
        if (sep == ":" || sep == "=")
            && k > 0
            && code[k - 1].kind == Kind::Ident
        {
            names.push(code[k - 1].text);
        }
    }
    names.sort_unstable();
    names.dedup();
    names
}

/// Rule `rng`: `Rng::new` / `.fork(` only in registered files.
fn rng_discipline(
    rel: &str,
    code: &[&Token<'_>],
    tests: &[(u32, u32)],
    out: &mut Vec<Finding>,
) {
    if RNG_REGISTRY.iter().any(|(f, _)| *f == rel) {
        return;
    }
    for i in 0..code.len() {
        let t = code[i];
        if t.kind != Kind::Ident || in_spans(tests, t.line) {
            continue;
        }
        if t.text == "Rng"
            && text_at(code, i + 1) == "::"
            && ident_at(code, i + 2, "new")
        {
            out.push(finding(
                "rng",
                true,
                t.line,
                "`Rng::new` outside a registered construction site — \
                 a new stream reorders the draw sequence the parity \
                 tests pin; thread an existing stream through, or \
                 register this site in lint::rules::RNG_REGISTRY"
                    .to_string(),
            ));
        }
        if t.text == "fork"
            && text_at(code, i.wrapping_sub(1)) == "."
            && i > 0
            && text_at(code, i + 1) == "("
        {
            out.push(finding(
                "rng",
                true,
                t.line,
                "`.fork(…)` derives a new RNG stream outside a \
                 registered site — register it in \
                 lint::rules::RNG_REGISTRY or reuse an existing stream"
                    .to_string(),
            ));
        }
    }
}

/// Rule `panic`: worker/IO paths must propagate through `error.rs`.
fn panic_safety(
    code: &[&Token<'_>],
    tests: &[(u32, u32)],
    out: &mut Vec<Finding>,
) {
    for i in 0..code.len() {
        let t = code[i];
        if t.kind != Kind::Ident || in_spans(tests, t.line) {
            continue;
        }
        if (t.text == "unwrap" || t.text == "expect")
            && i > 0
            && text_at(code, i - 1) == "."
            && text_at(code, i + 1) == "("
        {
            out.push(finding(
                "panic",
                false,
                t.line,
                format!(
                    "`.{}()` on a shard-worker/serve-IO path — \
                     propagate through `error.rs` (`crate::Result`)",
                    t.text,
                ),
            ));
        }
        if PANIC_MACROS.contains(&t.text)
            && text_at(code, i + 1) == "!"
        {
            out.push(finding(
                "panic",
                false,
                t.line,
                format!(
                    "`{}!` on a shard-worker/serve-IO path — return an \
                     `error.rs` error instead of tearing the worker \
                     down",
                    t.text,
                ),
            ));
        }
    }
}

/// Rule `unsafe`: every `unsafe` keyword needs a `SAFETY:` comment
/// whose comment run ends on the same line or within the three lines
/// above. A multi-line justification is a run of consecutive `//`
/// lines with the marker only on the first, so the marker comment's
/// reach extends through the contiguous comment lines that follow it.
/// Runs over the full token stream (comments included) and does not
/// exempt tests.
fn unsafe_hygiene(tokens: &[Token<'_>], out: &mut Vec<Finding>) {
    let comments: Vec<&Token<'_>> = tokens
        .iter()
        .filter(|c| {
            matches!(c.kind, Kind::LineComment | Kind::BlockComment)
        })
        .collect();
    let mut safety_spans: Vec<(u32, u32)> = Vec::new();
    for (i, c) in comments.iter().enumerate() {
        if !c.text.contains("SAFETY:") {
            continue;
        }
        let mut end = c.end_line;
        for d in &comments[i + 1..] {
            if d.line > end + 1 {
                break;
            }
            end = end.max(d.end_line);
        }
        safety_spans.push((c.line, end));
    }
    for t in tokens {
        if t.kind != Kind::Ident || t.text != "unsafe" {
            continue;
        }
        let covered = safety_spans
            .iter()
            .any(|&(start, end)| start <= t.line && end + 3 >= t.line);
        if !covered {
            out.push(finding(
                "unsafe",
                true,
                t.line,
                "`unsafe` without a `// SAFETY:` comment on the same \
                 line or the three lines above"
                    .to_string(),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::lint::lint_source;

    fn rules_hit(name: &str, src: &str) -> Vec<&'static str> {
        let mut rs: Vec<&'static str> =
            lint_source(name, src).into_iter().map(|f| f.rule).collect();
        rs.sort_unstable();
        rs.dedup();
        rs
    }

    #[test]
    fn instant_now_flagged_in_digest_scope_only() {
        let src = "fn f() { let t = Instant::now(); }\n";
        assert_eq!(rules_hit("fleet/soa.rs", src), vec!["determinism"]);
        assert!(rules_hit("obs/span.rs", src).is_empty());
        assert!(rules_hit("sim/clock.rs", src).is_empty());
    }

    #[test]
    fn system_time_flagged() {
        let src = "fn f() { let t = SystemTime::now(); }\n";
        assert_eq!(
            rules_hit("serve/coordinator.rs", src),
            vec!["determinism"]
        );
    }

    #[test]
    fn hash_iteration_flagged_but_keyed_access_is_not() {
        let bad = "\
fn f(m: &HashMap<u32, u32>) -> u32 {\n\
    let mut acc = 0;\n\
    for (_k, v) in m.iter() {\n\
        acc += *v;\n\
    }\n\
    acc\n\
}\n";
        assert_eq!(rules_hit("fl/server.rs", bad), vec!["determinism"]);
        let good = "\
fn f(m: &HashMap<u32, u32>, keys: &[u32]) -> u32 {\n\
    let mut acc = 0;\n\
    for k in keys {\n\
        acc += m.get(k).copied().unwrap_or(0);\n\
    }\n\
    acc\n\
}\n";
        assert!(rules_hit("fl/server.rs", good).is_empty());
    }

    #[test]
    fn for_loop_over_hash_binding_flagged() {
        let src = "\
fn f() {\n\
    let mut s = HashSet::new();\n\
    s.insert(1);\n\
    for v in &s {\n\
        drop(v);\n\
    }\n\
}\n";
        assert_eq!(
            rules_hit("fleet/engine.rs", src),
            vec!["determinism"]
        );
    }

    #[test]
    fn rng_construction_outside_registry_flagged() {
        let src = "fn f() -> u64 { Rng::new(7).next_u64() }\n";
        assert_eq!(rules_hit("fl/server.rs", src), vec!["rng"]);
        // Registered site: fine.
        assert!(rules_hit("fl/sim.rs", src).is_empty());
        // Out of digest scope: fine.
        assert!(rules_hit("trace/gen.rs", src).is_empty());
    }

    #[test]
    fn fork_outside_registry_flagged() {
        let src = "fn f(r: &mut Rng) -> Rng { r.fork(3) }\n";
        assert_eq!(rules_hit("fleet/soa.rs", src), vec!["rng"]);
        assert!(rules_hit("fleet/scenario.rs", src).is_empty());
    }

    #[test]
    fn panic_family_flagged_in_worker_paths_only() {
        let src = "\
fn f(x: Option<u32>) -> u32 {\n\
    if x.is_none() {\n\
        panic!(\"boom\");\n\
    }\n\
    x.unwrap()\n\
}\n";
        let hits = rules_hit("serve/server.rs", src);
        assert_eq!(hits, vec!["panic"]);
        assert!(rules_hit("fleet/bench.rs", src).is_empty());
        // unwrap_or_else is not unwrap: exact-identifier matching.
        let ok = "fn f(x: Option<u32>) -> u32 { x.unwrap_or_else(|| 0) }\n";
        assert!(rules_hit("serve/server.rs", ok).is_empty());
    }

    #[test]
    fn test_code_is_exempt_except_unsafe() {
        let src = "\
#[cfg(test)]\n\
mod tests {\n\
    #[test]\n\
    fn t() {\n\
        let r = Rng::new(1);\n\
        let t = Instant::now();\n\
        r.x.unwrap();\n\
        drop(t);\n\
    }\n\
}\n";
        assert!(rules_hit("fleet/soa.rs", src).is_empty());
        let unsafe_in_test = "\
#[test]\n\
fn t() {\n\
    let p = core::ptr::null::<u8>();\n\
    let _v = unsafe { p.read() };\n\
}\n";
        assert_eq!(
            rules_hit("util/affinity.rs", unsafe_in_test),
            vec!["unsafe"]
        );
    }

    #[test]
    fn safety_comment_satisfies_unsafe_hygiene() {
        let src = "\
fn f(p: *const u8) -> u8 {\n\
    // SAFETY: caller guarantees p is valid for reads.\n\
    unsafe { *p }\n\
}\n";
        assert!(rules_hit("util/affinity.rs", src).is_empty());
        let far = "\
fn f(p: *const u8) -> u8 {\n\
    // SAFETY: too far away to count.\n\
    let a = 1;\n\
    let b = a + 1;\n\
    let c = b + 1;\n\
    let d = c + 1;\n\
    drop((a, b, c, d));\n\
    unsafe { *p }\n\
}\n";
        assert_eq!(rules_hit("util/affinity.rs", far), vec!["unsafe"]);
    }

    #[test]
    fn multi_line_safety_run_reaches_the_unsafe_block() {
        // marker on the first line only; the run of consecutive `//`
        // lines must carry its reach down to the `unsafe`
        let src = "\
fn f(p: *const u8) -> u8 {\n\
    // SAFETY: p is valid for reads because the caller derived it\n\
    // from a live &[u8] borrow two frames up, and the read cannot\n\
    // outlive that borrow; nothing here mutates through it, and\n\
    // the pointee is plain-old-data so no drop glue can run.\n\
    // (Deliberately long: only the first line has the marker.)\n\
    unsafe { *p }\n\
}\n";
        assert!(rules_hit("util/affinity.rs", src).is_empty());
        // a gap in the run breaks the chain: the marker's reach stops
        // at the blank-separated comment, leaving the unsafe uncovered
        let gapped = "\
fn f(p: *const u8) -> u8 {\n\
    // SAFETY: reach ends here.\n\
\n\
    let a = 1;\n\
    let b = a + 1;\n\
    let c = b + 1;\n\
    drop((a, b, c));\n\
    // unrelated trailing note, no marker\n\
    unsafe { *p }\n\
}\n";
        assert_eq!(rules_hit("util/affinity.rs", gapped), vec!["unsafe"]);
    }

    #[test]
    fn pragma_suppresses_and_unused_pragma_fails() {
        let suppressed = "\
fn f() {\n\
    // lint: allow(determinism) — report-only telemetry timing\n\
    let t = Instant::now();\n\
    drop(t);\n\
}\n";
        assert!(rules_hit("fleet/soa.rs", suppressed).is_empty());
        let unused = "\
fn f() {\n\
    // lint: allow(determinism) — nothing here needs it\n\
    let t = 1;\n\
    drop(t);\n\
}\n";
        assert_eq!(rules_hit("fleet/soa.rs", unused), vec!["pragma"]);
    }

    #[test]
    fn pragma_without_reason_fails_even_when_it_suppresses() {
        let src = "\
fn f() {\n\
    let t = Instant::now(); // lint: allow(determinism)\n\
    drop(t);\n\
}\n";
        assert_eq!(rules_hit("fleet/soa.rs", src), vec!["pragma"]);
    }

    #[test]
    fn unknown_rule_in_pragma_fails() {
        let src = "\
fn f() {\n\
    // lint: allow(vibes) — not a rule\n\
    let t = 1;\n\
    drop(t);\n\
}\n";
        assert_eq!(rules_hit("fleet/soa.rs", src), vec!["pragma"]);
    }

    #[test]
    fn violations_inside_literals_do_not_fire() {
        let src = "\
fn f() -> &'static str {\n\
    // a comment mentioning Instant::now() is fine\n\
    \"Instant::now() .unwrap() panic!\"\n\
}\n";
        assert!(rules_hit("fleet/soa.rs", src).is_empty());
        let raw = "\
fn f() -> &'static str {\n\
    r#\"Rng::new(1) for x in m.iter()\"#\n\
}\n";
        assert!(rules_hit("serve/coordinator.rs", raw).is_empty());
    }
}
