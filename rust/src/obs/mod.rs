//! `obs` — the zero-dependency telemetry spine.
//!
//! Three layers share one sink:
//!
//! 1. **events** ([`event`]): cargo `machine_message`-style NDJSON —
//!    every record is one JSON object per line with a `"reason"`
//!    discriminator and a monotone `"seq"`, written to stderr, an
//!    `--events <path>` file, or an in-memory capture for tests.
//! 2. **metrics** ([`metrics`]): named counters and fixed-bucket
//!    latency histograms, recorded shard-/lane-locally and merged
//!    deterministically at round barriers (the FNV-digest discipline),
//!    so recording never takes a lock on the SoA hot path.
//! 3. **spans** ([`span`]): scoped phase timers (availability sweep,
//!    select, step, aggregate, flush) that land in both the event
//!    stream and `report::obs_table`.
//! 4. **traces** ([`trace`]): opt-in per-device lifecycle edges keyed
//!    `(round, device_id)` with monotonic timestamps, stamped at the
//!    coordinator/drive barrier points.
//!
//! The consume side lives in [`analyze`]: lifecycle reconstruction,
//! stage/straggler attribution, windowed rates, and run-vs-run diffing
//! over any NDJSON stream or `BENCH_*.json` snapshot — the engine
//! behind `swan obs trace|top|rates|diff`.
//!
//! The load-bearing invariant is **digest neutrality**: enabling any
//! of this must not change a single bit of `FleetOutcome` digests or
//! the serve coordinator's aggregate digest. Telemetry therefore only
//! *observes* existing control-flow boundaries — it never adds RNG
//! draws, reorders float folds, or injects barriers of its own.

pub mod analyze;
pub mod event;
pub mod metrics;
pub mod span;
pub mod trace;

pub use event::{
    BenchResult, CacheHitMiss, CheckinBatch, Deferral, LaneBurst,
    LateCarryover, Obs, ObsEvent, ProfileAdopted, ProfileExplored,
    RoundEnd, RoundStart, ServeRoundEnd, ServeStart, ShardProgress,
    SpanSummary,
};
pub use metrics::{
    CounterId, HistId, Histogram, MetricsRegistry, LATENCY_BUCKETS_S,
};
pub use span::{
    SpanEntry, SpanId, Spans, PHASE_AGGREGATE, PHASE_AVAILABILITY,
    PHASE_CLOSE, PHASE_FINISH, PHASE_FLUSH, PHASE_SELECT, PHASE_STEP,
};
pub use trace::{TraceClock, TraceEdge};

/// The audited wall-clock read for digest-affecting modules.
///
/// `swan lint`'s determinism rule forbids `Instant::now()` inside
/// `fleet`/`fl`/the serve coordinator, so those modules time their
/// phases through this single obs-owned chokepoint instead. Timing is
/// telemetry: the values land in spans, metrics, and `BENCH_*.json`
/// records, never in digests — keeping every wall-clock read behind
/// one audited symbol is what makes that reviewable.
#[inline]
pub fn wall_timer() -> std::time::Instant {
    std::time::Instant::now()
}
