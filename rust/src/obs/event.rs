//! Layer 1 of the telemetry spine: the NDJSON event stream.
//!
//! Follows cargo's `machine_message` idiom: every record is one JSON
//! object per line with a leading `"reason"` discriminator, built from
//! [`crate::util::json::Value`] (no serde in the offline crate set).
//! The sink assigns a monotonically increasing `seq` under the same
//! lock that writes the line, so file order always equals seq order.
//!
//! [`Obs`] is a cheap cloneable handle; [`Obs::off`] (the `Default`)
//! makes every emit a no-op behind a single `Option` check, which is
//! what lets telemetry be compiled into the hot paths while staying
//! digest-neutral and cost-free when disabled.

use crate::util::json::Value;
use std::fmt;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::sync::{Arc, Mutex};

/// A typed telemetry record: a `'static` reason plus a JSON payload.
/// Payloads should be `Value::Obj`s — their fields are inlined after
/// `reason`/`seq` in the emitted line.
pub trait ObsEvent {
    fn reason(&self) -> &'static str;
    fn payload(&self) -> Value;
}

enum Target {
    Stderr,
    File(BufWriter<File>),
    Capture(Vec<String>),
}

struct SinkState {
    seq: u64,
    target: Target,
}

struct Sink {
    state: Mutex<SinkState>,
}

/// Handle to the shared event sink. Clones share one sequence counter
/// and one output. `Obs::off()` is a null handle.
///
/// The `traces` flag opts a handle into per-device lifecycle edges
/// ([`super::trace::TraceEdge`]) on top of the per-round records: a
/// traced serve round emits a few lines per *device*, so the firehose
/// is off unless explicitly requested (`--trace` on the CLI).
#[derive(Clone, Default)]
pub struct Obs {
    inner: Option<Arc<Sink>>,
    traces: bool,
}

impl fmt::Debug for Obs {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.inner {
            None => f.write_str("Obs(off)"),
            Some(s) => {
                let kind = match s.state.lock() {
                    Ok(st) => match st.target {
                        Target::Stderr => "stderr",
                        Target::File(_) => "file",
                        Target::Capture(_) => "capture",
                    },
                    Err(_) => "poisoned",
                };
                write!(f, "Obs({kind})")
            }
        }
    }
}

impl Obs {
    /// Disabled sink: every emit is a no-op.
    pub fn off() -> Obs {
        Obs {
            inner: None,
            traces: false,
        }
    }

    fn with_target(target: Target) -> Obs {
        Obs {
            inner: Some(Arc::new(Sink {
                state: Mutex::new(SinkState { seq: 0, target }),
            })),
            traces: false,
        }
    }

    /// Opt this handle (and everything cloned from it afterwards) into
    /// per-device lifecycle trace edges.
    pub fn with_traces(mut self) -> Obs {
        self.traces = true;
        self
    }

    /// Emit NDJSON lines to stderr (keeps stdout clean for tables and
    /// `--json` report bodies).
    pub fn stderr() -> Obs {
        Obs::with_target(Target::Stderr)
    }

    /// Emit NDJSON lines to a file, truncating any existing content.
    pub fn to_file(
        path: impl AsRef<std::path::Path>,
    ) -> crate::Result<Obs> {
        let f = File::create(path.as_ref()).map_err(|e| {
            crate::err!(
                "obs: cannot open events file {}: {e}",
                path.as_ref().display()
            )
        })?;
        Ok(Obs::with_target(Target::File(BufWriter::new(f))))
    }

    /// In-memory sink for tests; read back with
    /// [`Obs::captured_lines`].
    pub fn capture() -> Obs {
        Obs::with_target(Target::Capture(Vec::new()))
    }

    /// True when emits actually go somewhere — gate for any payload
    /// construction that is not free.
    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// True when per-device trace edges should be emitted: the sink is
    /// live *and* was opted in via [`Obs::with_traces`].
    pub fn trace_on(&self) -> bool {
        self.traces && self.inner.is_some()
    }

    /// Serialize and write one event line. Telemetry is best-effort:
    /// IO errors and poisoned locks are swallowed, never surfaced into
    /// the workload.
    pub fn emit(&self, ev: &dyn ObsEvent) {
        let Some(sink) = &self.inner else { return };
        let mut fields: Vec<(String, Value)> = vec![
            ("reason".to_string(), Value::from(ev.reason())),
            ("seq".to_string(), Value::from(0.0)),
        ];
        match ev.payload() {
            Value::Obj(kv) => fields.extend(kv),
            Value::Null => {}
            other => fields.push(("payload".to_string(), other)),
        }
        let Ok(mut st) = sink.state.lock() else { return };
        fields[1].1 = Value::from(st.seq as f64);
        st.seq += 1;
        let line = format!("{}", Value::Obj(fields));
        match &mut st.target {
            Target::Stderr => eprintln!("{line}"),
            Target::File(w) => {
                let _ = writeln!(w, "{line}");
                let _ = w.flush();
            }
            Target::Capture(lines) => lines.push(line),
        }
    }

    /// Lines captured so far (capture sinks only; empty otherwise).
    pub fn captured_lines(&self) -> Vec<String> {
        match &self.inner {
            Some(sink) => match sink.state.lock() {
                Ok(st) => match &st.target {
                    Target::Capture(lines) => lines.clone(),
                    _ => Vec::new(),
                },
                Err(_) => Vec::new(),
            },
            None => Vec::new(),
        }
    }
}

// -- typed records ----------------------------------------------------------

/// Fleet round opened: the control loop is about to sweep availability.
pub struct RoundStart<'a> {
    pub scenario: &'a str,
    pub round: usize,
    pub now_s: f64,
}

impl ObsEvent for RoundStart<'_> {
    fn reason(&self) -> &'static str {
        "round-start"
    }
    fn payload(&self) -> Value {
        Value::obj()
            .set("scenario", self.scenario)
            .set("round", self.round)
            .set("now_s", self.now_s)
    }
}

/// Per-shard availability result for one round.
pub struct ShardProgress {
    pub round: usize,
    pub shard: usize,
    pub online: usize,
}

impl ObsEvent for ShardProgress {
    fn reason(&self) -> &'static str {
        "shard-progress"
    }
    fn payload(&self) -> Value {
        Value::obj()
            .set("round", self.round)
            .set("shard", self.shard)
            .set("online", self.online)
    }
}

/// Fleet round closed: what the round paid.
pub struct RoundEnd {
    pub round: usize,
    pub online: usize,
    pub picked: usize,
    pub round_time_s: f64,
    pub round_energy_j: f64,
    pub now_s: f64,
}

impl ObsEvent for RoundEnd {
    fn reason(&self) -> &'static str {
        "round-end"
    }
    fn payload(&self) -> Value {
        Value::obj()
            .set("round", self.round)
            .set("online", self.online)
            .set("picked", self.picked)
            .set("round_time_s", self.round_time_s)
            .set("round_energy_j", self.round_energy_j)
            .set("now_s", self.now_s)
    }
}

/// §4.2: a device model's Pareto chain was explored for the first time.
pub struct ProfileExplored<'a> {
    pub model: &'a str,
    /// Global id of the device billed for the exploration.
    pub requester: usize,
    pub chain_len: usize,
    pub exploration_time_s: f64,
    pub exploration_energy_j: f64,
}

impl ObsEvent for ProfileExplored<'_> {
    fn reason(&self) -> &'static str {
        "profile-explored"
    }
    fn payload(&self) -> Value {
        Value::obj()
            .set("model", self.model)
            .set("requester", self.requester)
            .set("chain_len", self.chain_len)
            .set("exploration_time_s", self.exploration_time_s)
            .set("exploration_energy_j", self.exploration_energy_j)
    }
}

/// §4.2: end-of-run adoption count for one model's cached profile.
pub struct ProfileAdopted<'a> {
    pub model: &'a str,
    pub adoptions: u64,
}

impl ObsEvent for ProfileAdopted<'_> {
    fn reason(&self) -> &'static str {
        "profile-adopted"
    }
    fn payload(&self) -> Value {
        Value::obj()
            .set("model", self.model)
            .set("adoptions", self.adoptions as f64)
    }
}

/// Serve-side profile cache traffic, cumulative at a round boundary.
pub struct CacheHitMiss {
    pub round: u32,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl ObsEvent for CacheHitMiss {
    fn reason(&self) -> &'static str {
        "cache-hit-miss"
    }
    fn payload(&self) -> Value {
        Value::obj()
            .set("round", self.round as f64)
            .set("hits", self.hits as f64)
            .set("misses", self.misses as f64)
            .set("evictions", self.evictions as f64)
    }
}

/// Serve admission: one check-in batch flushed into a round.
pub struct CheckinBatch {
    pub round: u32,
    pub size: usize,
}

impl ObsEvent for CheckinBatch {
    fn reason(&self) -> &'static str {
        "checkin-batch"
    }
    fn payload(&self) -> Value {
        Value::obj()
            .set("round", self.round as f64)
            .set("size", self.size)
    }
}

/// Serve admission: devices turned away at round close. Carries the
/// actual backoff advised on the wire (`retry_after_s`) and the
/// coalescing batch size in force, so an admission storm is diagnosable
/// from the stream alone.
pub struct Deferral {
    pub round: u32,
    pub deferred: u64,
    pub retry_after_s: f64,
    pub batch_size: usize,
}

impl ObsEvent for Deferral {
    fn reason(&self) -> &'static str {
        "deferral"
    }
    fn payload(&self) -> Value {
        Value::obj()
            .set("round", self.round as f64)
            .set("deferred", self.deferred as f64)
            .set("retry_after_s", self.retry_after_s)
            .set("batch_size", self.batch_size)
    }
}

/// Serve admission: check-ins that arrived during Update and carried
/// into the next round.
pub struct LateCarryover {
    pub round: u32,
    pub carried: usize,
}

impl ObsEvent for LateCarryover {
    fn reason(&self) -> &'static str {
        "late-carryover"
    }
    fn payload(&self) -> Value {
        Value::obj()
            .set("round", self.round as f64)
            .set("carried", self.carried)
    }
}

/// Serve round closed: the round's admission/aggregate summary.
pub struct ServeRoundEnd {
    pub round: u32,
    pub checkins: u64,
    pub admitted: usize,
    pub deferred: u64,
    pub participants: usize,
    pub round_time_s: f64,
    pub round_energy_j: f64,
}

impl ObsEvent for ServeRoundEnd {
    fn reason(&self) -> &'static str {
        "round-end"
    }
    fn payload(&self) -> Value {
        Value::obj()
            .set("round", self.round as f64)
            .set("checkins", self.checkins as f64)
            .set("admitted", self.admitted)
            .set("deferred", self.deferred as f64)
            .set("participants", self.participants)
            .set("round_time_s", self.round_time_s)
            .set("round_energy_j", self.round_energy_j)
    }
}

/// Loadgen: one lane finished its check-in burst for a round.
pub struct LaneBurst {
    pub lane: usize,
    pub round: usize,
    pub size: usize,
    pub burst_s: f64,
}

impl ObsEvent for LaneBurst {
    fn reason(&self) -> &'static str {
        "lane-burst"
    }
    fn payload(&self) -> Value {
        Value::obj()
            .set("lane", self.lane)
            .set("round", self.round)
            .set("size", self.size)
            .set("burst_s", self.burst_s)
    }
}

/// The TCP control plane came up.
pub struct ServeStart {
    pub addr: String,
    pub workers: usize,
}

impl ObsEvent for ServeStart {
    fn reason(&self) -> &'static str {
        "serve-start"
    }
    fn payload(&self) -> Value {
        Value::obj()
            .set("addr", self.addr.as_str())
            .set("workers", self.workers)
    }
}

/// Terminal bench record: the full `BENCH_*.json` body, nested so the
/// stream stays one-object-per-line.
pub struct BenchResult<'a> {
    pub bench: &'a str,
    pub record: Value,
}

impl ObsEvent for BenchResult<'_> {
    fn reason(&self) -> &'static str {
        "bench-result"
    }
    fn payload(&self) -> Value {
        Value::obj()
            .set("bench", self.bench)
            .set("record", self.record.clone())
    }
}

/// End-of-run phase-timer rollup (also rendered by `report::obs_table`).
pub struct SpanSummary<'a> {
    pub scope: &'a str,
    pub spans: &'a super::Spans,
}

impl ObsEvent for SpanSummary<'_> {
    fn reason(&self) -> &'static str {
        "span-summary"
    }
    fn payload(&self) -> Value {
        Value::obj()
            .set("scope", self.scope)
            .set("spans", self.spans.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn emitted_lines_parse_and_seq_is_monotone() {
        let obs = Obs::capture();
        obs.emit(&RoundStart {
            scenario: "smoke",
            round: 0,
            now_s: 0.0,
        });
        obs.emit(&CheckinBatch { round: 1, size: 256 });
        let lines = obs.captured_lines();
        assert_eq!(lines.len(), 2);
        let mut last_seq = -1.0;
        for line in &lines {
            let v = json::parse(line).expect("line must parse");
            let seq = v.req_f64("seq").unwrap();
            assert!(seq > last_seq, "seq not increasing");
            last_seq = seq;
            v.req_str("reason").unwrap();
        }
        let first = json::parse(&lines[0]).unwrap();
        assert_eq!(first.req_str("reason").unwrap(), "round-start");
        assert_eq!(first.req_str("scenario").unwrap(), "smoke");
    }

    #[test]
    fn hostile_scenario_names_round_trip() {
        let obs = Obs::capture();
        let name = "ci\"ty\nnew\\line\t{}";
        obs.emit(&RoundStart {
            scenario: name,
            round: 3,
            now_s: 1.5,
        });
        let line = &obs.captured_lines()[0];
        assert!(!line.contains('\n'), "NDJSON line must be one line");
        let v = json::parse(line).expect("escaped line must parse");
        assert_eq!(v.req_str("scenario").unwrap(), name);
    }

    #[test]
    fn off_sink_is_inert() {
        let obs = Obs::off();
        assert!(!obs.enabled());
        obs.emit(&CheckinBatch { round: 0, size: 1 });
        assert!(obs.captured_lines().is_empty());
        assert_eq!(format!("{obs:?}"), "Obs(off)");
    }

    #[test]
    fn clones_share_one_seq_counter() {
        let a = Obs::capture();
        let b = a.clone();
        a.emit(&CheckinBatch { round: 0, size: 1 });
        b.emit(&CheckinBatch { round: 0, size: 2 });
        let lines = a.captured_lines();
        assert_eq!(lines.len(), 2);
        let s0 = json::parse(&lines[0]).unwrap().req_f64("seq").unwrap();
        let s1 = json::parse(&lines[1]).unwrap().req_f64("seq").unwrap();
        assert_eq!((s0, s1), (0.0, 1.0));
    }
}
