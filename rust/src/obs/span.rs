//! Layer 3 of the telemetry spine: scoped phase timers.
//!
//! A [`Spans`] accumulates wall-clock totals per named phase
//! (availability sweep, select, step, aggregate, flush). It is a plain
//! local value — the control thread owns it, records around its own
//! phase boundaries, and the result lands in both the event stream
//! ([`super::SpanSummary`]) and `report::obs_table`. Nothing here runs
//! on worker threads, so spans cannot perturb the SoA hot path.

use crate::util::json::Value;
use std::time::Instant;

/// Canonical fleet-drive phase names.
pub const PHASE_AVAILABILITY: &str = "availability";
pub const PHASE_SELECT: &str = "select";
pub const PHASE_STEP: &str = "step";
pub const PHASE_AGGREGATE: &str = "aggregate";
/// Canonical serve phase names.
pub const PHASE_FLUSH: &str = "flush";
pub const PHASE_CLOSE: &str = "close";
pub const PHASE_FINISH: &str = "finish";

/// Index handle returned by [`Spans::span`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanId(usize);

#[derive(Clone, Debug)]
pub struct SpanEntry {
    pub name: String,
    pub count: u64,
    pub total_s: f64,
    pub max_s: f64,
}

/// Accumulated per-phase timings, in registration order.
#[derive(Clone, Debug, Default)]
pub struct Spans {
    entries: Vec<SpanEntry>,
}

impl Spans {
    /// Find-or-create a phase, returning its record handle.
    pub fn span(&mut self, name: &str) -> SpanId {
        if let Some(i) =
            self.entries.iter().position(|e| e.name == name)
        {
            return SpanId(i);
        }
        self.entries.push(SpanEntry {
            name: name.to_string(),
            count: 0,
            total_s: 0.0,
            max_s: 0.0,
        });
        SpanId(self.entries.len() - 1)
    }

    pub fn record(&mut self, id: SpanId, secs: f64) {
        let e = &mut self.entries[id.0];
        e.count += 1;
        e.total_s += secs;
        if secs > e.max_s {
            e.max_s = secs;
        }
    }

    /// Time a closure and record it under `id`, passing the result
    /// through.
    pub fn time<T>(
        &mut self,
        id: SpanId,
        f: impl FnOnce() -> T,
    ) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(id, t0.elapsed().as_secs_f64());
        out
    }

    pub fn entries(&self) -> &[SpanEntry] {
        &self.entries
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Sum of all phase totals — the denominator for share-% columns.
    pub fn total_s(&self) -> f64 {
        self.entries.iter().map(|e| e.total_s).sum()
    }

    /// Fold another span set in by phase name; unseen phases append in
    /// `other`'s order.
    pub fn merge_from(&mut self, other: &Spans) {
        for o in &other.entries {
            let id = self.span(&o.name);
            let e = &mut self.entries[id.0];
            e.count += o.count;
            e.total_s += o.total_s;
            if o.max_s > e.max_s {
                e.max_s = o.max_s;
            }
        }
    }

    pub fn to_json(&self) -> Value {
        let mut obj = Value::obj();
        for e in &self.entries {
            obj = obj.set(
                e.name.as_str(),
                Value::obj()
                    .set("count", e.count as f64)
                    .set("total_s", e.total_s)
                    .set("max_s", e.max_s),
            );
        }
        obj
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_accumulate_and_merge() {
        let mut s = Spans::default();
        let step = s.span(PHASE_STEP);
        let sel = s.span(PHASE_SELECT);
        s.record(step, 0.5);
        s.record(step, 1.5);
        s.record(sel, 0.25);
        assert_eq!(s.entries()[0].count, 2);
        assert!((s.entries()[0].total_s - 2.0).abs() < 1e-12);
        assert!((s.entries()[0].max_s - 1.5).abs() < 1e-12);
        assert!((s.total_s() - 2.25).abs() < 1e-12);

        let mut t = Spans::default();
        let agg = t.span(PHASE_AGGREGATE);
        t.record(agg, 0.1);
        t.merge_from(&s);
        let names: Vec<&str> =
            t.entries().iter().map(|e| e.name.as_str()).collect();
        assert_eq!(
            names,
            vec![PHASE_AGGREGATE, PHASE_STEP, PHASE_SELECT]
        );
        assert_eq!(t.entries()[1].count, 2);
    }

    #[test]
    fn time_records_elapsed() {
        let mut s = Spans::default();
        let id = s.span("work");
        let out = s.time(id, || 41 + 1);
        assert_eq!(out, 42);
        assert_eq!(s.entries()[0].count, 1);
        assert!(s.entries()[0].total_s >= 0.0);
    }

    #[test]
    fn nested_closes_bill_the_inner_phase_to_both_scopes() {
        // Spans are closed by scope exit, innermost first. A nested
        // record must land in its own phase AND inside the enclosing
        // phase's wall-clock (outer total >= inner total), and closing
        // the inner scope must not disturb the outer handle.
        let mut outer = Spans::default();
        let o = outer.span("round");
        let mut inner = Spans::default();
        let i = inner.span(PHASE_STEP);
        outer.time(o, || {
            inner.time(i, || std::thread::sleep(
                std::time::Duration::from_millis(2),
            ));
        });
        assert_eq!(outer.entries()[0].count, 1);
        assert_eq!(inner.entries()[0].count, 1);
        assert!(
            outer.entries()[0].total_s >= inner.entries()[0].total_s,
            "outer scope must contain the nested one"
        );

        // Same shape on ONE span set: handles stay valid across a
        // nested close because record never reorders entries.
        let mut s = Spans::default();
        let a = s.span("outer");
        let b = s.span("inner");
        s.record(b, 0.25); // inner closes first
        s.record(a, 1.0); // then the enclosing scope
        assert_eq!(s.entries()[0].name, "outer");
        assert!((s.entries()[0].total_s - 1.0).abs() < 1e-12);
        assert!((s.entries()[1].total_s - 0.25).abs() < 1e-12);
    }

    #[test]
    fn out_of_order_closes_accumulate_by_handle_not_close_order() {
        // Handles may be recorded in any order, repeatedly, and
        // interleaved; the entry a handle addresses is fixed at
        // registration, so close order cannot corrupt attribution.
        let mut s = Spans::default();
        let avail = s.span(PHASE_AVAILABILITY);
        let step = s.span(PHASE_STEP);
        let agg = s.span(PHASE_AGGREGATE);
        s.record(agg, 0.3); // closes before the phases that precede it
        s.record(avail, 0.1);
        s.record(step, 0.7);
        s.record(avail, 0.2); // reopened and closed again
        let names: Vec<&str> =
            s.entries().iter().map(|e| e.name.as_str()).collect();
        assert_eq!(
            names,
            vec![PHASE_AVAILABILITY, PHASE_STEP, PHASE_AGGREGATE],
            "entry order is registration order, not close order"
        );
        assert_eq!(s.entries()[0].count, 2);
        assert!((s.entries()[0].total_s - 0.3).abs() < 1e-12);
        assert!((s.entries()[0].max_s - 0.2).abs() < 1e-12);
        assert!((s.total_s() - 1.3).abs() < 1e-12);
        // Re-registering an already-closed phase returns the same
        // handle (no duplicate entries from late lookups).
        assert_eq!(s.span(PHASE_AGGREGATE), agg);
    }
}
