//! The consume side of the telemetry spine: turn an NDJSON event
//! stream (or a `BENCH_*.json` snapshot) into answers.
//!
//! Everything here is pure over parsed [`Value`]s so the CLI verbs
//! (`swan obs trace|top|rates|diff`) and the integration tests share
//! one engine:
//!
//! - [`lifecycles`] groups `trace-edge` records by their deterministic
//!   identity `(round, device_id)` in seq (= file) order and exposes
//!   inter-edge gaps, so "why was device 17 slow in round 412?" is a
//!   lookup, not a rerun.
//! - [`top_stages`] / [`top_devices`] aggregate those gaps into K-way
//!   attribution tables (slowest pipeline stage, worst stragglers).
//! - [`windowed_rates`] buckets check-in/deferral/aggregation edges
//!   into fixed wall-clock windows to spot admission storms; without
//!   trace edges it falls back to per-round counts from the base
//!   records.
//! - [`load_any`] + [`diff`] compare two runs — NDJSON vs NDJSON or
//!   snapshot vs snapshot — with percent deltas and direction-aware
//!   regression flags.
//!
//! [`required_fields`] is the per-reason schema contract shared with
//! `swan obs check`.

use std::collections::BTreeMap;

use crate::util::json::{self, Value};

use super::trace::{
    EDGE_AGGREGATED, EDGE_CHECKIN, EDGE_CONN_DEFERRED, EDGE_DEFERRED,
    SERVE_ADMITTED_CHAIN,
};

// -- schema -----------------------------------------------------------------

/// Required payload fields per event reason — the schema `swan obs
/// check` enforces. Unknown reasons return an empty slice (forward
/// compatible: new reasons are allowed, known ones must be complete).
/// `round-end` is shared by the fleet and serve emitters with
/// different extras, so only the common core is required.
pub fn required_fields(reason: &str) -> &'static [&'static str] {
    match reason {
        "round-start" => &["scenario", "round", "now_s"],
        "shard-progress" => &["round", "shard", "online"],
        "round-end" => &["round", "round_time_s", "round_energy_j"],
        "profile-explored" => &[
            "model",
            "requester",
            "chain_len",
            "exploration_time_s",
            "exploration_energy_j",
        ],
        "profile-adopted" => &["model", "adoptions"],
        "cache-hit-miss" => &["round", "hits", "misses", "evictions"],
        "checkin-batch" => &["round", "size"],
        "deferral" => {
            &["round", "deferred", "retry_after_s", "batch_size"]
        }
        "late-carryover" => &["round", "carried"],
        "serve-start" => &["addr", "workers"],
        "span-summary" => &["scope", "spans"],
        "bench-result" => &["bench", "record"],
        "trace-edge" => &["round", "edge", "t_s"],
        "lane-burst" => &["lane", "round", "size", "burst_s"],
        _ => &[],
    }
}

// -- stream reading ---------------------------------------------------------

/// Parse an NDJSON body: one event object per non-blank line, in file
/// order. `origin` only flavors error messages.
pub fn parse_events(text: &str, origin: &str) -> crate::Result<Vec<Value>> {
    let mut events = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = json::parse(line).map_err(|e| {
            crate::err!("{origin}:{}: bad event line: {e}", i + 1)
        })?;
        events.push(v);
    }
    crate::ensure!(!events.is_empty(), "{origin}: no events in stream");
    Ok(events)
}

/// Read and parse an NDJSON event file.
pub fn read_events(path: &str) -> crate::Result<Vec<Value>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| crate::err!("reading {path}: {e}"))?;
    parse_events(&text, path)
}

// -- lifecycle reconstruction -----------------------------------------------

/// One reconstructed edge: the full event record plus the fields every
/// consumer needs pre-extracted.
#[derive(Clone, Debug)]
pub struct LifeEdge {
    pub edge: String,
    pub t_s: f64,
    pub seq: f64,
    /// The whole event record, for detail fields (`retry_after_s`,
    /// selection `seq`, ...).
    pub v: Value,
}

/// All edges observed for one `(round, device)` identity, in seq
/// order.
#[derive(Clone, Debug)]
pub struct Lifecycle {
    pub round: u64,
    pub device: u64,
    pub edges: Vec<LifeEdge>,
}

impl Lifecycle {
    /// Wall-clock span from first to last edge.
    pub fn duration_s(&self) -> f64 {
        match (self.edges.first(), self.edges.last()) {
            (Some(a), Some(b)) => b.t_s - a.t_s,
            _ => 0.0,
        }
    }

    /// Inter-edge gaps as `("a→b", dt)` pairs, in order.
    pub fn gaps(&self) -> Vec<(String, f64)> {
        self.edges
            .windows(2)
            .map(|w| {
                (
                    format!("{}\u{2192}{}", w[0].edge, w[1].edge),
                    w[1].t_s - w[0].t_s,
                )
            })
            .collect()
    }

    /// The single largest inter-edge gap, if any.
    pub fn max_gap(&self) -> Option<(String, f64)> {
        self.gaps()
            .into_iter()
            .max_by(|a, b| a.1.total_cmp(&b.1))
    }

    /// Timestamps never go backwards in seq order — the causality
    /// contract of [`super::trace::TraceClock`].
    pub fn timestamps_monotone(&self) -> bool {
        self.edges.windows(2).all(|w| w[1].t_s >= w[0].t_s)
    }

    /// True when `chain` appears as an in-order subsequence of this
    /// lifecycle's edges.
    pub fn has_chain(&self, chain: &[&str]) -> bool {
        let mut want = chain.iter();
        let mut next = want.next();
        for e in &self.edges {
            match next {
                Some(&n) if e.edge == n => next = want.next(),
                Some(_) => {}
                None => break,
            }
        }
        next.is_none()
    }

    /// A complete admitted-and-selected serve lifecycle: every
    /// happy-path edge present, timestamps monotone.
    pub fn is_complete_admitted(&self) -> bool {
        self.has_chain(SERVE_ADMITTED_CHAIN) && self.timestamps_monotone()
    }
}

/// Group all `trace-edge` events by `(round, device)`. Events with a
/// null device (transport-level edges) have no lifecycle identity and
/// are skipped. Within a lifecycle, edge order is file (= seq) order.
pub fn lifecycles(events: &[Value]) -> Vec<Lifecycle> {
    let mut by_id: BTreeMap<(u64, u64), Vec<LifeEdge>> = BTreeMap::new();
    for v in events {
        if v.get("reason").and_then(Value::as_str) != Some("trace-edge") {
            continue;
        }
        let Some(device) = v.get("device").and_then(Value::as_f64) else {
            continue;
        };
        let (Some(round), Some(edge), Some(t_s)) = (
            v.get("round").and_then(Value::as_f64),
            v.get("edge").and_then(Value::as_str),
            v.get("t_s").and_then(Value::as_f64),
        ) else {
            continue;
        };
        let seq = v.get("seq").and_then(Value::as_f64).unwrap_or(0.0);
        by_id.entry((round as u64, device as u64)).or_default().push(
            LifeEdge {
                edge: edge.to_string(),
                t_s,
                seq,
                v: v.clone(),
            },
        );
    }
    by_id
        .into_iter()
        .map(|((round, device), edges)| Lifecycle {
            round,
            device,
            edges,
        })
        .collect()
}

/// [`lifecycles`] restricted to one round and/or one device.
pub fn lifecycles_filtered(
    events: &[Value],
    round: Option<u64>,
    device: Option<u64>,
) -> Vec<Lifecycle> {
    lifecycles(events)
        .into_iter()
        .filter(|lc| round.map_or(true, |r| lc.round == r))
        .filter(|lc| device.map_or(true, |d| lc.device == d))
        .collect()
}

/// A stall threshold when the user didn't give one: 5× the median
/// positive inter-edge gap across all lifecycles (0.0 — flag nothing —
/// when there are too few gaps to call anything an outlier).
pub fn auto_stall_threshold_s(lcs: &[Lifecycle]) -> f64 {
    let mut gaps: Vec<f64> = lcs
        .iter()
        .flat_map(|lc| lc.gaps())
        .map(|(_, dt)| dt)
        .filter(|dt| *dt > 0.0)
        .collect();
    if gaps.len() < 4 {
        return 0.0;
    }
    gaps.sort_by(|a, b| a.total_cmp(b));
    5.0 * gaps[gaps.len() / 2]
}

// -- attribution ------------------------------------------------------------

/// Aggregated latency for one attribution key (a pipeline stage or a
/// straggler device).
#[derive(Clone, Copy, Debug, Default)]
pub struct GapStat {
    pub count: u64,
    pub total_s: f64,
    pub max_s: f64,
}

impl GapStat {
    pub fn add(&mut self, v: f64) {
        self.count += 1;
        self.total_s += v;
        if v > self.max_s {
            self.max_s = v;
        }
    }

    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_s / self.count as f64
        }
    }
}

fn sorted_by_total(
    map: BTreeMap<String, GapStat>,
) -> Vec<(String, GapStat)> {
    let mut rows: Vec<_> = map.into_iter().collect();
    rows.sort_by(|a, b| b.1.total_s.total_cmp(&a.1.total_s));
    rows
}

/// Total latency attributed to each pipeline stage (`a→b` inter-edge
/// gap), slowest first.
pub fn top_stages(lcs: &[Lifecycle]) -> Vec<(String, GapStat)> {
    let mut map: BTreeMap<String, GapStat> = BTreeMap::new();
    for lc in lcs {
        for (stage, dt) in lc.gaps() {
            map.entry(stage).or_default().add(dt);
        }
    }
    sorted_by_total(map)
}

/// Per-device lifecycle durations (`count` = edges seen, `total` =
/// first-to-last span, `max` = worst single gap), slowest first —
/// the straggler list.
pub fn top_devices(lcs: &[Lifecycle]) -> Vec<(String, GapStat)> {
    let mut map: BTreeMap<String, GapStat> = BTreeMap::new();
    for lc in lcs {
        let key = format!("r{}/d{}", lc.round, lc.device);
        let stat = map.entry(key).or_default();
        stat.count = lc.edges.len() as u64;
        stat.total_s = lc.duration_s();
        stat.max_s = lc.max_gap().map(|(_, dt)| dt).unwrap_or(0.0);
    }
    sorted_by_total(map)
}

// -- rates ------------------------------------------------------------------

/// One row of the windowed-rates table.
#[derive(Clone, Debug, Default)]
pub struct RateRow {
    pub label: String,
    /// Time base for the rates: the window width (trace mode) or the
    /// round's virtual duration (fallback mode).
    pub span_s: f64,
    pub checkins: u64,
    pub deferred: u64,
    pub aggregated: u64,
}

/// Bucket admission traffic into fixed windows of `window_s` seconds
/// over trace-edge timestamps. When the stream has no trace edges,
/// falls back to one row per round built from the base records
/// (`checkin-batch` sizes, `deferral` counts, `round-end`
/// participants/picked), with the round's virtual `round_time_s` as
/// the time base.
pub fn windowed_rates(events: &[Value], window_s: f64) -> Vec<RateRow> {
    let window_s = if window_s > 0.0 { window_s } else { 1.0 };
    let mut windows: BTreeMap<u64, RateRow> = BTreeMap::new();
    let mut saw_trace = false;
    for v in events {
        if v.get("reason").and_then(Value::as_str) != Some("trace-edge") {
            continue;
        }
        let (Some(edge), Some(t_s)) = (
            v.get("edge").and_then(Value::as_str),
            v.get("t_s").and_then(Value::as_f64),
        ) else {
            continue;
        };
        saw_trace = true;
        let w = (t_s / window_s).floor() as u64;
        let row = windows.entry(w).or_insert_with(|| RateRow {
            label: format!(
                "[{:.2}s, {:.2}s)",
                w as f64 * window_s,
                (w + 1) as f64 * window_s
            ),
            span_s: window_s,
            ..RateRow::default()
        });
        match edge {
            EDGE_CHECKIN => row.checkins += 1,
            EDGE_DEFERRED | EDGE_CONN_DEFERRED => row.deferred += 1,
            EDGE_AGGREGATED => row.aggregated += 1,
            _ => {}
        }
    }
    if saw_trace {
        return windows.into_values().collect();
    }

    // Fallback: per-round admission counts from the base records.
    let mut rounds: BTreeMap<u64, RateRow> = BTreeMap::new();
    for v in events {
        let Some(reason) = v.get("reason").and_then(Value::as_str) else {
            continue;
        };
        let Some(round) = v.get("round").and_then(Value::as_f64) else {
            continue;
        };
        let row = rounds.entry(round as u64).or_insert_with(|| RateRow {
            label: format!("round {}", round as u64),
            ..RateRow::default()
        });
        match reason {
            "checkin-batch" => {
                row.checkins +=
                    v.get("size").and_then(Value::as_f64).unwrap_or(0.0)
                        as u64;
            }
            "deferral" => {
                row.deferred += v
                    .get("deferred")
                    .and_then(Value::as_f64)
                    .unwrap_or(0.0) as u64;
            }
            "round-end" => {
                // Serve rounds report participants; fleet rounds picked.
                let agg = v
                    .get("participants")
                    .or_else(|| v.get("picked"))
                    .and_then(Value::as_f64)
                    .unwrap_or(0.0);
                row.aggregated += agg as u64;
                row.span_s = v
                    .get("round_time_s")
                    .and_then(Value::as_f64)
                    .unwrap_or(0.0);
            }
            _ => {}
        }
    }
    rounds.into_values().collect()
}

// -- diff -------------------------------------------------------------------

/// What a path turned out to hold.
pub enum Loaded {
    /// An NDJSON event stream.
    Events(Vec<Value>),
    /// A single-object `BENCH_*.json` snapshot.
    Snapshot(Value),
}

impl Loaded {
    pub fn kind(&self) -> &'static str {
        match self {
            Loaded::Events(_) => "events",
            Loaded::Snapshot(_) => "snapshot",
        }
    }
}

/// Auto-detect NDJSON vs snapshot. A file that parses whole as one
/// JSON object is a snapshot unless it carries a `"reason"` field (a
/// one-line event stream); anything else is parsed line-by-line.
pub fn load_any(path: &str) -> crate::Result<Loaded> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| crate::err!("reading {path}: {e}"))?;
    if let Ok(v) = json::parse(&text) {
        if matches!(v, Value::Obj(_)) && v.get("reason").is_none() {
            return Ok(Loaded::Snapshot(v));
        }
    }
    Ok(Loaded::Events(parse_events(&text, path)?))
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Direction {
    HigherBetter,
    LowerBetter,
    Neutral,
}

/// Which way is "good" for a snapshot headline metric. Unknown keys
/// are reported but never gate.
fn snapshot_direction(key: &str) -> Direction {
    match key {
        "best_devices_stepped_per_sec"
        | "checkins_per_sec"
        | "tcp_checkins_per_sec"
        | "cache_hit_rate"
        | "speedup_vs_reference"
        | "speedup_same_shards" => Direction::HigherBetter,
        "p90_checkin_latency_s" | "deferral_rate" | "cache_evictions" => {
            Direction::LowerBetter
        }
        _ => Direction::Neutral,
    }
}

/// One compared metric. `delta_pct` is the candidate relative to the
/// baseline; `regressed` is set when the candidate is worse by more
/// than the threshold in the metric's known good direction.
#[derive(Clone, Debug)]
pub struct DiffRow {
    pub metric: String,
    pub candidate: f64,
    pub baseline: f64,
    pub delta_pct: f64,
    pub regressed: bool,
}

fn diff_row(
    metric: String,
    candidate: f64,
    baseline: f64,
    dir: Direction,
    threshold_pct: f64,
) -> DiffRow {
    let delta_pct = if baseline != 0.0 {
        (candidate - baseline) / baseline.abs() * 100.0
    } else if candidate == 0.0 {
        0.0
    } else {
        f64::INFINITY * candidate.signum()
    };
    let regressed = match dir {
        Direction::HigherBetter => delta_pct < -threshold_pct,
        Direction::LowerBetter => delta_pct > threshold_pct,
        Direction::Neutral => false,
    };
    DiffRow {
        metric,
        candidate,
        baseline,
        delta_pct,
        regressed,
    }
}

fn numeric_top_level(v: &Value) -> Vec<(String, f64)> {
    match v {
        Value::Obj(kv) => kv
            .iter()
            .filter(|(k, _)| k != "schema_version")
            .filter_map(|(k, v)| v.as_f64().map(|n| (k.clone(), n)))
            .collect(),
        _ => Vec::new(),
    }
}

fn reason_counts(events: &[Value]) -> BTreeMap<String, u64> {
    let mut map = BTreeMap::new();
    for v in events {
        if let Some(r) = v.get("reason").and_then(Value::as_str) {
            *map.entry(r.to_string()).or_insert(0) += 1;
        }
    }
    map
}

/// Compare a candidate run against a baseline. Both sides must be the
/// same kind; snapshots must additionally carry the same `bench` tag
/// (diffing a fleet snapshot against a serve one is a usage error, not
/// an all-metrics-missing report).
pub fn diff(
    candidate: &Loaded,
    baseline: &Loaded,
    threshold_pct: f64,
) -> crate::Result<Vec<DiffRow>> {
    match (candidate, baseline) {
        (Loaded::Snapshot(c), Loaded::Snapshot(b)) => {
            let (ct, bt) = (c.req_str("bench")?, b.req_str("bench")?);
            crate::ensure!(
                ct == bt,
                "cannot diff a '{ct}' snapshot against a '{bt}' snapshot"
            );
            let base: BTreeMap<String, f64> =
                numeric_top_level(b).into_iter().collect();
            let mut rows = Vec::new();
            for (k, cv) in numeric_top_level(c) {
                if let Some(&bv) = base.get(&k) {
                    rows.push(diff_row(
                        k.clone(),
                        cv,
                        bv,
                        snapshot_direction(&k),
                        threshold_pct,
                    ));
                }
            }
            crate::ensure!(
                !rows.is_empty(),
                "snapshots share no numeric metrics"
            );
            Ok(rows)
        }
        (Loaded::Events(c), Loaded::Events(b)) => {
            let mut rows = Vec::new();
            let (cc, bc) = (reason_counts(c), reason_counts(b));
            for (k, &cv) in &cc {
                if let Some(&bv) = bc.get(k) {
                    rows.push(diff_row(
                        format!("count.{k}"),
                        cv as f64,
                        bv as f64,
                        Direction::Neutral,
                        threshold_pct,
                    ));
                }
            }
            let (cs, bs) = (
                top_stages(&lifecycles(c)),
                top_stages(&lifecycles(b)),
            );
            let base: BTreeMap<String, GapStat> =
                bs.into_iter().collect();
            for (stage, stat) in cs {
                if let Some(bstat) = base.get(&stage) {
                    rows.push(diff_row(
                        format!("stage.{stage}.mean_s"),
                        stat.mean_s(),
                        bstat.mean_s(),
                        Direction::LowerBetter,
                        threshold_pct,
                    ));
                }
            }
            crate::ensure!(
                !rows.is_empty(),
                "event streams share no comparable metrics"
            );
            Ok(rows)
        }
        (c, b) => crate::bail!(
            "cannot diff {} against {} (both sides must be NDJSON \
             streams or both BENCH_*.json snapshots)",
            c.kind(),
            b.kind()
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{
        EDGE_ADMITTED, EDGE_LEASE_SENT, EDGE_SELECTED,
        EDGE_UPDATE_RECEIVED,
    };
    use crate::obs::{Obs, TraceEdge};

    fn edge(
        obs: &Obs,
        round: u32,
        device: u64,
        name: &'static str,
        t_s: f64,
    ) {
        obs.emit(&TraceEdge::new(round, device, name, t_s));
    }

    fn parsed(obs: &Obs) -> Vec<Value> {
        obs.captured_lines()
            .iter()
            .map(|l| json::parse(l).unwrap())
            .collect()
    }

    #[test]
    fn lifecycles_group_by_round_and_device_in_seq_order() {
        let obs = Obs::capture().with_traces();
        edge(&obs, 1, 7, EDGE_CHECKIN, 0.10);
        edge(&obs, 1, 9, EDGE_CHECKIN, 0.11);
        edge(&obs, 1, 7, EDGE_ADMITTED, 0.12);
        edge(&obs, 2, 7, EDGE_CHECKIN, 0.50);
        obs.emit(&TraceEdge::conn_deferred(1, 0.2, 30.0));
        let lcs = lifecycles(&parsed(&obs));
        assert_eq!(lcs.len(), 3, "null-device edges form no lifecycle");
        let d7r1 = lcs
            .iter()
            .find(|lc| lc.round == 1 && lc.device == 7)
            .unwrap();
        let names: Vec<&str> =
            d7r1.edges.iter().map(|e| e.edge.as_str()).collect();
        assert_eq!(names, [EDGE_CHECKIN, EDGE_ADMITTED]);
        assert!(d7r1.timestamps_monotone());
        assert!((d7r1.duration_s() - 0.02).abs() < 1e-12);
    }

    #[test]
    fn complete_admitted_chain_is_recognized() {
        let obs = Obs::capture().with_traces();
        let chain = [
            EDGE_CHECKIN,
            EDGE_ADMITTED,
            EDGE_SELECTED,
            EDGE_LEASE_SENT,
            EDGE_UPDATE_RECEIVED,
            EDGE_AGGREGATED,
        ];
        for (i, name) in chain.iter().enumerate() {
            edge(&obs, 0, 1, name, i as f64 * 0.1);
        }
        let lcs = lifecycles(&parsed(&obs));
        assert!(lcs[0].is_complete_admitted());
        assert_eq!(
            lcs[0].max_gap().unwrap().0,
            format!("{EDGE_CHECKIN}\u{2192}{EDGE_ADMITTED}")
        );

        let partial = Obs::capture().with_traces();
        edge(&partial, 0, 1, EDGE_CHECKIN, 0.0);
        edge(&partial, 0, 1, EDGE_ADMITTED, 0.1);
        let lcs = lifecycles(&parsed(&partial));
        assert!(!lcs[0].is_complete_admitted());
    }

    #[test]
    fn top_stages_attribute_the_slowest_gap() {
        let obs = Obs::capture().with_traces();
        // Two devices; the admitted→selected gap dominates.
        for d in [1u64, 2] {
            edge(&obs, 0, d, EDGE_CHECKIN, 0.0);
            edge(&obs, 0, d, EDGE_ADMITTED, 0.01);
            edge(&obs, 0, d, EDGE_SELECTED, 1.01);
        }
        let lcs = lifecycles(&parsed(&obs));
        let stages = top_stages(&lcs);
        assert_eq!(
            stages[0].0,
            format!("{EDGE_ADMITTED}\u{2192}{EDGE_SELECTED}")
        );
        assert_eq!(stages[0].1.count, 2);
        assert!((stages[0].1.mean_s() - 1.0).abs() < 1e-9);
        let devs = top_devices(&lcs);
        assert_eq!(devs.len(), 2);
        assert!(devs[0].0.starts_with("r0/d"));
    }

    #[test]
    fn windowed_rates_bucket_trace_edges() {
        let obs = Obs::capture().with_traces();
        edge(&obs, 0, 1, EDGE_CHECKIN, 0.1);
        edge(&obs, 0, 2, EDGE_CHECKIN, 0.2);
        edge(&obs, 0, 3, EDGE_DEFERRED, 0.3);
        edge(&obs, 0, 1, EDGE_AGGREGATED, 1.2);
        let rows = windowed_rates(&parsed(&obs), 1.0);
        assert_eq!(rows.len(), 2);
        assert_eq!((rows[0].checkins, rows[0].deferred), (2, 1));
        assert_eq!(rows[1].aggregated, 1);
    }

    #[test]
    fn rates_fall_back_to_round_records_without_traces() {
        let obs = Obs::capture();
        obs.emit(&crate::obs::CheckinBatch { round: 0, size: 40 });
        obs.emit(&crate::obs::Deferral {
            round: 0,
            deferred: 3,
            retry_after_s: 30.0,
            batch_size: 256,
        });
        obs.emit(&crate::obs::ServeRoundEnd {
            round: 0,
            checkins: 43,
            admitted: 40,
            deferred: 3,
            participants: 8,
            round_time_s: 2.0,
            round_energy_j: 1.0,
        });
        let rows = windowed_rates(&parsed(&obs), 1.0);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].label, "round 0");
        assert_eq!(
            (rows[0].checkins, rows[0].deferred, rows[0].aggregated),
            (40, 3, 8)
        );
        assert_eq!(rows[0].span_s, 2.0);
    }

    #[test]
    fn snapshot_diff_flags_directional_regressions_only() {
        let a = Value::obj()
            .set("bench", "fleet")
            .set("schema_version", 1.0)
            .set("best_devices_stepped_per_sec", 50.0)
            .set("rounds", 10.0);
        let b = Value::obj()
            .set("bench", "fleet")
            .set("schema_version", 2.0)
            .set("best_devices_stepped_per_sec", 100.0)
            .set("rounds", 20.0);
        let rows = diff(
            &Loaded::Snapshot(a.clone()),
            &Loaded::Snapshot(b.clone()),
            10.0,
        )
        .unwrap();
        let tput = rows
            .iter()
            .find(|r| r.metric == "best_devices_stepped_per_sec")
            .unwrap();
        assert!(tput.regressed, "-50% throughput must gate");
        assert!((tput.delta_pct + 50.0).abs() < 1e-9);
        let neutral =
            rows.iter().find(|r| r.metric == "rounds").unwrap();
        assert!(!neutral.regressed, "unknown direction never gates");
        assert!(
            !rows.iter().any(|r| r.metric == "schema_version"),
            "schema_version is not a metric"
        );
        // Reversed order: candidate faster than baseline — no gate.
        let rows =
            diff(&Loaded::Snapshot(b), &Loaded::Snapshot(a), 10.0)
                .unwrap();
        assert!(rows.iter().all(|r| !r.regressed));
    }

    #[test]
    fn mismatched_diff_inputs_error() {
        let snap = Loaded::Snapshot(Value::obj().set("bench", "fleet"));
        let obs = Obs::capture();
        obs.emit(&crate::obs::CheckinBatch { round: 0, size: 1 });
        let ev = Loaded::Events(parsed(&obs));
        assert!(diff(&snap, &ev, 10.0).is_err());
        let serve = Loaded::Snapshot(Value::obj().set("bench", "serve"));
        let fleet = Loaded::Snapshot(
            Value::obj().set("bench", "fleet").set("x", 1.0),
        );
        assert!(diff(&serve, &fleet, 10.0).is_err());
    }

    #[test]
    fn every_typed_reason_has_a_schema() {
        for reason in [
            "round-start",
            "shard-progress",
            "round-end",
            "profile-explored",
            "profile-adopted",
            "cache-hit-miss",
            "checkin-batch",
            "deferral",
            "late-carryover",
            "serve-start",
            "span-summary",
            "bench-result",
            "trace-edge",
            "lane-burst",
        ] {
            assert!(
                !required_fields(reason).is_empty(),
                "reason '{reason}' lost its schema"
            );
        }
        assert!(required_fields("some-future-reason").is_empty());
    }
}
