//! Causal device traces: the per-device lifecycle layer of the
//! telemetry spine.
//!
//! A trace is identified by `(round, device_id)` — both already
//! deterministic — and consists of **edges**: the barrier points a
//! check-in passes on its way through the serve pipeline
//! (or a picked device passes through the fleet drive). Every edge is
//! one [`TraceEdge`] NDJSON record carrying a monotonic timestamp from
//! a [`TraceClock`] anchored at coordinator/drive construction, so the
//! consume side ([`super::analyze`]) can reconstruct lifecycles and
//! attribute inter-edge latency without any cross-event bookkeeping.
//!
//! ```text
//! serve lifecycle (one check-in, round R):
//!
//!   checkin ──▶ admitted ──▶ selected ──▶ lease-sent ──▶
//!     │            │            │          update-received ──▶ aggregated
//!     │            │            └──▶ rejected          (or ──▶ late-carryover,
//!     │            └──────────────── (close barrier)        stamped into R+1)
//!     └──▶ deferred  (admission bound; carries retry_after_s)
//!
//! fleet lifecycle (one picked device, round R):
//!   selected ──▶ stepped
//! ```
//!
//! **Digest neutrality.** Edges are *observations* of barriers the
//! round structure already has: they never draw RNG, never reorder a
//! float fold, and their timestamps are wall-clock (`Instant`)
//! quantities that no simulation state ever reads back. Tracing is
//! additionally gated behind [`Obs::trace_on`](super::Obs::trace_on)
//! (the `--trace` CLI switch) because a traced serve round emits a few
//! edges per *device*, not per round — the base event stream stays
//! lean unless lifecycles were asked for.

use crate::util::json::Value;
use std::time::Instant;

use super::event::ObsEvent;

/// Serve pipeline edges, in causal order.
pub const EDGE_CHECKIN: &str = "checkin";
pub const EDGE_ADMITTED: &str = "admitted";
pub const EDGE_DEFERRED: &str = "deferred";
pub const EDGE_SELECTED: &str = "selected";
pub const EDGE_REJECTED: &str = "rejected";
pub const EDGE_LEASE_SENT: &str = "lease-sent";
pub const EDGE_UPDATE_RECEIVED: &str = "update-received";
pub const EDGE_AGGREGATED: &str = "aggregated";
pub const EDGE_LATE_CARRYOVER: &str = "late-carryover";
/// Transport-level deferral: a connection turned away by a saturated
/// IO pool, before any device id was read (the record's `device` is
/// null).
pub const EDGE_CONN_DEFERRED: &str = "conn-deferred";
/// Fleet drive edge: a picked device finished its local epoch.
pub const EDGE_STEPPED: &str = "stepped";

/// The complete happy-path chain of an admitted, selected serve
/// check-in — what `swan obs trace --expect-complete` looks for.
pub const SERVE_ADMITTED_CHAIN: &[&str] = &[
    EDGE_CHECKIN,
    EDGE_ADMITTED,
    EDGE_SELECTED,
    EDGE_LEASE_SENT,
    EDGE_UPDATE_RECEIVED,
    EDGE_AGGREGATED,
];

/// Monotonic timestamp source for trace edges: seconds since the
/// owning coordinator/drive started. `Instant`-backed, so edge
/// timestamps stamped in causal order are guaranteed non-decreasing —
/// the property the lifecycle reconstruction asserts.
#[derive(Clone, Debug)]
pub struct TraceClock(Instant);

impl TraceClock {
    pub fn start() -> TraceClock {
        TraceClock(Instant::now())
    }

    pub fn now_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

impl Default for TraceClock {
    fn default() -> TraceClock {
        TraceClock::start()
    }
}

/// One lifecycle edge. `detail` fields (an object) are inlined after
/// the fixed fields, so e.g. a `deferred` edge carries the actual
/// `retry_after_s` the device was told.
pub struct TraceEdge {
    pub round: u32,
    /// `None` for transport-level edges where no device id exists yet
    /// (serialized as JSON null).
    pub device: Option<u64>,
    pub edge: &'static str,
    /// Seconds on the emitting component's [`TraceClock`].
    pub t_s: f64,
    pub detail: Value,
}

impl TraceEdge {
    pub fn new(
        round: u32,
        device: u64,
        edge: &'static str,
        t_s: f64,
    ) -> TraceEdge {
        TraceEdge {
            round,
            device: Some(device),
            edge,
            t_s,
            detail: Value::Null,
        }
    }

    /// Append a detail field (inlined into the emitted record).
    pub fn with(mut self, key: &str, v: impl Into<Value>) -> TraceEdge {
        let obj = match self.detail {
            Value::Obj(_) => self.detail,
            _ => Value::obj(),
        };
        self.detail = obj.set(key, v);
        self
    }

    /// The accept-pool-overflow edge: no device id is known because the
    /// connection was refused before its first frame was read.
    pub fn conn_deferred(
        round: u32,
        t_s: f64,
        retry_after_s: f64,
    ) -> TraceEdge {
        TraceEdge {
            round,
            device: None,
            edge: EDGE_CONN_DEFERRED,
            t_s,
            detail: Value::obj().set("retry_after_s", retry_after_s),
        }
    }
}

impl ObsEvent for TraceEdge {
    fn reason(&self) -> &'static str {
        "trace-edge"
    }
    fn payload(&self) -> Value {
        let mut v = Value::obj()
            .set("round", self.round as f64)
            .set(
                "device",
                match self.device {
                    Some(d) => Value::Num(d as f64),
                    None => Value::Null,
                },
            )
            .set("edge", self.edge)
            .set("t_s", self.t_s);
        if let Value::Obj(kv) = &self.detail {
            for (k, val) in kv {
                v = v.set(k, val.clone());
            }
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Obs;
    use crate::util::json;

    #[test]
    fn edge_records_inline_their_detail_fields() {
        let obs = Obs::capture().with_traces();
        assert!(obs.trace_on());
        obs.emit(
            &TraceEdge::new(3, 17, EDGE_DEFERRED, 0.25)
                .with("retry_after_s", 30.0),
        );
        let line = &obs.captured_lines()[0];
        let v = json::parse(line).expect("edge line parses");
        assert_eq!(v.req_str("reason").unwrap(), "trace-edge");
        assert_eq!(v.req_f64("round").unwrap(), 3.0);
        assert_eq!(v.req_f64("device").unwrap(), 17.0);
        assert_eq!(v.req_str("edge").unwrap(), EDGE_DEFERRED);
        assert_eq!(v.req_f64("t_s").unwrap(), 0.25);
        assert_eq!(v.req_f64("retry_after_s").unwrap(), 30.0);
    }

    #[test]
    fn conn_deferred_has_a_null_device() {
        let obs = Obs::capture().with_traces();
        obs.emit(&TraceEdge::conn_deferred(0, 0.0, 30.0));
        let v = json::parse(&obs.captured_lines()[0]).unwrap();
        assert_eq!(v.req("device").unwrap(), &Value::Null);
        assert_eq!(v.req_str("edge").unwrap(), EDGE_CONN_DEFERRED);
    }

    #[test]
    fn trace_flag_gates_but_does_not_replace_enabled() {
        let off = Obs::off().with_traces();
        assert!(!off.trace_on(), "off sink never traces");
        let plain = Obs::capture();
        assert!(plain.enabled() && !plain.trace_on());
        let traced = Obs::capture().with_traces();
        assert!(traced.enabled() && traced.trace_on());
    }

    #[test]
    fn clock_is_monotone() {
        let c = TraceClock::start();
        let a = c.now_s();
        let b = c.now_s();
        assert!(b >= a && a >= 0.0);
    }
}
