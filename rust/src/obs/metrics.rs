//! Layer 2 of the telemetry spine: named counters and fixed-bucket
//! latency histograms.
//!
//! The registry is deliberately *not* shared-mutable: each shard (or
//! serve lane) records into its own local [`MetricsRegistry`] and the
//! control thread merges them **in shard order at the round barrier** —
//! the same discipline the FNV determinism digest uses — so recording
//! never takes a lock on the SoA hot path and never perturbs scheduling.
//! Hot loops pre-register names once ([`MetricsRegistry::counter`] /
//! [`MetricsRegistry::hist`]) and then bump by index.

use crate::util::json::Value;

/// Fixed latency bucket upper bounds (seconds), 1-2-5 series from 1 µs
/// to 10 s plus an implicit overflow bucket. Shared by every latency
/// histogram in the crate so merges are always bucket-compatible.
pub const LATENCY_BUCKETS_S: &[f64] = &[
    1e-6, 2e-6, 5e-6, 1e-5, 2e-5, 5e-5, 1e-4, 2e-4, 5e-4, 1e-3, 2e-3,
    5e-3, 1e-2, 2e-2, 5e-2, 0.1, 0.2, 0.5, 1.0, 2.0, 5.0, 10.0,
];

/// Index handle returned by [`MetricsRegistry::counter`]; bumping via the
/// handle is a single array index, cheap enough for per-device loops.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CounterId(usize);

/// Index handle returned by [`MetricsRegistry::hist`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HistId(usize);

/// Fixed-bound bucket histogram. A sample lands in the first bucket
/// whose upper bound is `>= value`; larger samples land in the overflow
/// bucket. Quantiles interpolate linearly inside a bucket, which is the
/// usual fixed-bucket tradeoff: cheap, mergeable, bounded error.
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: &'static [f64],
    counts: Vec<u64>,
    sum: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new(LATENCY_BUCKETS_S)
    }
}

impl Histogram {
    pub fn new(bounds: &'static [f64]) -> Histogram {
        Histogram {
            bounds,
            counts: vec![0; bounds.len() + 1],
            sum: 0.0,
            max: 0.0,
        }
    }

    pub fn observe(&mut self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|b| v <= *b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        if v > self.max {
            self.max = v;
        }
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Arithmetic mean of observed samples; 0.0 when empty.
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum / n as f64
        }
    }

    /// Largest observed sample; 0.0 when empty.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Quantile estimate, `q` in [0, 1]; 0.0 when empty. Interpolates
    /// within the bucket holding the target rank and clamps to the
    /// observed max (overflow-bucket hits report the max itself).
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let target =
            ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut before = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            if before + c >= target {
                if i == self.bounds.len() {
                    return self.max;
                }
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let hi = self.bounds[i];
                let frac = (target - before) as f64 / *c as f64;
                return (lo + frac * (hi - lo)).min(self.max);
            }
            before += c;
        }
        self.max
    }

    /// Fold another histogram in. Both sides must use the same bounds —
    /// in practice everything uses [`LATENCY_BUCKETS_S`].
    pub fn merge_from(&mut self, other: &Histogram) {
        assert_eq!(
            self.bounds.len(),
            other.bounds.len(),
            "histogram bucket mismatch"
        );
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.sum += other.sum;
        if other.max > self.max {
            self.max = other.max;
        }
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .set("count", self.count() as f64)
            .set("sum_s", self.sum)
            .set("max_s", self.max)
            .set("p50_s", self.quantile(0.50))
            .set("p90_s", self.quantile(0.90))
            .set("p99_s", self.quantile(0.99))
    }
}

/// Name-addressed counters + histograms. Lookup by name is linear — the
/// registry holds a handful of entries and hot paths go through the
/// pre-registered [`CounterId`]/[`HistId`] handles instead.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    counters: Vec<(String, u64)>,
    hists: Vec<(String, Histogram)>,
}

impl MetricsRegistry {
    /// Find-or-create a counter, returning its cheap bump handle.
    pub fn counter(&mut self, name: &str) -> CounterId {
        if let Some(i) =
            self.counters.iter().position(|(n, _)| n == name)
        {
            return CounterId(i);
        }
        self.counters.push((name.to_string(), 0));
        CounterId(self.counters.len() - 1)
    }

    pub fn add(&mut self, id: CounterId, n: u64) {
        self.counters[id.0].1 += n;
    }

    /// Cold-path convenience: find-or-create and bump in one call.
    pub fn inc(&mut self, name: &str, n: u64) {
        let id = self.counter(name);
        self.add(id, n);
    }

    pub fn counter_value(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| *v)
            .unwrap_or(0)
    }

    /// Find-or-create a histogram with the given bounds.
    pub fn hist(
        &mut self,
        name: &str,
        bounds: &'static [f64],
    ) -> HistId {
        if let Some(i) = self.hists.iter().position(|(n, _)| n == name)
        {
            return HistId(i);
        }
        self.hists.push((name.to_string(), Histogram::new(bounds)));
        HistId(self.hists.len() - 1)
    }

    pub fn observe(&mut self, id: HistId, v: f64) {
        self.hists[id.0].1.observe(v);
    }

    /// Fold a free-standing histogram into a registered one — how a
    /// lock-scoped local histogram (e.g. the serve intake timer kept
    /// under the pending lock) lands in the round registry at a
    /// barrier.
    pub fn merge_hist(&mut self, id: HistId, other: &Histogram) {
        self.hists[id.0].1.merge_from(other);
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.hists.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }

    pub fn counters(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counters.iter().map(|(n, v)| (n.as_str(), *v))
    }

    pub fn histograms(
        &self,
    ) -> impl Iterator<Item = (&str, &Histogram)> {
        self.hists.iter().map(|(n, h)| (n.as_str(), h))
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.hists.is_empty()
    }

    /// Fold another registry in by name. Names already present merge in
    /// place; unseen names append in `other`'s order — so merging shard
    /// registries in shard order is deterministic.
    pub fn merge_from(&mut self, other: &MetricsRegistry) {
        for (name, v) in &other.counters {
            let id = self.counter(name);
            self.add(id, *v);
        }
        for (name, h) in &other.hists {
            let id = self.hist(name, h.bounds);
            self.hists[id.0].1.merge_from(h);
        }
    }

    pub fn to_json(&self) -> Value {
        let mut counters = Value::obj();
        for (n, v) in &self.counters {
            counters = counters.set(n.as_str(), *v as f64);
        }
        let mut hists = Value::obj();
        for (n, h) in &self.hists {
            hists = hists.set(n.as_str(), h.to_json());
        }
        Value::obj().set("counters", counters).set("hists", hists)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_interpolate_within_buckets() {
        let mut h = Histogram::default();
        for i in 1..=10 {
            h.observe(i as f64 * 1e-3); // 1ms..10ms
        }
        assert_eq!(h.count(), 10);
        let p90 = h.quantile(0.90);
        // true p90 is 9.1e-3; the bucket holding rank 9 is (5e-3, 1e-2]
        assert!(p90 > 5e-3 && p90 <= 1e-2, "p90 = {p90}");
        assert!((h.quantile(1.0) - h.max()).abs() < 1e-12);
        assert!((h.mean() - 5.5e-3).abs() < 1e-12);
    }

    #[test]
    fn histogram_empty_and_overflow_are_defined() {
        let mut h = Histogram::default();
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.max(), 0.0);
        h.observe(1e9); // beyond the last bound -> overflow bucket
        assert_eq!(h.count(), 1);
        assert_eq!(h.quantile(0.5), 1e9);
    }

    #[test]
    fn histogram_merge_equals_single_stream() {
        let mut a = Histogram::default();
        let mut b = Histogram::default();
        let mut whole = Histogram::default();
        for i in 0..100 {
            let v = (i as f64 + 0.5) * 1e-4;
            whole.observe(v);
            if i % 2 == 0 {
                a.observe(v);
            } else {
                b.observe(v);
            }
        }
        a.merge_from(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.quantile(0.9), whole.quantile(0.9));
        assert!((a.sum() - whole.sum()).abs() < 1e-12);
    }

    #[test]
    fn overflow_bucket_survives_merge_and_json() {
        // Samples beyond the last bound land in the overflow bucket,
        // report the observed max as their quantile, and keep doing so
        // after a merge in either direction.
        let mut over = Histogram::default();
        over.observe(25.0);
        over.observe(60.0);
        assert_eq!(over.quantile(0.99), 60.0);

        let mut under = Histogram::default();
        under.observe(1e-3);
        under.merge_from(&over);
        assert_eq!(under.count(), 3);
        assert_eq!(under.max(), 60.0);
        assert_eq!(under.quantile(1.0), 60.0);
        let j = under.to_json();
        assert_eq!(j.req_f64("count").unwrap(), 3.0);
        assert_eq!(j.req_f64("max_s").unwrap(), 60.0);
        // p50 is rank 2 of {1e-3, 25, 60}: overflow bucket -> max.
        assert_eq!(j.req_f64("p50_s").unwrap(), 60.0);
    }

    #[test]
    fn shard_merge_is_count_invariant_at_one_and_four_shards() {
        // The same sample stream recorded by 1 shard or striped over 4
        // shard-local registries and merged in shard order must produce
        // identical counters, bucket counts, and quantiles — the
        // determinism discipline the fleet drive relies on.
        let samples: Vec<f64> =
            (0..200).map(|i| ((i * 37) % 97) as f64 * 1e-4).collect();

        let mut one = MetricsRegistry::default();
        let h1 = one.hist("fleet.round_wall_s", LATENCY_BUCKETS_S);
        let c1 = one.counter("fleet.online");
        for &v in &samples {
            one.observe(h1, v);
            one.add(c1, 1);
        }

        let mut shards: Vec<MetricsRegistry> =
            (0..4).map(|_| MetricsRegistry::default()).collect();
        for (i, &v) in samples.iter().enumerate() {
            let reg = &mut shards[i % 4];
            let h = reg.hist("fleet.round_wall_s", LATENCY_BUCKETS_S);
            reg.observe(h, v);
            reg.inc("fleet.online", 1);
        }
        let mut four = MetricsRegistry::default();
        for reg in &shards {
            four.merge_from(reg);
        }

        assert_eq!(
            four.counter_value("fleet.online"),
            one.counter_value("fleet.online")
        );
        let (ho, hf) = (
            one.histogram("fleet.round_wall_s").unwrap(),
            four.histogram("fleet.round_wall_s").unwrap(),
        );
        assert_eq!(hf.count(), ho.count());
        assert_eq!(hf.counts, ho.counts);
        assert_eq!(hf.sum().to_bits(), ho.sum().to_bits());
        assert_eq!(hf.max().to_bits(), ho.max().to_bits());
        for q in [0.5, 0.9, 0.99] {
            assert_eq!(
                hf.quantile(q).to_bits(),
                ho.quantile(q).to_bits(),
                "q{q} diverged between 1 and 4 shards"
            );
        }
    }

    #[test]
    fn merge_hist_folds_a_local_histogram_into_the_registry() {
        let mut local = Histogram::default();
        local.observe(2e-3);
        local.observe(4e-3);
        let mut reg = MetricsRegistry::default();
        let id = reg.hist("serve.edge.checkin_s", LATENCY_BUCKETS_S);
        reg.observe(id, 1e-3);
        reg.merge_hist(id, &local);
        let h = reg.histogram("serve.edge.checkin_s").unwrap();
        assert_eq!(h.count(), 3);
        assert!((h.sum() - 7e-3).abs() < 1e-12);
    }

    #[test]
    fn registry_handles_and_merge_are_deterministic() {
        let mut a = MetricsRegistry::default();
        let id = a.counter("steps");
        a.add(id, 3);
        a.inc("steps", 2);
        assert_eq!(a.counter_value("steps"), 5);
        assert_eq!(a.counter_value("absent"), 0);

        let mut b = MetricsRegistry::default();
        b.inc("polls", 7);
        b.inc("steps", 1);
        let h = b.hist("lat", LATENCY_BUCKETS_S);
        b.observe(h, 3e-3);

        a.merge_from(&b);
        assert_eq!(a.counter_value("steps"), 6);
        assert_eq!(a.counter_value("polls"), 7);
        assert_eq!(a.histogram("lat").unwrap().count(), 1);
        // merge order: existing names keep position, new ones append
        let names: Vec<&str> =
            a.counters().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["steps", "polls"]);
    }
}
