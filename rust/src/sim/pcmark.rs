//! PCMark-Work-3.0-style responsiveness benchmark model (Fig 3, Table 3).
//!
//! PCMark runs realistic foreground tasks (web browsing, video editing,
//! document work) on 1–2 application threads and reports a throughput-
//! derived score. We model each sub-test as a fixed work quantum on
//! foreground threads placed by the Android scheduler, plus a *real-time
//! floor* (video frames, animation waits) that a fast core cannot beat.
//! A concurrent training process steals cycle share on shared cores and
//! inflates the compute part of each sub-test.
//!
//! The floor is what gives Fig 3's asymmetry: on a fast SoC the compute
//! part hides inside the real-time floor, so contention barely moves the
//! score (S10e −11%); on the low-end Pixel 3 the compute part already
//! exceeds the floor and the full slowdown lands on the score (−27%).

use crate::soc::device::Device;

use super::android_sched::Scheduler;

/// One PCMark sub-test: work per thread (GFLOP-equivalent), thread count,
/// and the real-time floor (seconds) its scripted waits impose.
#[derive(Clone, Copy, Debug)]
pub struct SubTest {
    pub name: &'static str,
    pub gflop: f64,
    pub threads: usize,
    pub floor_s: f64,
}

/// The Work-3.0-like suite: mostly 1–2 threads, per §3.2 / [27].
pub const SUITE: [SubTest; 5] = [
    SubTest { name: "web_browsing", gflop: 22.0, threads: 1, floor_s: 1.00 },
    SubTest { name: "video_editing", gflop: 18.0, threads: 2, floor_s: 1.40 },
    SubTest { name: "writing", gflop: 26.0, threads: 1, floor_s: 0.90 },
    SubTest { name: "photo_editing", gflop: 34.0, threads: 2, floor_s: 0.80 },
    SubTest { name: "data_manipulation", gflop: 30.0, threads: 1, floor_s: 0.70 },
];

/// Score scale chosen so idle scores land in the real PCMark range
/// (Pixel 3 ≈ 7–8k, SD865-class ≈ 10–13k).
const SCORE_SCALE: f64 = 9500.0;

/// Run the suite with `training_cores` occupied by background training
/// threads (empty slice = no training). Returns the PCMark-like score.
pub fn pcmark_score(device: &Device, training_cores: &[usize]) -> f64 {
    let sched = Scheduler::new(device);
    let mut total_time = 0.0;
    for t in SUITE {
        let fg_cores = sched.foreground_cores(t.threads);
        // sub-test completes when its slowest thread finishes
        let mut worst: f64 = 0.0;
        for &c in &fg_cores {
            let n_train_here =
                training_cores.iter().filter(|&&tc| tc == c).count();
            let share = sched.foreground_share(n_train_here);
            let gflops = device.cores[c].peak_gflops * share;
            let time = (t.gflop / t.threads as f64) / gflops;
            worst = worst.max(time);
        }
        total_time += worst.max(t.floor_s);
    }
    SCORE_SCALE * SUITE.len() as f64 / total_time
}

/// Percentage impact of training on the score (negative = worse), the
/// exact quantity Table 3 / Fig 3 report.
pub fn score_impact_percent(device: &Device, training_cores: &[usize]) -> f64 {
    let clean = pcmark_score(device, &[]);
    let dirty = pcmark_score(device, training_cores);
    (dirty - clean) / clean * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::device::{device, DeviceId};

    #[test]
    fn clean_scores_in_realistic_range_and_ordered() {
        let p3 = pcmark_score(&device(DeviceId::Pixel3), &[]);
        let op8 = pcmark_score(&device(DeviceId::OnePlus8), &[]);
        let s10 = pcmark_score(&device(DeviceId::S10e), &[]);
        assert!(p3 > 4000.0 && p3 < 12000.0, "pixel3 {p3}");
        assert!(op8 > p3, "newer SoC must score higher: {op8} vs {p3}");
        assert!(s10 > p3, "{s10} vs {p3}");
    }

    #[test]
    fn training_on_big_cores_hurts_score() {
        let d = device(DeviceId::Pixel3);
        let impact = score_impact_percent(&d, &d.low_latency_cores());
        assert!(impact < -8.0, "greedy training impact {impact}%");
    }

    #[test]
    fn training_on_little_cores_harmless() {
        let d = device(DeviceId::Pixel3);
        let impact = score_impact_percent(&d, &[0, 1, 2, 3]);
        assert!(impact.abs() < 1.0, "little-core training impact {impact}%");
    }

    #[test]
    fn fewer_training_threads_hurt_less() {
        let d = device(DeviceId::S10e);
        let all = score_impact_percent(&d, &d.low_latency_cores());
        let one = score_impact_percent(&d, &[4]);
        assert!(one >= all, "one thread {one}% vs greedy {all}%");
    }

    #[test]
    fn pixel3_hurt_more_than_s10e_by_greedy_training() {
        // Fig 3: the lower-end device suffers more
        let p3 = device(DeviceId::Pixel3);
        let s10 = device(DeviceId::S10e);
        let i_p3 = score_impact_percent(&p3, &p3.low_latency_cores());
        let i_s10 = score_impact_percent(&s10, &s10.low_latency_cores());
        assert!(
            i_p3 < i_s10 - 3.0,
            "pixel3 {i_p3}% should be clearly worse than s10e {i_s10}%"
        );
    }

    #[test]
    fn impact_never_positive() {
        for id in [DeviceId::Pixel3, DeviceId::S10e, DeviceId::OnePlus8,
                   DeviceId::TabS6, DeviceId::Mi10] {
            let d = device(id);
            for cores in [vec![4], vec![4, 5], d.low_latency_cores()] {
                assert!(score_impact_percent(&d, &cores) <= 1e-9);
            }
        }
    }
}
