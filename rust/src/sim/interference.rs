//! Foreground-application interference sessions.
//!
//! §3.2: "a majority of Android applications only use 1–2 threads" [27],
//! arriving in bursts while the user interacts with the phone. The
//! generator produces an alternating renewal process of idle gaps and
//! app sessions (1–2 foreground threads plus a screen/app power draw);
//! the phone sim feeds the resulting thread count into the scheduler
//! model and the power draw into the battery.

use crate::util::rng::Rng;

/// Instantaneous foreground load.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ForegroundLoad {
    /// Active foreground compute threads (0 = device idle).
    pub threads: usize,
    /// Screen + app power draw, watts (0 when idle).
    pub power_w: f64,
}

impl ForegroundLoad {
    pub const IDLE: ForegroundLoad = ForegroundLoad {
        threads: 0,
        power_w: 0.0,
    };

    pub fn is_idle(&self) -> bool {
        self.threads == 0
    }
}

/// Alternating idle/session renewal process.
#[derive(Clone, Debug)]
pub struct SessionGenerator {
    rng: Rng,
    /// Mean idle gap between sessions, seconds.
    pub mean_idle_s: f64,
    /// Mean session length, seconds.
    pub mean_session_s: f64,
    /// Probability a session is heavy (2 threads vs 1).
    pub p_heavy: f64,
    state: ForegroundLoad,
    next_transition_s: f64,
}

impl SessionGenerator {
    pub fn new(seed: u64, mean_idle_s: f64, mean_session_s: f64, p_heavy: f64) -> Self {
        let mut rng = Rng::new(seed);
        let first = rng.exponential(mean_idle_s);
        SessionGenerator {
            rng,
            mean_idle_s,
            mean_session_s,
            p_heavy,
            state: ForegroundLoad::IDLE,
            next_transition_s: first,
        }
    }

    /// A generator that never produces foreground load (idle device).
    pub fn always_idle(seed: u64) -> Self {
        let mut g = SessionGenerator::new(seed, f64::INFINITY, 1.0, 0.0);
        g.next_transition_s = f64::INFINITY;
        g
    }

    /// Advance to absolute simulated time `now_s`, return current load.
    pub fn load_at(&mut self, now_s: f64) -> ForegroundLoad {
        while now_s >= self.next_transition_s {
            if self.state.is_idle() {
                // start a session
                let heavy = self.rng.bool(self.p_heavy);
                self.state = ForegroundLoad {
                    threads: if heavy { 2 } else { 1 },
                    power_w: if heavy { 2.2 } else { 1.3 }, // screen + app
                };
                self.next_transition_s +=
                    self.rng.exponential(self.mean_session_s);
            } else {
                self.state = ForegroundLoad::IDLE;
                self.next_transition_s += self.rng.exponential(self.mean_idle_s);
            }
        }
        self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn always_idle_stays_idle() {
        let mut g = SessionGenerator::always_idle(1);
        for t in 0..10_000 {
            assert!(g.load_at(t as f64 * 10.0).is_idle());
        }
    }

    #[test]
    fn sessions_alternate_and_threads_bounded() {
        let mut g = SessionGenerator::new(3, 300.0, 120.0, 0.3);
        let mut saw_idle = false;
        let mut saw_busy = false;
        for t in 0..50_000 {
            let l = g.load_at(t as f64);
            assert!(l.threads <= 2);
            if l.is_idle() {
                saw_idle = true;
                assert_eq!(l.power_w, 0.0);
            } else {
                saw_busy = true;
                assert!(l.power_w > 0.0);
            }
        }
        assert!(saw_idle && saw_busy);
    }

    #[test]
    fn duty_cycle_tracks_means() {
        let mut g = SessionGenerator::new(7, 300.0, 100.0, 0.5);
        let mut busy = 0usize;
        let n = 200_000;
        for t in 0..n {
            if !g.load_at(t as f64).is_idle() {
                busy += 1;
            }
        }
        let duty = busy as f64 / n as f64;
        let expect = 100.0 / 400.0;
        assert!(
            (duty - expect).abs() < 0.05,
            "duty {duty} vs expected {expect}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = SessionGenerator::new(11, 200.0, 80.0, 0.4);
        let mut b = SessionGenerator::new(11, 200.0, 80.0, 0.4);
        for t in 0..5000 {
            assert_eq!(a.load_at(t as f64), b.load_at(t as f64));
        }
    }
}
