//! The simulated phone: battery + thermal + scheduler + interference,
//! advanced on a shared virtual clock. This is the object both the Swan
//! explorer/controller and the baseline policy run against — they can
//! only observe what a real Android userland service could observe
//! (battery level/voltage/state, temperature, own step latencies), never
//! the simulator's ground-truth power.

use crate::power::{Battery, BatteryState, Charger, Thermal};
use crate::soc::device::Device;
use crate::soc::exec_model::{estimate, ExecEstimate, ExecutionContext};
use crate::workload::Workload;

use super::android_sched::Scheduler;
use super::clock::Clock;
use super::interference::{ForegroundLoad, SessionGenerator};

/// Power drawn by always-on background services (radios, sensors, OS).
const BACKGROUND_SERVICES_W: f64 = 0.12;

/// What a userland observer can read from the phone.
#[derive(Clone, Copy, Debug)]
pub struct Observation {
    pub battery_level: u32,
    pub battery_voltage: f64,
    pub battery_state: BatteryState,
    pub battery_temp_c: f64,
    pub screen_on: bool,
    pub now_s: f64,
}

/// One simulated device instance.
pub struct SimPhone {
    pub device: Device,
    pub battery: Battery,
    pub thermal: Thermal,
    pub clock: Clock,
    pub scheduler: Scheduler,
    pub sessions: SessionGenerator,
    pub charger: Option<Charger>,
    /// Ground truth counters (for evaluation only — the engine never reads
    /// these; they feed the paper tables as the "measured" columns).
    pub truth_train_energy_j: f64,
    pub truth_train_time_s: f64,
}

impl SimPhone {
    pub fn new(device: Device, seed: u64) -> Self {
        let scheduler = Scheduler::new(&device);
        let battery = Battery::new(device.battery_mah, 0.85);
        SimPhone {
            device,
            battery,
            thermal: Thermal::new(24.0),
            clock: Clock::new(),
            scheduler,
            sessions: SessionGenerator::always_idle(seed),
            charger: None,
            truth_train_energy_j: 0.0,
            truth_train_time_s: 0.0,
        }
    }

    pub fn with_sessions(mut self, sessions: SessionGenerator) -> Self {
        self.sessions = sessions;
        self
    }

    pub fn plug_charger(&mut self, charger: Charger) {
        self.charger = Some(charger);
    }

    pub fn unplug_charger(&mut self) {
        self.charger = None;
        self.battery.set_state(BatteryState::Discharging);
    }

    /// Current foreground load (advances the session process).
    pub fn foreground(&mut self) -> ForegroundLoad {
        self.sessions.load_at(self.clock.now())
    }

    pub fn observe(&mut self) -> Observation {
        let fg = self.foreground();
        Observation {
            battery_level: self.battery.level_percent(),
            battery_voltage: self.battery.voltage(),
            battery_state: self.battery.state(),
            battery_temp_c: self.thermal.temp_c,
            screen_on: !fg.is_idle(),
            now_s: self.clock.now(),
        }
    }

    /// Let simulated time pass with no training running.
    pub fn idle(&mut self, dt_s: f64) {
        let fg = self.foreground();
        let p = BACKGROUND_SERVICES_W + fg.power_w;
        self.apply_power(p, dt_s);
        self.clock.advance(dt_s);
    }

    /// Execute one training step on `cores`; returns the estimate the
    /// engine observes (latency) — energy is only observable through the
    /// battery. Foreground load is sampled once at step start (steps are
    /// short relative to sessions).
    pub fn run_train_step(
        &mut self,
        workload: &Workload,
        cores: &[usize],
    ) -> ExecEstimate {
        let fg = self.foreground();
        let share = self.scheduler.training_share(fg.threads);
        // §4.3: cores within a cluster are interchangeable — pin to the
        // least-contended ones (sched_setaffinity in the real system)
        let cores =
            self.scheduler
                .remap_least_contended(&self.device, cores, &share);
        let ctx = ExecutionContext::with_share(share);
        let est = estimate(&self.device, workload, &cores, &ctx);
        let p_total =
            est.avg_power_w + fg.power_w + BACKGROUND_SERVICES_W;
        self.apply_power(p_total, est.latency_s);
        self.clock.advance(est.latency_s);
        self.truth_train_energy_j += est.energy_j;
        self.truth_train_time_s += est.latency_s;
        est
    }

    fn apply_power(&mut self, load_w: f64, dt_s: f64) {
        match self.charger {
            Some(ch) => {
                ch.step(&mut self.battery, load_w, dt_s);
            }
            None => {
                self.battery.drain(load_w, dt_s);
            }
        }
        self.thermal.step(load_w, dt_s);
    }

    /// Paper §4.1 admission check: idle, cool, and battery healthy.
    pub fn admits_training(&mut self, min_battery_level: u32) -> bool {
        let obs = self.observe();
        let battery_ok = obs.battery_state == BatteryState::Charging
            || obs.battery_level >= min_battery_level;
        !obs.screen_on && obs.battery_temp_c <= 35.0 && battery_ok
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::device::{device, DeviceId};
    use crate::workload::{builtin, WorkloadName};

    fn phone() -> SimPhone {
        SimPhone::new(device(DeviceId::Pixel3), 42)
    }

    #[test]
    fn training_drains_battery_and_heats() {
        let mut p = phone();
        let w = builtin(WorkloadName::Resnet34);
        let soc0 = p.battery.soc();
        let t0 = p.thermal.temp_c;
        for _ in 0..50 {
            p.run_train_step(&w, &[4, 5, 6, 7]);
        }
        assert!(p.battery.soc() < soc0);
        assert!(p.thermal.temp_c > t0);
        assert!(p.truth_train_time_s > 0.0);
    }

    #[test]
    fn idle_drains_much_less() {
        let mut a = phone();
        let mut b = phone();
        let w = builtin(WorkloadName::Resnet34);
        a.idle(600.0);
        while b.clock.now() < 600.0 {
            b.run_train_step(&w, &[4, 5, 6, 7]);
        }
        assert!(a.battery.soc() > b.battery.soc());
    }

    #[test]
    fn admission_gates_on_temperature() {
        let mut p = phone();
        let w = builtin(WorkloadName::Resnet34);
        assert!(p.admits_training(30));
        // heat it up past 35°C with sustained full-tilt training
        for _ in 0..3000 {
            p.run_train_step(&w, &[4, 5, 6, 7]);
            if p.thermal.temp_c > 35.5 {
                break;
            }
        }
        assert!(p.thermal.temp_c > 35.0, "never got hot: {}", p.thermal.temp_c);
        assert!(!p.admits_training(30));
    }

    #[test]
    fn admission_gates_on_battery_level() {
        let mut p = phone();
        p.battery.set_soc(0.10);
        assert!(!p.admits_training(30));
        p.plug_charger(Charger::new(18.0));
        p.battery.charge(1.0, 1.0); // set state to Charging
        assert!(p.admits_training(30), "charging overrides low battery");
    }

    #[test]
    fn admission_gates_on_screen() {
        let d = device(DeviceId::Pixel3);
        let mut p = SimPhone::new(d, 1)
            .with_sessions(SessionGenerator::new(1, 1e-6, 1e9, 0.0));
        // session generator immediately starts an (endless) session
        p.idle(10.0);
        assert!(!p.admits_training(0));
    }

    #[test]
    fn interference_inflates_step_latency() {
        let w = builtin(WorkloadName::Resnet34);
        let mut quiet = phone();
        let t_quiet = quiet.run_train_step(&w, &[4, 5, 6, 7]).latency_s;
        let d = device(DeviceId::Pixel3);
        let mut busy = SimPhone::new(d, 2)
            .with_sessions(SessionGenerator::new(2, 1e-6, 1e9, 1.0));
        busy.idle(1.0); // enter the session
        let t_busy = busy.run_train_step(&w, &[4, 5, 6, 7]).latency_s;
        assert!(
            t_busy > 1.3 * t_quiet,
            "foreground contention must slow training: {t_busy} vs {t_quiet}"
        );
    }

    #[test]
    fn charger_keeps_battery_up_during_training() {
        let mut p = phone();
        p.plug_charger(Charger::new(18.0));
        let w = builtin(WorkloadName::ShufflenetV2);
        let soc0 = p.battery.soc();
        for _ in 0..100 {
            p.run_train_step(&w, &[4]);
        }
        assert!(p.battery.soc() >= soc0 - 0.01, "18W charger out-supplies training");
    }
}
