//! Device-level simulation: virtual time, the Android cpuset scheduler,
//! foreground interference sessions, the phone process that ties battery
//! + thermal + scheduler together, and the PCMark-style responsiveness
//! benchmark used for Table 3 / Fig 3.

pub mod android_sched;
pub mod clock;
pub mod interference;
pub mod pcmark;
pub mod phone;

pub use android_sched::Scheduler;
pub use clock::Clock;
pub use interference::{ForegroundLoad, SessionGenerator};
pub use phone::SimPhone;
