//! Virtual clock. All simulated components share seconds-since-start;
//! the FL harness reports time-to-accuracy in this clock, never
//! wall-clock (§5.1's emulation does the same).

#[derive(Clone, Debug, Default)]
pub struct Clock {
    now_s: f64,
}

impl Clock {
    pub fn new() -> Self {
        Clock { now_s: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.now_s
    }

    pub fn advance(&mut self, dt_s: f64) {
        debug_assert!(dt_s >= 0.0, "time cannot go backwards");
        self.now_s += dt_s;
    }

    pub fn hours(&self) -> f64 {
        self.now_s / 3600.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_monotonically() {
        let mut c = Clock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(1.5);
        c.advance(2.5);
        assert!((c.now() - 4.0).abs() < 1e-12);
        assert!((c.hours() - 4.0 / 3600.0).abs() < 1e-15);
    }
}
