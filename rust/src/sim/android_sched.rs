//! Android cpuset/priority scheduling model.
//!
//! From the Android sources the paper cites ([1] in §4.3): foreground
//! application threads are dispatched to the fastest available cores and
//! get CFS priority over background work. We model the part Swan
//! interacts with: given `k` foreground threads, they occupy the `k`
//! fastest cores (prime → big → little), and on any core shared with
//! training threads, the foreground thread receives a priority-weighted
//! share of cycles.
//!
//! This is the mechanism behind both directions of Table 3:
//! - training on big cores slows foreground apps (PCMark drops), and
//! - foreground apps shrink training's share (Swan's controller sees the
//!   step-latency inflation and migrates away).

use crate::soc::device::Device;

/// CFS nice-level weight ratio between a foreground thread and a
/// background (training) thread sharing a core. Android runs background
/// work at nice ≥ 10; weight ratio ≈ 3:1 is the corresponding CFS ratio
/// order of magnitude.
pub const FG_WEIGHT: f64 = 3.0;

#[derive(Clone, Debug)]
pub struct Scheduler {
    n_cores: usize,
    /// Cores sorted fastest-first (prime, big, little), used for
    /// foreground placement.
    fast_order: Vec<usize>,
}

impl Scheduler {
    pub fn new(device: &Device) -> Self {
        let mut order: Vec<usize> = (0..device.n_cores()).collect();
        order.sort_by(|&a, &b| {
            device.cores[b]
                .peak_gflops
                .partial_cmp(&device.cores[a].peak_gflops)
                .unwrap()
        });
        Scheduler {
            n_cores: device.n_cores(),
            fast_order: order,
        }
    }

    /// Which cores `n_fg_threads` foreground threads occupy.
    pub fn foreground_cores(&self, n_fg_threads: usize) -> Vec<usize> {
        self.fast_order
            .iter()
            .take(n_fg_threads.min(self.n_cores))
            .copied()
            .collect()
    }

    /// Per-core cycle share available to ONE training thread pinned to
    /// each core, given the current foreground thread placement.
    pub fn training_share(&self, n_fg_threads: usize) -> Vec<f64> {
        let fg = self.foreground_cores(n_fg_threads);
        (0..self.n_cores)
            .map(|c| {
                let n_fg_here = fg.iter().filter(|&&f| f == c).count() as f64;
                1.0 / (1.0 + FG_WEIGHT * n_fg_here)
            })
            .collect()
    }

    /// Foreground thread's own cycle share on `core` when training pins
    /// `n_train_here` threads there (for the PCMark model).
    pub fn foreground_share(&self, n_train_here: usize) -> f64 {
        FG_WEIGHT / (FG_WEIGHT + n_train_here as f64)
    }

    /// Within-cluster affinity remap (§4.3 "moving away from cores under
    /// contention"): cores of the same kind are interchangeable, so a
    /// choice asking for k big cores is pinned — via sched_setaffinity —
    /// to the k *least-contended* big cores. Returns the concrete core
    /// ids to use for a requested choice under the given per-core shares.
    pub fn remap_least_contended(
        &self,
        device: &crate::soc::device::Device,
        requested: &[usize],
        share: &[f64],
    ) -> Vec<usize> {
        use crate::soc::core::CoreKind;
        let mut out = Vec::with_capacity(requested.len());
        for kind in [CoreKind::Little, CoreKind::Big, CoreKind::Prime] {
            let want = requested
                .iter()
                .filter(|&&c| device.kind_of(c) == kind)
                .count();
            if want == 0 {
                continue;
            }
            let mut cands = device.cores_of_kind(kind);
            // most-available first, index as tie-break (sort is stable)
            cands.sort_by(|&a, &b| {
                share[b].partial_cmp(&share[a]).unwrap()
            });
            out.extend_from_slice(&cands[..want]);
        }
        out.sort_unstable();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::device::{device, DeviceId};

    #[test]
    fn foreground_lands_on_fastest_cores() {
        let d = device(DeviceId::OnePlus8); // core 7 is prime
        let s = Scheduler::new(&d);
        assert_eq!(s.foreground_cores(1), vec![7]);
        let two = s.foreground_cores(2);
        assert!(two.contains(&7));
        assert!(two.iter().all(|&c| c >= 4), "fg must stay on big/prime");
    }

    #[test]
    fn training_share_drops_only_on_contended_cores() {
        let d = device(DeviceId::Pixel3);
        let s = Scheduler::new(&d);
        let share = s.training_share(2);
        let fg = s.foreground_cores(2);
        for c in 0..d.n_cores() {
            if fg.contains(&c) {
                assert!((share[c] - 0.25).abs() < 1e-12);
            } else {
                assert_eq!(share[c], 1.0);
            }
        }
    }

    #[test]
    fn idle_device_gives_full_shares() {
        let d = device(DeviceId::S10e);
        let s = Scheduler::new(&d);
        assert!(s.training_share(0).iter().all(|&x| x == 1.0));
    }

    #[test]
    fn foreground_share_degrades_with_training_threads() {
        let d = device(DeviceId::Pixel3);
        let s = Scheduler::new(&d);
        assert_eq!(s.foreground_share(0), 1.0);
        assert!(s.foreground_share(1) < 1.0);
        assert!(s.foreground_share(2) < s.foreground_share(1));
    }
}
