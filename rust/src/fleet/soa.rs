//! The struct-of-arrays fleet kernel: the allocation-free hot path that
//! steps 100k–1M [`FleetDevice`]s.
//!
//! The PR 1 [`ShardedEventLoop`](super::engine::ShardedEventLoop) pays,
//! per round, an mpsc message-node allocation per phase, fresh
//! `Vec`/`HashMap`s for job and result routing, a full sort of the
//! online set, and — dominating everything at 100k devices — a
//! per-device availability poll that chases an `Arc` into the trace,
//! computes the same grid index three times, and streams ~150 bytes of
//! `FleetDevice` per poll. This kernel removes all of that for the
//! scenario-instantiated population:
//!
//! - **Struct-of-arrays state.** Every `FleetDevice` field lives in a
//!   flat per-shard array (battery/charger state as a column-wise
//!   [`LoanBank`], RNG stream seeds, profile/model index,
//!   interference/thermal envelopes), so the poll sweep touches ~60
//!   sequential bytes per device instead of a scattered struct.
//! - **Staged batch passes, not per-device loops.** `poll` runs five
//!   lane-friendly stages: one batched `sample_many` call per distinct
//!   trace refreshes the `(level, charging)` combo cache (sound because
//!   the sample is a pure function of `(trace, shift, now)`); a gather
//!   pass widens the cache into per-device lanes; `LoanBank::tick_all`
//!   advances every loan branch-free; `availability_gate_many` writes a
//!   dense online bitmap with non-short-circuit mask arithmetic; a
//!   compaction pass emits the ascending online list. `step` likewise
//!   splits into a **batched RNG stage** (both envelope uniforms
//!   pre-drawn per job via [`envelope_draws`] — a fresh generator per
//!   `(seed, round)` cell, so batch order cannot change the stream), a
//!   pure **plan** loop (select-based [`envelope_apply`], no branches),
//!   and a **commit** loop (state writes + result scatter). Each stage
//!   body is straight-line arithmetic over flat slices that rustc
//!   auto-vectorizes.
//! - **Core-pinned persistent workers, double-buffered mailboxes.** One
//!   worker per shard lives for the whole drive, pinned to a CPU via
//!   [`util::affinity`](crate::util::affinity) (graceful no-op where
//!   unsupported); the control thread exchanges preallocated
//!   job/online/result buffers through a `Mutex + Condvar` mailbox
//!   (`std::mem::swap`, zero copies, zero steady-state allocation — no
//!   mpsc nodes).
//! - **Dense index routing.** Jobs carry their global picked-order
//!   `seq` and shard-local device index; results scatter into a reused
//!   per-seq array; the online lists k-way merge through a reused
//!   min-heap. The `HashMap<u32, StepJob>` / `HashMap<u32, StepResult>`
//!   routing of the PR 1 kernel is gone, and the steady-state round
//!   path performs no allocation at all.
//!
//! **Determinism.** The guarantee is unchanged *and* cross-kernel: all
//! stochastic streams stay keyed on (seed, device id) or (seed, round),
//! selection reuses [`round_rng`] plus an allocation-free
//! [`select_uniform_into`] proven draw-for-draw identical to the PR 1
//! selection, and the control thread folds results in global picked
//! order. Aggregates are bit-identical for any shard count **and**
//! bit-identical to the PR 1 kernel on the same scenario + seed —
//! `tests/fleet_determinism.rs` and the fleet bench assert both via
//! [`FleetOutcome::digest`].

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use crate::fl::availability::sweep_gate;
use crate::fl::energy_loan::LoanBank;
use crate::fl::selection::select_uniform_into;
// the lint determinism rule bans raw wall-clock constructors in
// digest-affecting modules; timing here is telemetry, never state
use crate::obs::wall_timer;
use crate::soc::device::DeviceId;
use crate::trace::resample::ResampledTrace;
use crate::util::affinity;

use super::coordinator::{FleetPolicy, StepCost};
use super::device::{envelope_apply, envelope_draws, FleetDevice};
use super::engine::{round_rng, DriveConfig, EMPTY_ROUND_WAIT_S};
use super::metrics::{FleetOutcome, KERNEL_SOA};

/// One participation order: dense routing indices + resolved §4.2 cost.
#[derive(Clone, Copy, Debug)]
struct SoaJob {
    /// Index into this round's global picked order (the fold key).
    seq: u32,
    /// Global device id (carried on events for traceability).
    device: u32,
    /// Shard-local device index (`device / n_shards`).
    local: u32,
    cost: StepCost,
    extra_time_s: f64,
    extra_energy_j: f64,
}

#[derive(Clone, Copy, Debug)]
struct SoaResult {
    seq: u32,
    time_s: f64,
    energy_j: f64,
    steps: u32,
}

/// A `(trace, shift)` pair — the unit the per-round sample cache keys on.
type Combo = (Arc<ResampledTrace>, f64);

/// All combos sharing one underlying trace, so the per-round cache
/// refresh is one batched [`ResampledTrace::sample_many`] call per
/// distinct trace instead of one scalar `sample` per combo.
struct TraceGroup {
    trace: Arc<ResampledTrace>,
    /// `(combo index, shift)` in combo-table order.
    members: Vec<(u32, f64)>,
}

/// Shard-local telemetry counters, bumped lock-free inside the worker's
/// own sweep/step and folded into the outcome registry in shard order
/// after the workers are parked — the FNV-digest barrier discipline, so
/// the allocation-free hot path never sees a lock or an atomic for
/// telemetry's sake.
#[derive(Clone, Copy, Debug, Default)]
struct SoaTally {
    polled: u64,
    online: u64,
    stepped: u64,
    /// Envelope uniforms pre-drawn by the batched RNG stage.
    rng_draws: u64,
    /// 1 if this shard's worker successfully pinned to a CPU.
    pinned: u64,
}

/// One shard's device population, one field per array ("SoA row" `k` is
/// shard-local device `k`, global id `shard_idx + k * n_shards`).
struct SoaShard {
    ids: Vec<usize>,
    models: Vec<DeviceId>,
    /// Index into the fleet's combo table (profile of trace + shift).
    combo: Vec<u32>,
    min_level_pct: Vec<f64>,
    /// Battery/charger state as flat columns. The tick/borrow
    /// arithmetic is *the* `fl::LoanBank` arithmetic, proven
    /// bit-identical to scalar `EnergyLoan` in `fl::energy_loan` —
    /// exactness with the PR 1 kernel by construction, not by mirroring.
    bank: LoanBank,
    /// Per-device stream seed (interference/thermal draws).
    seeds: Vec<u64>,
    epoch_steps: Vec<u32>,
    interference_p: Vec<f64>,
    interference_slowdown: Vec<f64>,
    thermal_throttle_p: Vec<f64>,
    thermal_derate: Vec<f64>,
    participations: Vec<u32>,
    train_time_s: Vec<f64>,
    /// Per-combo fused samples, refreshed each round.
    cache_level: Vec<f64>,
    cache_charging: Vec<bool>,
    // Batch-pass scratch columns, all reused across rounds.
    /// Per-device level lanes (gathered from the combo cache).
    lvl: Vec<f64>,
    /// Per-device charging lanes.
    chg: Vec<bool>,
    /// Dense online bitmap the gate sweep writes.
    mask: Vec<bool>,
    /// Per-group wrapped sample times / sampled values.
    scratch_ts: Vec<f64>,
    scratch_lvl: Vec<f64>,
    scratch_chg: Vec<bool>,
    /// Pre-drawn envelope uniforms, one pair per job.
    draw0: Vec<f64>,
    draw1: Vec<f64>,
    /// Planned per-job cost, plan → commit.
    plan_time: Vec<f64>,
    plan_energy: Vec<f64>,
    tally: SoaTally,
}

impl SoaShard {
    fn with_capacity(cap: usize) -> SoaShard {
        SoaShard {
            ids: Vec::with_capacity(cap),
            models: Vec::with_capacity(cap),
            combo: Vec::with_capacity(cap),
            min_level_pct: Vec::with_capacity(cap),
            bank: LoanBank::with_capacity(cap),
            seeds: Vec::with_capacity(cap),
            epoch_steps: Vec::with_capacity(cap),
            interference_p: Vec::with_capacity(cap),
            interference_slowdown: Vec::with_capacity(cap),
            thermal_throttle_p: Vec::with_capacity(cap),
            thermal_derate: Vec::with_capacity(cap),
            participations: Vec::with_capacity(cap),
            train_time_s: Vec::with_capacity(cap),
            cache_level: Vec::new(),
            cache_charging: Vec::new(),
            lvl: Vec::new(),
            chg: Vec::new(),
            mask: Vec::new(),
            scratch_ts: Vec::new(),
            scratch_lvl: Vec::new(),
            scratch_chg: Vec::new(),
            draw0: Vec::new(),
            draw1: Vec::new(),
            plan_time: Vec::new(),
            plan_energy: Vec::new(),
            tally: SoaTally::default(),
        }
    }

    fn len(&self) -> usize {
        self.ids.len()
    }

    fn push_device(&mut self, d: FleetDevice, combo: u32) {
        self.ids.push(d.id);
        self.models.push(d.model);
        self.combo.push(combo);
        self.min_level_pct.push(d.min_level_pct);
        self.bank.push(&d.loan);
        self.seeds.push(d.seed);
        self.epoch_steps.push(d.epoch_steps as u32);
        self.interference_p.push(d.interference_p);
        self.interference_slowdown.push(d.interference_slowdown);
        self.thermal_throttle_p.push(d.thermal_throttle_p);
        self.thermal_derate.push(d.thermal_derate);
        self.participations.push(d.participations as u32);
        self.train_time_s.push(d.train_time_s);
    }

    /// Availability sweep as staged batch passes (module docs):
    /// combo-cache refresh via one `sample_many` per distinct trace,
    /// a per-device gather into dense lanes, the shared branch-free
    /// `fl::availability::sweep_gate` tick→gate pass (also the FL
    /// engine's `ClientLanes::poll` sweep), and a compaction pass into
    /// the ascending online
    /// list. Decision-identical to gating each device through
    /// `fl::availability_gate_sampled`: the cache is sound because the
    /// sample depends only on `(trace, shift, now_s)`, and
    /// tick-then-gate is the scalar gate's own statement order.
    fn poll(
        &mut self,
        now_s: f64,
        n_combos: usize,
        groups: &[TraceGroup],
        online: &mut Vec<u32>,
        shard_idx: usize,
        n_shards: usize,
    ) {
        // stage 1: combo cache refresh, one batched sample per trace
        self.cache_level.resize(n_combos, 0.0);
        self.cache_charging.resize(n_combos, false);
        for g in groups {
            self.scratch_ts.clear();
            self.scratch_ts.extend(
                g.members
                    .iter()
                    .map(|&(_, shift)| g.trace.wrap(now_s + shift)),
            );
            g.trace.sample_many(
                &self.scratch_ts,
                &mut self.scratch_lvl,
                &mut self.scratch_chg,
            );
            for (m, &(ci, _)) in g.members.iter().enumerate() {
                self.cache_level[ci as usize] = self.scratch_lvl[m];
                self.cache_charging[ci as usize] = self.scratch_chg[m];
            }
        }
        // stage 2: gather per-device (level, charging) lanes
        let n = self.len();
        self.lvl.clear();
        self.chg.clear();
        for k in 0..n {
            let ci = self.combo[k] as usize;
            self.lvl.push(self.cache_level[ci]);
            self.chg.push(self.cache_charging[ci]);
        }
        // stages 3+4: the shared branch-free tick→gate sweep (one
        // definition with the FL engine's `ClientLanes::poll`, so the
        // two round drivers evolve loan bits identically)
        sweep_gate(
            &mut self.bank,
            now_s,
            &self.lvl,
            &self.chg,
            &self.min_level_pct,
            &mut self.mask,
        );
        // stage 5: compact the bitmap into ascending global ids
        online.clear();
        for (k, &hit) in self.mask.iter().enumerate() {
            if hit {
                online.push((shard_idx + k * n_shards) as u32);
            }
        }
        self.tally.polled += n as u64;
        self.tally.online += online.len() as u64;
    }

    /// Local epochs for this round's jobs as three staged batch passes:
    /// batched RNG (pre-draw both envelope uniforms per job — a fresh
    /// generator per `(seed, round)` cell, so the scalar draw sequence
    /// is reproduced exactly), a pure plan loop (select-based
    /// [`envelope_apply`], `cost · steps · multiplier + exploration
    /// bill` in the PR 1 worker's operation order), and a commit loop
    /// (state writes + result scatter). Replaces the per-job event
    /// queue bit-identically: every job's cost is independent of the
    /// others, each device is picked at most once per round, and the
    /// control thread scatters results by `seq` — so intra-shard
    /// completion order was never observable.
    fn step(
        &mut self,
        _now_s: f64,
        round: usize,
        jobs: &[SoaJob],
        results: &mut Vec<SoaResult>,
    ) {
        results.clear();
        self.tally.stepped += jobs.len() as u64;
        // stage 1: batched RNG
        self.draw0.clear();
        self.draw1.clear();
        for j in jobs {
            let (d0, d1) =
                envelope_draws(self.seeds[j.local as usize], round);
            self.draw0.push(d0);
            self.draw1.push(d1);
        }
        self.tally.rng_draws += 2 * jobs.len() as u64;
        // stage 2: plan — pure, branch-free cost arithmetic
        self.plan_time.clear();
        self.plan_energy.clear();
        for (ji, j) in jobs.iter().enumerate() {
            let k = j.local as usize;
            let steps = self.epoch_steps[k];
            let mult = envelope_apply(
                self.draw0[ji],
                self.draw1[ji],
                self.interference_p[k],
                self.interference_slowdown[k],
                self.thermal_throttle_p[k],
                self.thermal_derate[k],
            );
            self.plan_time.push(
                j.cost.latency_s * steps as f64 * mult + j.extra_time_s,
            );
            self.plan_energy.push(
                j.cost.energy_j * steps as f64 * mult + j.extra_energy_j,
            );
        }
        // stage 3: commit — FleetDevice::charge on the SoA columns
        for (ji, j) in jobs.iter().enumerate() {
            let k = j.local as usize;
            let t = self.plan_time[ji];
            let e = self.plan_energy[ji];
            self.train_time_s[k] += t;
            self.bank.borrow(k, e);
            self.participations[k] += 1;
            results.push(SoaResult {
                seq: j.seq,
                time_s: t,
                energy_j: e,
                steps: self.epoch_steps[k],
            });
        }
    }
}

/// What the control thread asks a shard worker to do next.
#[derive(Clone, Copy, Debug)]
enum Cmd {
    /// Nothing pending (the worker's wait state).
    Idle,
    Poll { now_s: f64 },
    Step { now_s: f64, round: usize },
    Stop,
}

/// The double-buffered exchange slot between control and one worker.
/// Buffers move by `std::mem::swap` only; after the first round every
/// round is allocation-free.
struct Mailbox {
    cmd: Cmd,
    /// Worker completed the last command (control's wait predicate).
    done: bool,
    /// Worker panicked (set by its drop guard so control can't hang).
    dead: bool,
    online: Vec<u32>,
    jobs: Vec<SoaJob>,
    results: Vec<SoaResult>,
}

struct Slot {
    mx: Mutex<Mailbox>,
    cv: Condvar,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            mx: Mutex::new(Mailbox {
                cmd: Cmd::Idle,
                done: false,
                dead: false,
                online: Vec::new(),
                jobs: Vec::new(),
                results: Vec::new(),
            }),
            cv: Condvar::new(),
        }
    }
}

/// Hand control a command; for `Step`, swap the prepared job buffer in.
/// A poisoned mailbox (its worker unwound holding the lock) is an
/// error, not a cascade — the caller releases the fleet via
/// [`StopOnDrop`] and reports the dead shard.
fn send(
    slot: &Slot,
    cmd: Cmd,
    jobs: Option<&mut Vec<SoaJob>>,
) -> crate::Result<()> {
    let mut g = slot
        .mx
        .lock()
        .map_err(|_| crate::err!("soa fleet: mailbox poisoned"))?;
    if let Some(j) = jobs {
        std::mem::swap(&mut g.jobs, j);
    }
    g.cmd = cmd;
    g.done = false;
    slot.cv.notify_all();
    Ok(())
}

/// Block until shard `si` finishes its command, returning the mailbox
/// for buffer exchange. A dead worker turns into a control-thread
/// error (whose propagation drops [`StopOnDrop`], releasing the whole
/// fleet so the scope join can't deadlock).
fn wait_done<'a>(
    slots: &'a [Slot],
    si: usize,
) -> crate::Result<MutexGuard<'a, Mailbox>> {
    let slot = &slots[si];
    let poisoned =
        || crate::err!("soa fleet: shard {si} mailbox poisoned");
    let mut g = slot.mx.lock().map_err(|_| poisoned())?;
    while !g.done {
        g = slot.cv.wait(g).map_err(|_| poisoned())?;
    }
    crate::ensure!(!g.dead, "soa fleet: shard worker {si} died");
    Ok(g)
}

/// Releases every worker on drop — normal exit or control-thread
/// unwind alike. The PR 1 kernel got this for free (dropping the mpsc
/// senders errored the workers' `recv`); with condvar mailboxes a
/// control panic (a policy callback, a poisoned lock) would otherwise
/// leave workers parked forever and deadlock the scope join. Locks are
/// taken fallibly here: a poisoned mailbox belongs to a worker that
/// already died and needs no release.
struct StopOnDrop<'a> {
    slots: &'a [Slot],
}

impl Drop for StopOnDrop<'_> {
    fn drop(&mut self) {
        for slot in self.slots {
            if let Ok(mut g) = slot.mx.lock() {
                g.cmd = Cmd::Stop;
                slot.cv.notify_all();
            }
        }
    }
}

/// Drop guard that flags the mailbox if the worker unwinds, so the
/// control thread fails fast instead of waiting forever.
struct DeathNotice<'a> {
    slot: &'a Slot,
}

impl Drop for DeathNotice<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            if let Ok(mut g) = self.slot.mx.lock() {
                g.dead = true;
                g.done = true;
                self.slot.cv.notify_all();
            }
        }
    }
}

fn worker_loop(
    shard: &mut SoaShard,
    slot: &Slot,
    n_combos: usize,
    groups: &[TraceGroup],
    shard_idx: usize,
    n_shards: usize,
) {
    let _notice = DeathNotice { slot };
    // Pin this worker to a fixed CPU so its shard's SoA columns stay
    // hot in one core's caches across rounds. Best-effort: a refusal
    // (unsupported platform, --no-pin, restrictive cpuset) only costs
    // the telemetry bit — never correctness (the digest can't see it).
    if affinity::pin_current_thread(shard_idx % affinity::available_cpus())
    {
        shard.tally.pinned = 1;
    }
    let mut online: Vec<u32> = Vec::new();
    let mut jobs: Vec<SoaJob> = Vec::new();
    let mut results: Vec<SoaResult> = Vec::new();
    loop {
        // A poisoned mailbox means a control- or sibling-side unwind
        // while holding the lock: retire this worker quietly — the
        // control thread sees the same poison through `wait_done` and
        // errors there, so nothing can hang on us.
        let cmd = {
            let Ok(mut g) = slot.mx.lock() else { return };
            while matches!(g.cmd, Cmd::Idle) {
                g = match slot.cv.wait(g) {
                    Ok(g) => g,
                    Err(_) => return,
                };
            }
            let c = g.cmd;
            g.cmd = Cmd::Idle;
            if matches!(c, Cmd::Step { .. }) {
                std::mem::swap(&mut g.jobs, &mut jobs);
            }
            c
        };
        match cmd {
            Cmd::Poll { now_s } => {
                shard.poll(
                    now_s, n_combos, groups, &mut online, shard_idx,
                    n_shards,
                );
                let Ok(mut g) = slot.mx.lock() else { return };
                std::mem::swap(&mut g.online, &mut online);
                g.done = true;
                slot.cv.notify_all();
            }
            Cmd::Step { now_s, round } => {
                shard.step(now_s, round, &jobs, &mut results);
                let Ok(mut g) = slot.mx.lock() else { return };
                std::mem::swap(&mut g.results, &mut results);
                g.done = true;
                slot.cv.notify_all();
            }
            Cmd::Stop => return,
            // the wait loop above never hands Idle out, but a spurious
            // one should re-park the worker, not unwind it
            Cmd::Idle => {}
        }
    }
}

/// Ascending k-way merge of the per-shard online lists (each already
/// ascending) into global id order — replaces the PR 1 flatten +
/// `sort_unstable`. O(n log k) via a hand-rolled min-heap of
/// `(value, shard)` heads; `cursors`, `heap` and `out` are all
/// caller-owned and reused across rounds, so the steady-state merge
/// allocates nothing. Values are globally unique device ids, so no
/// tie-break is needed.
fn merge_online(
    lists: &[Vec<u32>],
    cursors: &mut [usize],
    heap: &mut Vec<(u32, u32)>,
    out: &mut Vec<usize>,
) {
    out.clear();
    heap.clear();
    for (s, list) in lists.iter().enumerate() {
        cursors[s] = 0;
        if !list.is_empty() {
            heap.push((list[0], s as u32));
            cursors[s] = 1;
        }
    }
    for i in (0..heap.len() / 2).rev() {
        sift_down(heap, i);
    }
    while let Some(&(v, s)) = heap.first() {
        out.push(v as usize);
        let si = s as usize;
        if cursors[si] < lists[si].len() {
            heap[0] = (lists[si][cursors[si]], s);
            cursors[si] += 1;
        } else {
            let last = heap.len() - 1;
            heap.swap(0, last);
            heap.pop();
        }
        sift_down(heap, 0);
    }
}

fn sift_down(heap: &mut [(u32, u32)], mut i: usize) {
    loop {
        let l = 2 * i + 1;
        let r = l + 1;
        let mut m = i;
        if l < heap.len() && heap[l].0 < heap[m].0 {
            m = l;
        }
        if r < heap.len() && heap[r].0 < heap[m].0 {
            m = r;
        }
        if m == i {
            return;
        }
        heap.swap(i, m);
        i = m;
    }
}

/// The struct-of-arrays fleet kernel over a [`FleetDevice`] population.
///
/// Same drive contract as the generic
/// [`ShardedEventLoop`](super::engine::ShardedEventLoop) — build with
/// [`new`](SoaFleet::new), run rounds with [`drive`](SoaFleet::drive),
/// tear down with [`into_devices`](SoaFleet::into_devices) — but the
/// hot path is the allocation-free SoA sweep described in the module
/// docs.
pub struct SoaFleet {
    shards: Vec<SoaShard>,
    /// Distinct `(trace, shift)` profiles across the fleet.
    combos: Vec<Combo>,
    /// Combos grouped by underlying trace (batched cache refresh).
    groups: Vec<TraceGroup>,
    /// SoC model per global device id (central policy resolution).
    models: Vec<DeviceId>,
    n_devices: usize,
}

impl SoaFleet {
    /// Unpack `devices` (global id = vector index) into per-shard flat
    /// arrays, round-robin across `n_shards` — the same partition (and
    /// clamp) as the generic kernel.
    pub fn new(devices: Vec<FleetDevice>, n_shards: usize) -> SoaFleet {
        let n_shards = n_shards.max(1).min(devices.len().max(1));
        let n_devices = devices.len();
        let models: Vec<DeviceId> =
            devices.iter().map(|d| d.model).collect();
        let mut combos: Vec<Combo> = Vec::new();
        let mut combo_of: HashMap<(usize, u64), u32> = HashMap::new();
        let mut shards: Vec<SoaShard> = (0..n_shards)
            .map(|_| SoaShard::with_capacity(n_devices / n_shards + 1))
            .collect();
        for (i, d) in devices.into_iter().enumerate() {
            let key = (Arc::as_ptr(&d.trace) as usize, d.shift_s.to_bits());
            let ci = match combo_of.get(&key) {
                Some(&c) => c,
                None => {
                    let c = combos.len() as u32;
                    combos.push((d.trace.clone(), d.shift_s));
                    combo_of.insert(key, c);
                    c
                }
            };
            shards[i % n_shards].push_device(d, ci);
        }
        let mut groups: Vec<TraceGroup> = Vec::new();
        let mut group_of: HashMap<usize, usize> = HashMap::new();
        for (ci, (trace, shift)) in combos.iter().enumerate() {
            let gi = *group_of
                .entry(Arc::as_ptr(trace) as usize)
                .or_insert_with(|| {
                    groups.push(TraceGroup {
                        trace: trace.clone(),
                        members: Vec::new(),
                    });
                    groups.len() - 1
                });
            groups[gi].members.push((ci as u32, *shift));
        }
        SoaFleet {
            shards,
            combos,
            groups,
            models,
            n_devices,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    /// Distinct `(trace, shift)` profiles the sample cache keys on.
    pub fn n_combos(&self) -> usize {
        self.combos.len()
    }

    /// Tear down, repacking the arrays into [`FleetDevice`]s in
    /// global-id order (errors, rather than panicking, if a shard lost
    /// devices).
    pub fn into_devices(self) -> crate::Result<Vec<FleetDevice>> {
        let n = self.n_devices;
        let n_shards = self.shards.len();
        for (s, shard) in self.shards.iter().enumerate() {
            let expect = if s < n {
                (n - s + n_shards - 1) / n_shards
            } else {
                0
            };
            crate::ensure!(
                shard.len() == expect,
                "soa fleet lost devices: shard {s} holds {} rows, \
                 expected {expect} of {n}",
                shard.len()
            );
        }
        let mut out = Vec::with_capacity(n);
        for gid in 0..n {
            let shard = &self.shards[gid % n_shards];
            let k = gid / n_shards;
            let (trace, shift) = &self.combos[shard.combo[k] as usize];
            out.push(FleetDevice {
                id: shard.ids[k],
                model: shard.models[k],
                trace: trace.clone(),
                shift_s: *shift,
                loan: shard.bank.get(k),
                epoch_steps: shard.epoch_steps[k] as usize,
                min_level_pct: shard.min_level_pct[k],
                interference_p: shard.interference_p[k],
                interference_slowdown: shard.interference_slowdown[k],
                thermal_throttle_p: shard.thermal_throttle_p[k],
                thermal_derate: shard.thermal_derate[k],
                seed: shard.seeds[k],
                participations: shard.participations[k] as usize,
                train_time_s: shard.train_time_s[k],
            });
        }
        Ok(out)
    }

    /// Run `cfg.rounds` rounds of availability → selection → local
    /// epoch → clock advance. Scheduling, stochastic streams and fold
    /// order replicate the generic kernel exactly (see the module
    /// docs), so the returned aggregates are bit-identical to it at
    /// every shard count.
    pub fn drive(
        &mut self,
        policy: &mut dyn FleetPolicy,
        cfg: &DriveConfig,
    ) -> crate::Result<FleetOutcome> {
        let wall0 = wall_timer();
        let n_shards = self.shards.len();
        let shards = &mut self.shards;
        let n_combos = self.combos.len();
        let groups = &self.groups;
        let models = &self.models;
        for shard in shards.iter_mut() {
            shard.tally = SoaTally::default();
        }

        let mut outcome = FleetOutcome {
            scenario: cfg.scenario.clone(),
            arm: cfg.arm.name(),
            devices: self.n_devices,
            shards: n_shards,
            kernel: KERNEL_SOA,
            ..Default::default()
        };

        let slots: Vec<Slot> = (0..n_shards).map(|_| Slot::new()).collect();

        std::thread::scope(|scope| -> crate::Result<()> {
            let mut handles = Vec::with_capacity(n_shards);
            for (si, shard) in shards.iter_mut().enumerate() {
                let slot = &slots[si];
                handles.push(scope.spawn(move || {
                    worker_loop(shard, slot, n_combos, groups, si, n_shards)
                }));
            }
            // The control body runs fallibly: leaving it — normally or
            // through `?` — drops StopOnDrop, which releases every
            // worker before the joins below.
            let run = (|| -> crate::Result<()> {
                let _stop = StopOnDrop { slots: &slots };

                // Control-side buffers, all reused across rounds: after the
                // first round the steady state allocates nothing.
                let mut online_lists: Vec<Vec<u32>> =
                    (0..n_shards).map(|_| Vec::new()).collect();
                let mut job_bufs: Vec<Vec<SoaJob>> =
                    (0..n_shards).map(|_| Vec::new()).collect();
                let mut cursors: Vec<usize> = vec![0; n_shards];
                let mut merge_heap: Vec<(u32, u32)> = Vec::new();
                let mut online: Vec<usize> = Vec::new();
                let mut picked: Vec<usize> = Vec::new();
                let mut scratch: HashMap<usize, usize> = HashMap::new();
                let mut active: Vec<usize> = Vec::new();
                let mut fold_time: Vec<f64> = Vec::new();
                let mut fold_energy: Vec<f64> = Vec::new();
                let mut fold_steps: Vec<u32> = Vec::new();

                let mut now_s = 0.0f64;
                let mut total_energy = 0.0f64;
                let mut total_steps = 0u64;
                let mut participations = 0u64;

                // Telemetry locals — wall-clock observers only, never fed
                // back into the simulation, so the digest cannot see them.
                let mut spans = crate::obs::Spans::default();
                let sp_avail = spans.span(crate::obs::PHASE_AVAILABILITY);
                let sp_select = spans.span(crate::obs::PHASE_SELECT);
                let sp_step = spans.span(crate::obs::PHASE_STEP);
                let sp_agg = spans.span(crate::obs::PHASE_AGGREGATE);
                let mut metrics = crate::obs::MetricsRegistry::default();
                let c_online = metrics.counter("fleet.online");
                let c_picked = metrics.counter("fleet.picked");
                let h_round = metrics
                    .hist("fleet.round_wall_s", crate::obs::LATENCY_BUCKETS_S);
                let h_avail = metrics.hist(
                    "fleet.stage.availability_s",
                    crate::obs::LATENCY_BUCKETS_S,
                );
                let h_select = metrics
                    .hist("fleet.stage.select_s", crate::obs::LATENCY_BUCKETS_S);
                let h_step = metrics
                    .hist("fleet.stage.step_s", crate::obs::LATENCY_BUCKETS_S);
                let h_agg = metrics.hist(
                    "fleet.stage.aggregate_s",
                    crate::obs::LATENCY_BUCKETS_S,
                );
                // Trace timestamps: anchored at drive start, read only at
                // the control thread's own barriers.
                let tclock = crate::obs::TraceClock::start();

                for round in 0..cfg.rounds {
                    let round_t0 = wall_timer();
                    if cfg.obs.enabled() {
                        cfg.obs.emit(&crate::obs::RoundStart {
                            scenario: &cfg.scenario,
                            round,
                            now_s,
                        });
                    }
                    // 1. availability: every shard sweeps in parallel
                    let phase_t0 = wall_timer();
                    for slot in &slots {
                        send(slot, Cmd::Poll { now_s }, None)?;
                    }
                    for si in 0..n_shards {
                        let mut g = wait_done(&slots, si)?;
                        std::mem::swap(&mut g.online, &mut online_lists[si]);
                    }
                    if cfg.obs.enabled() {
                        for (si, list) in online_lists.iter().enumerate() {
                            cfg.obs.emit(&crate::obs::ShardProgress {
                                round,
                                shard: si,
                                online: list.len(),
                            });
                        }
                    }
                    merge_online(
                        &online_lists,
                        &mut cursors,
                        &mut merge_heap,
                        &mut online,
                    );
                    outcome.online_per_round.push((round, online.len()));
                    let avail_s = phase_t0.elapsed().as_secs_f64();
                    spans.record(sp_avail, avail_s);
                    metrics.observe(h_avail, avail_s);
                    metrics.add(c_online, online.len() as u64);
                    if online.is_empty() {
                        now_s += EMPTY_ROUND_WAIT_S;
                        metrics.observe(
                            h_round,
                            round_t0.elapsed().as_secs_f64(),
                        );
                        if cfg.obs.enabled() {
                            cfg.obs.emit(&crate::obs::RoundEnd {
                                round,
                                online: 0,
                                picked: 0,
                                round_time_s: 0.0,
                                round_energy_j: 0.0,
                                now_s,
                            });
                        }
                        continue;
                    }

                    // 2. selection: central, keyed on (seed, round) only
                    let phase_t0 = wall_timer();
                    let mut rng = round_rng(cfg.seed, round);
                    select_uniform_into(
                        &online,
                        cfg.clients_per_round,
                        &mut rng,
                        &mut scratch,
                        &mut picked,
                    );
                    metrics.add(c_picked, picked.len() as u64);

                    // 3. resolve policy costs centrally, in picked order
                    //    (§4.2 exploration billing is order-sensitive)
                    for buf in job_bufs.iter_mut() {
                        buf.clear();
                    }
                    for (seq, &gid) in picked.iter().enumerate() {
                        let rc = policy.step_cost(models[gid], gid);
                        job_bufs[gid % n_shards].push(SoaJob {
                            seq: seq as u32,
                            device: gid as u32,
                            local: (gid / n_shards) as u32,
                            cost: rc.cost,
                            extra_time_s: rc.exploration_time_s,
                            extra_energy_j: rc.exploration_energy_j,
                        });
                    }

                    let select_s = phase_t0.elapsed().as_secs_f64();
                    spans.record(sp_select, select_s);
                    metrics.observe(h_select, select_s);
                    if cfg.obs.trace_on() {
                        // one timestamp per barrier: the edges record WHEN
                        // the selection barrier passed, not a fictional
                        // per-device ordering within it
                        let t_s = tclock.now_s();
                        for (seq, &gid) in picked.iter().enumerate() {
                            cfg.obs.emit(
                                &crate::obs::TraceEdge::new(
                                    round as u32,
                                    gid as u64,
                                    crate::obs::trace::EDGE_SELECTED,
                                    t_s,
                                )
                                .with("seq", seq as f64),
                            );
                        }
                    }

                    // 4. parallel event-driven local epochs
                    let phase_t0 = wall_timer();
                    active.clear();
                    for si in 0..n_shards {
                        if job_bufs[si].is_empty() {
                            continue;
                        }
                        active.push(si);
                        send(
                            &slots[si],
                            Cmd::Step { now_s, round },
                            Some(&mut job_bufs[si]),
                        )?;
                    }

                    // 5. scatter results by seq, fold in global picked
                    //    order — the same fixed reduction order as the
                    //    generic kernel, so aggregates are bit-identical
                    fold_time.clear();
                    fold_time.resize(picked.len(), 0.0);
                    fold_energy.clear();
                    fold_energy.resize(picked.len(), 0.0);
                    fold_steps.clear();
                    fold_steps.resize(picked.len(), 0);
                    for &si in &active {
                        let mut g = wait_done(&slots, si)?;
                        for r in g.results.drain(..) {
                            let s = r.seq as usize;
                            fold_time[s] = r.time_s;
                            fold_energy[s] = r.energy_j;
                            fold_steps[s] = r.steps;
                        }
                    }
                    let step_s = phase_t0.elapsed().as_secs_f64();
                    spans.record(sp_step, step_s);
                    metrics.observe(h_step, step_s);
                    if cfg.obs.trace_on() {
                        let t_s = tclock.now_s();
                        for (s, &gid) in picked.iter().enumerate() {
                            cfg.obs.emit(
                                &crate::obs::TraceEdge::new(
                                    round as u32,
                                    gid as u64,
                                    crate::obs::trace::EDGE_STEPPED,
                                    t_s,
                                )
                                .with("time_s", fold_time[s])
                                .with("energy_j", fold_energy[s]),
                            );
                        }
                    }
                    let phase_t0 = wall_timer();
                    let mut round_time = 0.0f64;
                    let mut round_energy = 0.0f64;
                    for s in 0..picked.len() {
                        total_energy += fold_energy[s];
                        round_energy += fold_energy[s];
                        total_steps += fold_steps[s] as u64;
                        participations += 1;
                        round_time = round_time.max(fold_time[s]);
                    }
                    now_s += round_time + cfg.server_overhead_s;
                    outcome.rounds_run = round + 1;
                    let agg_s = phase_t0.elapsed().as_secs_f64();
                    spans.record(sp_agg, agg_s);
                    metrics.observe(h_agg, agg_s);
                    metrics
                        .observe(h_round, round_t0.elapsed().as_secs_f64());
                    if cfg.obs.enabled() {
                        cfg.obs.emit(&crate::obs::RoundEnd {
                            round,
                            online: online.len(),
                            picked: picked.len(),
                            round_time_s: round_time,
                            round_energy_j: round_energy,
                            now_s,
                        });
                    }
                }

                outcome.total_time_s = now_s;
                outcome.total_energy_j = total_energy;
                outcome.total_steps = total_steps;
                outcome.participations = participations;
                outcome.spans = spans;
                outcome.metrics = metrics;
                Ok(())
            })();
            // Join the workers so a panicked one surfaces as an error
            // from this scope instead of an abort at scope exit.
            let mut panicked = 0usize;
            for h in handles {
                if h.join().is_err() {
                    panicked += 1;
                }
            }
            run?;
            crate::ensure!(
                panicked == 0,
                "{panicked} soa shard worker(s) panicked"
            );
            Ok(())
        })?;
        outcome.wall_s = wall0.elapsed().as_secs_f64();
        // Worker tallies, folded in shard order now that every worker
        // is parked (the scope joined them) and the borrows are back.
        for shard in &self.shards {
            outcome.metrics.inc("fleet.shard_polls", shard.tally.polled);
            outcome
                .metrics
                .inc("fleet.shard_online", shard.tally.online);
            outcome.metrics.inc("fleet.shard_steps", shard.tally.stepped);
            outcome.metrics.inc("fleet.rng_draws", shard.tally.rng_draws);
            outcome
                .metrics
                .inc("fleet.workers_pinned", shard.tally.pinned);
        }
        if cfg.obs.enabled() {
            cfg.obs.emit(&crate::obs::SpanSummary {
                scope: "fleet-drive",
                spans: &outcome.spans,
            });
        }
        Ok(outcome)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::FlArm;
    use crate::fleet::engine::{run_scenario, run_scenario_reference};
    use crate::fleet::scenario::ScenarioSpec;

    fn tiny_spec() -> ScenarioSpec {
        ScenarioSpec {
            name: "soa-unit".to_string(),
            devices: 300,
            rounds: 10,
            clients_per_round: 15,
            trace_users: 2,
            ..ScenarioSpec::default()
        }
    }

    #[test]
    fn soa_matches_reference_kernel_bit_for_bit() {
        let spec = tiny_spec();
        let reference = run_scenario_reference(&spec, 1, FlArm::Swan).unwrap();
        for shards in [1usize, 3, 8] {
            let soa = run_scenario(&spec, shards, FlArm::Swan).unwrap();
            assert_eq!(
                soa.digest(),
                reference.digest(),
                "soa@{shards} shards vs reference"
            );
            assert_eq!(soa.online_per_round, reference.online_per_round);
            assert_eq!(
                soa.total_time_s.to_bits(),
                reference.total_time_s.to_bits()
            );
            assert_eq!(
                soa.total_energy_j.to_bits(),
                reference.total_energy_j.to_bits()
            );
        }
    }

    #[test]
    fn soa_baseline_arm_matches_reference_too() {
        let spec = tiny_spec();
        let a = run_scenario(&spec, 4, FlArm::Baseline).unwrap();
        let b = run_scenario_reference(&spec, 4, FlArm::Baseline).unwrap();
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn device_round_trip_preserves_state_and_order() {
        let spec = tiny_spec();
        let devices = spec.build_fleet().unwrap();
        let expect: Vec<(usize, u64, f64)> = devices
            .iter()
            .map(|d| (d.id, d.seed, d.shift_s))
            .collect();
        let fleet = SoaFleet::new(devices, 7);
        assert_eq!(fleet.n_shards(), 7);
        assert_eq!(fleet.n_devices(), 300);
        // 2 traces × 24 shifts bound the combo table
        assert!(fleet.n_combos() <= 48, "combos {}", fleet.n_combos());
        let back = fleet.into_devices().unwrap();
        assert_eq!(back.len(), 300);
        for (d, (id, seed, shift)) in back.iter().zip(&expect) {
            assert_eq!(d.id, *id);
            assert_eq!(d.seed, *seed);
            assert_eq!(d.shift_s, *shift);
            assert_eq!(d.participations, 0);
        }
    }

    #[test]
    fn round_trip_after_a_drive_keeps_charges() {
        let spec = tiny_spec();
        let out = run_scenario(&spec, 2, FlArm::Swan).unwrap();
        assert!(out.participations > 0);
        // drive through the raw API to inspect surviving state
        let workload =
            crate::workload::load_or_builtin(spec.workload, "artifacts");
        let mut coord = super::super::coordinator::ProfileCoordinator::new(
            workload,
        );
        let mut policy = super::super::coordinator::CoordinatorPolicy {
            coord: &mut coord,
            arm: FlArm::Swan,
        };
        let mut fleet = SoaFleet::new(spec.build_fleet().unwrap(), 3);
        let cfg = super::super::engine::drive_config(
            &spec,
            FlArm::Swan,
            crate::obs::Obs::off(),
        );
        let drove = fleet.drive(&mut policy, &cfg).unwrap();
        let back = fleet.into_devices().unwrap();
        let parts: usize = back.iter().map(|d| d.participations).sum();
        assert_eq!(parts as u64, drove.participations);
        let trained: f64 = back.iter().map(|d| d.train_time_s).sum();
        assert!(trained > 0.0);
    }

    #[test]
    fn merge_online_is_an_ascending_merge() {
        let lists = vec![vec![0u32, 4, 8], vec![1, 5], vec![2], vec![]];
        let mut cursors = vec![0usize; 4];
        let mut heap = vec![(77u32, 77u32)]; // stale scratch is cleared
        let mut out = vec![99usize]; // stale content must be cleared
        merge_online(&lists, &mut cursors, &mut heap, &mut out);
        assert_eq!(out, vec![0, 1, 2, 4, 5, 8]);
        // reuse with different content
        let lists2 = vec![vec![3u32], vec![0, 1, 2]];
        let mut cursors2 = vec![7usize, 7];
        merge_online(&lists2, &mut cursors2, &mut heap, &mut out);
        assert_eq!(out, vec![0, 1, 2, 3]);
    }

    #[test]
    fn merge_online_heap_matches_a_sort_on_random_round_robin_lists() {
        // the round-robin partition the fleet actually produces:
        // shard s holds ids ≡ s (mod k), each list ascending
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x4E46);
        for &k in &[1usize, 3, 8] {
            let mut lists: Vec<Vec<u32>> = vec![Vec::new(); k];
            let mut want: Vec<usize> = Vec::new();
            for gid in 0..500u32 {
                if rng.bool(0.3) {
                    lists[gid as usize % k].push(gid);
                    want.push(gid as usize);
                }
            }
            let mut cursors = vec![0usize; k];
            let mut heap = Vec::new();
            let mut out = Vec::new();
            merge_online(&lists, &mut cursors, &mut heap, &mut out);
            assert_eq!(out, want, "k={k}");
        }
    }

    #[test]
    fn shard_count_clamped_to_population() {
        let spec = ScenarioSpec {
            devices: 3,
            trace_users: 1,
            ..ScenarioSpec::default()
        };
        let fleet = SoaFleet::new(spec.build_fleet().unwrap(), 64);
        assert_eq!(fleet.n_shards(), 3);
    }
}
