//! §4.2 exploration amortized at fleet scale.
//!
//! On a real deployment Swan does not benchmark the choice space on
//! every phone: the *first* device of each SoC model explores (paying
//! real time and battery for it) and uploads its `ChoiceProfile`s; the
//! coordinator distributes the pruned chain to every later device of the
//! same model, which adopts it for free. This module makes that
//! amortization explicit and measurable: the kernel bills the explorer
//! device the full exploration cost in its first round, and the outcome
//! reports how many devices adopted per exploration.

use crate::fl::FlArm;
use crate::soc::device::{device, Device, DeviceId};
use crate::soc::exec_model::{estimate, ExecutionContext};
use crate::swan::choice::enumerate_choices;
use crate::swan::profile::ChoiceProfile;
use crate::swan::prune::prune_dominated;
use crate::workload::Workload;

/// Benchmark steps per choice during exploration (§4.2 request minimum).
pub const EXPLORE_STEPS: usize = 5;

/// Benchmark the full §4.2 choice space of one device on one workload —
/// THE exploration pipeline (enumerate → estimate per choice), shared
/// by the fleet [`ProfileCoordinator`] and the serve profile cache
/// (`serve::cache::plan_cost`) so their chain economics can never
/// silently diverge. Profiles come back in enumeration order, unpruned.
pub fn explore_profiles(
    workload: &Workload,
    d: &Device,
) -> Vec<ChoiceProfile> {
    let ctx = ExecutionContext::exclusive(d.n_cores());
    enumerate_choices(d)
        .into_iter()
        .map(|ch| {
            let est = estimate(d, workload, &ch.cores, &ctx);
            ChoiceProfile {
                choice: ch,
                latency_s: est.latency_s,
                energy_j: est.energy_j,
                power_w: est.avg_power_w,
                steps_measured: EXPLORE_STEPS,
            }
        })
        .collect()
}

/// Per-step cost of one device model under one policy arm.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepCost {
    pub latency_s: f64,
    pub energy_j: f64,
}

/// What the kernel needs back from a policy for one picked device: the
/// steady-state per-step cost plus any one-time exploration charge
/// billed to this requester.
#[derive(Clone, Copy, Debug, Default)]
pub struct ResolvedCost {
    pub cost: StepCost,
    pub exploration_time_s: f64,
    pub exploration_energy_j: f64,
}

/// Maps a picked device to its per-step cost. Implemented by
/// [`ProfileCoordinator`] (via [`CoordinatorPolicy`]) for fleet runs and
/// by `fl::FlSim`'s policy table for the FL harness — both feed the same
/// [`ShardedEventLoop`](super::engine::ShardedEventLoop).
pub trait FleetPolicy {
    fn step_cost(&mut self, model: DeviceId, requester: usize) -> ResolvedCost;
}

/// One SoC model's distributed profile state.
pub struct ModelProfile {
    /// Pruned preference chain (index 0 = fastest choice).
    pub chain: Vec<ChoiceProfile>,
    /// The PyTorch-greedy baseline cost, benchmarked identically.
    pub greedy: StepCost,
    /// Global id of the device that paid for exploration.
    pub explorer_device: usize,
    pub exploration_time_s: f64,
    pub exploration_energy_j: f64,
    /// Devices that adopted the chain without exploring.
    pub adoptions: usize,
}

/// Aggregate §4.2 accounting for one run.
#[derive(Clone, Copy, Debug, Default)]
pub struct CoordinatorStats {
    pub models_explored: usize,
    pub adoptions: usize,
    pub exploration_time_s: f64,
    pub exploration_energy_j: f64,
}

/// The fleet-scale §4.2 coordinator: lazily explores each SoC model the
/// first time one of its devices is picked, then serves the chain.
pub struct ProfileCoordinator {
    workload: Workload,
    entries: Vec<(DeviceId, ModelProfile)>,
    obs: crate::obs::Obs,
}

impl ProfileCoordinator {
    pub fn new(workload: Workload) -> ProfileCoordinator {
        ProfileCoordinator {
            workload,
            entries: Vec::new(),
            obs: crate::obs::Obs::off(),
        }
    }

    pub fn workload(&self) -> &Workload {
        &self.workload
    }

    /// Attach a telemetry sink: each first-time exploration emits a
    /// `profile-explored` event. Adoptions are *not* emitted here —
    /// they happen inside the per-device policy resolution hot loop;
    /// the drive emits aggregated `profile-adopted` records at the end
    /// (see [`adoption_counts`](ProfileCoordinator::adoption_counts)).
    pub fn set_obs(&mut self, obs: crate::obs::Obs) {
        self.obs = obs;
    }

    /// (model, adoptions) in exploration order — the aggregate feed for
    /// end-of-run `profile-adopted` events.
    pub fn adoption_counts(&self) -> Vec<(DeviceId, usize)> {
        self.entries
            .iter()
            .map(|(m, e)| (*m, e.adoptions))
            .collect()
    }

    fn explore(workload: &Workload, model: DeviceId, requester: usize) -> ModelProfile {
        let d = device(model);
        let profiles = explore_profiles(workload, &d);
        // the explorer device pays for every benchmarked choice, in
        // enumeration order (the same accumulation order as before the
        // shared-pipeline extraction, so billing stays bit-identical)
        let mut exploration_time_s = 0.0;
        let mut exploration_energy_j = 0.0;
        for p in &profiles {
            exploration_time_s += p.latency_s * EXPLORE_STEPS as f64;
            exploration_energy_j += p.energy_j * EXPLORE_STEPS as f64;
        }
        let ctx = ExecutionContext::exclusive(d.n_cores());
        let greedy_est =
            estimate(&d, workload, &d.low_latency_cores(), &ctx);
        ModelProfile {
            chain: prune_dominated(profiles),
            greedy: StepCost {
                latency_s: greedy_est.latency_s,
                energy_j: greedy_est.energy_j,
            },
            explorer_device: requester,
            exploration_time_s,
            exploration_energy_j,
            adoptions: 0,
        }
    }

    /// Resolve the per-step cost for a device of `model` under `arm`.
    ///
    /// The first resolution of a model runs the full §4.2 exploration
    /// and bills it to `requester` (Swan arm only — the greedy baseline
    /// never explores); every later resolution adopts for free.
    pub fn resolve(
        &mut self,
        model: DeviceId,
        requester: usize,
        arm: FlArm,
    ) -> ResolvedCost {
        let found = self.entries.iter().position(|(m, _)| *m == model);
        let fresh = found.is_none();
        let idx = match found {
            Some(i) => i,
            None => {
                let entry =
                    Self::explore(&self.workload, model, requester);
                if self.obs.enabled() {
                    self.obs.emit(&crate::obs::ProfileExplored {
                        model: model.key(),
                        requester,
                        chain_len: entry.chain.len(),
                        exploration_time_s: entry.exploration_time_s,
                        exploration_energy_j: entry.exploration_energy_j,
                    });
                }
                self.entries.push((model, entry));
                self.entries.len() - 1
            }
        };
        let entry = &mut self.entries[idx].1;
        let cost = match arm {
            FlArm::Swan => {
                let best = &entry.chain[0];
                StepCost {
                    latency_s: best.latency_s,
                    energy_j: best.energy_j,
                }
            }
            FlArm::Baseline => entry.greedy,
        };
        if fresh && arm == FlArm::Swan {
            ResolvedCost {
                cost,
                exploration_time_s: entry.exploration_time_s,
                exploration_energy_j: entry.exploration_energy_j,
            }
        } else {
            // Adoption is a Swan concept: the baseline neither explores
            // nor adopts a chain, it just runs greedy.
            if !fresh && arm == FlArm::Swan {
                entry.adoptions += 1;
            }
            ResolvedCost {
                cost,
                ..Default::default()
            }
        }
    }

    /// The distributed chain for `model`, if explored.
    pub fn chain(&self, model: DeviceId) -> Option<&[ChoiceProfile]> {
        self.entries
            .iter()
            .find(|(m, _)| *m == model)
            .map(|(_, e)| e.chain.as_slice())
    }

    pub fn stats(&self) -> CoordinatorStats {
        let mut s = CoordinatorStats {
            models_explored: self.entries.len(),
            ..Default::default()
        };
        for (_, e) in &self.entries {
            s.adoptions += e.adoptions;
            s.exploration_time_s += e.exploration_time_s;
            s.exploration_energy_j += e.exploration_energy_j;
        }
        s
    }
}

/// Adapter binding a coordinator to one policy arm for a kernel run.
pub struct CoordinatorPolicy<'a> {
    pub coord: &'a mut ProfileCoordinator,
    pub arm: FlArm,
}

impl FleetPolicy for CoordinatorPolicy<'_> {
    fn step_cost(&mut self, model: DeviceId, requester: usize) -> ResolvedCost {
        self.coord.resolve(model, requester, self.arm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{builtin, WorkloadName};

    fn coord() -> ProfileCoordinator {
        ProfileCoordinator::new(builtin(WorkloadName::ShufflenetV2))
    }

    #[test]
    fn first_device_pays_exploration_rest_adopt() {
        let mut c = coord();
        let first = c.resolve(DeviceId::S10e, 42, FlArm::Swan);
        assert!(
            first.exploration_time_s > 0.0,
            "first device must be billed exploration"
        );
        assert!(first.exploration_energy_j > 0.0);
        let second = c.resolve(DeviceId::S10e, 43, FlArm::Swan);
        assert_eq!(second.exploration_time_s, 0.0, "adopters pay nothing");
        assert_eq!(second.cost.latency_s, first.cost.latency_s);
        let stats = c.stats();
        assert_eq!(stats.models_explored, 1);
        assert_eq!(stats.adoptions, 1);
    }

    #[test]
    fn swan_never_slower_than_greedy() {
        for wl in [
            WorkloadName::Resnet34,
            WorkloadName::MobilenetV2,
            WorkloadName::ShufflenetV2,
        ] {
            let mut c = ProfileCoordinator::new(builtin(wl));
            for d in crate::soc::device::all_devices() {
                let s = c.resolve(d.id, 0, FlArm::Swan);
                let b = c.resolve(d.id, 0, FlArm::Baseline);
                assert!(
                    s.cost.latency_s <= b.cost.latency_s + 1e-12,
                    "{:?}/{wl:?}: swan {} > greedy {}",
                    d.id,
                    s.cost.latency_s,
                    b.cost.latency_s
                );
            }
        }
    }

    #[test]
    fn baseline_never_billed_exploration() {
        let mut c = coord();
        let b = c.resolve(DeviceId::Pixel3, 7, FlArm::Baseline);
        assert_eq!(b.exploration_time_s, 0.0);
        assert_eq!(b.exploration_energy_j, 0.0);
    }

    #[test]
    fn chain_head_is_fastest() {
        let mut c = coord();
        c.resolve(DeviceId::OnePlus8, 0, FlArm::Swan);
        let chain = c.chain(DeviceId::OnePlus8).unwrap();
        assert!(!chain.is_empty());
        for p in chain {
            assert!(chain[0].latency_s <= p.latency_s + 1e-15);
        }
        assert!(c.chain(DeviceId::TabS6).is_none());
    }

    #[test]
    fn exploration_cost_covers_the_whole_choice_space() {
        let mut c = coord();
        let rc = c.resolve(DeviceId::Pixel3, 0, FlArm::Swan);
        // pixel3 has 8 choices × 5 steps; each step ≥ the fastest step
        let per_step = rc.cost.latency_s;
        assert!(
            rc.exploration_time_s >= 8.0 * EXPLORE_STEPS as f64 * per_step,
            "exploration {} vs floor {}",
            rc.exploration_time_s,
            8.0 * EXPLORE_STEPS as f64 * per_step
        );
    }
}
