//! The fleet bench harnesses behind `swan bench fleet`, `swan bench
//! serve` and `benches/fleet_throughput.rs`.
//!
//! [`run_fleet_bench`] runs a scenario through both kernels — the PR 1
//! reference [`ShardedEventLoop`](super::engine::ShardedEventLoop) and
//! the SoA kernel ([`SoaFleet`](super::soa::SoaFleet)) — across a list
//! of shard counts, *errors* unless every run produced the same
//! aggregate digest (the cross-kernel determinism contract), and
//! renders the result as the `BENCH_fleet.json` record that tracks the
//! perf trajectory from PR 2 onward.
//!
//! [`run_serve_bench`] is the `serve` load-generator mode: the same
//! scenario fleet pointed at the coordinator control plane, first
//! in-process and then (optionally) over loopback TCP, with a
//! machinery-free oracle replay as the parity reference. Any digest
//! divergence between oracle, in-process and TCP runs is an *error*,
//! and the result lands in `BENCH_serve.json` — check-ins/sec, p90
//! check-in latency and the deferral rate, the first bench in the repo
//! denominated in requests served rather than devices stepped.
//!
//! [`run_fl_bench`] is the numerics-loop harness behind `swan bench
//! fl`: one FL config driven through `fl::engine::run_direct` (the
//! oracle), the in-process serve path and (optionally) loopback TCP —
//! real SGD through the coordinator on every path. Bit-identical
//! digests AND final weights are *asserted*, then the run lands in
//! `BENCH_fl.json` denominated in training rounds/sec plus
//! time-to-accuracy on the virtual clock.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::fl::{
    run_direct, run_serve, serve_config, ClientLanes, FlArm, FlConfig,
    FlOutcome, FlSim,
};
use crate::obs::{BenchResult, Obs};
use crate::serve::{
    run_inproc_with, run_oracle, run_tcp, serve_tcp, Coordinator,
    InProcClient, ServeClient, ServeConfig, ServeRunOutcome, ServeStats,
    TcpClient,
};
use crate::train::{SoftmaxProbe, SyntheticDataset};
use crate::util::json::Value;
use crate::workload::{load_or_builtin, WorkloadName};

use super::engine::{run_scenario_obs, run_scenario_reference_obs};
use super::metrics::FleetOutcome;
use super::scenario::ScenarioSpec;

/// Everything one harness invocation produced.
#[derive(Clone, Debug)]
pub struct FleetBenchReport {
    pub spec: ScenarioSpec,
    pub arm: FlArm,
    /// The shared aggregate digest every run must reproduce.
    pub digest: String,
    /// SoA-kernel outcomes, one per requested shard count.
    pub soa: Vec<FleetOutcome>,
    /// Reference-kernel outcomes (empty when the caller skipped them).
    pub reference: Vec<FleetOutcome>,
}

/// Run `spec` on both kernels across `shard_counts` (reference runs are
/// skipped when `with_reference` is false — e.g. metro/million scale,
/// where the PR 1 kernel is the bottleneck being measured around).
///
/// Fails if any run's digest diverges: a determinism violation is a
/// result bug, not a performance data point.
pub fn run_fleet_bench(
    spec: &ScenarioSpec,
    shard_counts: &[usize],
    arm: FlArm,
    with_reference: bool,
    obs: &Obs,
) -> crate::Result<FleetBenchReport> {
    crate::ensure!(
        !shard_counts.is_empty(),
        "fleet bench needs at least one shard count"
    );
    let mut soa = Vec::new();
    let mut reference = Vec::new();
    for &shards in shard_counts {
        soa.push(run_scenario_obs(spec, shards, arm, obs)?);
        if with_reference {
            reference
                .push(run_scenario_reference_obs(spec, shards, arm, obs)?);
        }
    }
    let digest = soa[0].digest();
    for o in soa.iter().chain(reference.iter()) {
        crate::ensure!(
            o.digest() == digest,
            "fleet determinism violated: {} kernel at {} shards \
             produced {} instead of {}",
            o.kernel,
            o.shards,
            o.digest(),
            digest
        );
    }
    let report = FleetBenchReport {
        spec: spec.clone(),
        arm,
        digest,
        soa,
        reference,
    };
    if obs.enabled() {
        obs.emit(&BenchResult {
            bench: "fleet",
            record: report.to_json(),
        });
    }
    Ok(report)
}

fn best_of(outs: &[FleetOutcome]) -> Option<&FleetOutcome> {
    outs.iter().max_by(|a, b| {
        a.devices_stepped_per_sec()
            .total_cmp(&b.devices_stepped_per_sec())
    })
}

impl FleetBenchReport {
    /// The fastest SoA run.
    pub fn best_soa(&self) -> &FleetOutcome {
        best_of(&self.soa).expect("harness guarantees at least one run")
    }

    pub fn best_reference(&self) -> Option<&FleetOutcome> {
        best_of(&self.reference)
    }

    /// Gate this run's determinism digest against a golden value (the
    /// CLI's `--expect-digest`, wired into CI's bench-smoke). A kernel
    /// bug that perturbs simulation arithmetic then fails loudly as a
    /// parity error instead of surfacing as an unexplained perf dip.
    pub fn assert_digest(&self, want: &str) -> crate::Result<()> {
        crate::ensure!(
            self.digest == want,
            "fleet bench digest mismatch: got {} want {want} \
             (scenario {}, arm {})",
            self.digest,
            self.spec.name,
            self.arm.name()
        );
        Ok(())
    }

    /// Best-vs-best devices-stepped/sec ratio (None without reference
    /// runs, or when the reference produced no throughput).
    pub fn speedup_best(&self) -> Option<f64> {
        let r = self.best_reference()?.devices_stepped_per_sec();
        if r > 0.0 {
            Some(self.best_soa().devices_stepped_per_sec() / r)
        } else {
            None
        }
    }

    /// Per-shard-count SoA/reference throughput ratios.
    pub fn speedup_same_shards(&self) -> Vec<(usize, f64)> {
        let mut out = Vec::new();
        for s in &self.soa {
            if let Some(r) =
                self.reference.iter().find(|r| r.shards == s.shards)
            {
                let rr = r.devices_stepped_per_sec();
                if rr > 0.0 {
                    out.push((s.shards, s.devices_stepped_per_sec() / rr));
                }
            }
        }
        out
    }

    /// The `BENCH_fleet.json` record (schema documented in the README's
    /// Performance section).
    pub fn to_json(&self) -> Value {
        let runs: Vec<Value> = self
            .soa
            .iter()
            .chain(self.reference.iter())
            .map(|o| o.to_json())
            .collect();
        let mut same = Value::obj();
        for (shards, ratio) in self.speedup_same_shards() {
            same = same.set(&shards.to_string(), ratio);
        }
        let best = self.best_soa();
        Value::obj()
            .set("bench", "fleet")
            .set("schema_version", 1usize)
            .set("scenario", self.spec.to_json())
            .set("arm", self.arm.name())
            .set("digest", self.digest.clone())
            .set("best_kernel", best.kernel)
            .set("best_shards", best.shards)
            .set(
                "best_devices_stepped_per_sec",
                best.devices_stepped_per_sec(),
            )
            .set(
                "speedup_vs_reference",
                match self.speedup_best() {
                    Some(r) => Value::Num(r),
                    None => Value::Null,
                },
            )
            .set("speedup_same_shards", same)
            .set("runs", Value::Arr(runs))
    }

    /// Machine-parseable single line (`BENCH_fleet {…}`) for log
    /// scrapers; the bench binary and `swan bench fleet` both print it.
    pub fn one_line(&self) -> String {
        format!("BENCH_fleet {}", self.to_json())
    }

    /// Write the pretty record to `path` (conventionally
    /// `BENCH_fleet.json` at the repo root).
    pub fn write_json(&self, path: impl AsRef<Path>) -> crate::Result<PathBuf> {
        let path = path.as_ref().to_path_buf();
        std::fs::write(&path, format!("{:#}\n", self.to_json()))?;
        Ok(path)
    }
}

/// Everything one serve-bench invocation produced.
#[derive(Clone, Debug)]
pub struct ServeBenchReport {
    pub spec: ScenarioSpec,
    pub lanes: usize,
    /// The oracle replay's digest (None when bounded admission makes
    /// the oracle inapplicable — deferral order is transport-defined).
    pub oracle_digest: Option<String>,
    pub inproc: ServeRunOutcome,
    pub tcp: Option<ServeRunOutcome>,
    /// Coordinator-side cache/admission counters from the in-process
    /// run.
    pub stats: ServeStats,
}

/// Drive `spec`'s fleet through the serve control plane with `lanes`
/// load-generator threads (and connections, on the TCP path).
///
/// With unbounded admission (`admit_capacity == 0`) every path must
/// reproduce the oracle digest — "bit-identical round aggregates vs
/// `fl::server`" is asserted here, not sampled. A nonzero
/// `admit_capacity` instead measures overload behaviour (deferral
/// rate); the oracle check is skipped because which check-ins overflow
/// a bounded queue is arrival-order-defined, but the TCP-vs-in-process
/// comparison of *counts* still runs.
pub fn run_serve_bench(
    spec: &ScenarioSpec,
    lanes: usize,
    with_tcp: bool,
    admit_capacity: usize,
    obs: &Obs,
) -> crate::Result<ServeBenchReport> {
    let lanes = lanes.max(1);
    let mut cfg = ServeConfig::for_scenario(spec);
    cfg.admit_capacity = admit_capacity;

    let oracle = if admit_capacity == 0 {
        Some(run_oracle(spec, &cfg)?)
    } else {
        None
    };

    let (inproc, coord) = run_inproc_with(spec, lanes, &cfg, obs)?;
    if let Some(o) = &oracle {
        crate::ensure!(
            inproc.digest == o.digest,
            "serve parity violated: in-process path produced {} but the \
             fl::server oracle produced {}",
            inproc.digest,
            o.digest
        );
        crate::ensure!(
            inproc.participations == o.participations,
            "serve parity violated: {} participations vs oracle {}",
            inproc.participations,
            o.participations
        );
    }
    let stats = coord.stats();

    let tcp = if with_tcp {
        let tcp_coord =
            Arc::new(Coordinator::with_obs(cfg.clone(), obs.clone())?);
        let handle = serve_tcp(tcp_coord, "127.0.0.1:0", lanes)?;
        let addr = handle.addr;
        let out = run_tcp(spec, lanes, addr, cfg.update_dim, obs);
        // clients are dropped by now (run_tcp owns them), so the pool
        // drains and the join below cannot hang — even on error
        handle.shutdown();
        let out = out?;
        if admit_capacity == 0 {
            crate::ensure!(
                out.digest == inproc.digest,
                "serve parity violated: loopback-TCP digest {} vs \
                 in-process {}",
                out.digest,
                inproc.digest
            );
        } else {
            // bounded admission: WHICH check-ins overflow the queue is
            // arrival-order-defined, so transports legitimately diverge
            // — only the round structure is comparable
            crate::ensure!(
                out.rounds_run == inproc.rounds_run,
                "serve bench: TCP ran {} rounds vs in-process {}",
                out.rounds_run,
                inproc.rounds_run
            );
        }
        Some(out)
    } else {
        None
    };

    let report = ServeBenchReport {
        spec: spec.clone(),
        lanes,
        oracle_digest: oracle.map(|o| o.digest),
        inproc,
        tcp,
        stats,
    };
    if obs.enabled() {
        obs.emit(&BenchResult {
            bench: "serve",
            record: report.to_json(),
        });
    }
    Ok(report)
}

impl ServeBenchReport {
    /// Every load-generator run this bench performed (in-process
    /// first, then loopback TCP when it ran).
    pub fn runs(&self) -> Vec<&ServeRunOutcome> {
        let mut v = vec![&self.inproc];
        if let Some(t) = &self.tcp {
            v.push(t);
        }
        v
    }

    /// Profile-cache hit rate across the in-process run.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.stats.cache_hits + self.stats.cache_misses;
        if total > 0 {
            self.stats.cache_hits as f64 / total as f64
        } else {
            0.0
        }
    }

    /// The `BENCH_serve.json` record (schema documented in the
    /// README's serve section).
    pub fn to_json(&self) -> Value {
        let runs: Vec<Value> =
            self.runs().iter().map(|o| o.to_json()).collect();
        Value::obj()
            .set("bench", "serve")
            .set("schema_version", 1usize)
            .set("scenario", self.spec.to_json())
            .set("lanes", self.lanes)
            .set("digest", self.inproc.digest.clone())
            .set(
                "oracle_digest",
                match &self.oracle_digest {
                    Some(d) => Value::Str(d.clone()),
                    None => Value::Null,
                },
            )
            .set("checkins_per_sec", self.inproc.checkins_per_sec())
            .set(
                "tcp_checkins_per_sec",
                match &self.tcp {
                    Some(t) => Value::Num(t.checkins_per_sec()),
                    None => Value::Null,
                },
            )
            .set(
                "p90_checkin_latency_s",
                self.inproc.p90_checkin_latency_s(),
            )
            .set("deferral_rate", self.inproc.deferral_rate())
            .set("cache_hit_rate", self.cache_hit_rate())
            .set("cache_evictions", self.stats.cache_evictions as f64)
            .set("runs", Value::Arr(runs))
    }

    /// Machine-parseable single line (`BENCH_serve {…}`).
    pub fn one_line(&self) -> String {
        format!("BENCH_serve {}", self.to_json())
    }

    /// Write the pretty record to `path` (conventionally
    /// `BENCH_serve.json` at the repo root).
    pub fn write_json(&self, path: impl AsRef<Path>) -> crate::Result<PathBuf> {
        let path = path.as_ref().to_path_buf();
        std::fs::write(&path, format!("{:#}\n", self.to_json()))?;
        Ok(path)
    }
}

/// Accuracy target for the headline time-to-accuracy metric (the
/// softmax probe on 35-class synthetic speech starts near 1/35 chance;
/// reaching 20% demonstrates genuine learning through the wire).
pub const FL_TTA_TARGET: f64 = 0.20;

/// Everything one numerics-loop bench invocation produced.
#[derive(Clone, Debug)]
pub struct FlBenchReport {
    pub cfg: FlConfig,
    pub arm: FlArm,
    pub workload: WorkloadName,
    pub lanes: usize,
    /// Fleet size the config synthesized (quality traces × 24 shifts).
    pub n_clients: usize,
    /// The digest every path reproduced bit-for-bit.
    pub digest: String,
    pub direct: FlOutcome,
    pub inproc: FlOutcome,
    pub tcp: Option<FlOutcome>,
    pub direct_wall_s: f64,
    pub inproc_wall_s: f64,
    pub tcp_wall_s: Option<f64>,
}

/// Drive one FL config through all three wirings of the unified engine
/// — direct oracle, in-process serve, and (when `with_tcp`) loopback
/// TCP with `lanes` connections — and *assert* bit-identical digests
/// and final weights across them. Divergence is an error, not a data
/// point. The serve coordinators attach `obs`, so a telemetry-enabled
/// run emits the usual `ServeRoundEnd`/trace events for `swan obs`.
pub fn run_fl_bench(
    cfg: &FlConfig,
    arm: FlArm,
    workload: WorkloadName,
    lanes: usize,
    with_tcp: bool,
    obs: &Obs,
) -> crate::Result<FlBenchReport> {
    let lanes = lanes.max(1);
    let ds = SyntheticDataset::speech(cfg.seed);
    let w = load_or_builtin(workload, "artifacts");
    let probe = SoftmaxProbe::new(ds.clone());
    let sim = FlSim::new(cfg.clone(), arm, ds, &w)?;
    let clients = sim.clients;

    let t0 = crate::obs::wall_timer();
    let mut oracle_lanes = ClientLanes::new(&clients, cfg.seed);
    let direct = run_direct(cfg, arm, &mut oracle_lanes, &probe, &w)?;
    let direct_wall_s = t0.elapsed().as_secs_f64();

    let coord = Arc::new(Coordinator::with_obs(
        serve_config(cfg, arm, workload, probe.dim()),
        obs.clone(),
    )?);
    let lane_clients: Vec<Box<dyn ServeClient>> = (0..lanes)
        .map(|_| {
            Box::new(InProcClient::new(coord.clone()))
                as Box<dyn ServeClient>
        })
        .collect();
    let t1 = crate::obs::wall_timer();
    let mut inproc_lanes = ClientLanes::new(&clients, cfg.seed);
    let inproc = run_serve(cfg, arm, &mut inproc_lanes, &probe, lane_clients)?;
    let inproc_wall_s = t1.elapsed().as_secs_f64();
    assert_fl_parity("in-process", &direct, &inproc)?;

    let (tcp, tcp_wall_s) = if with_tcp {
        let tcp_coord = Arc::new(Coordinator::with_obs(
            serve_config(cfg, arm, workload, probe.dim()),
            obs.clone(),
        )?);
        let handle = serve_tcp(tcp_coord, "127.0.0.1:0", lanes)?;
        let addr = handle.addr;
        let t2 = crate::obs::wall_timer();
        let run = (|| -> crate::Result<FlOutcome> {
            let conns: Vec<Box<dyn ServeClient>> = (0..lanes)
                .map(|_| {
                    TcpClient::connect(addr)
                        .map(|c| Box::new(c) as Box<dyn ServeClient>)
                })
                .collect::<crate::Result<_>>()?;
            let mut tcp_lanes = ClientLanes::new(&clients, cfg.seed);
            run_serve(cfg, arm, &mut tcp_lanes, &probe, conns)
        })();
        // connections are dropped by now (run_serve owns them), so the
        // worker pool drains and the join cannot hang — even on error
        handle.shutdown();
        let wall = t2.elapsed().as_secs_f64();
        let out = run?;
        assert_fl_parity("loopback-TCP", &direct, &out)?;
        (Some(out), Some(wall))
    } else {
        (None, None)
    };

    let report = FlBenchReport {
        cfg: cfg.clone(),
        arm,
        workload,
        lanes,
        n_clients: clients.len(),
        digest: direct.digest.clone(),
        direct,
        inproc,
        tcp,
        direct_wall_s,
        inproc_wall_s,
        tcp_wall_s,
    };
    if obs.enabled() {
        obs.emit(&BenchResult {
            bench: "fl",
            record: report.to_json(),
        });
    }
    Ok(report)
}

fn assert_fl_parity(
    path: &str,
    oracle: &FlOutcome,
    served: &FlOutcome,
) -> crate::Result<()> {
    crate::ensure!(
        served.digest == oracle.digest,
        "fl numerics parity violated: {path} path produced digest {} \
         but the direct oracle produced {}",
        served.digest,
        oracle.digest
    );
    crate::ensure!(
        served.final_model.len() == oracle.final_model.len()
            && served
                .final_model
                .iter()
                .zip(&oracle.final_model)
                .all(|(a, b)| a.to_bits() == b.to_bits()),
        "fl numerics parity violated: {path} final weights are not \
         bit-identical to the oracle (digest collided?)"
    );
    Ok(())
}

impl FlBenchReport {
    /// Serve-routed training throughput (the headline number): rounds
    /// of real federated SGD the coordinator closed per wall second.
    pub fn rounds_per_sec(&self) -> f64 {
        if self.inproc_wall_s > 0.0 {
            self.inproc.rounds_run as f64 / self.inproc_wall_s
        } else {
            0.0
        }
    }

    /// Oracle-path throughput (no coordinator machinery).
    pub fn direct_rounds_per_sec(&self) -> f64 {
        if self.direct_wall_s > 0.0 {
            self.direct.rounds_run as f64 / self.direct_wall_s
        } else {
            0.0
        }
    }

    /// TCP-path throughput, when the TCP leg ran.
    pub fn tcp_rounds_per_sec(&self) -> Option<f64> {
        match (&self.tcp, self.tcp_wall_s) {
            (Some(t), Some(w)) if w > 0.0 => {
                Some(t.rounds_run as f64 / w)
            }
            _ => None,
        }
    }

    /// Gate the parity digest against a golden value (CLI
    /// `--expect-digest`, wired into CI's numerics-smoke).
    pub fn assert_digest(&self, want: &str) -> crate::Result<()> {
        crate::ensure!(
            self.digest == want,
            "fl bench digest mismatch: got {} want {want} (arm {}, \
             seed {})",
            self.digest,
            self.arm.name(),
            self.cfg.seed
        );
        Ok(())
    }

    /// The `BENCH_fl.json` record (schema documented in the README's
    /// "Training through the control plane" section).
    pub fn to_json(&self) -> Value {
        let (final_t_s, final_acc) = self
            .direct
            .accuracy_curve
            .last()
            .unwrap_or((0.0, 0.0));
        Value::obj()
            .set("bench", "fl")
            .set("schema_version", 1usize)
            .set("arm", self.arm.name())
            .set("workload", self.workload.key())
            .set("seed", self.cfg.seed as usize)
            .set("clients", self.n_clients)
            .set("clients_per_round", self.cfg.clients_per_round)
            .set("local_steps", self.cfg.local_steps)
            .set("rounds", self.cfg.rounds)
            .set("lanes", self.lanes)
            .set("model_dim", self.direct.final_model.len())
            .set("digest", self.digest.clone())
            .set("rounds_per_sec", self.rounds_per_sec())
            .set("direct_rounds_per_sec", self.direct_rounds_per_sec())
            .set(
                "tcp_rounds_per_sec",
                match self.tcp_rounds_per_sec() {
                    Some(r) => Value::Num(r),
                    None => Value::Null,
                },
            )
            .set("final_accuracy", final_acc)
            .set("final_eval_t_s", final_t_s)
            .set(
                "time_to_accuracy_s",
                match self.direct.time_to_accuracy(FL_TTA_TARGET) {
                    Some(t) => Value::Num(t),
                    None => Value::Null,
                },
            )
            .set("tta_target", FL_TTA_TARGET)
            .set("total_virtual_time_s", self.direct.total_time_s)
            .set("total_energy_j", self.direct.total_energy_j)
    }

    /// Machine-parseable single line (`BENCH_fl {…}`).
    pub fn one_line(&self) -> String {
        format!("BENCH_fl {}", self.to_json())
    }

    /// Write the pretty record to `path` (conventionally
    /// `BENCH_fl.json` at the repo root).
    pub fn write_json(&self, path: impl AsRef<Path>) -> crate::Result<PathBuf> {
        let path = path.as_ref().to_path_buf();
        std::fs::write(&path, format!("{:#}\n", self.to_json()))?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ScenarioSpec {
        ScenarioSpec {
            name: "bench-unit".to_string(),
            devices: 240,
            rounds: 6,
            clients_per_round: 10,
            trace_users: 2,
            ..ScenarioSpec::default()
        }
    }

    #[test]
    fn assert_digest_gates_on_the_golden_string() {
        let rep = run_fleet_bench(
            &spec(),
            &[1],
            FlArm::Swan,
            false,
            &Obs::off(),
        )
        .unwrap();
        rep.assert_digest(&rep.digest.clone()).unwrap();
        let err = rep.assert_digest("t00000000-bogus").unwrap_err();
        assert!(
            err.to_string().contains("digest mismatch"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn harness_runs_both_kernels_and_agrees() {
        let rep =
            run_fleet_bench(&spec(), &[1, 2], FlArm::Swan, true, &Obs::off())
                .unwrap();
        assert_eq!(rep.soa.len(), 2);
        assert_eq!(rep.reference.len(), 2);
        assert!(!rep.digest.is_empty());
        assert_eq!(rep.speedup_same_shards().len(), 2);
        assert!(rep.speedup_best().is_some());
        let v = rep.to_json();
        assert_eq!(v.req_str("bench").unwrap(), "fleet");
        assert_eq!(v.req_str("digest").unwrap(), rep.digest);
        assert_eq!(v.req_arr("runs").unwrap().len(), 4);
        assert!(v.req_f64("best_devices_stepped_per_sec").unwrap() >= 0.0);
        // the one-liner is a single line and parses back as JSON
        let line = rep.one_line();
        assert!(!line.trim().contains('\n'));
        let payload = line.strip_prefix("BENCH_fleet ").unwrap();
        assert!(crate::util::json::parse(payload).is_ok());
    }

    #[test]
    fn harness_can_skip_reference_runs() {
        let rep = run_fleet_bench(
            &spec(),
            &[2],
            FlArm::Baseline,
            false,
            &Obs::off(),
        )
        .unwrap();
        assert!(rep.reference.is_empty());
        assert!(rep.speedup_best().is_none());
        assert!(rep.speedup_same_shards().is_empty());
        assert!(matches!(
            rep.to_json().req("speedup_vs_reference").unwrap(),
            Value::Null
        ));
    }

    #[test]
    fn empty_shard_list_is_an_error() {
        assert!(run_fleet_bench(
            &spec(),
            &[],
            FlArm::Swan,
            true,
            &Obs::off()
        )
        .is_err());
    }

    #[test]
    fn serve_bench_asserts_parity_and_renders_json() {
        let rep =
            run_serve_bench(&spec(), 2, false, 0, &Obs::off()).unwrap();
        assert!(rep.oracle_digest.is_some());
        assert_eq!(
            rep.oracle_digest.as_deref(),
            Some(rep.inproc.digest.as_str())
        );
        assert!(rep.tcp.is_none());
        assert!(rep.inproc.participations > 0);
        assert!(rep.cache_hit_rate() > 0.5, "contexts repeat every round");
        let v = rep.to_json();
        assert_eq!(v.req_str("bench").unwrap(), "serve");
        assert_eq!(v.req_str("digest").unwrap(), rep.inproc.digest);
        assert_eq!(v.req_arr("runs").unwrap().len(), 1);
        assert!(v.req_f64("checkins_per_sec").unwrap() >= 0.0);
        assert_eq!(v.req_f64("deferral_rate").unwrap(), 0.0);
        let line = rep.one_line();
        assert!(!line.trim().contains('\n'));
        let payload = line.strip_prefix("BENCH_serve ").unwrap();
        assert!(crate::util::json::parse(payload).is_ok());
    }

    #[test]
    fn fl_bench_asserts_parity_and_renders_json() {
        let cfg = FlConfig {
            seed: 9,
            raw_traces: 6,
            quality_traces: 2,
            clients_per_round: 3,
            local_steps: 2,
            rounds: 3,
            eval_every: 2,
            eval_batches: 1,
            daily_credit_j: 3_000.0,
            server_overhead_s: 0.5,
        };
        let rep = run_fl_bench(
            &cfg,
            FlArm::Swan,
            WorkloadName::ShufflenetV2,
            2,
            false,
            &Obs::off(),
        )
        .unwrap();
        assert_eq!(rep.inproc.digest, rep.digest);
        assert!(rep.tcp.is_none());
        assert!(rep.digest.starts_with("serve-"));
        rep.assert_digest(&rep.digest.clone()).unwrap();
        assert!(rep.assert_digest("serve-bogus").is_err());
        assert!(rep.rounds_per_sec() > 0.0);
        let v = rep.to_json();
        assert_eq!(v.req_str("bench").unwrap(), "fl");
        assert_eq!(v.req_str("digest").unwrap(), rep.digest);
        assert!(v.req_f64("rounds_per_sec").unwrap() > 0.0);
        assert!(v.req_f64("model_dim").unwrap() > 0.0);
        let line = rep.one_line();
        assert!(!line.trim().contains('\n'));
        let payload = line.strip_prefix("BENCH_fl ").unwrap();
        assert!(crate::util::json::parse(payload).is_ok());
    }

    #[test]
    fn serve_bench_bounded_admission_reports_deferrals() {
        let rep =
            run_serve_bench(&spec(), 1, false, 4, &Obs::off()).unwrap();
        assert!(rep.oracle_digest.is_none(), "oracle skipped when bounded");
        assert!(rep.inproc.deferred > 0);
        assert!(rep.inproc.deferral_rate() > 0.0);
        assert!(matches!(
            rep.to_json().req("oracle_digest").unwrap(),
            Value::Null
        ));
    }
}
