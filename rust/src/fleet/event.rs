//! Deterministic per-shard event queue.
//!
//! Each shard of the [`ShardedEventLoop`](super::engine::ShardedEventLoop)
//! advances its devices by processing timestamped events between global
//! round barriers. Determinism never *depends* on pop order — devices are
//! independent between barriers and the control thread folds results in a
//! fixed order — but the queue still breaks timestamp ties FIFO so a
//! shard's local trace replays identically run to run.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// What can happen to a device inside a round.
///
/// Both kinds carry `job` — the dense index into the round's job slice
/// for this shard — so handlers resolve their `StepJob` with one
/// array load instead of the `HashMap<device, job>` routing the PR 1
/// kernel paid per event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EventKind {
    /// A picked device begins its local epoch.
    BeginEpoch { job: u32 },
    /// The epoch completes: charge the device, record the metrics.
    EpochDone {
        job: u32,
        time_s: f64,
        energy_j: f64,
        steps: u32,
    },
}

/// A timestamped occurrence on one device.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// Virtual time the event fires, seconds.
    pub at_s: f64,
    /// Global device id.
    pub device: u32,
    pub kind: EventKind,
}

struct Entry {
    event: Event,
    seq: u64,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // `BinaryHeap` pops the maximum; invert so the earliest event
        // (then the first-pushed on ties) is the maximum.
        other
            .event
            .at_s
            .total_cmp(&self.event.at_s)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Min-heap of [`Event`]s with FIFO tie-breaking.
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    pub fn push(&mut self, event: Event) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { event, seq });
    }

    /// Pop the earliest event (FIFO on equal timestamps).
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|e| e.event)
    }

    /// Timestamp of the next event, if any.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.event.at_s)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl Default for EventQueue {
    fn default() -> Self {
        EventQueue::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(at_s: f64, device: u32) -> Event {
        Event {
            at_s,
            device,
            kind: EventKind::BeginEpoch { job: device },
        }
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(ev(3.0, 0));
        q.push(ev(1.0, 1));
        q.push(ev(2.0, 2));
        let order: Vec<u32> = std::iter::from_fn(|| q.pop())
            .map(|e| e.device)
            .collect();
        assert_eq!(order, vec![1, 2, 0]);
        assert!(q.is_empty());
    }

    #[test]
    fn equal_times_pop_fifo() {
        let mut q = EventQueue::new();
        for d in 0..5u32 {
            q.push(ev(7.5, d));
        }
        let order: Vec<u32> = std::iter::from_fn(|| q.pop())
            .map(|e| e.device)
            .collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = EventQueue::new();
        q.push(ev(10.0, 0));
        q.push(ev(5.0, 1));
        assert_eq!(q.peek_time(), Some(5.0));
        assert_eq!(q.pop().unwrap().device, 1);
        q.push(ev(2.0, 2));
        assert_eq!(q.pop().unwrap().device, 2);
        assert_eq!(q.pop().unwrap().device, 0);
        assert_eq!(q.pop().map(|e| e.device), None);
    }

    #[test]
    fn len_tracks() {
        let mut q = EventQueue::new();
        assert_eq!(q.len(), 0);
        q.push(ev(1.0, 0));
        q.push(ev(2.0, 1));
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn epoch_done_payload_roundtrips() {
        let mut q = EventQueue::new();
        q.push(Event {
            at_s: 1.0,
            device: 9,
            kind: EventKind::EpochDone {
                job: 4,
                time_s: 2.5,
                energy_j: 7.0,
                steps: 12,
            },
        });
        match q.pop().unwrap().kind {
            EventKind::EpochDone {
                job,
                time_s,
                energy_j,
                steps,
            } => {
                assert_eq!(job, 4);
                assert_eq!(time_s, 2.5);
                assert_eq!(energy_j, 7.0);
                assert_eq!(steps, 12);
            }
            other => panic!("wrong kind: {other:?}"),
        }
    }
}
