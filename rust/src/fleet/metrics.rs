//! Fleet run outcomes and the throughput figures the bench reports.

use crate::util::json::Value;

/// Kernel tags recorded on outcomes (and in `BENCH_fleet.json`).
pub const KERNEL_EVENT_LOOP: &str = "event_loop";
pub const KERNEL_SOA: &str = "soa";

/// Everything a fleet run reports.
///
/// Aggregates (`total_*`, `online_per_round`, `participations`) are
/// bit-identical for any shard count — [`digest`](FleetOutcome::digest)
/// fingerprints exactly that invariant set. `wall_s` and the derived
/// throughput are the only shard-dependent numbers.
#[derive(Clone, Debug, Default)]
pub struct FleetOutcome {
    pub scenario: String,
    pub arm: &'static str,
    /// Which kernel produced this outcome ([`KERNEL_EVENT_LOOP`] or
    /// [`KERNEL_SOA`]). Informational only — excluded from
    /// [`digest`](FleetOutcome::digest), which fingerprints exactly the
    /// aggregates both kernels must agree on bit-for-bit.
    pub kernel: &'static str,
    pub devices: usize,
    pub shards: usize,
    pub rounds_run: usize,
    /// Device-epochs executed (one per picked device per round).
    pub participations: u64,
    /// Total local SGD steps paid across the fleet.
    pub total_steps: u64,
    /// Virtual seconds elapsed.
    pub total_time_s: f64,
    /// Fleet energy borrowed, joules.
    pub total_energy_j: f64,
    /// §4.2 accounting (from the `ProfileCoordinator`).
    pub models_explored: usize,
    pub adoptions: u64,
    pub exploration_time_s: f64,
    pub exploration_energy_j: f64,
    /// (round, #online) — the Figs 5b/6b/7b series at fleet scale.
    pub online_per_round: Vec<(usize, usize)>,
    /// Wall-clock seconds for the whole drive.
    pub wall_s: f64,
    /// Phase timers (availability / select / step / aggregate) from the
    /// control loop. Wall-clock-derived, so — like `wall_s` — excluded
    /// from [`digest`](FleetOutcome::digest).
    pub spans: crate::obs::Spans,
    /// Shard-local counters + histograms merged in shard order at the
    /// end of the drive. Excluded from the digest.
    pub metrics: crate::obs::MetricsRegistry,
}

impl FleetOutcome {
    /// Device-epochs stepped (the bench's headline unit).
    pub fn devices_stepped(&self) -> u64 {
        self.participations
    }

    /// Throughput: device-epochs per wall-clock second.
    pub fn devices_stepped_per_sec(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.participations as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Throughput in local SGD steps per wall-clock second.
    pub fn steps_per_sec(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.total_steps as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn online_first(&self) -> usize {
        self.online_per_round.first().map(|x| x.1).unwrap_or(0)
    }

    pub fn online_last(&self) -> usize {
        self.online_per_round.last().map(|x| x.1).unwrap_or(0)
    }

    /// Bit-exact fingerprint of the shard-invariant aggregates (virtual
    /// time + energy bits, step/participation counts,
    /// [`crate::util::fnv::Fnv1a`] over the online series). Two runs of
    /// the same scenario must produce equal digests regardless of shard
    /// count.
    pub fn digest(&self) -> String {
        let mut h = crate::util::fnv::Fnv1a::default();
        for (r, n) in &self.online_per_round {
            h.push(*r as u64);
            h.push(*n as u64);
        }
        format!(
            "t{:016x}-e{:016x}-s{}-p{}-o{:016x}",
            self.total_time_s.to_bits(),
            self.total_energy_j.to_bits(),
            self.total_steps,
            self.participations,
            h.h
        )
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .set("scenario", self.scenario.clone())
            .set("arm", self.arm)
            .set("kernel", self.kernel)
            .set("devices", self.devices)
            .set("shards", self.shards)
            .set("rounds_run", self.rounds_run)
            .set("participations", self.participations as f64)
            .set("total_steps", self.total_steps as f64)
            .set("total_time_s", self.total_time_s)
            .set("total_energy_j", self.total_energy_j)
            .set("models_explored", self.models_explored)
            .set("adoptions", self.adoptions as f64)
            .set("exploration_time_s", self.exploration_time_s)
            .set("exploration_energy_j", self.exploration_energy_j)
            .set("online_first", self.online_first())
            .set("online_last", self.online_last())
            .set("devices_stepped_per_sec", self.devices_stepped_per_sec())
            .set("wall_s", self.wall_s)
            .set("spans", self.spans.to_json())
            .set("metrics", self.metrics.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digest_sensitive_to_aggregates_only() {
        let mut a = FleetOutcome {
            total_time_s: 100.0,
            total_energy_j: 5.0,
            total_steps: 10,
            participations: 2,
            online_per_round: vec![(0, 5), (1, 4)],
            wall_s: 1.0,
            shards: 1,
            ..Default::default()
        };
        let mut b = a.clone();
        b.wall_s = 99.0; // shard-dependent fields must not matter
        b.shards = 8;
        b.kernel = KERNEL_SOA; // nor which kernel produced the run
        assert_eq!(a.digest(), b.digest());
        a.total_energy_j += 1e-12; // a single ulp-ish change must show
        assert_ne!(a.digest(), b.digest());
    }

    #[test]
    fn throughput_figures() {
        let o = FleetOutcome {
            participations: 500,
            total_steps: 2_500,
            wall_s: 2.0,
            ..Default::default()
        };
        assert_eq!(o.devices_stepped(), 500);
        assert_eq!(o.devices_stepped_per_sec(), 250.0);
        assert_eq!(o.steps_per_sec(), 1_250.0);
        let zero = FleetOutcome::default();
        assert_eq!(zero.devices_stepped_per_sec(), 0.0);
    }

    #[test]
    fn online_endpoints() {
        let o = FleetOutcome {
            online_per_round: vec![(0, 9), (1, 7), (2, 3)],
            ..Default::default()
        };
        assert_eq!(o.online_first(), 9);
        assert_eq!(o.online_last(), 3);
        assert_eq!(FleetOutcome::default().online_first(), 0);
    }

    #[test]
    fn json_has_throughput() {
        let o = FleetOutcome {
            scenario: "smoke".into(),
            arm: "swan",
            participations: 10,
            wall_s: 1.0,
            ..Default::default()
        };
        let v = o.to_json();
        assert_eq!(v.req_str("scenario").unwrap(), "smoke");
        assert!(v.req_f64("devices_stepped_per_sec").unwrap() > 0.0);
    }
}
