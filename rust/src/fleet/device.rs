//! What the fleet kernel schedules: the [`FleetNode`] trait and the
//! scenario-instantiated light device state.
//!
//! Two implementations exist. [`FleetDevice`] is the trace-driven,
//! data-free node a [`ScenarioSpec`](super::scenario::ScenarioSpec)
//! stamps out by the hundred thousand; `fl::FlClient` is the full FL
//! harness client (device + trace + dataset partition). Both run on the
//! same [`ShardedEventLoop`](super::engine::ShardedEventLoop), which is
//! how `fl::FlSim` and the fleet CLI share one scheduler.

use std::sync::Arc;

use crate::fl::energy_loan::EnergyLoan;
use crate::fl::FlClient;
use crate::soc::device::DeviceId;
use crate::trace::resample::ResampledTrace;
use crate::util::rng::Rng;

/// A device the [`ShardedEventLoop`](super::engine::ShardedEventLoop)
/// can schedule.
///
/// Implementations must be deterministic functions of their own state
/// and the arguments — never of scheduling order — so that resharding
/// cannot change results.
pub trait FleetNode: Send {
    /// The SoC model, for §4.2 profile lookup.
    fn model(&self) -> DeviceId;

    /// Availability at virtual time `now_s`. May advance device-local
    /// bookkeeping (e.g. energy-loan repayment); called exactly once per
    /// round per device, in device order within each shard.
    fn poll_online(&mut self, now_s: f64) -> bool;

    /// Steps in one local epoch when this device is picked.
    fn epoch_steps(&self) -> usize;

    /// Per-step cost multiplier at `(now_s, round)` — the interference /
    /// thermal envelope. Must be a pure function of device state and the
    /// arguments.
    fn cost_multiplier(&self, now_s: f64, round: usize) -> f64 {
        let _ = (now_s, round);
        1.0
    }

    /// Record one participation's systems cost.
    fn charge(&mut self, time_s: f64, energy_j: f64);
}

impl FleetNode for FlClient {
    fn model(&self) -> DeviceId {
        self.device.id
    }

    fn poll_online(&mut self, now_s: f64) -> bool {
        self.online(now_s)
    }

    fn epoch_steps(&self) -> usize {
        FlClient::epoch_steps(self)
    }

    fn charge(&mut self, time_s: f64, energy_j: f64) {
        self.charge_participation(time_s, energy_j);
    }
}

/// The deterministic interference/thermal envelope multiplier for one
/// (device, round): keyed on the device's stream seed and the round
/// only — identical under any sharding and any scheduling order. This
/// is THE definition for both kernels: [`FleetDevice::cost_multiplier`]
/// and the SoA kernel's step sweep call it, so cross-kernel bit-parity
/// holds by construction.
///
/// The round-mixing constant must differ from the id-mixing constant in
/// `ScenarioSpec::build_fleet`, or the XOR cancels on the id == round
/// diagonal and those devices' schedules become perfectly correlated.
pub(crate) fn envelope_multiplier(
    seed: u64,
    round: usize,
    interference_p: f64,
    interference_slowdown: f64,
    thermal_throttle_p: f64,
    thermal_derate: f64,
) -> f64 {
    let (d0, d1) = envelope_draws(seed, round);
    envelope_apply(
        d0,
        d1,
        interference_p,
        interference_slowdown,
        thermal_throttle_p,
        thermal_derate,
    )
}

/// The RNG half of [`envelope_multiplier`]: the two uniform draws for
/// one `(device seed, round)` cell, in draw order. Split out so the SoA
/// kernel's batched RNG stage can pre-draw a whole shard into dense
/// arrays; each cell gets a fresh generator keyed only on `(seed,
/// round)`, so drawing in any batch order reproduces the scalar
/// sequence exactly.
#[inline]
pub(crate) fn envelope_draws(seed: u64, round: usize) -> (f64, f64) {
    let mut rng = Rng::new(
        seed ^ (round as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93),
    );
    (rng.f64(), rng.f64())
}

/// The arithmetic half of [`envelope_multiplier`]: fold two pre-drawn
/// uniforms into the cost multiplier. Written as selects (`×1.0` on the
/// miss lane) rather than branches so the batched step sweep stays
/// lane-parallel — bit-identical to the branching form because
/// multiplying by exactly `1.0` is an IEEE identity for these finite
/// positive factors.
#[inline]
pub(crate) fn envelope_apply(
    d0: f64,
    d1: f64,
    interference_p: f64,
    interference_slowdown: f64,
    thermal_throttle_p: f64,
    thermal_derate: f64,
) -> f64 {
    let mut m = 1.0;
    m *= if d0 < interference_p {
        interference_slowdown
    } else {
        1.0
    };
    m *= if d1 < thermal_throttle_p {
        thermal_derate
    } else {
        1.0
    };
    m
}

/// A scenario-instantiated device: GreenHub trace (shared, time-shifted
/// per Appendix A.2), energy loan against its charger envelope, and
/// deterministic interference/thermal schedules. Light enough to stamp
/// out a million of.
pub struct FleetDevice {
    pub id: usize,
    pub model: DeviceId,
    /// Shared trace from the scenario pool.
    pub trace: Arc<ResampledTrace>,
    /// Hourly-shift augmentation offset, seconds.
    pub shift_s: f64,
    pub loan: EnergyLoan,
    pub epoch_steps: usize,
    /// Minimum traced battery level (%) when not charging.
    pub min_level_pct: f64,
    /// Probability a foreground session overlaps a given round's epoch.
    pub interference_p: f64,
    /// Latency/energy multiplier while interfered.
    pub interference_slowdown: f64,
    /// Probability a round's epoch runs DVFS-throttled.
    pub thermal_throttle_p: f64,
    /// Multiplier while throttled.
    pub thermal_derate: f64,
    /// Per-device stream seed (derived from scenario seed + id only).
    pub seed: u64,
    pub participations: usize,
    pub train_time_s: f64,
}

impl FleetNode for FleetDevice {
    fn model(&self) -> DeviceId {
        self.model
    }

    fn poll_online(&mut self, now_s: f64) -> bool {
        crate::fl::availability::availability_gate(
            &self.trace,
            &mut self.loan,
            now_s,
            self.shift_s,
            self.min_level_pct,
        )
    }

    fn epoch_steps(&self) -> usize {
        self.epoch_steps
    }

    fn cost_multiplier(&self, _now_s: f64, round: usize) -> f64 {
        envelope_multiplier(
            self.seed,
            round,
            self.interference_p,
            self.interference_slowdown,
            self.thermal_throttle_p,
            self.thermal_derate,
        )
    }

    fn charge(&mut self, time_s: f64, energy_j: f64) {
        self.train_time_s += time_s;
        self.loan.borrow(energy_j);
        self.participations += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::greenhub::TraceGenerator;
    use crate::trace::resample::resample_trace;

    fn test_device(credit_j: f64) -> FleetDevice {
        let tr = Arc::new(
            resample_trace(&TraceGenerator::default().generate(1, 0)).unwrap(),
        );
        FleetDevice {
            id: 0,
            model: DeviceId::Pixel3,
            trace: tr,
            shift_s: 0.0,
            loan: EnergyLoan::new(2915.0, credit_j),
            epoch_steps: 5,
            min_level_pct: 20.0,
            interference_p: 0.25,
            interference_slowdown: 2.5,
            thermal_throttle_p: 0.1,
            thermal_derate: 1.5,
            seed: 7,
            participations: 0,
            train_time_s: 0.0,
        }
    }

    #[test]
    fn availability_varies_over_a_day() {
        let mut d = test_device(50_000.0);
        let states: Vec<bool> =
            (0..144).map(|i| d.poll_online(i as f64 * 600.0)).collect();
        assert!(states.iter().any(|&s| s), "never online in a day");
    }

    #[test]
    fn heavy_borrowing_takes_device_offline() {
        let mut d = test_device(1_000.0);
        let mut t = 0.0;
        while !d.poll_online(t) {
            t += 600.0;
        }
        let full_pack = d.loan.capacity_j;
        d.charge(100.0, full_pack);
        assert!(!d.poll_online(t), "full-pack loan must kill availability");
        assert_eq!(d.participations, 1);
        assert_eq!(d.train_time_s, 100.0);
    }

    #[test]
    fn shift_changes_the_timeline_not_the_trace() {
        // a high level gate makes availability track the diurnal level
        // curve, so a 6h shift must visibly move the online window
        let mut a = test_device(50_000.0);
        let mut b = test_device(50_000.0);
        a.min_level_pct = 95.0;
        b.min_level_pct = 95.0;
        b.shift_s = 6.0 * 3600.0;
        let sa: Vec<bool> =
            (0..144).map(|i| a.poll_online(i as f64 * 600.0)).collect();
        let sb: Vec<bool> =
            (0..144).map(|i| b.poll_online(i as f64 * 600.0)).collect();
        assert!(sa.iter().any(|&s| s) || sb.iter().any(|&s| s));
        assert_ne!(sa, sb, "6h shift must move the availability window");
    }

    #[test]
    fn cost_multiplier_deterministic_and_bounded() {
        let d = test_device(50_000.0);
        let mut hit = 0;
        for round in 0..200 {
            let m1 = d.cost_multiplier(0.0, round);
            let m2 = d.cost_multiplier(1e9, round); // time-independent
            assert_eq!(m1, m2);
            assert!(m1 >= 1.0 && m1 <= 2.5 * 1.5 + 1e-9, "m={m1}");
            if m1 > 1.0 {
                hit += 1;
            }
        }
        assert!(hit > 10 && hit < 150, "schedule implausible: {hit}/200");
    }

    #[test]
    fn envelope_split_recomposes_bit_identically() {
        // the batched draw/apply split must reproduce the fused scalar
        // multiplier for every (seed, round, params) cell
        let mut rng = Rng::new(0xE57);
        for _ in 0..500 {
            let seed = rng.next_u64();
            let round = rng.index(10_000);
            let ip = rng.f64() * 0.6;
            let is = 1.0 + rng.f64() * 2.0;
            let tp = rng.f64() * 0.4;
            let td = 1.0 + rng.f64();
            let fused = envelope_multiplier(seed, round, ip, is, tp, td);
            let (d0, d1) = envelope_draws(seed, round);
            let split = envelope_apply(d0, d1, ip, is, tp, td);
            assert_eq!(split.to_bits(), fused.to_bits());
        }
    }

    #[test]
    fn fl_client_is_a_fleet_node() {
        use crate::soc::device::device;
        use crate::train::data::SyntheticDataset;
        let tr =
            resample_trace(&TraceGenerator::default().generate(1, 0)).unwrap();
        let ds = SyntheticDataset::vision(0);
        let mut c = FlClient::new(
            0,
            device(DeviceId::S10e),
            tr,
            ds.partition(0),
            50_000.0,
        );
        assert_eq!(FleetNode::model(&c), DeviceId::S10e);
        assert!(FleetNode::epoch_steps(&c) >= 1);
        assert_eq!(c.cost_multiplier(0.0, 0), 1.0);
        let before = c.participations;
        FleetNode::charge(&mut c, 10.0, 100.0);
        assert_eq!(c.participations, before + 1);
    }
}
