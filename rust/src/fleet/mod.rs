//! The fleet simulation kernel: sharded, event-driven evaluation of
//! Swan at population scale (100k–1M simulated devices).
//!
//! The paper's headline claims rest on *large-scale* FL evaluations
//! across heterogeneous smartphone SoCs; the seed reproduced them with a
//! serial per-round loop that cannot reach that scale. This subsystem
//! supplies the missing machinery:
//!
//! - [`scenario`] — [`ScenarioSpec`]: experiment setups as *data*
//!   (device-model mixes, GreenHub trace assignment, charger/thermal
//!   envelopes, interference schedules), loadable via `util::json`.
//! - [`device`] — the [`FleetNode`] abstraction the kernel schedules;
//!   implemented by both the scenario-instantiated [`FleetDevice`] and
//!   the FL harness's `fl::FlClient`, so both paths share one scheduler.
//! - [`event`] — the deterministic per-shard event queue; events carry
//!   dense job indices so routing is an array load, not a hash lookup.
//! - [`coordinator`] — [`ProfileCoordinator`]: §4.2 exploration
//!   amortized at fleet scale (the first device of each SoC model
//!   explores and is billed for it; every later device adopts the
//!   distributed `ChoiceProfile` chain for free).
//! - [`engine`] — [`ShardedEventLoop`]: the generic trait-object kernel
//!   (devices partitioned round-robin across worker threads,
//!   `std::thread` + mpsc channels, no external crates). It schedules
//!   arbitrary [`FleetNode`]s — `fl::FlSim`'s full clients included —
//!   and doubles as the reference implementation the SoA kernel is
//!   parity-checked against.
//! - [`soa`] — [`SoaFleet`]: the allocation-free struct-of-arrays
//!   kernel `run_scenario` drives (PR 2). Device state lives in flat
//!   per-shard arrays, a per-round `(trace, shift)` sample cache
//!   collapses 100k availability lookups into a few hundred, persistent
//!   workers exchange preallocated buffers through double-buffered
//!   mailboxes, and results scatter through dense `seq` arrays. Every
//!   stochastic stream stays keyed on (seed, device id) or (seed,
//!   round) — never on shard layout — and the control thread folds
//!   results in a fixed order, so aggregate metrics are **bit-identical
//!   for any shard count and across both kernels**.
//! - [`metrics`] — [`FleetOutcome`] + the `devices-stepped/sec`
//!   throughput figures the `fleet` bench and report emit.
//! - [`bench`] — [`run_fleet_bench`]: the throughput harness behind
//!   `swan bench fleet` and `benches/fleet_throughput.rs`; emits the
//!   `BENCH_fleet.json` perf-trajectory record. Also
//!   [`run_serve_bench`]: the `serve` load-generator mode that points
//!   this fleet at the [`crate::serve`] coordinator control plane
//!   (in-process + loopback TCP, digest-parity-gated, emits
//!   `BENCH_serve.json`). And [`run_fl_bench`]: the numerics-loop
//!   harness (`swan bench fl`) driving real federated SGD through the
//!   unified `fl::engine` on every wiring, emitting `BENCH_fl.json`.

pub mod bench;
pub mod coordinator;
pub mod device;
pub mod engine;
pub mod event;
pub mod metrics;
pub mod scenario;
pub mod soa;

pub use bench::{
    run_fl_bench, run_fleet_bench, run_serve_bench, FlBenchReport,
    FleetBenchReport, ServeBenchReport,
};
pub use coordinator::{
    explore_profiles, CoordinatorPolicy, CoordinatorStats, FleetPolicy,
    ProfileCoordinator, ResolvedCost, StepCost,
};
pub use device::{FleetDevice, FleetNode};
pub use engine::{
    run_scenario, run_scenario_obs, run_scenario_reference,
    run_scenario_reference_obs, DriveConfig, ShardedEventLoop,
};
pub use event::{Event, EventKind, EventQueue};
pub use metrics::{FleetOutcome, KERNEL_EVENT_LOOP, KERNEL_SOA};
pub use scenario::ScenarioSpec;
pub use soa::SoaFleet;
