//! The fleet simulation kernel: sharded, event-driven evaluation of
//! Swan at population scale (100k–1M simulated devices).
//!
//! The paper's headline claims rest on *large-scale* FL evaluations
//! across heterogeneous smartphone SoCs; the seed reproduced them with a
//! serial per-round loop that cannot reach that scale. This subsystem
//! supplies the missing machinery:
//!
//! - [`scenario`] — [`ScenarioSpec`]: experiment setups as *data*
//!   (device-model mixes, GreenHub trace assignment, charger/thermal
//!   envelopes, interference schedules), loadable via `util::json`.
//! - [`device`] — the [`FleetNode`] abstraction the kernel schedules;
//!   implemented by both the scenario-instantiated [`FleetDevice`] and
//!   the FL harness's `fl::FlClient`, so both paths share one scheduler.
//! - [`event`] — the deterministic per-shard event queue.
//! - [`coordinator`] — [`ProfileCoordinator`]: §4.2 exploration
//!   amortized at fleet scale (the first device of each SoC model
//!   explores and is billed for it; every later device adopts the
//!   distributed `ChoiceProfile` chain for free).
//! - [`engine`] — [`ShardedEventLoop`]: devices partitioned round-robin
//!   across worker threads (`std::thread` + mpsc channels, no external
//!   crates). Every stochastic stream is keyed on (seed, device id) or
//!   (seed, round) — never on shard layout — and the control thread
//!   folds per-device results in a fixed order, so aggregate metrics are
//!   **bit-identical for any shard count**.
//! - [`metrics`] — [`FleetOutcome`] + the `devices-stepped/sec`
//!   throughput figures the `fleet` bench and report emit.

pub mod coordinator;
pub mod device;
pub mod engine;
pub mod event;
pub mod metrics;
pub mod scenario;

pub use coordinator::{
    CoordinatorPolicy, CoordinatorStats, FleetPolicy, ProfileCoordinator,
    ResolvedCost, StepCost,
};
pub use device::{FleetDevice, FleetNode};
pub use engine::{run_scenario, DriveConfig, ShardedEventLoop};
pub use event::{Event, EventKind, EventQueue};
pub use metrics::FleetOutcome;
pub use scenario::ScenarioSpec;
