//! The sharded event loop: the generic (trait-object) fleet kernel.
//!
//! This is the PR 1 kernel, kept as (a) the scheduler for arbitrary
//! [`FleetNode`] populations — `fl::FlSim`'s clients carry datasets and
//! can't be decomposed into flat arrays — and (b) the reference
//! implementation the struct-of-arrays kernel
//! ([`SoaFleet`](super::soa::SoaFleet), which `run_scenario` now
//! drives) is benchmarked and parity-checked against.
//!
//! Devices are partitioned round-robin across worker threads
//! (`std::thread::scope` + mpsc channels; no external crates). Each
//! round is two parallel phases separated by a control-thread barrier:
//!
//! ```text
//! control                    workers (one per shard)
//! ───────                    ──────────────────────
//! Poll(now)      ──────────▶ poll every local device's availability
//!                ◀──────────  online ids (ascending)
//! merge, select participants (central RNG keyed on (seed, round)),
//! resolve §4.2 policy costs in picked order
//! Step(jobs)     ──────────▶ event queue: BeginEpoch → EpochDone,
//!                             charging loans, applying interference
//!                ◀──────────  per-device (time, energy, steps)
//! fold results in picked order, advance the virtual clock
//! ```
//!
//! **Determinism.** Every stochastic stream is keyed on scenario seed +
//! device id or round — never on shard layout — device state only ever
//! depends on its own history, and the control thread performs every
//! floating-point reduction in a fixed order (global picked order). So
//! the aggregate metrics are bit-identical for any shard count; the
//! `fleet_determinism` integration test and the bench both assert it via
//! [`FleetOutcome::digest`].

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};

// Wall-clock reads go through the audited obs chokepoint: the lint
// determinism rule bans raw wall-clock constructors in
// digest-affecting modules (timing here is telemetry, never
// simulation state).
use crate::obs::wall_timer;

use crate::fl::{select_uniform, FlArm};
use crate::obs::{
    Obs, ProfileAdopted, RoundEnd, RoundStart, ShardProgress, SpanSummary,
};
use crate::util::rng::Rng;

use super::coordinator::{CoordinatorPolicy, FleetPolicy, ProfileCoordinator, StepCost};
use super::device::FleetNode;
use super::event::{Event, EventKind, EventQueue};
use super::metrics::FleetOutcome;
use super::scenario::ScenarioSpec;

/// Virtual wait when nobody is online (mirrors `fl::FlSim`), seconds.
/// Shared with the SoA kernel (and the serve load generator) so all
/// round drivers advance the clock identically.
pub(crate) const EMPTY_ROUND_WAIT_S: f64 = 600.0;

/// Round structure for one kernel run.
#[derive(Clone, Debug)]
pub struct DriveConfig {
    pub scenario: String,
    pub arm: FlArm,
    pub seed: u64,
    pub rounds: usize,
    pub clients_per_round: usize,
    pub server_overhead_s: f64,
    /// Telemetry sink. `Obs::off()` (the default) makes every emission
    /// a no-op; either way the digest is bit-identical — telemetry only
    /// observes existing barriers, never adds RNG draws or reorders
    /// float folds.
    pub obs: Obs,
}

/// Selection RNG for one round — a function of (seed, round) only, so
/// resharding can never perturb who gets picked. Shared with the SoA
/// kernel (and the serve coordinator/oracle) so every selection path
/// picks identical participants.
pub(crate) fn round_rng(seed: u64, round: usize) -> Rng {
    Rng::new(
        seed ^ 0x5EED_F1EE7
            ^ (round as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
    )
}

/// Shard-local telemetry counters. Workers bump these lock-free on
/// their own state; the control thread folds them into the outcome's
/// registry **in shard order** after the workers are joined — the same
/// barrier discipline as the FNV digest, so recording costs the hot
/// path nothing.
#[derive(Clone, Copy, Debug, Default)]
struct ShardTally {
    polled: u64,
    online: u64,
    stepped: u64,
}

struct Shard<N> {
    /// Local nodes in ascending global-id order; node `k` of shard `s`
    /// is global device `s + k * n_shards`.
    nodes: Vec<N>,
    queue: EventQueue,
    tally: ShardTally,
}

/// One participation order for a shard's device.
#[derive(Clone, Copy, Debug)]
struct StepJob {
    device: u32,
    cost: StepCost,
    /// One-time §4.2 exploration bill (first device of a model).
    extra_time_s: f64,
    extra_energy_j: f64,
}

#[derive(Clone, Copy, Debug)]
struct StepResult {
    device: u32,
    time_s: f64,
    energy_j: f64,
    steps: u32,
}

enum ShardCmd {
    Poll { now_s: f64 },
    Step { now_s: f64, round: usize, jobs: Vec<StepJob> },
    Stop,
}

enum ShardReply {
    Online { online: Vec<u32> },
    Stepped { results: Vec<StepResult> },
}

fn shard_worker<N: FleetNode>(
    shard_idx: usize,
    n_shards: usize,
    shard: &mut Shard<N>,
    rx: Receiver<ShardCmd>,
    tx: Sender<ShardReply>,
) {
    while let Ok(cmd) = rx.recv() {
        match cmd {
            ShardCmd::Poll { now_s } => {
                let mut online = Vec::new();
                for (k, node) in shard.nodes.iter_mut().enumerate() {
                    if node.poll_online(now_s) {
                        online.push((shard_idx + k * n_shards) as u32);
                    }
                }
                shard.tally.polled += shard.nodes.len() as u64;
                shard.tally.online += online.len() as u64;
                if tx.send(ShardReply::Online { online }).is_err() {
                    return;
                }
            }
            ShardCmd::Step { now_s, round, jobs } => {
                shard.tally.stepped += jobs.len() as u64;
                for (ji, job) in jobs.iter().enumerate() {
                    shard.queue.push(Event {
                        at_s: now_s,
                        device: job.device,
                        kind: EventKind::BeginEpoch { job: ji as u32 },
                    });
                }
                let mut results = Vec::with_capacity(jobs.len());
                while let Some(ev) = shard.queue.pop() {
                    let local = (ev.device as usize - shard_idx) / n_shards;
                    match ev.kind {
                        EventKind::BeginEpoch { job } => {
                            // dense index into this round's job slice —
                            // no per-event HashMap routing
                            let j = jobs[job as usize];
                            let node = &shard.nodes[local];
                            let steps = node.epoch_steps();
                            let mult = node.cost_multiplier(ev.at_s, round);
                            let t = j.cost.latency_s * steps as f64 * mult
                                + j.extra_time_s;
                            let e = j.cost.energy_j * steps as f64 * mult
                                + j.extra_energy_j;
                            shard.queue.push(Event {
                                at_s: ev.at_s + t,
                                device: ev.device,
                                kind: EventKind::EpochDone {
                                    job,
                                    time_s: t,
                                    energy_j: e,
                                    steps: steps as u32,
                                },
                            });
                        }
                        EventKind::EpochDone {
                            time_s,
                            energy_j,
                            steps,
                            ..
                        } => {
                            shard.nodes[local].charge(time_s, energy_j);
                            results.push(StepResult {
                                device: ev.device,
                                time_s,
                                energy_j,
                                steps,
                            });
                        }
                    }
                }
                if tx.send(ShardReply::Stepped { results }).is_err() {
                    return;
                }
            }
            ShardCmd::Stop => return,
        }
    }
}

/// The sharded simulation kernel over any [`FleetNode`] population.
pub struct ShardedEventLoop<N: FleetNode> {
    shards: Vec<Shard<N>>,
    /// SoC model per global device id (for central policy resolution).
    models: Vec<crate::soc::device::DeviceId>,
    n_devices: usize,
}

impl<N: FleetNode> ShardedEventLoop<N> {
    /// Partition `nodes` (global id = vector index) round-robin across
    /// `n_shards` worker shards.
    pub fn new(nodes: Vec<N>, n_shards: usize) -> ShardedEventLoop<N> {
        let n_shards = n_shards.max(1).min(nodes.len().max(1));
        let n_devices = nodes.len();
        let models = nodes.iter().map(|n| n.model()).collect();
        let mut shards: Vec<Shard<N>> = (0..n_shards)
            .map(|_| Shard {
                nodes: Vec::with_capacity(n_devices / n_shards + 1),
                queue: EventQueue::new(),
                tally: ShardTally::default(),
            })
            .collect();
        for (i, node) in nodes.into_iter().enumerate() {
            shards[i % n_shards].nodes.push(node);
        }
        ShardedEventLoop {
            shards,
            models,
            n_devices,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    /// Tear down, returning the nodes in global-id order.
    ///
    /// The round-robin partition makes the reassembly a stable
    /// permutation of the shard-order concatenation: taking one node
    /// from each shard in shard order per "row" of local index `k`
    /// yields exactly global order `s + k·n_shards`. So nodes are moved
    /// straight out of the shard vectors — no `Vec<Option<N>>` scatter,
    /// no per-slot unwrap — and a population mismatch is reported as an
    /// error instead of a panic.
    pub fn into_nodes(self) -> crate::Result<Vec<N>> {
        let n_shards = self.shards.len();
        let n = self.n_devices;
        for (s, shard) in self.shards.iter().enumerate() {
            // shard s owns global ids {s, s+n_shards, …} ∩ [0, n)
            let expect = if s < n {
                (n - s + n_shards - 1) / n_shards
            } else {
                0
            };
            crate::ensure!(
                shard.nodes.len() == expect,
                "fleet kernel lost devices: shard {s} holds {} nodes, \
                 expected {expect} of {n}",
                shard.nodes.len()
            );
        }
        let mut columns: Vec<std::vec::IntoIter<N>> = self
            .shards
            .into_iter()
            .map(|sh| sh.nodes.into_iter())
            .collect();
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let before = out.len();
            for it in columns.iter_mut() {
                if let Some(node) = it.next() {
                    out.push(node);
                }
            }
            if out.len() == before {
                break; // all columns dry — the ensure below reports it
            }
        }
        crate::ensure!(
            out.len() == n && columns.iter_mut().all(|it| it.next().is_none()),
            "fleet kernel reassembly mismatch: got {} of {n} nodes",
            out.len()
        );
        Ok(out)
    }

    /// Run `cfg.rounds` rounds of the availability → selection → local
    /// epoch → clock-advance loop (the scheduler both `fl::FlSim` and
    /// the fleet CLI share). See the module doc for the determinism
    /// contract.
    ///
    /// A dead shard worker (panicked, or its channel torn down) surfaces
    /// as `Err` — the control thread stops the remaining shards, joins
    /// every worker, and reports which side failed, instead of aborting
    /// the whole coordinator through an `expect`.
    pub fn drive(
        &mut self,
        policy: &mut dyn FleetPolicy,
        cfg: &DriveConfig,
    ) -> crate::Result<FleetOutcome> {
        let wall0 = wall_timer();
        let shards = &mut self.shards;
        let models = &self.models;
        let n_shards = shards.len();
        for shard in shards.iter_mut() {
            shard.tally = ShardTally::default();
        }

        let mut outcome = FleetOutcome {
            scenario: cfg.scenario.clone(),
            arm: cfg.arm.name(),
            devices: self.n_devices,
            shards: n_shards,
            kernel: super::metrics::KERNEL_EVENT_LOOP,
            ..Default::default()
        };

        std::thread::scope(|scope| -> crate::Result<()> {
            // One reply channel per shard: a panicked worker drops its
            // sender, so the control thread's recv fails immediately
            // and the control loop below turns it into an error.
            let mut cmd_txs: Vec<Sender<ShardCmd>> =
                Vec::with_capacity(n_shards);
            let mut reply_rxs: Vec<Receiver<ShardReply>> =
                Vec::with_capacity(n_shards);
            let mut handles = Vec::with_capacity(n_shards);
            for (si, shard) in shards.iter_mut().enumerate() {
                let (tx, rx) = channel::<ShardCmd>();
                let (reply_tx, reply_rx) = channel::<ShardReply>();
                cmd_txs.push(tx);
                reply_rxs.push(reply_rx);
                handles.push(scope.spawn(move || {
                    shard_worker(si, n_shards, shard, rx, reply_tx)
                }));
            }

            let mut now_s = 0.0f64;
            let mut total_energy = 0.0f64;
            let mut total_steps = 0u64;
            let mut participations = 0u64;

            // Telemetry locals: phase spans and the control-side
            // registry. Wall-clock only — never fed back into the
            // simulation, so the digest cannot see them.
            let mut spans = crate::obs::Spans::default();
            let sp_avail = spans.span(crate::obs::PHASE_AVAILABILITY);
            let sp_select = spans.span(crate::obs::PHASE_SELECT);
            let sp_step = spans.span(crate::obs::PHASE_STEP);
            let sp_agg = spans.span(crate::obs::PHASE_AGGREGATE);
            let mut metrics = crate::obs::MetricsRegistry::default();
            let c_online = metrics.counter("fleet.online");
            let c_picked = metrics.counter("fleet.picked");
            let h_round = metrics
                .hist("fleet.round_wall_s", crate::obs::LATENCY_BUCKETS_S);
            let h_avail = metrics.hist(
                "fleet.stage.availability_s",
                crate::obs::LATENCY_BUCKETS_S,
            );
            let h_select = metrics
                .hist("fleet.stage.select_s", crate::obs::LATENCY_BUCKETS_S);
            let h_step = metrics
                .hist("fleet.stage.step_s", crate::obs::LATENCY_BUCKETS_S);
            let h_agg = metrics.hist(
                "fleet.stage.aggregate_s",
                crate::obs::LATENCY_BUCKETS_S,
            );
            // Trace timestamps: anchored at drive start, read only at
            // the control thread's own barriers.
            let tclock = crate::obs::TraceClock::start();

            // The control loop proper, fallible: any send/recv against
            // a dead shard breaks out with an error naming it.
            let run = (|| -> crate::Result<()> {
                for round in 0..cfg.rounds {
                    let round_t0 = wall_timer();
                    if cfg.obs.enabled() {
                        cfg.obs.emit(&RoundStart {
                            scenario: &cfg.scenario,
                            round,
                            now_s,
                        });
                    }
                    let phase_t0 = wall_timer();
                    // 1. availability: every shard polls in parallel
                    for (sid, tx) in cmd_txs.iter().enumerate() {
                        crate::ensure!(
                            tx.send(ShardCmd::Poll { now_s }).is_ok(),
                            "fleet shard {sid} hung up before round \
                             {round}'s poll"
                        );
                    }
                    let mut online_by_shard: Vec<Vec<u32>> =
                        (0..n_shards).map(|_| Vec::new()).collect();
                    for (sid, reply_rx) in reply_rxs.iter().enumerate() {
                        match reply_rx.recv() {
                            Ok(ShardReply::Online { online }) => {
                                online_by_shard[sid] = online;
                            }
                            Ok(ShardReply::Stepped { .. }) => {
                                crate::bail!(
                                    "fleet shard {sid} answered round \
                                     {round}'s poll with step results"
                                )
                            }
                            Err(_) => crate::bail!(
                                "fleet shard {sid} died during round \
                                 {round}'s poll"
                            ),
                        }
                    }
                    if cfg.obs.enabled() {
                        for (sid, o) in online_by_shard.iter().enumerate()
                        {
                            cfg.obs.emit(&ShardProgress {
                                round,
                                shard: sid,
                                online: o.len(),
                            });
                        }
                    }
                    let mut online: Vec<usize> = online_by_shard
                        .into_iter()
                        .flatten()
                        .map(|i| i as usize)
                        .collect();
                    online.sort_unstable();
                    outcome.online_per_round.push((round, online.len()));
                    let avail_s = phase_t0.elapsed().as_secs_f64();
                    spans.record(sp_avail, avail_s);
                    metrics.observe(h_avail, avail_s);
                    metrics.add(c_online, online.len() as u64);
                    if online.is_empty() {
                        now_s += EMPTY_ROUND_WAIT_S;
                        metrics.observe(
                            h_round,
                            round_t0.elapsed().as_secs_f64(),
                        );
                        if cfg.obs.enabled() {
                            cfg.obs.emit(&RoundEnd {
                                round,
                                online: 0,
                                picked: 0,
                                round_time_s: 0.0,
                                round_energy_j: 0.0,
                                now_s,
                            });
                        }
                        continue;
                    }

                    // 2. selection: central, keyed on (seed, round) only
                    let phase_t0 = wall_timer();
                    let mut rng = round_rng(cfg.seed, round);
                    let picked = select_uniform(
                        &online,
                        cfg.clients_per_round,
                        &mut rng,
                    );
                    metrics.add(c_picked, picked.len() as u64);

                    // 3. resolve policy costs centrally, in picked order
                    //    (§4.2 exploration billing is order-sensitive)
                    let mut jobs_by_shard: Vec<Vec<StepJob>> =
                        (0..n_shards).map(|_| Vec::new()).collect();
                    for &gid in &picked {
                        let rc = policy.step_cost(models[gid], gid);
                        jobs_by_shard[gid % n_shards].push(StepJob {
                            device: gid as u32,
                            cost: rc.cost,
                            extra_time_s: rc.exploration_time_s,
                            extra_energy_j: rc.exploration_energy_j,
                        });
                    }
                    let select_s = phase_t0.elapsed().as_secs_f64();
                    spans.record(sp_select, select_s);
                    metrics.observe(h_select, select_s);
                    if cfg.obs.trace_on() {
                        // one timestamp per barrier: the edges record
                        // WHEN the selection barrier passed, not a
                        // fictional per-device ordering within it
                        let t_s = tclock.now_s();
                        for (i, &gid) in picked.iter().enumerate() {
                            cfg.obs.emit(
                                &crate::obs::TraceEdge::new(
                                    round as u32,
                                    gid as u64,
                                    crate::obs::trace::EDGE_SELECTED,
                                    t_s,
                                )
                                .with("seq", i as f64),
                            );
                        }
                    }

                    // 4. parallel event-driven local epochs
                    let phase_t0 = wall_timer();
                    let mut active: Vec<usize> = Vec::new();
                    for (sid, tx) in cmd_txs.iter().enumerate() {
                        let jobs = std::mem::take(&mut jobs_by_shard[sid]);
                        if jobs.is_empty() {
                            continue;
                        }
                        active.push(sid);
                        crate::ensure!(
                            tx.send(ShardCmd::Step {
                                now_s,
                                round,
                                jobs,
                            })
                            .is_ok(),
                            "fleet shard {sid} hung up before round \
                             {round}'s step"
                        );
                    }
                    let mut results: HashMap<u32, StepResult> =
                        HashMap::new();
                    for &sid in &active {
                        match reply_rxs[sid].recv() {
                            Ok(ShardReply::Stepped { results: rs }) => {
                                for r in rs {
                                    results.insert(r.device, r);
                                }
                            }
                            Ok(ShardReply::Online { .. }) => {
                                crate::bail!(
                                    "fleet shard {sid} answered round \
                                     {round}'s step with a poll reply"
                                )
                            }
                            Err(_) => crate::bail!(
                                "fleet shard {sid} died during round \
                                 {round}'s step"
                            ),
                        }
                    }

                    let step_s = phase_t0.elapsed().as_secs_f64();
                    spans.record(sp_step, step_s);
                    metrics.observe(h_step, step_s);
                    if cfg.obs.trace_on() {
                        let t_s = tclock.now_s();
                        for &gid in &picked {
                            if let Some(r) = results.get(&(gid as u32)) {
                                cfg.obs.emit(
                                    &crate::obs::TraceEdge::new(
                                        round as u32,
                                        gid as u64,
                                        crate::obs::trace::EDGE_STEPPED,
                                        t_s,
                                    )
                                    .with("time_s", r.time_s)
                                    .with("energy_j", r.energy_j),
                                );
                            }
                        }
                    }

                    // 5. fold in global picked order — a fixed reduction
                    //    order keeps aggregates bit-identical under any
                    //    sharding (synchronous FL: stragglers pace
                    //    rounds)
                    let phase_t0 = wall_timer();
                    let mut round_time = 0.0f64;
                    let mut round_energy = 0.0f64;
                    for &gid in &picked {
                        let r = results.get(&(gid as u32)).ok_or_else(
                            || {
                                crate::err!(
                                    "fleet: no step result for device \
                                     {gid} in round {round}"
                                )
                            },
                        )?;
                        total_energy += r.energy_j;
                        round_energy += r.energy_j;
                        total_steps += r.steps as u64;
                        participations += 1;
                        round_time = round_time.max(r.time_s);
                    }
                    now_s += round_time + cfg.server_overhead_s;
                    outcome.rounds_run = round + 1;
                    let agg_s = phase_t0.elapsed().as_secs_f64();
                    spans.record(sp_agg, agg_s);
                    metrics.observe(h_agg, agg_s);
                    metrics.observe(
                        h_round,
                        round_t0.elapsed().as_secs_f64(),
                    );
                    if cfg.obs.enabled() {
                        cfg.obs.emit(&RoundEnd {
                            round,
                            online: online.len(),
                            picked: picked.len(),
                            round_time_s: round_time,
                            round_energy_j: round_energy,
                            now_s,
                        });
                    }
                }
                Ok(())
            })();

            // Release every worker — after an error too — then join
            // them here so a panicked worker becomes an `Err` from this
            // scope instead of a coordinator abort at scope exit.
            for tx in &cmd_txs {
                let _ = tx.send(ShardCmd::Stop);
            }
            drop(cmd_txs);
            let mut panicked = 0usize;
            for h in handles {
                if h.join().is_err() {
                    panicked += 1;
                }
            }
            run?;
            crate::ensure!(
                panicked == 0,
                "{panicked} fleet shard worker(s) panicked"
            );

            outcome.total_time_s = now_s;
            outcome.total_energy_j = total_energy;
            outcome.total_steps = total_steps;
            outcome.participations = participations;
            outcome.spans = spans;
            outcome.metrics = metrics;
            Ok(())
        })?;
        outcome.wall_s = wall0.elapsed().as_secs_f64();
        // Shard-local tallies, folded in shard order now that the
        // workers are joined and the shard borrows are back.
        for shard in &self.shards {
            outcome.metrics.inc("fleet.shard_polls", shard.tally.polled);
            outcome
                .metrics
                .inc("fleet.shard_online", shard.tally.online);
            outcome.metrics.inc("fleet.shard_steps", shard.tally.stepped);
        }
        if cfg.obs.enabled() {
            cfg.obs.emit(&SpanSummary {
                scope: "fleet-drive",
                spans: &outcome.spans,
            });
        }
        Ok(outcome)
    }
}

/// The round structure a [`ScenarioSpec`] implies.
pub(super) fn drive_config(
    spec: &ScenarioSpec,
    arm: FlArm,
    obs: Obs,
) -> DriveConfig {
    DriveConfig {
        scenario: spec.name.clone(),
        arm,
        seed: spec.seed,
        rounds: spec.rounds,
        clients_per_round: spec.clients_per_round,
        server_overhead_s: spec.server_overhead_s,
        obs,
    }
}

/// Attach the coordinator's §4.2 accounting to an outcome. Exploration
/// is a Swan-arm concept: the greedy baseline never explores (the
/// coordinator may have profiled models as a side effect, but no
/// baseline device was billed or adopted).
pub(super) fn attach_exploration(
    out: &mut FleetOutcome,
    coord: &ProfileCoordinator,
    arm: FlArm,
) {
    if arm == FlArm::Swan {
        let stats = coord.stats();
        out.models_explored = stats.models_explored;
        out.adoptions = stats.adoptions as u64;
        out.exploration_time_s = stats.exploration_time_s;
        out.exploration_energy_j = stats.exploration_energy_j;
    }
}

/// End-of-run §4.2 adoption events — one `profile-adopted` record per
/// model whose cached chain was reused at least once. Aggregated here
/// rather than per-adoption: adoptions happen inside the per-device
/// policy resolution loop, far too hot for an event each.
fn emit_adoptions(obs: &Obs, coord: &ProfileCoordinator, arm: FlArm) {
    if !obs.enabled() || arm != FlArm::Swan {
        return;
    }
    for (model, adoptions) in coord.adoption_counts() {
        if adoptions > 0 {
            obs.emit(&ProfileAdopted {
                model: model.key(),
                adoptions: adoptions as u64,
            });
        }
    }
}

/// Run one scenario end to end on the struct-of-arrays kernel (the
/// default since PR 2): build the fleet, drive it through a
/// [`ProfileCoordinator`]-backed policy, attach §4.2 accounting.
/// Aggregates are bit-identical to [`run_scenario_reference`].
pub fn run_scenario(
    spec: &ScenarioSpec,
    n_shards: usize,
    arm: FlArm,
) -> crate::Result<FleetOutcome> {
    run_scenario_obs(spec, n_shards, arm, &Obs::off())
}

/// [`run_scenario`] with a telemetry sink: NDJSON round lifecycle +
/// §4.2 exploration events, phase spans and merged shard metrics on
/// the outcome. Digest-neutral — `tests/obs_stream.rs` asserts the
/// enabled and disabled runs are bit-identical.
pub fn run_scenario_obs(
    spec: &ScenarioSpec,
    n_shards: usize,
    arm: FlArm,
    obs: &Obs,
) -> crate::Result<FleetOutcome> {
    let workload = crate::workload::load_or_builtin(spec.workload, "artifacts");
    let mut coord = ProfileCoordinator::new(workload);
    coord.set_obs(obs.clone());
    let nodes = spec.build_fleet()?;
    let mut fleet = super::soa::SoaFleet::new(nodes, n_shards);
    let cfg = drive_config(spec, arm, obs.clone());
    let mut policy = CoordinatorPolicy {
        coord: &mut coord,
        arm,
    };
    let mut out = fleet.drive(&mut policy, &cfg)?;
    attach_exploration(&mut out, &coord, arm);
    emit_adoptions(obs, &coord, arm);
    Ok(out)
}

/// Same scenario on the PR 1 message-passing [`ShardedEventLoop`] — the
/// reference the bench compares the SoA kernel against, and the parity
/// oracle for `tests/fleet_determinism.rs`.
pub fn run_scenario_reference(
    spec: &ScenarioSpec,
    n_shards: usize,
    arm: FlArm,
) -> crate::Result<FleetOutcome> {
    run_scenario_reference_obs(spec, n_shards, arm, &Obs::off())
}

/// [`run_scenario_reference`] with a telemetry sink.
pub fn run_scenario_reference_obs(
    spec: &ScenarioSpec,
    n_shards: usize,
    arm: FlArm,
    obs: &Obs,
) -> crate::Result<FleetOutcome> {
    let workload = crate::workload::load_or_builtin(spec.workload, "artifacts");
    let mut coord = ProfileCoordinator::new(workload);
    coord.set_obs(obs.clone());
    let nodes = spec.build_fleet()?;
    let mut engine = ShardedEventLoop::new(nodes, n_shards);
    let cfg = drive_config(spec, arm, obs.clone());
    let mut policy = CoordinatorPolicy {
        coord: &mut coord,
        arm,
    };
    let mut out = engine.drive(&mut policy, &cfg)?;
    attach_exploration(&mut out, &coord, arm);
    emit_adoptions(obs, &coord, arm);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::scenario::ScenarioSpec;

    fn tiny_spec() -> ScenarioSpec {
        ScenarioSpec {
            name: "unit".to_string(),
            devices: 240,
            rounds: 8,
            clients_per_round: 12,
            trace_users: 2,
            ..ScenarioSpec::default()
        }
    }

    #[test]
    fn resharding_is_bit_identical() {
        let spec = tiny_spec();
        let a = run_scenario(&spec, 1, FlArm::Swan).unwrap();
        let b = run_scenario(&spec, 3, FlArm::Swan).unwrap();
        let c = run_scenario(&spec, 7, FlArm::Swan).unwrap();
        assert_eq!(a.digest(), b.digest(), "1 vs 3 shards");
        assert_eq!(a.digest(), c.digest(), "1 vs 7 shards");
        assert_eq!(a.online_per_round, b.online_per_round);
        assert_eq!(a.total_time_s.to_bits(), c.total_time_s.to_bits());
        assert_eq!(a.total_energy_j.to_bits(), c.total_energy_j.to_bits());
    }

    #[test]
    fn swan_cheaper_than_baseline_at_fleet_scale() {
        let spec = tiny_spec();
        let swan = run_scenario(&spec, 2, FlArm::Swan).unwrap();
        let base = run_scenario(&spec, 2, FlArm::Baseline).unwrap();
        assert!(swan.participations > 0);
        assert!(
            base.total_energy_j > 2.0 * swan.total_energy_j,
            "shufflenet fleet: baseline {} J vs swan {} J",
            base.total_energy_j,
            swan.total_energy_j
        );
        assert!(base.total_time_s > swan.total_time_s);
    }

    #[test]
    fn exploration_amortizes_across_the_fleet() {
        let spec = tiny_spec();
        let out = run_scenario(&spec, 2, FlArm::Swan).unwrap();
        assert!(out.models_explored >= 1 && out.models_explored <= 5);
        assert!(
            out.adoptions as usize
                >= out.participations as usize - out.models_explored,
            "all but the explorers must adopt: {} adoptions, {} parts",
            out.adoptions,
            out.participations
        );
        assert!(out.exploration_time_s > 0.0);
    }

    #[test]
    fn into_nodes_restores_global_order() {
        let spec = ScenarioSpec {
            devices: 11,
            trace_users: 1,
            ..ScenarioSpec::default()
        };
        let nodes = spec.build_fleet().unwrap();
        let engine = ShardedEventLoop::new(nodes, 4);
        assert_eq!(engine.n_shards(), 4);
        assert_eq!(engine.n_devices(), 11);
        let back = engine.into_nodes().unwrap();
        assert_eq!(back.len(), 11);
        for (i, n) in back.iter().enumerate() {
            assert_eq!(n.id, i);
        }
    }

    #[test]
    fn into_nodes_errors_on_missing_slot() {
        use crate::soc::device::DeviceId;

        struct Stub(usize);
        impl FleetNode for Stub {
            fn model(&self) -> DeviceId {
                DeviceId::Pixel3
            }
            fn poll_online(&mut self, _now_s: f64) -> bool {
                false
            }
            fn epoch_steps(&self) -> usize {
                1
            }
            fn charge(&mut self, _time_s: f64, _energy_j: f64) {}
        }

        fn shard_of(nodes: Vec<Stub>) -> Shard<Stub> {
            Shard {
                nodes,
                queue: EventQueue::new(),
                tally: ShardTally::default(),
            }
        }

        // well-formed: 3 devices over 2 shards reassemble in id order
        let ok = ShardedEventLoop {
            shards: vec![
                shard_of(vec![Stub(0), Stub(2)]),
                shard_of(vec![Stub(1)]),
            ],
            models: vec![DeviceId::Pixel3; 3],
            n_devices: 3,
        };
        let back = ok.into_nodes().unwrap();
        assert_eq!(back.iter().map(|s| s.0).collect::<Vec<_>>(), vec![0, 1, 2]);

        // a shard lost a node: must be an error, not a panic
        let broken = ShardedEventLoop {
            shards: vec![
                shard_of(vec![Stub(0), Stub(2)]),
                shard_of(vec![]),
            ],
            models: vec![DeviceId::Pixel3; 3],
            n_devices: 3,
        };
        let err = broken.into_nodes();
        assert!(err.is_err(), "missing slot must surface as an error");
    }

    #[test]
    fn shard_count_clamped_to_population() {
        let spec = ScenarioSpec {
            devices: 3,
            trace_users: 1,
            ..ScenarioSpec::default()
        };
        let nodes = spec.build_fleet().unwrap();
        let engine = ShardedEventLoop::new(nodes, 64);
        assert_eq!(engine.n_shards(), 3);
    }

    #[test]
    fn zero_rounds_is_a_clean_noop() {
        let spec = ScenarioSpec {
            devices: 10,
            rounds: 0,
            trace_users: 1,
            ..ScenarioSpec::default()
        };
        let out = run_scenario(&spec, 2, FlArm::Swan).unwrap();
        assert_eq!(out.rounds_run, 0);
        assert_eq!(out.participations, 0);
        assert_eq!(out.total_time_s, 0.0);
    }
}
