//! Fleet scenarios as data.
//!
//! A [`ScenarioSpec`] describes everything a fleet run needs — device
//! count, SoC-model mix, GreenHub trace pool + assignment, charger
//! envelope (daily credit), availability gate, interference and thermal
//! schedules, and the round structure — so experiment setups live in
//! JSON instead of hard-coded Rust. Builtin presets cover the scales the
//! bench and report use (`smoke`, `city`, `metro`, `million`).

use std::sync::Arc;

use crate::fl::energy_loan::EnergyLoan;
use crate::soc::device::{device, DeviceId};
use crate::trace::resample::ResampledTrace;
use crate::util::json::{parse_file, Value};
use crate::util::rng::Rng;
use crate::workload::WorkloadName;

use super::device::FleetDevice;

/// A data-driven fleet experiment description.
#[derive(Clone, Debug)]
pub struct ScenarioSpec {
    pub name: String,
    pub seed: u64,
    /// Fleet size (devices simulated concurrently).
    pub devices: usize,
    pub rounds: usize,
    /// Participants selected per round.
    pub clients_per_round: usize,
    /// Local SGD steps each participant pays per round.
    pub local_steps: usize,
    /// Device-model mix as (model, weight); normalized at sampling time.
    pub mix: Vec<(DeviceId, f64)>,
    pub workload: WorkloadName,
    /// GreenHub trace pool size; device `i` is assigned trace
    /// `i % pool` with an `(i / pool) % 24` hourly shift — the Appendix
    /// A.2 augmentation applied at fleet scale.
    pub trace_users: usize,
    /// Charger envelope: daily charger credit available to FL, J/day
    /// (per-device 0.6–1.6× jitter, the same draw `fl::FlSim` makes).
    pub daily_credit_j: f64,
    /// Minimum traced battery level (%) when not charging (§4.1 gate).
    pub min_level_pct: f64,
    /// Interference schedule: probability a foreground session overlaps
    /// a picked device's epoch in a given round, and its slowdown.
    pub interference_p: f64,
    pub interference_slowdown: f64,
    /// Thermal envelope: probability of a DVFS-throttled epoch + derate.
    pub thermal_throttle_p: f64,
    pub thermal_derate: f64,
    pub server_overhead_s: f64,
}

fn opt_usize(v: &Value, key: &str, dst: &mut usize) -> crate::Result<()> {
    if let Some(x) = v.get(key) {
        *dst = x
            .as_usize()
            .ok_or_else(|| crate::err!("scenario key '{key}' must be a number"))?;
    }
    Ok(())
}

fn opt_f64(v: &Value, key: &str, dst: &mut f64) -> crate::Result<()> {
    if let Some(x) = v.get(key) {
        *dst = x
            .as_f64()
            .ok_or_else(|| crate::err!("scenario key '{key}' must be a number"))?;
    }
    Ok(())
}

fn default_mix() -> Vec<(DeviceId, f64)> {
    vec![
        (DeviceId::Pixel3, 0.25),
        (DeviceId::S10e, 0.20),
        (DeviceId::OnePlus8, 0.20),
        (DeviceId::TabS6, 0.15),
        (DeviceId::Mi10, 0.20),
    ]
}

impl Default for ScenarioSpec {
    fn default() -> Self {
        ScenarioSpec {
            name: "custom".to_string(),
            seed: 0,
            devices: 1_000,
            rounds: 20,
            clients_per_round: 50,
            local_steps: 5,
            mix: default_mix(),
            workload: WorkloadName::ShufflenetV2,
            trace_users: 8,
            daily_credit_j: 3_000.0,
            min_level_pct: 20.0,
            interference_p: 0.15,
            interference_slowdown: 2.5,
            thermal_throttle_p: 0.05,
            thermal_derate: 1.5,
            server_overhead_s: 0.5,
        }
    }
}

impl ScenarioSpec {
    /// Builtin presets: `smoke` (CI scale), `city` (the 100k bench
    /// scenario), `metro`, `million`.
    pub fn builtin(key: &str) -> Option<ScenarioSpec> {
        let mut s = ScenarioSpec {
            name: key.to_string(),
            ..ScenarioSpec::default()
        };
        match key {
            "smoke" => {
                s.devices = 2_000;
                s.rounds = 25;
                s.clients_per_round = 100;
            }
            "city" => {
                s.devices = 100_000;
                s.rounds = 20;
                s.clients_per_round = 1_000;
                s.trace_users = 16;
            }
            "metro" => {
                s.devices = 250_000;
                s.rounds = 15;
                s.clients_per_round = 2_000;
                s.trace_users = 24;
            }
            "million" => {
                s.devices = 1_000_000;
                s.rounds = 10;
                s.clients_per_round = 5_000;
                s.trace_users = 32;
            }
            _ => return None,
        }
        Some(s)
    }

    /// Parse a spec; only `name` is required, everything else defaults.
    pub fn from_json(v: &Value) -> crate::Result<ScenarioSpec> {
        let mut s = ScenarioSpec {
            name: v.req_str("name")?.to_string(),
            ..ScenarioSpec::default()
        };
        opt_usize(v, "devices", &mut s.devices)?;
        opt_usize(v, "rounds", &mut s.rounds)?;
        opt_usize(v, "clients_per_round", &mut s.clients_per_round)?;
        opt_usize(v, "local_steps", &mut s.local_steps)?;
        opt_usize(v, "trace_users", &mut s.trace_users)?;
        // seeds are u64; JSON numbers are f64-backed, so large seeds
        // travel as strings to stay bit-exact (see `to_json`)
        if let Some(sv) = v.get("seed") {
            s.seed = match sv {
                Value::Num(n) => {
                    crate::ensure!(
                        n.fract() == 0.0
                            && *n >= 0.0
                            && *n <= (1u64 << 53) as f64,
                        "scenario 'seed' must be a non-negative integer \
                         (use a string for seeds above 2^53)"
                    );
                    *n as u64
                }
                Value::Str(st) => st.parse::<u64>().map_err(|_| {
                    crate::err!("scenario 'seed' is not a u64: '{st}'")
                })?,
                _ => crate::bail!("scenario 'seed' must be a number or string"),
            };
        }
        opt_f64(v, "daily_credit_j", &mut s.daily_credit_j)?;
        opt_f64(v, "min_level_pct", &mut s.min_level_pct)?;
        opt_f64(v, "interference_p", &mut s.interference_p)?;
        opt_f64(v, "interference_slowdown", &mut s.interference_slowdown)?;
        opt_f64(v, "thermal_throttle_p", &mut s.thermal_throttle_p)?;
        opt_f64(v, "thermal_derate", &mut s.thermal_derate)?;
        opt_f64(v, "server_overhead_s", &mut s.server_overhead_s)?;
        if let Some(w) = v.get("workload").and_then(Value::as_str) {
            s.workload = WorkloadName::parse(w)
                .ok_or_else(|| crate::err!("unknown workload '{w}'"))?;
        }
        if let Some(mv) = v.get("mix") {
            let kv = match mv {
                Value::Obj(kv) => kv,
                _ => crate::bail!("'mix' must be an object of weights"),
            };
            let mut mix = Vec::new();
            for (k, wv) in kv {
                let id = DeviceId::parse(k).ok_or_else(|| {
                    crate::err!("unknown device '{k}' in mix")
                })?;
                let w = wv.as_f64().ok_or_else(|| {
                    crate::err!("mix weight for '{k}' is not a number")
                })?;
                crate::ensure!(w >= 0.0, "negative mix weight for '{k}'");
                mix.push((id, w));
            }
            crate::ensure!(
                mix.iter().any(|(_, w)| *w > 0.0),
                "mix has no positive weight"
            );
            s.mix = mix;
        }
        crate::ensure!(s.devices > 0, "scenario needs devices > 0");
        crate::ensure!(s.clients_per_round > 0, "clients_per_round must be > 0");
        // negative/NaN envelopes would invert loans or corrupt the
        // event timeline — reject rather than simulate garbage
        for (key, x) in [
            ("daily_credit_j", s.daily_credit_j),
            ("min_level_pct", s.min_level_pct),
            ("server_overhead_s", s.server_overhead_s),
        ] {
            crate::ensure!(
                x.is_finite() && x >= 0.0,
                "scenario '{key}' must be finite and >= 0, got {x}"
            );
        }
        for (key, p) in [
            ("interference_p", s.interference_p),
            ("thermal_throttle_p", s.thermal_throttle_p),
        ] {
            crate::ensure!(
                p.is_finite() && (0.0..=1.0).contains(&p),
                "scenario '{key}' must be a probability in [0, 1], got {p}"
            );
        }
        for (key, m) in [
            ("interference_slowdown", s.interference_slowdown),
            ("thermal_derate", s.thermal_derate),
        ] {
            crate::ensure!(
                m.is_finite() && m >= 1.0,
                "scenario '{key}' must be a multiplier >= 1, got {m}"
            );
        }
        Ok(s)
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> crate::Result<ScenarioSpec> {
        Self::from_json(&parse_file(path)?)
    }

    pub fn to_json(&self) -> Value {
        let mut mix = Value::obj();
        for (id, w) in &self.mix {
            mix = mix.set(id.key(), *w);
        }
        // seeds above 2^53 don't fit an f64-backed JSON number exactly
        let seed = if self.seed <= (1u64 << 53) {
            Value::Num(self.seed as f64)
        } else {
            Value::Str(self.seed.to_string())
        };
        Value::obj()
            .set("name", self.name.clone())
            .set("seed", seed)
            .set("devices", self.devices)
            .set("rounds", self.rounds)
            .set("clients_per_round", self.clients_per_round)
            .set("local_steps", self.local_steps)
            .set("workload", self.workload.key())
            .set("trace_users", self.trace_users)
            .set("daily_credit_j", self.daily_credit_j)
            .set("min_level_pct", self.min_level_pct)
            .set("interference_p", self.interference_p)
            .set("interference_slowdown", self.interference_slowdown)
            .set("thermal_throttle_p", self.thermal_throttle_p)
            .set("thermal_derate", self.thermal_derate)
            .set("server_overhead_s", self.server_overhead_s)
            .set("mix", mix)
    }

    /// Instantiate the fleet: synthesize + A.2-filter + resample the
    /// trace pool (as `fl::FlSim` does), then stamp out devices with
    /// deterministic per-device streams — model from the mix, charger
    /// credit jitter, trace + hourly-shift assignment. Device `i`'s
    /// state is a function of `(spec, i)` only, never of shard layout.
    pub fn build_fleet(&self) -> crate::Result<Vec<FleetDevice>> {
        let want = self.trace_users.max(1);
        let pool: Vec<Arc<ResampledTrace>> =
            crate::trace::synthesize_quality_pool(self.seed, want, want * 20)?
                .into_iter()
                .map(Arc::new)
                .collect();
        crate::ensure!(
            !pool.is_empty(),
            "no quality traces generated for scenario '{}'",
            self.name
        );

        let weights: Vec<f64> = self.mix.iter().map(|(_, w)| *w).collect();
        let battery: Vec<(DeviceId, f64)> = self
            .mix
            .iter()
            .map(|(id, _)| (*id, device(*id).battery_mah))
            .collect();

        let mut out = Vec::with_capacity(self.devices);
        for i in 0..self.devices {
            let mut rng = Rng::new(
                self.seed
                    ^ (i as u64).wrapping_mul(0xA076_1D64_78BD_642F),
            );
            let (model, mah) = battery[rng.weighted(&weights)];
            let credit = self.daily_credit_j * rng.range(0.6, 1.6);
            out.push(FleetDevice {
                id: i,
                model,
                trace: pool[i % pool.len()].clone(),
                shift_s: ((i / pool.len()) % 24) as f64 * 3600.0,
                loan: EnergyLoan::new(mah, credit),
                epoch_steps: self.local_steps.max(1),
                min_level_pct: self.min_level_pct,
                interference_p: self.interference_p,
                interference_slowdown: self.interference_slowdown,
                thermal_throttle_p: self.thermal_throttle_p,
                thermal_derate: self.thermal_derate,
                seed: self.seed
                    ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                participations: 0,
                train_time_s: 0.0,
            });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::device::FleetNode;

    #[test]
    fn builtins_exist_and_scale_up() {
        let smoke = ScenarioSpec::builtin("smoke").unwrap();
        let city = ScenarioSpec::builtin("city").unwrap();
        let million = ScenarioSpec::builtin("million").unwrap();
        assert!(smoke.devices < city.devices);
        assert_eq!(city.devices, 100_000);
        assert_eq!(million.devices, 1_000_000);
        assert!(ScenarioSpec::builtin("nope").is_none());
    }

    #[test]
    fn json_roundtrip_preserves_fields() {
        let mut spec = ScenarioSpec::builtin("smoke").unwrap();
        spec.seed = 9;
        spec.interference_p = 0.33;
        spec.workload = WorkloadName::MobilenetV2;
        let v = spec.to_json();
        let back = ScenarioSpec::from_json(&v).unwrap();
        assert_eq!(back.name, "smoke");
        assert_eq!(back.seed, 9);
        assert_eq!(back.devices, spec.devices);
        assert_eq!(back.workload, WorkloadName::MobilenetV2);
        assert!((back.interference_p - 0.33).abs() < 1e-12);
        assert_eq!(back.mix.len(), spec.mix.len());
    }

    #[test]
    fn every_builtin_preset_survives_the_json_roundtrip() {
        // the presets are the bench tiers (smoke → CI, city → the 100k
        // bench, metro/million → standing SoA tiers); their specs must
        // survive to_json → from_json field-for-field or a recorded
        // BENCH_*.json no longer reproduces the run it claims to
        for key in ["smoke", "city", "metro", "million"] {
            let spec = ScenarioSpec::builtin(key).unwrap();
            let back = ScenarioSpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(back.name, spec.name, "{key}");
            assert_eq!(back.seed, spec.seed, "{key}");
            assert_eq!(back.devices, spec.devices, "{key}");
            assert_eq!(back.rounds, spec.rounds, "{key}");
            assert_eq!(
                back.clients_per_round, spec.clients_per_round,
                "{key}"
            );
            assert_eq!(back.local_steps, spec.local_steps, "{key}");
            assert_eq!(back.workload, spec.workload, "{key}");
            assert_eq!(back.trace_users, spec.trace_users, "{key}");
            assert_eq!(
                back.daily_credit_j.to_bits(),
                spec.daily_credit_j.to_bits(),
                "{key}"
            );
            assert_eq!(
                back.min_level_pct.to_bits(),
                spec.min_level_pct.to_bits(),
                "{key}"
            );
            assert_eq!(
                back.interference_p.to_bits(),
                spec.interference_p.to_bits(),
                "{key}"
            );
            assert_eq!(
                back.interference_slowdown.to_bits(),
                spec.interference_slowdown.to_bits(),
                "{key}"
            );
            assert_eq!(
                back.thermal_throttle_p.to_bits(),
                spec.thermal_throttle_p.to_bits(),
                "{key}"
            );
            assert_eq!(
                back.thermal_derate.to_bits(),
                spec.thermal_derate.to_bits(),
                "{key}"
            );
            assert_eq!(
                back.server_overhead_s.to_bits(),
                spec.server_overhead_s.to_bits(),
                "{key}"
            );
            // the mix travels as an object: same weights per model,
            // regardless of entry order
            assert_eq!(back.mix.len(), spec.mix.len(), "{key}");
            for (id, w) in &spec.mix {
                let wb = back
                    .mix
                    .iter()
                    .find(|(b, _)| b == id)
                    .map(|(_, w)| *w)
                    .unwrap_or(f64::NAN);
                assert_eq!(wb.to_bits(), w.to_bits(), "{key}/{id:?}");
            }
        }
    }

    #[test]
    fn huge_seeds_survive_the_json_roundtrip() {
        // seeds above 2^53 cannot live in an f64 JSON number; they must
        // travel as strings and come back bit-exact
        let mut spec = ScenarioSpec::builtin("smoke").unwrap();
        spec.seed = u64::MAX - 12345;
        let back = ScenarioSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back.seed, spec.seed);
    }

    #[test]
    fn json_text_parses_with_defaults() {
        let src = r#"{
            "name": "tiny", "devices": 64, "rounds": 3,
            "workload": "resnet34",
            "mix": {"pixel3": 1.0, "s10e": 1.0}
        }"#;
        let v = crate::util::json::parse(src).unwrap();
        let s = ScenarioSpec::from_json(&v).unwrap();
        assert_eq!(s.devices, 64);
        assert_eq!(s.workload, WorkloadName::Resnet34);
        assert_eq!(s.mix.len(), 2);
        // defaults filled in
        assert_eq!(s.clients_per_round, 50);
        assert!(s.daily_credit_j > 0.0);
    }

    #[test]
    fn rejects_bad_specs() {
        for src in [
            r#"{"devices": 10}"#,                                  // no name
            r#"{"name": "x", "workload": "gpt5"}"#,                // bad wl
            r#"{"name": "x", "mix": {"nokia3310": 1.0}}"#,         // bad dev
            r#"{"name": "x", "mix": {"pixel3": 0.0}}"#,            // zero mix
            r#"{"name": "x", "devices": 0}"#,                      // empty
            r#"{"name": "x", "rounds": "500"}"#,                   // typed
            r#"{"name": "x", "interference_p": true}"#,            // typed
            r#"{"name": "x", "seed": [1]}"#,                       // typed
            r#"{"name": "x", "seed": -3}"#,                        // range
            r#"{"name": "x", "seed": 1.5}"#,                       // range
            r#"{"name": "x", "interference_p": 1.5}"#,             // range
            r#"{"name": "x", "interference_slowdown": -2.0}"#,     // range
            r#"{"name": "x", "daily_credit_j": -1.0}"#,            // range
        ] {
            let v = crate::util::json::parse(src).unwrap();
            assert!(ScenarioSpec::from_json(&v).is_err(), "{src}");
        }
    }

    #[test]
    fn build_fleet_is_deterministic_and_mixed() {
        let spec = ScenarioSpec {
            devices: 500,
            trace_users: 2,
            ..ScenarioSpec::default()
        };
        let a = spec.build_fleet().unwrap();
        let b = spec.build_fleet().unwrap();
        assert_eq!(a.len(), 500);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.model, y.model);
            assert_eq!(x.seed, y.seed);
            assert_eq!(x.shift_s, y.shift_s);
        }
        // every model in the default mix shows up
        let mut seen = std::collections::HashSet::new();
        for d in &a {
            seen.insert(d.model);
        }
        assert_eq!(seen.len(), 5, "all five models represented");
        // trace assignment: 2 traces × 24 shifts cycle
        assert_eq!(a[0].shift_s, 0.0);
        assert_eq!(a[2].shift_s, 3600.0);
    }

    #[test]
    fn mix_weights_respected() {
        let spec = ScenarioSpec {
            devices: 2_000,
            mix: vec![(DeviceId::Pixel3, 3.0), (DeviceId::S10e, 1.0)],
            trace_users: 1,
            ..ScenarioSpec::default()
        };
        let fleet = spec.build_fleet().unwrap();
        let pixel = fleet
            .iter()
            .filter(|d| d.model() == DeviceId::Pixel3)
            .count();
        let frac = pixel as f64 / fleet.len() as f64;
        assert!(
            (0.70..0.80).contains(&frac),
            "pixel3 fraction {frac} vs expected 0.75"
        );
    }
}
