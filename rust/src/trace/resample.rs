//! Appendix A.2 resampling: PCHIP onto a uniform 10-minute grid, then
//! battery-state derivation from consecutive level deltas
//! (charging = +1, not-discharging = 0, discharging = −1).

use crate::util::pchip::{grid_cell, Pchip, PchipTable};

use super::greenhub::RawTrace;

pub const GRID_DT_S: f64 = 600.0; // 10 minutes

/// Android-style three-valued battery state.
pub type BatteryStateSeq = Vec<i8>;

/// A uniformly resampled trace.
#[derive(Clone, Debug)]
pub struct ResampledTrace {
    pub user_id: usize,
    pub start_s: f64,
    pub dt_s: f64,
    pub level: Vec<f64>,
    pub state: BatteryStateSeq,
}

impl ResampledTrace {
    pub fn duration_s(&self) -> f64 {
        self.dt_s * self.level.len().saturating_sub(1) as f64
    }

    fn idx(&self, t_s: f64) -> usize {
        if self.level.is_empty() {
            return 0;
        }
        grid_cell(self.start_s, self.dt_s, self.level.len(), t_s)
    }

    pub fn level_at(&self, t_s: f64) -> f64 {
        self.level[self.idx(t_s)]
    }

    /// Fused `(level, is_charging)` lookup: one grid-index computation
    /// serves both reads. This is the per-poll fast path the fleet
    /// kernel and the availability gate ride — `level_at` +
    /// `is_charging` would compute the same index twice.
    #[inline]
    pub fn sample(&self, t_s: f64) -> (f64, bool) {
        let i = self.idx(t_s);
        (self.level[i], self.state[i] > 0)
    }

    /// +1 charging, 0 not-discharging, −1 discharging at time `t_s`.
    pub fn state_at(&self, t_s: f64) -> i8 {
        self.state[self.idx(t_s)]
    }

    pub fn is_charging(&self, t_s: f64) -> bool {
        self.state_at(t_s) > 0
    }

    /// Batch twin of [`sample`](ResampledTrace::sample): one pass over
    /// `ts` writing fused `(level, charging)` reads into the caller's
    /// reusable buffers (cleared, then refilled — no steady-state
    /// allocation). Each lane is the same clamp + two indexed loads as
    /// the scalar path, elementwise bit-identical; the fleet kernel's
    /// availability sweep runs one call per distinct trace instead of
    /// one `sample` per device.
    pub fn sample_many(
        &self,
        ts: &[f64],
        levels: &mut Vec<f64>,
        charging: &mut Vec<bool>,
    ) {
        levels.clear();
        charging.clear();
        if self.level.is_empty() {
            return;
        }
        let (t0, dt, n) = (self.start_s, self.dt_s, self.level.len());
        for &t in ts {
            let i = grid_cell(t0, dt, n, t);
            levels.push(self.level[i]);
            charging.push(self.state[i] > 0);
        }
    }

    /// Wrap time around the trace (FL runs can outlast a 28-day trace).
    pub fn wrap(&self, t_s: f64) -> f64 {
        let d = self.duration_s().max(self.dt_s);
        self.start_s + (t_s - self.start_s).rem_euclid(d)
    }
}

/// Appendix A.2: PCHIP-resample `tr` to the 10-minute grid and derive
/// battery_state from level deltas.
pub fn resample_trace(tr: &RawTrace) -> crate::Result<ResampledTrace> {
    crate::ensure!(tr.t_s.len() >= 2, "trace too short to resample");
    // PCHIP needs strictly increasing x; drop duplicate timestamps
    let mut xs = Vec::with_capacity(tr.t_s.len());
    let mut ys = Vec::with_capacity(tr.level.len());
    for (t, l) in tr.t_s.iter().zip(&tr.level) {
        if xs.last().map_or(true, |&last| *t > last) {
            xs.push(*t);
            ys.push(*l);
        }
    }
    let interp = Pchip::new(xs.clone(), ys)
        .map_err(|e| crate::err!("pchip: {e}"))?;
    let start = xs[0];
    let end = xs[xs.len() - 1];
    let n = ((end - start) / GRID_DT_S).floor() as usize + 1;
    // one cursor-driven interpolation pass builds the uniform table; all
    // later per-call lookups are O(1) indexed loads on its values
    let mut level =
        PchipTable::build(&interp, start, GRID_DT_S, n).into_values();
    // PCHIP is monotone between knots but fp rounding can still step a
    // hair outside the physical range
    for l in &mut level {
        *l = l.clamp(0.0, 100.0);
    }

    // battery_state from the sign of consecutive deltas (A.2)
    let mut state = vec![0i8; n];
    for i in 1..n {
        let d = level[i] - level[i - 1];
        state[i] = if d > 1e-9 {
            1
        } else if d < -1e-9 {
            -1
        } else {
            0
        };
    }
    if n > 1 {
        state[0] = state[1];
    }
    Ok(ResampledTrace {
        user_id: tr.user_id,
        start_s: start,
        dt_s: GRID_DT_S,
        level,
        state,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::greenhub::TraceGenerator;

    #[test]
    fn grid_is_uniform_10min() {
        let tr = TraceGenerator::default().generate(1, 0);
        let rs = resample_trace(&tr).unwrap();
        assert_eq!(rs.dt_s, 600.0);
        assert!(rs.level.len() > 28 * 144, "≥ 28 days of 10-min samples");
    }

    #[test]
    fn levels_stay_in_range_no_overshoot() {
        // PCHIP monotonicity: resampled levels must stay within [0, 100]
        // even around steep charge knees
        let tr = TraceGenerator::default().generate(2, 1);
        let rs = resample_trace(&tr).unwrap();
        for &l in &rs.level {
            assert!((0.0..=100.0).contains(&l), "overshoot: {l}");
        }
    }

    #[test]
    fn state_matches_deltas() {
        let tr = RawTrace {
            user_id: 0,
            t_s: vec![0.0, 600.0, 1200.0, 1800.0, 2400.0],
            level: vec![50.0, 52.0, 52.0, 49.0, 48.0],
        };
        let rs = resample_trace(&tr).unwrap();
        assert_eq!(rs.state[1], 1, "rising level ⇒ charging");
        assert_eq!(rs.state[3], -1, "falling level ⇒ discharging");
    }

    #[test]
    fn charging_periods_detected_in_synthetic_traces() {
        let tr = TraceGenerator::default().generate(3, 2);
        let rs = resample_trace(&tr).unwrap();
        let charging =
            rs.state.iter().filter(|&&s| s > 0).count() as f64;
        let frac = charging / rs.state.len() as f64;
        // the battery fills within a few hours of plugging in, after
        // which the level is flat and A.2's delta rule reads
        // "not discharging" — so strictly-rising samples are only a few
        // hours/day (the paper's pipeline has the same artifact)
        assert!(
            frac > 0.02 && frac < 0.50,
            "charging fraction {frac} implausible"
        );
    }

    #[test]
    fn lookup_helpers() {
        let tr = RawTrace {
            user_id: 3,
            t_s: vec![0.0, 600.0, 1200.0],
            level: vec![10.0, 20.0, 30.0],
        };
        let rs = resample_trace(&tr).unwrap();
        assert_eq!(rs.level_at(0.0), 10.0);
        assert_eq!(rs.level_at(650.0), 20.0);
        assert!(rs.is_charging(650.0));
        // out-of-range clamps
        assert_eq!(rs.level_at(1e9), 30.0);
        // wrap
        let w = rs.wrap(1200.0 + 601.0);
        assert!(w >= 0.0 && w <= 1200.0);
    }

    #[test]
    fn fused_sample_matches_split_lookups() {
        let tr = TraceGenerator::default().generate(4, 3);
        let rs = resample_trace(&tr).unwrap();
        for i in 0..600 {
            let t = rs.start_s + i as f64 * 137.0;
            let (level, charging) = rs.sample(t);
            assert_eq!(level.to_bits(), rs.level_at(t).to_bits());
            assert_eq!(charging, rs.is_charging(t));
        }
    }

    #[test]
    fn sample_many_matches_scalar_sample_bitwise() {
        let rs = resample_trace(&TraceGenerator::default().generate(5, 7))
            .unwrap();
        // unsorted queries incl. both clamp ends and exact cell edges
        let mut ts: Vec<f64> = (0..500)
            .map(|i| rs.start_s + (i * 977 % 331) as f64 * 431.0 - 3600.0)
            .collect();
        ts.push(-1e12);
        ts.push(1e12);
        ts.push(rs.start_s);
        ts.push(rs.start_s + rs.duration_s());
        let mut levels = vec![0.0; 3]; // stale contents must be discarded
        let mut charging = vec![true; 3];
        rs.sample_many(&ts, &mut levels, &mut charging);
        assert_eq!(levels.len(), ts.len());
        assert_eq!(charging.len(), ts.len());
        for (k, &t) in ts.iter().enumerate() {
            let (l, c) = rs.sample(t);
            assert_eq!(levels[k].to_bits(), l.to_bits(), "t={t}");
            assert_eq!(charging[k], c, "t={t}");
        }
    }

    #[test]
    fn duplicate_timestamps_dropped() {
        let tr = RawTrace {
            user_id: 0,
            t_s: vec![0.0, 600.0, 600.0, 1200.0],
            level: vec![50.0, 51.0, 51.0, 52.0],
        };
        assert!(resample_trace(&tr).is_ok());
    }
}
