//! Synthetic GreenHub-style raw battery traces.
//!
//! Reproduces the statistical pathologies the paper's Appendix A.2
//! pipeline exists to clean up:
//! - irregular sampling (per-user base rate + jitter),
//! - missing stretches (phone off / app killed), occasionally > 6 h,
//! - diurnal structure: overnight charging, daytime discharge with
//!   usage bursts, occasional daytime top-ups,
//! - device-specific discharge rates and battery sizes.
//!
//! Levels are integer percent (what Android logs), timestamps seconds.

use crate::util::rng::Rng;

/// One user's raw (irregular) battery trace.
#[derive(Clone, Debug)]
pub struct RawTrace {
    pub user_id: usize,
    /// Sample timestamps, seconds from trace start, strictly increasing.
    pub t_s: Vec<f64>,
    /// Battery level 0–100 (integer-valued, stored as f64 for PCHIP).
    pub level: Vec<f64>,
}

impl RawTrace {
    pub fn duration_s(&self) -> f64 {
        if self.t_s.len() < 2 {
            0.0
        } else {
            self.t_s[self.t_s.len() - 1] - self.t_s[0]
        }
    }

    pub fn samples_per_day(&self) -> f64 {
        let d = self.duration_s();
        if d <= 0.0 {
            0.0
        } else {
            self.t_s.len() as f64 / (d / 86_400.0)
        }
    }

    pub fn max_gap_s(&self) -> f64 {
        self.t_s
            .windows(2)
            .map(|w| w[1] - w[0])
            .fold(0.0, f64::max)
    }

    pub fn gaps_longer_than(&self, secs: f64) -> usize {
        self.t_s.windows(2).filter(|w| w[1] - w[0] > secs).count()
    }
}

/// Generator of per-user traces.
pub struct TraceGenerator {
    pub days: usize,
    /// Mean sampling interval, seconds (GreenHub logs opportunistically;
    /// ~100+/day = every ~10 min average for "good" users).
    pub mean_interval_s: f64,
    /// Probability per day of a long (> 6 h) outage.
    pub p_long_gap_per_day: f64,
}

impl Default for TraceGenerator {
    fn default() -> Self {
        TraceGenerator {
            days: 35,
            mean_interval_s: 420.0,
            p_long_gap_per_day: 0.08,
        }
    }
}

impl TraceGenerator {
    /// Generate user `user_id`'s trace (deterministic per seed+user).
    pub fn generate(&self, seed: u64, user_id: usize) -> RawTrace {
        let mut rng =
            Rng::new(seed ^ (user_id as u64).wrapping_mul(0x2545_F491));
        // user habits
        let charge_start_h = rng.range(21.0, 24.5); // plug in between 9pm–0:30
        let charge_dur_h = rng.range(6.0, 9.5);
        let idle_drain_pct_h = rng.range(0.6, 1.6); // %/hour background
        let usage_extra_pct_h = rng.range(4.0, 10.0); // %/hour while using
        let usage_sessions_per_day = rng.range(4.0, 14.0);
        let charger_pct_h = rng.range(25.0, 45.0);
        let daytime_topup_p = rng.range(0.05, 0.35);

        let total_s = self.days as f64 * 86_400.0;
        let mut t = 0.0f64;
        let mut level = rng.range(40.0, 95.0);
        let mut ts = Vec::new();
        let mut lv = Vec::new();

        // simulate at 60 s resolution, record at irregular sample times
        let mut next_sample = rng.exponential(self.mean_interval_s);
        let mut gap_until = -1.0f64;
        let mut topup_until = -1.0f64;
        while t < total_s {
            let hour = (t / 3600.0) % 24.0;
            let day_frac = hour;
            // nightly charge window (wraps midnight)
            let in_night_charge = {
                let start = charge_start_h % 24.0;
                let end = (charge_start_h + charge_dur_h) % 24.0;
                if start < end {
                    day_frac >= start && day_frac < end
                } else {
                    day_frac >= start || day_frac < end
                }
            };
            // occasional daytime top-up
            if !in_night_charge
                && topup_until < t
                && rng.bool(daytime_topup_p / (24.0 * 60.0))
            {
                topup_until = t + rng.range(900.0, 3600.0);
            }
            let charging = in_night_charge || t < topup_until;

            // usage bursts
            let using = !charging
                && rng.bool(usage_sessions_per_day / (24.0 * 60.0) * 8.0);

            let dpct_min = if charging {
                charger_pct_h / 60.0
            } else {
                -(idle_drain_pct_h
                    + if using { usage_extra_pct_h } else { 0.0 })
                    / 60.0
            };
            level = (level + dpct_min).clamp(1.0, 100.0);

            // long outages
            if gap_until < t && rng.bool(self.p_long_gap_per_day / (24.0 * 60.0))
            {
                gap_until = t + rng.range(6.5 * 3600.0, 20.0 * 3600.0);
            }

            if t >= next_sample {
                if t > gap_until {
                    ts.push(t);
                    lv.push(level.floor());
                }
                next_sample = t + rng.exponential(self.mean_interval_s);
            }
            t += 60.0;
        }
        RawTrace {
            user_id,
            t_s: ts,
            level: lv,
        }
    }

    /// Generate a population of users.
    pub fn population(&self, seed: u64, n: usize) -> Vec<RawTrace> {
        (0..n).map(|u| self.generate(seed, u)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_deterministic_and_distinct() {
        let g = TraceGenerator::default();
        let a = g.generate(1, 0);
        let b = g.generate(1, 0);
        let c = g.generate(1, 1);
        assert_eq!(a.t_s, b.t_s);
        assert_eq!(a.level, b.level);
        assert_ne!(a.level, c.level);
    }

    #[test]
    fn timestamps_increasing_levels_valid() {
        let g = TraceGenerator::default();
        for u in 0..5 {
            let tr = g.generate(7, u);
            assert!(tr.t_s.len() > 1000, "too few samples: {}", tr.t_s.len());
            for w in tr.t_s.windows(2) {
                assert!(w[1] > w[0]);
            }
            for &l in &tr.level {
                assert!((0.0..=100.0).contains(&l));
                assert_eq!(l.fract(), 0.0, "levels must be integer percent");
            }
        }
    }

    #[test]
    fn exhibits_diurnal_charging() {
        // overnight the battery must regularly be higher than evening
        let g = TraceGenerator::default();
        let tr = g.generate(3, 2);
        // average level by hour of day
        let mut by_hour = vec![(0.0f64, 0usize); 24];
        for (t, l) in tr.t_s.iter().zip(&tr.level) {
            let h = ((t / 3600.0) % 24.0) as usize;
            by_hour[h].0 += l;
            by_hour[h].1 += 1;
        }
        let avg = |h: usize| by_hour[h].0 / by_hour[h].1.max(1) as f64;
        let morning = avg(7).max(avg(8));
        let evening = avg(19).min(avg(20));
        assert!(
            morning > evening + 5.0,
            "no diurnal pattern: morning {morning} evening {evening}"
        );
    }

    #[test]
    fn has_irregular_sampling_and_gaps() {
        let g = TraceGenerator::default();
        let tr = g.generate(5, 4);
        let gaps: Vec<f64> = tr.t_s.windows(2).map(|w| w[1] - w[0]).collect();
        let mean = crate::util::stats::mean(&gaps);
        let std = crate::util::stats::std(&gaps);
        assert!(std > 0.3 * mean, "sampling suspiciously regular");
    }
}
