//! GreenHub-style battery traces and the paper's Appendix-A pipeline.
//!
//! The real GreenHub dataset (50M samples / 300k devices) is proprietary
//! to download at this scale; `greenhub.rs` synthesizes traces with the
//! same pathologies (irregular sampling, gaps, diurnal charging), and the
//! rest of the pipeline is the paper's own preprocessing implemented for
//! real: A.2 quality filters, PCHIP resampling to a 10-minute grid,
//! battery-state derivation, and the 23×1-hour shift augmentation that
//! yields 2400 clients.

pub mod augment;
pub mod filter;
pub mod greenhub;
pub mod resample;

pub use augment::augment_shifts;
pub use filter::{passes_quality_filters, FilterStats};
pub use greenhub::{RawTrace, TraceGenerator};
pub use resample::{resample_trace, BatteryStateSeq, ResampledTrace};

/// Synthesize raw traces until `want` pass the A.2 quality filters
/// (bounded by `max_attempts` synthesized users), resampled to the
/// 10-minute grid — the shared front half of the FL and fleet
/// pipelines. May return fewer than `want` if attempts run out.
pub fn synthesize_quality_pool(
    seed: u64,
    want: usize,
    max_attempts: usize,
) -> crate::Result<Vec<ResampledTrace>> {
    let gen = TraceGenerator::default();
    let mut pool = Vec::new();
    let mut uid = 0usize;
    while pool.len() < want && uid < max_attempts {
        let tr = gen.generate(seed, uid);
        uid += 1;
        if passes_quality_filters(&tr) {
            pool.push(resample_trace(&tr)?);
        }
    }
    Ok(pool)
}

#[cfg(test)]
mod tests {
    #[test]
    fn quality_pool_respects_want_and_cap() {
        let pool = super::synthesize_quality_pool(42, 3, 60).unwrap();
        assert_eq!(pool.len(), 3, "generator should fill a small pool");
        let none = super::synthesize_quality_pool(42, 3, 0).unwrap();
        assert!(none.is_empty());
    }
}
