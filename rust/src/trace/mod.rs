//! GreenHub-style battery traces and the paper's Appendix-A pipeline.
//!
//! The real GreenHub dataset (50M samples / 300k devices) is proprietary
//! to download at this scale; `greenhub.rs` synthesizes traces with the
//! same pathologies (irregular sampling, gaps, diurnal charging), and the
//! rest of the pipeline is the paper's own preprocessing implemented for
//! real: A.2 quality filters, PCHIP resampling to a 10-minute grid,
//! battery-state derivation, and the 23×1-hour shift augmentation that
//! yields 2400 clients.

pub mod augment;
pub mod filter;
pub mod greenhub;
pub mod resample;

pub use augment::augment_shifts;
pub use filter::{passes_quality_filters, FilterStats};
pub use greenhub::{RawTrace, TraceGenerator};
pub use resample::{resample_trace, BatteryStateSeq, ResampledTrace};
