//! Appendix A.2 trace-quality filters, verbatim:
//!
//! 1. sampling period ≥ 28 days;
//! 2. overall sampling frequency ≥ 5/432 Hz (100 samples/day average);
//! 3. max gap between adjacent samples ≤ 24 h;
//! 4. at most 15 gaps longer than 6 h.

use super::greenhub::RawTrace;

pub const MIN_PERIOD_S: f64 = 28.0 * 86_400.0;
pub const MIN_SAMPLES_PER_DAY: f64 = 100.0; // == 5/432 Hz
pub const MAX_GAP_S: f64 = 24.0 * 3600.0;
pub const MAX_LONG_GAPS: usize = 15;
pub const LONG_GAP_S: f64 = 6.0 * 3600.0;

#[derive(Clone, Copy, Debug, Default)]
pub struct FilterStats {
    pub total: usize,
    pub pass: usize,
    pub fail_period: usize,
    pub fail_frequency: usize,
    pub fail_max_gap: usize,
    pub fail_long_gaps: usize,
}

pub fn passes_quality_filters(tr: &RawTrace) -> bool {
    tr.duration_s() >= MIN_PERIOD_S
        && tr.samples_per_day() >= MIN_SAMPLES_PER_DAY
        && tr.max_gap_s() <= MAX_GAP_S
        && tr.gaps_longer_than(LONG_GAP_S) <= MAX_LONG_GAPS
}

/// Filter a population, collecting per-criterion failure counts.
pub fn select_quality_traces(
    traces: Vec<RawTrace>,
) -> (Vec<RawTrace>, FilterStats) {
    let mut stats = FilterStats {
        total: traces.len(),
        ..Default::default()
    };
    let mut keep = Vec::new();
    for tr in traces {
        if tr.duration_s() < MIN_PERIOD_S {
            stats.fail_period += 1;
        } else if tr.samples_per_day() < MIN_SAMPLES_PER_DAY {
            stats.fail_frequency += 1;
        } else if tr.max_gap_s() > MAX_GAP_S {
            stats.fail_max_gap += 1;
        } else if tr.gaps_longer_than(LONG_GAP_S) > MAX_LONG_GAPS {
            stats.fail_long_gaps += 1;
        } else {
            stats.pass += 1;
            keep.push(tr);
        }
    }
    (keep, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::greenhub::TraceGenerator;

    fn trace(t_s: Vec<f64>) -> RawTrace {
        let level = vec![50.0; t_s.len()];
        RawTrace {
            user_id: 0,
            t_s,
            level,
        }
    }

    #[test]
    fn rejects_short_period() {
        let t: Vec<f64> = (0..10_000).map(|i| i as f64 * 60.0).collect();
        assert!(!passes_quality_filters(&trace(t))); // ~7 days
    }

    #[test]
    fn rejects_sparse_sampling() {
        // 29 days but only ~48 samples/day
        let t: Vec<f64> = (0..(29 * 48)).map(|i| i as f64 * 1800.0).collect();
        assert!(!passes_quality_filters(&trace(t)));
    }

    #[test]
    fn rejects_giant_gap() {
        let mut t: Vec<f64> = (0..(30 * 144)).map(|i| i as f64 * 600.0).collect();
        // inject a 25 h hole
        for v in t.iter_mut().skip(2000) {
            *v += 25.0 * 3600.0;
        }
        assert!(!passes_quality_filters(&trace(t)));
    }

    #[test]
    fn rejects_many_long_gaps() {
        let mut t = Vec::new();
        let mut now = 0.0;
        for day in 0..30 {
            for i in 0..130 {
                t.push(now + i as f64 * 300.0);
            }
            now += 86_400.0;
            let _ = day;
            // 130×5min ≈ 10.8h of samples, then a 13h gap → 30 long gaps
        }
        let tr = trace(t);
        assert!(tr.gaps_longer_than(LONG_GAP_S) > MAX_LONG_GAPS);
        assert!(!passes_quality_filters(&tr));
    }

    #[test]
    fn accepts_clean_dense_trace() {
        let t: Vec<f64> = (0..(30 * 150)).map(|i| i as f64 * 576.0).collect();
        assert!(passes_quality_filters(&trace(t)));
    }

    #[test]
    fn generator_population_mostly_passes() {
        // the synthetic generator (35 days, ~7 min interval, few outages)
        // should produce mostly usable traces — like GreenHub's good users
        let g = TraceGenerator::default();
        let (keep, stats) = select_quality_traces(g.population(42, 30));
        assert_eq!(stats.total, 30);
        assert!(
            keep.len() >= 15,
            "only {}/30 passed: {stats:?}",
            keep.len()
        );
    }
}
