//! Appendix A.2 temporal augmentation: "we select sub-intervals of 100
//! traces shifted by 1 hour, 23 times. This results in 2400 clients
//! spread across the planet." — i.e. each quality trace becomes 24
//! clients (the original + 23 shifted copies), emulating users in every
//! timezone.

use super::resample::ResampledTrace;

/// Shift a resampled trace's timeline by `shift_s` (rotating the level
/// and state arrays — the diurnal structure moves with it).
pub fn shift_trace(tr: &ResampledTrace, shift_s: f64, new_id: usize) -> ResampledTrace {
    let n = tr.level.len();
    let k = ((shift_s / tr.dt_s).round() as usize) % n.max(1);
    let rot = |v: &Vec<f64>| -> Vec<f64> {
        let mut out = Vec::with_capacity(n);
        out.extend_from_slice(&v[k..]);
        out.extend_from_slice(&v[..k]);
        out
    };
    let mut state = Vec::with_capacity(n);
    state.extend_from_slice(&tr.state[k..]);
    state.extend_from_slice(&tr.state[..k]);
    ResampledTrace {
        user_id: new_id,
        start_s: tr.start_s,
        dt_s: tr.dt_s,
        level: rot(&tr.level),
        state,
    }
}

/// The full augmentation: every input trace × 24 hourly shifts.
pub fn augment_shifts(traces: &[ResampledTrace]) -> Vec<ResampledTrace> {
    let mut out = Vec::with_capacity(traces.len() * 24);
    for tr in traces {
        for shift in 0..24 {
            out.push(shift_trace(
                tr,
                shift as f64 * 3600.0,
                out.len(),
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::greenhub::TraceGenerator;
    use crate::trace::resample::resample_trace;

    #[test]
    fn hundred_traces_become_2400_clients() {
        // cheap structural check with 3 traces × 24 = 72
        let g = TraceGenerator::default();
        let rs: Vec<_> = (0..3)
            .map(|u| resample_trace(&g.generate(1, u)).unwrap())
            .collect();
        let aug = augment_shifts(&rs);
        assert_eq!(aug.len(), 72);
        // ids unique
        let mut ids: Vec<usize> = aug.iter().map(|t| t.user_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 72);
    }

    #[test]
    fn shift_rotates_not_mutates() {
        let g = TraceGenerator::default();
        let rs = resample_trace(&g.generate(2, 0)).unwrap();
        let sh = shift_trace(&rs, 6.0 * 3600.0, 99);
        assert_eq!(sh.level.len(), rs.level.len());
        // same multiset of levels
        let mut a = rs.level.clone();
        let mut b = sh.level.clone();
        a.sort_by(|x, y| x.partial_cmp(y).unwrap());
        b.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert_eq!(a, b);
        // but a different timeline
        assert_ne!(rs.level[..100], sh.level[..100]);
        // rotation by 6h = 36 grid steps
        assert_eq!(sh.level[0], rs.level[36]);
    }

    #[test]
    fn zero_shift_is_identity() {
        let g = TraceGenerator::default();
        let rs = resample_trace(&g.generate(3, 0)).unwrap();
        let sh = shift_trace(&rs, 0.0, 1);
        assert_eq!(sh.level, rs.level);
        assert_eq!(sh.state, rs.state);
    }
}
