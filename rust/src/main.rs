//! Swan CLI entrypoint (subcommands wired in cli::run).
fn main() {
    if let Err(e) = swan::cli::run_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
