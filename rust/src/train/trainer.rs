//! Local trainer: composes a scheduling policy (Swan engine or greedy
//! baseline) with the PJRT executor and a client's data partition.
//!
//! Every local step does two things at once:
//! - **numerics**: one real SGD step through the AOT-compiled HLO;
//! - **systems**: the same step's latency/energy on the simulated phone
//!   under the policy's current execution choice.
//!
//! The FL harness consumes both: losses drive the accuracy curves,
//! simulated time drives time-to-accuracy, battery drain drives the
//! energy-loan availability model.

use crate::baseline::GreedyBaseline;
use crate::runtime::{ModelExecutor, TrainState};
use crate::sim::SimPhone;
use crate::swan::SwanEngine;
use crate::train::data::{Partition, SyntheticDataset};
use crate::Result;

/// Which scheduling policy drives the device.
pub enum Policy {
    Swan(SwanEngine),
    Greedy(GreedyBaseline),
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Swan(_) => "swan",
            Policy::Greedy(_) => "baseline",
        }
    }
}

/// Result of a burst of local steps.
#[derive(Clone, Debug, Default)]
pub struct LocalRunReport {
    pub losses: Vec<f32>,
    pub sim_seconds: f64,
    pub energy_j: f64,
    pub steps: usize,
}

/// One device's trainer.
pub struct LocalTrainer<'e> {
    pub executor: &'e ModelExecutor<'e>,
    pub dataset: SyntheticDataset,
    pub partition: Partition,
    step_counter: usize,
}

impl<'e> LocalTrainer<'e> {
    pub fn new(
        executor: &'e ModelExecutor<'e>,
        dataset: SyntheticDataset,
        partition: Partition,
    ) -> Self {
        LocalTrainer {
            executor,
            dataset,
            partition,
            step_counter: 0,
        }
    }

    /// Run `steps` local SGD steps under `policy` on `phone`.
    pub fn run_local_steps(
        &mut self,
        policy: &mut Policy,
        phone: &mut SimPhone,
        state: &mut TrainState,
        steps: usize,
    ) -> Result<LocalRunReport> {
        let mut report = LocalRunReport::default();
        let t0 = phone.clock.now();
        let e0 = phone.truth_train_energy_j;
        for _ in 0..steps {
            let (x, y) = self.dataset.batch(
                &self.partition,
                self.step_counter,
                self.executor.meta.batch,
            );
            self.step_counter += 1;
            let mut loss_out: Result<f32> = Ok(f32::NAN);
            match policy {
                Policy::Swan(engine) => {
                    engine.run_local_step(phone, || {
                        loss_out = self.executor.train_step(state, &x, &y);
                    });
                }
                Policy::Greedy(baseline) => {
                    baseline.run_local_step(phone, || {
                        loss_out = self.executor.train_step(state, &x, &y);
                    });
                }
            }
            report.losses.push(loss_out?);
            report.steps += 1;
        }
        report.sim_seconds = phone.clock.now() - t0;
        report.energy_j = phone.truth_train_energy_j - e0;
        Ok(report)
    }
}

// Integration coverage for this module lives in rust/tests/ (it needs
// compiled artifacts and a PJRT client).
