//! Training metrics: loss curves and evaluation results.

/// (simulated time, value) series — the x-axis of Figs 5a/6a/7a is
/// simulated wall-clock, not rounds.
#[derive(Clone, Debug, Default)]
pub struct LossCurve {
    pub points: Vec<(f64, f64)>,
}

impl LossCurve {
    pub fn push(&mut self, t_s: f64, value: f64) {
        self.points.push((t_s, value));
    }

    pub fn last(&self) -> Option<(f64, f64)> {
        self.points.last().copied()
    }

    /// First simulated time at which `value` crosses `target` (downward
    /// for loss, upward for accuracy via `upward`).
    pub fn time_to(&self, target: f64, upward: bool) -> Option<f64> {
        self.points
            .iter()
            .find(|(_, v)| if upward { *v >= target } else { *v <= target })
            .map(|(t, _)| *t)
    }

    /// Best value reached.
    pub fn best(&self, upward: bool) -> Option<f64> {
        self.points
            .iter()
            .map(|(_, v)| *v)
            .fold(None, |acc, v| match acc {
                None => Some(v),
                Some(a) => Some(if upward { a.max(v) } else { a.min(v) }),
            })
    }

    pub fn to_csv(&self, value_name: &str) -> String {
        let mut s = format!("t_s,{value_name}\n");
        for (t, v) in &self.points {
            s.push_str(&format!("{t},{v}\n"));
        }
        s
    }
}

/// Aggregate evaluation over several batches.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalResult {
    pub loss: f64,
    pub accuracy: f64,
    pub n: usize,
}

impl EvalResult {
    pub fn from_batches(batches: &[(f32, f32, usize)]) -> EvalResult {
        let n: usize = batches.iter().map(|b| b.2).sum();
        if n == 0 {
            return EvalResult::default();
        }
        let loss: f64 = batches
            .iter()
            .map(|(l, _, bn)| *l as f64 * *bn as f64)
            .sum::<f64>()
            / n as f64;
        let correct: f64 =
            batches.iter().map(|(_, c, _)| *c as f64).sum();
        EvalResult {
            loss,
            accuracy: correct / n as f64,
            n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_to_crossing() {
        let mut c = LossCurve::default();
        c.push(0.0, 4.0);
        c.push(10.0, 2.0);
        c.push(20.0, 1.0);
        assert_eq!(c.time_to(2.5, false), Some(10.0));
        assert_eq!(c.time_to(0.5, false), None);
        assert_eq!(c.best(false), Some(1.0));
    }

    #[test]
    fn accuracy_crossing_upward() {
        let mut c = LossCurve::default();
        c.push(0.0, 0.1);
        c.push(5.0, 0.4);
        c.push(9.0, 0.6);
        assert_eq!(c.time_to(0.5, true), Some(9.0));
        assert_eq!(c.best(true), Some(0.6));
    }

    #[test]
    fn eval_result_aggregates() {
        let r = EvalResult::from_batches(&[(2.0, 8.0, 16), (4.0, 4.0, 16)]);
        assert!((r.loss - 3.0).abs() < 1e-9);
        assert!((r.accuracy - 12.0 / 32.0).abs() < 1e-9);
        assert_eq!(r.n, 32);
    }

    #[test]
    fn empty_eval_safe() {
        let r = EvalResult::from_batches(&[]);
        assert_eq!(r.n, 0);
        assert_eq!(r.accuracy, 0.0);
    }

    #[test]
    fn csv_export() {
        let mut c = LossCurve::default();
        c.push(1.0, 2.0);
        let csv = c.to_csv("loss");
        assert!(csv.starts_with("t_s,loss\n"));
        assert!(csv.contains("1,2"));
    }
}
