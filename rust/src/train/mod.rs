//! Local training: synthetic federated datasets, metrics, and the
//! trainer that composes the Swan engine (systems) with the PJRT
//! executor (numerics).

pub mod data;
pub mod metrics;
pub mod softmax;
pub mod trainer;

pub use data::{Partition, SyntheticDataset};
pub use metrics::{EvalResult, LossCurve};
pub use softmax::{ExecutorSgd, LocalSgd, SoftmaxProbe};
pub use trainer::LocalTrainer;
