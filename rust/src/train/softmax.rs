//! Pure-Rust local-SGD backends for the unified FL engine.
//!
//! [`LocalSgd`] is the numerics contract `fl::engine` trains through.
//! The model is one flat `Vec<f32>`, so FedAvg aggregation, wire
//! transport (`serve::wire` carries raw f32 bits) and digest folding
//! are backend-agnostic. Two backends:
//!
//! - [`SoftmaxProbe`] — softmax regression over a fixed random
//!   projection of the synthetic dataset. Zero-dependency, so it runs
//!   in CI (no PJRT plugin), and fully deterministic: the same
//!   (model, partition, step list) always yields bit-identical
//!   updates, which is what the serve-vs-oracle parity gates pin.
//!   The class templates stay linearly separable-ish in the projected
//!   space, so the probe has a real learning signal and
//!   time-to-accuracy is meaningful, if modest.
//! - [`ExecutorSgd`] — the PJRT executor from `runtime`, flattened
//!   leaf-major into the flat-model contract. FedAvg is element-wise,
//!   so aggregating the flattened vector is bit-identical to
//!   aggregating per leaf.

use crate::runtime::ModelExecutor;
use crate::util::rng::Rng;

use super::data::{Partition, SyntheticDataset};
use super::metrics::EvalResult;

/// Projected feature count for [`SoftmaxProbe`] (plus one bias term).
pub const PROBE_FEATURES: usize = 16;

/// Local-SGD batch size for [`SoftmaxProbe`] — matches the
/// `epoch_steps` batch the availability model assumes.
pub const PROBE_BATCH: usize = 16;

const EVAL_BATCH: usize = 64;
const LR: f32 = 0.5;

/// One client's worth of local training, against a flat f32 model.
///
/// `local_update` must be a pure function of `(global, part, steps)` —
/// the engine replays it from several wirings (oracle, in-process
/// serve, TCP serve) and requires bit-identical results.
pub trait LocalSgd {
    /// Flat model dimension.
    fn dim(&self) -> usize;

    /// Deterministic initial model.
    fn init_global(&self, seed: u64) -> Vec<f32>;

    /// Run local SGD from `global` over the given batch-step indices
    /// (already shuffled by the engine) and return the updated model.
    fn local_update(
        &self,
        global: &[f32],
        part: &Partition,
        steps: &[usize],
    ) -> crate::Result<Vec<f32>>;

    /// Held-out evaluation of `global` over `batches` eval batches.
    fn eval(&self, global: &[f32], batches: usize) -> crate::Result<EvalResult>;
}

/// Softmax-regression probe over a fixed random projection.
///
/// Features: `PROBE_FEATURES` random-Gaussian projections of the raw
/// sample (rows scaled by `1/sqrt(numel)` so features are unit-scale),
/// plus a constant bias input. Model: `num_classes × (PROBE_FEATURES+1)`
/// weights, row-major by class.
#[derive(Clone, Debug)]
pub struct SoftmaxProbe {
    dataset: SyntheticDataset,
    /// `[PROBE_FEATURES][numel]` projection, row-major.
    proj: Vec<f32>,
}

const D: usize = PROBE_FEATURES + 1;

impl SoftmaxProbe {
    pub fn new(dataset: SyntheticDataset) -> Self {
        let numel = dataset.sample_numel();
        let mut rng = Rng::new(dataset.seed ^ 0x50F7_AB0E);
        let scale = 1.0 / (numel as f64).sqrt();
        let proj = (0..PROBE_FEATURES * numel)
            .map(|_| (rng.normal() * scale) as f32)
            .collect();
        SoftmaxProbe { dataset, proj }
    }

    pub fn dataset(&self) -> &SyntheticDataset {
        &self.dataset
    }

    /// Project a flattened batch into `[batch][D]` feature rows.
    fn features(&self, x: &[f32], batch: usize, out: &mut [f32]) {
        let numel = self.dataset.sample_numel();
        for b in 0..batch {
            let sample = &x[b * numel..(b + 1) * numel];
            let row_out = &mut out[b * D..(b + 1) * D];
            for (f, slot) in row_out[..PROBE_FEATURES].iter_mut().enumerate() {
                let row = &self.proj[f * numel..(f + 1) * numel];
                let mut acc = 0.0f32;
                for (p, v) in row.iter().zip(sample) {
                    acc += p * v;
                }
                *slot = acc;
            }
            row_out[PROBE_FEATURES] = 1.0;
        }
    }

    /// Class probabilities for one feature row.
    fn probs(&self, w: &[f32], feat: &[f32], out: &mut [f32]) {
        for (k, z) in out.iter_mut().enumerate() {
            let row = &w[k * D..(k + 1) * D];
            let mut acc = 0.0f32;
            for (a, b) in row.iter().zip(feat) {
                acc += a * b;
            }
            *z = acc;
        }
        let m = out.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let mut sum = 0.0f32;
        for v in out.iter_mut() {
            *v = (*v - m).exp();
            sum += *v;
        }
        for v in out.iter_mut() {
            *v /= sum;
        }
    }
}

impl LocalSgd for SoftmaxProbe {
    fn dim(&self) -> usize {
        self.dataset.num_classes * D
    }

    fn init_global(&self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..self.dim())
            .map(|_| (rng.normal() * 0.01) as f32)
            .collect()
    }

    fn local_update(
        &self,
        global: &[f32],
        part: &Partition,
        steps: &[usize],
    ) -> crate::Result<Vec<f32>> {
        crate::ensure!(
            global.len() == self.dim(),
            "model dim mismatch: got {}, want {}",
            global.len(),
            self.dim()
        );
        let classes = self.dataset.num_classes;
        let mut w = global.to_vec();
        let mut feats = vec![0.0f32; PROBE_BATCH * D];
        let mut p = vec![0.0f32; classes];
        let mut grad = vec![0.0f32; classes * D];
        for &step in steps {
            let (x, y) = self.dataset.batch(part, step, PROBE_BATCH);
            self.features(&x, PROBE_BATCH, &mut feats);
            grad.iter_mut().for_each(|g| *g = 0.0);
            for b in 0..PROBE_BATCH {
                let feat = &feats[b * D..(b + 1) * D];
                self.probs(&w, feat, &mut p);
                let label = y[b] as usize;
                for k in 0..classes {
                    let err = p[k] - if k == label { 1.0 } else { 0.0 };
                    let grow = &mut grad[k * D..(k + 1) * D];
                    for (g, f) in grow.iter_mut().zip(feat) {
                        *g += err * f;
                    }
                }
            }
            let scale = LR / PROBE_BATCH as f32;
            for (wv, g) in w.iter_mut().zip(&grad) {
                *wv -= scale * g;
            }
        }
        Ok(w)
    }

    fn eval(&self, global: &[f32], batches: usize) -> crate::Result<EvalResult> {
        crate::ensure!(
            global.len() == self.dim(),
            "model dim mismatch: got {}, want {}",
            global.len(),
            self.dim()
        );
        let classes = self.dataset.num_classes;
        let mut feats = vec![0.0f32; EVAL_BATCH * D];
        let mut p = vec![0.0f32; classes];
        let mut agg = Vec::with_capacity(batches);
        for b in 0..batches {
            let (x, y) = self.dataset.eval_batch(b, EVAL_BATCH);
            self.features(&x, EVAL_BATCH, &mut feats);
            let mut loss = 0.0f32;
            let mut correct = 0.0f32;
            for (s, &label) in y.iter().enumerate() {
                let feat = &feats[s * D..(s + 1) * D];
                self.probs(global, feat, &mut p);
                let label = label as usize;
                loss -= p[label].max(1e-12).ln();
                let argmax = p
                    .iter()
                    .enumerate()
                    .fold((0usize, f32::NEG_INFINITY), |best, (k, &v)| {
                        if v > best.1 {
                            (k, v)
                        } else {
                            best
                        }
                    })
                    .0;
                if argmax == label {
                    correct += 1.0;
                }
            }
            agg.push((loss / EVAL_BATCH as f32, correct, EVAL_BATCH));
        }
        Ok(EvalResult::from_batches(&agg))
    }
}

/// PJRT-executor adapter: flattens the executor's leaf-major params
/// into the engine's flat-model contract.
pub struct ExecutorSgd<'e, 'c> {
    exec: &'e ModelExecutor<'c>,
    dataset: SyntheticDataset,
    /// Per-leaf element counts, in metadata order.
    leaf_lens: Vec<usize>,
}

impl<'e, 'c> ExecutorSgd<'e, 'c> {
    pub fn new(exec: &'e ModelExecutor<'c>, dataset: SyntheticDataset) -> Self {
        let leaf_lens =
            exec.meta.params.iter().map(|s| s.numel()).collect();
        ExecutorSgd {
            exec,
            dataset,
            leaf_lens,
        }
    }

    fn unflatten(&self, flat: &[f32]) -> crate::Result<Vec<Vec<f32>>> {
        crate::ensure!(
            flat.len() == self.dim(),
            "model dim mismatch: got {}, want {}",
            flat.len(),
            self.dim()
        );
        let mut out = Vec::with_capacity(self.leaf_lens.len());
        let mut off = 0;
        for &n in &self.leaf_lens {
            out.push(flat[off..off + n].to_vec());
            off += n;
        }
        Ok(out)
    }
}

fn flatten(leaves: Vec<Vec<f32>>) -> Vec<f32> {
    let mut out = Vec::with_capacity(leaves.iter().map(Vec::len).sum());
    for leaf in leaves {
        out.extend(leaf);
    }
    out
}

impl LocalSgd for ExecutorSgd<'_, '_> {
    fn dim(&self) -> usize {
        self.leaf_lens.iter().sum()
    }

    fn init_global(&self, seed: u64) -> Vec<f32> {
        flatten(self.exec.init_host_params(seed))
    }

    fn local_update(
        &self,
        global: &[f32],
        part: &Partition,
        steps: &[usize],
    ) -> crate::Result<Vec<f32>> {
        let host = self.unflatten(global)?;
        let mut state = self.exec.state_from_host(&host)?;
        for &step in steps {
            let (x, y) =
                self.dataset.batch(part, step, self.exec.meta.batch);
            self.exec.train_step(&mut state, &x, &y)?;
        }
        Ok(flatten(self.exec.state_to_host(&state)?))
    }

    fn eval(&self, global: &[f32], batches: usize) -> crate::Result<EvalResult> {
        let host = self.unflatten(global)?;
        let state = self.exec.state_from_host(&host)?;
        let mut agg = Vec::with_capacity(batches);
        for b in 0..batches {
            let (x, y) =
                self.dataset.eval_batch(b, self.exec.meta.batch);
            let (loss, correct) = self.exec.eval_step(&state, &x, &y)?;
            agg.push((loss, correct, self.exec.meta.batch));
        }
        Ok(EvalResult::from_batches(&agg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::server::fedavg;

    #[test]
    fn probe_dim_matches_classes() {
        let probe = SoftmaxProbe::new(SyntheticDataset::speech(1));
        assert_eq!(probe.dim(), 35 * D);
        let probe = SoftmaxProbe::new(SyntheticDataset::vision(1));
        assert_eq!(probe.dim(), 64 * D);
    }

    #[test]
    fn local_update_is_bit_deterministic() {
        let probe = SoftmaxProbe::new(SyntheticDataset::speech(7));
        let part = probe.dataset().partition(3);
        let g = probe.init_global(42);
        let steps = [4usize, 1, 9];
        let a = probe.local_update(&g, &part, &steps).unwrap();
        let b = probe.local_update(&g, &part, &steps).unwrap();
        let bits = |v: &[f32]| -> Vec<u32> {
            v.iter().map(|x| x.to_bits()).collect()
        };
        assert_eq!(bits(&a), bits(&b));
        // Step order matters: a different shuffle is a different model.
        let c = probe.local_update(&g, &part, &[9, 1, 4]).unwrap();
        assert_ne!(bits(&a), bits(&c));
    }

    #[test]
    fn update_rejects_wrong_dim() {
        let probe = SoftmaxProbe::new(SyntheticDataset::speech(7));
        let part = probe.dataset().partition(0);
        assert!(probe.local_update(&[0.0; 3], &part, &[0]).is_err());
        assert!(probe.eval(&[0.0; 3], 1).is_err());
    }

    #[test]
    fn probe_learns_above_chance() {
        let probe = SoftmaxProbe::new(SyntheticDataset::speech(11));
        let mut global = probe.init_global(42);
        let e0 = probe.eval(&global, 4).unwrap();
        for round in 0..5 {
            let mut updates = Vec::new();
            for c in 0..8usize {
                let part = probe.dataset().partition(c);
                let steps: Vec<usize> =
                    (round * 5..round * 5 + 5).collect();
                let w =
                    probe.local_update(&global, &part, &steps).unwrap();
                updates.push((vec![w], part.n_samples as f64));
            }
            global = fedavg(&updates)
                .unwrap()
                .into_iter()
                .next()
                .unwrap();
        }
        let e1 = probe.eval(&global, 4).unwrap();
        assert!(
            e1.loss < e0.loss,
            "loss did not improve: {} -> {}",
            e0.loss,
            e1.loss
        );
        let chance = 1.0 / 35.0;
        assert!(
            e1.accuracy > 2.0 * chance,
            "accuracy {} not above chance {}",
            e1.accuracy,
            chance
        );
    }
}
