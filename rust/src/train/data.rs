//! Synthetic federated datasets (DESIGN.md substitution for Google
//! Speech / OpenImage).
//!
//! Class-conditional Gaussian data: every class has a fixed random
//! template tensor; a sample is `template + noise`. That makes the
//! learning problem real (models must separate 35/64 classes in input
//! space) while trivially partitionable at any client count.
//!
//! Non-IID structure follows the FL literature (and FedScale's
//! label-skew reality): each client's label distribution is a draw from
//! a symmetric Dirichlet(α); small α ⇒ clients see few classes.
//! Everything is generated deterministically from (dataset seed,
//! client id, step) so no tensors are stored — 2400 clients cost nothing.

use crate::util::rng::Rng;

/// Per-client view of the dataset.
#[derive(Clone, Debug)]
pub struct Partition {
    pub client_id: usize,
    /// Client's label distribution (Dirichlet draw).
    pub label_probs: Vec<f64>,
    /// Samples this client holds (drives FL weighting + local steps).
    pub n_samples: usize,
}

/// Deterministic synthetic classification dataset.
#[derive(Clone, Debug)]
pub struct SyntheticDataset {
    pub seed: u64,
    pub num_classes: usize,
    /// Per-sample tensor shape (no batch), e.g. [32, 32, 3].
    pub sample_shape: Vec<usize>,
    /// Input noise level relative to the template (higher = harder).
    pub noise: f32,
    /// Dirichlet concentration for client label skew.
    pub alpha: f64,
}

impl SyntheticDataset {
    pub fn speech(seed: u64) -> Self {
        // Google-Speech tier: 35 classes, 32×32×1 spectrogram-like
        SyntheticDataset {
            seed,
            num_classes: 35,
            sample_shape: vec![32, 32, 1],
            noise: 1.0,
            alpha: 0.5,
        }
    }

    pub fn vision(seed: u64) -> Self {
        // OpenImage tier: 64 classes, 32×32×3 image-like
        SyntheticDataset {
            seed,
            num_classes: 64,
            sample_shape: vec![32, 32, 3],
            noise: 1.0,
            alpha: 0.3,
        }
    }

    pub fn sample_numel(&self) -> usize {
        self.sample_shape.iter().product()
    }

    /// The fixed class template (unit-scale Gaussian from a class seed).
    fn template(&self, class: usize, out: &mut [f32]) {
        let mut rng = Rng::new(
            self.seed ^ 0xC1A5_5EED ^ (class as u64).wrapping_mul(0x9E37),
        );
        for v in out.iter_mut() {
            *v = rng.normal() as f32;
        }
    }

    /// Client partition (label skew + sample count).
    pub fn partition(&self, client_id: usize) -> Partition {
        let mut rng =
            Rng::new(self.seed ^ (client_id as u64).wrapping_mul(0x5851_F42D));
        let label_probs = rng.dirichlet(self.alpha, self.num_classes);
        // FedScale-like long-tailed sample counts: log-normal-ish 40–600
        let n_samples =
            (40.0 * (1.0 + rng.exponential(3.0)).min(15.0)) as usize;
        Partition {
            client_id,
            label_probs,
            n_samples,
        }
    }

    /// Generate one batch for (client, step). `x` is flattened
    /// batch-major NHWC, `y` the labels.
    pub fn batch(
        &self,
        part: &Partition,
        step: usize,
        batch: usize,
    ) -> (Vec<f32>, Vec<i32>) {
        let numel = self.sample_numel();
        let mut x = vec![0.0f32; batch * numel];
        let mut y = vec![0i32; batch];
        let mut tmpl = vec![0.0f32; numel];
        let mut rng = Rng::new(
            self.seed
                ^ (part.client_id as u64).wrapping_mul(0x9E37_79B9)
                ^ (step as u64).wrapping_mul(0x85EB_CA6B),
        );
        for b in 0..batch {
            let class = rng.weighted(&part.label_probs);
            y[b] = class as i32;
            self.template(class, &mut tmpl);
            let dst = &mut x[b * numel..(b + 1) * numel];
            for (d, t) in dst.iter_mut().zip(&tmpl) {
                *d = *t + self.noise * rng.normal() as f32;
            }
        }
        (x, y)
    }

    /// IID held-out eval batch (uniform labels, distinct seed stream).
    pub fn eval_batch(&self, step: usize, batch: usize) -> (Vec<f32>, Vec<i32>) {
        let numel = self.sample_numel();
        let mut x = vec![0.0f32; batch * numel];
        let mut y = vec![0i32; batch];
        let mut tmpl = vec![0.0f32; numel];
        let mut rng = Rng::new(
            self.seed ^ 0xE7A1_BA7C ^ (step as u64).wrapping_mul(0xC2B2_AE35),
        );
        for b in 0..batch {
            let class = rng.index(self.num_classes);
            y[b] = class as i32;
            self.template(class, &mut tmpl);
            let dst = &mut x[b * numel..(b + 1) * numel];
            for (d, t) in dst.iter_mut().zip(&tmpl) {
                *d = *t + self.noise * rng.normal() as f32;
            }
        }
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_deterministic() {
        let ds = SyntheticDataset::vision(7);
        let p = ds.partition(3);
        let (x1, y1) = ds.batch(&p, 5, 16);
        let (x2, y2) = ds.batch(&p, 5, 16);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        let (x3, _) = ds.batch(&p, 6, 16);
        assert_ne!(x1, x3, "different steps must differ");
    }

    #[test]
    fn labels_in_range_and_skewed() {
        let ds = SyntheticDataset::vision(1);
        let p = ds.partition(0);
        assert!((p.label_probs.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        let mut seen = std::collections::HashSet::new();
        for step in 0..20 {
            let (_, y) = ds.batch(&p, step, 16);
            for l in y {
                assert!((l as usize) < ds.num_classes);
                seen.insert(l);
            }
        }
        // α=0.3 skew: a single client must NOT see all 64 classes
        assert!(
            seen.len() < ds.num_classes,
            "client saw {} classes — not skewed",
            seen.len()
        );
    }

    #[test]
    fn clients_differ() {
        let ds = SyntheticDataset::speech(2);
        let a = ds.partition(0);
        let b = ds.partition(1);
        assert_ne!(a.label_probs, b.label_probs);
        let (xa, _) = ds.batch(&a, 0, 8);
        let (xb, _) = ds.batch(&b, 0, 8);
        assert_ne!(xa, xb);
    }

    #[test]
    fn sample_counts_plausible() {
        let ds = SyntheticDataset::vision(3);
        let counts: Vec<usize> =
            (0..200).map(|c| ds.partition(c).n_samples).collect();
        assert!(counts.iter().all(|&n| (40..=640).contains(&n)));
        let mean = counts.iter().sum::<usize>() as f64 / counts.len() as f64;
        assert!(mean > 60.0 && mean < 400.0, "mean {mean}");
    }

    #[test]
    fn same_class_shares_template() {
        let ds = SyntheticDataset::vision(4);
        let n = ds.sample_numel();
        let mut t1 = vec![0.0; n];
        let mut t2 = vec![0.0; n];
        ds.template(5, &mut t1);
        ds.template(5, &mut t2);
        assert_eq!(t1, t2);
        ds.template(6, &mut t2);
        assert_ne!(t1, t2);
    }

    #[test]
    fn eval_batch_uniformish() {
        let ds = SyntheticDataset::speech(5);
        let (_, y) = ds.eval_batch(0, 512);
        let mut counts = vec![0usize; ds.num_classes];
        for l in y {
            counts[l as usize] += 1;
        }
        let nonzero = counts.iter().filter(|&&c| c > 0).count();
        assert!(nonzero > ds.num_classes / 2, "eval labels too skewed");
    }
}
