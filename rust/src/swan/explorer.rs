//! Exploration of execution choices (§4.2).
//!
//! Upon a training request, Swan benchmarks unexplored choices — but
//! only while the device is *idle and discharging*, because the energy
//! attribution comes from battery-level drops (Appendix B): with the
//! screen off and no charger, a drop interval's energy belongs to
//! training + known background services, nothing else.
//!
//! Exploration is work-conserving: the benchmark steps are real training
//! steps (the trainer passes a step closure), so a device explores while
//! contributing model updates.

use crate::power::EnergyMeter;
use crate::sim::SimPhone;
use crate::workload::Workload;

use super::choice::{enumerate_choices, ExecutionChoice};
use super::profile::ChoiceProfile;

/// Result of exploring one choice.
#[derive(Clone, Debug)]
pub struct ExplorationResult {
    pub profile: ChoiceProfile,
    /// Whether the energy figure came from a measured battery drop or
    /// had to fall back to the latency-weighted estimate (short runs may
    /// not cross a 1% boundary).
    pub energy_from_meter: bool,
}

/// Drives the §4.2 exploration process on one simulated phone.
pub struct Explorer {
    /// Minimum benchmark steps per choice (request-specified minimum).
    pub min_steps: usize,
    /// Idle-monitoring estimate of background power, watts (from the
    /// §4.1 monitoring phase), subtracted from metered power.
    pub background_power_w: f64,
}

impl Default for Explorer {
    fn default() -> Self {
        Explorer {
            min_steps: 5,
            background_power_w: 0.12,
        }
    }
}

impl Explorer {
    /// Monitor the idle device for `dt_s` to estimate background power
    /// from the battery-drop rate (§4.1 monitoring step).
    pub fn monitor_background(&mut self, phone: &mut SimPhone, dt_s: f64) {
        let mut meter = EnergyMeter::start(&phone.battery, phone.clock.now());
        let t_end = phone.clock.now() + dt_s;
        while phone.clock.now() < t_end {
            phone.idle(60.0);
            meter.poll(&phone.battery, phone.clock.now());
        }
        if let Some(p) = meter.mean_power_w() {
            self.background_power_w = p;
        }
    }

    /// Benchmark a single choice with `steps` training steps.
    ///
    /// Energy attribution (Appendix B): when the run crosses ≥1 battery
    /// percent, power comes from the 1%-drop interval estimator; shorter
    /// runs read the fuel gauge's charge counter directly (Android's
    /// `BATTERY_PROPERTY_CHARGE_COUNTER`, µAh resolution) — both are
    /// userland-observable signals, never simulator ground truth. The
    /// idle-monitoring background power estimate is subtracted.
    pub fn explore_choice(
        &self,
        phone: &mut SimPhone,
        workload: &Workload,
        choice: &ExecutionChoice,
        steps: usize,
    ) -> ExplorationResult {
        let t0 = phone.clock.now();
        let q0 = phone.battery.charge_c;
        let v0 = phone.battery.voltage();
        let mut meter = EnergyMeter::start(&phone.battery, t0);
        let mut latencies = Vec::with_capacity(steps);
        for _ in 0..steps {
            let est = phone.run_train_step(workload, &choice.cores);
            latencies.push(est.latency_s);
            meter.poll(&phone.battery, phone.clock.now());
        }
        let t1 = phone.clock.now();
        let wall = (t1 - t0).max(1e-9);
        let mean_latency = crate::util::stats::mean(&latencies);

        let (power_w, from_meter) = match meter.mean_power_w() {
            Some(p) if !meter.intervals.is_empty() => {
                ((p - self.background_power_w).max(0.0), true)
            }
            _ => {
                // charge-counter delta × average voltage
                let q1 = phone.battery.charge_c;
                let v1 = phone.battery.voltage();
                let e = (q0 - q1).max(0.0) * (v0 + v1) / 2.0;
                (
                    (e / wall - self.background_power_w).max(0.0),
                    false,
                )
            }
        };
        let energy_per_step = power_w * wall / steps as f64;
        ExplorationResult {
            profile: ChoiceProfile {
                choice: choice.clone(),
                latency_s: mean_latency,
                energy_j: energy_per_step,
                power_w,
                steps_measured: steps,
            },
            energy_from_meter: from_meter,
        }
    }

    /// Explore the whole choice space, honouring the §4.1 gates: skip
    /// (and retry later) whenever the device stops being idle+discharging
    /// or overheats. Returns profiles for every choice.
    pub fn explore_all(
        &self,
        phone: &mut SimPhone,
        workload: &Workload,
    ) -> Vec<ChoiceProfile> {
        let choices = enumerate_choices(&phone.device);
        let mut profiles = Vec::with_capacity(choices.len());
        for choice in &choices {
            // gate: idle, discharging, cool (§4.2)
            let mut guard = 0;
            while !(phone.admits_training(20) && phone.charger.is_none()) {
                phone.idle(300.0);
                guard += 1;
                if guard > 10_000 {
                    break; // pathological trace; benchmark anyway
                }
            }
            let res =
                self.explore_choice(phone, workload, choice, self.min_steps);
            profiles.push(res.profile);
        }
        profiles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::device::{device, DeviceId};
    use crate::soc::exec_model::{estimate, ExecutionContext};
    use crate::workload::{builtin, WorkloadName};

    fn phone() -> SimPhone {
        SimPhone::new(device(DeviceId::Pixel3), 7)
    }

    #[test]
    fn explores_every_choice() {
        let mut p = phone();
        let w = builtin(WorkloadName::ShufflenetV2);
        let profiles = Explorer::default().explore_all(&mut p, &w);
        assert_eq!(profiles.len(), 8); // pixel3 choice space
        for pr in &profiles {
            assert!(pr.latency_s > 0.0, "{}", pr.choice.label());
            assert_eq!(pr.steps_measured, 5);
        }
    }

    #[test]
    fn measured_latency_matches_ground_truth_model() {
        // on an idle phone the explorer's latency must equal the exec
        // model's exclusive-context estimate
        let mut p = phone();
        let w = builtin(WorkloadName::Resnet34);
        let d = device(DeviceId::Pixel3);
        let ctx = ExecutionContext::exclusive(8);
        let ch = ExecutionChoice::new(&d, vec![4, 5, 6, 7]);
        let res = Explorer::default().explore_choice(&mut p, &w, &ch, 5);
        let truth = estimate(&d, &w, &[4, 5, 6, 7], &ctx).latency_s;
        assert!(
            (res.profile.latency_s - truth).abs() / truth < 1e-9,
            "{} vs {}",
            res.profile.latency_s,
            truth
        );
    }

    #[test]
    fn metered_energy_close_to_ground_truth_when_long_enough() {
        let mut p = phone();
        let w = builtin(WorkloadName::Resnet34);
        let d = device(DeviceId::Pixel3);
        let ch = ExecutionChoice::new(&d, vec![4, 5, 6, 7]);
        let truth = estimate(
            &d,
            &w,
            &[4, 5, 6, 7],
            &ExecutionContext::exclusive(8),
        );
        // run enough steps to cross several 1% battery drops
        let steps = 400;
        let res = Explorer::default().explore_choice(&mut p, &w, &ch, steps);
        assert!(res.energy_from_meter);
        let rel = (res.profile.energy_j - truth.energy_j).abs() / truth.energy_j;
        assert!(
            rel < 0.25,
            "metered {} vs truth {} (rel {rel})",
            res.profile.energy_j,
            truth.energy_j
        );
    }

    #[test]
    fn exploration_ordering_matches_model_ordering() {
        // the profile ranking Swan acts on must agree with ground truth
        let mut p = phone();
        let w = builtin(WorkloadName::ShufflenetV2);
        let profiles = Explorer::default().explore_all(&mut p, &w);
        let lat = |label: &str| {
            profiles
                .iter()
                .find(|pr| pr.choice.label() == label)
                .unwrap()
                .latency_s
        };
        assert!(lat("4") < lat("4567"), "anti-scaling must be observed");
        assert!(lat("4") < lat("0"), "big beats little");
    }

    #[test]
    fn background_monitoring_estimates_idle_power() {
        let mut p = phone();
        let mut ex = Explorer::default();
        ex.background_power_w = 0.0;
        ex.monitor_background(&mut p, 24.0 * 3600.0);
        assert!(
            ex.background_power_w > 0.05 && ex.background_power_w < 0.3,
            "estimated background {}",
            ex.background_power_w
        );
    }
}
