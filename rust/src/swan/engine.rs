//! The Swan engine: the standardized client interface (§4.1).
//!
//! Distributed frameworks (our FL harness, or PySyft-style clients in
//! the paper) talk to the engine through exactly two calls:
//! `is_active()` — may this device train right now? — and
//! `run_local_step(...)` — execute one step under Swan's current
//! execution choice, observing and reacting to interference.
//!
//! The engine owns the full §4 lifecycle: monitoring → exploration →
//! pruned preference chain → controller-driven training.

use crate::sim::SimPhone;
use crate::workload::Workload;

use super::controller::{Controller, ControllerConfig, MigrationEvent};
use super::explorer::Explorer;
use super::profile::ChoiceProfile;
use super::prune::prune_dominated;

#[derive(Clone, Debug)]
pub struct SwanConfig {
    pub controller: ControllerConfig,
    /// Minimum battery level (%) to admit training when not charging
    /// (§4.1 step 3).
    pub min_battery_level: u32,
    /// Benchmark steps per choice during exploration.
    pub explore_steps: usize,
}

impl Default for SwanConfig {
    fn default() -> Self {
        SwanConfig {
            controller: ControllerConfig::default(),
            min_battery_level: 20,
            explore_steps: 5,
        }
    }
}

/// Outcome of one engine-driven local step.
#[derive(Clone, Debug)]
pub struct StepReport {
    pub latency_s: f64,
    pub choice: String,
    pub migration: MigrationEvent,
}

/// Swan engine bound to one (simulated) phone and one workload.
pub struct SwanEngine {
    pub cfg: SwanConfig,
    workload: Workload,
    controller: Controller,
    /// Profiles as explored (pre-pruning), kept for reporting/sharing.
    pub profiles: Vec<ChoiceProfile>,
}

impl SwanEngine {
    /// Full §4.2 bring-up: explore every choice on this phone, prune,
    /// build the controller.
    pub fn explore_and_build(
        phone: &mut SimPhone,
        workload: Workload,
        cfg: SwanConfig,
    ) -> Self {
        let explorer = Explorer {
            min_steps: cfg.explore_steps,
            ..Explorer::default()
        };
        let profiles = explorer.explore_all(phone, &workload);
        Self::from_profiles(workload, profiles, cfg)
    }

    /// §4.2 amortization: a new device of a known model skips exploration
    /// by adopting coordinator-distributed profiles.
    pub fn from_profiles(
        workload: Workload,
        profiles: Vec<ChoiceProfile>,
        cfg: SwanConfig,
    ) -> Self {
        let chain = prune_dominated(profiles.clone());
        let controller = Controller::new(chain, cfg.controller.clone());
        SwanEngine {
            cfg,
            workload,
            controller,
            profiles,
        }
    }

    /// Standardized interface: may this device train right now?
    pub fn is_active(&self, phone: &mut SimPhone) -> bool {
        phone.admits_training(self.cfg.min_battery_level)
    }

    /// Standardized interface: run one local training step under the
    /// current execution choice; observe latency; maybe migrate.
    ///
    /// `train_fn` performs the *numerics* (the PJRT-executed real step);
    /// the phone supplies the *systems* cost. They are composed here so
    /// callers can't accidentally run numerics without paying sim time.
    pub fn run_local_step<F: FnMut()>(
        &mut self,
        phone: &mut SimPhone,
        mut train_fn: F,
    ) -> StepReport {
        let choice = self.controller.current().choice.clone();
        let est = phone.run_train_step(&self.workload, &choice.cores);
        train_fn();
        let migration = self.controller.observe_step(est.latency_s);
        StepReport {
            latency_s: est.latency_s,
            choice: choice.label(),
            migration,
        }
    }

    pub fn current_choice(&self) -> &ChoiceProfile {
        self.controller.current()
    }

    pub fn chain(&self) -> &[ChoiceProfile] {
        self.controller.chain()
    }

    pub fn migrations(&self) -> (usize, usize) {
        (self.controller.n_downgrades, self.controller.n_upgrades)
    }

    /// The fastest explored profile — what Swan reports to Table 2 as its
    /// choice under no interference.
    pub fn best_profile(&self) -> &ChoiceProfile {
        &self.controller.chain()[0]
    }

    pub fn workload(&self) -> &Workload {
        &self.workload
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::interference::SessionGenerator;
    use crate::soc::device::{device, DeviceId};
    use crate::workload::{builtin, WorkloadName};

    #[test]
    fn bring_up_produces_nonempty_chain() {
        let mut phone = SimPhone::new(device(DeviceId::Pixel3), 1);
        let eng = SwanEngine::explore_and_build(
            &mut phone,
            builtin(WorkloadName::ShufflenetV2),
            SwanConfig::default(),
        );
        assert!(!eng.chain().is_empty());
        assert_eq!(eng.profiles.len(), 8);
        // shufflenet: best profile is a single big core
        assert_eq!(eng.best_profile().choice.label(), "4");
    }

    #[test]
    fn steps_run_and_report() {
        let mut phone = SimPhone::new(device(DeviceId::Pixel3), 2);
        let mut eng = SwanEngine::explore_and_build(
            &mut phone,
            builtin(WorkloadName::Resnet34),
            SwanConfig::default(),
        );
        let mut numerics_ran = 0;
        let rep = eng.run_local_step(&mut phone, || numerics_ran += 1);
        assert_eq!(numerics_ran, 1);
        assert!(rep.latency_s > 0.0);
        assert_eq!(rep.choice, "4567");
    }

    #[test]
    fn engine_migrates_under_interference_and_returns() {
        // idle phone → fastest choice; session arrives → downgrade;
        // session ends → upgrade back
        let d = device(DeviceId::Pixel3);
        let mut phone = SimPhone::new(d.clone(), 3);
        let mut eng = SwanEngine::explore_and_build(
            &mut phone,
            builtin(WorkloadName::Resnet34),
            SwanConfig::default(),
        );
        assert_eq!(eng.current_choice().choice.label(), "4567");

        // inject an endless heavy session
        phone.sessions = SessionGenerator::new(9, 1e-6, 1e12, 1.0);
        phone.idle(1.0);
        let mut downgraded = false;
        for _ in 0..30 {
            let rep = eng.run_local_step(&mut phone, || {});
            if matches!(rep.migration, MigrationEvent::Downgrade { .. }) {
                downgraded = true;
                break;
            }
        }
        assert!(downgraded, "must downgrade under heavy foreground session");

        // back to idle
        phone.sessions = SessionGenerator::always_idle(10);
        let mut upgraded = false;
        for _ in 0..100 {
            let rep = eng.run_local_step(&mut phone, || {});
            if matches!(rep.migration, MigrationEvent::Upgrade { .. }) {
                upgraded = true;
                break;
            }
        }
        assert!(upgraded, "must upgrade once the device is quiet again");
    }

    #[test]
    fn is_active_respects_gates() {
        let mut phone = SimPhone::new(device(DeviceId::Pixel3), 4);
        let eng = SwanEngine::explore_and_build(
            &mut phone,
            builtin(WorkloadName::ShufflenetV2),
            SwanConfig::default(),
        );
        assert!(eng.is_active(&mut phone));
        phone.battery.set_soc(0.05);
        assert!(!eng.is_active(&mut phone));
    }

    #[test]
    fn profile_sharing_skips_exploration() {
        let mut phone_a = SimPhone::new(device(DeviceId::Pixel3), 5);
        let w = builtin(WorkloadName::MobilenetV2);
        let eng_a = SwanEngine::explore_and_build(
            &mut phone_a,
            w.clone(),
            SwanConfig::default(),
        );
        // second device of the same model adopts a's profiles (§4.2)
        let eng_b = SwanEngine::from_profiles(
            w,
            eng_a.profiles.clone(),
            SwanConfig::default(),
        );
        assert_eq!(
            eng_a.best_profile().choice.label(),
            eng_b.best_profile().choice.label()
        );
        assert_eq!(eng_a.chain().len(), eng_b.chain().len());
    }
}
