//! Execution choices: which CPU cores a training step runs on.
//!
//! Appendix B's state space, concretely: within a cluster, cores are
//! interchangeable, so a choice is characterized by how many cores of
//! each kind it uses — (n_little) XOR (n_big, n_prime). Little cores are
//! never mixed with low-latency cores: under OpenMP's static split the
//! little core paces the whole op (see `soc::exec_model`), so mixed
//! combos are dominated by construction and the paper's own example
//! space ("4567" … "4", "0123" … "0") excludes them.

use crate::soc::core::CoreKind;
use crate::soc::device::Device;

/// A concrete core combination, sorted ascending (paper labels like
/// "4567" are exactly the concatenated core ids).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ExecutionChoice {
    pub cores: Vec<usize>,
    counts: (usize, usize, usize), // (little, big, prime)
}

impl ExecutionChoice {
    pub fn new(device: &Device, mut cores: Vec<usize>) -> Self {
        cores.sort_unstable();
        cores.dedup();
        assert!(!cores.is_empty(), "empty execution choice");
        let mut counts = (0, 0, 0);
        for &c in &cores {
            match device.kind_of(c) {
                CoreKind::Little => counts.0 += 1,
                CoreKind::Big => counts.1 += 1,
                CoreKind::Prime => counts.2 += 1,
            }
        }
        ExecutionChoice { cores, counts }
    }

    /// Paper-style label: concatenated core indices ("4567").
    pub fn label(&self) -> String {
        self.cores
            .iter()
            .map(|c| c.to_string())
            .collect::<Vec<_>>()
            .join("")
    }

    pub fn n_threads(&self) -> usize {
        self.cores.len()
    }

    pub fn n_little(&self) -> usize {
        self.counts.0
    }

    pub fn n_big(&self) -> usize {
        self.counts.1
    }

    pub fn n_prime(&self) -> usize {
        self.counts.2
    }

    pub fn uses_low_latency(&self) -> bool {
        self.counts.1 + self.counts.2 > 0
    }
}

/// Enumerate the full choice space for a device (Appendix B).
///
/// Low-latency choices: every (n_big, n_prime) with n_big+n_prime ≥ 1,
/// taking the lowest-indexed cores of each kind (cluster symmetry).
/// Little choices: every n_little ≥ 1. No mixing across the divide.
pub fn enumerate_choices(device: &Device) -> Vec<ExecutionChoice> {
    let little = device.cores_of_kind(CoreKind::Little);
    let big = device.cores_of_kind(CoreKind::Big);
    let prime = device.cores_of_kind(CoreKind::Prime);

    let mut out = Vec::new();
    for nb in 0..=big.len() {
        for np in 0..=prime.len() {
            if nb + np == 0 {
                continue;
            }
            let mut cores: Vec<usize> = big[..nb].to_vec();
            cores.extend_from_slice(&prime[..np]);
            out.push(ExecutionChoice::new(device, cores));
        }
    }
    for nl in 1..=little.len() {
        out.push(ExecutionChoice::new(device, little[..nl].to_vec()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::device::{device, DeviceId};

    #[test]
    fn pixel3_space_matches_paper_example() {
        // §4.3: Pixel 3 order example lists 4567, 456, 45, 4, 0123, 012, 01, 0
        let d = device(DeviceId::Pixel3);
        let labels: Vec<String> =
            enumerate_choices(&d).iter().map(|c| c.label()).collect();
        for want in ["4567", "456", "45", "4", "0123", "012", "01", "0"] {
            assert!(labels.contains(&want.to_string()), "missing {want}");
        }
        assert_eq!(labels.len(), 8, "pixel3 has exactly the 8 paper choices");
    }

    #[test]
    fn prime_devices_get_mixed_big_prime_combos() {
        // §4.3 rule 3 example uses "47" and "45" on a prime device
        let d = device(DeviceId::OnePlus8); // cores 4,5,6 big; 7 prime
        let labels: Vec<String> =
            enumerate_choices(&d).iter().map(|c| c.label()).collect();
        assert!(labels.contains(&"47".to_string()));
        assert!(labels.contains(&"45".to_string()));
        assert!(labels.contains(&"4567".to_string()));
        assert!(labels.contains(&"7".to_string()));
    }

    #[test]
    fn no_choice_mixes_little_with_low_latency() {
        for id in [DeviceId::Pixel3, DeviceId::S10e, DeviceId::OnePlus8] {
            let d = device(id);
            for ch in enumerate_choices(&d) {
                assert!(
                    !(ch.n_little() > 0 && ch.uses_low_latency()),
                    "mixed choice {} on {:?}",
                    ch.label(),
                    id
                );
            }
        }
    }

    #[test]
    fn choices_unique_and_nonempty() {
        for id in [DeviceId::Pixel3, DeviceId::S10e, DeviceId::OnePlus8,
                   DeviceId::TabS6, DeviceId::Mi10] {
            let d = device(id);
            let all = enumerate_choices(&d);
            let mut labels: Vec<String> =
                all.iter().map(|c| c.label()).collect();
            let n = labels.len();
            labels.sort();
            labels.dedup();
            assert_eq!(labels.len(), n, "duplicate choices on {id:?}");
            for ch in &all {
                assert!(ch.n_threads() >= 1);
            }
        }
    }

    #[test]
    fn label_and_counts_consistent() {
        let d = device(DeviceId::S10e); // 0-3 little, 4-5 big, 6-7 prime
        let ch = ExecutionChoice::new(&d, vec![6, 4, 7]);
        assert_eq!(ch.label(), "467");
        assert_eq!(ch.n_big(), 1);
        assert_eq!(ch.n_prime(), 2);
        assert_eq!(ch.n_little(), 0);
    }

    #[test]
    fn dedups_cores() {
        let d = device(DeviceId::Pixel3);
        let ch = ExecutionChoice::new(&d, vec![4, 4, 5]);
        assert_eq!(ch.n_threads(), 2);
    }
}
