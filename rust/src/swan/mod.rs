//! The Swan neural engine — the paper's contribution (§4).
//!
//! - [`choice`] — the execution-choice state space (Appendix B): core
//!   combinations that never mix little with low-latency clusters.
//! - [`cost`] — the "relinquish cost" total order (§4.3 rules 1–3).
//! - [`prune`] — removal of choices that present no viable tradeoff.
//! - [`profile`] — per-choice performance profiles from exploration.
//! - [`explorer`] — §4.2: benchmark unexplored choices when the device
//!   is idle and discharging, attributing energy via battery drops.
//! - [`controller`] — §4.3/Fig 4b: the run-time control loop that infers
//!   interference from step-latency inflation and migrates execution.
//! - [`engine`] — the standardized client interface (`is_active`,
//!   `run_local_step`) that distributed frameworks call.

pub mod choice;
pub mod controller;
pub mod cost;
pub mod engine;
pub mod explorer;
pub mod profile;
pub mod prune;

pub use choice::ExecutionChoice;
pub use controller::{Controller, ControllerConfig, MigrationEvent};
pub use cost::cost_key;
pub use engine::{SwanEngine, SwanConfig};
pub use explorer::{ExplorationResult, Explorer};
pub use profile::ChoiceProfile;
pub use prune::prune_dominated;
