//! Pruning choices that present no viable tradeoff (§4.3).
//!
//! After sorting profiles by increasing expected latency, a choice is
//! kept only if it is *cheaper* (relinquish cost) than every faster
//! choice — i.e. the Pareto frontier of (latency, cost). Anything else
//! would be a downgrade that surrenders performance without freeing
//! compute for the interfering app ("4567" for ShuffleNet: slower AND
//! costlier than "4", so pruned).

use super::cost::cost_key;
use super::profile::ChoiceProfile;

/// Sort by latency ascending and drop cost-dominated choices. The
/// returned list is Swan's preference chain: index 0 is the fastest,
/// each later entry trades latency for relinquished compute.
pub fn prune_dominated(mut profiles: Vec<ChoiceProfile>) -> Vec<ChoiceProfile> {
    profiles.sort_by(|a, b| a.latency_s.partial_cmp(&b.latency_s).unwrap());
    let mut kept: Vec<ChoiceProfile> = Vec::new();
    for p in profiles {
        let min_cost_so_far = kept.iter().map(|k| cost_key(&k.choice)).min();
        match min_cost_so_far {
            None => kept.push(p),
            Some(mc) => {
                if cost_key(&p.choice) < mc {
                    kept.push(p);
                }
            }
        }
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::device::{device, DeviceId};
    use crate::soc::exec_model::{estimate, ExecutionContext};
    use crate::swan::choice::{enumerate_choices, ExecutionChoice};
    use crate::workload::{builtin, WorkloadName};

    fn profiles_for(
        dev: DeviceId,
        workload: WorkloadName,
    ) -> Vec<ChoiceProfile> {
        let d = device(dev);
        let w = builtin(workload);
        let ctx = ExecutionContext::exclusive(d.n_cores());
        enumerate_choices(&d)
            .into_iter()
            .map(|ch| {
                let est = estimate(&d, &w, &ch.cores, &ctx);
                ChoiceProfile {
                    choice: ch,
                    latency_s: est.latency_s,
                    energy_j: est.energy_j,
                    power_w: est.avg_power_w,
                    steps_measured: 5,
                }
            })
            .collect()
    }

    #[test]
    fn chain_sorted_by_latency_and_strictly_cheaper() {
        for (dev, wl) in [
            (DeviceId::Pixel3, WorkloadName::Resnet34),
            (DeviceId::Pixel3, WorkloadName::ShufflenetV2),
            (DeviceId::S10e, WorkloadName::MobilenetV2),
            (DeviceId::OnePlus8, WorkloadName::Resnet34),
        ] {
            let kept = prune_dominated(profiles_for(dev, wl));
            assert!(!kept.is_empty());
            for w in kept.windows(2) {
                assert!(w[0].latency_s <= w[1].latency_s, "latency order");
                assert!(
                    cost_key(&w[1].choice) < cost_key(&w[0].choice),
                    "each downgrade must relinquish compute: {} then {}",
                    w[0].choice.label(),
                    w[1].choice.label()
                );
            }
        }
    }

    #[test]
    fn resnet_keeps_tradeoff_shufflenet_prunes_greedy() {
        // §4.3's worked example on Pixel 3
        let rn = prune_dominated(profiles_for(
            DeviceId::Pixel3,
            WorkloadName::Resnet34,
        ));
        let rn_labels: Vec<String> =
            rn.iter().map(|p| p.choice.label()).collect();
        // ResNet scales: 4567 is fastest, kept at the head of the chain
        assert_eq!(rn_labels[0], "4567");
        assert!(rn_labels.contains(&"4".to_string()));

        let sn = prune_dominated(profiles_for(
            DeviceId::Pixel3,
            WorkloadName::ShufflenetV2,
        ));
        let sn_labels: Vec<String> =
            sn.iter().map(|p| p.choice.label()).collect();
        // ShuffleNet anti-scales: 4567 is slower AND costlier than 4 → pruned
        assert!(
            !sn_labels.contains(&"4567".to_string()),
            "4567 must be pruned for shufflenet: {sn_labels:?}"
        );
        assert_eq!(sn_labels[0], "4", "single big core is fastest");
    }

    #[test]
    fn fastest_choice_always_survives() {
        use crate::util::check::check;
        check(50, |rng| {
            let devs = [DeviceId::Pixel3, DeviceId::S10e, DeviceId::OnePlus8,
                        DeviceId::TabS6, DeviceId::Mi10];
            let wls = [WorkloadName::Resnet34, WorkloadName::MobilenetV2,
                       WorkloadName::ShufflenetV2];
            let profs =
                profiles_for(devs[rng.index(5)], wls[rng.index(3)]);
            let fastest = profs
                .iter()
                .map(|p| p.latency_s)
                .fold(f64::INFINITY, f64::min);
            let kept = prune_dominated(profs);
            crate::prop_assert!(
                (kept[0].latency_s - fastest).abs() < 1e-12,
                "head of chain must be the fastest profile"
            );
            Ok(())
        });
    }

    #[test]
    fn pruned_set_always_ends_with_cheapest_core() {
        // the chain must bottom out at a single little core ("0") so the
        // controller can always fully yield
        let kept =
            prune_dominated(profiles_for(DeviceId::Pixel3, WorkloadName::Resnet34));
        let last = kept.last().unwrap();
        assert_eq!(last.choice.label(), "0");
    }

    #[test]
    fn synthetic_tie_handling() {
        // two profiles with equal latency: only the cheaper survives
        let d = device(DeviceId::Pixel3);
        let mk = |cores: Vec<usize>, lat: f64| ChoiceProfile {
            choice: ExecutionChoice::new(&d, cores),
            latency_s: lat,
            energy_j: 1.0,
            power_w: 1.0,
            steps_measured: 1,
        };
        let kept = prune_dominated(vec![
            mk(vec![4, 5], 1.0),
            mk(vec![4], 1.0),
            mk(vec![0], 2.0),
        ]);
        let labels: Vec<String> =
            kept.iter().map(|p| p.choice.label()).collect();
        assert!(labels.contains(&"4".to_string()) || labels[0] == "45");
        // '45' may be first by sort stability, but '4' must survive and '45'
        // must not appear after it
        let pos4 = labels.iter().position(|l| l == "4");
        assert!(pos4.is_some());
    }
}
