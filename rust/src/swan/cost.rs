//! The relinquish-cost total order over execution choices (§4.3).
//!
//! The paper's three rules, derived from how Android hands fast cores to
//! foreground apps:
//!
//! 1. more cores of the same type is costlier        (cost[4567] > cost[4])
//! 2. any low-latency cores beat any little cores    (cost[4]   > cost[0123])
//! 3. prime cores are costlier than big cores        (cost[47]  > cost[45])
//!
//! All three are satisfied by comparing the tuple
//! `(n_prime, n_big, n_little)` lexicographically — "how much of the
//! stuff foreground apps want most does this choice hold?". The result
//! for Pixel 3 is exactly the paper's example chain
//! "4567" > "456" > "45" > "4" > "0123" > "012" > "01" > "0".

use super::choice::ExecutionChoice;

/// Sort key; higher = costlier (relinquishes more useful compute).
pub fn cost_key(choice: &ExecutionChoice) -> (usize, usize, usize) {
    (choice.n_prime(), choice.n_big(), choice.n_little())
}

/// Strict "costlier than" per the total order.
pub fn costlier(a: &ExecutionChoice, b: &ExecutionChoice) -> bool {
    cost_key(a) > cost_key(b)
}

/// Sort choices from costliest to cheapest (the paper's downgrade chain).
pub fn sort_by_cost_desc(choices: &mut [ExecutionChoice]) {
    choices.sort_by(|a, b| cost_key(b).cmp(&cost_key(a)));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::device::{device, DeviceId};
    use crate::swan::choice::enumerate_choices;

    fn by_label(dev: DeviceId, label: &str) -> ExecutionChoice {
        let d = device(dev);
        let cores: Vec<usize> = label
            .chars()
            .map(|c| c.to_digit(10).unwrap() as usize)
            .collect();
        ExecutionChoice::new(&d, cores)
    }

    #[test]
    fn rule1_more_same_type_costlier() {
        let a = by_label(DeviceId::Pixel3, "4567");
        let b = by_label(DeviceId::Pixel3, "4");
        assert!(costlier(&a, &b));
        let a = by_label(DeviceId::Pixel3, "012");
        let b = by_label(DeviceId::Pixel3, "01");
        assert!(costlier(&a, &b));
    }

    #[test]
    fn rule2_low_latency_beats_little() {
        let a = by_label(DeviceId::Pixel3, "4");
        let b = by_label(DeviceId::Pixel3, "0123");
        assert!(costlier(&a, &b));
    }

    #[test]
    fn rule3_prime_costlier_than_big() {
        // OnePlus 8: core 7 = prime
        let a = by_label(DeviceId::OnePlus8, "47");
        let b = by_label(DeviceId::OnePlus8, "45");
        assert!(costlier(&a, &b));
    }

    #[test]
    fn pixel3_full_chain_matches_paper() {
        let want = ["4567", "456", "45", "4", "0123", "012", "01", "0"];
        let d = device(DeviceId::Pixel3);
        let mut all = enumerate_choices(&d);
        sort_by_cost_desc(&mut all);
        let got: Vec<String> = all.iter().map(|c| c.label()).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn total_order_is_strict_on_choice_space() {
        // lexicographic keys must be pairwise distinct within a device
        for id in [DeviceId::Pixel3, DeviceId::S10e, DeviceId::OnePlus8] {
            let d = device(id);
            let all = enumerate_choices(&d);
            for i in 0..all.len() {
                for j in 0..all.len() {
                    if i != j {
                        assert_ne!(
                            cost_key(&all[i]),
                            cost_key(&all[j]),
                            "tie between {} and {} on {:?}",
                            all[i].label(),
                            all[j].label(),
                            id
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn order_transitive_property() {
        use crate::util::check::check;
        check(100, |rng| {
            let d = device(DeviceId::S10e);
            let all = enumerate_choices(&d);
            let a = &all[rng.index(all.len())];
            let b = &all[rng.index(all.len())];
            let c = &all[rng.index(all.len())];
            if costlier(a, b) && costlier(b, c) {
                crate::prop_assert!(
                    costlier(a, c),
                    "transitivity violated: {} {} {}",
                    a.label(),
                    b.label(),
                    c.label()
                );
            }
            Ok(())
        });
    }
}
