//! The run-time migration control loop (§4.3, Fig 4b).
//!
//! Swan holds the pruned preference chain (fastest → cheapest). At run
//! time it compares each step's observed latency against the active
//! profile's expectation (EWMA-smoothed). Sustained inflation ⇒ some
//! foreground app is contending for our cores ⇒ *downgrade* one chain
//! position, relinquishing exactly the compute the cost order says the
//! app wants. After a quiet period at a downgraded position, probe an
//! *upgrade* back toward the fast end.
//!
//! The controller sees only what a real userland engine could see: its
//! own step latencies and the battery/thermal observations.

use super::profile::ChoiceProfile;

#[derive(Clone, Debug)]
pub struct ControllerConfig {
    /// Latency inflation (observed / expected) that signals interference.
    pub downgrade_ratio: f64,
    /// Inflation below which the core is considered quiet.
    pub quiet_ratio: f64,
    /// EWMA smoothing for observed/expected ratio.
    pub ewma_alpha: f64,
    /// Consecutive quiet steps required before probing an upgrade.
    pub upgrade_patience: usize,
    /// Consecutive inflated steps required before downgrading.
    pub downgrade_patience: usize,
    /// Cap for the exponential upgrade backoff (see `Controller`).
    pub max_upgrade_patience: usize,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            downgrade_ratio: 1.35,
            quiet_ratio: 1.15,
            ewma_alpha: 0.4,
            upgrade_patience: 8,
            downgrade_patience: 2,
            max_upgrade_patience: 256,
        }
    }
}

/// A migration decision, reported for tracing/evaluation.
#[derive(Clone, Debug, PartialEq)]
pub enum MigrationEvent {
    Stay,
    Downgrade { from: String, to: String },
    Upgrade { from: String, to: String },
}

/// Run-time choice selector over the pruned chain.
pub struct Controller {
    /// Pruned profiles, latency-ascending (= cost-descending).
    chain: Vec<ChoiceProfile>,
    cfg: ControllerConfig,
    /// Current position in the chain (0 = fastest).
    pos: usize,
    ratio_ewma: crate::util::stats::Ewma,
    hot_streak: usize,
    quiet_streak: usize,
    /// Exponential upgrade backoff: when an upgrade probe is punished
    /// (downgraded again within a few steps), the patience before the
    /// next probe doubles — persistent interference (a long PCMark run,
    /// a gaming session) stops costing a slow probe every few steps.
    current_upgrade_patience: usize,
    steps_since_upgrade: usize,
    /// Total migrations performed (evaluation metric).
    pub n_downgrades: usize,
    pub n_upgrades: usize,
}

impl Controller {
    /// `chain` must be the output of `prune_dominated` (asserted).
    pub fn new(chain: Vec<ChoiceProfile>, cfg: ControllerConfig) -> Self {
        assert!(!chain.is_empty(), "empty preference chain");
        for w in chain.windows(2) {
            assert!(
                w[0].latency_s <= w[1].latency_s,
                "chain must be latency-ascending"
            );
        }
        let alpha = cfg.ewma_alpha;
        let patience = cfg.upgrade_patience;
        Controller {
            chain,
            cfg,
            pos: 0,
            ratio_ewma: crate::util::stats::Ewma::new(alpha),
            hot_streak: 0,
            quiet_streak: 0,
            current_upgrade_patience: patience,
            steps_since_upgrade: usize::MAX,
            n_downgrades: 0,
            n_upgrades: 0,
        }
    }

    pub fn current(&self) -> &ChoiceProfile {
        &self.chain[self.pos]
    }

    pub fn chain(&self) -> &[ChoiceProfile] {
        &self.chain
    }

    pub fn position(&self) -> usize {
        self.pos
    }

    /// Feed one observed step latency; returns the migration decision to
    /// apply to the NEXT step.
    pub fn observe_step(&mut self, observed_latency_s: f64) -> MigrationEvent {
        let expected = self.current().latency_s.max(1e-9);
        let ratio = self.ratio_ewma.update(observed_latency_s / expected);

        if ratio > self.cfg.downgrade_ratio {
            self.hot_streak += 1;
            self.quiet_streak = 0;
        } else if ratio < self.cfg.quiet_ratio {
            self.quiet_streak += 1;
            self.hot_streak = 0;
        } else {
            self.hot_streak = 0;
            self.quiet_streak = 0;
        }

        self.steps_since_upgrade = self.steps_since_upgrade.saturating_add(1);

        if self.hot_streak >= self.cfg.downgrade_patience
            && self.pos + 1 < self.chain.len()
        {
            let from = self.current().choice.label();
            self.pos += 1;
            self.n_downgrades += 1;
            self.hot_streak = 0;
            self.ratio_ewma.reset();
            // punished probe ⇒ back off exponentially
            if self.steps_since_upgrade <= self.cfg.downgrade_patience + 2 {
                self.current_upgrade_patience = (self.current_upgrade_patience
                    * 2)
                .min(self.cfg.max_upgrade_patience);
            }
            return MigrationEvent::Downgrade {
                from,
                to: self.current().choice.label(),
            };
        }

        if self.quiet_streak >= self.current_upgrade_patience && self.pos > 0 {
            let from = self.current().choice.label();
            self.pos -= 1;
            self.n_upgrades += 1;
            self.quiet_streak = 0;
            self.steps_since_upgrade = 0;
            self.ratio_ewma.reset();
            return MigrationEvent::Upgrade {
                from,
                to: self.current().choice.label(),
            };
        }

        MigrationEvent::Stay
    }

    /// Reset the upgrade backoff (e.g. the screen turned off).
    pub fn reset_backoff(&mut self) {
        self.current_upgrade_patience = self.cfg.upgrade_patience;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::device::{device, DeviceId};
    use crate::soc::exec_model::{estimate, ExecutionContext};
    use crate::swan::choice::enumerate_choices;
    use crate::swan::prune::prune_dominated;
    use crate::workload::{builtin, WorkloadName};

    fn chain(dev: DeviceId, wl: WorkloadName) -> Vec<ChoiceProfile> {
        let d = device(dev);
        let w = builtin(wl);
        let ctx = ExecutionContext::exclusive(d.n_cores());
        let profiles = enumerate_choices(&d)
            .into_iter()
            .map(|ch| {
                let est = estimate(&d, &w, &ch.cores, &ctx);
                ChoiceProfile {
                    choice: ch,
                    latency_s: est.latency_s,
                    energy_j: est.energy_j,
                    power_w: est.avg_power_w,
                    steps_measured: 5,
                }
            })
            .collect();
        prune_dominated(profiles)
    }

    #[test]
    fn starts_at_fastest() {
        let c = Controller::new(
            chain(DeviceId::Pixel3, WorkloadName::Resnet34),
            ControllerConfig::default(),
        );
        assert_eq!(c.position(), 0);
        assert_eq!(c.current().choice.label(), "4567");
    }

    #[test]
    fn sustained_inflation_downgrades() {
        let mut c = Controller::new(
            chain(DeviceId::Pixel3, WorkloadName::Resnet34),
            ControllerConfig::default(),
        );
        let base = c.current().latency_s;
        let mut migrated = false;
        for _ in 0..10 {
            if let MigrationEvent::Downgrade { from, to } =
                c.observe_step(base * 2.0)
            {
                assert_eq!(from, "4567");
                assert_eq!(to, "456");
                migrated = true;
                break;
            }
        }
        assert!(migrated, "controller must downgrade under 2× inflation");
        assert_eq!(c.n_downgrades, 1);
    }

    #[test]
    fn quiet_period_upgrades_back() {
        let mut c = Controller::new(
            chain(DeviceId::Pixel3, WorkloadName::Resnet34),
            ControllerConfig::default(),
        );
        // force a downgrade
        let base0 = c.current().latency_s;
        for _ in 0..10 {
            c.observe_step(base0 * 2.0);
        }
        assert!(c.position() > 0);
        // now run quiet: observed latency tracks whatever choice is
        // active (the device is idle again)
        let mut upgraded = false;
        for _ in 0..100 {
            let expected = c.current().latency_s;
            if let MigrationEvent::Upgrade { .. } = c.observe_step(expected) {
                upgraded = true;
            }
            if c.position() == 0 {
                break;
            }
        }
        assert!(upgraded, "controller must upgrade after a quiet period");
        assert_eq!(c.position(), 0);
    }

    #[test]
    fn no_thrash_on_borderline_noise() {
        // latencies jittering ±10% around expectation must cause no
        // migration at all
        let mut c = Controller::new(
            chain(DeviceId::S10e, WorkloadName::MobilenetV2),
            ControllerConfig::default(),
        );
        let base = c.current().latency_s;
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..500 {
            let jitter = 1.0 + 0.1 * (rng.f64() * 2.0 - 1.0);
            c.observe_step(base * jitter);
        }
        assert_eq!(c.n_downgrades, 0);
        assert_eq!(c.n_upgrades, 0);
    }

    #[test]
    fn never_leaves_chain_bounds() {
        use crate::util::check::check;
        check(50, |rng| {
            let mut c = Controller::new(
                chain(DeviceId::OnePlus8, WorkloadName::ShufflenetV2),
                ControllerConfig::default(),
            );
            let n = c.chain().len();
            for _ in 0..200 {
                let lat = c.current().latency_s * rng.range(0.5, 4.0);
                c.observe_step(lat);
                crate::prop_assert!(c.position() < n, "position out of bounds");
            }
            Ok(())
        });
    }

    #[test]
    fn upgrade_backoff_under_persistent_interference() {
        // under never-ending contention the controller must spend an
        // ever-larger fraction of steps at the quiet position instead of
        // bouncing every `upgrade_patience` steps
        let mut c = Controller::new(
            chain(DeviceId::Pixel3, WorkloadName::Resnet34),
            ControllerConfig::default(),
        );
        let mut upgrades_first_100 = 0;
        let mut upgrades_last_100 = 0;
        for i in 0..600 {
            // observed latency: 3× inflation whenever at the fast end,
            // nominal otherwise (interference only touches big cores)
            let expected = c.current().latency_s;
            let obs = if c.position() == 0 { expected * 3.0 } else { expected };
            if let MigrationEvent::Upgrade { .. } = c.observe_step(obs) {
                if i < 100 {
                    upgrades_first_100 += 1;
                } else if i >= 500 {
                    upgrades_last_100 += 1;
                }
            }
        }
        assert!(
            upgrades_last_100 < upgrades_first_100,
            "backoff should slow probing: first {upgrades_first_100},              last {upgrades_last_100}"
        );
    }

    #[test]
    fn bottom_of_chain_absorbs_persistent_interference() {
        let mut c = Controller::new(
            chain(DeviceId::Pixel3, WorkloadName::Resnet34),
            ControllerConfig::default(),
        );
        for _ in 0..500 {
            let lat = c.current().latency_s * 3.0;
            c.observe_step(lat);
        }
        assert_eq!(c.position(), c.chain().len() - 1);
        assert_eq!(c.current().choice.label(), "0");
    }
}
