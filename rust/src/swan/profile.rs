//! Per-choice performance profiles produced by exploration (§4.2).

use super::choice::ExecutionChoice;

/// What Swan knows about one execution choice after benchmarking it.
#[derive(Clone, Debug)]
pub struct ChoiceProfile {
    pub choice: ExecutionChoice,
    /// Mean measured step latency, seconds.
    pub latency_s: f64,
    /// Estimated energy per step, joules (battery-drop attribution —
    /// includes measurement noise, see `power::meter`).
    pub energy_j: f64,
    /// Estimated average power during the benchmark, watts.
    pub power_w: f64,
    /// Steps actually measured.
    pub steps_measured: usize,
}

impl ChoiceProfile {
    /// Serialize for the coordinator (the FL server shares profiles
    /// across same-model devices so new installs skip exploration, §4.2).
    pub fn to_json(&self) -> crate::util::json::Value {
        crate::util::json::Value::obj()
            .set("choice", self.choice.label())
            .set("latency_s", self.latency_s)
            .set("energy_j", self.energy_j)
            .set("power_w", self.power_w)
            .set("steps_measured", self.steps_measured)
    }

    pub fn from_json(
        v: &crate::util::json::Value,
        device: &crate::soc::device::Device,
    ) -> crate::Result<ChoiceProfile> {
        let label = v.req_str("choice")?;
        let cores: Vec<usize> = label
            .chars()
            .map(|c| {
                c.to_digit(10)
                    .map(|d| d as usize)
                    .ok_or_else(|| crate::err!("bad choice label '{label}'"))
            })
            .collect::<crate::Result<_>>()?;
        Ok(ChoiceProfile {
            choice: ExecutionChoice::new(device, cores),
            latency_s: v.req_f64("latency_s")?,
            energy_j: v.req_f64("energy_j")?,
            power_w: v.req_f64("power_w")?,
            steps_measured: v.req_usize("steps_measured")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::device::{device, DeviceId};

    #[test]
    fn json_roundtrip() {
        let d = device(DeviceId::OnePlus8);
        let p = ChoiceProfile {
            choice: ExecutionChoice::new(&d, vec![4, 7]),
            latency_s: 1.25,
            energy_j: 6.5,
            power_w: 5.2,
            steps_measured: 8,
        };
        let v = p.to_json();
        let q = ChoiceProfile::from_json(&v, &d).unwrap();
        assert_eq!(q.choice.label(), "47");
        assert!((q.latency_s - 1.25).abs() < 1e-12);
        assert_eq!(q.steps_measured, 8);
    }

    #[test]
    fn rejects_garbage_label() {
        let d = device(DeviceId::Pixel3);
        let v = crate::util::json::Value::obj()
            .set("choice", "4x")
            .set("latency_s", 1.0)
            .set("energy_j", 1.0)
            .set("power_w", 1.0)
            .set("steps_measured", 1usize);
        assert!(ChoiceProfile::from_json(&v, &d).is_err());
    }
}
