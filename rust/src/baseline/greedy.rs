//! The PyTorch greedy baseline (§5.1).
//!
//! "The baseline uses the execution choice defined by PyTorch that
//! greedily picks as many threads as there are low-latency cores" — a
//! static policy: all big+prime cores, no exploration, no migration,
//! oblivious to interference, battery and temperature (beyond the same
//! idle-admission gate real FL clients use).

use crate::sim::SimPhone;
use crate::soc::device::Device;
use crate::swan::choice::ExecutionChoice;
use crate::workload::Workload;

pub struct GreedyBaseline {
    choice: ExecutionChoice,
    workload: Workload,
}

impl GreedyBaseline {
    pub fn new(device: &Device, workload: Workload) -> Self {
        let cores = device.low_latency_cores();
        GreedyBaseline {
            choice: ExecutionChoice::new(device, cores),
            workload,
        }
    }

    pub fn choice(&self) -> &ExecutionChoice {
        &self.choice
    }

    /// Baseline admission: like real FL deployments, train when idle and
    /// battery is healthy — but never adapt the core set.
    pub fn is_active(&self, phone: &mut SimPhone, min_battery: u32) -> bool {
        phone.admits_training(min_battery)
    }

    /// One training step on the static greedy choice.
    pub fn run_local_step<F: FnMut()>(
        &self,
        phone: &mut SimPhone,
        mut train_fn: F,
    ) -> f64 {
        let est = phone.run_train_step(&self.workload, &self.choice.cores);
        train_fn();
        est.latency_s
    }

    pub fn workload(&self) -> &Workload {
        &self.workload
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::device::{device, DeviceId};
    use crate::workload::{builtin, WorkloadName};

    #[test]
    fn greedy_uses_all_low_latency_cores() {
        for id in [DeviceId::Pixel3, DeviceId::S10e, DeviceId::OnePlus8] {
            let d = device(id);
            let b = GreedyBaseline::new(&d, builtin(WorkloadName::Resnet34));
            assert_eq!(b.choice().cores, d.low_latency_cores());
            assert_eq!(b.choice().n_little(), 0);
        }
    }

    #[test]
    fn greedy_never_migrates() {
        let d = device(DeviceId::Pixel3);
        let mut phone = SimPhone::new(d.clone(), 11);
        let b = GreedyBaseline::new(&d, builtin(WorkloadName::ShufflenetV2));
        let before = b.choice().label();
        for _ in 0..50 {
            b.run_local_step(&mut phone, || {});
        }
        assert_eq!(b.choice().label(), before);
    }

    #[test]
    fn greedy_slower_than_single_core_on_shufflenet() {
        // the §3.1 pathology the baseline walks into
        let d = device(DeviceId::S10e);
        let mut p1 = SimPhone::new(d.clone(), 1);
        let mut p2 = SimPhone::new(d.clone(), 1);
        let w = builtin(WorkloadName::ShufflenetV2);
        let b = GreedyBaseline::new(&d, w.clone());
        let t_greedy = b.run_local_step(&mut p1, || {});
        let est = p2.run_train_step(&w, &[6]); // single prime core
        assert!(
            t_greedy > 2.0 * est.latency_s,
            "greedy {t_greedy} vs single prime {}",
            est.latency_s
        );
    }
}
