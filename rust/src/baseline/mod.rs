//! Baseline execution policies Swan is compared against.

pub mod greedy;

pub use greedy::GreedyBaseline;
