//! `swan lint` findings rendered as a report table.

use crate::lint::Finding;
use crate::util::table::Table;

/// One row per finding: file, line, rule, severity, message.
pub fn lint_table(findings: &[Finding]) -> Table {
    let mut t = Table::new(
        "swan lint findings",
        &["file", "line", "rule", "severity", "message"],
    );
    for f in findings {
        t.row(&[
            f.file.clone(),
            f.line.to_string(),
            f.rule.to_string(),
            if f.deny { "deny" } else { "warn" }.to_string(),
            f.message.clone(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_one_row_per_finding() {
        let fs = vec![Finding {
            file: "rust/src/fleet/soa.rs".into(),
            line: 42,
            rule: "determinism",
            deny: true,
            message: "wall clock in digest scope".into(),
        }];
        let t = lint_table(&fs);
        assert_eq!(t.rows.len(), 1);
        let md = t.to_markdown();
        assert!(md.contains("determinism"));
        assert!(md.contains("42"));
        assert!(md.contains("deny"));
    }
}
