//! Report emitters — one per paper table/figure (DESIGN.md §4).
//!
//! Each function computes the rows/series the paper reports and returns
//! them as data plus a formatted `util::table::Table`; the `benches/`
//! binaries print and persist them, and `EXPERIMENTS.md` records
//! paper-vs-measured.

pub mod fig1;
pub mod fig2;
pub mod fleet_eval;
pub mod lint_eval;
pub mod local_eval;
pub mod obs_eval;
pub mod pcmark_eval;
pub mod serve_eval;

pub use fig1::fig1b_matmul_rows;
pub use fig2::fig2_combo_rows;
pub use fleet_eval::{fleet_eval_rows, fleet_table};
pub use lint_eval::lint_table;
pub use local_eval::{table2_rows, Table2Row};
pub use obs_eval::{obs_metrics_table, obs_table, obs_top_table};
pub use pcmark_eval::{fig3_rows, table3_rows, Table3Row};
pub use serve_eval::serve_table;
