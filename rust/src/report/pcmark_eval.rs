//! Figure 3 + Table 3: PCMark impact of background training.
//!
//! Fig 3 compares the score with and without *baseline* (greedy)
//! training in the background. Table 3 then adds Swan: while PCMark's
//! foreground threads run, Swan's controller observes its own step
//! latency inflating on the contended cores and walks down the
//! preference chain; the table scores the device with training pinned
//! to whatever choice the controller settles on.

use crate::sim::interference::SessionGenerator;
use crate::sim::pcmark::{pcmark_score, score_impact_percent};
use crate::sim::SimPhone;
use crate::soc::device::{all_devices, device, Device, DeviceId};
use crate::swan::engine::{SwanConfig, SwanEngine};
use crate::util::table::Table;
use crate::workload::{load_or_builtin, Workload, WorkloadName};

#[derive(Clone, Debug)]
pub struct Table3Row {
    pub device: DeviceId,
    pub baseline_impact_pct: f64,
    pub swan_impact_pct: f64,
    pub swan_settled_choice: String,
}

/// Fig 3 rows: (device, score idle, score w/ greedy training, impact %).
pub fn fig3_rows(artifacts_dir: &str) -> (Vec<(DeviceId, f64, f64, f64)>, Table) {
    let _ = artifacts_dir;
    let mut rows = Vec::new();
    let mut table = Table::new(
        "Fig 3 — PCMark score with and without background training (greedy)",
        &["device", "score_idle", "score_training", "impact_%"],
    );
    for d in all_devices() {
        let clean = pcmark_score(&d, &[]);
        let dirty = pcmark_score(&d, &d.low_latency_cores());
        let impact = (dirty - clean) / clean * 100.0;
        rows.push((d.id, clean, dirty, impact));
        table.row(&[
            d.id.name().to_string(),
            format!("{clean:.0}"),
            format!("{dirty:.0}"),
            format!("{impact:.1}%"),
        ]);
    }
    (rows, table)
}

/// Run Swan on a phone with a persistent 2-thread foreground session
/// (PCMark running) until the controller stops migrating; return its
/// settled choice.
fn swan_settled_choice(d: &Device, workload: &Workload) -> Vec<usize> {
    // bring-up on an idle phone (profiles are interference-free)
    let mut phone = SimPhone::new(d.clone(), 0x5CA9);
    let mut engine = SwanEngine::explore_and_build(
        &mut phone,
        workload.clone(),
        SwanConfig::default(),
    );
    // now the benchmark starts: endless heavy session. Run long enough
    // for the upgrade backoff to converge, then report the choice the
    // controller spent the most simulated TIME at — that is what PCMark
    // experiences.
    phone.sessions = SessionGenerator::new(0x9C, 1e-6, 1e15, 1.0);
    phone.idle(1.0);
    let mut time_at: std::collections::BTreeMap<String, f64> =
        std::collections::BTreeMap::new();
    for _ in 0..400 {
        let label = engine.current_choice().choice.label();
        let rep = engine.run_local_step(&mut phone, || {});
        *time_at.entry(label).or_insert(0.0) += rep.latency_s;
    }
    let dominant = time_at
        .into_iter()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .map(|(l, _)| l)
        .expect("ran steps");
    let dominant_cores: Vec<usize> = dominant
        .chars()
        .map(|c| c.to_digit(10).unwrap() as usize)
        .collect();
    // what actually runs is the within-cluster remap away from the
    // PCMark threads (sched_setaffinity) — score those concrete cores
    let sched = crate::sim::android_sched::Scheduler::new(d);
    let share = sched.training_share(2);
    sched.remap_least_contended(d, &dominant_cores, &share)
}

/// Table 3 rows for the four paper devices (the paper omits Mi 10 from
/// Table 3 but notes it saw no impact; we compute all five).
pub fn table3_rows(artifacts_dir: &str) -> (Vec<Table3Row>, Table) {
    // the paper's Table-3 experiment trains the speech model (ResNet-34)
    let workload = load_or_builtin(WorkloadName::Resnet34, artifacts_dir);
    let mut rows = Vec::new();
    for id in [DeviceId::TabS6, DeviceId::OnePlus8, DeviceId::Pixel3,
               DeviceId::S10e, DeviceId::Mi10] {
        let d = device(id);
        let baseline_impact =
            score_impact_percent(&d, &d.low_latency_cores());
        let settled = swan_settled_choice(&d, &workload);
        let swan_impact = score_impact_percent(&d, &settled);
        rows.push(Table3Row {
            device: id,
            baseline_impact_pct: baseline_impact,
            swan_impact_pct: swan_impact,
            swan_settled_choice: settled
                .iter()
                .map(|c| c.to_string())
                .collect::<String>(),
        });
    }
    let mut table = Table::new(
        "Table 3 — PCMark impact while training in the background",
        &["device", "baseline", "swan", "swan_choice_under_interference"],
    );
    for r in &rows {
        table.row(&[
            r.device.name().to_string(),
            format!("{:.1} %", r.baseline_impact_pct),
            format!("{:.1} %", r.swan_impact_pct),
            r.swan_settled_choice.clone(),
        ]);
    }
    (rows, table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_training_always_hurts_pixel3_worst() {
        let (rows, _) = fig3_rows("artifacts");
        assert_eq!(rows.len(), 5);
        for (id, clean, dirty, impact) in &rows {
            assert!(dirty <= clean, "{id:?}");
            assert!(*impact <= 0.0);
        }
        let worst = rows
            .iter()
            .min_by(|a, b| a.3.partial_cmp(&b.3).unwrap())
            .unwrap();
        assert_eq!(worst.0, DeviceId::Pixel3, "paper: Pixel 3 hit hardest");
    }

    #[test]
    fn table3_swan_strictly_better_than_baseline() {
        let (rows, _) = table3_rows("artifacts");
        for r in &rows {
            assert!(
                r.swan_impact_pct >= r.baseline_impact_pct,
                "{:?}: swan {:.1}% worse than baseline {:.1}%",
                r.device,
                r.swan_impact_pct,
                r.baseline_impact_pct
            );
        }
        // and strictly better somewhere meaningful (paper: Pixel 3
        // −27% → −3.1%)
        let p3 = rows
            .iter()
            .find(|r| r.device == DeviceId::Pixel3)
            .unwrap();
        assert!(
            p3.swan_impact_pct > p3.baseline_impact_pct + 5.0,
            "pixel3: swan {:.1}% vs baseline {:.1}%",
            p3.swan_impact_pct,
            p3.baseline_impact_pct
        );
    }

    #[test]
    fn swan_migrates_off_contended_cores() {
        let (rows, _) = table3_rows("artifacts");
        for r in &rows {
            // under a persistent 2-thread session the settled choice must
            // not be the full greedy set
            assert!(
                r.swan_settled_choice.len() < 4,
                "{:?}: settled on {}",
                r.device,
                r.swan_settled_choice
            );
        }
    }
}
