//! Serve-plane evaluation emitter: the coordinator control plane's
//! request-throughput table (check-ins/sec, p90 check-in latency,
//! deferral rate) — the `swan bench serve` CLI path renders through
//! here.

use crate::serve::ServeRunOutcome;
use crate::util::bench::fmt_secs;
use crate::util::table::Table;

/// Render serve load-generator outcomes as a table (one row per run —
/// typically the in-process and loopback-TCP paths of one bench).
pub fn serve_table(outcomes: &[&ServeRunOutcome]) -> Table {
    let mut t = Table::new(
        "Serve control plane — request throughput and admission",
        &[
            "scenario",
            "transport",
            "devices",
            "lanes",
            "rounds",
            "checkins",
            "admitted",
            "deferred",
            "parts",
            "checkins_per_s",
            "p90_checkin",
            "virtual_h",
            "energy_kJ",
        ],
    );
    for o in outcomes {
        t.row(&[
            o.scenario.clone(),
            o.transport.to_string(),
            o.devices.to_string(),
            o.lanes.to_string(),
            o.rounds_run.to_string(),
            o.checkins.to_string(),
            o.admitted.to_string(),
            o.deferred.to_string(),
            o.participations.to_string(),
            format!("{:.0}", o.checkins_per_sec()),
            fmt_secs(o.p90_checkin_latency_s()),
            format!("{:.2}", o.total_time_s / 3600.0),
            format!("{:.1}", o.total_energy_j / 1e3),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_has_one_row_per_outcome() {
        let a = ServeRunOutcome {
            scenario: "smoke".into(),
            transport: "inproc",
            devices: 2_000,
            lanes: 4,
            rounds_run: 5,
            checkins: 5_000,
            admitted: 5_000,
            participations: 500,
            checkin_wall_s: 1.0,
            latency_hist: {
                let mut h = crate::obs::Histogram::default();
                h.observe(1e-5);
                h.observe(2e-5);
                h
            },
            ..Default::default()
        };
        let mut b = a.clone();
        b.transport = "tcp";
        b.deferred = 7;
        let t = serve_table(&[&a, &b]);
        assert_eq!(t.rows.len(), 2);
        let md = t.to_markdown();
        assert!(md.contains("checkins_per_s"));
        assert!(md.contains("tcp"));
        assert!(md.contains("inproc"));
    }
}
