//! Figure 2: per-core-combination latency / energy / power for a
//! (device, model) pair — the motivation study of §3.1.

use crate::soc::device::{device, Device, DeviceId};
use crate::soc::exec_model::{estimate, ExecutionContext};
use crate::swan::choice::enumerate_choices;
use crate::util::table::Table;
use crate::workload::Workload;

/// One row per execution choice: (label, latency s, energy J, power W),
/// normalized columns like the paper's relative plots are added in the
/// table.
pub fn fig2_combo_rows(
    dev: DeviceId,
    workload: &Workload,
) -> (Vec<(String, f64, f64, f64)>, Table) {
    let d: Device = device(dev);
    let ctx = ExecutionContext::exclusive(d.n_cores());
    let mut rows = Vec::new();
    for ch in enumerate_choices(&d) {
        let est = estimate(&d, workload, &ch.cores, &ctx);
        rows.push((
            ch.label(),
            est.latency_s,
            est.energy_j,
            est.avg_power_w,
        ));
    }
    // paper plots relative to the best value of each metric
    let min_lat = rows.iter().map(|r| r.1).fold(f64::INFINITY, f64::min);
    let min_en = rows.iter().map(|r| r.2).fold(f64::INFINITY, f64::min);
    let min_pw = rows.iter().map(|r| r.3).fold(f64::INFINITY, f64::min);
    let mut table = Table::new(
        &format!(
            "Fig 2 — {} on {}: per-combination latency/energy/power",
            workload.name,
            d.id.name()
        ),
        &[
            "combo",
            "latency_s",
            "rel_lat",
            "energy_j",
            "rel_energy",
            "power_w",
            "rel_power",
        ],
    );
    for (label, lat, en, pw) in &rows {
        table.row(&[
            label.clone(),
            format!("{lat:.3}"),
            format!("{:.2}", lat / min_lat),
            format!("{en:.2}"),
            format!("{:.2}", en / min_en),
            format!("{pw:.2}"),
            format!("{:.2}", pw / min_pw),
        ]);
    }
    (rows, table)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{builtin, WorkloadName};

    fn col<'a>(
        rows: &'a [(String, f64, f64, f64)],
        label: &str,
    ) -> &'a (String, f64, f64, f64) {
        rows.iter().find(|r| r.0 == label).unwrap()
    }

    #[test]
    fn fig2a_resnet_pixel3_shapes() {
        let (rows, _) = fig2_combo_rows(
            DeviceId::Pixel3,
            &builtin(WorkloadName::Resnet34),
        );
        assert_eq!(rows.len(), 8);
        // fastest = 4567
        let fastest = rows
            .iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        assert_eq!(fastest.0, "4567");
        // most energy-efficient = a single big core
        let thrifty = rows
            .iter()
            .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
            .unwrap();
        assert_eq!(thrifty.0, "4");
        // little combos always lower power than big combos
        assert!(col(&rows, "0123").3 < col(&rows, "4567").3);
        assert!(col(&rows, "0").3 < col(&rows, "4").3);
    }

    #[test]
    fn fig2b_shufflenet_pixel3_shapes() {
        let (rows, _) = fig2_combo_rows(
            DeviceId::Pixel3,
            &builtin(WorkloadName::ShufflenetV2),
        );
        // single big core both fastest AND most energy-efficient (§3.1)
        let fastest = rows
            .iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        let thrifty = rows
            .iter()
            .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
            .unwrap();
        assert_eq!(fastest.0, "4");
        assert_eq!(thrifty.0, "4");
        // and 4567 is strictly worse than 4 on both axes
        assert!(col(&rows, "4567").1 > col(&rows, "4").1);
        assert!(col(&rows, "4567").2 > col(&rows, "4").2);
    }
}
