//! Observability report emitters: render a drive's phase-span
//! breakdown ([`crate::obs::Spans`]) and counter/histogram registry
//! ([`crate::obs::MetricsRegistry`]) as the repo's standard tables —
//! the human face of the telemetry the NDJSON stream carries for
//! machines. `swan bench fleet` prints the span table under the
//! throughput table so "where did the round wall-clock go" is answered
//! in the same terminal scroll.

use crate::obs::analyze::GapStat;
use crate::obs::{MetricsRegistry, Spans};
use crate::util::bench::fmt_secs;
use crate::util::table::Table;

/// Phase-span breakdown: one row per span, with each phase's share of
/// the total recorded wall time.
pub fn obs_table(title: &str, spans: &Spans) -> Table {
    let mut t = Table::new(
        title,
        &["phase", "count", "total", "mean", "max", "share"],
    );
    let total = spans.total_s();
    for e in spans.entries() {
        let mean = if e.count > 0 {
            e.total_s / e.count as f64
        } else {
            0.0
        };
        let share = if total > 0.0 {
            100.0 * e.total_s / total
        } else {
            0.0
        };
        t.row(&[
            e.name.clone(),
            e.count.to_string(),
            fmt_secs(e.total_s),
            fmt_secs(mean),
            fmt_secs(e.max_s),
            format!("{share:.1}%"),
        ]);
    }
    t
}

/// Counter + histogram summary: counters one row each, histograms as
/// count/mean/p90/max rows.
pub fn obs_metrics_table(title: &str, metrics: &MetricsRegistry) -> Table {
    let mut t = Table::new(
        title,
        &["metric", "count", "mean", "p90", "max"],
    );
    for (name, v) in metrics.counters() {
        t.row(&[
            name.to_string(),
            v.to_string(),
            "-".to_string(),
            "-".to_string(),
            "-".to_string(),
        ]);
    }
    for (name, h) in metrics.histograms() {
        t.row(&[
            name.to_string(),
            h.count().to_string(),
            fmt_secs(h.mean()),
            fmt_secs(h.quantile(0.90)),
            fmt_secs(h.max()),
        ]);
    }
    t
}

/// Top-K attribution table for `swan obs top`: one row per key (a
/// pipeline stage or a `rR/dD` device) from the analysis engine's
/// [`GapStat`] aggregates, already sorted slowest-first by the caller.
pub fn obs_top_table(
    title: &str,
    rows: &[(String, GapStat)],
) -> Table {
    let mut t = Table::new(
        title,
        &["key", "count", "total", "mean", "max", "share"],
    );
    let total: f64 = rows.iter().map(|(_, s)| s.total_s).sum();
    for (key, s) in rows {
        let share = if total > 0.0 {
            100.0 * s.total_s / total
        } else {
            0.0
        };
        t.row(&[
            key.clone(),
            s.count.to_string(),
            fmt_secs(s.total_s),
            fmt_secs(s.mean_s()),
            fmt_secs(s.max_s),
            format!("{share:.1}%"),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_table_reports_shares_that_sum_to_one() {
        let mut spans = Spans::default();
        let a = spans.span("availability");
        let b = spans.span("step");
        spans.record(a, 1.0);
        spans.record(b, 3.0);
        let t = obs_table("spans", &spans);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][5], "25.0%");
        assert_eq!(t.rows[1][5], "75.0%");
        let md = t.to_markdown();
        assert!(md.contains("availability"));
    }

    #[test]
    fn metrics_table_mixes_counters_and_histograms() {
        let mut m = MetricsRegistry::default();
        m.inc("fleet.online", 42);
        let h = m.hist("fleet.round_wall_s", crate::obs::LATENCY_BUCKETS_S);
        m.observe(h, 2e-3);
        let t = obs_metrics_table("metrics", &m);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][0], "fleet.online");
        assert_eq!(t.rows[0][1], "42");
        assert_eq!(t.rows[1][0], "fleet.round_wall_s");
        assert_eq!(t.rows[1][1], "1");
    }

    #[test]
    fn empty_inputs_render_headers_only() {
        assert!(obs_table("t", &Spans::default()).rows.is_empty());
        assert!(obs_metrics_table("t", &MetricsRegistry::default())
            .rows
            .is_empty());
        assert!(obs_top_table("t", &[]).rows.is_empty());
    }

    #[test]
    fn top_table_shares_follow_totals() {
        let mut a = GapStat::default();
        a.add(3.0);
        let mut b = GapStat::default();
        b.add(0.5);
        b.add(0.5);
        let rows = vec![
            ("admitted\u{2192}selected".to_string(), a),
            ("checkin\u{2192}admitted".to_string(), b),
        ];
        let t = obs_top_table("top stages", &rows);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][0], "admitted\u{2192}selected");
        assert_eq!(t.rows[0][1], "1");
        assert_eq!(t.rows[0][5], "75.0%");
        assert_eq!(t.rows[1][1], "2");
        assert_eq!(t.rows[1][5], "25.0%");
    }
}
