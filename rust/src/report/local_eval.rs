//! Table 2: local speedup and energy-efficiency of Swan's explored best
//! choice over the PyTorch greedy baseline, per device × model.
//!
//! This is a *measured* experiment, not a pure model read-out: for each
//! (device, model) a simulated phone is brought up, Swan runs the full
//! §4.2 exploration with Appendix-B battery-drop energy attribution, and
//! the resulting best profile is compared against the greedy choice
//! benchmarked the same way.

use crate::sim::SimPhone;
use crate::soc::device::{all_devices, DeviceId};
use crate::swan::choice::ExecutionChoice;
use crate::swan::explorer::Explorer;
use crate::util::table::{fmt_ratio, Table};
use crate::workload::{load_or_builtin, WorkloadName};

#[derive(Clone, Debug)]
pub struct Table2Row {
    pub device: DeviceId,
    pub model: &'static str,
    pub speedup: f64,
    pub energy_eff: f64,
    pub swan_choice: String,
    pub baseline_choice: String,
}

const MODELS: [(WorkloadName, &str); 3] = [
    (WorkloadName::Resnet34, "Resnet34"),
    (WorkloadName::ShufflenetV2, "ShuffleNet"),
    (WorkloadName::MobilenetV2, "MobileNet"),
];

/// Compute all 15 Table-2 cells (5 devices × 3 models).
pub fn table2_rows(artifacts_dir: &str) -> (Vec<Table2Row>, Table) {
    let mut rows = Vec::new();
    for d in all_devices() {
        for (wl, model_name) in MODELS {
            let workload = load_or_builtin(wl, artifacts_dir);
            let explorer = Explorer::default();

            // Swan: explore everything on an idle phone, take the best
            let mut phone = SimPhone::new(d.clone(), 0xBEEF + d.id.key().len() as u64);
            let profiles = explorer.explore_all(&mut phone, &workload);
            let best = profiles
                .iter()
                .min_by(|a, b| a.latency_s.partial_cmp(&b.latency_s).unwrap())
                .unwrap();

            // Baseline: greedy choice benchmarked identically
            let greedy_choice =
                ExecutionChoice::new(&d, d.low_latency_cores());
            let mut phone_b = SimPhone::new(d.clone(), 0xF00D);
            let greedy = explorer
                .explore_choice(&mut phone_b, &workload, &greedy_choice, 5)
                .profile;

            rows.push(Table2Row {
                device: d.id,
                model: model_name,
                speedup: greedy.latency_s / best.latency_s,
                energy_eff: greedy.energy_j / best.energy_j.max(1e-12),
                swan_choice: best.choice.label(),
                baseline_choice: greedy_choice.label(),
            });
        }
    }
    let mut table = Table::new(
        "Table 2 — local speedup and energy efficiency over baseline",
        &["device", "model", "speedup", "energy_eff", "swan_choice", "baseline"],
    );
    for r in &rows {
        table.row(&[
            r.device.name().to_string(),
            r.model.to_string(),
            fmt_ratio(r.speedup),
            fmt_ratio(r.energy_eff),
            r.swan_choice.clone(),
            r.baseline_choice.clone(),
        ]);
    }
    (rows, table)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows() -> Vec<Table2Row> {
        table2_rows("artifacts").0
    }

    fn cell<'a>(rows: &'a [Table2Row], dev: DeviceId, model: &str) -> &'a Table2Row {
        rows.iter()
            .find(|r| r.device == dev && r.model == model)
            .unwrap()
    }

    #[test]
    fn swan_never_loses() {
        for r in rows() {
            assert!(
                r.speedup >= 0.999,
                "{:?}/{}: swan slower than baseline ({:.2})",
                r.device,
                r.model,
                r.speedup
            );
        }
    }

    #[test]
    fn pixel3_resnet_is_the_tie() {
        // paper: 1× — greedy already optimal on Pixel 3 for ResNet-34
        let rs = rows();
        let r = cell(&rs, DeviceId::Pixel3, "Resnet34");
        assert!(r.speedup < 1.05, "expected tie, got {:.2}", r.speedup);
        assert_eq!(r.swan_choice, r.baseline_choice);
    }

    #[test]
    fn depthwise_models_win_big_on_8core_devices() {
        // paper: 17–39× speedups for ShuffleNet/MobileNet off-Pixel3
        let rs = rows();
        for dev in [DeviceId::S10e, DeviceId::OnePlus8, DeviceId::TabS6,
                    DeviceId::Mi10] {
            for model in ["ShuffleNet", "MobileNet"] {
                let r = cell(&rs, dev, model);
                assert!(
                    r.speedup > 5.0,
                    "{dev:?}/{model}: speedup only {:.1}",
                    r.speedup
                );
                assert!(
                    r.energy_eff > 2.0,
                    "{dev:?}/{model}: energy eff only {:.1}",
                    r.energy_eff
                );
            }
        }
    }

    #[test]
    fn s10e_shufflenet_is_the_headline() {
        // paper's biggest cell: 39× on S10e ShuffleNet; ours must be the
        // max of the ShuffleNet column and >10×
        let rs = rows();
        let s10e = cell(&rs, DeviceId::S10e, "ShuffleNet").speedup;
        assert!(s10e > 10.0, "headline speedup only {s10e:.1}");
        for dev in [DeviceId::Pixel3, DeviceId::OnePlus8, DeviceId::TabS6,
                    DeviceId::Mi10] {
            assert!(
                cell(&rs, dev, "ShuffleNet").speedup <= s10e,
                "{dev:?} beats the S10e headline"
            );
        }
    }

    #[test]
    fn pixel3_wins_smallest() {
        // paper: Pixel 3 column is 1×/1.8×/1.6× — smallest per model
        let rs = rows();
        for model in ["Resnet34", "ShuffleNet", "MobileNet"] {
            let p3 = cell(&rs, DeviceId::Pixel3, model).speedup;
            for dev in [DeviceId::S10e, DeviceId::OnePlus8, DeviceId::TabS6,
                        DeviceId::Mi10] {
                assert!(
                    p3 <= cell(&rs, dev, model).speedup + 1e-9,
                    "{model}: pixel3 ({p3:.1}) not the smallest win"
                );
            }
        }
    }

    #[test]
    fn swan_prefers_single_core_for_depthwise_models() {
        let rs = rows();
        for dev in [DeviceId::S10e, DeviceId::OnePlus8] {
            let r = cell(&rs, dev, "ShuffleNet");
            assert_eq!(
                r.swan_choice.len(),
                1,
                "{dev:?}: expected single-core choice, got {}",
                r.swan_choice
            );
        }
    }
}
