//! Fleet-scale evaluation emitter: devices-stepped/sec throughput and
//! the per-arm fleet aggregates, as a paper-style table. The `swan
//! report fleet` CLI path and `benches/fleet_throughput.rs` both come
//! through here.

use crate::fl::FlArm;
use crate::fleet::{run_scenario, FleetOutcome, ScenarioSpec};
use crate::util::table::Table;

/// Render fleet outcomes as a table (one row per run).
pub fn fleet_table(outcomes: &[FleetOutcome]) -> Table {
    let mut t = Table::new(
        "Fleet simulation — throughput and aggregates",
        &[
            "scenario",
            "arm",
            "devices",
            "shards",
            "rounds",
            "steps",
            "virtual_h",
            "energy_kJ",
            "online_first",
            "online_last",
            "devices_stepped_per_s",
        ],
    );
    for o in outcomes {
        t.row(&[
            o.scenario.clone(),
            o.arm.to_string(),
            o.devices.to_string(),
            o.shards.to_string(),
            o.rounds_run.to_string(),
            o.total_steps.to_string(),
            format!("{:.2}", o.total_time_s / 3600.0),
            format!("{:.1}", o.total_energy_j / 1e3),
            o.online_first().to_string(),
            o.online_last().to_string(),
            format!("{:.0}", o.devices_stepped_per_sec()),
        ]);
    }
    t
}

/// Run both arms of a builtin scenario and build the table.
pub fn fleet_eval_rows(
    scenario: &str,
    shards: usize,
) -> crate::Result<(Vec<FleetOutcome>, Table)> {
    let spec = ScenarioSpec::builtin(scenario)
        .ok_or_else(|| crate::err!("unknown scenario '{scenario}'"))?;
    let mut outs = Vec::new();
    for arm in [FlArm::Swan, FlArm::Baseline] {
        outs.push(run_scenario(&spec, shards, arm)?);
    }
    let table = fleet_table(&outs);
    Ok((outs, table))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_has_one_row_per_outcome() {
        let outs = vec![
            FleetOutcome {
                scenario: "smoke".into(),
                arm: "swan",
                devices: 10,
                ..Default::default()
            },
            FleetOutcome {
                scenario: "smoke".into(),
                arm: "baseline",
                devices: 10,
                ..Default::default()
            },
        ];
        let t = fleet_table(&outs);
        assert_eq!(t.rows.len(), 2);
        let md = t.to_markdown();
        assert!(md.contains("devices_stepped_per_s"));
        assert!(md.contains("baseline"));
    }

    #[test]
    fn unknown_scenario_errors() {
        assert!(fleet_eval_rows("galactic", 2).is_err());
    }
}
