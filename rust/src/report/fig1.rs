//! Figure 1b: per-core 512×512 matmul latency across SoCs (+ GPU).

use crate::soc::device::all_devices;
use crate::soc::exec_model::{estimate, estimate_gpu, ExecutionContext};
use crate::util::table::Table;
use crate::workload::{builtin, WorkloadName};

/// One row per (device, core|gpu): label + latency in ms.
pub fn fig1b_matmul_rows() -> (Vec<(String, String, f64)>, Table) {
    let w = builtin(WorkloadName::Matmul512);
    let mut rows = Vec::new();
    let mut table = Table::new(
        "Fig 1b — per-core 512x512 matmul latency (ms, simulated)",
        &["device", "unit", "latency_ms"],
    );
    for d in all_devices() {
        let ctx = ExecutionContext::exclusive(d.n_cores());
        for c in 0..d.n_cores() {
            let est = estimate(&d, &w, &[c], &ctx);
            let ms = est.latency_s * 1e3;
            rows.push((
                d.id.key().to_string(),
                format!("core{c}({})", d.cores[c].kind),
                ms,
            ));
            table.row(&[
                d.id.name().to_string(),
                format!("core {c} ({})", d.cores[c].kind),
                format!("{ms:.2}"),
            ]);
        }
        let gpu = estimate_gpu(&d, &w);
        let ms = gpu.latency_s * 1e3;
        rows.push((d.id.key().to_string(), "gpu".to_string(), ms));
        table.row(&[
            d.id.name().to_string(),
            "GPU".to_string(),
            format!("{ms:.2}"),
        ]);
    }
    (rows, table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_paper() {
        let (rows, _t) = fig1b_matmul_rows();
        assert_eq!(rows.len(), 5 * 9); // 8 cores + gpu per device
        // within each device: little slower than big, gpu fastest
        for dev in ["pixel3", "s10e", "oneplus8", "tabs6", "mi10"] {
            let lat = |unit_prefix: &str| {
                rows.iter()
                    .find(|(d, u, _)| d == dev && u.starts_with(unit_prefix))
                    .unwrap()
                    .2
            };
            assert!(lat("core0") > lat("core4"), "{dev}: little ≤ big?");
            assert!(lat("gpu") < lat("core7"), "{dev}: gpu not fastest");
        }
    }

    #[test]
    fn prime_faster_than_big_where_present() {
        let (rows, _) = fig1b_matmul_rows();
        for dev in ["s10e", "oneplus8", "tabs6", "mi10"] {
            let core7 = rows
                .iter()
                .find(|(d, u, _)| d == dev && u.starts_with("core7"))
                .unwrap()
                .2;
            let core4 = rows
                .iter()
                .find(|(d, u, _)| d == dev && u.starts_with("core4"))
                .unwrap()
                .2;
            assert!(core7 < core4, "{dev}");
        }
    }
}
