//! # Swan — a neural engine for efficient DNN training on smartphone SoCs
//!
//! Reproduction of *Swan* (Singapuram et al., 2022) as a three-layer
//! Rust + JAX + Pallas stack. This crate is **Layer 3**: the Swan
//! scheduling engine itself, the smartphone-SoC simulator it schedules
//! on (the paper's testbed, rebuilt — see `DESIGN.md` substitution
//! ledger), the PJRT runtime that executes the AOT-lowered training
//! steps, and the federated-learning harness for the paper's large-scale
//! evaluation.
//!
//! Module map (bottom-up):
//! - [`error`] — the crate-local error type + `err!`/`bail!`/`ensure!`
//!   (the offline crate set has no `anyhow`).
//! - [`util`] — zero-dependency substrates: RNG, JSON, PCHIP, stats,
//!   property-test + bench harnesses (the offline crate set has no
//!   serde/rand/criterion/proptest); [`cli`] — the hand-rolled launcher.
//! - [`soc`], [`power`] — the simulated phone: heterogeneous cores,
//!   cache contention, DVFS, battery/charger/thermal models.
//! - [`workload`] — op-level training-step descriptors (emitted by
//!   `python/compile/workloads.py` at artifact-build time).
//! - [`sim`] — virtual clock, Android cpuset scheduling, foreground
//!   interference sessions, the PCMark-like responsiveness benchmark.
//! - [`swan`] — the paper's contribution: execution choices, the cost
//!   total order, pruning, the explorer and the migration controller.
//! - [`baseline`] — the PyTorch greedy policy Swan is compared against.
//! - [`xla`] — stub of the PJRT bindings (`xla` is not in the offline
//!   crate set); [`runtime`] — PJRT loading/execution of
//!   `artifacts/*.hlo.txt` (real numerics when the bindings are present;
//!   the stub keeps every simulator-only path fully functional).
//! - [`train`], [`trace`], [`fl`] — local trainer + synthetic datasets,
//!   GreenHub-style battery traces, and the FedAvg simulation.
//! - [`fleet`] — the sharded, event-driven fleet simulation kernels:
//!   [`fleet::scenario`] data-driven experiment specs (device-model
//!   mixes, GreenHub trace assignment, charger envelopes, interference
//!   schedules), [`fleet::soa`] the allocation-free struct-of-arrays
//!   kernel that steps 100k–1M devices (flat per-shard state, shared
//!   trace-sample cache, persistent double-buffered workers),
//!   [`fleet::engine`] the generic `ShardedEventLoop` reference kernel
//!   `fl::FlSim` rides, and [`fleet::coordinator`] the §4.2 fleet-scale
//!   exploration amortizer — all bit-identical at any shard count, and
//!   [`fleet::bench`] the throughput harness emitting
//!   `BENCH_fleet.json`.
//! - [`serve`] — the zero-dependency FL coordinator control plane: a
//!   `std::net` TCP listener + thread-per-worker IO pool behind a
//!   compact length-prefixed wire format ([`serve::wire`]: `CheckIn`,
//!   `PlanLease`, `UpdatePush`, `Ack`), batched check-in admission with
//!   explicit `Retry-After` backpressure, an LRU profile cache keyed on
//!   (SoC model, thermal band, charger state) sharing §4.2 exploration
//!   across equivalent devices, FedAvg aggregation through
//!   [`fl::server`], and the fleet repurposed as its load generator
//!   ([`serve::loadgen`]) — in-process and loopback-TCP paths are
//!   digest-parity-checked against a machinery-free oracle
//!   (`BENCH_serve.json`, `swan serve`, `swan bench serve`).
//! - [`obs`] — the zero-dependency telemetry spine: `machine_message`
//!   NDJSON events (`reason` + `seq`, stderr / `--events <path>` /
//!   capture sinks), shard-local counter + fixed-bucket-histogram
//!   registries merged deterministically at round barriers, and scoped
//!   phase spans — all digest-neutral by construction, feeding
//!   `report::obs_table` and the CI perf-floor gate.
//! - [`report`] — emitters that regenerate every paper table and figure.
//! - [`lint`] — `swan lint`: a hand-rolled static analyzer over the
//!   crate's own sources (lexer + syntactic rule scans) that rejects
//!   determinism hazards (wall clock / hash-ordered iteration in
//!   digest-affecting modules), unregistered RNG construction,
//!   panics on worker/IO paths, and undocumented `unsafe` — with
//!   per-site `// lint: allow(rule) — reason` pragmas, wired into CI.

pub mod error;
pub mod util;
pub mod soc;
pub mod power;
pub mod workload;
pub mod sim;
pub mod swan;
pub mod baseline;
pub mod xla;
pub mod runtime;
pub mod train;
pub mod trace;
pub mod fl;
pub mod fleet;
pub mod obs;
pub mod serve;
pub mod report;
pub mod lint;
pub mod cli;

/// Crate-wide result type.
pub type Result<T> = std::result::Result<T, error::Error>;
