//! The simulated smartphone SoC — the paper's testbed, rebuilt.
//!
//! The reproduction band for this paper is 0: no physical phones, no
//! fuel-gauge power rail. Swan's decisions, however, depend only on the
//! *relative* latency / power / energy of core combinations, so this
//! module provides an analytical SoC model calibrated to the five devices
//! the paper evaluates (§5.1). See `DESIGN.md` §1 for the substitution
//! ledger and the calibration rationale.
//!
//! - [`core`] — core kinds (Little / Big / Prime) and per-core specs.
//! - [`device`] — the five-device database (Pixel 3, S10e, OnePlus 8,
//!   Galaxy Tab S6, Mi 10) with SoC topologies from public specs.
//! - [`cache`] — the cache-contention ("thrashing") model behind §3.1.
//! - [`exec_model`] — workload × core-set → (latency, power, energy):
//!   an op-level roofline with OpenMP-static straggler semantics.

pub mod cache;
pub mod core;
pub mod device;
pub mod exec_model;

pub use core::{CoreId, CoreKind, CoreSpec};
pub use device::{Device, DeviceId, all_devices, device};
pub use exec_model::{ExecEstimate, ExecutionContext, estimate};
