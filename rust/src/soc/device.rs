//! The five-device database (paper §5.1 experimental setup).
//!
//! Topologies and clocks come from public SoC specs; per-core GFLOPS are
//! NEON-roofline estimates (flops/cycle × clock); power figures are in
//! the envelope reported for these cores in mobile-SoC literature. The
//! per-device `thrash_beta` is the one *calibrated* parameter: it encodes
//! how violently the shared cache degrades under multi-threaded
//! memory-bound kernels (§3.1), which the paper measured but never
//! modeled — calibrated so the Table-2 improvement *ordering* holds
//! (S10e most severe, Pixel 3 mildest).

use super::core::{CoreKind, CoreSpec};

/// Stable device identifier used on CLIs and in reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DeviceId {
    Pixel3,
    S10e,
    OnePlus8,
    TabS6,
    Mi10,
}

impl DeviceId {
    pub fn parse(s: &str) -> Option<DeviceId> {
        match s.to_ascii_lowercase().as_str() {
            "pixel3" | "pixel-3" => Some(DeviceId::Pixel3),
            "s10e" | "samsungs10e" => Some(DeviceId::S10e),
            "oneplus8" | "op8" => Some(DeviceId::OnePlus8),
            "tabs6" | "galaxytabs6" => Some(DeviceId::TabS6),
            "mi10" | "xiaomimi10" => Some(DeviceId::Mi10),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DeviceId::Pixel3 => "Google Pixel 3",
            DeviceId::S10e => "Samsung S10e",
            DeviceId::OnePlus8 => "OnePlus 8",
            DeviceId::TabS6 => "Galaxy Tab S6",
            DeviceId::Mi10 => "Xiaomi Mi 10",
        }
    }

    pub fn key(&self) -> &'static str {
        match self {
            DeviceId::Pixel3 => "pixel3",
            DeviceId::S10e => "s10e",
            DeviceId::OnePlus8 => "oneplus8",
            DeviceId::TabS6 => "tabs6",
            DeviceId::Mi10 => "mi10",
        }
    }
}

/// A simulated phone's static hardware model.
#[derive(Clone, Debug)]
pub struct Device {
    pub id: DeviceId,
    pub soc: &'static str,
    pub cores: Vec<CoreSpec>,
    /// Shared-cache capacity visible to the training threads, bytes
    /// (cluster L2 + system cache, lumped).
    pub shared_cache_bytes: f64,
    /// DRAM bandwidth, bytes/s.
    pub mem_bw_bytes: f64,
    /// Calibrated multi-thread cache-thrashing severity (see module doc).
    pub thrash_beta: f64,
    /// SoC base (uncore + rails) power with screen off, watts.
    pub base_power_w: f64,
    /// Battery capacity in mAh and pack voltage range for the meter.
    pub battery_mah: f64,
    /// Mobile GPU (Fig 1b only; the training backend is CPU-only, §4.2).
    pub gpu_gflops: f64,
    pub gpu_power_w: f64,
}

impl Device {
    pub fn n_cores(&self) -> usize {
        self.cores.len()
    }

    pub fn kind_of(&self, core: usize) -> CoreKind {
        self.cores[core].kind
    }

    pub fn cores_of_kind(&self, kind: CoreKind) -> Vec<usize> {
        (0..self.cores.len())
            .filter(|&i| self.cores[i].kind == kind)
            .collect()
    }

    /// The cores PyTorch's greedy heuristic uses: all low-latency
    /// (big + prime) cores (§3.1 "as many threads as low-latency cores").
    pub fn low_latency_cores(&self) -> Vec<usize> {
        (0..self.cores.len())
            .filter(|&i| self.cores[i].kind != CoreKind::Little)
            .collect()
    }

    pub fn has_prime(&self) -> bool {
        self.cores.iter().any(|c| c.kind == CoreKind::Prime)
    }
}

/// Build one device model.
pub fn device(id: DeviceId) -> Device {
    match id {
        // Snapdragon 845: 4×A55-deriv @1.77 + 4×A75-deriv @2.5, no prime,
        // LPDDR4X ~14.9 GB/s class. Lowest-end device in the set; its
        // small system cache thrashes least *relative to baseline* because
        // the baseline only has 4 big cores to burn.
        DeviceId::Pixel3 => Device {
            id,
            soc: "Snapdragon 845",
            cores: vec![
                CoreSpec::little("Kryo385-Ag", 1.77, 4.3, 0.40),
                CoreSpec::little("Kryo385-Ag", 1.77, 4.3, 0.40),
                CoreSpec::little("Kryo385-Ag", 1.77, 4.3, 0.40),
                CoreSpec::little("Kryo385-Ag", 1.77, 4.3, 0.40),
                CoreSpec::big("Kryo385-Au", 2.50, 17.5, 1.80),
                CoreSpec::big("Kryo385-Au", 2.50, 17.5, 1.80),
                CoreSpec::big("Kryo385-Au", 2.50, 17.5, 1.80),
                CoreSpec::big("Kryo385-Au", 2.50, 17.5, 1.80),
            ],
            shared_cache_bytes: 2.0e6,
            mem_bw_bytes: 14.9e9,
            thrash_beta: 3.0,
            base_power_w: 0.55,
            battery_mah: 2915.0,
            gpu_gflops: 520.0,
            gpu_power_w: 4.0,
        },
        // Exynos 9820: 4×A55 @1.95 + 2×A75 @2.31 + 2×M4 @2.73.
        // The paper's most thrash-prone device (39× ShuffleNet win).
        DeviceId::S10e => Device {
            id,
            soc: "Exynos 9820",
            cores: vec![
                CoreSpec::little("A55", 1.95, 4.8, 0.42),
                CoreSpec::little("A55", 1.95, 4.8, 0.42),
                CoreSpec::little("A55", 1.95, 4.8, 0.42),
                CoreSpec::little("A55", 1.95, 4.8, 0.42),
                CoreSpec::big("A75", 2.31, 17.0, 1.65),
                CoreSpec::big("A75", 2.31, 17.0, 1.65),
                CoreSpec::prime("M4", 2.73, 24.0, 2.70),
                CoreSpec::prime("M4", 2.73, 24.0, 2.70),
            ],
            shared_cache_bytes: 3.0e6,
            mem_bw_bytes: 24.0e9,
            thrash_beta: 80.0,
            base_power_w: 0.50,
            battery_mah: 3100.0,
            gpu_gflops: 600.0,
            gpu_power_w: 4.2,
        },
        // Snapdragon 865: 4×A55 @1.8 + 3×A77 @2.42 + 1×A77 prime @2.84,
        // LPDDR5.
        DeviceId::OnePlus8 => Device {
            id,
            soc: "Snapdragon 865",
            cores: vec![
                CoreSpec::little("A55", 1.80, 4.5, 0.40),
                CoreSpec::little("A55", 1.80, 4.5, 0.40),
                CoreSpec::little("A55", 1.80, 4.5, 0.40),
                CoreSpec::little("A55", 1.80, 4.5, 0.40),
                CoreSpec::big("A77", 2.42, 20.0, 1.75),
                CoreSpec::big("A77", 2.42, 20.0, 1.75),
                CoreSpec::big("A77", 2.42, 20.0, 1.75),
                CoreSpec::prime("A77", 2.84, 23.5, 2.60),
            ],
            shared_cache_bytes: 2.5e6,
            mem_bw_bytes: 25.6e9,
            thrash_beta: 45.0,
            base_power_w: 0.50,
            battery_mah: 4300.0,
            gpu_gflops: 1000.0,
            gpu_power_w: 4.5,
        },
        // Snapdragon 855: 4×A55 @1.78 + 3×A76 @2.42 + 1×A76 prime @2.84.
        DeviceId::TabS6 => Device {
            id,
            soc: "Snapdragon 855",
            cores: vec![
                CoreSpec::little("A55", 1.78, 4.4, 0.40),
                CoreSpec::little("A55", 1.78, 4.4, 0.40),
                CoreSpec::little("A55", 1.78, 4.4, 0.40),
                CoreSpec::little("A55", 1.78, 4.4, 0.40),
                CoreSpec::big("A76", 2.42, 19.0, 1.70),
                CoreSpec::big("A76", 2.42, 19.0, 1.70),
                CoreSpec::big("A76", 2.42, 19.0, 1.70),
                CoreSpec::prime("A76", 2.84, 22.5, 2.50),
            ],
            shared_cache_bytes: 2.5e6,
            mem_bw_bytes: 17.0e9,
            thrash_beta: 42.0,
            base_power_w: 0.65, // tablet: larger board
            battery_mah: 7040.0,
            gpu_gflops: 900.0,
            gpu_power_w: 4.5,
        },
        // Snapdragon 865 again (Mi 10) — same CPU complex as OnePlus 8,
        // slightly different memory/thermal tuning.
        DeviceId::Mi10 => Device {
            id,
            soc: "Snapdragon 865",
            cores: vec![
                CoreSpec::little("A55", 1.80, 4.5, 0.40),
                CoreSpec::little("A55", 1.80, 4.5, 0.40),
                CoreSpec::little("A55", 1.80, 4.5, 0.40),
                CoreSpec::little("A55", 1.80, 4.5, 0.40),
                CoreSpec::big("A77", 2.42, 20.0, 1.75),
                CoreSpec::big("A77", 2.42, 20.0, 1.75),
                CoreSpec::big("A77", 2.42, 20.0, 1.75),
                CoreSpec::prime("A77", 2.84, 23.5, 2.60),
            ],
            shared_cache_bytes: 2.5e6,
            mem_bw_bytes: 27.0e9,
            thrash_beta: 45.0,
            base_power_w: 0.48,
            battery_mah: 4780.0,
            gpu_gflops: 1000.0,
            gpu_power_w: 4.5,
        },
    }
}

/// All five devices, in the paper's Table-2 row order.
pub fn all_devices() -> Vec<Device> {
    vec![
        device(DeviceId::TabS6),
        device(DeviceId::OnePlus8),
        device(DeviceId::Pixel3),
        device(DeviceId::S10e),
        device(DeviceId::Mi10),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_devices_eight_cores_each() {
        let all = all_devices();
        assert_eq!(all.len(), 5);
        for d in &all {
            assert_eq!(d.n_cores(), 8, "{}", d.id.name());
            assert_eq!(d.cores_of_kind(CoreKind::Little).len(), 4);
        }
    }

    #[test]
    fn pixel3_has_no_prime_core() {
        assert!(!device(DeviceId::Pixel3).has_prime());
        assert!(device(DeviceId::OnePlus8).has_prime());
        assert!(device(DeviceId::S10e).has_prime());
    }

    #[test]
    fn low_latency_cores_match_paper() {
        // PyTorch greedy = #big+prime threads; 4 on every device here
        for d in all_devices() {
            assert_eq!(d.low_latency_cores().len(), 4, "{}", d.id.name());
            for c in d.low_latency_cores() {
                assert!(c >= 4);
            }
        }
    }

    #[test]
    fn big_cores_faster_and_hungrier_than_little() {
        for d in all_devices() {
            let l = &d.cores[0];
            let b = &d.cores[4];
            assert!(b.peak_gflops > 3.0 * l.peak_gflops);
            assert!(b.power_active_w > 3.0 * l.power_active_w);
        }
    }

    #[test]
    fn parse_roundtrip() {
        for d in all_devices() {
            assert_eq!(DeviceId::parse(d.id.key()), Some(d.id));
        }
        assert_eq!(DeviceId::parse("nokia3310"), None);
    }

    #[test]
    fn s10e_thrashes_hardest_pixel3_least() {
        let betas: Vec<(f64, &str)> = all_devices()
            .iter()
            .map(|d| (d.thrash_beta, d.id.key()))
            .collect();
        let s10e = betas.iter().find(|b| b.1 == "s10e").unwrap().0;
        let pixel3 = betas.iter().find(|b| b.1 == "pixel3").unwrap().0;
        for (b, k) in &betas {
            if *k != "s10e" {
                assert!(*b < s10e, "{k}");
            }
            if *k != "pixel3" {
                assert!(*b > pixel3, "{k}");
            }
        }
    }
}
