//! Workload × core-set → (latency, power, energy).
//!
//! An op-level roofline with three mobile-specific twists the paper's
//! measurements hinge on:
//!
//! 1. **OpenMP-static straggler semantics.** PyTorch's CPU backend splits
//!    each op evenly across its threads, so a heterogeneous core set runs
//!    at the pace of its *slowest* member — which is why mixing little
//!    cores into a big-core combo makes training slower, and why the
//!    paper's choice space is ordered rather than "more cores = better".
//! 2. **Per-core stream bandwidth.** A single mobile core cannot saturate
//!    DRAM; memory-bound ops gain bandwidth with threads — until twist 3.
//! 3. **Cache thrashing** (`soc::cache`): memory-bound ops (depthwise
//!    conv above all) slow down super-linearly with thread count, giving
//!    Fig 2b's anti-scaling and the huge Table-2 wins on ShuffleNet.
//!
//! Power integrates per-op: active cores burn `power_active_w` scaled by
//! their duty cycle within the op (stragglers keep fast cores idle), with
//! memory-stalled cycles burning a calibrated fraction of active power.

use super::cache::thrash_multiplier;
use super::device::Device;
use crate::workload::Workload;

/// Fractional parallel-sync overhead per extra thread (OpenMP barrier +
/// work-imbalance); calibrated so 4 homogeneous cores give ≈2.9×.
const SYNC_OVERHEAD_PER_THREAD: f64 = 0.12;
/// Fraction of DRAM bandwidth one big core's load/store stream reaches.
const BIG_STREAM_FRACTION: f64 = 0.35;
/// Same for a little core (narrower LSQ, lower clock).
const LITTLE_STREAM_FRACTION: f64 = 0.15;
/// Power burned while memory-stalled, as a fraction of active power.
const STALL_POWER_FRACTION: f64 = 0.55;
/// Fraction of a matmul-class op's peak the NEON pipes sustain.
const COMPUTE_EFFICIENCY: f64 = 0.85;
/// Per-extra-core active-power inflation: multi-core residency holds the
/// cluster at a higher DVFS voltage and OpenMP spin-waits burn cycles at
/// barriers, so per-core power rises with thread count. This is why a
/// single big core is the most energy-efficient choice for ResNet-34 in
/// Fig 2a even though four cores are ~3× faster.
const MULTI_CORE_POWER_PENALTY: f64 = 0.08;

/// Per-core availability (1.0 = exclusive use; lower when the Android
/// scheduler timeslices the training thread against other apps).
#[derive(Clone, Debug)]
pub struct ExecutionContext {
    pub share: Vec<f64>,
}

impl ExecutionContext {
    pub fn exclusive(n_cores: usize) -> Self {
        ExecutionContext {
            share: vec![1.0; n_cores],
        }
    }

    pub fn with_share(share: Vec<f64>) -> Self {
        ExecutionContext { share }
    }
}

/// Simulated cost of one training step (or one benchmark op).
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecEstimate {
    /// Wall-clock seconds for one step.
    pub latency_s: f64,
    /// Joules for one step (SoC base power included).
    pub energy_j: f64,
    /// Mean power over the step, watts.
    pub avg_power_w: f64,
    /// Peak per-op power over the step, watts.
    pub peak_power_w: f64,
}

/// Estimate one training step of `workload` on `cores` of `device`.
///
/// `cores` is the execution choice (paper's "0123", "4567", …);
/// panics on empty or out-of-range core sets (programmer error).
pub fn estimate(
    device: &Device,
    workload: &Workload,
    cores: &[usize],
    ctx: &ExecutionContext,
) -> ExecEstimate {
    assert!(!cores.is_empty(), "empty execution choice");
    for &c in cores {
        assert!(c < device.n_cores(), "core {c} out of range");
    }
    let n = cores.len();
    let par_factor = 1.0 + SYNC_OVERHEAD_PER_THREAD * (n as f64 - 1.0);

    // effective per-core compute throughput under scheduler shares
    let eff_gflops: Vec<f64> = cores
        .iter()
        .map(|&c| {
            device.cores[c].peak_gflops
                * 1e9
                * COMPUTE_EFFICIENCY
                * ctx.share.get(c).copied().unwrap_or(1.0).max(1e-3)
        })
        .collect();
    let slowest = eff_gflops.iter().cloned().fold(f64::INFINITY, f64::min);

    // aggregate stream bandwidth for this core set
    let stream_bw: f64 = cores
        .iter()
        .map(|&c| {
            let frac = match device.cores[c].kind {
                super::core::CoreKind::Little => LITTLE_STREAM_FRACTION,
                _ => BIG_STREAM_FRACTION,
            };
            frac * device.mem_bw_bytes
                * ctx.share.get(c).copied().unwrap_or(1.0).max(1e-3)
        })
        .sum::<f64>()
        .min(device.mem_bw_bytes);

    let mut total_time = 0.0;
    let mut active_energy = 0.0;
    let mut peak_power = 0.0f64;

    for op in &workload.ops {
        // compute wall: even split, straggler-paced
        let t_compute = (op.flops / n as f64) * par_factor / slowest;
        // memory wall: shared bandwidth + contention blowup
        let thrash = thrash_multiplier(
            op.kind,
            n,
            op.bytes,
            device.shared_cache_bytes,
            device.thrash_beta,
        );
        let t_mem = op.bytes * thrash / stream_bw;
        let t_op = t_compute.max(t_mem).max(1e-12);

        // per-core duty cycle within this op
        let mut p_op = 0.0;
        for (i, &c) in cores.iter().enumerate() {
            let spec = &device.cores[c];
            let duty = if t_compute >= t_mem {
                // compute-bound: core i busy for its own share of work
                ((op.flops / n as f64) * par_factor / eff_gflops[i]) / t_op
            } else {
                // memory-bound: all threads run the whole op, stalled
                STALL_POWER_FRACTION
            };
            let p_active = spec.power_active_w
                * (1.0 + MULTI_CORE_POWER_PENALTY * (n as f64 - 1.0));
            p_op += spec.power_idle_w
                + (p_active - spec.power_idle_w) * duty.min(1.0);
        }
        peak_power = peak_power.max(p_op + device.base_power_w);
        total_time += t_op;
        active_energy += p_op * t_op;
    }

    let energy = active_energy + device.base_power_w * total_time;
    ExecEstimate {
        latency_s: total_time,
        energy_j: energy,
        avg_power_w: energy / total_time,
        peak_power_w: peak_power,
    }
}

/// Fig 1b helper: time a single op on the mobile GPU.
pub fn estimate_gpu(device: &Device, workload: &Workload) -> ExecEstimate {
    const GPU_EFFICIENCY: f64 = 0.35;
    let mut total = 0.0;
    for op in &workload.ops {
        let t_c = op.flops / (device.gpu_gflops * 1e9 * GPU_EFFICIENCY);
        let t_m = op.bytes / device.mem_bw_bytes;
        total += t_c.max(t_m);
    }
    let power = device.gpu_power_w + device.base_power_w;
    ExecEstimate {
        latency_s: total,
        energy_j: power * total,
        avg_power_w: power,
        peak_power_w: power,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::device::{device, DeviceId};
    use crate::util::check::check;
    use crate::workload::{builtin, WorkloadName};

    fn pixel3() -> Device {
        device(DeviceId::Pixel3)
    }

    fn ex(d: &Device) -> ExecutionContext {
        ExecutionContext::exclusive(d.n_cores())
    }

    #[test]
    fn resnet_all_big_cores_fastest() {
        // Fig 2a: 4567 is the fastest choice for ResNet-34 on Pixel 3
        let d = pixel3();
        let w = builtin(WorkloadName::Resnet34);
        let ctx = ex(&d);
        let t = |cores: &[usize]| estimate(&d, &w, cores, &ctx).latency_s;
        let t4567 = t(&[4, 5, 6, 7]);
        for combo in [
            vec![4, 5, 6],
            vec![4, 5],
            vec![4],
            vec![0, 1, 2, 3],
            vec![0],
        ] {
            assert!(t4567 < t(&combo), "{combo:?} beat 4567");
        }
    }

    #[test]
    fn resnet_single_big_most_energy_efficient_of_big_combos() {
        // Fig 2a: energy-best is a single low-latency core
        let d = pixel3();
        let w = builtin(WorkloadName::Resnet34);
        let ctx = ex(&d);
        let e = |cores: &[usize]| estimate(&d, &w, cores, &ctx).energy_j;
        assert!(e(&[4]) < e(&[4, 5, 6, 7]));
        assert!(e(&[4]) < e(&[4, 5, 6]));
        assert!(e(&[4]) < e(&[4, 5]));
    }

    #[test]
    fn little_cores_lowest_power_not_lowest_energy() {
        // §3.1: "low power usage does not translate to low energy usage"
        let d = pixel3();
        let w = builtin(WorkloadName::Resnet34);
        let ctx = ex(&d);
        let big = estimate(&d, &w, &[4], &ctx);
        let little = estimate(&d, &w, &[0], &ctx);
        assert!(little.avg_power_w < big.avg_power_w);
        assert!(little.energy_j > big.energy_j);
    }

    #[test]
    fn shufflenet_single_big_beats_all_big() {
        // Fig 2b: ShuffleNet anti-scales — one big core is both faster
        // and more energy-efficient than all four
        let d = pixel3();
        let w = builtin(WorkloadName::ShufflenetV2);
        let ctx = ex(&d);
        let one = estimate(&d, &w, &[4], &ctx);
        let four = estimate(&d, &w, &[4, 5, 6, 7], &ctx);
        assert!(one.latency_s < four.latency_s, "dw thrash must anti-scale");
        assert!(one.energy_j < four.energy_j);
    }

    #[test]
    fn resnet_scales_where_shufflenet_does_not() {
        let d = pixel3();
        let ctx = ex(&d);
        let rn = builtin(WorkloadName::Resnet34);
        let sn = builtin(WorkloadName::ShufflenetV2);
        let speedup = |w: &Workload| {
            estimate(&d, w, &[4], &ctx).latency_s
                / estimate(&d, w, &[4, 5, 6, 7], &ctx).latency_s
        };
        assert!(speedup(&rn) > 2.0, "resnet speedup {}", speedup(&rn));
        assert!(speedup(&sn) < 1.0, "shufflenet speedup {}", speedup(&sn));
    }

    #[test]
    fn heterogeneous_combo_straggles() {
        // adding a little core to a big core should NOT speed things up
        // for compute-bound work (equal split → little core straggles)
        let d = pixel3();
        let w = builtin(WorkloadName::Resnet34);
        let ctx = ex(&d);
        let t_big = estimate(&d, &w, &[4], &ctx).latency_s;
        let t_mixed = estimate(&d, &w, &[0, 4], &ctx).latency_s;
        assert!(t_mixed > 0.9 * t_big, "mixed {t_mixed} vs big {t_big}");
    }

    #[test]
    fn reduced_share_slows_down() {
        let d = pixel3();
        let w = builtin(WorkloadName::Resnet34);
        let full = estimate(&d, &w, &[4, 5], &ex(&d));
        let mut share = vec![1.0; d.n_cores()];
        share[4] = 0.5; // foreground app stealing half of core 4
        let contended =
            estimate(&d, &w, &[4, 5], &ExecutionContext::with_share(share));
        assert!(contended.latency_s > 1.5 * full.latency_s);
    }

    #[test]
    fn estimates_are_positive_and_consistent() {
        check(100, |rng| {
            let ids = [
                DeviceId::Pixel3,
                DeviceId::S10e,
                DeviceId::OnePlus8,
                DeviceId::TabS6,
                DeviceId::Mi10,
            ];
            let d = device(ids[rng.index(5)]);
            let w = builtin(
                [
                    WorkloadName::Resnet34,
                    WorkloadName::MobilenetV2,
                    WorkloadName::ShufflenetV2,
                ][rng.index(3)],
            );
            let n = 1 + rng.index(d.n_cores());
            let cores = rng.sample_indices(d.n_cores(), n);
            let est = estimate(&d, &w, &cores, &ExecutionContext::exclusive(8));
            crate::prop_assert!(est.latency_s > 0.0, "latency");
            crate::prop_assert!(est.energy_j > 0.0, "energy");
            crate::prop_assert!(
                est.peak_power_w >= est.avg_power_w * 0.99,
                "peak {} < avg {}",
                est.peak_power_w,
                est.avg_power_w
            );
            crate::prop_assert!(
                (est.energy_j / est.latency_s - est.avg_power_w).abs()
                    < 1e-6 * est.avg_power_w.max(1.0),
                "P*t != E"
            );
            Ok(())
        });
    }

    #[test]
    fn gpu_beats_single_core_on_matmul() {
        // Fig 1b: the Adreno GPU multiplies 512×512 far faster than any core
        let d = pixel3();
        let w = builtin(WorkloadName::Matmul512);
        let gpu = estimate_gpu(&d, &w);
        let cpu = estimate(&d, &w, &[7], &ex(&d));
        assert!(gpu.latency_s < cpu.latency_s / 3.0);
    }

    #[test]
    fn step_latency_in_plausible_mobile_range() {
        // sanity: batch-16 resnet34 train step on a phone is O(seconds)
        let d = pixel3();
        let w = builtin(WorkloadName::Resnet34);
        let t = estimate(&d, &w, &[4, 5, 6, 7], &ex(&d)).latency_s;
        assert!(t > 0.2 && t < 20.0, "t={t}");
    }
}
