//! Core kinds and per-core specifications.

use std::fmt;

/// Index of a core within its device (matches the paper's "0"–"7" naming:
/// low indices are the low-power cluster).
pub type CoreId = usize;

/// The heterogeneity classes in ARM big.LITTLE(+prime) SoCs (Figure 1a).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum CoreKind {
    /// Low-power, high-latency cluster (Cortex-A5x; paper's cores 0–3).
    Little,
    /// Low-latency performance cluster (Cortex-A7x; paper's cores 4–6/7).
    Big,
    /// Overclocked "Prime" core (e.g. core 7 on SD855/SD865).
    Prime,
}

impl fmt::Display for CoreKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CoreKind::Little => write!(f, "little"),
            CoreKind::Big => write!(f, "big"),
            CoreKind::Prime => write!(f, "prime"),
        }
    }
}

/// Static per-core model parameters.
#[derive(Clone, Debug)]
pub struct CoreSpec {
    pub kind: CoreKind,
    /// Microarchitecture label (documentation only).
    pub uarch: &'static str,
    /// Max clock in GHz.
    pub freq_ghz: f64,
    /// Peak sustained f32 throughput in GFLOP/s at max clock
    /// (NEON: ~4 flops/cycle on A5x, ~8 on A7x-class).
    pub peak_gflops: f64,
    /// Active power at full load, watts.
    pub power_active_w: f64,
    /// Idle (clock-gated) power, watts.
    pub power_idle_w: f64,
}

impl CoreSpec {
    pub fn little(uarch: &'static str, freq_ghz: f64, gflops: f64, pw: f64) -> Self {
        CoreSpec {
            kind: CoreKind::Little,
            uarch,
            freq_ghz,
            peak_gflops: gflops,
            power_active_w: pw,
            power_idle_w: 0.01,
        }
    }

    pub fn big(uarch: &'static str, freq_ghz: f64, gflops: f64, pw: f64) -> Self {
        CoreSpec {
            kind: CoreKind::Big,
            uarch,
            freq_ghz,
            peak_gflops: gflops,
            power_active_w: pw,
            power_idle_w: 0.02,
        }
    }

    pub fn prime(uarch: &'static str, freq_ghz: f64, gflops: f64, pw: f64) -> Self {
        CoreSpec {
            kind: CoreKind::Prime,
            uarch,
            freq_ghz,
            peak_gflops: gflops,
            power_active_w: pw,
            power_idle_w: 0.02,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_ordering_matches_cost_rules() {
        // swan::cost rule 2/3 rely on Little < Big < Prime
        assert!(CoreKind::Little < CoreKind::Big);
        assert!(CoreKind::Big < CoreKind::Prime);
    }

    #[test]
    fn constructors_set_kind() {
        assert_eq!(CoreSpec::little("a55", 1.8, 7.0, 0.45).kind, CoreKind::Little);
        assert_eq!(CoreSpec::big("a76", 2.4, 19.0, 1.7).kind, CoreKind::Big);
        assert_eq!(CoreSpec::prime("a76", 2.84, 23.0, 2.5).kind, CoreKind::Prime);
    }
}
