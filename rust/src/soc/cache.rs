//! Cache-contention ("thrashing") model — the quantitative core of §3.1.
//!
//! The paper's observation: depthwise convolution is memory-intensive, so
//! running it on multiple threads makes them *compete for the shared
//! cache*, and performance collapses instead of scaling ("a known issue
//! addressed on GPUs and Intel CPUs, but not ARM"). We model this as a
//! super-linear slowdown multiplier on memory-bound ops as a function of
//! thread count, scaled by the device's calibrated `thrash_beta`
//! (see `soc::device`) and by how much the op's streaming working set
//! exceeds the shared cache.
//!
//! `thrash(1) == 1` always: a single thread owns the cache exclusively,
//! which is exactly why "one big core" wins for ShuffleNet in Fig 2b.

use crate::workload::OpKind;

/// How strongly an op kind suffers cache contention. Depthwise conv is
/// the pathological case; other elementwise/streaming ops contend for
/// bandwidth but have no reuse to lose, so they degrade far less.
pub fn contention_severity(kind: OpKind) -> f64 {
    match kind {
        OpKind::Dw => 1.0,
        OpKind::Norm | OpKind::Pool | OpKind::Add | OpKind::Act => 0.25,
        OpKind::Update => 0.15,
        // matmul-class ops are tiled to stay cache-resident; they lose
        // almost nothing to co-runners
        OpKind::Conv | OpKind::Pw | OpKind::Linear => 0.005,
    }
}

/// Slowdown multiplier for an op executed by `n_threads` threads whose
/// combined streaming working set is `working_set_bytes`, on a device
/// with `shared_cache_bytes` of cache and thrash severity `beta`.
///
/// Super-linear in n (∝ n²−1): each added thread both shrinks every
/// thread's effective cache share *and* adds a stream that evicts the
/// others — the standard capacity-miss blowup shape for shared LRU
/// caches. Already at n=2 the reuse a single exclusive owner enjoyed is
/// gone, which is exactly Fig 2b's "one big core wins" observation.
pub fn thrash_multiplier(
    kind: OpKind,
    n_threads: usize,
    working_set_bytes: f64,
    shared_cache_bytes: f64,
    beta: f64,
) -> f64 {
    if n_threads <= 1 {
        return 1.0;
    }
    let sev = contention_severity(kind);
    if sev == 0.0 {
        return 1.0;
    }
    // pressure in [0, 1]: fraction of the op's reuse that thrashing can
    // destroy. Once the streaming working set reaches the cache size the
    // damage saturates — adding more working set cannot make the misses
    // worse than "every access misses".
    let pressure = (working_set_bytes / shared_cache_bytes).min(1.0);
    let n = n_threads as f64;
    1.0 + beta * sev * pressure * (n * n - 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    #[test]
    fn single_thread_never_thrashes() {
        for kind in OpKind::ALL {
            assert_eq!(
                thrash_multiplier(kind, 1, 1e9, 2e6, 10.0),
                1.0,
                "{kind:?}"
            );
        }
    }

    #[test]
    fn depthwise_worst_matmul_negligible() {
        let dw = thrash_multiplier(OpKind::Dw, 4, 8e6, 2e6, 4.0);
        let mm = thrash_multiplier(OpKind::Conv, 4, 8e6, 2e6, 4.0);
        assert!(dw > 10.0 * mm, "dw={dw} mm={mm}");
        assert!(mm < 1.5);
    }

    #[test]
    fn monotone_in_threads_and_beta() {
        check(200, |rng| {
            let ws = rng.range(1e5, 1e8);
            let cache = rng.range(1e6, 8e6);
            let beta = rng.range(0.1, 8.0);
            let mut prev = 0.0;
            for n in 1..=8 {
                let t = thrash_multiplier(OpKind::Dw, n, ws, cache, beta);
                crate::prop_assert!(t >= prev, "not monotone at n={n}");
                prev = t;
            }
            let hi = thrash_multiplier(OpKind::Dw, 4, ws, cache, beta * 2.0);
            let lo = thrash_multiplier(OpKind::Dw, 4, ws, cache, beta);
            crate::prop_assert!(hi >= lo, "beta not monotone");
            Ok(())
        });
    }

    #[test]
    fn small_working_set_thrashes_less() {
        let small = thrash_multiplier(OpKind::Dw, 4, 0.5e6, 4e6, 4.0);
        let large = thrash_multiplier(OpKind::Dw, 4, 16e6, 4e6, 4.0);
        assert!(large > 2.0 * small);
    }
}
