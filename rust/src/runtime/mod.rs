//! The PJRT runtime: load AOT-lowered HLO text and execute training steps.
//!
//! Python runs ONCE, at `make artifacts`; from here on the request path is
//! pure Rust → PJRT:
//!
//! ```text
//! PjRtClient::cpu()
//!   → HloModuleProto::from_text_file("artifacts/<model>_train.hlo.txt")
//!   → client.compile(...)
//!   → executable.execute_b(device-resident params ++ [x, y])
//! ```
//!
//! - [`artifact`] — metadata (`artifacts/meta/*.json`) describing each
//!   model's parameter order/shapes/inits and IO layout.
//! - [`client`] — thin PJRT CPU client wrapper.
//! - [`executor`] — compiled train/eval steps with parameters held as
//!   device buffers between steps (the L3 hot path; see §Perf).
//! - [`registry`] — artifact discovery.

pub mod artifact;
pub mod client;
pub mod executor;
pub mod registry;

pub use artifact::{InitKind, ModelMeta, ParamSpec};
pub use client::RuntimeClient;
pub use executor::{ModelExecutor, TrainState};
pub use registry::Registry;
