//! Artifact discovery: find the artifacts directory and list models.

use crate::util::json::parse_file;

pub struct Registry {
    pub dir: std::path::PathBuf,
    pub models: Vec<String>,
}

impl Registry {
    /// Locate artifacts via `SWAN_ARTIFACTS` or by walking up from the
    /// current directory (tests run from the crate root, binaries may
    /// run from anywhere in the workspace).
    pub fn discover() -> crate::Result<Registry> {
        if let Ok(dir) = std::env::var("SWAN_ARTIFACTS") {
            return Self::open(dir);
        }
        let mut cur = std::env::current_dir()?;
        loop {
            let cand = cur.join("artifacts");
            if cand.join("meta").join("index.json").exists() {
                return Self::open(cand);
            }
            if !cur.pop() {
                crate::bail!(
                    "artifacts/ not found — run `make artifacts` first \
                     (or set SWAN_ARTIFACTS)"
                );
            }
        }
    }

    pub fn open(dir: impl Into<std::path::PathBuf>) -> crate::Result<Registry> {
        let dir = dir.into();
        let idx = parse_file(dir.join("meta").join("index.json"))?;
        let models = idx
            .req_arr("models")?
            .iter()
            .filter_map(|m| m.as_str().map(str::to_string))
            .collect();
        Ok(Registry { dir, models })
    }

    pub fn has_model(&self, name: &str) -> bool {
        self.models.iter().any(|m| m == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn discovers_built_artifacts() {
        // unit tests run from the crate root; artifacts may or may not be
        // built — both paths must behave sensibly.
        match Registry::discover() {
            Ok(reg) => {
                assert!(reg.has_model("shufflenet_s"));
                assert!(reg.has_model("resnet_s"));
                assert!(reg.has_model("mobilenet_s"));
                assert!(!reg.has_model("gpt5"));
            }
            Err(e) => {
                assert!(e.to_string().contains("make artifacts"));
            }
        }
    }

    #[test]
    fn open_missing_dir_errors() {
        assert!(Registry::open("/nonexistent/path").is_err());
    }
}
