//! Artifact metadata: the contract between `python/compile/aot.py` and
//! the Rust runtime. The JSON is the single source of truth for
//! parameter order (sorted names), shapes, init schemes and IO layout —
//! the runtime never hardcodes a model.

use crate::util::json::{parse_file, Value};

/// How a parameter tensor is initialized (mirrors `model.SpecBuilder`).
#[derive(Clone, Debug, PartialEq)]
pub enum InitKind {
    /// He-normal with the given fan-in: N(0, sqrt(2/fan_in)).
    He { fan_in: usize },
    Ones,
    Zeros,
}

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: InitKind,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed `artifacts/meta/<model>.json`.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub task: String,
    pub paper_model: String,
    pub batch: usize,
    pub learning_rate: f64,
    pub num_classes: usize,
    /// Full input shape including batch, e.g. [16, 32, 32, 3].
    pub input_shape: Vec<usize>,
    pub label_shape: Vec<usize>,
    pub params: Vec<ParamSpec>,
    pub train_outputs: usize,
    pub eval_outputs: usize,
    pub train_hlo: String,
    pub eval_hlo: String,
    pub workload_key: String,
    pub workload_small_key: String,
}

impl ModelMeta {
    pub fn load(meta_path: impl AsRef<std::path::Path>) -> crate::Result<Self> {
        let v = parse_file(meta_path)?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Value) -> crate::Result<Self> {
        let mut params = Vec::new();
        for p in v.req_arr("params")? {
            let name = p.req_str("name")?.to_string();
            let shape: Vec<usize> = p
                .req_arr("shape")?
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect();
            crate::ensure!(
                shape.iter().all(|&d| d > 0),
                "bad shape for param {name}"
            );
            let init = match p.req_str("init")? {
                "he" => InitKind::He {
                    fan_in: p.req_usize("fan_in")?,
                },
                "ones" => InitKind::Ones,
                "zeros" => InitKind::Zeros,
                other => crate::bail!("unknown init kind '{other}'"),
            };
            params.push(ParamSpec { name, shape, init });
        }
        // aot.py writes sorted names; the executor's positional protocol
        // depends on it, so verify rather than trust.
        for w in params.windows(2) {
            crate::ensure!(
                w[0].name < w[1].name,
                "params not sorted: {} >= {}",
                w[0].name,
                w[1].name
            );
        }
        let art = v.req("artifacts")?;
        Ok(ModelMeta {
            name: v.req_str("name")?.to_string(),
            task: v.req_str("task")?.to_string(),
            paper_model: v.req_str("paper_model")?.to_string(),
            batch: v.req_usize("batch")?,
            learning_rate: v.req_f64("learning_rate")?,
            num_classes: v.req_usize("num_classes")?,
            input_shape: v
                .req_arr("input_shape")?
                .iter()
                .filter_map(|d| d.as_usize())
                .collect(),
            label_shape: v
                .req_arr("label_shape")?
                .iter()
                .filter_map(|d| d.as_usize())
                .collect(),
            params,
            train_outputs: v.req_usize("train_outputs")?,
            eval_outputs: v.req_usize("eval_outputs")?,
            train_hlo: art.req_str("train")?.to_string(),
            eval_hlo: art.req_str("eval")?.to_string(),
            workload_key: v.req_str("workload")?.to_string(),
            workload_small_key: v.req_str("workload_small")?.to_string(),
        })
    }

    pub fn param_scalars(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    pub fn input_numel(&self) -> usize {
        self.input_shape.iter().product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_json() -> &'static str {
        r#"{
          "name": "toy", "task": "vision", "paper_model": "toynet",
          "batch": 4, "learning_rate": 0.05, "num_classes": 3,
          "input_shape": [4, 8, 8, 1], "label_shape": [4],
          "params": [
            {"name": "a.w", "shape": [3, 3, 1, 8], "init": "he", "fan_in": 9},
            {"name": "b.beta", "shape": [8], "init": "zeros"},
            {"name": "b.gamma", "shape": [8], "init": "ones"}
          ],
          "train_outputs": 4, "eval_outputs": 2,
          "artifacts": {"train": "toy_train.hlo.txt", "eval": "toy_eval.hlo.txt"},
          "workload": "workload_toynet.json",
          "workload_small": "workload_toy.json"
        }"#
    }

    #[test]
    fn parses_toy_meta() {
        let v = crate::util::json::parse(toy_json()).unwrap();
        let m = ModelMeta::from_json(&v).unwrap();
        assert_eq!(m.name, "toy");
        assert_eq!(m.params.len(), 3);
        assert_eq!(m.params[0].init, InitKind::He { fan_in: 9 });
        assert_eq!(m.param_scalars(), 3 * 3 * 8 + 8 + 8);
        assert_eq!(m.input_numel(), 4 * 8 * 8);
    }

    #[test]
    fn rejects_unsorted_params() {
        let src = toy_json().replace("a.w", "z.w");
        let v = crate::util::json::parse(&src).unwrap();
        assert!(ModelMeta::from_json(&v).is_err());
    }

    #[test]
    fn loads_real_artifacts_if_built() {
        let p = std::path::Path::new("artifacts/meta/shufflenet_s.json");
        if p.exists() {
            let m = ModelMeta::load(p).unwrap();
            assert_eq!(m.name, "shufflenet_s");
            assert_eq!(m.batch, 16);
            assert_eq!(m.train_outputs, m.params.len() + 1);
            assert!(m.param_scalars() > 10_000);
        }
    }
}
