//! Compiled train/eval executors with device-resident parameters.
//!
//! The protocol (fixed by `aot.py`): the train executable takes
//! `(p_0 … p_{N-1}, x, y)` positionally (params in sorted-name order) and
//! returns the tuple `(p'_0 … p'_{N-1}, loss)`; eval returns
//! `(loss, n_correct)`.
//!
//! Hot path: parameters live as `PjRtBuffer`s between steps and each
//! step is ONE `execute_b` call. PJRT may return the root tuple either
//! flattened into N+1 buffers or as a single tuple buffer depending on
//! build; both are handled — the flattened path keeps everything on
//! device, the tuple path falls back to literal decompose + re-upload
//! (measured in `benches/perf_hotpath.rs`).

use crate::util::rng::Rng;
use crate::xla;
use crate::Result;

use super::artifact::{InitKind, ModelMeta, ParamSpec};
use super::client::RuntimeClient;

/// Device-resident model parameters.
pub struct TrainState {
    pub params: Vec<xla::PjRtBuffer>,
    /// Steps taken since init (diagnostic).
    pub steps: usize,
}

/// One model's compiled executables.
pub struct ModelExecutor<'c> {
    pub meta: ModelMeta,
    client: &'c RuntimeClient,
    train_exe: xla::PjRtLoadedExecutable,
    eval_exe: xla::PjRtLoadedExecutable,
}

impl<'c> ModelExecutor<'c> {
    /// Compile both step functions from the artifacts directory.
    pub fn load(
        client: &'c RuntimeClient,
        artifacts_dir: impl AsRef<std::path::Path>,
        model: &str,
    ) -> Result<Self> {
        let dir = artifacts_dir.as_ref();
        let meta = ModelMeta::load(dir.join("meta").join(format!("{model}.json")))?;
        let train_exe = client.compile_hlo_file(dir.join(&meta.train_hlo))?;
        let eval_exe = client.compile_hlo_file(dir.join(&meta.eval_hlo))?;
        Ok(ModelExecutor {
            meta,
            client,
            train_exe,
            eval_exe,
        })
    }

    /// He/ones/zeros host-side init per metadata (mirrors
    /// `model.init_params`; Rust owns init so no Python at runtime).
    pub fn init_host_params(&self, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        self.meta
            .params
            .iter()
            .map(|spec| init_tensor(spec, &mut rng))
            .collect()
    }

    /// Upload host params to device buffers.
    pub fn state_from_host(&self, host: &[Vec<f32>]) -> Result<TrainState> {
        crate::ensure!(host.len() == self.meta.params.len());
        let mut params = Vec::with_capacity(host.len());
        for (spec, data) in self.meta.params.iter().zip(host) {
            crate::ensure!(
                data.len() == spec.numel(),
                "param {} length mismatch",
                spec.name
            );
            params.push(self.client.upload_f32(data, &spec.shape)?);
        }
        Ok(TrainState { params, steps: 0 })
    }

    /// Fresh initialized state.
    pub fn init_state(&self, seed: u64) -> Result<TrainState> {
        let host = self.init_host_params(seed);
        self.state_from_host(&host)
    }

    /// Download parameters (for FedAvg aggregation on the server).
    pub fn state_to_host(&self, state: &TrainState) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(state.params.len());
        for buf in &state.params {
            let lit = buf
                .to_literal_sync()
                .map_err(|e| crate::err!("download: {e}"))?;
            out.push(
                lit.to_vec::<f32>()
                    .map_err(|e| crate::err!("to_vec: {e}"))?,
            );
        }
        Ok(out)
    }

    /// One SGD step on a batch; updates `state` in place, returns loss.
    pub fn train_step(
        &self,
        state: &mut TrainState,
        x: &[f32],
        y: &[i32],
    ) -> Result<f32> {
        let xb = self.client.upload_f32(x, &self.meta.input_shape)?;
        let yb = self.client.upload_i32(y, &self.meta.label_shape)?;
        let mut args: Vec<&xla::PjRtBuffer> =
            state.params.iter().collect();
        args.push(&xb);
        args.push(&yb);
        let mut outs = self
            .train_exe
            .execute_b(&args)
            .map_err(|e| crate::err!("train execute: {e}"))?;
        let replica = outs.swap_remove(0);
        let n = self.meta.train_outputs;
        if replica.len() == n {
            // flattened outputs: stay on device
            let mut bufs = replica;
            let loss_buf = bufs.pop().expect("loss output");
            state.params = bufs;
            state.steps += 1;
            let loss = loss_buf
                .to_literal_sync()
                .map_err(|e| crate::err!("loss download: {e}"))?;
            Ok(first_f32(&loss)?)
        } else if replica.len() == 1 {
            // tuple root: host round-trip fallback
            let tup = replica[0]
                .to_literal_sync()
                .map_err(|e| crate::err!("tuple download: {e}"))?;
            let mut parts = tup
                .to_tuple()
                .map_err(|e| crate::err!("untuple: {e}"))?;
            crate::ensure!(parts.len() == n, "expected {n} tuple elements");
            let loss_lit = parts.pop().unwrap();
            let mut new_params = Vec::with_capacity(parts.len());
            for (lit, spec) in parts.into_iter().zip(&self.meta.params) {
                let host = lit
                    .to_vec::<f32>()
                    .map_err(|e| crate::err!("to_vec: {e}"))?;
                new_params.push(self.client.upload_f32(&host, &spec.shape)?);
            }
            state.params = new_params;
            state.steps += 1;
            Ok(first_f32(&loss_lit)?)
        } else {
            crate::bail!(
                "unexpected output arity {} (want {n} or 1)",
                replica.len()
            )
        }
    }

    /// Evaluate a batch: (mean loss, #correct).
    pub fn eval_step(
        &self,
        state: &TrainState,
        x: &[f32],
        y: &[i32],
    ) -> Result<(f32, f32)> {
        let xb = self.client.upload_f32(x, &self.meta.input_shape)?;
        let yb = self.client.upload_i32(y, &self.meta.label_shape)?;
        let mut args: Vec<&xla::PjRtBuffer> =
            state.params.iter().collect();
        args.push(&xb);
        args.push(&yb);
        let mut outs = self
            .eval_exe
            .execute_b(&args)
            .map_err(|e| crate::err!("eval execute: {e}"))?;
        let replica = outs.swap_remove(0);
        if replica.len() == 2 {
            let loss = first_f32(
                &replica[0]
                    .to_literal_sync()
                    .map_err(|e| crate::err!("loss: {e}"))?,
            )?;
            let correct = first_f32(
                &replica[1]
                    .to_literal_sync()
                    .map_err(|e| crate::err!("correct: {e}"))?,
            )?;
            Ok((loss, correct))
        } else {
            let tup = replica[0]
                .to_literal_sync()
                .map_err(|e| crate::err!("tuple: {e}"))?;
            let (l, c) = tup
                .to_tuple2()
                .map_err(|e| crate::err!("untuple: {e}"))?;
            Ok((first_f32(&l)?, first_f32(&c)?))
        }
    }
}

fn first_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| crate::err!("scalar read: {e}"))
}

fn init_tensor(spec: &ParamSpec, rng: &mut Rng) -> Vec<f32> {
    let n = spec.numel();
    match &spec.init {
        InitKind::He { fan_in } => {
            let std = (2.0 / *fan_in as f64).sqrt();
            (0..n).map(|_| (rng.normal() * std) as f32).collect()
        }
        InitKind::Ones => vec![1.0; n],
        InitKind::Zeros => vec![0.0; n],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{InitKind, ParamSpec};

    #[test]
    fn init_tensor_statistics() {
        let mut rng = Rng::new(0);
        let spec = ParamSpec {
            name: "w".into(),
            shape: vec![100, 100],
            init: InitKind::He { fan_in: 50 },
        };
        let t = init_tensor(&spec, &mut rng);
        assert_eq!(t.len(), 10_000);
        let mean: f32 = t.iter().sum::<f32>() / t.len() as f32;
        let want_std = (2.0f32 / 50.0).sqrt();
        let var: f32 =
            t.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>()
                / t.len() as f32;
        assert!(mean.abs() < 0.01);
        assert!((var.sqrt() - want_std).abs() / want_std < 0.05);
    }

    #[test]
    fn init_tensor_constants() {
        let mut rng = Rng::new(0);
        let ones = init_tensor(
            &ParamSpec {
                name: "g".into(),
                shape: vec![7],
                init: InitKind::Ones,
            },
            &mut rng,
        );
        assert_eq!(ones, vec![1.0; 7]);
        let zeros = init_tensor(
            &ParamSpec {
                name: "b".into(),
                shape: vec![5],
                init: InitKind::Zeros,
            },
            &mut rng,
        );
        assert_eq!(zeros, vec![0.0; 5]);
    }
}

impl<'c> ModelExecutor<'c> {
    /// Debug helper: raw execute_b on the train executable, returns
    /// outputs-per-replica count.
    pub fn debug_execute(
        &self,
        args: &[&xla::PjRtBuffer],
    ) -> Result<usize> {
        let outs = self
            .train_exe
            .execute_b(args)
            .map_err(|e| crate::err!("execute: {e}"))?;
        Ok(outs[0].len())
    }
}
