//! PJRT CPU client wrapper.
//!
//! One client per process; executables and buffers keep a handle to it.
//! (The `xla` crate's `PjRtClient` is a cheap cloneable wrapper around
//! the underlying C++ client.)

use crate::xla;
use crate::Result;

pub struct RuntimeClient {
    pub client: xla::PjRtClient,
}

impl RuntimeClient {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| crate::err!("PJRT cpu client: {e}"))?;
        Ok(RuntimeClient { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load HLO **text** (see aot.py for why text, not serialized proto)
    /// and compile it.
    pub fn compile_hlo_file(
        &self,
        path: impl AsRef<std::path::Path>,
    ) -> Result<xla::PjRtLoadedExecutable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path).map_err(|e| {
            crate::err!("parsing HLO text {}: {e}", path.display())
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| crate::err!("compiling {}: {e}", path.display()))
    }

    /// Upload an f32 tensor.
    ///
    /// NOTE: must go through `buffer_from_host_buffer` — its C++ side
    /// uses `HostBufferSemantics::kImmutableOnlyDuringCall`, i.e. the
    /// copy completes before the call returns. `buffer_from_host_literal`
    /// is ASYNC in XLA (`BufferFromHostLiteral`): the worker thread reads
    /// the literal after this function returns, and a dropped temporary
    /// literal turns into a use-after-free SIGSEGV on the PJRT thread.
    pub fn upload_f32(
        &self,
        data: &[f32],
        dims: &[usize],
    ) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| crate::err!("upload f32: {e}"))
    }

    /// Upload an i32 tensor (same synchronous-copy requirement).
    pub fn upload_i32(
        &self,
        data: &[i32],
        dims: &[usize],
    ) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| crate::err!("upload i32: {e}"))
    }
}
