//! Crate-local error type (the offline crate set has no `anyhow`).
//!
//! A message-carrying error plus the three macros the crate idiomatically
//! used from anyhow: [`err!`](crate::err), [`bail!`](crate::bail) and
//! [`ensure!`](crate::ensure). Errors are plain strings — the crate's
//! failure modes are configuration/IO shaped, never recoverable typed
//! conditions, so a message is the right amount of structure.

use std::fmt;

/// Crate-wide error: a human-readable message.
#[derive(Clone, Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(msg: String) -> Error {
        Error { msg }
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Error {
        Error::msg(msg)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<std::fmt::Error> for Error {
    fn from(e: std::fmt::Error) -> Error {
        Error::msg(e.to_string())
    }
}

/// Build an [`Error`] from a format string: `crate::err!("bad {x}")`.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::error::Error::msg(format!($($arg)*)))
    };
}

/// Return early with an [`Error`] unless the condition holds. With no
/// message the stringified condition is reported.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::error::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::error::Error::msg(format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_plain() -> crate::Result<()> {
        crate::ensure!(1 + 1 == 3);
        Ok(())
    }

    fn fails_fmt(n: usize) -> crate::Result<usize> {
        crate::ensure!(n < 10, "n too big: {n}");
        Ok(n)
    }

    fn bails() -> crate::Result<()> {
        crate::bail!("gave up after {} tries", 3);
    }

    #[test]
    fn display_carries_message() {
        let e = Error::msg("boom");
        assert_eq!(e.to_string(), "boom");
        assert_eq!(format!("{e:#}"), "boom");
    }

    #[test]
    fn err_macro_formats() {
        let e = crate::err!("bad value {}", 42);
        assert_eq!(e.to_string(), "bad value 42");
    }

    #[test]
    fn ensure_plain_names_condition() {
        let e = fails_plain().unwrap_err();
        assert!(e.to_string().contains("1 + 1 == 3"), "{e}");
    }

    #[test]
    fn ensure_formatted_and_passing() {
        assert_eq!(fails_fmt(5).unwrap(), 5);
        let e = fails_fmt(20).unwrap_err();
        assert_eq!(e.to_string(), "n too big: 20");
    }

    #[test]
    fn bail_returns_error() {
        assert_eq!(bails().unwrap_err().to_string(), "gave up after 3 tries");
    }

    #[test]
    fn io_error_converts() {
        fn read() -> crate::Result<String> {
            Ok(std::fs::read_to_string("/nonexistent/swan/path")?)
        }
        assert!(read().is_err());
    }
}
