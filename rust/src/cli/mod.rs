//! Hand-rolled CLI argument parsing (clap is not in the offline set).
//!
//! Supports `swan <subcommand> [--flag value] [--switch]` with typed
//! accessors, defaults, and auto-generated usage text.

use std::collections::BTreeMap;

/// Declarative option spec for one subcommand.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_switch: bool,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, name: &str, default: f64) -> crate::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<f64>()
                .map_err(|_| crate::err!("--{name} expects a number, got '{s}'")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> crate::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<usize>()
                .map_err(|_| crate::err!("--{name} expects an integer, got '{s}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> crate::Result<u64> {
        Ok(self.get_usize(name, default as usize)? as u64)
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

/// Parse a token stream against a spec list.
pub fn parse_args(
    tokens: &[String],
    specs: &[OptSpec],
) -> crate::Result<Args> {
    let mut args = Args::default();
    for spec in specs {
        if let (Some(d), false) = (spec.default, spec.is_switch) {
            args.values.insert(spec.name.to_string(), d.to_string());
        }
    }
    let mut i = 0;
    while i < tokens.len() {
        let t = &tokens[i];
        if let Some(name) = t.strip_prefix("--") {
            let (name, inline) = match name.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (name, None),
            };
            let spec = specs
                .iter()
                .find(|s| s.name == name)
                .ok_or_else(|| crate::err!("unknown flag --{name}"))?;
            if spec.is_switch {
                if inline.is_some() {
                    crate::bail!("--{name} is a switch and takes no value");
                }
                args.switches.push(name.to_string());
            } else {
                let value = match inline {
                    Some(v) => v,
                    None => {
                        i += 1;
                        tokens
                            .get(i)
                            .cloned()
                            .ok_or_else(|| crate::err!("--{name} needs a value"))?
                    }
                };
                args.values.insert(name.to_string(), value);
            }
        } else {
            args.positional.push(t.clone());
        }
        i += 1;
    }
    Ok(args)
}

/// Render usage text for a subcommand.
pub fn usage(cmd: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut out = format!("swan {cmd} — {about}\n\noptions:\n");
    for s in specs {
        let tail = if s.is_switch {
            String::new()
        } else if let Some(d) = s.default {
            format!(" <val> (default: {d})")
        } else {
            " <val>".to_string()
        };
        out.push_str(&format!("  --{}{:<24} {}\n", s.name, tail, s.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "device", help: "device id", default: Some("pixel3"), is_switch: false },
            OptSpec { name: "steps", help: "step count", default: Some("10"), is_switch: false },
            OptSpec { name: "verbose", help: "more output", default: None, is_switch: true },
        ]
    }

    fn toks(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = parse_args(&[], &specs()).unwrap();
        assert_eq!(a.get("device"), Some("pixel3"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 10);
        assert!(!a.has("verbose"));
    }

    #[test]
    fn parses_values_and_switches() {
        let a = parse_args(
            &toks(&["--device", "s10e", "--verbose", "--steps=25", "pos"]),
            &specs(),
        )
        .unwrap();
        assert_eq!(a.get("device"), Some("s10e"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 25);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["pos"]);
    }

    #[test]
    fn rejects_unknown_and_malformed() {
        assert!(parse_args(&toks(&["--nope", "1"]), &specs()).is_err());
        assert!(parse_args(&toks(&["--device"]), &specs()).is_err());
        assert!(parse_args(&toks(&["--verbose=1"]), &specs()).is_err());
        let a = parse_args(&toks(&["--steps", "abc"]), &specs()).unwrap();
        assert!(a.get_usize("steps", 0).is_err());
    }

    #[test]
    fn usage_mentions_flags() {
        let u = usage("train", "run local training", &specs());
        assert!(u.contains("--device"));
        assert!(u.contains("default: pixel3"));
    }
}

pub mod commands;
pub use commands::run_main;
