//! The `swan` binary's subcommands — the launcher over the whole stack.
//!
//! ```text
//! swan devices                       list the simulated device fleet
//! swan explore --device s10e --model shufflenet_v2
//! swan train   --model shufflenet_s --device pixel3 --steps 20
//! swan pcmark  [--artifacts artifacts]
//! swan fl      --model shufflenet_s --rounds 20 --clients 3
//! swan fleet   --scenario city --shards 8 --arm both
//! swan serve   --port 7077 --scenario smoke --workers 4 --events serve.ndjson
//! swan bench   fleet --scenario city --shards 1,2,4,8 --json
//! swan bench   serve --scenario smoke --lanes 4 --json
//! swan bench   fl --rounds 6 --lanes 4 --json
//! swan bench   floor --floors ci/perf_floors.json
//! swan obs     check events.ndjson
//! swan obs     trace events.ndjson --round 1 [--device 17]
//! swan obs     top events.ndjson --by stage|device
//! swan obs     rates events.ndjson --window 0.5
//! swan obs     diff BENCH_fleet.json baseline.json --threshold 10
//! swan lint    [--deny-all] [--json] [rust/src ...]
//! swan traces  --users 4
//! swan report  table2|table3|fig1|fig2|fig3|fleet
//! ```
//!
//! `--events <path>` (fleet/serve/bench) streams the telemetry spine's
//! NDJSON event stream to a file; `--events stderr` (or `-`) streams to
//! stderr; adding `--trace` turns on per-device lifecycle edges
//! (`trace-edge` records). The `swan obs` verbs consume those streams:
//! `check` validates framing + per-reason schema, `trace` reconstructs
//! device lifecycles, `top` attributes latency to stages/stragglers,
//! `rates` windows admission traffic, and `diff` compares two runs
//! (NDJSON or `BENCH_*.json`) with direction-aware regression gates.
//! `swan bench floor` enforces the committed CI perf floors against
//! bench records.

use crate::report;
use crate::runtime::{ModelExecutor, Registry, RuntimeClient};
use crate::sim::SimPhone;
use crate::soc::device::{all_devices, device, DeviceId};
use crate::swan::{SwanConfig, SwanEngine};
use crate::train::data::SyntheticDataset;
use crate::util::table::Table;
use crate::workload::{load_or_builtin, WorkloadName};

use super::{parse_args, usage, Args, OptSpec};

fn opt(name: &'static str, help: &'static str, default: Option<&'static str>) -> OptSpec {
    OptSpec {
        name,
        help,
        default,
        is_switch: false,
    }
}

fn switch(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec {
        name,
        help,
        default: None,
        is_switch: true,
    }
}

pub fn run_main() -> crate::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            print_help();
            return Ok(());
        }
    };
    match cmd {
        "devices" => cmd_devices(),
        "explore" => cmd_explore(&rest),
        "train" => cmd_train(&rest),
        "pcmark" => cmd_pcmark(),
        "fl" => cmd_fl(&rest),
        "fleet" => cmd_fleet(&rest),
        "serve" => cmd_serve(&rest),
        "bench" => cmd_bench(&rest),
        "obs" => cmd_obs(&rest),
        "lint" => cmd_lint(&rest),
        "traces" => cmd_traces(&rest),
        "report" => cmd_report(&rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            crate::bail!("unknown subcommand '{other}'")
        }
    }
}

fn print_help() {
    println!(
        "swan — neural engine for efficient DNN training on smartphone SoCs\n\
         \n\
         subcommands:\n\
         \x20 devices   list the simulated device fleet\n\
         \x20 explore   run §4.2 exploration on one device/model\n\
         \x20 train     real local training under Swan scheduling\n\
         \x20 pcmark    Fig-3/Table-3 user-experience evaluation\n\
         \x20 fl        federated-learning simulation (§5.3; --serve routes it through the coordinator)\n\
         \x20 fleet     sharded fleet simulation (100k–1M devices)\n\
         \x20 serve     run the FL coordinator control plane on TCP\n\
         \x20 bench     throughput harnesses (BENCH_fleet / BENCH_serve / BENCH_fl .json)\n\
         \x20 obs       telemetry toolkit (check|trace|top|rates|diff)\n\
         \x20 lint      static analysis over the crate's own sources\n\
         \x20 traces    generate + preprocess GreenHub-style traces\n\
         \x20 report    regenerate a paper table/figure\n"
    );
}

fn cmd_devices() -> crate::Result<()> {
    let mut t = Table::new(
        "simulated devices",
        &["key", "name", "soc", "cores", "cache_MB", "bw_GB/s", "battery_mAh"],
    );
    for d in all_devices() {
        let mut topo = String::new();
        for k in [
            crate::soc::core::CoreKind::Little,
            crate::soc::core::CoreKind::Big,
            crate::soc::core::CoreKind::Prime,
        ] {
            let n = d.cores_of_kind(k).len();
            if n > 0 {
                topo.push_str(&format!("{n}{} ", k));
            }
        }
        t.row(&[
            d.id.key().to_string(),
            d.id.name().to_string(),
            d.soc.to_string(),
            topo.trim().to_string(),
            format!("{:.1}", d.shared_cache_bytes / 1e6),
            format!("{:.1}", d.mem_bw_bytes / 1e9),
            format!("{:.0}", d.battery_mah),
        ]);
    }
    println!("{}", t.to_markdown());
    Ok(())
}

fn device_arg(args: &Args) -> crate::Result<DeviceId> {
    let key = args.get_str("device", "pixel3");
    DeviceId::parse(&key)
        .ok_or_else(|| crate::err!("unknown device '{key}'"))
}

fn cmd_explore(rest: &[String]) -> crate::Result<()> {
    let specs = [
        opt("device", "device key", Some("pixel3")),
        opt("model", "workload (resnet34|mobilenet_v2|shufflenet_v2)", Some("shufflenet_v2")),
        opt("steps", "benchmark steps per choice", Some("5")),
    ];
    let args = parse_args(rest, &specs)?;
    let dev = device_arg(&args)?;
    let wl = WorkloadName::parse(&args.get_str("model", ""))
        .ok_or_else(|| crate::err!("unknown model"))?;
    let workload = load_or_builtin(wl, "artifacts");
    let mut phone = SimPhone::new(device(dev), 1);
    let cfg = SwanConfig {
        explore_steps: args.get_usize("steps", 5)?,
        ..SwanConfig::default()
    };
    let engine = SwanEngine::explore_and_build(&mut phone, workload, cfg);
    let mut t = Table::new(
        &format!("profiles on {}", dev.name()),
        &["choice", "latency_s", "energy_j", "power_w", "in_chain"],
    );
    let kept: Vec<String> =
        engine.chain().iter().map(|p| p.choice.label()).collect();
    for p in &engine.profiles {
        t.row(&[
            p.choice.label(),
            format!("{:.3}", p.latency_s),
            format!("{:.3}", p.energy_j),
            format!("{:.2}", p.power_w),
            kept.contains(&p.choice.label()).to_string(),
        ]);
    }
    println!("{}", t.to_markdown());
    println!("{}", usage("explore", "explore execution choices", &specs));
    Ok(())
}

fn cmd_train(rest: &[String]) -> crate::Result<()> {
    let specs = [
        opt("device", "device key", Some("pixel3")),
        opt("model", "trainable model", Some("shufflenet_s")),
        opt("steps", "training steps", Some("20")),
        opt("seed", "rng seed", Some("0")),
    ];
    let args = parse_args(rest, &specs)?;
    let dev = device_arg(&args)?;
    let model = args.get_str("model", "shufflenet_s");
    let steps = args.get_usize("steps", 20)?;
    let seed = args.get_u64("seed", 0)?;

    let reg = Registry::discover()?;
    let client = RuntimeClient::cpu()?;
    let exec = ModelExecutor::load(&client, &reg.dir, &model)?;
    let paper = WorkloadName::paper_scale_of(
        WorkloadName::parse(&model)
            .ok_or_else(|| crate::err!("unknown model"))?,
    );
    let workload = load_or_builtin(paper, "artifacts");

    let mut phone = SimPhone::new(device(dev), seed);
    let mut engine = SwanEngine::explore_and_build(
        &mut phone,
        workload,
        SwanConfig::default(),
    );
    let ds = if exec.meta.task == "speech" {
        SyntheticDataset::speech(seed)
    } else {
        SyntheticDataset::vision(seed)
    };
    let part = ds.partition(0);
    let mut state = exec.init_state(seed)?;
    for step in 0..steps {
        let (x, y) = ds.batch(&part, step, exec.meta.batch);
        let mut loss = f32::NAN;
        let rep = engine.run_local_step(&mut phone, || {
            loss = exec.train_step(&mut state, &x, &y).expect("step");
        });
        println!(
            "step {step:3}: loss {loss:.4} choice {} sim {:.0} ms",
            rep.choice,
            rep.latency_s * 1e3
        );
    }
    Ok(())
}

fn cmd_pcmark() -> crate::Result<()> {
    let (_r, fig3) = report::fig3_rows("artifacts");
    fig3.emit()?;
    let (_r, t3) = report::table3_rows("artifacts");
    t3.emit()?;
    Ok(())
}

fn cmd_fl(rest: &[String]) -> crate::Result<()> {
    let specs = [
        opt("model", "trainable model", Some("shufflenet_s")),
        opt("rounds", "FL rounds", Some("20")),
        opt("clients", "clients per round", Some("3")),
        opt("steps", "local steps", Some("3")),
        opt("traces", "quality traces (×24 clients)", Some("2")),
        opt("arm", "swan|baseline|both", Some("both")),
        opt("seed", "rng seed", Some("17")),
        switch(
            "serve",
            "route training through the serve coordinator (softmax-probe \
             numerics, in-process + loopback TCP, no PJRT artifacts)",
        ),
        opt("lanes", "serve lanes when --serve", Some("2")),
        opt("events", EVENTS_HELP, None),
        switch("trace", TRACE_HELP),
    ];
    let args = parse_args(rest, &specs)?;
    let model = args.get_str("model", "shufflenet_s");
    let cfg = crate::fl::FlConfig {
        seed: args.get_u64("seed", 17)?,
        raw_traces: args.get_usize("traces", 2)? * 4,
        quality_traces: args.get_usize("traces", 2)?,
        clients_per_round: args.get_usize("clients", 3)?,
        local_steps: args.get_usize("steps", 3)?,
        rounds: args.get_usize("rounds", 20)?,
        eval_every: 2,
        eval_batches: 2,
        daily_credit_j: 2_000.0,
        server_overhead_s: 2.0,
    };
    let paper = WorkloadName::paper_scale_of(
        WorkloadName::parse(&model)
            .ok_or_else(|| crate::err!("unknown model"))?,
    );
    let arm_s = args.get_str("arm", "both");
    let arms: Vec<crate::fl::FlArm> = match arm_s.as_str() {
        "swan" => vec![crate::fl::FlArm::Swan],
        "baseline" => vec![crate::fl::FlArm::Baseline],
        _ => vec![crate::fl::FlArm::Swan, crate::fl::FlArm::Baseline],
    };

    if args.has("serve") {
        // the unified engine through the control plane: every round's
        // SGD is leased, pushed and FedAvg'd inside the coordinator,
        // and the harness asserts bit-identity against the direct
        // oracle on both the in-process and loopback-TCP wirings
        let obs = obs_arg(&args)?;
        let lanes = args.get_usize("lanes", 2)?.max(1);
        for arm in arms {
            let report =
                crate::fleet::run_fl_bench(&cfg, arm, paper, lanes, true, &obs)?;
            println!(
                "[{}] vt={:.1}h energy={:.1}kJ best_acc={:.3} rounds={} \
                 digest={}",
                arm.name(),
                report.direct.total_time_s / 3600.0,
                report.direct.total_energy_j / 1e3,
                report.direct.best_accuracy(),
                report.direct.rounds_run,
                report.digest
            );
        }
        return Ok(());
    }

    let reg = Registry::discover()?;
    let client = RuntimeClient::cpu()?;
    let exec = ModelExecutor::load(&client, &reg.dir, &model)?;
    let workload = load_or_builtin(paper, "artifacts");
    for arm in arms {
        let ds = if exec.meta.task == "speech" {
            SyntheticDataset::speech(cfg.seed)
        } else {
            SyntheticDataset::vision(cfg.seed)
        };
        let mut sim = crate::fl::FlSim::new(cfg.clone(), arm, ds, &workload)?;
        let out = sim.run(&exec)?;
        println!(
            "[{}] vt={:.1}h energy={:.1}kJ best_acc={:.3} rounds={}",
            arm.name(),
            out.total_time_s / 3600.0,
            out.total_energy_j / 1e3,
            out.best_accuracy(),
            out.rounds_run
        );
    }
    Ok(())
}

fn cmd_fleet(rest: &[String]) -> crate::Result<()> {
    let specs = [
        opt("scenario", "builtin scenario (smoke|city|metro|million)", Some("smoke")),
        opt("file", "load a ScenarioSpec JSON instead of a builtin", None),
        opt("shards", "worker shards (0 = available parallelism)", Some("4")),
        opt("devices", "override device count (0 = scenario value)", Some("0")),
        opt("rounds", "override round count (0 = scenario value)", Some("0")),
        opt("arm", "swan|baseline|both", Some("both")),
        opt("events", EVENTS_HELP, None),
        switch("trace", TRACE_HELP),
    ];
    let args = parse_args(rest, &specs)?;
    let spec = scenario_arg(&args, "smoke")?;
    let obs = obs_arg(&args)?;
    let mut shards = args.get_usize("shards", 4)?;
    if shards == 0 {
        shards = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
    }
    // unlike `swan fl`, a fleet run can be hours of compute — fail fast
    // on a typo'd arm instead of silently running both
    let arms: Vec<crate::fl::FlArm> = match args.get_str("arm", "both").as_str()
    {
        "swan" => vec![crate::fl::FlArm::Swan],
        "baseline" => vec![crate::fl::FlArm::Baseline],
        "both" => vec![crate::fl::FlArm::Swan, crate::fl::FlArm::Baseline],
        other => crate::bail!("unknown --arm '{other}' (swan|baseline|both)"),
    };
    println!("scenario: {:#}", spec.to_json());
    let mut outcomes = Vec::new();
    for arm in arms {
        let out = crate::fleet::run_scenario_obs(&spec, shards, arm, &obs)?;
        println!(
            "[{}] {} devices × {} rounds on {} shards: vt={:.1}h \
             energy={:.1}kJ steps={} online {}→{} | \
             {:.0} devices-stepped/s ({:.2}s wall)",
            out.arm,
            out.devices,
            out.rounds_run,
            out.shards,
            out.total_time_s / 3600.0,
            out.total_energy_j / 1e3,
            out.total_steps,
            out.online_first(),
            out.online_last(),
            out.devices_stepped_per_sec(),
            out.wall_s,
        );
        outcomes.push(out);
    }
    report::fleet_table(&outcomes).emit()?;
    for out in &outcomes {
        report::obs_table(
            &format!(
                "fleet phase breakdown [{}] {} shards",
                out.arm, out.shards
            ),
            &out.spans,
        )
        .emit()?;
    }
    Ok(())
}

/// Load a scenario from `--file` or a builtin key, with the shared
/// `--devices`/`--rounds` overrides applied.
fn scenario_arg(
    args: &Args,
    default_builtin: &str,
) -> crate::Result<crate::fleet::ScenarioSpec> {
    let mut spec = match args.get("file") {
        Some(path) => crate::fleet::ScenarioSpec::load(path)?,
        None => {
            let key = args.get_str("scenario", default_builtin);
            crate::fleet::ScenarioSpec::builtin(&key).ok_or_else(|| {
                crate::err!(
                    "unknown scenario '{key}' (smoke|city|metro|million)"
                )
            })?
        }
    };
    let devices = args.get_usize("devices", 0)?;
    if devices > 0 {
        spec.devices = devices;
    }
    let rounds = args.get_usize("rounds", 0)?;
    if rounds > 0 {
        spec.rounds = rounds;
    }
    Ok(spec)
}

/// Resolve the telemetry sink from the shared `--events` opt: a path
/// streams NDJSON to that file, the literal `stderr` (or `-`) streams
/// to stderr, and no flag leaves telemetry off. The `--trace` switch
/// additionally turns on per-device `trace-edge` records — it needs a
/// live sink, so `--trace` without `--events` is an error rather than
/// a silent no-op.
fn obs_arg(args: &Args) -> crate::Result<crate::obs::Obs> {
    let obs = match args.get("events") {
        None => crate::obs::Obs::off(),
        Some("stderr") | Some("-") => crate::obs::Obs::stderr(),
        Some(path) => crate::obs::Obs::to_file(path)?,
    };
    if args.has("trace") {
        crate::ensure!(
            obs.enabled(),
            "--trace emits per-device lifecycle records into the event \
             stream: pass --events <path> too"
        );
        return Ok(obs.with_traces());
    }
    Ok(obs)
}

const EVENTS_HELP: &str =
    "stream NDJSON telemetry to a file path, or 'stderr'";
const TRACE_HELP: &str =
    "emit per-device trace-edge records (needs --events)";

fn cmd_serve(rest: &[String]) -> crate::Result<()> {
    // no --devices/--rounds here: the coordinator serves whatever
    // fleet connects — only the scenario's seed/K/overhead/workload
    // shape its config
    let specs = [
        opt("scenario", "builtin scenario shaping the coordinator config", Some("smoke")),
        opt("file", "load a ScenarioSpec JSON instead of a builtin", None),
        opt("host", "bind address", Some("127.0.0.1")),
        opt("port", "bind port (0 = ephemeral)", Some("7077")),
        opt("workers", "IO worker threads (= max concurrent connections)", Some("4")),
        opt("batch", "check-in coalescing batch size", Some("256")),
        opt("cap", "per-round admission bound (0 = unbounded)", Some("0")),
        opt("cache", "LRU profile-cache capacity (contexts)", Some("64")),
        opt("events", EVENTS_HELP, None),
        switch("trace", TRACE_HELP),
    ];
    let args = parse_args(rest, &specs)?;
    let spec = scenario_arg(&args, "smoke")?;
    let obs = obs_arg(&args)?;
    let mut cfg = crate::serve::ServeConfig::for_scenario(&spec);
    cfg.batch_size = args.get_usize("batch", 256)?.max(1);
    cfg.admit_capacity = args.get_usize("cap", 0)?;
    cfg.cache_capacity = args.get_usize("cache", 64)?;
    let workers = args.get_usize("workers", 4)?.max(1);
    let bind = format!(
        "{}:{}",
        args.get_str("host", "127.0.0.1"),
        args.get_usize("port", 7077)?
    );
    let coord = std::sync::Arc::new(crate::serve::Coordinator::with_obs(
        cfg.clone(),
        obs,
    )?);
    let handle = crate::serve::serve_tcp(coord, &bind, workers)?;
    println!(
        "serve: coordinator for scenario '{}' listening on {} \
         ({workers} workers, batch {}, cap {}, cache {})",
        spec.name,
        handle.addr,
        cfg.batch_size,
        cfg.admit_capacity,
        cfg.cache_capacity
    );
    println!(
        "serve: drive it with `swan bench serve --scenario {}` or any \
         wire-format client; ctrl-c to stop",
        spec.name
    );
    handle.wait();
    Ok(())
}

fn cmd_bench(rest: &[String]) -> crate::Result<()> {
    let (what, rest) = match rest.split_first() {
        Some((w, r)) => (w.as_str(), r.to_vec()),
        None => ("fleet", Vec::new()),
    };
    match what {
        "fleet" => cmd_bench_fleet(&rest),
        "serve" => cmd_bench_serve(&rest),
        "fl" => cmd_bench_fl(&rest),
        "floor" => cmd_bench_floor(&rest),
        other => {
            crate::bail!("unknown bench '{other}' (fleet|serve|fl|floor)")
        }
    }
}

/// `swan bench fl` — the numerics-loop harness: real federated SGD
/// (softmax probe) through the unified engine on every wiring (direct
/// oracle, in-process serve, loopback TCP), digest-parity-gated, with
/// serve-routed training rounds/sec as the headline number.
fn cmd_bench_fl(rest: &[String]) -> crate::Result<()> {
    let specs = [
        opt("model", "paper-scale workload for systems costs", Some("shufflenet_v2")),
        opt("rounds", "FL rounds", Some("6")),
        opt("clients", "clients per round", Some("5")),
        opt("steps", "local SGD steps per client per round", Some("3")),
        opt("traces", "quality traces (×24 clients)", Some("4")),
        opt("lanes", "serve lanes (threads + TCP connections)", Some("4")),
        opt("arm", "swan|baseline", Some("swan")),
        opt("seed", "rng seed", Some("17")),
        opt("out", "record path, implies --json (default BENCH_fl.json)", None),
        OptSpec {
            name: "json",
            help: "write the BENCH_fl.json record to --out",
            default: None,
            is_switch: true,
        },
        OptSpec {
            name: "no-tcp",
            help: "skip the loopback-TCP path (oracle + in-process only)",
            default: None,
            is_switch: true,
        },
        opt(
            "expect-digest",
            "fail unless the run reproduces this golden digest",
            None,
        ),
        opt("events", EVENTS_HELP, None),
        switch("trace", TRACE_HELP),
    ];
    let args = parse_args(rest, &specs)?;
    let obs = obs_arg(&args)?;
    let wl = WorkloadName::parse(&args.get_str("model", "shufflenet_v2"))
        .ok_or_else(|| crate::err!("unknown model"))?;
    let traces = args.get_usize("traces", 4)?;
    let cfg = crate::fl::FlConfig {
        seed: args.get_u64("seed", 17)?,
        raw_traces: traces * 4,
        quality_traces: traces,
        clients_per_round: args.get_usize("clients", 5)?,
        local_steps: args.get_usize("steps", 3)?,
        rounds: args.get_usize("rounds", 6)?,
        eval_every: 2,
        eval_batches: 2,
        daily_credit_j: 3_000.0,
        server_overhead_s: 2.0,
    };
    let arm = match args.get_str("arm", "swan").as_str() {
        "swan" => crate::fl::FlArm::Swan,
        "baseline" => crate::fl::FlArm::Baseline,
        other => crate::bail!("unknown --arm '{other}' (swan|baseline)"),
    };
    let lanes = args.get_usize("lanes", 4)?.max(1);

    println!(
        "bench fl: {} clients × {} rounds, K={}, {} local steps, {} lanes",
        traces * 24,
        cfg.rounds,
        cfg.clients_per_round,
        cfg.local_steps,
        lanes
    );
    let report = crate::fleet::run_fl_bench(
        &cfg,
        arm,
        wl,
        lanes,
        !args.has("no-tcp"),
        &obs,
    )?;
    println!(
        "parity: every path reproduced digest {} with bit-identical \
         final weights ({} params)",
        report.digest,
        report.direct.final_model.len()
    );
    let tcp_part = match report.tcp_rounds_per_sec() {
        Some(r) => format!(", tcp {r:.2}"),
        None => String::new(),
    };
    println!(
        "rounds/sec: direct {:.2}, serve {:.2}{tcp_part}",
        report.direct_rounds_per_sec(),
        report.rounds_per_sec()
    );
    if let Some((t_s, acc)) = report.direct.accuracy_curve.last() {
        println!(
            "accuracy: {acc:.3} at vt {:.1}h; time-to-{:.0}%: {}",
            t_s / 3600.0,
            100.0 * crate::fleet::bench::FL_TTA_TARGET,
            match report
                .direct
                .time_to_accuracy(crate::fleet::bench::FL_TTA_TARGET)
            {
                Some(t) => format!("{:.1}h", t / 3600.0),
                None => "not reached".to_string(),
            }
        );
    }
    if let Some(want) = args.get("expect-digest") {
        report.assert_digest(want)?;
        println!("digest matches --expect-digest");
    }
    println!("{}", report.one_line());
    if args.has("json") || args.get("out").is_some() {
        let path = report.write_json(args.get_str("out", "BENCH_fl.json"))?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_bench_serve(rest: &[String]) -> crate::Result<()> {
    let specs = [
        opt("scenario", "builtin scenario (smoke|city|metro|million)", Some("smoke")),
        opt("file", "load a ScenarioSpec JSON instead of a builtin", None),
        opt("devices", "override device count (0 = scenario value)", Some("0")),
        opt("rounds", "override round count (0 = scenario value)", Some("0")),
        opt("lanes", "load-generator lanes (threads + TCP connections)", Some("4")),
        opt("cap", "admission bound (0 = unbounded + oracle parity check)", Some("0")),
        opt("out", "record path, implies --json (default BENCH_serve.json)", None),
        OptSpec {
            name: "json",
            help: "write the BENCH_serve.json record to --out",
            default: None,
            is_switch: true,
        },
        OptSpec {
            name: "no-tcp",
            help: "skip the loopback-TCP path (in-process + oracle only)",
            default: None,
            is_switch: true,
        },
        opt("events", EVENTS_HELP, None),
        switch("trace", TRACE_HELP),
    ];
    let args = parse_args(rest, &specs)?;
    let spec = scenario_arg(&args, "smoke")?;
    let obs = obs_arg(&args)?;
    let lanes = args.get_usize("lanes", 4)?.max(1);
    let cap = args.get_usize("cap", 0)?;

    println!("bench serve: scenario {:#}", spec.to_json());
    let report = crate::fleet::run_serve_bench(
        &spec,
        lanes,
        !args.has("no-tcp"),
        cap,
        &obs,
    )?;
    report::serve_table(&report.runs()).emit()?;
    for run in report.runs() {
        let h = &run.latency_hist;
        println!(
            "{:9} check-in latency: p50 {}, p90 {} over {} burst samples",
            run.transport,
            crate::util::bench::fmt_secs(h.quantile(0.50)),
            crate::util::bench::fmt_secs(h.quantile(0.90)),
            h.count()
        );
    }
    match &report.oracle_digest {
        Some(d) => println!(
            "parity: {} run(s) reproduced the fl::server oracle digest {d}",
            report.runs().len()
        ),
        None => println!(
            "parity: oracle skipped (bounded admission, cap {cap})"
        ),
    }
    println!(
        "cache: {:.1}% hit rate, {} exploration(s), {} eviction(s)",
        100.0 * report.cache_hit_rate(),
        report.stats.cache_misses,
        report.stats.cache_evictions
    );
    if report.inproc.deferred > 0 {
        println!(
            "backpressure: {} deferral(s), rate {:.3}",
            report.inproc.deferred,
            report.inproc.deferral_rate()
        );
    }
    println!("{}", report.one_line());
    if args.has("json") || args.get("out").is_some() {
        let path = report.write_json(args.get_str("out", "BENCH_serve.json"))?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_bench_fleet(rest: &[String]) -> crate::Result<()> {
    let specs = [
        opt("scenario", "builtin scenario (smoke|city|metro|million)", Some("city")),
        opt("file", "load a ScenarioSpec JSON instead of a builtin", None),
        opt("shards", "comma-separated shard counts", Some("1,2,4,8")),
        opt("devices", "override device count (0 = scenario value)", Some("0")),
        opt("rounds", "override round count (0 = scenario value)", Some("0")),
        opt("arm", "swan|baseline", Some("swan")),
        opt("out", "record path, implies --json (default BENCH_fleet.json)", None),
        OptSpec {
            name: "json",
            help: "write the BENCH_fleet.json record to --out",
            default: None,
            is_switch: true,
        },
        OptSpec {
            name: "no-reference",
            help: "skip the PR-1 reference-kernel runs (SoA only)",
            default: None,
            is_switch: true,
        },
        OptSpec {
            name: "reference",
            help: "force reference-kernel runs even at metro/million scale",
            default: None,
            is_switch: true,
        },
        OptSpec {
            name: "no-pin",
            help: "disable shard-worker core pinning (shared machines)",
            default: None,
            is_switch: true,
        },
        opt(
            "expect-digest",
            "fail unless the run reproduces this golden digest",
            None,
        ),
        opt("events", EVENTS_HELP, None),
        switch("trace", TRACE_HELP),
    ];
    let args = parse_args(rest, &specs)?;
    if args.has("no-pin") {
        crate::util::affinity::set_pinning(false);
    }
    let spec = scenario_arg(&args, "city")?;
    let obs = obs_arg(&args)?;
    let shards_arg = args.get_str("shards", "1,2,4,8");
    let mut shard_counts = Vec::new();
    for tok in shards_arg.split(',') {
        let n = tok.trim().parse::<usize>().map_err(|_| {
            crate::err!("--shards expects comma-separated integers, got '{tok}'")
        })?;
        crate::ensure!(n > 0, "--shards entries must be > 0");
        shard_counts.push(n);
    }
    let arm = match args.get_str("arm", "swan").as_str() {
        "swan" => crate::fl::FlArm::Swan,
        "baseline" => crate::fl::FlArm::Baseline,
        other => crate::bail!("unknown --arm '{other}' (swan|baseline)"),
    };

    // metro/million are standing SoA bench tiers: at that scale the
    // PR 1 reference kernel is the bottleneck being measured around, so
    // it defaults off (--reference forces it, --no-reference still
    // forces it off for custom specs)
    let with_reference = if args.has("reference") {
        true
    } else if args.has("no-reference") {
        false
    } else {
        !matches!(spec.name.as_str(), "metro" | "million")
    };

    println!("bench fleet: scenario {:#}", spec.to_json());
    let report = crate::fleet::run_fleet_bench(
        &spec,
        &shard_counts,
        arm,
        with_reference,
        &obs,
    )?;
    let outcomes: Vec<crate::fleet::FleetOutcome> = report
        .reference
        .iter()
        .chain(report.soa.iter())
        .cloned()
        .collect();
    report::fleet_table(&outcomes).emit()?;
    let best = report.best_soa();
    report::obs_table(
        &format!("fleet phase breakdown (soa, {} shards)", best.shards),
        &best.spans,
    )
    .emit()?;
    report::obs_metrics_table(
        &format!("fleet counters (soa, {} shards)", best.shards),
        &best.metrics,
    )
    .emit()?;
    for (shards, ratio) in report.speedup_same_shards() {
        println!("speedup vs reference @ {shards} shards: {ratio:.2}x");
    }
    if let Some(ratio) = report.speedup_best() {
        println!("speedup best-vs-best: {ratio:.2}x");
    }
    println!(
        "determinism: {} runs reproduced digest {}",
        outcomes.len(),
        report.digest
    );
    if let Some(want) = args.get("expect-digest") {
        report.assert_digest(want)?;
        println!("digest matches --expect-digest");
    }
    println!("{}", report.one_line());
    // an explicit --out names a file the user expects to appear, so it
    // implies --json rather than being silently ignored
    if args.has("json") || args.get("out").is_some() {
        let path = report.write_json(args.get_str("out", "BENCH_fleet.json"))?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

/// `swan bench floor` — the CI perf-floor gate: fail when a freshly
/// emitted bench record regresses below the committed floors.
fn cmd_bench_floor(rest: &[String]) -> crate::Result<()> {
    let specs = [
        opt("floors", "perf-floor policy JSON", Some("ci/perf_floors.json")),
        opt("fleet", "BENCH_fleet.json record to gate ('' = skip)", Some("BENCH_fleet.json")),
        opt("serve", "BENCH_serve.json record to gate ('' = skip)", Some("BENCH_serve.json")),
        opt("fl", "BENCH_fl.json record to gate ('' = skip)", Some("BENCH_fl.json")),
        opt("min-fleet", "override the fleet floor, devices-stepped/sec (0 = use policy)", Some("0")),
        opt("min-serve", "override the serve floor, checkins/sec (0 = use policy)", Some("0")),
        opt("min-fl", "override the fl floor, serve-routed rounds/sec (0 = use policy)", Some("0")),
    ];
    let args = parse_args(rest, &specs)?;
    let floors_path = args.get_str("floors", "ci/perf_floors.json");
    let floors = crate::util::json::parse_file(&floors_path)?;

    let fleet_path = args.get_str("fleet", "BENCH_fleet.json");
    if !fleet_path.is_empty() {
        let rec = crate::util::json::parse_file(&fleet_path)?;
        let got = rec.req_f64("best_devices_stepped_per_sec")?;
        let over = args.get_f64("min-fleet", 0.0)?;
        let floor = if over > 0.0 {
            over
        } else {
            floors.req_f64("fleet_devices_stepped_per_sec_min")?
        };
        crate::ensure!(
            got >= floor,
            "perf floor violated: {fleet_path} reports {got:.0} \
             devices-stepped/sec, floor is {floor:.0} ({floors_path})"
        );
        println!(
            "perf floor ok: fleet {got:.0} >= {floor:.0} \
             devices-stepped/sec"
        );
    }

    let serve_path = args.get_str("serve", "BENCH_serve.json");
    if !serve_path.is_empty() {
        let rec = crate::util::json::parse_file(&serve_path)?;
        let got = rec.req_f64("checkins_per_sec")?;
        let over = args.get_f64("min-serve", 0.0)?;
        let floor = if over > 0.0 {
            over
        } else {
            floors.req_f64("serve_checkins_per_sec_min")?
        };
        crate::ensure!(
            got >= floor,
            "perf floor violated: {serve_path} reports {got:.0} \
             checkins/sec, floor is {floor:.0} ({floors_path})"
        );
        println!("perf floor ok: serve {got:.0} >= {floor:.0} checkins/sec");
    }

    let fl_path = args.get_str("fl", "BENCH_fl.json");
    if !fl_path.is_empty() {
        let rec = crate::util::json::parse_file(&fl_path)?;
        let got = rec.req_f64("rounds_per_sec")?;
        let over = args.get_f64("min-fl", 0.0)?;
        let floor = if over > 0.0 {
            over
        } else {
            floors.req_f64("fl_rounds_per_sec_min")?
        };
        crate::ensure!(
            got >= floor,
            "perf floor violated: {fl_path} reports {got:.2} \
             serve-routed rounds/sec, floor is {floor:.2} ({floors_path})"
        );
        println!(
            "perf floor ok: fl {got:.2} >= {floor:.2} serve-routed \
             rounds/sec"
        );
    }
    Ok(())
}

fn cmd_obs(rest: &[String]) -> crate::Result<()> {
    match rest.split_first() {
        Some((what, r)) if what == "check" => cmd_obs_check(r),
        Some((what, r)) if what == "trace" => cmd_obs_trace(r),
        Some((what, r)) if what == "top" => cmd_obs_top(r),
        Some((what, r)) if what == "rates" => cmd_obs_rates(r),
        Some((what, r)) if what == "diff" => cmd_obs_diff(r),
        Some((other, _)) => crate::bail!(
            "unknown obs subcommand '{other}' (check|trace|top|rates|diff)"
        ),
        None => crate::bail!(
            "usage: swan obs <check|trace|top|rates|diff> ..."
        ),
    }
}

/// Pull the one required positional `<events.ndjson>` argument the obs
/// verbs share.
fn obs_file_arg<'a>(
    args: &'a Args,
    verb: &str,
    tail: &str,
) -> crate::Result<&'a str> {
    args.positional.first().map(String::as_str).ok_or_else(|| {
        crate::err!("usage: swan obs {verb} <events.ndjson>{tail}")
    })
}

/// `swan obs check <file>` — validate a captured NDJSON event stream:
/// every line parses as a JSON object with a string `reason` and a
/// numeric `seq`, `seq` strictly increases in file order (the sink
/// assigns seq under the same lock that orders the writes, so even
/// equal seqs mean two writers shared a stream), and every typed
/// reason carries its full payload schema
/// ([`crate::obs::analyze::required_fields`]).
fn cmd_obs_check(rest: &[String]) -> crate::Result<()> {
    let path = rest.first().ok_or_else(|| {
        crate::err!("usage: swan obs check <events.ndjson>")
    })?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| crate::err!("reading {path}: {e}"))?;
    let mut events = 0usize;
    let mut last_seq = -1.0f64;
    let mut by_reason: std::collections::BTreeMap<String, usize> =
        std::collections::BTreeMap::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let lineno = i + 1;
        let v = crate::util::json::parse(line)
            .map_err(|e| crate::err!("{path}:{lineno}: bad JSON: {e}"))?;
        let reason = v
            .req_str("reason")
            .map_err(|e| crate::err!("{path}:{lineno}: {e}"))?;
        crate::ensure!(
            !reason.is_empty(),
            "{path}:{lineno}: empty reason"
        );
        let seq = v
            .req_f64("seq")
            .map_err(|e| crate::err!("{path}:{lineno}: {e}"))?;
        crate::ensure!(
            seq > last_seq,
            "{path}:{lineno}: seq {seq} after {last_seq} — stream \
             ordering violated"
        );
        last_seq = seq;
        for field in crate::obs::analyze::required_fields(reason) {
            crate::ensure!(
                v.get(field).is_some(),
                "{path}:{lineno}: '{reason}' event is missing \
                 required field '{field}'"
            );
        }
        *by_reason.entry(reason.to_string()).or_insert(0) += 1;
        events += 1;
    }
    crate::ensure!(events > 0, "{path}: no events in stream");
    println!("obs check: {events} well-formed event(s) in {path}");
    for (reason, n) in &by_reason {
        println!("  {reason}: {n}");
    }
    Ok(())
}

/// `swan obs trace <file> --round R [--device D]` — reconstruct the
/// per-device lifecycles the `--trace` switch recorded, print each as
/// a timeline of edges with inter-edge gaps, and flag stalls (gaps
/// over `--stall`, or 5× the median gap when `--stall 0`).
fn cmd_obs_trace(rest: &[String]) -> crate::Result<()> {
    use crate::util::bench::fmt_secs;
    let specs = [
        opt("round", "round to reconstruct (required)", None),
        opt("device", "restrict to one device id", None),
        opt(
            "stall",
            "flag gaps over this many seconds (0 = 5x median gap)",
            Some("0"),
        ),
        opt("limit", "max lifecycles to print", Some("20")),
        switch(
            "expect-complete",
            "fail unless a complete admitted lifecycle exists",
        ),
    ];
    let args = parse_args(rest, &specs)?;
    let path = obs_file_arg(&args, "trace", " --round <R>")?;
    crate::ensure!(
        args.get("round").is_some(),
        "swan obs trace needs --round <R> (a lifecycle's identity is \
         (round, device))"
    );
    let round = args.get_u64("round", 0)?;
    let device = match args.get("device") {
        Some(_) => Some(args.get_u64("device", 0)?),
        None => None,
    };
    let limit = args.get_usize("limit", 20)?;

    let events = crate::obs::analyze::read_events(path)?;
    let lcs = crate::obs::analyze::lifecycles_filtered(
        &events,
        Some(round),
        device,
    );
    crate::ensure!(
        !lcs.is_empty(),
        "{path}: no trace-edge records for round {round}{} — was the \
         run traced? (pass --trace with --events)",
        device.map(|d| format!(", device {d}")).unwrap_or_default()
    );
    let stall = match args.get_f64("stall", 0.0)? {
        s if s > 0.0 => s,
        _ => crate::obs::analyze::auto_stall_threshold_s(&lcs),
    };
    let complete =
        lcs.iter().filter(|lc| lc.is_complete_admitted()).count();
    println!(
        "round {round}: {} lifecycle(s), {complete} complete admitted\
         {}",
        lcs.len(),
        if stall > 0.0 {
            format!(", stall threshold {}", fmt_secs(stall))
        } else {
            String::new()
        }
    );
    for lc in lcs.iter().take(limit) {
        let tag = if lc.is_complete_admitted() {
            " [complete]"
        } else if !lc.timestamps_monotone() {
            " [NON-MONOTONE]"
        } else {
            ""
        };
        println!(
            "  device {} ({} edges, {}){tag}",
            lc.device,
            lc.edges.len(),
            fmt_secs(lc.duration_s())
        );
        let mut prev_t = None;
        for e in &lc.edges {
            match prev_t {
                None => println!(
                    "    {:>10}  {}",
                    fmt_secs(e.t_s),
                    e.edge
                ),
                Some(p) => {
                    let gap = e.t_s - p;
                    let mark = if stall > 0.0 && gap > stall {
                        "  <-- stall"
                    } else {
                        ""
                    };
                    println!(
                        "    {:>10}  {}{mark}",
                        format!("+{}", fmt_secs(gap)),
                        e.edge
                    );
                }
            }
            prev_t = Some(e.t_s);
        }
    }
    if lcs.len() > limit {
        println!("  ... {} more (raise --limit)", lcs.len() - limit);
    }
    if args.has("expect-complete") {
        crate::ensure!(
            complete > 0,
            "{path}: round {round} has no complete admitted lifecycle"
        );
    }
    Ok(())
}

/// `swan obs top <file> --by stage|device` — K-way latency
/// attribution: which pipeline stage (inter-edge gap) or which device
/// lifecycle ate the most wall-clock. Without trace edges, stage mode
/// falls back to the `span-summary` records the runs always emit.
fn cmd_obs_top(rest: &[String]) -> crate::Result<()> {
    let specs = [
        opt("by", "attribution axis: stage|device", Some("stage")),
        opt("limit", "max rows to print", Some("10")),
        opt("round", "restrict to one round", None),
    ];
    let args = parse_args(rest, &specs)?;
    let path = obs_file_arg(&args, "top", " [--by stage|device]")?;
    let by = args.get_str("by", "stage");
    let limit = args.get_usize("limit", 10)?;
    let round = match args.get("round") {
        Some(_) => Some(args.get_u64("round", 0)?),
        None => None,
    };

    let events = crate::obs::analyze::read_events(path)?;
    let lcs =
        crate::obs::analyze::lifecycles_filtered(&events, round, None);
    let mut rows = match by.as_str() {
        "stage" => crate::obs::analyze::top_stages(&lcs),
        "device" => {
            crate::ensure!(
                !lcs.is_empty(),
                "{path}: no trace-edge records — --by device needs a \
                 traced run (pass --trace with --events)"
            );
            crate::obs::analyze::top_devices(&lcs)
        }
        other => crate::bail!("--by expects stage|device, got '{other}'"),
    };
    // Stage mode degrades gracefully: an untraced stream still carries
    // span-summary records, which answer the same "where did the time
    // go" question at phase granularity.
    if rows.is_empty() && by == "stage" {
        let mut map: std::collections::BTreeMap<
            String,
            crate::obs::analyze::GapStat,
        > = std::collections::BTreeMap::new();
        for v in &events {
            if v.get("reason")
                .and_then(crate::util::json::Value::as_str)
                != Some("span-summary")
            {
                continue;
            }
            let Some(crate::util::json::Value::Obj(spans)) =
                v.get("spans")
            else {
                continue;
            };
            for (name, s) in spans {
                let stat = map.entry(format!("span:{name}")).or_default();
                stat.count += s
                    .get("count")
                    .and_then(crate::util::json::Value::as_f64)
                    .unwrap_or(0.0) as u64;
                stat.total_s += s
                    .get("total_s")
                    .and_then(crate::util::json::Value::as_f64)
                    .unwrap_or(0.0);
                let max = s
                    .get("max_s")
                    .and_then(crate::util::json::Value::as_f64)
                    .unwrap_or(0.0);
                if max > stat.max_s {
                    stat.max_s = max;
                }
            }
        }
        rows = map.into_iter().collect();
        rows.sort_by(|a, b| b.1.total_s.total_cmp(&a.1.total_s));
        crate::ensure!(
            !rows.is_empty(),
            "{path}: no trace-edge or span-summary records to attribute"
        );
    }
    rows.truncate(limit);
    report::obs_top_table(&format!("top {by}s — {path}"), &rows)
        .emit()?;
    Ok(())
}

/// `swan obs rates <file> --window S` — bucket admission traffic
/// (check-ins, deferrals, aggregations) into fixed wall-clock windows;
/// falls back to per-round counts when the stream has no trace edges.
fn cmd_obs_rates(rest: &[String]) -> crate::Result<()> {
    let specs =
        [opt("window", "window width in seconds", Some("1"))];
    let args = parse_args(rest, &specs)?;
    let path = obs_file_arg(&args, "rates", " [--window S]")?;
    let window = args.get_f64("window", 1.0)?;
    crate::ensure!(window > 0.0, "--window must be positive");
    let events = crate::obs::analyze::read_events(path)?;
    let rows = crate::obs::analyze::windowed_rates(&events, window);
    crate::ensure!(
        !rows.is_empty(),
        "{path}: no admission traffic (trace edges or round records)"
    );
    let mut t = Table::new(
        &format!("admission rates — {path}"),
        &[
            "window",
            "checkins",
            "deferred",
            "aggregated",
            "checkins/s",
            "defer_rate",
        ],
    );
    for r in &rows {
        let cps = if r.span_s > 0.0 {
            r.checkins as f64 / r.span_s
        } else {
            0.0
        };
        let seen = r.checkins + r.deferred;
        let defer_rate = if seen > 0 {
            100.0 * r.deferred as f64 / seen as f64
        } else {
            0.0
        };
        t.row(&[
            r.label.clone(),
            r.checkins.to_string(),
            r.deferred.to_string(),
            r.aggregated.to_string(),
            format!("{cps:.1}"),
            format!("{defer_rate:.1}%"),
        ]);
    }
    t.emit()?;
    Ok(())
}

fn fmt_metric(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.4}")
    }
}

/// `swan obs diff <candidate> <baseline>` — compare two runs (NDJSON
/// streams or `BENCH_*.json` snapshots) and exit nonzero when a metric
/// with a known good direction regresses past `--threshold` percent.
fn cmd_obs_diff(rest: &[String]) -> crate::Result<()> {
    let specs = [
        opt("threshold", "regression gate in percent", Some("10")),
        switch("report-only", "print the diff but never fail"),
    ];
    let args = parse_args(rest, &specs)?;
    let (cand_path, base_path) =
        match (args.positional.first(), args.positional.get(1)) {
            (Some(c), Some(b)) => (c.as_str(), b.as_str()),
            _ => crate::bail!(
                "usage: swan obs diff <candidate> <baseline> \
                 [--threshold PCT] [--report-only]"
            ),
        };
    let threshold = args.get_f64("threshold", 10.0)?;
    crate::ensure!(threshold >= 0.0, "--threshold must be >= 0");
    let cand = crate::obs::analyze::load_any(cand_path)?;
    let base = crate::obs::analyze::load_any(base_path)?;
    let rows = crate::obs::analyze::diff(&cand, &base, threshold)?;
    let mut t = Table::new(
        &format!("{cand_path} vs {base_path}"),
        &["metric", "candidate", "baseline", "delta", "verdict"],
    );
    let mut regressions = 0usize;
    for r in &rows {
        if r.regressed {
            regressions += 1;
        }
        t.row(&[
            r.metric.clone(),
            fmt_metric(r.candidate),
            fmt_metric(r.baseline),
            format!("{:+.1}%", r.delta_pct),
            if r.regressed { "REGRESSED" } else { "ok" }.to_string(),
        ]);
    }
    t.emit()?;
    if regressions > 0 && !args.has("report-only") {
        crate::bail!(
            "{regressions} metric(s) regressed more than {threshold}% \
             vs {base_path}"
        );
    }
    println!(
        "obs diff: {} metric(s), {regressions} regression(s) over \
         {threshold}%",
        rows.len()
    );
    Ok(())
}

fn cmd_lint(rest: &[String]) -> crate::Result<()> {
    let specs = vec![
        switch(
            "deny-all",
            "treat warn-level findings (panic family) as errors",
        ),
        switch("json", "emit one JSON object per finding (NDJSON)"),
    ];
    if rest.iter().any(|a| a == "--help") {
        println!(
            "{}",
            usage(
                "lint",
                "static analysis over the crate's own sources",
                &specs
            )
        );
        return Ok(());
    }
    let args = parse_args(rest, &specs)?;
    let paths = if args.positional.is_empty() {
        vec!["rust/src".to_string()]
    } else {
        args.positional.clone()
    };
    let findings = crate::lint::lint_paths(&paths)?;
    if args.has("json") {
        use crate::util::json::Value;
        for f in &findings {
            let v = Value::obj()
                .set("file", f.file.as_str())
                .set("line", f.line as usize)
                .set("rule", f.rule)
                .set("severity", if f.deny { "deny" } else { "warn" })
                .set("message", f.message.as_str());
            println!("{v}");
        }
    } else if findings.is_empty() {
        println!("swan lint: clean ({})", paths.join(", "));
    } else {
        println!("{}", report::lint_table(&findings).to_markdown());
    }
    let failing = crate::lint::failing(&findings, args.has("deny-all"));
    crate::ensure!(
        failing == 0,
        "swan lint: {failing} failing finding(s) of {} total",
        findings.len()
    );
    Ok(())
}

fn cmd_traces(rest: &[String]) -> crate::Result<()> {
    let specs = [opt("users", "raw users to synthesize", Some("8"))];
    let args = parse_args(rest, &specs)?;
    let n = args.get_usize("users", 8)?;
    let gen = crate::trace::greenhub::TraceGenerator::default();
    let (kept, stats) =
        crate::trace::filter::select_quality_traces(gen.population(1, n));
    println!(
        "generated {n} users → {} pass A.2 filters \
         (period {}, freq {}, gap {}, long-gaps {})",
        stats.pass,
        stats.fail_period,
        stats.fail_frequency,
        stats.fail_max_gap,
        stats.fail_long_gaps
    );
    let resampled: Vec<_> = kept
        .iter()
        .map(|t| crate::trace::resample::resample_trace(t).unwrap())
        .collect();
    let augmented = crate::trace::augment::augment_shifts(&resampled);
    println!(
        "resampled to 10-min grid, 23×1h shift augmentation → {} clients",
        augmented.len()
    );
    Ok(())
}

fn cmd_report(rest: &[String]) -> crate::Result<()> {
    let which = rest.first().map(String::as_str).unwrap_or("table2");
    match which {
        "fig1" | "fig1b" => report::fig1b_matmul_rows().1.emit()?,
        "fig2" | "fig2a" => {
            let w = load_or_builtin(WorkloadName::Resnet34, "artifacts");
            report::fig2_combo_rows(DeviceId::Pixel3, &w).1.emit()?
        }
        "fig2b" => {
            let w = load_or_builtin(WorkloadName::ShufflenetV2, "artifacts");
            report::fig2_combo_rows(DeviceId::Pixel3, &w).1.emit()?
        }
        "fig3" => report::fig3_rows("artifacts").1.emit()?,
        "table2" => report::table2_rows("artifacts").1.emit()?,
        "table3" => report::table3_rows("artifacts").1.emit()?,
        "fleet" => report::fleet_eval_rows("smoke", 4)?.1.emit()?,
        other => crate::bail!(
            "unknown report '{other}' \
             (fig1|fig2|fig2b|fig3|table2|table3|fleet)"
        ),
    }
    Ok(())
}
