//! The coordinator wire format: compact length-prefixed binary frames.
//!
//! Every frame is `u32-LE body length | body`, where the body is a
//! one-byte message tag followed by fixed-width little-endian fields
//! (f64/f32 travel as raw bits, so values round-trip bit-exactly — the
//! digest-parity contract between the in-process and loopback-TCP paths
//! depends on that). No serde, no varints, no text: a `CheckIn` is 20
//! bytes on the wire (4 length + 1 tag + 15 payload) and decoding is a
//! handful of array loads.
//!
//! Message set (tag):
//!
//! | tag | message        | direction | payload |
//! |-----|----------------|-----------|---------|
//! | 1   | `CheckIn`      | c → s     | device u64, model u8, band u8, charging u8, steps u32 |
//! | 2   | `LeasePoll`    | c → s     | device u64 |
//! | 3   | `PlanLease`    | s → c     | device u64, round u32, seq u32, steps u32, latency f64, energy f64 |
//! | 4   | `UpdatePush`   | c → s     | device u64, round u32, seq u32, weight f64, n u32, n×f32 |
//! | 5   | `Ack`          | s → c     | kind u8 (+ retry f32 / picked u32) |
//! | 6   | `RoundCtl`     | c → s     | round u32, op u8 (1 = close, 2 = finish) |
//! | 7   | `RoundSummary` | s → c     | round u32, checkins u64, admitted u64, deferred u64, participants u32, round_time f64, round_energy f64, digest u64 |
//! | 8   | `ModelPull`    | c → s     | device u64 |
//! | 9   | `ModelState`   | s → c     | round u32, n u32, n×f32 |
//! | 10  | `ModelInit`    | c → s     | n u32, n×f32 |
//!
//! Oversized or malformed frames are decode errors, never panics: a
//! hostile or corrupt peer costs the server one connection, not the
//! process.

use std::io::{Read, Write};

use crate::soc::device::DeviceId;

/// Hard ceiling on a frame body (guards against corrupt length
/// prefixes allocating gigabytes). 16 MiB fits ~4M-parameter updates.
pub const MAX_FRAME_BYTES: usize = 16 << 20;

/// A device's round-start report: who it is and what context it is in.
/// `band`/`charging` are the profile-cache key axes (§4.2 sharing).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CheckIn {
    pub device: u64,
    /// SoC model wire code (see [`model_code`]).
    pub model: u8,
    /// Thermal band 0 (cool) / 1 (warm) / 2 (hot).
    pub band: u8,
    pub charging: bool,
    /// Local SGD steps this device runs if leased.
    pub steps: u32,
}

/// After `RoundCtl::Close`, an admitted device asks whether it was
/// selected.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LeasePoll {
    pub device: u64,
}

/// A participation lease: the resolved §4.2 plan cost for this device's
/// whole local epoch, plus the dense slot (`seq`) its update must fill.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlanLease {
    pub device: u64,
    pub round: u32,
    /// Index into the round's picked order — the aggregation fold key.
    pub seq: u32,
    pub steps: u32,
    pub latency_s: f64,
    pub energy_j: f64,
}

/// A leased device's model update (one flat parameter leaf + weight).
#[derive(Clone, Debug, PartialEq)]
pub struct UpdatePush {
    pub device: u64,
    pub round: u32,
    pub seq: u32,
    pub weight: f64,
    pub params: Vec<f32>,
}

/// Server verdicts. `Deferred` is the explicit-backpressure path: the
/// admission queue is full and the device should retry after the given
/// delay instead of hammering the coordinator.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Ack {
    Admitted,
    Deferred { retry_after_s: f32 },
    NotSelected,
    Accepted,
    Rejected,
    Closed { picked: u32 },
}

/// Round-phase control (driven by the load generator / deployment
/// round pacer): close check-ins → run selection; finish → aggregate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RoundOp {
    Close,
    Finish,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RoundCtl {
    pub round: u32,
    pub op: RoundOp,
}

/// What one finished round produced. `digest` is the coordinator's
/// cumulative parity digest after folding this round.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RoundSummary {
    pub round: u32,
    pub checkins: u64,
    pub admitted: u64,
    pub deferred: u64,
    pub participants: u32,
    /// Straggler-paced round duration (max lease latency), seconds.
    pub round_time_s: f64,
    pub round_energy_j: f64,
    pub digest: u64,
}

/// Ask the coordinator for the current global model (the serve-routed
/// training loop pulls after each `RoundCtl::Finish`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelPull {
    pub device: u64,
}

/// The coordinator's current global model: the round counter it is
/// valid for plus the flat f32 parameters (raw bits on the wire, so
/// the pulled model is bit-identical to the aggregate).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelState {
    pub round: u32,
    pub params: Vec<f32>,
}

/// Seed the coordinator's global model before round 0 (the training
/// driver owns initialization so every wiring starts from one model).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelInit {
    pub params: Vec<f32>,
}

/// One wire frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    CheckIn(CheckIn),
    LeasePoll(LeasePoll),
    PlanLease(PlanLease),
    UpdatePush(UpdatePush),
    Ack(Ack),
    RoundCtl(RoundCtl),
    RoundSummary(RoundSummary),
    ModelPull(ModelPull),
    ModelState(ModelState),
    ModelInit(ModelInit),
}

/// SoC model → wire code. The codes are part of the wire format: do not
/// reorder.
pub fn model_code(id: DeviceId) -> u8 {
    match id {
        DeviceId::Pixel3 => 0,
        DeviceId::S10e => 1,
        DeviceId::OnePlus8 => 2,
        DeviceId::TabS6 => 3,
        DeviceId::Mi10 => 4,
    }
}

/// Wire code → SoC model (None for unknown codes — a decode-time
/// rejection, not a panic).
pub fn model_from_code(code: u8) -> Option<DeviceId> {
    match code {
        0 => Some(DeviceId::Pixel3),
        1 => Some(DeviceId::S10e),
        2 => Some(DeviceId::OnePlus8),
        3 => Some(DeviceId::TabS6),
        4 => Some(DeviceId::Mi10),
        _ => None,
    }
}

const TAG_CHECK_IN: u8 = 1;
const TAG_LEASE_POLL: u8 = 2;
const TAG_PLAN_LEASE: u8 = 3;
const TAG_UPDATE_PUSH: u8 = 4;
const TAG_ACK: u8 = 5;
const TAG_ROUND_CTL: u8 = 6;
const TAG_ROUND_SUMMARY: u8 = 7;
const TAG_MODEL_PULL: u8 = 8;
const TAG_MODEL_STATE: u8 = 9;
const TAG_MODEL_INIT: u8 = 10;

const ACK_ADMITTED: u8 = 1;
const ACK_DEFERRED: u8 = 2;
const ACK_NOT_SELECTED: u8 = 3;
const ACK_ACCEPTED: u8 = 4;
const ACK_REJECTED: u8 = 5;
const ACK_CLOSED: u8 = 6;

// -- encoding ---------------------------------------------------------------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_bits().to_le_bytes());
}

/// Append `msg` as one frame (length prefix included) to `buf`.
pub fn encode_into(msg: &Msg, buf: &mut Vec<u8>) {
    let start = buf.len();
    put_u32(buf, 0); // length placeholder, patched below
    match msg {
        Msg::CheckIn(m) => {
            buf.push(TAG_CHECK_IN);
            put_u64(buf, m.device);
            buf.push(m.model);
            buf.push(m.band);
            buf.push(m.charging as u8);
            put_u32(buf, m.steps);
        }
        Msg::LeasePoll(m) => {
            buf.push(TAG_LEASE_POLL);
            put_u64(buf, m.device);
        }
        Msg::PlanLease(m) => {
            buf.push(TAG_PLAN_LEASE);
            put_u64(buf, m.device);
            put_u32(buf, m.round);
            put_u32(buf, m.seq);
            put_u32(buf, m.steps);
            put_f64(buf, m.latency_s);
            put_f64(buf, m.energy_j);
        }
        Msg::UpdatePush(m) => {
            buf.push(TAG_UPDATE_PUSH);
            put_u64(buf, m.device);
            put_u32(buf, m.round);
            put_u32(buf, m.seq);
            put_f64(buf, m.weight);
            put_u32(buf, m.params.len() as u32);
            for p in &m.params {
                put_f32(buf, *p);
            }
        }
        Msg::Ack(a) => {
            buf.push(TAG_ACK);
            match a {
                Ack::Admitted => buf.push(ACK_ADMITTED),
                Ack::Deferred { retry_after_s } => {
                    buf.push(ACK_DEFERRED);
                    put_f32(buf, *retry_after_s);
                }
                Ack::NotSelected => buf.push(ACK_NOT_SELECTED),
                Ack::Accepted => buf.push(ACK_ACCEPTED),
                Ack::Rejected => buf.push(ACK_REJECTED),
                Ack::Closed { picked } => {
                    buf.push(ACK_CLOSED);
                    put_u32(buf, *picked);
                }
            }
        }
        Msg::RoundCtl(m) => {
            buf.push(TAG_ROUND_CTL);
            put_u32(buf, m.round);
            buf.push(match m.op {
                RoundOp::Close => 1,
                RoundOp::Finish => 2,
            });
        }
        Msg::RoundSummary(m) => {
            buf.push(TAG_ROUND_SUMMARY);
            put_u32(buf, m.round);
            put_u64(buf, m.checkins);
            put_u64(buf, m.admitted);
            put_u64(buf, m.deferred);
            put_u32(buf, m.participants);
            put_f64(buf, m.round_time_s);
            put_f64(buf, m.round_energy_j);
            put_u64(buf, m.digest);
        }
        Msg::ModelPull(m) => {
            buf.push(TAG_MODEL_PULL);
            put_u64(buf, m.device);
        }
        Msg::ModelState(m) => {
            buf.push(TAG_MODEL_STATE);
            put_u32(buf, m.round);
            put_u32(buf, m.params.len() as u32);
            for p in &m.params {
                put_f32(buf, *p);
            }
        }
        Msg::ModelInit(m) => {
            buf.push(TAG_MODEL_INIT);
            put_u32(buf, m.params.len() as u32);
            for p in &m.params {
                put_f32(buf, *p);
            }
        }
    }
    let body_len = (buf.len() - start - 4) as u32;
    buf[start..start + 4].copy_from_slice(&body_len.to_le_bytes());
}

/// Encode `msg` as a standalone frame.
pub fn encode(msg: &Msg) -> Vec<u8> {
    let mut buf = Vec::with_capacity(32);
    encode_into(msg, &mut buf);
    buf
}

// -- decoding ---------------------------------------------------------------

struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> crate::Result<&'a [u8]> {
        crate::ensure!(
            self.pos + n <= self.b.len(),
            "wire: truncated frame (need {n} bytes at offset {}, body is {})",
            self.pos,
            self.b.len()
        );
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> crate::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> crate::Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> crate::Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([
            s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7],
        ]))
    }

    fn f64(&mut self) -> crate::Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn f32(&mut self) -> crate::Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn done(&self) -> crate::Result<()> {
        crate::ensure!(
            self.pos == self.b.len(),
            "wire: {} trailing bytes after message",
            self.b.len() - self.pos
        );
        Ok(())
    }
}

/// Decode one frame body (the bytes after the length prefix).
pub fn decode_body(body: &[u8]) -> crate::Result<Msg> {
    let mut c = Cursor { b: body, pos: 0 };
    let tag = c.u8()?;
    let msg = match tag {
        TAG_CHECK_IN => Msg::CheckIn(CheckIn {
            device: c.u64()?,
            model: c.u8()?,
            band: c.u8()?,
            charging: c.u8()? != 0,
            steps: c.u32()?,
        }),
        TAG_LEASE_POLL => Msg::LeasePoll(LeasePoll { device: c.u64()? }),
        TAG_PLAN_LEASE => Msg::PlanLease(PlanLease {
            device: c.u64()?,
            round: c.u32()?,
            seq: c.u32()?,
            steps: c.u32()?,
            latency_s: c.f64()?,
            energy_j: c.f64()?,
        }),
        TAG_UPDATE_PUSH => {
            let device = c.u64()?;
            let round = c.u32()?;
            let seq = c.u32()?;
            let weight = c.f64()?;
            let n = c.u32()? as usize;
            // divide instead of multiply: `n * 4` could wrap on 32-bit
            // targets and bypass the allocation bound
            crate::ensure!(
                n <= body.len() / 4,
                "wire: update claims {n} params in a {}-byte body",
                body.len()
            );
            let mut params = Vec::with_capacity(n);
            for _ in 0..n {
                params.push(c.f32()?);
            }
            Msg::UpdatePush(UpdatePush {
                device,
                round,
                seq,
                weight,
                params,
            })
        }
        TAG_ACK => {
            let kind = c.u8()?;
            Msg::Ack(match kind {
                ACK_ADMITTED => Ack::Admitted,
                ACK_DEFERRED => Ack::Deferred {
                    retry_after_s: c.f32()?,
                },
                ACK_NOT_SELECTED => Ack::NotSelected,
                ACK_ACCEPTED => Ack::Accepted,
                ACK_REJECTED => Ack::Rejected,
                ACK_CLOSED => Ack::Closed { picked: c.u32()? },
                other => crate::bail!("wire: unknown ack kind {other}"),
            })
        }
        TAG_ROUND_CTL => {
            let round = c.u32()?;
            let op = match c.u8()? {
                1 => RoundOp::Close,
                2 => RoundOp::Finish,
                other => crate::bail!("wire: unknown round op {other}"),
            };
            Msg::RoundCtl(RoundCtl { round, op })
        }
        TAG_ROUND_SUMMARY => Msg::RoundSummary(RoundSummary {
            round: c.u32()?,
            checkins: c.u64()?,
            admitted: c.u64()?,
            deferred: c.u64()?,
            participants: c.u32()?,
            round_time_s: c.f64()?,
            round_energy_j: c.f64()?,
            digest: c.u64()?,
        }),
        TAG_MODEL_PULL => Msg::ModelPull(ModelPull { device: c.u64()? }),
        TAG_MODEL_STATE => {
            let round = c.u32()?;
            let n = c.u32()? as usize;
            crate::ensure!(
                n <= body.len() / 4,
                "wire: model state claims {n} params in a {}-byte body",
                body.len()
            );
            let mut params = Vec::with_capacity(n);
            for _ in 0..n {
                params.push(c.f32()?);
            }
            Msg::ModelState(ModelState { round, params })
        }
        TAG_MODEL_INIT => {
            let n = c.u32()? as usize;
            crate::ensure!(
                n <= body.len() / 4,
                "wire: model init claims {n} params in a {}-byte body",
                body.len()
            );
            let mut params = Vec::with_capacity(n);
            for _ in 0..n {
                params.push(c.f32()?);
            }
            Msg::ModelInit(ModelInit { params })
        }
        other => crate::bail!("wire: unknown message tag {other}"),
    };
    c.done()?;
    Ok(msg)
}

/// Write one frame to `w` (no flush — callers batch frames and flush
/// once per pipeline burst).
pub fn write_frame<W: Write>(w: &mut W, msg: &Msg) -> crate::Result<()> {
    let buf = encode(msg);
    w.write_all(&buf)?;
    Ok(())
}

/// Read one frame from `r`. `Ok(None)` means the peer closed the
/// connection cleanly at a frame boundary; EOF mid-frame is an error.
pub fn read_frame<R: Read>(r: &mut R) -> crate::Result<Option<Msg>> {
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        let n = r.read(&mut len_buf[got..])?;
        if n == 0 {
            if got == 0 {
                return Ok(None); // clean EOF between frames
            }
            crate::bail!("wire: EOF inside a frame header ({got}/4 bytes)");
        }
        got += n;
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    crate::ensure!(
        (1..=MAX_FRAME_BYTES).contains(&len),
        "wire: frame body of {len} bytes outside 1..={MAX_FRAME_BYTES}"
    );
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)
        .map_err(|e| crate::err!("wire: EOF inside a {len}-byte frame: {e}"))?;
    decode_body(&body).map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Msg) {
        let bytes = encode(&msg);
        let len =
            u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
        assert_eq!(len as usize + 4, bytes.len(), "length prefix");
        let back = decode_body(&bytes[4..]).unwrap();
        assert_eq!(back, msg);
    }

    #[test]
    fn every_message_roundtrips() {
        roundtrip(Msg::CheckIn(CheckIn {
            device: u64::MAX - 3,
            model: 4,
            band: 2,
            charging: true,
            steps: 7,
        }));
        roundtrip(Msg::LeasePoll(LeasePoll { device: 9 }));
        roundtrip(Msg::PlanLease(PlanLease {
            device: 1,
            round: 2,
            seq: 3,
            steps: 4,
            latency_s: 0.1 + 0.2, // a value with ugly low bits
            energy_j: f64::MIN_POSITIVE,
        }));
        roundtrip(Msg::UpdatePush(UpdatePush {
            device: 5,
            round: 6,
            seq: 0,
            weight: 12.5,
            params: vec![1.0, -2.5, f32::MIN_POSITIVE, 0.0],
        }));
        for ack in [
            Ack::Admitted,
            Ack::Deferred { retry_after_s: 30.0 },
            Ack::NotSelected,
            Ack::Accepted,
            Ack::Rejected,
            Ack::Closed { picked: 1000 },
        ] {
            roundtrip(Msg::Ack(ack));
        }
        for op in [RoundOp::Close, RoundOp::Finish] {
            roundtrip(Msg::RoundCtl(RoundCtl { round: 19, op }));
        }
        roundtrip(Msg::RoundSummary(RoundSummary {
            round: 3,
            checkins: 2_000,
            admitted: 1_900,
            deferred: 100,
            participants: 100,
            round_time_s: 123.456,
            round_energy_j: 9.75,
            digest: 0xDEAD_BEEF_CAFE_F00D,
        }));
        roundtrip(Msg::ModelPull(ModelPull { device: 77 }));
        roundtrip(Msg::ModelState(ModelState {
            round: 12,
            params: vec![0.5, -1.25, f32::MIN_POSITIVE, -0.0],
        }));
        roundtrip(Msg::ModelInit(ModelInit {
            params: vec![1.0, 2.0, -3.5],
        }));
    }

    #[test]
    fn floats_roundtrip_bit_exactly() {
        let lease = PlanLease {
            device: 0,
            round: 0,
            seq: 0,
            steps: 1,
            latency_s: f64::from_bits(0x3FF0_0000_0000_0001), // 1.0 + 1 ulp
            energy_j: -0.0,
        };
        let bytes = encode(&Msg::PlanLease(lease));
        match decode_body(&bytes[4..]).unwrap() {
            Msg::PlanLease(back) => {
                assert_eq!(back.latency_s.to_bits(), lease.latency_s.to_bits());
                assert_eq!(back.energy_j.to_bits(), lease.energy_j.to_bits());
            }
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn stream_framing_and_clean_eof() {
        let mut buf = Vec::new();
        let a = Msg::Ack(Ack::Admitted);
        let b = Msg::LeasePoll(LeasePoll { device: 42 });
        encode_into(&a, &mut buf);
        encode_into(&b, &mut buf);
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap(), Some(a));
        assert_eq!(read_frame(&mut r).unwrap(), Some(b));
        assert_eq!(read_frame(&mut r).unwrap(), None, "clean EOF");
    }

    #[test]
    fn malformed_frames_error_not_panic() {
        // unknown tag
        assert!(decode_body(&[99]).is_err());
        // truncated body
        assert!(decode_body(&[TAG_LEASE_POLL, 1, 2]).is_err());
        // trailing garbage
        let mut bytes = encode(&Msg::Ack(Ack::Accepted));
        bytes.push(0);
        let len = (bytes.len() - 4) as u32;
        bytes[0..4].copy_from_slice(&len.to_le_bytes());
        assert!(decode_body(&bytes[4..]).is_err());
        // EOF mid-header and mid-frame
        let mut r: &[u8] = &[1, 0];
        assert!(read_frame(&mut r).is_err());
        let good = encode(&Msg::Ack(Ack::Accepted));
        let mut r2 = &good[..good.len() - 1];
        assert!(read_frame(&mut r2).is_err());
        // absurd length prefix rejected before allocation
        let mut r3: &[u8] = &[0xFF, 0xFF, 0xFF, 0xFF, 0, 0];
        assert!(read_frame(&mut r3).is_err());
        // update param count inconsistent with body size
        let mut body = vec![TAG_UPDATE_PUSH];
        body.extend_from_slice(&7u64.to_le_bytes());
        body.extend_from_slice(&0u32.to_le_bytes());
        body.extend_from_slice(&0u32.to_le_bytes());
        body.extend_from_slice(&1.0f64.to_bits().to_le_bytes());
        body.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_body(&body).is_err());
        // model state/init param counts inconsistent with body size
        let mut state = vec![TAG_MODEL_STATE];
        state.extend_from_slice(&0u32.to_le_bytes());
        state.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_body(&state).is_err());
        let mut init = vec![TAG_MODEL_INIT];
        init.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_body(&init).is_err());
    }

    #[test]
    fn model_codes_are_a_bijection() {
        for d in crate::soc::device::all_devices() {
            assert_eq!(model_from_code(model_code(d.id)), Some(d.id));
        }
        assert_eq!(model_from_code(200), None);
    }
}
