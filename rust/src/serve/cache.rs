//! The coordinator's LRU profile cache: §4.2 exploration shared across
//! equivalent devices.
//!
//! At fleet scale, millions of check-ins collapse onto a handful of
//! *contexts*: (SoC model, thermal band, charger state). The execution
//! plan Swan would pick — chain head after enumerate → estimate → prune
//! (§4.2) — is a pure function of that context, so the coordinator
//! explores each context **once** and serves the cached [`StepCost`] to
//! every equivalent device instead of recomputing the choice space per
//! check-in. The cache is a fixed-capacity LRU (intrusive list over a
//! slot arena + `HashMap` index, no external crates): a deployment that
//! adds SoC models or finer bands evicts the coldest context instead of
//! growing without bound, and because [`plan_cost`] is pure, an evicted
//! entry re-explores to bit-identical values — eviction can never
//! perturb the digest-parity contract.

use std::collections::HashMap;

use crate::fl::FlArm;
use crate::fleet::coordinator::{explore_profiles, StepCost};
use crate::soc::device::{device, DeviceId};
use crate::soc::exec_model::{estimate, ExecutionContext};
use crate::swan::prune::prune_dominated;
use crate::workload::Workload;

/// Thermal bands a check-in may report (0 = cool … 2 = hot).
pub const N_THERMAL_BANDS: u8 = 3;

/// Per-band DVFS derate applied to the explored plan cost. Band 0 runs
/// the plan as profiled; hotter bands pay progressively throttled
/// clocks.
pub fn band_derate(band: u8) -> f64 {
    match band {
        0 => 1.0,
        1 => 1.25,
        _ => 1.5,
    }
}

/// Charger-state multiplier: an uncharged device runs its epoch under
/// the OS's battery-saver cap; a charging device runs the plan as
/// profiled.
pub fn charger_relief(charging: bool) -> f64 {
    if charging {
        1.0
    } else {
        1.1
    }
}

/// The profile-cache key: one execution context.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Wire model code (`wire::model_code`).
    pub model: u8,
    pub band: u8,
    pub charging: bool,
}

impl PlanKey {
    /// Dense packing for the `HashMap` index.
    fn pack(self) -> u32 {
        ((self.model as u32) << 8)
            | ((self.band as u32) << 1)
            | self.charging as u32
    }
}

/// The §4.2 plan cost for one context — THE definition both the
/// coordinator (through the cache) and the parity oracle (directly)
/// evaluate, so their lease arithmetic agrees bit-for-bit. Pure:
/// explores the full choice space through the same
/// [`explore_profiles`] pipeline the fleet `ProfileCoordinator` runs,
/// prunes, takes the chain head, and applies the band/charger
/// envelope.
pub fn plan_cost(
    workload: &Workload,
    model: DeviceId,
    band: u8,
    charging: bool,
) -> StepCost {
    let d = device(model);
    let chain = prune_dominated(explore_profiles(workload, &d));
    let best = &chain[0];
    let m = band_derate(band) * charger_relief(charging);
    StepCost {
        latency_s: best.latency_s * m,
        energy_j: best.energy_j * m,
    }
}

/// [`plan_cost`] under a policy arm. The Swan arm is the §4.2 chain
/// head (bit-identical to [`plan_cost`]); the baseline arm is the
/// PyTorch-greedy low-latency core set — the same estimate the fleet
/// `ProfileCoordinator` benches for its baseline — under the same
/// band/charger envelope, so the FL arms differ only in the execution
/// plan, never in the environment model.
pub fn plan_cost_for_arm(
    workload: &Workload,
    model: DeviceId,
    band: u8,
    charging: bool,
    arm: FlArm,
) -> StepCost {
    match arm {
        FlArm::Swan => plan_cost(workload, model, band, charging),
        FlArm::Baseline => {
            let d = device(model);
            let ctx = ExecutionContext::exclusive(d.n_cores());
            let est =
                estimate(&d, workload, &d.low_latency_cores(), &ctx);
            let m = band_derate(band) * charger_relief(charging);
            StepCost {
                latency_s: est.latency_s * m,
                energy_j: est.energy_j * m,
            }
        }
    }
}

const NIL: usize = usize::MAX;

struct Slot {
    key: u32,
    cost: StepCost,
    prev: usize,
    next: usize,
}

/// Fixed-capacity LRU over [`PlanKey`] → [`StepCost`].
pub struct ProfileCache {
    cap: usize,
    map: HashMap<u32, usize>,
    slots: Vec<Slot>,
    /// Most-recently-used slot (NIL when empty).
    head: usize,
    /// Least-recently-used slot (the eviction victim).
    tail: usize,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl ProfileCache {
    pub fn new(capacity: usize) -> ProfileCache {
        let cap = capacity.max(1);
        ProfileCache {
            cap,
            map: HashMap::with_capacity(cap),
            slots: Vec::with_capacity(cap),
            head: NIL,
            tail: NIL,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    fn unlink(&mut self, i: usize) {
        let (prev, next) = (self.slots[i].prev, self.slots[i].next);
        if prev != NIL {
            self.slots[prev].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.slots[next].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    fn push_front(&mut self, i: usize) {
        self.slots[i].prev = NIL;
        self.slots[i].next = self.head;
        if self.head != NIL {
            self.slots[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    /// Look `key` up, computing (and inserting) via `explore` on a
    /// miss; either way the entry becomes most-recently-used. Returns
    /// the plan cost and whether it was a hit.
    pub fn get_or_insert_with(
        &mut self,
        key: PlanKey,
        explore: impl FnOnce() -> StepCost,
    ) -> (StepCost, bool) {
        let packed = key.pack();
        if let Some(&i) = self.map.get(&packed) {
            self.hits += 1;
            if self.head != i {
                self.unlink(i);
                self.push_front(i);
            }
            return (self.slots[i].cost, true);
        }
        self.misses += 1;
        let cost = explore();
        let i = if self.map.len() >= self.cap {
            // reuse the LRU victim's slot
            let victim = self.tail;
            self.evictions += 1;
            self.unlink(victim);
            self.map.remove(&self.slots[victim].key);
            self.slots[victim].key = packed;
            self.slots[victim].cost = cost;
            victim
        } else {
            self.slots.push(Slot {
                key: packed,
                cost,
                prev: NIL,
                next: NIL,
            });
            self.slots.len() - 1
        };
        self.push_front(i);
        self.map.insert(packed, i);
        (cost, false)
    }

    /// Recency order, MRU first (tests + introspection).
    #[cfg(test)]
    fn keys_mru_first(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.map.len());
        let mut i = self.head;
        while i != NIL {
            out.push(self.slots[i].key);
            i = self.slots[i].next;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{builtin, WorkloadName};

    fn key(model: u8, band: u8, charging: bool) -> PlanKey {
        PlanKey {
            model,
            band,
            charging,
        }
    }

    fn stub(v: f64) -> StepCost {
        StepCost {
            latency_s: v,
            energy_j: 2.0 * v,
        }
    }

    #[test]
    fn shares_exploration_across_equivalent_devices() {
        let mut c = ProfileCache::new(8);
        let mut explorations = 0;
        for _ in 0..100 {
            let (cost, _) = c.get_or_insert_with(key(1, 0, true), || {
                explorations += 1;
                stub(3.0)
            });
            assert_eq!(cost.latency_s, 3.0);
        }
        assert_eq!(explorations, 1, "one exploration serves all equals");
        assert_eq!(c.hits, 99);
        assert_eq!(c.misses, 1);
        assert_eq!(c.evictions, 0);
    }

    #[test]
    fn distinct_contexts_are_distinct_entries() {
        let mut c = ProfileCache::new(16);
        c.get_or_insert_with(key(0, 0, false), || stub(1.0));
        c.get_or_insert_with(key(0, 0, true), || stub(2.0));
        c.get_or_insert_with(key(0, 1, false), || stub(3.0));
        c.get_or_insert_with(key(1, 0, false), || stub(4.0));
        assert_eq!(c.len(), 4);
        let (back, hit) =
            c.get_or_insert_with(key(0, 1, false), || unreachable!());
        assert!(hit);
        assert_eq!(back.latency_s, 3.0);
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c = ProfileCache::new(2);
        c.get_or_insert_with(key(0, 0, false), || stub(1.0));
        c.get_or_insert_with(key(1, 0, false), || stub(2.0));
        // touch key 0 so key 1 becomes the LRU victim
        c.get_or_insert_with(key(0, 0, false), || unreachable!());
        c.get_or_insert_with(key(2, 0, false), || stub(3.0));
        assert_eq!(c.evictions, 1);
        assert_eq!(c.len(), 2);
        assert_eq!(
            c.keys_mru_first(),
            vec![
                key(2, 0, false).pack(),
                key(0, 0, false).pack()
            ]
        );
        // evicted key 1 must re-explore
        let (_, hit) = c.get_or_insert_with(key(1, 0, false), || stub(2.0));
        assert!(!hit);
        assert_eq!(c.evictions, 2);
    }

    #[test]
    fn single_slot_cache_still_correct() {
        let mut c = ProfileCache::new(0); // clamped to 1
        assert_eq!(c.capacity(), 1);
        c.get_or_insert_with(key(0, 0, false), || stub(1.0));
        let (v, hit) = c.get_or_insert_with(key(1, 1, true), || stub(9.0));
        assert!(!hit);
        assert_eq!(v.latency_s, 9.0);
        assert_eq!(c.len(), 1);
        let (v0, hit0) = c.get_or_insert_with(key(1, 1, true), || stub(0.0));
        assert!(hit0);
        assert_eq!(v0.latency_s, 9.0);
    }

    #[test]
    fn plan_cost_is_deterministic_and_band_monotone() {
        let w = builtin(WorkloadName::ShufflenetV2);
        let a = plan_cost(&w, DeviceId::S10e, 0, true);
        let b = plan_cost(&w, DeviceId::S10e, 0, true);
        assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits());
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        // hotter bands and missing charger only ever slow the plan down
        let warm = plan_cost(&w, DeviceId::S10e, 1, true);
        let hot = plan_cost(&w, DeviceId::S10e, 2, true);
        let unplugged = plan_cost(&w, DeviceId::S10e, 0, false);
        assert!(a.latency_s < warm.latency_s);
        assert!(warm.latency_s < hot.latency_s);
        assert!(a.latency_s < unplugged.latency_s);
        assert!(a.energy_j < hot.energy_j);
    }

    #[test]
    fn plan_cost_for_arm_matches_both_coordinator_arms() {
        let w = builtin(WorkloadName::ShufflenetV2);
        let mut coord =
            crate::fleet::coordinator::ProfileCoordinator::new(w.clone());
        let swan =
            coord.resolve(DeviceId::S10e, 0, crate::fl::FlArm::Swan);
        let greedy =
            coord.resolve(DeviceId::S10e, 0, crate::fl::FlArm::Baseline);
        let s =
            plan_cost_for_arm(&w, DeviceId::S10e, 0, true, FlArm::Swan);
        let b = plan_cost_for_arm(
            &w,
            DeviceId::S10e,
            0,
            true,
            FlArm::Baseline,
        );
        assert_eq!(s.latency_s.to_bits(), swan.cost.latency_s.to_bits());
        assert_eq!(b.latency_s.to_bits(), greedy.cost.latency_s.to_bits());
        assert_eq!(b.energy_j.to_bits(), greedy.cost.energy_j.to_bits());
        // the envelope applies to both arms identically
        let b_hot = plan_cost_for_arm(
            &w,
            DeviceId::S10e,
            2,
            false,
            FlArm::Baseline,
        );
        assert!(b_hot.latency_s > b.latency_s);
    }

    #[test]
    fn plan_cost_matches_the_fleet_coordinator_head() {
        // same chain-head (band 0, charging) as the fleet-scale §4.2
        // coordinator resolves for the Swan arm
        let w = builtin(WorkloadName::ShufflenetV2);
        let mut coord =
            crate::fleet::coordinator::ProfileCoordinator::new(w.clone());
        let rc =
            coord.resolve(DeviceId::Pixel3, 0, crate::fl::FlArm::Swan);
        let plan = plan_cost(&w, DeviceId::Pixel3, 0, true);
        assert_eq!(plan.latency_s.to_bits(), rc.cost.latency_s.to_bits());
        assert_eq!(plan.energy_j.to_bits(), rc.cost.energy_j.to_bits());
    }
}
