//! Client-side transports: one trait, two wirings.
//!
//! [`ServeClient`] is the batch-oriented face of the coordinator
//! protocol the load generator drives. [`InProcClient`] calls straight
//! into a shared [`Coordinator`] — no sockets, no serialization — and
//! is the parity baseline; [`TcpClient`] speaks the
//! [`wire`](super::wire) format over a `TcpStream`, **pipelining**
//! every batch (write all frames, flush once, read all replies) so a
//! 2k-device round costs a handful of syscalls per lane instead of a
//! round-trip per device. The digest-parity assertion in the serve
//! bench is exactly the claim that these two impls are observationally
//! identical.

use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;

use super::coordinator::Coordinator;
use super::wire::{
    encode_into, read_frame, Ack, CheckIn, LeasePoll, ModelInit,
    ModelPull, Msg, PlanLease, RoundCtl, RoundOp, RoundSummary,
    UpdatePush,
};

/// Reply to a lease poll.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LeaseReply {
    Lease(PlanLease),
    NotSelected,
}

/// A connection-shaped handle onto the coordinator, batch-oriented so
/// transports can pipeline. One client serves many simulated devices.
pub trait ServeClient: Send {
    /// One check-in per request, replies in request order.
    fn check_in_batch(&mut self, reqs: &[CheckIn]) -> crate::Result<Vec<Ack>>;

    /// Ask, for each admitted device, whether it was selected.
    fn lease_poll_batch(
        &mut self,
        devices: &[u64],
    ) -> crate::Result<Vec<LeaseReply>>;

    /// Push the selected devices' updates; every ack must be `Accepted`.
    fn push_update_batch(
        &mut self,
        pushes: Vec<UpdatePush>,
    ) -> crate::Result<Vec<Ack>>;

    /// `RoundCtl::Close` — returns the picked count.
    fn round_close(&mut self, round: u32) -> crate::Result<u32>;

    /// `RoundCtl::Finish` — returns the round summary.
    fn round_finish(&mut self, round: u32) -> crate::Result<RoundSummary>;

    /// Seed the coordinator's global model (training driver only).
    fn model_init(&mut self, params: Vec<f32>) -> crate::Result<()>;

    /// Pull the current global model: (first round it will train, flat
    /// params). Bit-exact over both wirings — f32 raw bits on the wire.
    fn model_pull(&mut self) -> crate::Result<(u32, Vec<f32>)>;
}

/// Direct in-process wiring: `fleet` devices check in through the
/// coordinator without sockets.
pub struct InProcClient {
    pub coord: Arc<Coordinator>,
}

impl InProcClient {
    pub fn new(coord: Arc<Coordinator>) -> InProcClient {
        InProcClient { coord }
    }
}

impl ServeClient for InProcClient {
    fn check_in_batch(&mut self, reqs: &[CheckIn]) -> crate::Result<Vec<Ack>> {
        Ok(reqs.iter().map(|ci| self.coord.check_in(*ci)).collect())
    }

    fn lease_poll_batch(
        &mut self,
        devices: &[u64],
    ) -> crate::Result<Vec<LeaseReply>> {
        let mut out = Vec::with_capacity(devices.len());
        for &d in devices {
            out.push(match self.coord.lease_poll(d)? {
                Some(l) => LeaseReply::Lease(l),
                None => LeaseReply::NotSelected,
            });
        }
        Ok(out)
    }

    fn push_update_batch(
        &mut self,
        pushes: Vec<UpdatePush>,
    ) -> crate::Result<Vec<Ack>> {
        Ok(pushes
            .into_iter()
            .map(|up| self.coord.push_update(up))
            .collect())
    }

    fn round_close(&mut self, round: u32) -> crate::Result<u32> {
        self.coord.close_round(round)
    }

    fn round_finish(&mut self, round: u32) -> crate::Result<RoundSummary> {
        self.coord.finish_round(round)
    }

    fn model_init(&mut self, params: Vec<f32>) -> crate::Result<()> {
        self.coord.set_global(params)
    }

    fn model_pull(&mut self) -> crate::Result<(u32, Vec<f32>)> {
        self.coord.model_pull()
    }
}

/// Loopback/remote TCP wiring over the binary wire format.
pub struct TcpClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    /// Persistent encode buffer: a whole pipeline chunk's frames
    /// serialize here and go out as one `write_all`, so small frames
    /// coalesce and the steady state allocates nothing per frame.
    enc: Vec<u8>,
}

impl TcpClient {
    pub fn connect(addr: SocketAddr) -> crate::Result<TcpClient> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| crate::err!("serve: connect {addr}: {e}"))?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(
            stream.try_clone().map_err(|e| {
                crate::err!("serve: clone stream for {addr}: {e}")
            })?,
        );
        Ok(TcpClient {
            reader,
            writer: BufWriter::new(stream),
            enc: Vec::new(),
        })
    }

    /// Frames pipelined per write/flush/read burst. Bounding the burst
    /// keeps the server's un-read replies within socket buffers even
    /// for 100k-device rounds — a client that wrote its whole round
    /// before reading anything could otherwise deadlock against a
    /// server blocked on its own full send buffer.
    const MAX_PIPELINE: usize = 512;

    /// Pipeline `reqs` and collect one reply per request.
    fn exchange(&mut self, reqs: &[Msg]) -> crate::Result<Vec<Msg>> {
        let mut out = Vec::with_capacity(reqs.len());
        for chunk in reqs.chunks(Self::MAX_PIPELINE) {
            self.enc.clear();
            for m in chunk {
                encode_into(m, &mut self.enc);
            }
            self.writer.write_all(&self.enc)?;
            self.writer.flush()?;
            for _ in 0..chunk.len() {
                match read_frame(&mut self.reader)? {
                    Some(m) => out.push(m),
                    None => crate::bail!(
                        "serve: server closed the connection mid-exchange \
                         ({}/{} replies)",
                        out.len(),
                        reqs.len()
                    ),
                }
            }
        }
        Ok(out)
    }

    fn expect_ack(m: Msg) -> crate::Result<Ack> {
        match m {
            Msg::Ack(a) => Ok(a),
            other => crate::bail!("serve: expected an ack, got {other:?}"),
        }
    }
}

impl ServeClient for TcpClient {
    fn check_in_batch(&mut self, reqs: &[CheckIn]) -> crate::Result<Vec<Ack>> {
        let frames: Vec<Msg> =
            reqs.iter().map(|ci| Msg::CheckIn(*ci)).collect();
        self.exchange(&frames)?
            .into_iter()
            .map(Self::expect_ack)
            .collect()
    }

    fn lease_poll_batch(
        &mut self,
        devices: &[u64],
    ) -> crate::Result<Vec<LeaseReply>> {
        let frames: Vec<Msg> = devices
            .iter()
            .map(|&device| Msg::LeasePoll(LeasePoll { device }))
            .collect();
        self.exchange(&frames)?
            .into_iter()
            .map(|m| match m {
                Msg::PlanLease(l) => Ok(LeaseReply::Lease(l)),
                Msg::Ack(Ack::NotSelected) => Ok(LeaseReply::NotSelected),
                other => crate::bail!(
                    "serve: expected a lease or NotSelected, got {other:?}"
                ),
            })
            .collect()
    }

    fn push_update_batch(
        &mut self,
        pushes: Vec<UpdatePush>,
    ) -> crate::Result<Vec<Ack>> {
        let frames: Vec<Msg> =
            pushes.into_iter().map(Msg::UpdatePush).collect();
        self.exchange(&frames)?
            .into_iter()
            .map(Self::expect_ack)
            .collect()
    }

    fn round_close(&mut self, round: u32) -> crate::Result<u32> {
        let reply = self.exchange(&[Msg::RoundCtl(RoundCtl {
            round,
            op: RoundOp::Close,
        })])?;
        let first = reply.into_iter().next().ok_or_else(|| {
            crate::err!("serve: close_round({round}) got an empty reply")
        })?;
        match Self::expect_ack(first)? {
            Ack::Closed { picked } => Ok(picked),
            other => {
                crate::bail!("serve: close_round({round}) got {other:?}")
            }
        }
    }

    fn round_finish(&mut self, round: u32) -> crate::Result<RoundSummary> {
        let reply = self.exchange(&[Msg::RoundCtl(RoundCtl {
            round,
            op: RoundOp::Finish,
        })])?;
        let first = reply.into_iter().next().ok_or_else(|| {
            crate::err!("serve: finish_round({round}) got an empty reply")
        })?;
        match first {
            Msg::RoundSummary(s) => Ok(s),
            other => {
                crate::bail!("serve: finish_round({round}) got {other:?}")
            }
        }
    }

    fn model_init(&mut self, params: Vec<f32>) -> crate::Result<()> {
        let reply =
            self.exchange(&[Msg::ModelInit(ModelInit { params })])?;
        let first = reply.into_iter().next().ok_or_else(|| {
            crate::err!("serve: model_init got an empty reply")
        })?;
        match Self::expect_ack(first)? {
            Ack::Accepted => Ok(()),
            other => crate::bail!("serve: model_init got {other:?}"),
        }
    }

    fn model_pull(&mut self) -> crate::Result<(u32, Vec<f32>)> {
        let reply =
            self.exchange(&[Msg::ModelPull(ModelPull { device: 0 })])?;
        let first = reply.into_iter().next().ok_or_else(|| {
            crate::err!("serve: model_pull got an empty reply")
        })?;
        match first {
            Msg::ModelState(s) => Ok((s.round, s.params)),
            other => crate::bail!("serve: model_pull got {other:?}"),
        }
    }
}
