//! The coordinator core: transport-agnostic round logic.
//!
//! One [`Coordinator`] owns the whole server-side state machine; the
//! TCP server ([`super::server`]) and the in-process client
//! ([`super::client::InProcClient`]) are thin shims over the same five
//! entry points, which is what makes the loopback-TCP and in-process
//! digests comparable at all.
//!
//! A round is two phases, paced by `RoundCtl` from the deployment's
//! round driver (the load generator, in this repo):
//!
//! ```text
//! CheckIn phase     devices report (model, thermal band, charger
//!                   state, epoch size); admission control defers the
//!                   overflow; admitted check-ins coalesce into
//!                   fixed-size batches that warm the profile cache
//!                   under one lock acquisition per batch
//! -- RoundCtl::Close: sort admitted by device id, select K via the
//!    fleet kernel's (seed, round)-keyed RNG, resolve leases from the
//!    (now warm) LRU cache in picked order --
//! Update phase      selected devices poll their PlanLease, run the
//!                   epoch, push their weighted update into its dense
//!                   seq slot
//! -- RoundCtl::Finish: FedAvg (fl::server) over the seq-ordered
//!    updates, fold the parity digest, emit the RoundSummary --
//! ```
//!
//! **Determinism.** Everything folded into the digest is independent of
//! arrival order: selection sees the admitted set sorted by device id,
//! leases resolve in picked order from a cache whose values are pure
//! functions of the key, updates aggregate in seq (= picked) order, and
//! the round RNG is keyed on (seed, round) only. So any interleaving of
//! lanes, sockets, or batches that delivers the same check-ins produces
//! the same summary — the property the serve bench asserts between the
//! in-process and loopback-TCP paths.
//!
//! **Backpressure.** Admission is a bounded per-round queue: past
//! `admit_capacity`, check-ins get `Ack::Deferred` with a Retry-After
//! delay instead of unbounded queue growth — overload degrades into a
//! deterministic deferral rate (reported in `BENCH_serve.json`), not
//! into latency collapse.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Mutex;

use crate::fl::server::fedavg;
use crate::fl::selection::select_uniform;
// the lint determinism rule bans raw wall-clock constructors in
// digest-affecting modules; timing here is telemetry, never round state
use crate::obs::wall_timer;
use crate::fleet::engine::{round_rng, EMPTY_ROUND_WAIT_S};
use crate::fleet::scenario::ScenarioSpec;
use crate::workload::{load_or_builtin, Workload, WorkloadName};

use super::cache::{plan_cost_for_arm, PlanKey, ProfileCache};
use super::wire::{
    model_from_code, Ack, CheckIn, PlanLease, RoundSummary, UpdatePush,
};

/// Retry-After delay handed to deferred devices, seconds. Deterministic
/// (no jitter server-side): dithering retry storms is the client
/// library's job, deciding *when* capacity exists again is the
/// server's.
pub const RETRY_AFTER_S: f32 = 30.0;

/// Coordinator tuning. Derive one from a fleet scenario with
/// [`ServeConfig::for_scenario`] so the serve path and the fleet kernel
/// agree on seed, round structure and workload.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub seed: u64,
    /// Participants selected per round (K).
    pub clients_per_round: usize,
    /// Server-side per-round overhead added by the round pacer, seconds.
    pub server_overhead_s: f64,
    /// Check-ins coalesced per batch before touching round/cache locks.
    pub batch_size: usize,
    /// Per-round admission bound; 0 = unbounded (no deferrals).
    pub admit_capacity: usize,
    /// LRU profile-cache capacity (contexts, not devices).
    pub cache_capacity: usize,
    /// Parameter count every `UpdatePush` must carry.
    pub update_dim: usize,
    pub workload: WorkloadName,
    /// Policy arm every lease resolves under (§4.2 chain head vs the
    /// greedy baseline). `Swan` reproduces the historical `plan_cost`
    /// values bit-for-bit.
    pub arm: crate::fl::FlArm,
}

impl ServeConfig {
    pub fn for_scenario(spec: &ScenarioSpec) -> ServeConfig {
        ServeConfig {
            seed: spec.seed,
            clients_per_round: spec.clients_per_round,
            server_overhead_s: spec.server_overhead_s,
            batch_size: 256,
            admit_capacity: 0,
            cache_capacity: 64,
            update_dim: 32,
            workload: spec.workload,
            arm: crate::fl::FlArm::Swan,
        }
    }
}

/// FNV-1a fold over the round stream — the parity digest (the repo's
/// shared [`crate::util::fnv::Fnv1a`] primitive, the same fold the
/// fleet kernel digests with). The oracle in `serve::loadgen` folds
/// the identical field sequence from a direct simulation +
/// `fl::server::fedavg`, so a single flipped bit anywhere in the serve
/// pipeline (wire codec, batching, cache, selection, aggregation
/// order) diverges the digest.
pub use crate::util::fnv::Fnv1a as DigestFold;

/// Hex rendering of a serve parity digest (`serve-<16 hex digits>`).
pub fn digest_hex(h: u64) -> String {
    format!("serve-{h:016x}")
}

/// Check-in intake shared by every connection: the coalescing buffer
/// plus the per-round admission counters. Held for a push per check-in;
/// the heavier round/cache locks are only taken once per flushed batch.
struct Pending {
    batch: Vec<CheckIn>,
    checkins: u64,
    admitted: usize,
    deferred: u64,
    /// Intake service time per check-in (the `checkin` pipeline edge),
    /// kept lock-local and merged into the round registry at close —
    /// same discipline as the shard-local fleet metrics.
    intake_hist: crate::obs::Histogram,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    CheckIn,
    Update,
}

struct RoundState {
    round: u32,
    phase: Phase,
    admitted: Vec<CheckIn>,
    /// Check-ins that arrived after this round closed (free-running
    /// wire clients racing the round pacer): admitted for the *next*
    /// round, consistent with their pending-counter accounting.
    next_admitted: Vec<CheckIn>,
    /// device → lease, for the picked set only.
    leases: HashMap<u64, PlanLease>,
    picked: Vec<u64>,
    /// Update slots, indexed by lease seq.
    updates: Vec<Option<(Vec<f32>, f64)>>,
    received: usize,
    /// Counters frozen at close time (reported in the summary).
    round_checkins: u64,
    round_deferred: u64,
    // -- run-cumulative state --
    digest: DigestFold,
    /// Cumulative counters + control-plane latency histograms
    /// (telemetry; wall-clock only, excluded from the parity digest).
    metrics: crate::obs::MetricsRegistry,
    total_time_s: f64,
    total_energy_j: f64,
    last_aggregate: Vec<f32>,
    /// The global model the serve-routed training loop trains: seeded
    /// via [`Coordinator::set_global`], replaced by each round's FedAvg
    /// aggregate, served back through [`Coordinator::model_pull`].
    /// Never folded into the digest directly — the aggregate bits
    /// already are.
    global: Vec<f32>,
}

/// Run-cumulative counters (mirrors what the load generator folds from
/// summaries — exposed for the bench record and tests).
#[derive(Clone, Copy, Debug, Default)]
pub struct Totals {
    pub rounds_run: usize,
    pub checkins: u64,
    pub admitted: u64,
    pub deferred: u64,
    pub participations: u64,
    /// Virtual seconds (straggler-paced rounds + overhead / idle waits).
    pub total_time_s: f64,
    pub total_energy_j: f64,
}

/// Cache + admission counters for the bench record.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeStats {
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    pub totals: Totals,
}

/// The FL coordinator control plane (see the module docs).
pub struct Coordinator {
    cfg: ServeConfig,
    workload: Workload,
    cache: Mutex<ProfileCache>,
    pending: Mutex<Pending>,
    round: Mutex<RoundState>,
    obs: crate::obs::Obs,
    /// Timestamp source for trace edges, anchored at construction.
    clock: crate::obs::TraceClock,
    /// The round an arriving check-in will land in, maintained at the
    /// close/finish barriers. Purely observational (trace-edge round
    /// identity without taking the round lock on the intake path);
    /// Relaxed is enough because nothing simulation-visible reads it.
    intake_round: AtomicU32,
}

impl Coordinator {
    pub fn new(cfg: ServeConfig) -> crate::Result<Coordinator> {
        Self::with_obs(cfg, crate::obs::Obs::off())
    }

    /// Like [`new`](Coordinator::new), with a telemetry sink attached:
    /// admission batches, deferrals, late carryovers, cache traffic and
    /// round lifecycle stream as NDJSON events. Telemetry observes the
    /// existing round barriers and never reorders them, so the parity
    /// digest is bit-identical with the sink on or off.
    pub fn with_obs(
        cfg: ServeConfig,
        obs: crate::obs::Obs,
    ) -> crate::Result<Coordinator> {
        crate::ensure!(
            cfg.clients_per_round > 0,
            "serve: clients_per_round must be > 0"
        );
        crate::ensure!(cfg.batch_size > 0, "serve: batch_size must be > 0");
        crate::ensure!(cfg.update_dim > 0, "serve: update_dim must be > 0");
        let workload = load_or_builtin(cfg.workload, "artifacts");
        Ok(Coordinator {
            cache: Mutex::new(ProfileCache::new(cfg.cache_capacity)),
            pending: Mutex::new(Pending {
                batch: Vec::with_capacity(cfg.batch_size),
                checkins: 0,
                admitted: 0,
                deferred: 0,
                intake_hist: crate::obs::Histogram::default(),
            }),
            round: Mutex::new(RoundState {
                round: 0,
                phase: Phase::CheckIn,
                admitted: Vec::new(),
                next_admitted: Vec::new(),
                leases: HashMap::new(),
                picked: Vec::new(),
                updates: Vec::new(),
                received: 0,
                round_checkins: 0,
                round_deferred: 0,
                digest: DigestFold::default(),
                metrics: crate::obs::MetricsRegistry::default(),
                total_time_s: 0.0,
                total_energy_j: 0.0,
                last_aggregate: Vec::new(),
                global: Vec::new(),
            }),
            cfg,
            workload,
            obs,
            clock: crate::obs::TraceClock::start(),
            intake_round: AtomicU32::new(0),
        })
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// The attached telemetry sink (off by default).
    pub fn obs(&self) -> &crate::obs::Obs {
        &self.obs
    }

    /// The round an arriving check-in will land in (observational —
    /// see the field docs). Used by trace edges emitted outside the
    /// round lock, e.g. the TCP server's accept-overflow deferral.
    pub fn intake_round(&self) -> u32 {
        self.intake_round.load(Ordering::Relaxed)
    }

    /// Seconds on this coordinator's trace clock.
    pub fn trace_now_s(&self) -> f64 {
        self.clock.now_s()
    }

    /// Lock for round-mutating paths. A poisoned lock means another
    /// server thread panicked mid-round — the state may be torn, so
    /// surface it as a protocol error the caller propagates (the wire
    /// layer turns it into a `Rejected` ack) instead of cascading the
    /// panic through every IO worker.
    fn lock<'a, T>(
        m: &'a Mutex<T>,
    ) -> crate::Result<std::sync::MutexGuard<'a, T>> {
        m.lock().map_err(|_| {
            crate::err!(
                "serve: coordinator state poisoned by a peer thread panic"
            )
        })
    }

    /// Lock for read-only report accessors (digest/stats/metrics).
    /// These run after the harness has already observed any failure
    /// through [`Self::lock`]; a poisoned snapshot is still worth
    /// reporting, so recover the guard rather than failing the report.
    fn lock_report<'a, T>(m: &'a Mutex<T>) -> std::sync::MutexGuard<'a, T> {
        m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Move a coalesced batch into the round state and warm the profile
    /// cache — the amortization point: one round-lock and one
    /// cache-lock acquisition per `batch_size` check-ins, and at most
    /// one exploration per distinct context regardless of batch
    /// composition.
    fn flush_batch(&self, batch: Vec<CheckIn>) -> crate::Result<()> {
        if batch.is_empty() {
            return Ok(());
        }
        let t0 = wall_timer();
        let size = batch.len();
        let mut r = Self::lock(&self.round)?;
        // a check-in landing after its round closed (free-running
        // clients racing the pacer) was counted toward the *next*
        // round's pending counters, so it belongs to the next round's
        // admitted set — not to the closed round it can no longer join
        let lands_in = if r.phase == Phase::CheckIn {
            r.admitted.extend_from_slice(&batch);
            r.round
        } else {
            r.next_admitted.extend_from_slice(&batch);
            r.round + 1
        };
        drop(r);
        let mut cache = Self::lock(&self.cache)?;
        for ci in &batch {
            if let Some(model) = model_from_code(ci.model) {
                let key = PlanKey {
                    model: ci.model,
                    band: ci.band,
                    charging: ci.charging,
                };
                cache.get_or_insert_with(key, || {
                    plan_cost_for_arm(
                        &self.workload,
                        model,
                        ci.band,
                        ci.charging,
                        self.cfg.arm,
                    )
                });
            }
        }
        drop(cache);
        let mut r = Self::lock(&self.round)?;
        let h = r
            .metrics
            .hist("serve.flush_s", crate::obs::LATENCY_BUCKETS_S);
        r.metrics.observe(h, t0.elapsed().as_secs_f64());
        drop(r);
        if self.obs.enabled() {
            self.obs.emit(&crate::obs::CheckinBatch {
                round: lands_in,
                size,
            });
        }
        Ok(())
    }

    /// Check-in intake (any thread). Rejects unknown models, defers
    /// past the admission bound, otherwise admits into the current
    /// coalescing batch.
    pub fn check_in(&self, ci: CheckIn) -> Ack {
        if model_from_code(ci.model).is_none()
            || ci.band >= super::cache::N_THERMAL_BANDS
            || ci.steps == 0
        {
            return Ack::Rejected;
        }
        let t0 = wall_timer();
        let (ack, full_batch) = {
            // an Ack-returning entry point: poison degrades to the
            // protocol's refusal instead of an unwind
            let Ok(mut p) = Self::lock(&self.pending) else {
                return Ack::Rejected;
            };
            p.checkins += 1;
            let out = if self.cfg.admit_capacity > 0
                && p.admitted >= self.cfg.admit_capacity
            {
                p.deferred += 1;
                (
                    Ack::Deferred {
                        retry_after_s: RETRY_AFTER_S,
                    },
                    Vec::new(),
                )
            } else {
                p.admitted += 1;
                p.batch.push(ci);
                let full = if p.batch.len() >= self.cfg.batch_size {
                    std::mem::replace(
                        &mut p.batch,
                        Vec::with_capacity(self.cfg.batch_size),
                    )
                } else {
                    Vec::new()
                };
                (Ack::Admitted, full)
            };
            p.intake_hist.observe(t0.elapsed().as_secs_f64());
            out
        };
        if self.obs.trace_on() {
            let round = self.intake_round();
            let t_s = self.clock.now_s();
            self.obs.emit(&crate::obs::TraceEdge::new(
                round,
                ci.device,
                crate::obs::trace::EDGE_CHECKIN,
                t_s,
            ));
            match ack {
                Ack::Admitted => self.obs.emit(
                    &crate::obs::TraceEdge::new(
                        round,
                        ci.device,
                        crate::obs::trace::EDGE_ADMITTED,
                        t_s,
                    ),
                ),
                Ack::Deferred { retry_after_s } => self.obs.emit(
                    &crate::obs::TraceEdge::new(
                        round,
                        ci.device,
                        crate::obs::trace::EDGE_DEFERRED,
                        t_s,
                    )
                    .with("retry_after_s", retry_after_s as f64),
                ),
                _ => {}
            }
        }
        // the admitted check-ins in a batch that fails to flush never
        // reach round state; their acks were already computed, so the
        // honest degraded answer for THIS caller is a rejection
        if self.flush_batch(full_batch).is_err() {
            return Ack::Rejected;
        }
        ack
    }

    /// End the check-in phase of `round`: flush the partial batch, run
    /// selection, resolve the picked leases. Returns the picked count.
    pub fn close_round(&self, round: u32) -> crate::Result<u32> {
        let t0 = wall_timer();
        let (batch, checkins, deferred, intake_hist) = {
            let mut p = Self::lock(&self.pending)?;
            let b = std::mem::take(&mut p.batch);
            let c = std::mem::take(&mut p.checkins);
            let d = std::mem::take(&mut p.deferred);
            let ih = std::mem::take(&mut p.intake_hist);
            p.admitted = 0;
            (b, c, d, ih)
        };
        self.flush_batch(batch)?;

        let mut r = Self::lock(&self.round)?;
        crate::ensure!(
            r.phase == Phase::CheckIn && r.round == round,
            "serve: close_round({round}) in phase {:?} of round {}",
            r.phase,
            r.round
        );
        r.round_checkins = checkins;
        r.round_deferred = deferred;

        // arrival order (lanes, sockets, batches) must not leak into
        // selection OR lease context: sort by the full payload so a
        // device that double-checked-in with different payloads (e.g.
        // a retry racing a thermal change) keeps an arrival-independent
        // representative, then drop the duplicates
        r.admitted.sort_by_key(|ci| {
            (ci.device, ci.model, ci.band, ci.charging, ci.steps)
        });
        r.admitted.dedup_by_key(|ci| ci.device);

        let ids: Vec<usize> =
            r.admitted.iter().map(|ci| ci.device as usize).collect();
        let mut rng = round_rng(self.cfg.seed, round as usize);
        let picked_ids =
            select_uniform(&ids, self.cfg.clients_per_round, &mut rng);

        let mut cache = Self::lock(&self.cache)?;
        let mut leases = HashMap::with_capacity(picked_ids.len());
        for (seq, &gid) in picked_ids.iter().enumerate() {
            let idx = r
                .admitted
                .binary_search_by_key(&(gid as u64), |ci| ci.device)
                .map_err(|_| {
                    crate::err!("serve: picked device {gid} not admitted")
                })?;
            let ci = r.admitted[idx];
            let model = model_from_code(ci.model).ok_or_else(|| {
                crate::err!(
                    "serve: round {round} admitted unknown model code {}",
                    ci.model
                )
            })?;
            let key = PlanKey {
                model: ci.model,
                band: ci.band,
                charging: ci.charging,
            };
            let (cost, _) = cache.get_or_insert_with(key, || {
                plan_cost_for_arm(
                    &self.workload,
                    model,
                    ci.band,
                    ci.charging,
                    self.cfg.arm,
                )
            });
            leases.insert(
                ci.device,
                PlanLease {
                    device: ci.device,
                    round,
                    seq: seq as u32,
                    steps: ci.steps,
                    latency_s: cost.latency_s * ci.steps as f64,
                    energy_j: cost.energy_j * ci.steps as f64,
                },
            );
        }
        drop(cache);

        let n = picked_ids.len();
        r.picked = picked_ids.into_iter().map(|g| g as u64).collect();
        r.leases = leases;
        r.updates = vec![None; n];
        r.received = 0;
        r.phase = Phase::Update;
        // check-ins arriving from here on land in the next round
        self.intake_round.store(round + 1, Ordering::Relaxed);
        let h = r
            .metrics
            .hist("serve.close_s", crate::obs::LATENCY_BUCKETS_S);
        r.metrics.observe(h, t0.elapsed().as_secs_f64());
        let h = r
            .metrics
            .hist("serve.edge.checkin_s", crate::obs::LATENCY_BUCKETS_S);
        r.metrics.merge_hist(h, &intake_hist);
        // the selection verdict per admitted device, for trace edges
        // emitted after the lock drops
        let verdicts: Vec<(u64, Option<u32>)> = if self.obs.trace_on() {
            r.admitted
                .iter()
                .map(|ci| {
                    (ci.device, r.leases.get(&ci.device).map(|l| l.seq))
                })
                .collect()
        } else {
            Vec::new()
        };
        drop(r);
        if self.obs.trace_on() {
            let t_s = self.clock.now_s();
            for (device, seq) in verdicts {
                match seq {
                    Some(seq) => self.obs.emit(
                        &crate::obs::TraceEdge::new(
                            round,
                            device,
                            crate::obs::trace::EDGE_SELECTED,
                            t_s,
                        )
                        .with("seq", seq as f64),
                    ),
                    None => self.obs.emit(&crate::obs::TraceEdge::new(
                        round,
                        device,
                        crate::obs::trace::EDGE_REJECTED,
                        t_s,
                    )),
                }
            }
        }
        if deferred > 0 && self.obs.enabled() {
            self.obs.emit(&crate::obs::Deferral {
                round,
                deferred,
                retry_after_s: RETRY_AFTER_S as f64,
                batch_size: self.cfg.batch_size,
            });
        }
        Ok(n as u32)
    }

    /// An admitted device asks whether it was selected this round.
    pub fn lease_poll(&self, device: u64) -> crate::Result<Option<PlanLease>> {
        let t0 = wall_timer();
        let mut r = Self::lock(&self.round)?;
        crate::ensure!(
            r.phase == Phase::Update,
            "serve: lease_poll before the round closed"
        );
        let lease = r.leases.get(&device).copied();
        let h = r
            .metrics
            .hist("serve.edge.lease_s", crate::obs::LATENCY_BUCKETS_S);
        r.metrics.observe(h, t0.elapsed().as_secs_f64());
        let round = r.round;
        drop(r);
        if self.obs.trace_on() {
            if let Some(l) = &lease {
                self.obs.emit(
                    &crate::obs::TraceEdge::new(
                        round,
                        device,
                        crate::obs::trace::EDGE_LEASE_SENT,
                        self.clock.now_s(),
                    )
                    .with("seq", l.seq as f64),
                );
            }
        }
        Ok(lease)
    }

    /// Accept a leased device's update into its dense seq slot.
    pub fn push_update(&self, up: UpdatePush) -> Ack {
        let t0 = wall_timer();
        let device = up.device;
        let round = up.round;
        let Ok(mut r) = Self::lock(&self.round) else {
            return Ack::Rejected;
        };
        if r.phase != Phase::Update {
            return Ack::Rejected;
        }
        let ok = match r.leases.get(&up.device) {
            Some(l) => {
                l.round == up.round
                    && l.seq == up.seq
                    && up.params.len() == self.cfg.update_dim
                    && up.weight.is_finite()
                    && up.weight > 0.0
            }
            None => false,
        };
        let slot = up.seq as usize;
        if !ok || slot >= r.updates.len() || r.updates[slot].is_some() {
            return Ack::Rejected;
        }
        r.updates[slot] = Some((up.params, up.weight));
        r.received += 1;
        let h = r
            .metrics
            .hist("serve.edge.update_s", crate::obs::LATENCY_BUCKETS_S);
        r.metrics.observe(h, t0.elapsed().as_secs_f64());
        drop(r);
        if self.obs.trace_on() {
            self.obs.emit(&crate::obs::TraceEdge::new(
                round,
                device,
                crate::obs::trace::EDGE_UPDATE_RECEIVED,
                self.clock.now_s(),
            ));
        }
        Ack::Accepted
    }

    /// Aggregate the finished round (FedAvg via `fl::server`), fold the
    /// parity digest, advance to the next round's check-in phase.
    pub fn finish_round(&self, round: u32) -> crate::Result<RoundSummary> {
        let t0 = wall_timer();
        let mut r = Self::lock(&self.round)?;
        crate::ensure!(
            r.phase == Phase::Update && r.round == round,
            "serve: finish_round({round}) in phase {:?} of round {}",
            r.phase,
            r.round
        );
        crate::ensure!(
            r.received == r.picked.len(),
            "serve: round {round} finished with {}/{} updates",
            r.received,
            r.picked.len()
        );

        // straggler-paced round time + fleet energy, in picked (= seq)
        // order so the f64 energy sum is reduction-order deterministic
        let mut round_time_s = 0.0f64;
        let mut round_energy_j = 0.0f64;
        for gid in &r.picked {
            let l = &r.leases[gid];
            round_time_s = round_time_s.max(l.latency_s);
            round_energy_j += l.energy_j;
        }

        // parity digest: round, admitted count, picked ids, round
        // time/energy bits, then the aggregate parameter bits — the
        // exact sequence the oracle folds
        let admitted = r.admitted.len() as u64;
        let mut digest = r.digest;
        digest.push(round as u64);
        digest.push(admitted);
        for gid in &r.picked {
            digest.push(*gid);
        }
        digest.push_f64(round_time_s);
        digest.push_f64(round_energy_j);

        let participants = r.picked.len() as u32;
        if participants > 0 {
            // the `received == picked` ensure above makes an empty slot
            // impossible, but a counting bug must surface as an error,
            // not an unwind inside the round lock
            let mut updates: Vec<(Vec<Vec<f32>>, f64)> =
                Vec::with_capacity(r.updates.len());
            for (seq, slot) in r.updates.drain(..).enumerate() {
                let (params, w) = slot.ok_or_else(|| {
                    crate::err!(
                        "serve: round {round} lost the update for seq {seq}"
                    )
                })?;
                updates.push((vec![params], w));
            }
            let agg = fedavg(&updates)?;
            for v in &agg[0] {
                digest.push_f32(*v);
            }
            r.last_aggregate = agg.into_iter().next().unwrap_or_default();
            // the aggregate IS the next global model — this single
            // assignment is what closes the numerics loop
            r.global = r.last_aggregate.clone();
        } else {
            // an empty round leaves the global model untouched
            r.updates.clear();
            r.last_aggregate.clear();
        }
        r.digest = digest;

        let round_checkins = r.round_checkins;
        let round_deferred = r.round_deferred;
        r.metrics.inc("serve.rounds", 1);
        r.metrics.inc("serve.checkins", round_checkins);
        r.metrics.inc("serve.admitted", admitted);
        r.metrics.inc("serve.deferred", round_deferred);
        r.metrics.inc("serve.participations", participants as u64);
        r.total_time_s += if admitted == 0 {
            EMPTY_ROUND_WAIT_S
        } else {
            round_time_s + self.cfg.server_overhead_s
        };
        r.total_energy_j += round_energy_j;

        let summary = RoundSummary {
            round,
            checkins: r.round_checkins,
            admitted,
            deferred: r.round_deferred,
            participants,
            round_time_s,
            round_energy_j,
            digest: r.digest.h,
        };

        let carried = r.next_admitted.len();
        // trace-edge payloads, collected before the round state is
        // recycled and emitted after the lock drops
        let (agg_devices, carried_devices) = if self.obs.trace_on() {
            (
                r.picked.clone(),
                r.next_admitted
                    .iter()
                    .map(|ci| ci.device)
                    .collect::<Vec<u64>>(),
            )
        } else {
            (Vec::new(), Vec::new())
        };
        r.round += 1;
        r.phase = Phase::CheckIn;
        // late check-ins banked during the update phase open the next
        // round's admitted set
        r.admitted = std::mem::take(&mut r.next_admitted);
        r.leases.clear();
        r.picked.clear();
        r.received = 0;
        r.round_checkins = 0;
        r.round_deferred = 0;
        let h = r
            .metrics
            .hist("serve.finish_s", crate::obs::LATENCY_BUCKETS_S);
        r.metrics.observe(h, t0.elapsed().as_secs_f64());
        if self.obs.enabled() {
            // lock order: round before cache, matching stats()
            let (hits, misses, evictions) = {
                let cache = Self::lock(&self.cache)?;
                (cache.hits, cache.misses, cache.evictions)
            };
            drop(r);
            if self.obs.trace_on() {
                let t_s = self.clock.now_s();
                for device in agg_devices {
                    self.obs.emit(&crate::obs::TraceEdge::new(
                        round,
                        device,
                        crate::obs::trace::EDGE_AGGREGATED,
                        t_s,
                    ));
                }
                // a carried check-in's lifecycle continues in the round
                // it was banked into
                for device in carried_devices {
                    self.obs.emit(&crate::obs::TraceEdge::new(
                        round + 1,
                        device,
                        crate::obs::trace::EDGE_LATE_CARRYOVER,
                        t_s,
                    ));
                }
            }
            self.obs.emit(&crate::obs::ServeRoundEnd {
                round,
                checkins: round_checkins,
                admitted: admitted as usize,
                deferred: round_deferred,
                participants: participants as usize,
                round_time_s,
                round_energy_j,
            });
            if carried > 0 {
                self.obs
                    .emit(&crate::obs::LateCarryover { round, carried });
            }
            self.obs.emit(&crate::obs::CacheHitMiss {
                round,
                hits,
                misses,
                evictions,
            });
        }
        Ok(summary)
    }

    /// Seed (or replace) the global model. The training driver owns
    /// initialization, so every wiring — oracle, in-process, TCP —
    /// starts each run from one bit-identical model. Digest-neutral:
    /// only aggregates fold parameter bits.
    pub fn set_global(&self, params: Vec<f32>) -> crate::Result<()> {
        crate::ensure!(
            params.len() == self.cfg.update_dim,
            "serve: model init carries {} params, expected {}",
            params.len(),
            self.cfg.update_dim
        );
        let mut r = Self::lock(&self.round)?;
        r.global = params;
        Ok(())
    }

    /// The current global model and the round counter it is valid for
    /// (i.e. the first round that will train from it). Errors until
    /// [`set_global`](Coordinator::set_global) has seeded a model.
    pub fn model_pull(&self) -> crate::Result<(u32, Vec<f32>)> {
        let r = Self::lock(&self.round)?;
        crate::ensure!(
            !r.global.is_empty(),
            "serve: model pull before a global model was seeded"
        );
        Ok((r.round, r.global.clone()))
    }

    /// Cumulative parity digest (hex form used in reports/benches).
    pub fn digest(&self) -> String {
        digest_hex(Self::lock_report(&self.round).digest.h)
    }

    /// The last finished round's FedAvg aggregate (tests compare this
    /// against a direct `fl::server::fedavg` call bit-for-bit).
    pub fn last_aggregate(&self) -> Vec<f32> {
        Self::lock_report(&self.round).last_aggregate.clone()
    }

    pub fn stats(&self) -> ServeStats {
        // lock order: round before cache, matching close_round/flush
        let r = Self::lock_report(&self.round);
        let cache = Self::lock_report(&self.cache);
        ServeStats {
            cache_hits: cache.hits,
            cache_misses: cache.misses,
            cache_evictions: cache.evictions,
            totals: Totals {
                rounds_run: r.metrics.counter_value("serve.rounds")
                    as usize,
                checkins: r.metrics.counter_value("serve.checkins"),
                admitted: r.metrics.counter_value("serve.admitted"),
                deferred: r.metrics.counter_value("serve.deferred"),
                participations: r
                    .metrics
                    .counter_value("serve.participations"),
                total_time_s: r.total_time_s,
                total_energy_j: r.total_energy_j,
            },
        }
    }

    /// Snapshot of the cumulative counter/histogram registry (the
    /// telemetry superset behind [`stats`](Coordinator::stats):
    /// `serve.*` counters plus `serve.flush_s` / `serve.close_s` /
    /// `serve.finish_s` control-plane latency histograms and the
    /// per-pipeline-edge `serve.edge.checkin_s` / `serve.edge.lease_s`
    /// / `serve.edge.update_s` service-time histograms).
    pub fn metrics(&self) -> crate::obs::MetricsRegistry {
        Self::lock_report(&self.round).metrics.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::device::DeviceId;
    use crate::serve::wire::model_code;

    fn cfg(k: usize, cap: usize) -> ServeConfig {
        ServeConfig {
            seed: 7,
            clients_per_round: k,
            server_overhead_s: 0.5,
            batch_size: 3,
            admit_capacity: cap,
            cache_capacity: 16,
            update_dim: 4,
            workload: WorkloadName::ShufflenetV2,
            arm: crate::fl::FlArm::Swan,
        }
    }

    fn ci(device: u64, model: DeviceId) -> CheckIn {
        CheckIn {
            device,
            model: model_code(model),
            band: 0,
            charging: true,
            steps: 5,
        }
    }

    fn drive_round(
        c: &Coordinator,
        round: u32,
        devices: &[(u64, DeviceId)],
    ) -> (RoundSummary, Vec<(Vec<f32>, f64)>) {
        for &(d, m) in devices {
            assert_eq!(c.check_in(ci(d, m)), Ack::Admitted);
        }
        let picked = c.close_round(round).unwrap();
        let mut pushed = Vec::new();
        for &(d, _) in devices {
            if let Some(l) = c.lease_poll(d).unwrap() {
                let params: Vec<f32> =
                    (0..4).map(|i| (d as f32) + i as f32).collect();
                let w = l.steps as f64;
                assert_eq!(
                    c.push_update(UpdatePush {
                        device: d,
                        round,
                        seq: l.seq,
                        weight: w,
                        params: params.clone(),
                    }),
                    Ack::Accepted
                );
                pushed.push((l.seq, params, w));
            }
        }
        assert_eq!(pushed.len(), picked as usize);
        pushed.sort_by_key(|(seq, _, _)| *seq);
        let summary = c.finish_round(round).unwrap();
        (
            summary,
            pushed.into_iter().map(|(_, p, w)| (p, w)).collect(),
        )
    }

    #[test]
    fn aggregate_is_bit_identical_to_fl_server_fedavg() {
        let c = Coordinator::new(cfg(3, 0)).unwrap();
        let devices: Vec<(u64, DeviceId)> = vec![
            (0, DeviceId::Pixel3),
            (1, DeviceId::S10e),
            (2, DeviceId::OnePlus8),
            (3, DeviceId::TabS6),
            (4, DeviceId::Mi10),
        ];
        let (summary, updates) = drive_round(&c, 0, &devices);
        assert_eq!(summary.participants, 3);
        assert_eq!(summary.admitted, 5);
        let oracle = fedavg(
            &updates
                .iter()
                .map(|(p, w)| (vec![p.clone()], *w))
                .collect::<Vec<_>>(),
        )
        .unwrap();
        let got = c.last_aggregate();
        assert_eq!(got.len(), oracle[0].len());
        for (a, b) in got.iter().zip(&oracle[0]) {
            assert_eq!(a.to_bits(), b.to_bits(), "fedavg parity");
        }
    }

    #[test]
    fn global_model_follows_the_aggregate() {
        let c = Coordinator::new(cfg(3, 0)).unwrap();
        // pull before seeding is a protocol error
        assert!(c.model_pull().is_err());
        // wrong-dim seed rejected
        assert!(c.set_global(vec![1.0; 3]).is_err());
        c.set_global(vec![0.25f32; 4]).unwrap();
        let (round, g) = c.model_pull().unwrap();
        assert_eq!(round, 0);
        assert_eq!(g, vec![0.25f32; 4]);
        let devices: Vec<(u64, DeviceId)> =
            vec![(0, DeviceId::Pixel3), (1, DeviceId::S10e)];
        let _ = drive_round(&c, 0, &devices);
        let (round, g) = c.model_pull().unwrap();
        assert_eq!(round, 1, "pull reports the round trained next");
        let agg = c.last_aggregate();
        assert_eq!(g.len(), agg.len());
        for (a, b) in g.iter().zip(&agg) {
            assert_eq!(a.to_bits(), b.to_bits(), "global == aggregate");
        }
    }

    #[test]
    fn admission_bound_defers_deterministically() {
        let c = Coordinator::new(cfg(2, 2)).unwrap();
        let mut admitted = 0;
        let mut deferred = 0;
        for d in 0..5u64 {
            match c.check_in(ci(d, DeviceId::Pixel3)) {
                Ack::Admitted => admitted += 1,
                Ack::Deferred { retry_after_s } => {
                    assert!(retry_after_s > 0.0);
                    deferred += 1;
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!((admitted, deferred), (2, 3));
        let picked = c.close_round(0).unwrap();
        assert_eq!(picked, 2);
        for d in 0..5u64 {
            if let Some(l) = c.lease_poll(d).unwrap() {
                c.push_update(UpdatePush {
                    device: d,
                    round: 0,
                    seq: l.seq,
                    weight: 1.0,
                    params: vec![0.0; 4],
                });
            }
        }
        let s = c.finish_round(0).unwrap();
        assert_eq!(s.checkins, 5);
        assert_eq!(s.admitted, 2);
        assert_eq!(s.deferred, 3);
        // next round's admission budget is fresh
        assert_eq!(c.check_in(ci(9, DeviceId::Mi10)), Ack::Admitted);
    }

    #[test]
    fn digest_is_independent_of_arrival_order() {
        let devices: Vec<(u64, DeviceId)> = (0..10)
            .map(|d| (d as u64, DeviceId::Pixel3))
            .collect();
        let mut reversed = devices.clone();
        reversed.reverse();
        let a = Coordinator::new(cfg(4, 0)).unwrap();
        let b = Coordinator::new(cfg(4, 0)).unwrap();
        let (sa, _) = drive_round(&a, 0, &devices);
        let (sb, _) = drive_round(&b, 0, &reversed);
        assert_eq!(sa.digest, sb.digest);
        assert_eq!(sa.round_time_s.to_bits(), sb.round_time_s.to_bits());
        assert_eq!(
            sa.round_energy_j.to_bits(),
            sb.round_energy_j.to_bits()
        );
    }

    #[test]
    fn protocol_misuse_is_rejected_not_fatal() {
        let c = Coordinator::new(cfg(1, 0)).unwrap();
        // unknown model / bad band / zero steps
        assert_eq!(
            c.check_in(CheckIn {
                device: 0,
                model: 99,
                band: 0,
                charging: false,
                steps: 5
            }),
            Ack::Rejected
        );
        assert_eq!(
            c.check_in(CheckIn {
                device: 0,
                model: 0,
                band: 7,
                charging: false,
                steps: 5
            }),
            Ack::Rejected
        );
        // wrong-phase control ops error
        assert!(c.finish_round(0).is_err());
        assert!(c.lease_poll(0).is_err());
        assert!(c.close_round(3).is_err(), "round number mismatch");
        // a full round with one device
        assert_eq!(c.check_in(ci(0, DeviceId::Pixel3)), Ack::Admitted);
        c.close_round(0).unwrap();
        let l = c.lease_poll(0).unwrap().unwrap();
        // wrong dim, wrong seq, double push
        assert_eq!(
            c.push_update(UpdatePush {
                device: 0,
                round: 0,
                seq: l.seq,
                weight: 1.0,
                params: vec![0.0; 3],
            }),
            Ack::Rejected
        );
        assert!(c.finish_round(0).is_err(), "missing update");
        assert_eq!(
            c.push_update(UpdatePush {
                device: 0,
                round: 0,
                seq: l.seq,
                weight: 1.0,
                params: vec![0.0; 4],
            }),
            Ack::Accepted
        );
        assert_eq!(
            c.push_update(UpdatePush {
                device: 0,
                round: 0,
                seq: l.seq,
                weight: 1.0,
                params: vec![0.0; 4],
            }),
            Ack::Rejected,
            "slot already filled"
        );
        c.finish_round(0).unwrap();
    }

    #[test]
    fn late_checkins_carry_over_to_the_next_round() {
        // a free-running client racing the round pacer: its check-in
        // lands between close and finish, so it must neither join nor
        // inflate the closing round — it opens the next one instead
        let c = Coordinator::new(cfg(4, 0)).unwrap();
        assert_eq!(c.check_in(ci(0, DeviceId::Pixel3)), Ack::Admitted);
        c.close_round(0).unwrap();
        assert_eq!(
            c.check_in(ci(1, DeviceId::S10e)),
            Ack::Admitted,
            "late check-in is admitted (for the next round)"
        );
        let l = c.lease_poll(0).unwrap().unwrap();
        c.push_update(UpdatePush {
            device: 0,
            round: 0,
            seq: l.seq,
            weight: 1.0,
            params: vec![0.0; 4],
        });
        let s0 = c.finish_round(0).unwrap();
        assert_eq!(s0.admitted, 1, "late arrival not billed to round 0");
        // round 1: the carried device is selectable without re-checking
        let picked = c.close_round(1).unwrap();
        assert_eq!(picked, 1);
        let lease = c.lease_poll(1).unwrap();
        assert!(lease.is_some(), "carried device holds round 1's lease");
    }

    #[test]
    fn empty_round_advances_the_clock_by_the_idle_wait() {
        let c = Coordinator::new(cfg(3, 0)).unwrap();
        assert_eq!(c.close_round(0).unwrap(), 0);
        let s = c.finish_round(0).unwrap();
        assert_eq!(s.participants, 0);
        assert_eq!(s.round_time_s, 0.0);
        let t = c.stats().totals;
        assert_eq!(t.total_time_s, EMPTY_ROUND_WAIT_S);
        assert_eq!(t.rounds_run, 1);
    }

    #[test]
    fn batching_amortizes_exploration_across_equivalent_devices() {
        let c = Coordinator::new(cfg(8, 0)).unwrap();
        // 30 devices, all the same (model, band, charging) context
        let devices: Vec<(u64, DeviceId)> =
            (0..30).map(|d| (d as u64, DeviceId::S10e)).collect();
        drive_round(&c, 0, &devices);
        let s = c.stats();
        assert_eq!(s.cache_misses, 1, "one exploration for 30 devices");
        assert!(s.cache_hits >= 29 + 8 - 1, "hits {}", s.cache_hits);
    }
}
