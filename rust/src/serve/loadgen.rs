//! The serve load generator: the million-device fleet repurposed as
//! traffic, plus the independent parity oracle.
//!
//! A [`ScenarioSpec`] fleet (`fleet::scenario::build_fleet`) is
//! partitioned round-robin across `lanes` worker threads; each lane
//! owns one [`ServeClient`] connection and, per round, polls its
//! devices' availability, checks the online ones in (one pipelined
//! batch), then lease-polls, charges the leased devices' loans, and
//! pushes their synthetic updates. Lane 0 paces rounds with
//! `RoundCtl::Close`/`Finish`. The same driver runs over the in-process
//! client and loopback TCP — the transport is the only variable.
//!
//! [`run_oracle`] replays the identical round semantics with *none* of
//! the serve machinery: a serial loop over the devices, selection via
//! the fleet kernel's `round_rng`, plan costs from
//! [`plan_cost`](super::cache::plan_cost) directly, aggregation via
//! `fl::server::fedavg`. It folds the same digest field sequence as the
//! coordinator, so `oracle digest == serve digest` is the claim that
//! wire codec, batching, admission, the LRU cache and dense-seq
//! aggregation are all value-transparent.

use std::sync::Arc;
use std::time::Instant;

use crate::fl::selection::select_uniform;
use crate::fl::server::fedavg;
use crate::fleet::device::{FleetDevice, FleetNode};
use crate::fleet::engine::{round_rng, EMPTY_ROUND_WAIT_S};
use crate::fleet::scenario::ScenarioSpec;
use crate::obs::{Histogram, Obs};
use crate::util::json::Value;
use crate::util::rng::Rng;
use crate::workload::load_or_builtin;

use super::cache::plan_cost;
use super::client::{InProcClient, LeaseReply, ServeClient, TcpClient};
use super::coordinator::{digest_hex, Coordinator, DigestFold, ServeConfig};
use super::wire::{model_code, Ack, CheckIn, UpdatePush};

/// Transport tags recorded in outcomes and `BENCH_serve.json`.
pub const TRANSPORT_INPROC: &str = "inproc";
pub const TRANSPORT_TCP: &str = "tcp";

/// Deterministic thermal band for (device stream seed, round) — the
/// load-side model of the DVFS state a real device would report.
pub fn thermal_band(seed: u64, round: usize) -> u8 {
    let mut rng = Rng::new(
        seed ^ (round as u64).wrapping_mul(0x94D0_49BB_1331_11EB),
    );
    rng.index(super::cache::N_THERMAL_BANDS as usize) as u8
}

/// Deterministic synthetic model update for (scenario seed, device,
/// round) — what a real device's local SGD would produce, reduced to a
/// reproducible vector so aggregates are parity-checkable.
pub fn synth_update(
    seed: u64,
    device: u64,
    round: usize,
    dim: usize,
) -> Vec<f32> {
    let mut rng = Rng::new(
        seed ^ device.wrapping_mul(0x8E84_86E2_4F32_19A3)
            ^ (round as u64).wrapping_mul(0xB5AD_4ECE_DA1C_E2A9),
    );
    (0..dim).map(|_| (rng.f32() - 0.5) * 2.0).collect()
}

/// Everything one load-generator run produced.
#[derive(Clone, Debug, Default)]
pub struct ServeRunOutcome {
    pub scenario: String,
    pub transport: &'static str,
    pub devices: usize,
    pub lanes: usize,
    pub rounds_run: usize,
    pub checkins: u64,
    pub admitted: u64,
    pub deferred: u64,
    pub participations: u64,
    /// Virtual seconds (straggler-paced rounds + overhead/idle waits).
    pub total_time_s: f64,
    pub total_energy_j: f64,
    /// The coordinator's cumulative parity digest (hex form).
    pub digest: String,
    /// Wall seconds for the whole run.
    pub wall_s: f64,
    /// Summed per-round check-in serving windows (slowest lane's
    /// request burst; availability sweeps excluded) — the
    /// `checkins_per_sec` denominator measures the coordinator, not
    /// the load generator's simulation.
    pub checkin_wall_s: f64,
    /// Batch-amortized per-check-in round-trip latencies, one
    /// observation per (lane, round) with traffic, in the crate's
    /// fixed latency buckets (merged across lanes in lane order).
    pub latency_hist: Histogram,
}

impl ServeRunOutcome {
    /// Headline throughput: check-ins served per wall second of
    /// check-in traffic.
    pub fn checkins_per_sec(&self) -> f64 {
        if self.checkin_wall_s > 0.0 {
            self.checkins as f64 / self.checkin_wall_s
        } else {
            0.0
        }
    }

    /// Tail latency: p90 of the batch-amortized check-in observations.
    pub fn p90_checkin_latency_s(&self) -> f64 {
        self.latency_hist.quantile(0.90)
    }

    /// Fraction of check-ins answered with `Deferred` backpressure.
    pub fn deferral_rate(&self) -> f64 {
        if self.checkins > 0 {
            self.deferred as f64 / self.checkins as f64
        } else {
            0.0
        }
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .set("scenario", self.scenario.clone())
            .set("transport", self.transport)
            .set("devices", self.devices)
            .set("lanes", self.lanes)
            .set("rounds_run", self.rounds_run)
            .set("checkins", self.checkins as f64)
            .set("admitted", self.admitted as f64)
            .set("deferred", self.deferred as f64)
            .set("participations", self.participations as f64)
            .set("total_time_s", self.total_time_s)
            .set("total_energy_j", self.total_energy_j)
            .set("digest", self.digest.clone())
            .set("wall_s", self.wall_s)
            .set("checkin_wall_s", self.checkin_wall_s)
            .set("checkins_per_sec", self.checkins_per_sec())
            .set("p90_checkin_latency_s", self.p90_checkin_latency_s())
            .set("deferral_rate", self.deferral_rate())
            .set("checkin_latency_hist", self.latency_hist.to_json())
    }
}

/// One load-generator worker: a device partition + its connection.
struct Lane {
    lane_idx: usize,
    n_lanes: usize,
    devices: Vec<FleetDevice>,
    client: Box<dyn ServeClient>,
    reqs: Vec<CheckIn>,
    admitted: Vec<u64>,
    latencies: Histogram,
    /// Wall seconds of this round's check-in burst alone (the request
    /// traffic, not the availability sweep) — the driver folds the max
    /// across lanes into `checkin_wall_s`.
    last_burst_s: f64,
    /// Client-side telemetry sink (clone of the run's sink; one
    /// `lane-burst` record per (lane, round) with traffic).
    obs: Obs,
}

impl Lane {
    /// Availability poll + pipelined check-in burst for one round.
    fn checkin_phase(
        &mut self,
        now_s: f64,
        round: usize,
    ) -> crate::Result<()> {
        self.reqs.clear();
        self.admitted.clear();
        self.last_burst_s = 0.0;
        for d in self.devices.iter_mut() {
            if d.poll_online(now_s) {
                let t = d.trace.wrap(now_s + d.shift_s);
                let (_, charging) = d.trace.sample(t);
                self.reqs.push(CheckIn {
                    device: d.id as u64,
                    model: model_code(d.model),
                    band: thermal_band(d.seed, round),
                    charging,
                    steps: d.epoch_steps as u32,
                });
            }
        }
        if self.reqs.is_empty() {
            return Ok(());
        }
        let t0 = Instant::now();
        let acks = self.client.check_in_batch(&self.reqs)?;
        self.last_burst_s = t0.elapsed().as_secs_f64();
        self.latencies
            .observe(self.last_burst_s / self.reqs.len() as f64);
        if self.obs.enabled() {
            self.obs.emit(&crate::obs::LaneBurst {
                lane: self.lane_idx,
                round,
                size: self.reqs.len(),
                burst_s: self.last_burst_s,
            });
        }
        crate::ensure!(
            acks.len() == self.reqs.len(),
            "serve loadgen: {} acks for {} check-ins",
            acks.len(),
            self.reqs.len()
        );
        for (req, ack) in self.reqs.iter().zip(&acks) {
            match ack {
                Ack::Admitted => self.admitted.push(req.device),
                Ack::Deferred { .. } => {}
                other => crate::bail!(
                    "serve loadgen: device {} check-in got {other:?}",
                    req.device
                ),
            }
        }
        Ok(())
    }

    /// Lease poll + local charge + update push for one round.
    fn update_phase(
        &mut self,
        round: u32,
        seed: u64,
        dim: usize,
    ) -> crate::Result<()> {
        if self.admitted.is_empty() {
            return Ok(());
        }
        let replies = self.client.lease_poll_batch(&self.admitted)?;
        crate::ensure!(
            replies.len() == self.admitted.len(),
            "serve loadgen: {} lease replies for {} polls",
            replies.len(),
            self.admitted.len()
        );
        let mut pushes = Vec::new();
        for (&dev, reply) in self.admitted.iter().zip(&replies) {
            let lease = match reply {
                LeaseReply::Lease(l) => l,
                LeaseReply::NotSelected => continue,
            };
            crate::ensure!(
                lease.device == dev && lease.round == round,
                "serve loadgen: lease {}/{} for poll {dev}/{round}",
                lease.device,
                lease.round
            );
            // the device pays its leased epoch: loan + train-time
            // bookkeeping feed the next rounds' availability
            let local = dev as usize / self.n_lanes;
            crate::ensure!(
                dev as usize % self.n_lanes == self.lane_idx
                    && local < self.devices.len(),
                "serve loadgen: device {dev} leased to the wrong lane"
            );
            self.devices[local].charge(lease.latency_s, lease.energy_j);
            pushes.push(UpdatePush {
                device: dev,
                round,
                seq: lease.seq,
                weight: lease.steps as f64,
                params: synth_update(seed, dev, round as usize, dim),
            });
        }
        if pushes.is_empty() {
            return Ok(());
        }
        let n = pushes.len();
        let acks = self.client.push_update_batch(pushes)?;
        crate::ensure!(
            acks.len() == n && acks.iter().all(|a| *a == Ack::Accepted),
            "serve loadgen: update push rejected"
        );
        Ok(())
    }
}

/// Drive `spec.rounds` rounds of the serve protocol through the given
/// per-lane clients (all pointed at one coordinator). See the module
/// docs for the round structure.
pub fn run_loadgen(
    spec: &ScenarioSpec,
    clients: Vec<Box<dyn ServeClient>>,
    transport: &'static str,
    update_dim: usize,
    obs: &Obs,
) -> crate::Result<ServeRunOutcome> {
    crate::ensure!(
        !clients.is_empty(),
        "serve loadgen needs at least one lane"
    );
    let n_lanes = clients.len();
    let all = spec.build_fleet()?;
    let n_devices = all.len();
    let mut partitions: Vec<Vec<FleetDevice>> =
        (0..n_lanes).map(|_| Vec::new()).collect();
    for d in all {
        partitions[d.id % n_lanes].push(d);
    }
    let mut lanes: Vec<Lane> = partitions
        .into_iter()
        .zip(clients)
        .enumerate()
        .map(|(lane_idx, (devices, client))| Lane {
            lane_idx,
            n_lanes,
            devices,
            client,
            reqs: Vec::new(),
            admitted: Vec::new(),
            latencies: Histogram::default(),
            last_burst_s: 0.0,
            obs: obs.clone(),
        })
        .collect();

    let mut out = ServeRunOutcome {
        scenario: spec.name.clone(),
        transport,
        devices: n_devices,
        lanes: n_lanes,
        ..Default::default()
    };
    let wall0 = Instant::now();
    let mut now_s = 0.0f64;
    // same basis as the oracle's fold, so a zero-round run still
    // digest-matches instead of reporting a bare 0
    let mut digest_u64 = DigestFold::default().h;

    for round in 0..spec.rounds {
        std::thread::scope(|s| -> crate::Result<()> {
            let mut handles = Vec::with_capacity(lanes.len());
            for lane in lanes.iter_mut() {
                handles.push(s.spawn(move || lane.checkin_phase(now_s, round)));
            }
            for h in handles {
                h.join()
                    .map_err(|_| crate::err!("serve loadgen lane panicked"))??;
            }
            Ok(())
        })?;
        // concurrent lanes: the round's request-serving window is the
        // slowest lane's burst (availability sweep excluded, so
        // checkins_per_sec measures the coordinator, not the simulator)
        out.checkin_wall_s += lanes
            .iter()
            .map(|l| l.last_burst_s)
            .fold(0.0f64, f64::max);

        lanes[0].client.round_close(round as u32)?;

        let seed = spec.seed;
        std::thread::scope(|s| -> crate::Result<()> {
            let mut handles = Vec::with_capacity(lanes.len());
            for lane in lanes.iter_mut() {
                handles.push(s.spawn(move || {
                    lane.update_phase(round as u32, seed, update_dim)
                }));
            }
            for h in handles {
                h.join()
                    .map_err(|_| crate::err!("serve loadgen lane panicked"))??;
            }
            Ok(())
        })?;

        let summary = lanes[0].client.round_finish(round as u32)?;
        out.checkins += summary.checkins;
        out.admitted += summary.admitted;
        out.deferred += summary.deferred;
        out.participations += summary.participants as u64;
        out.total_energy_j += summary.round_energy_j;
        now_s += if summary.admitted == 0 {
            EMPTY_ROUND_WAIT_S
        } else {
            summary.round_time_s + spec.server_overhead_s
        };
        digest_u64 = summary.digest;
        out.rounds_run = round + 1;
    }

    out.total_time_s = now_s;
    out.wall_s = wall0.elapsed().as_secs_f64();
    out.digest = digest_hex(digest_u64);
    // fixed lane order: merged histograms are identical no matter how
    // the lane threads interleaved
    for lane in lanes.iter() {
        out.latency_hist.merge_from(&lane.latencies);
    }
    Ok(out)
}

/// In-process wiring: `lanes` [`InProcClient`]s over one shared
/// coordinator. Returns the coordinator too so callers can read cache
/// stats.
pub fn run_inproc(
    spec: &ScenarioSpec,
    lanes: usize,
    cfg: &ServeConfig,
) -> crate::Result<(ServeRunOutcome, Arc<Coordinator>)> {
    run_inproc_with(spec, lanes, cfg, &Obs::off())
}

/// [`run_inproc`] with a telemetry sink attached to the coordinator:
/// check-in batches, deferrals, carryovers, cache traffic and round
/// lifecycle stream as NDJSON while the run is in flight.
pub fn run_inproc_with(
    spec: &ScenarioSpec,
    lanes: usize,
    cfg: &ServeConfig,
    obs: &Obs,
) -> crate::Result<(ServeRunOutcome, Arc<Coordinator>)> {
    let coord =
        Arc::new(Coordinator::with_obs(cfg.clone(), obs.clone())?);
    let clients: Vec<Box<dyn ServeClient>> = (0..lanes.max(1))
        .map(|_| {
            Box::new(InProcClient::new(Arc::clone(&coord)))
                as Box<dyn ServeClient>
        })
        .collect();
    let out = run_loadgen(
        spec,
        clients,
        TRANSPORT_INPROC,
        cfg.update_dim,
        obs,
    )?;
    Ok((out, coord))
}

/// Loopback/remote TCP wiring: `lanes` connections to `addr`.
pub fn run_tcp(
    spec: &ScenarioSpec,
    lanes: usize,
    addr: std::net::SocketAddr,
    update_dim: usize,
    obs: &Obs,
) -> crate::Result<ServeRunOutcome> {
    let mut clients: Vec<Box<dyn ServeClient>> = Vec::new();
    for _ in 0..lanes.max(1) {
        clients.push(Box::new(TcpClient::connect(addr)?));
    }
    run_loadgen(spec, clients, TRANSPORT_TCP, update_dim, obs)
}

/// What the oracle replay produced.
#[derive(Clone, Debug, Default)]
pub struct OracleOutcome {
    pub digest: String,
    pub rounds_run: usize,
    pub participations: u64,
    pub total_time_s: f64,
    pub total_energy_j: f64,
}

/// Serial replay of the serve round semantics with no coordinator, no
/// cache, no wire format: availability → `round_rng` selection →
/// direct [`plan_cost`] leases → `fl::server::fedavg` aggregation,
/// folding the digest field-for-field as the coordinator does. Only
/// valid against runs with unbounded admission (deferrals are a serve
/// concept the oracle doesn't model).
pub fn run_oracle(
    spec: &ScenarioSpec,
    cfg: &ServeConfig,
) -> crate::Result<OracleOutcome> {
    let workload = load_or_builtin(cfg.workload, "artifacts");
    let mut devices = spec.build_fleet()?;
    let mut fold = DigestFold::default();
    let mut out = OracleOutcome::default();
    let mut now_s = 0.0f64;

    for round in 0..spec.rounds {
        let mut online: Vec<usize> = Vec::new();
        for d in devices.iter_mut() {
            if d.poll_online(now_s) {
                online.push(d.id);
            }
        }
        let mut rng = round_rng(cfg.seed, round);
        let picked =
            select_uniform(&online, cfg.clients_per_round, &mut rng);

        fold.push(round as u64);
        fold.push(online.len() as u64);
        for &gid in &picked {
            fold.push(gid as u64);
        }

        let mut round_time_s = 0.0f64;
        let mut round_energy_j = 0.0f64;
        let mut updates: Vec<(Vec<Vec<f32>>, f64)> =
            Vec::with_capacity(picked.len());
        let mut charges: Vec<(usize, f64, f64)> =
            Vec::with_capacity(picked.len());
        for &gid in &picked {
            let d = &devices[gid];
            let t = d.trace.wrap(now_s + d.shift_s);
            let (_, charging) = d.trace.sample(t);
            let band = thermal_band(d.seed, round);
            let cost = plan_cost(&workload, d.model, band, charging);
            let steps = d.epoch_steps as u32;
            let latency_s = cost.latency_s * steps as f64;
            let energy_j = cost.energy_j * steps as f64;
            round_time_s = round_time_s.max(latency_s);
            round_energy_j += energy_j;
            charges.push((gid, latency_s, energy_j));
            updates.push((
                vec![synth_update(
                    cfg.seed,
                    gid as u64,
                    round,
                    cfg.update_dim,
                )],
                steps as f64,
            ));
        }
        for (gid, t, e) in charges {
            devices[gid].charge(t, e);
        }

        fold.push_f64(round_time_s);
        fold.push_f64(round_energy_j);
        if !updates.is_empty() {
            let agg = fedavg(&updates)?;
            for v in &agg[0] {
                fold.push_f32(*v);
            }
        }

        out.participations += picked.len() as u64;
        out.total_energy_j += round_energy_j;
        now_s += if online.is_empty() {
            EMPTY_ROUND_WAIT_S
        } else {
            round_time_s + spec.server_overhead_s
        };
        out.rounds_run = round + 1;
    }
    out.total_time_s = now_s;
    out.digest = digest_hex(fold.h);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> ScenarioSpec {
        ScenarioSpec {
            name: "serve-unit".to_string(),
            devices: 180,
            rounds: 5,
            clients_per_round: 12,
            trace_users: 2,
            ..ScenarioSpec::default()
        }
    }

    #[test]
    fn inproc_digest_matches_the_oracle_at_any_lane_count() {
        let spec = tiny_spec();
        let cfg = ServeConfig::for_scenario(&spec);
        let oracle = run_oracle(&spec, &cfg).unwrap();
        assert!(oracle.participations > 0);
        for lanes in [1usize, 3] {
            let (out, _) = run_inproc(&spec, lanes, &cfg).unwrap();
            assert_eq!(
                out.digest, oracle.digest,
                "inproc@{lanes} lanes vs oracle"
            );
            assert_eq!(out.participations, oracle.participations);
            assert_eq!(
                out.total_time_s.to_bits(),
                oracle.total_time_s.to_bits()
            );
            assert_eq!(
                out.total_energy_j.to_bits(),
                oracle.total_energy_j.to_bits()
            );
            assert_eq!(out.deferred, 0);
            assert_eq!(out.admitted, out.checkins);
        }
    }

    #[test]
    fn bounded_admission_defers_and_still_completes() {
        let spec = tiny_spec();
        let mut cfg = ServeConfig::for_scenario(&spec);
        cfg.admit_capacity = 5;
        let (out, _) = run_inproc(&spec, 2, &cfg).unwrap();
        assert!(out.deferred > 0, "tiny capacity must defer");
        assert!(out.deferral_rate() > 0.0 && out.deferral_rate() < 1.0);
        assert!(out.admitted <= 5 * out.rounds_run as u64);
        assert_eq!(out.rounds_run, spec.rounds);
    }

    #[test]
    fn synthetic_streams_are_deterministic() {
        assert_eq!(synth_update(1, 2, 3, 8), synth_update(1, 2, 3, 8));
        assert_ne!(synth_update(1, 2, 3, 8), synth_update(1, 2, 4, 8));
        assert_ne!(synth_update(1, 5, 3, 8), synth_update(1, 2, 3, 8));
        assert_eq!(synth_update(0, 0, 0, 16).len(), 16);
        assert_eq!(thermal_band(9, 4), thermal_band(9, 4));
        let bands: Vec<u8> =
            (0..64).map(|r| thermal_band(1234, r)).collect();
        assert!(bands.iter().all(|b| *b < 3));
        assert!(
            bands.windows(2).any(|w| w[0] != w[1]),
            "band schedule must actually vary"
        );
    }

    #[test]
    fn outcome_metrics_derive_sanely() {
        let mut hist = Histogram::default();
        for i in 1..=10 {
            hist.observe(i as f64 * 1e-3);
        }
        let out = ServeRunOutcome {
            checkins: 100,
            deferred: 25,
            checkin_wall_s: 2.0,
            latency_hist: hist,
            ..Default::default()
        };
        assert_eq!(out.checkins_per_sec(), 50.0);
        assert_eq!(out.deferral_rate(), 0.25);
        // target rank 9 of 10 interpolates 4/5 into the (5ms, 10ms]
        // bucket: 5e-3 + 0.8 * 5e-3 = 9e-3
        let p90 = out.p90_checkin_latency_s();
        assert!((p90 - 9e-3).abs() < 1e-9, "p90={p90}");
        let v = out.to_json();
        assert!(v.req_f64("checkins_per_sec").unwrap() > 0.0);
        assert!(
            v.get("checkin_latency_hist").is_some(),
            "hist missing from the bench record"
        );
        assert_eq!(ServeRunOutcome::default().checkins_per_sec(), 0.0);
        assert_eq!(ServeRunOutcome::default().deferral_rate(), 0.0);
        assert_eq!(
            ServeRunOutcome::default().p90_checkin_latency_s(),
            0.0,
            "empty histogram p90 is defined"
        );
    }
}
