//! The TCP face of the coordinator: a `std::net` listener with a
//! thread-per-worker accept/IO pool (no async runtime, no external
//! crates).
//!
//! One accept thread hands connections to a fixed pool of IO workers
//! through a bounded queue. A connection is owned by one worker for its
//! whole life (the load generator holds one connection per lane), so
//! the pool size bounds concurrent connections — when the queue is
//! full, the accept thread writes a `Deferred` ack and closes, which is
//! the transport-level face of the same deterministic-degradation
//! policy the admission queue applies per check-in.
//!
//! The per-connection loop is a plain frame → dispatch → reply cycle
//! over the [`wire`](super::wire) codec, with one latency-critical
//! detail: replies buffer in a `BufWriter` and only flush when the
//! reader is about to block, so a pipelined burst of N check-ins costs
//! O(1) syscalls instead of 2N.

use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use super::coordinator::{Coordinator, RETRY_AFTER_S};
use super::wire::{encode_into, read_frame, write_frame, Ack, Msg, RoundOp};

/// A running TCP coordinator. Dropping the handle does NOT stop the
/// server; call [`shutdown`](TcpServeHandle::shutdown) (benches) or
/// [`wait`](TcpServeHandle::wait) (the `swan serve` CLI).
pub struct TcpServeHandle {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

/// Serve `coord` on `bind_addr` (e.g. `"127.0.0.1:0"` for an ephemeral
/// loopback port) with `workers` IO threads.
pub fn serve_tcp(
    coord: Arc<Coordinator>,
    bind_addr: &str,
    workers: usize,
) -> crate::Result<TcpServeHandle> {
    let workers = workers.max(1);
    let listener = TcpListener::bind(bind_addr)
        .map_err(|e| crate::err!("serve: bind {bind_addr}: {e}"))?;
    let addr = listener.local_addr()?;
    if coord.obs().enabled() {
        coord.obs().emit(&crate::obs::ServeStart {
            addr: addr.to_string(),
            workers,
        });
    }
    let stop = Arc::new(AtomicBool::new(false));

    let (tx, rx) = sync_channel::<TcpStream>(workers);
    let rx = Arc::new(Mutex::new(rx));

    let mut worker_handles = Vec::with_capacity(workers);
    for _ in 0..workers {
        let rx = Arc::clone(&rx);
        let coord = Arc::clone(&coord);
        worker_handles.push(std::thread::spawn(move || loop {
            // take the receiver lock only to pull the next connection;
            // a poisoned lock means a sibling worker died mid-recv —
            // retire this worker too rather than poisoning the pool
            let conn = {
                let guard: std::sync::MutexGuard<'_, Receiver<TcpStream>> =
                    match rx.lock() {
                        Ok(g) => g,
                        Err(_) => return,
                    };
                guard.recv()
            };
            match conn {
                Ok(stream) => serve_conn(&coord, stream),
                Err(_) => return, // accept thread gone: drain complete
            }
        }));
    }

    let stop_accept = Arc::clone(&stop);
    let accept = std::thread::spawn(move || {
        let obs = coord.obs().clone();
        for stream in listener.incoming() {
            if stop_accept.load(Ordering::SeqCst) {
                break;
            }
            let stream = match stream {
                Ok(s) => s,
                Err(_) => {
                    // persistent accept errors (e.g. fd exhaustion)
                    // return immediately — back off instead of
                    // busy-spinning the accept thread at 100% CPU
                    std::thread::sleep(
                        std::time::Duration::from_millis(50),
                    );
                    continue;
                }
            };
            match tx.try_send(stream) {
                Ok(()) => {}
                Err(TrySendError::Full(mut s)) => {
                    // every worker is owned by a live connection:
                    // degrade deterministically instead of queueing.
                    // Without nodelay, Nagle holds this tiny frame for
                    // an RTT and the overflowing client retries late.
                    s.set_nodelay(true).ok();
                    let _ = write_frame(
                        &mut s,
                        &Msg::Ack(Ack::Deferred {
                            retry_after_s: RETRY_AFTER_S,
                        }),
                    );
                    let _ = s.flush();
                    if obs.trace_on() {
                        // no device id yet — the connection never got
                        // to speak — so this edge has a null device
                        obs.emit(&crate::obs::TraceEdge::conn_deferred(
                            coord.intake_round(),
                            coord.trace_now_s(),
                            RETRY_AFTER_S as f64,
                        ));
                    }
                }
                Err(TrySendError::Disconnected(_)) => break,
            }
        }
        // tx drops here; idle workers' recv() errors and they exit
    });

    Ok(TcpServeHandle {
        addr,
        stop,
        accept: Some(accept),
        workers: worker_handles,
    })
}

/// One connection's frame loop. IO or protocol-codec errors end the
/// connection (one peer's corruption never takes down the server);
/// coordinator-level refusals travel back as `Rejected` acks.
fn serve_conn(coord: &Arc<Coordinator>, stream: TcpStream) {
    stream.set_nodelay(true).ok();
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let mut writer = BufWriter::new(stream);
    // persistent encode buffer: replies (mostly small Acks) serialize
    // here and append to the BufWriter in one write, so a pipelined
    // burst coalesces into the existing flush batching with no
    // per-frame Vec allocation
    let mut enc: Vec<u8> = Vec::new();
    loop {
        // about to block on the socket? push out buffered replies
        // first, or a pipelining peer deadlocks waiting for them
        if reader.buffer().is_empty() && writer.flush().is_err() {
            return;
        }
        let msg = match read_frame(&mut reader) {
            Ok(Some(m)) => m,
            Ok(None) => {
                let _ = writer.flush();
                return; // clean EOF
            }
            Err(_) => return, // corrupt frame: drop the connection
        };
        let reply = dispatch(coord, msg);
        enc.clear();
        encode_into(&reply, &mut enc);
        if writer.write_all(&enc).is_err() {
            return;
        }
    }
}

fn dispatch(coord: &Arc<Coordinator>, msg: Msg) -> Msg {
    match msg {
        Msg::CheckIn(ci) => Msg::Ack(coord.check_in(ci)),
        Msg::LeasePoll(lp) => match coord.lease_poll(lp.device) {
            Ok(Some(lease)) => Msg::PlanLease(lease),
            Ok(None) => Msg::Ack(Ack::NotSelected),
            Err(_) => Msg::Ack(Ack::Rejected),
        },
        Msg::UpdatePush(up) => Msg::Ack(coord.push_update(up)),
        Msg::RoundCtl(ctl) => match ctl.op {
            RoundOp::Close => match coord.close_round(ctl.round) {
                Ok(picked) => Msg::Ack(Ack::Closed { picked }),
                Err(_) => Msg::Ack(Ack::Rejected),
            },
            RoundOp::Finish => match coord.finish_round(ctl.round) {
                Ok(summary) => Msg::RoundSummary(summary),
                Err(_) => Msg::Ack(Ack::Rejected),
            },
        },
        Msg::ModelInit(mi) => match coord.set_global(mi.params) {
            Ok(()) => Msg::Ack(Ack::Accepted),
            Err(_) => Msg::Ack(Ack::Rejected),
        },
        Msg::ModelPull(_) => match coord.model_pull() {
            Ok((round, params)) => Msg::ModelState(
                crate::serve::wire::ModelState { round, params },
            ),
            Err(_) => Msg::Ack(Ack::Rejected),
        },
        // server-to-client message types arriving inbound are misuse
        Msg::PlanLease(_)
        | Msg::Ack(_)
        | Msg::RoundSummary(_)
        | Msg::ModelState(_) => Msg::Ack(Ack::Rejected),
    }
}

impl TcpServeHandle {
    /// Stop accepting, wake the accept thread, and join the pool.
    /// Callers must have closed their client connections first —
    /// workers finish serving any still-open connection before
    /// exiting.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // the accept loop is parked in accept(2); poke it
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }

    /// Block until the accept thread exits (the `swan serve` CLI's
    /// foreground mode — effectively forever, until the process dies).
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::client::{ServeClient, TcpClient};
    use crate::serve::coordinator::ServeConfig;
    use crate::serve::wire::CheckIn;
    use crate::workload::WorkloadName;

    fn cfg() -> ServeConfig {
        ServeConfig {
            seed: 3,
            clients_per_round: 2,
            server_overhead_s: 0.5,
            batch_size: 4,
            admit_capacity: 0,
            cache_capacity: 16,
            update_dim: 4,
            workload: WorkloadName::ShufflenetV2,
            arm: crate::fl::FlArm::Swan,
        }
    }

    #[test]
    fn a_full_round_over_loopback() {
        let coord = Arc::new(Coordinator::new(cfg()).unwrap());
        let handle =
            serve_tcp(Arc::clone(&coord), "127.0.0.1:0", 2).unwrap();
        {
            let mut c = TcpClient::connect(handle.addr).unwrap();
            // wrong-dim model init is a Rejected ack, not a hang
            assert!(c.model_init(vec![0.5; 3]).is_err());
            c.model_init(vec![0.5; 4]).unwrap();
            let (round0, g0) = c.model_pull().unwrap();
            assert_eq!(round0, 0);
            assert_eq!(g0, vec![0.5; 4]);
            let reqs: Vec<CheckIn> = (0..6u64)
                .map(|d| CheckIn {
                    device: d,
                    model: (d % 5) as u8,
                    band: 0,
                    charging: true,
                    steps: 5,
                })
                .collect();
            let acks = c.check_in_batch(&reqs).unwrap();
            assert!(acks.iter().all(|a| *a == Ack::Admitted));
            let picked = c.round_close(0).unwrap();
            assert_eq!(picked, 2);
            let devices: Vec<u64> = reqs.iter().map(|r| r.device).collect();
            let replies = c.lease_poll_batch(&devices).unwrap();
            let mut pushes = Vec::new();
            for r in &replies {
                if let crate::serve::client::LeaseReply::Lease(l) = r {
                    pushes.push(crate::serve::wire::UpdatePush {
                        device: l.device,
                        round: 0,
                        seq: l.seq,
                        weight: l.steps as f64,
                        params: vec![1.0, 2.0, 3.0, 4.0],
                    });
                }
            }
            assert_eq!(pushes.len(), 2);
            let acks = c.push_update_batch(pushes).unwrap();
            assert!(acks.iter().all(|a| *a == Ack::Accepted));
            let s = c.round_finish(0).unwrap();
            assert_eq!(s.participants, 2);
            assert_eq!(s.admitted, 6);
            assert_eq!(s.digest, {
                // the handle's digest is readable in-process too
                u64::from_str_radix(
                    coord.digest().strip_prefix("serve-").unwrap(),
                    16,
                )
                .unwrap()
            });
            // the pulled model is the round's aggregate, bit-exact
            // over the wire
            let (round1, g1) = c.model_pull().unwrap();
            assert_eq!(round1, 1);
            let agg = coord.last_aggregate();
            assert_eq!(g1.len(), agg.len());
            for (a, b) in g1.iter().zip(&agg) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        handle.shutdown();
    }

    #[test]
    fn overflow_connections_get_a_deferral_frame() {
        let coord = Arc::new(Coordinator::new(cfg()).unwrap());
        let handle = serve_tcp(coord, "127.0.0.1:0", 1).unwrap();
        // occupy the only worker with a live connection
        let held = TcpClient::connect(handle.addr).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(50));
        // with the worker busy and a 1-slot queue, at most one of the
        // next two connections can be queued; the overflow one must
        // receive a deterministic Deferred frame (the queued one just
        // never gets served, so its read times out)
        let overflow: Vec<TcpStream> = (0..2)
            .map(|_| {
                let s = TcpStream::connect(handle.addr).unwrap();
                s.set_read_timeout(Some(
                    std::time::Duration::from_millis(500),
                ))
                .unwrap();
                s
            })
            .collect();
        let mut deferred = 0;
        let mut readers: Vec<BufReader<TcpStream>> =
            overflow.into_iter().map(BufReader::new).collect();
        for r in readers.iter_mut() {
            if let Ok(Some(Msg::Ack(Ack::Deferred { retry_after_s }))) =
                read_frame(r)
            {
                assert!(retry_after_s > 0.0);
                deferred += 1;
            }
        }
        assert!(deferred >= 1, "overload must surface as a deferral");
        drop(held);
        drop(readers);
        handle.shutdown();
    }
}
