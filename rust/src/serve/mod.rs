//! `serve` — the zero-dependency FL coordinator control plane.
//!
//! The paper's deployment context (§2, §5) is a central coordinator
//! admitting, profiling and aggregating check-ins from millions of
//! smartphones. PR 1–2 built the *fleet side* of that loop at scale;
//! this subsystem supplies the *server side* and repurposes the fleet
//! as its load generator — the repo's first subsystem whose throughput
//! is measured in requests served, not devices stepped.
//!
//! - [`wire`] — the compact length-prefixed binary wire format
//!   (`CheckIn`, `PlanLease`, `UpdatePush`, `Ack`, round control);
//!   f64/f32 fields travel as raw bits so values round-trip exactly.
//! - [`cache`] — the LRU **profile cache** keyed on (SoC model,
//!   thermal band, charger state): §4.2 exploration runs once per
//!   context and is shared across every equivalent device.
//! - [`coordinator`] — the transport-agnostic round state machine:
//!   bounded admission with `Retry-After` deferrals (overload degrades
//!   into a deterministic deferral rate), check-ins coalesced into
//!   fixed-size batches (one round/cache lock acquisition per batch),
//!   (seed, round)-keyed selection via the fleet kernel's `round_rng`,
//!   and FedAvg aggregation through `fl::server` over dense seq slots.
//! - [`server`] — the `std::net` TCP listener with a thread-per-worker
//!   accept/IO pool; pipelining-aware framing (flush only when the
//!   reader would block).
//! - [`client`] — the [`ServeClient`] trait with both wirings:
//!   [`InProcClient`] (fleet devices check in with no sockets) and
//!   [`TcpClient`] (pipelined batches over loopback/remote TCP).
//! - [`loadgen`] — the fleet-as-traffic load generator (lane threads
//!   over a `ScenarioSpec` fleet) and [`run_oracle`], the serial
//!   machinery-free replay whose digest the serve paths must reproduce
//!   bit-for-bit.
//!
//! **Parity contract.** Everything the coordinator folds into its
//! digest is arrival-order independent, so three independently wired
//! runs — oracle, in-process, loopback TCP — must produce one digest.
//! `fleet::bench::run_serve_bench` (behind `swan bench serve` and the
//! CI `serve-smoke` job) errors on any divergence.

pub mod cache;
pub mod client;
pub mod coordinator;
pub mod loadgen;
pub mod server;
pub mod wire;

pub use cache::{plan_cost, PlanKey, ProfileCache};
pub use client::{InProcClient, LeaseReply, ServeClient, TcpClient};
pub use coordinator::{
    Coordinator, DigestFold, ServeConfig, ServeStats, RETRY_AFTER_S,
};
pub use loadgen::{
    run_inproc, run_inproc_with, run_loadgen, run_oracle, run_tcp,
    synth_update, thermal_band, OracleOutcome, ServeRunOutcome,
};
pub use server::{serve_tcp, TcpServeHandle};
pub use wire::{
    model_code, model_from_code, Ack, CheckIn, ModelInit, ModelPull,
    ModelState, Msg, PlanLease, RoundSummary, UpdatePush,
};
