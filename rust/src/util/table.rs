//! Markdown / CSV table emitter for the paper-table reports.

/// A simple column-aligned table with a title.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// GitHub-flavoured markdown.
    pub fn to_markdown(&self) -> String {
        let w = self.widths();
        let mut out = format!("### {}\n\n", self.title);
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:width$} |", c, width = w[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers));
        let mut sep = String::from("|");
        for wi in &w {
            sep.push_str(&format!("{:-<width$}|", "", width = wi + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .headers
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(
                &row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","),
            );
            out.push('\n');
        }
        out
    }

    /// Print markdown to stdout and persist both formats under
    /// `target/reports/`.
    pub fn emit(&self) -> std::io::Result<()> {
        println!("\n{}", self.to_markdown());
        let dir = std::path::Path::new("target/reports");
        std::fs::create_dir_all(dir)?;
        let safe: String = self
            .title
            .chars()
            .map(|c| if c.is_alphanumeric() { c } else { '_' })
            .collect();
        std::fs::write(dir.join(format!("{safe}.md")), self.to_markdown())?;
        std::fs::write(dir.join(format!("{safe}.csv")), self.to_csv())?;
        Ok(())
    }
}

/// Format a ratio as the paper does ("1.9×", "21×").
pub fn fmt_ratio(r: f64) -> String {
    if r >= 10.0 {
        format!("{r:.0}×")
    } else {
        format!("{r:.1}×")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row_strs(&["1", "2"]);
        let md = t.to_markdown();
        assert!(md.contains("### T"));
        assert!(md.lines().count() >= 4);
        assert!(md.contains("| a"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row_strs(&["1"]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("T", &["a"]);
        t.row_strs(&["x,y"]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    fn ratio_format_matches_paper_style() {
        assert_eq!(fmt_ratio(1.86), "1.9×");
        assert_eq!(fmt_ratio(21.3), "21×");
        assert_eq!(fmt_ratio(6.5), "6.5×");
    }
}
