//! Seedable PRNG: splitmix64 seeding + xoshiro256++ core.
//!
//! Every stochastic component in the simulator (trace generation, client
//! sampling, synthetic datasets, interference sessions, the property-test
//! harness) draws from this generator so whole experiments replay
//! bit-identically from a single seed — the FL tables depend on that.

/// xoshiro256++ (Blackman & Vigna), seeded via splitmix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. one per simulated client).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) (Lemire's method, unbiased enough for sims).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize index into a slice of length `n`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (shape >= 0 handled by boosting).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            let u = self.f64().max(1e-300);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Symmetric Dirichlet(alpha) over `k` categories.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let s: f64 = v.iter().sum();
        if s <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        for x in &mut v {
            *x /= s;
        }
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.index(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Draw from a discrete distribution given (unnormalized) weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(17);
        for alpha in [0.1, 0.5, 1.0, 10.0] {
            let v = r.dirichlet(alpha, 8);
            assert_eq!(v.len(), 8);
            assert!((v.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(v.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(19);
        let s = r.sample_indices(50, 20);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(23);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > 5 * counts[0]);
    }

    #[test]
    fn gamma_positive_and_mean() {
        let mut r = Rng::new(29);
        let n = 50_000;
        let shape = 2.5;
        let mean: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
        assert!((mean - shape).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(31);
        let n = 50_000;
        let mean: f64 =
            (0..n).map(|_| r.exponential(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(37);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<u32>>());
    }
}
