//! Summary statistics used by the explorer, benches and reports.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Linear-interpolated percentile, p in [0, 100]; 0.0 for empty input.
/// Sorts with `total_cmp` so a stray NaN sample cannot panic the
/// reporting path (NaNs sort last and only perturb the top ranks).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Smallest sample; 0.0 for empty input (±INFINITY would poison the
/// CSV/JSON emitters, which have no representation for it).
pub fn min(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Largest sample; 0.0 for empty input (see [`min`]).
pub fn max(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Exponentially weighted moving average — the controller's interference
/// signal smoother.
#[derive(Clone, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ewma { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }

    pub fn reset(&mut self) {
        self.value = None;
    }
}

/// Online mean/variance (Welford) — used by the energy meter.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        // empty min/max must return finite values: ±INFINITY is not
        // representable in the JSON/CSV the bench emitters write
        assert_eq!(min(&[]), 0.0);
        assert_eq!(max(&[]), 0.0);
        assert!(min(&[]).is_finite() && max(&[]).is_finite());
    }

    #[test]
    fn nan_samples_cannot_panic_percentile() {
        // partial_cmp().unwrap() used to panic here; total_cmp sorts
        // NaN last instead
        let xs = [3.0, f64::NAN, 1.0];
        let p0 = percentile(&xs, 0.0);
        assert_eq!(p0, 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        // unsorted input must work too
        let xs = [5.0, 1.0, 3.0];
        assert!((percentile(&xs, 50.0) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        assert_eq!(e.update(10.0), 10.0); // first sample passes through
        for _ in 0..50 {
            e.update(2.0);
        }
        assert!((e.get().unwrap() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0, 8.0, 13.0];
        let mut w = Welford::default();
        for x in xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(w.count(), 7);
    }
}
