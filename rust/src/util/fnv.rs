//! FNV-1a — the repo's one order-sensitive fold for determinism
//! fingerprints.
//!
//! Both the fleet kernel's aggregate digest
//! (`fleet::metrics::FleetOutcome::digest`) and the serve control
//! plane's parity digest (`serve::coordinator::DigestFold`) fold their
//! field streams through this primitive, so the offset-basis/prime
//! constants live in exactly one place. FNV-1a is deliberately not a
//! cryptographic hash: the digests detect *divergence between runs
//! that should be identical* (resharding, transport changes), not
//! adversarial collisions.

/// An incremental FNV-1a fold over 64-bit words. Floats are folded as
/// raw bits, so a single-ulp difference changes the digest.
#[derive(Clone, Copy, Debug)]
pub struct Fnv1a {
    pub h: u64,
}

impl Default for Fnv1a {
    fn default() -> Self {
        Fnv1a {
            h: 0xcbf2_9ce4_8422_2325, // FNV-1a 64-bit offset basis
        }
    }
}

impl Fnv1a {
    pub fn push(&mut self, x: u64) {
        self.h ^= x;
        self.h = self.h.wrapping_mul(0x0000_0100_0000_01B3); // FNV prime
    }

    pub fn push_f64(&mut self, x: f64) {
        self.push(x.to_bits());
    }

    pub fn push_f32(&mut self, x: f32) {
        self.push(x.to_bits() as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fold_is_order_sensitive_and_ulp_sensitive() {
        let mut a = Fnv1a::default();
        a.push(1);
        a.push(2);
        let mut b = Fnv1a::default();
        b.push(2);
        b.push(1);
        assert_ne!(a.h, b.h, "order must matter");

        let mut x = Fnv1a::default();
        x.push_f64(1.0);
        let mut y = Fnv1a::default();
        y.push_f64(f64::from_bits(1.0f64.to_bits() + 1));
        assert_ne!(x.h, y.h, "one ulp must matter");

        let mut z = Fnv1a::default();
        z.push_f32(1.5);
        let mut w = Fnv1a::default();
        w.push(1.5f32.to_bits() as u64);
        assert_eq!(z.h, w.h, "push_f32 folds the raw bits");
    }

    #[test]
    fn empty_fold_is_the_offset_basis() {
        assert_eq!(Fnv1a::default().h, 0xcbf2_9ce4_8422_2325);
    }
}
