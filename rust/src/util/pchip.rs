//! PCHIP — Piecewise Cubic Hermite Interpolating Polynomial.
//!
//! Rust port of `scipy.interpolate.PchipInterpolator` (Fritsch–Carlson
//! monotone derivatives), which Appendix A.2 of the paper uses to resample
//! irregular GreenHub battery traces onto a uniform 10-minute grid. The
//! monotonicity-preserving property matters: battery level between two
//! samples must never overshoot (a battery cannot charge above the later
//! sample while discharging), which a plain cubic spline would violate.

/// Monotone cubic Hermite interpolator over strictly increasing `x`.
#[derive(Clone, Debug)]
pub struct Pchip {
    x: Vec<f64>,
    y: Vec<f64>,
    d: Vec<f64>, // derivative at each knot
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PchipError {
    TooFew(usize),
    NotIncreasing(usize),
    LengthMismatch(usize, usize),
}

impl std::fmt::Display for PchipError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PchipError::TooFew(n) => {
                write!(f, "need at least 2 points, got {n}")
            }
            PchipError::NotIncreasing(i) => {
                write!(f, "x must be strictly increasing at index {i}")
            }
            PchipError::LengthMismatch(a, b) => {
                write!(f, "x and y length mismatch: {a} vs {b}")
            }
        }
    }
}

impl std::error::Error for PchipError {}

/// Segment cache for [`Pchip::eval_monotone`]: remembers the last
/// segment hit so sorted query streams pay an amortized O(1) walk
/// instead of a binary search per call.
#[derive(Clone, Copy, Debug, Default)]
pub struct PchipCursor {
    seg: usize,
}

impl Pchip {
    pub fn new(x: Vec<f64>, y: Vec<f64>) -> Result<Self, PchipError> {
        if x.len() != y.len() {
            return Err(PchipError::LengthMismatch(x.len(), y.len()));
        }
        let n = x.len();
        if n < 2 {
            return Err(PchipError::TooFew(n));
        }
        for i in 1..n {
            if x[i] <= x[i - 1] {
                return Err(PchipError::NotIncreasing(i));
            }
        }
        let d = derivatives(&x, &y);
        Ok(Pchip { x, y, d })
    }

    /// Binary search for the segment with `x[i] <= t < x[i+1]`.
    /// Caller guarantees `x[0] < t < x[n-1]`.
    fn segment_of(&self, t: f64) -> usize {
        let n = self.x.len();
        let mut lo = 0usize;
        let mut hi = n - 1;
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if self.x[mid] <= t {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }

    /// Hermite evaluation on segment `lo` (shared by every eval path so
    /// cursor and binary-search lookups are bit-identical).
    #[inline]
    fn eval_segment(&self, lo: usize, t: f64) -> f64 {
        let h = self.x[lo + 1] - self.x[lo];
        let s = (t - self.x[lo]) / h;
        hermite(
            s,
            h,
            self.y[lo],
            self.y[lo + 1],
            self.d[lo],
            self.d[lo + 1],
        )
    }

    /// Evaluate at `t`; clamps outside the knot range (flat extrapolation —
    /// matches how the trace pipeline holds the last battery reading).
    pub fn eval(&self, t: f64) -> f64 {
        let n = self.x.len();
        if t <= self.x[0] {
            return self.y[0];
        }
        if t >= self.x[n - 1] {
            return self.y[n - 1];
        }
        self.eval_segment(self.segment_of(t), t)
    }

    /// Evaluate at `t` with a segment cursor. For non-decreasing query
    /// streams the segment is found by a short forward walk from the
    /// cursor (amortized O(1)); a backward jump falls back to the
    /// binary search. Always bit-identical to [`eval`](Pchip::eval).
    pub fn eval_monotone(&self, t: f64, cur: &mut PchipCursor) -> f64 {
        let n = self.x.len();
        if t <= self.x[0] {
            cur.seg = 0;
            return self.y[0];
        }
        if t >= self.x[n - 1] {
            cur.seg = n - 2;
            return self.y[n - 1];
        }
        let mut lo = cur.seg.min(n - 2);
        if self.x[lo] > t {
            // query moved backward: cursor is useless, search fresh
            lo = self.segment_of(t);
        } else {
            while self.x[lo + 1] <= t {
                lo += 1;
            }
        }
        cur.seg = lo;
        self.eval_segment(lo, t)
    }

    /// Evaluate a batch of queries with one forward cursor. Meant for
    /// sorted (non-decreasing) `ts`, where the whole batch costs one
    /// pass over the knots; unsorted input still returns exact values
    /// through the cursor's binary-search fallback.
    pub fn eval_many(&self, ts: &[f64]) -> Vec<f64> {
        let mut cur = PchipCursor::default();
        ts.iter().map(|&t| self.eval_monotone(t, &mut cur)).collect()
    }

    /// Evaluate on a uniform grid from `t0` with spacing `dt`, `n` points
    /// (a sorted stream, so this rides the cursor path).
    pub fn resample(&self, t0: f64, dt: f64, n: usize) -> Vec<f64> {
        let mut cur = PchipCursor::default();
        (0..n)
            .map(|i| self.eval_monotone(t0 + dt * i as f64, &mut cur))
            .collect()
    }
}

/// Precomputed uniform-grid evaluation table: `values[i] = eval(t0 + dt·i)`.
///
/// Interpolation is paid once at build time; afterwards a lookup
/// ([`at`](PchipTable::at)) is one floor-divide and an indexed load.
/// `trace::resample::resample_trace` builds its grid through this and
/// moves [`into_values`](PchipTable::into_values) into
/// `ResampledTrace::level`, whose O(1) indexed lookups the fleet
/// kernels then ride per poll.
#[derive(Clone, Debug)]
pub struct PchipTable {
    pub t0: f64,
    pub dt: f64,
    values: Vec<f64>,
}

impl PchipTable {
    /// Evaluate `p` on the uniform grid `(t0, dt, n)` once — a sorted
    /// batch, so it goes through [`Pchip::eval_many`]'s single forward
    /// cursor.
    pub fn build(p: &Pchip, t0: f64, dt: f64, n: usize) -> PchipTable {
        let ts: Vec<f64> = (0..n).map(|i| t0 + dt * i as f64).collect();
        PchipTable {
            t0,
            dt,
            values: p.eval_many(&ts),
        }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Consume the table, keeping only the grid values.
    pub fn into_values(self) -> Vec<f64> {
        self.values
    }

    /// O(1) floor-cell lookup, clamped to the grid range.
    #[inline]
    pub fn at(&self, t: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values[grid_cell(self.t0, self.dt, self.values.len(), t)]
    }

    /// Batch twin of [`at`](PchipTable::at): one gather pass over `ts`
    /// into the caller's reusable `out` buffer (cleared, then refilled —
    /// zero steady-state allocation once `out` has grown to size). The
    /// loop body is a pure clamp + indexed load with no per-iteration
    /// branches, so the shard-wide availability sweep in the fleet
    /// kernel runs it lane-parallel. Elementwise bit-identical to `at`,
    /// including the NaN-for-empty contract.
    pub fn eval_many(&self, ts: &[f64], out: &mut Vec<f64>) {
        out.clear();
        if self.values.is_empty() {
            out.resize(ts.len(), f64::NAN);
            return;
        }
        let (t0, dt, n) = (self.t0, self.dt, self.values.len());
        out.extend(
            ts.iter().map(|&t| self.values[grid_cell(t0, dt, n, t)]),
        );
    }
}

/// THE uniform-grid floor-cell index: `clamp(floor((t - t0)/dt), 0, len-1)`.
///
/// Shared by [`PchipTable::at`], [`PchipTable::eval_many`] and
/// `trace::resample::ResampledTrace` so every grid consumer in the crate
/// clamps identically — a second hand-rolled copy of this formula is how
/// batch and scalar paths drift apart by one cell at boundaries. Caller
/// guarantees `len > 0`.
#[inline]
pub fn grid_cell(t0: f64, dt: f64, len: usize, t: f64) -> usize {
    (((t - t0) / dt).floor() as i64).clamp(0, len as i64 - 1) as usize
}

#[inline]
fn hermite(s: f64, h: f64, y0: f64, y1: f64, d0: f64, d1: f64) -> f64 {
    // cubic Hermite basis on normalized s ∈ [0, 1]
    let s2 = s * s;
    let s3 = s2 * s;
    let h00 = 2.0 * s3 - 3.0 * s2 + 1.0;
    let h10 = s3 - 2.0 * s2 + s;
    let h01 = -2.0 * s3 + 3.0 * s2;
    let h11 = s3 - s2;
    h00 * y0 + h10 * h * d0 + h01 * y1 + h11 * h * d1
}

/// Fritsch–Carlson derivative estimates (scipy `_find_derivatives`).
fn derivatives(x: &[f64], y: &[f64]) -> Vec<f64> {
    let n = x.len();
    let mut h = vec![0.0; n - 1];
    let mut s = vec![0.0; n - 1]; // secant slopes
    for i in 0..n - 1 {
        h[i] = x[i + 1] - x[i];
        s[i] = (y[i + 1] - y[i]) / h[i];
    }
    let mut d = vec![0.0; n];
    if n == 2 {
        d[0] = s[0];
        d[1] = s[0];
        return d;
    }
    // interior: weighted harmonic mean where secants agree in sign
    for i in 1..n - 1 {
        let (s0, s1) = (s[i - 1], s[i]);
        if s0 == 0.0 || s1 == 0.0 || (s0 > 0.0) != (s1 > 0.0) {
            d[i] = 0.0;
        } else {
            let w1 = 2.0 * h[i] + h[i - 1];
            let w2 = h[i] + 2.0 * h[i - 1];
            d[i] = (w1 + w2) / (w1 / s0 + w2 / s1);
        }
    }
    d[0] = edge_derivative(h[0], h[1], s[0], s[1]);
    d[n - 1] = edge_derivative(h[n - 2], h[n - 3], s[n - 2], s[n - 3]);
    d
}

/// One-sided three-point estimate with scipy's sign clipping.
fn edge_derivative(h0: f64, h1: f64, s0: f64, s1: f64) -> f64 {
    let mut d = ((2.0 * h0 + h1) * s0 - h0 * s1) / (h0 + h1);
    if d.signum() != s0.signum() || s0 == 0.0 {
        if s0 == 0.0 {
            return 0.0;
        }
        d = 0.0;
    } else if (s0 > 0.0) != (s1 > 0.0) && d.abs() > 3.0 * s0.abs() {
        d = 3.0 * s0;
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interpolates_knots_exactly() {
        let x = vec![0.0, 1.0, 2.5, 4.0, 7.0];
        let y = vec![1.0, 3.0, 2.0, 2.0, 9.0];
        let p = Pchip::new(x.clone(), y.clone()).unwrap();
        for (xi, yi) in x.iter().zip(&y) {
            assert!((p.eval(*xi) - yi).abs() < 1e-12);
        }
    }

    #[test]
    fn linear_data_stays_linear() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 2.0 * v + 1.0).collect();
        let p = Pchip::new(x, y).unwrap();
        for i in 0..90 {
            let t = i as f64 * 0.1;
            assert!((p.eval(t) - (2.0 * t + 1.0)).abs() < 1e-9, "t={t}");
        }
    }

    #[test]
    fn monotone_data_gives_monotone_interpolant() {
        // the property the paper needs: battery % must not overshoot
        let x = vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0];
        let y = vec![100.0, 97.0, 96.5, 80.0, 79.9, 50.0];
        let p = Pchip::new(x, y).unwrap();
        let mut prev = f64::INFINITY;
        for i in 0..=500 {
            let v = p.eval(i as f64 * 0.01);
            assert!(v <= prev + 1e-9, "overshoot at {i}: {v} > {prev}");
            prev = v;
        }
        assert!(p.eval(0.0) <= 100.0 && p.eval(5.0) >= 50.0 - 1e-9);
    }

    #[test]
    fn flat_segments_stay_flat() {
        let x = vec![0.0, 1.0, 2.0, 3.0];
        let y = vec![5.0, 5.0, 5.0, 7.0];
        let p = Pchip::new(x, y).unwrap();
        for i in 0..=100 {
            let t = i as f64 * 0.02; // within [0, 2]
            assert!((p.eval(t) - 5.0).abs() < 1e-9);
        }
    }

    #[test]
    fn clamps_out_of_range() {
        let p = Pchip::new(vec![1.0, 2.0], vec![10.0, 20.0]).unwrap();
        assert_eq!(p.eval(0.0), 10.0);
        assert_eq!(p.eval(5.0), 20.0);
    }

    #[test]
    fn matches_scipy_reference_values() {
        // scipy.interpolate.PchipInterpolator(
        //     [0, 1, 2, 4, 5], [0, 1, 0.5, 2, 2.5]) evaluated at selected ts
        let p = Pchip::new(
            vec![0.0, 1.0, 2.0, 4.0, 5.0],
            vec![0.0, 1.0, 0.5, 2.0, 2.5],
        )
        .unwrap();
        // values computed with scipy 1.17.1
        let cases = [
            (0.5, 0.71875),
            (1.5, 0.75),
            (3.0, 1.1032608695652175),
            (4.5, 2.271286231884058),
        ];
        for (t, want) in cases {
            let got = p.eval(t);
            assert!(
                (got - want).abs() < 1e-9,
                "t={t}: got {got}, want {want}"
            );
        }
    }

    #[test]
    fn rejects_bad_input() {
        assert!(Pchip::new(vec![0.0], vec![1.0]).is_err());
        assert!(Pchip::new(vec![0.0, 0.0], vec![1.0, 2.0]).is_err());
        assert!(Pchip::new(vec![2.0, 1.0], vec![1.0, 2.0]).is_err());
        assert!(Pchip::new(vec![0.0, 1.0], vec![1.0]).is_err());
    }

    #[test]
    fn resample_uniform_grid() {
        let p = Pchip::new(vec![0.0, 10.0], vec![0.0, 10.0]).unwrap();
        let out = p.resample(0.0, 2.5, 5);
        assert_eq!(out.len(), 5);
        assert!((out[2] - 5.0).abs() < 1e-9);
        assert!((out[4] - 10.0).abs() < 1e-9);
    }

    fn wiggly() -> Pchip {
        Pchip::new(
            vec![0.0, 1.0, 2.5, 4.0, 7.0, 9.5, 12.0],
            vec![1.0, 3.0, 2.0, 2.0, 9.0, 4.0, 6.5],
        )
        .unwrap()
    }

    #[test]
    fn eval_monotone_bit_identical_to_eval() {
        let p = wiggly();
        let mut cur = PchipCursor::default();
        for i in 0..=1300 {
            let t = -0.5 + i as f64 * 0.01; // sorted sweep incl. clamps
            assert_eq!(
                p.eval_monotone(t, &mut cur).to_bits(),
                p.eval(t).to_bits(),
                "t={t}"
            );
        }
    }

    #[test]
    fn cursor_survives_backward_jumps_and_reset() {
        let p = wiggly();
        let mut cur = PchipCursor::default();
        // walk the cursor to the far end…
        assert_eq!(p.eval_monotone(11.0, &mut cur).to_bits(), p.eval(11.0).to_bits());
        // …then jump backwards: must fall back to search, stay exact
        for t in [0.3, 5.5, 1.7, 8.0, 0.1] {
            assert_eq!(
                p.eval_monotone(t, &mut cur).to_bits(),
                p.eval(t).to_bits(),
                "t={t}"
            );
        }
        // a fresh cursor re-evaluates from segment 0 identically
        let mut fresh = PchipCursor::default();
        assert_eq!(
            p.eval_monotone(6.0, &mut fresh).to_bits(),
            p.eval(6.0).to_bits()
        );
    }

    #[test]
    fn cursor_boundary_cases_pin_the_knot_edges() {
        let p = wiggly(); // knots span [0, 12]
        // exactly at / below the first knot: clamp branch, cursor reset
        let mut cur = PchipCursor::default();
        assert_eq!(p.eval_monotone(11.0, &mut cur).to_bits(), p.eval(11.0).to_bits());
        assert_eq!(p.eval_monotone(0.0, &mut cur).to_bits(), p.y[0].to_bits());
        assert_eq!(cur.seg, 0, "at-first-knot query must reset the cursor");
        assert_eq!(p.eval_monotone(-3.0, &mut cur).to_bits(), p.y[0].to_bits());
        assert_eq!(cur.seg, 0);
        // just inside the first segment after a clamp: forward walk
        assert_eq!(
            p.eval_monotone(0.5, &mut cur).to_bits(),
            p.eval(0.5).to_bits()
        );
        // exactly at / above the last knot: clamp branch, cursor parked
        // on the final segment
        let n = p.x.len();
        assert_eq!(
            p.eval_monotone(12.0, &mut cur).to_bits(),
            p.y[n - 1].to_bits()
        );
        assert_eq!(cur.seg, n - 2, "at-last-knot query parks on last seg");
        assert_eq!(
            p.eval_monotone(1e12, &mut cur).to_bits(),
            p.y[n - 1].to_bits()
        );
        // interior knots hit exactly must match eval bit-for-bit too
        let mut fresh = PchipCursor::default();
        for &t in &p.x {
            assert_eq!(
                p.eval_monotone(t, &mut fresh).to_bits(),
                p.eval(t).to_bits(),
                "knot t={t}"
            );
        }
    }

    #[test]
    fn single_segment_interpolant_and_table() {
        // two knots = one segment: the smallest legal Pchip; the cursor
        // has nowhere to walk and must still agree with eval everywhere
        let p = Pchip::new(vec![2.0, 4.0], vec![10.0, 20.0]).unwrap();
        let mut cur = PchipCursor::default();
        for i in 0..=60 {
            let t = 1.0 + i as f64 * 0.1; // sweeps below, across, above
            assert_eq!(
                p.eval_monotone(t, &mut cur).to_bits(),
                p.eval(t).to_bits(),
                "t={t}"
            );
            assert_eq!(cur.seg, 0, "only one segment exists");
        }
        // midpoint of linear data stays linear
        assert!((p.eval(3.0) - 15.0).abs() < 1e-12);

        // a one-cell table: every query clamps onto the single value
        let single = PchipTable::build(&p, 2.0, 1.0, 1);
        assert_eq!(single.len(), 1);
        for t in [-1e9, 2.0, 2.5, 1e9] {
            assert_eq!(single.at(t).to_bits(), p.eval(2.0).to_bits());
        }
        // an empty table reports NaN rather than indexing out of range
        let empty = PchipTable::build(&p, 2.0, 1.0, 0);
        assert!(empty.is_empty());
        assert!(empty.at(2.0).is_nan());
    }

    #[test]
    fn eval_many_matches_per_point_eval_and_clamps() {
        let p = wiggly();
        let ts: Vec<f64> =
            (0..200).map(|i| -1.0 + i as f64 * 0.08).collect();
        let batch = p.eval_many(&ts);
        assert_eq!(batch.len(), ts.len());
        for (t, got) in ts.iter().zip(&batch) {
            assert_eq!(got.to_bits(), p.eval(*t).to_bits(), "t={t}");
        }
        // out-of-range clamps flat on both ends
        let ends = p.eval_many(&[-100.0, 1e9]);
        assert_eq!(ends[0], 1.0);
        assert_eq!(ends[1], 6.5);
    }

    #[test]
    fn table_eval_many_matches_at_and_cursor_paths() {
        let p = wiggly();
        let table = PchipTable::build(&p, 0.0, 0.5, 25);
        // a deliberately unsorted query mix: interior cells, exact cell
        // edges, both clamp ends
        let ts: Vec<f64> = vec![
            3.3, -4.0, 0.0, 12.0, 0.5, 11.99, 1e9, 6.25, -0.0001, 7.5,
        ];
        let mut out = Vec::new();
        table.eval_many(&ts, &mut out);
        assert_eq!(out.len(), ts.len());
        for (t, got) in ts.iter().zip(&out) {
            assert_eq!(got.to_bits(), table.at(*t).to_bits(), "t={t}");
        }
        // the buffer is reused: a second, shorter batch must clear first
        table.eval_many(&[2.0], &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].to_bits(), table.at(2.0).to_bits());
        // on grid points the table equals the cursor-driven interpolant,
        // so eval_many agrees with eval_monotone there too
        let grid: Vec<f64> = (0..25).map(|i| i as f64 * 0.5).collect();
        table.eval_many(&grid, &mut out);
        let mut cur = PchipCursor::default();
        for (t, got) in grid.iter().zip(&out) {
            assert_eq!(
                got.to_bits(),
                p.eval_monotone(*t, &mut cur).to_bits(),
                "grid t={t}"
            );
        }
    }

    #[test]
    fn table_eval_many_empty_and_single_cell() {
        let p = Pchip::new(vec![2.0, 4.0], vec![10.0, 20.0]).unwrap();
        let mut out = vec![99.0; 4]; // stale contents must be discarded
        let empty = PchipTable::build(&p, 2.0, 1.0, 0);
        empty.eval_many(&[0.0, 2.0, 1e9], &mut out);
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|v| v.is_nan()));
        let single = PchipTable::build(&p, 2.0, 1.0, 1);
        single.eval_many(&[-1e9, 2.0, 2.5, 1e9], &mut out);
        assert_eq!(out.len(), 4);
        for v in &out {
            assert_eq!(v.to_bits(), single.at(2.0).to_bits());
        }
    }

    #[test]
    fn grid_cell_clamps_both_ends() {
        assert_eq!(grid_cell(0.0, 1.0, 10, -5.0), 0);
        assert_eq!(grid_cell(0.0, 1.0, 10, 0.0), 0);
        assert_eq!(grid_cell(0.0, 1.0, 10, 3.7), 3);
        assert_eq!(grid_cell(0.0, 1.0, 10, 9.0), 9);
        assert_eq!(grid_cell(0.0, 1.0, 10, 1e12), 9);
        assert_eq!(grid_cell(100.0, 600.0, 3, 100.0 + 1200.0), 2);
    }

    #[test]
    fn table_matches_resample_and_clamps() {
        let p = wiggly();
        let table = PchipTable::build(&p, 0.0, 0.5, 25);
        assert_eq!(table.len(), 25);
        assert!(!table.is_empty());
        let direct = p.resample(0.0, 0.5, 25);
        assert_eq!(table.values(), &direct[..]);
        // floor-cell lookups, clamped outside the grid
        assert_eq!(table.at(0.6).to_bits(), direct[1].to_bits());
        assert_eq!(table.at(-5.0).to_bits(), direct[0].to_bits());
        assert_eq!(table.at(1e6).to_bits(), direct[24].to_bits());
        assert_eq!(table.into_values(), direct);
    }
}
