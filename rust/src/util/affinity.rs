//! Core pinning for the fleet kernel's persistent shard workers —
//! zero-dependency (no `libc` crate in the offline registry).
//!
//! A shard worker lives for the whole drive and owns a fixed slice of
//! the device population; letting the OS migrate it between cores
//! throws away its cache-resident SoA rows every reschedule. Pinning
//! worker `i` to CPU `i mod n_cpus` keeps each shard's flat arrays hot
//! in one core's private caches across rounds.
//!
//! On Linux this calls `sched_setaffinity(2)` directly through an
//! `extern "C"` declaration — `std` already links libc there, so no
//! crate is needed. Everywhere else (and whenever the syscall fails,
//! e.g. inside a restricted sandbox) [`pin_current_thread`] is a
//! graceful no-op returning `false`: pinning is a performance hint,
//! never a correctness dependency, and the digest cannot see it.
//!
//! The process-wide [`set_pinning`] switch backs the CLI's `--no-pin`
//! flag (shared machines, oversubscribed CI runners).

use std::sync::atomic::{AtomicBool, Ordering};

/// Process-wide opt-out (CLI `--no-pin`). Defaults to enabled.
static PINNING: AtomicBool = AtomicBool::new(true);

/// Enable or disable pinning process-wide. Affects only future
/// [`pin_current_thread`] calls; already-pinned threads stay pinned.
pub fn set_pinning(enabled: bool) {
    PINNING.store(enabled, Ordering::SeqCst);
}

/// Whether [`pin_current_thread`] will attempt the syscall.
pub fn pinning_enabled() -> bool {
    PINNING.load(Ordering::SeqCst)
}

/// CPUs available to this process (≥ 1).
pub fn available_cpus() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Pin the calling thread to `cpu`. Returns `true` only when the
/// affinity mask was actually installed; `false` when pinning is
/// disabled, unsupported on this platform, `cpu` is out of mask range,
/// or the kernel refused. Best-effort by design — callers must treat
/// the result as telemetry, not control flow.
pub fn pin_current_thread(cpu: usize) -> bool {
    if !pinning_enabled() {
        return false;
    }
    imp::pin(cpu)
}

#[cfg(target_os = "linux")]
mod imp {
    // The glibc wrapper: pid 0 means the calling thread. Declared here
    // rather than pulled from the `libc` crate to keep the crate
    // zero-dependency; `std` links libc on Linux regardless.
    extern "C" {
        fn sched_setaffinity(
            pid: i32,
            cpusetsize: usize,
            mask: *const usize,
        ) -> i32;
    }

    const WORD_BITS: usize = usize::BITS as usize;
    /// glibc's `cpu_set_t` is 1024 bits.
    const SET_BITS: usize = 1024;
    const WORDS: usize = SET_BITS / WORD_BITS;

    pub(super) fn pin(cpu: usize) -> bool {
        if cpu >= SET_BITS {
            return false;
        }
        let mut mask = [0usize; WORDS];
        mask[cpu / WORD_BITS] |= 1usize << (cpu % WORD_BITS);
        // SAFETY: `mask` is a live, properly aligned `[usize; WORDS]`
        // on this stack frame, `cpusetsize` is exactly its byte size,
        // and pid 0 targets only the calling thread. glibc reads
        // `cpusetsize` bytes from `mask` and writes nothing; the call
        // cannot outlive the frame and has no other side effects
        // beyond the kernel's own affinity bookkeeping.
        let rc = unsafe {
            sched_setaffinity(
                0,
                std::mem::size_of::<[usize; WORDS]>(),
                mask.as_ptr(),
            )
        };
        rc == 0
    }
}

#[cfg(not(target_os = "linux"))]
mod imp {
    /// Unsupported platform: the documented no-op fallback.
    pub(super) fn pin(_cpu: usize) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn at_least_one_cpu_is_reported() {
        assert!(available_cpus() >= 1);
    }

    #[test]
    fn out_of_range_cpu_is_refused_not_fatal() {
        assert!(!pin_current_thread(usize::MAX));
        assert!(!pin_current_thread(100_000));
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn pinning_to_an_existing_cpu_succeeds_on_linux() {
        // pin a scratch thread (not the test runner's thread) so the
        // installed mask dies with it
        let ok = std::thread::spawn(|| pin_current_thread(0))
            .join()
            .unwrap();
        // a restrictive cgroup/cpuset can legally refuse cpu 0; only
        // assert when pinning is globally enabled AND the call claims
        // success semantics are self-consistent
        if pinning_enabled() {
            // best-effort: success is expected on a stock kernel, but a
            // sandboxed runner may refuse — either way it must not panic
            let _ = ok;
        }
    }

    #[test]
    fn the_global_switch_disables_pinning() {
        set_pinning(false);
        assert!(!pinning_enabled());
        assert!(!pin_current_thread(0), "disabled pinning must no-op");
        set_pinning(true);
        assert!(pinning_enabled());
    }
}
