//! Mini property-test harness (proptest is not in the offline crate set).
//!
//! `check(n, |rng| ...)` runs a property closure against `n` seeded random
//! inputs; on failure it reruns the failing seed with a clear message so
//! the case reproduces deterministically. Properties return
//! `Result<(), String>` so assertions can carry diagnostics.

use super::rng::Rng;

/// Outcome of one property case.
pub type Prop = Result<(), String>;

/// Run `cases` random cases of `prop`, each with a deterministically
/// derived RNG. Panics with the offending seed on first failure.
pub fn check<F>(cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Prop,
{
    check_seeded(0xC0FFEE, cases, &mut prop);
}

/// Same, with an explicit base seed (used to reproduce failures).
pub fn check_seeded<F>(base_seed: u64, cases: u64, prop: &mut F)
where
    F: FnMut(&mut Rng) -> Prop,
{
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property failed on case {case} (reproduce with \
                 check_seeded({base_seed:#x}, ...) case {case}): {msg}"
            );
        }
    }
}

/// Assert helper producing `Prop`-style errors.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(50, |rng| {
            count += 1;
            let x = rng.f64();
            prop_assert!((0.0..1.0).contains(&x), "x out of range: {x}");
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(100, |rng| {
            let x = rng.f64();
            prop_assert!(x < 0.5, "x too big: {x}");
            Ok(())
        });
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        check(10, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        check(10, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
