//! Minimal JSON: parser + pretty writer (no serde in the offline set).
//!
//! Used for the artifact metadata emitted by `python/compile/aot.py`
//! (`artifacts/meta/*.json`), run configs, and report output. Object keys
//! preserve insertion order so emitted reports diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JsonError {
    Eof(usize),
    Unexpected(usize, char),
    BadNumber(usize),
    BadEscape(usize),
    Trailing(usize),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Eof(p) => {
                write!(f, "unexpected end of input at byte {p}")
            }
            JsonError::Unexpected(p, c) => {
                write!(f, "unexpected character '{c}' at byte {p}")
            }
            JsonError::BadNumber(p) => write!(f, "invalid number at byte {p}"),
            JsonError::BadEscape(p) => write!(f, "invalid escape at byte {p}"),
            JsonError::Trailing(p) => {
                write!(f, "trailing characters at byte {p}")
            }
        }
    }
}

impl std::error::Error for JsonError {}

impl Value {
    // -- accessors ----------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Required-field helpers that turn misses into crate errors.
    pub fn req(&self, key: &str) -> crate::Result<&Value> {
        self.get(key)
            .ok_or_else(|| crate::err!("missing json key '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> crate::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| crate::err!("json key '{key}' is not a number"))
    }

    pub fn req_usize(&self, key: &str) -> crate::Result<usize> {
        Ok(self.req_f64(key)? as usize)
    }

    pub fn req_str(&self, key: &str) -> crate::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| crate::err!("json key '{key}' is not a string"))
    }

    pub fn req_arr(&self, key: &str) -> crate::Result<&[Value]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| crate::err!("json key '{key}' is not an array"))
    }

    // -- builders ------------------------------------------------------------
    pub fn obj() -> Value {
        Value::Obj(Vec::new())
    }

    pub fn set(mut self, key: &str, v: impl Into<Value>) -> Value {
        if let Value::Obj(ref mut kv) = self {
            kv.push((key.to_string(), v.into()));
        }
        self
    }

    pub fn from_map(map: &BTreeMap<String, f64>) -> Value {
        Value::Obj(
            map.iter()
                .map(|(k, v)| (k.clone(), Value::Num(*v)))
                .collect(),
        )
    }
}

impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Num(v)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::Num(v as f64)
    }
}
impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Num(v as f64)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Str(v)
    }
}
impl From<Vec<Value>> for Value {
    fn from(v: Vec<Value>) -> Self {
        Value::Arr(v)
    }
}
impl From<Vec<f64>> for Value {
    fn from(v: Vec<f64>) -> Self {
        Value::Arr(v.into_iter().map(Value::Num).collect())
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

pub fn parse(src: &str) -> Result<Value, JsonError> {
    let b = src.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(b, &mut pos)?;
    skip_ws(b, &mut pos);
    if pos != b.len() {
        return Err(JsonError::Trailing(pos));
    }
    Ok(v)
}

pub fn parse_file(path: impl AsRef<std::path::Path>) -> crate::Result<Value> {
    let s = std::fs::read_to_string(path.as_ref()).map_err(|e| {
        crate::err!("reading {}: {e}", path.as_ref().display())
    })?;
    parse(&s).map_err(|e| {
        crate::err!("parsing {}: {e}", path.as_ref().display())
    })
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Value, JsonError> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err(JsonError::Eof(*pos));
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Value::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Value::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Value::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Value::Null),
        b'-' | b'0'..=b'9' => parse_num(b, pos),
        c => Err(JsonError::Unexpected(*pos, c as char)),
    }
}

fn parse_lit(
    b: &[u8],
    pos: &mut usize,
    lit: &str,
    v: Value,
) -> Result<Value, JsonError> {
    if b.len() - *pos >= lit.len() && &b[*pos..*pos + lit.len()] == lit.as_bytes()
    {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(JsonError::Unexpected(*pos, b[*pos] as char))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Value, JsonError> {
    let start = *pos;
    if b[*pos] == b'-' {
        *pos += 1;
    }
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Value::Num)
        .ok_or(JsonError::BadNumber(start))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, JsonError> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        if *pos >= b.len() {
            return Err(JsonError::Eof(*pos));
        }
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    return Err(JsonError::Eof(*pos));
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            return Err(JsonError::Eof(*pos));
                        }
                        let hex =
                            std::str::from_utf8(&b[*pos + 1..*pos + 5])
                                .map_err(|_| JsonError::BadEscape(*pos))?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| JsonError::BadEscape(*pos))?;
                        out.push(
                            char::from_u32(cp).unwrap_or('\u{fffd}'),
                        );
                        *pos += 4;
                    }
                    _ => return Err(JsonError::BadEscape(*pos)),
                }
                *pos += 1;
            }
            _ => {
                // copy one UTF-8 scalar
                let s = &b[*pos..];
                let len = utf8_len(s[0]);
                let chunk = std::str::from_utf8(&s[..len.min(s.len())])
                    .map_err(|_| JsonError::BadEscape(*pos))?;
                out.push_str(chunk);
                *pos += len;
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Value, JsonError> {
    *pos += 1; // '['
    let mut out = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Value::Arr(out));
    }
    loop {
        out.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        if *pos >= b.len() {
            return Err(JsonError::Eof(*pos));
        }
        match b[*pos] {
            b',' => *pos += 1,
            b']' => {
                *pos += 1;
                return Ok(Value::Arr(out));
            }
            c => return Err(JsonError::Unexpected(*pos, c as char)),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Value, JsonError> {
    *pos += 1; // '{'
    let mut out = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Value::Obj(out));
    }
    loop {
        skip_ws(b, pos);
        if *pos >= b.len() {
            return Err(JsonError::Eof(*pos));
        }
        if b[*pos] != b'"' {
            return Err(JsonError::Unexpected(*pos, b[*pos] as char));
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b':' {
            return Err(JsonError::Unexpected(
                *pos,
                if *pos < b.len() { b[*pos] as char } else { '?' },
            ));
        }
        *pos += 1;
        let v = parse_value(b, pos)?;
        out.push((key, v));
        skip_ws(b, pos);
        if *pos >= b.len() {
            return Err(JsonError::Eof(*pos));
        }
        match b[*pos] {
            b',' => *pos += 1,
            b'}' => {
                *pos += 1;
                return Ok(Value::Obj(out));
            }
            c => return Err(JsonError::Unexpected(*pos, c as char)),
        }
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write_value(self, f, 0, f.alternate())
    }
}

fn write_value(
    v: &Value,
    f: &mut fmt::Formatter<'_>,
    indent: usize,
    pretty: bool,
) -> fmt::Result {
    match v {
        Value::Null => write!(f, "null"),
        Value::Bool(b) => write!(f, "{b}"),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                write!(f, "{}", *n as i64)
            } else {
                write!(f, "{n}")
            }
        }
        Value::Str(s) => write_escaped(s, f),
        Value::Arr(items) => {
            if items.is_empty() {
                return write!(f, "[]");
            }
            write!(f, "[")?;
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    write!(f, ",")?;
                }
                if pretty {
                    write!(f, "\n{}", " ".repeat(indent + 1))?;
                }
                write_value(item, f, indent + 1, pretty)?;
            }
            if pretty {
                write!(f, "\n{}", " ".repeat(indent))?;
            }
            write!(f, "]")
        }
        Value::Obj(kv) => {
            if kv.is_empty() {
                return write!(f, "{{}}");
            }
            write!(f, "{{")?;
            for (i, (k, val)) in kv.iter().enumerate() {
                if i > 0 {
                    write!(f, ",")?;
                }
                if pretty {
                    write!(f, "\n{}", " ".repeat(indent + 1))?;
                }
                write_escaped(k, f)?;
                write!(f, ":")?;
                if pretty {
                    write!(f, " ")?;
                }
                write_value(val, f, indent + 1, pretty)?;
            }
            if pretty {
                write!(f, "\n{}", " ".repeat(indent))?;
            }
            write!(f, "}}")
        }
    }
}

fn write_escaped(s: &str, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Value::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = parse("\"héllo → 日本\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 日本"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{'a': 1}").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"x": 1, "y": [true, null, "s"], "z": {"w": 2.5}}"#;
        let v = parse(src).unwrap();
        let compact = format!("{v}");
        let pretty = format!("{v:#}");
        assert_eq!(parse(&compact).unwrap(), v);
        assert_eq!(parse(&pretty).unwrap(), v);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"zz": 1, "aa": 2}"#).unwrap();
        if let Value::Obj(kv) = &v {
            assert_eq!(kv[0].0, "zz");
            assert_eq!(kv[1].0, "aa");
        } else {
            panic!();
        }
    }

    #[test]
    fn builder_and_accessors() {
        let v = Value::obj()
            .set("name", "swan")
            .set("n", 3usize)
            .set("ok", true);
        assert_eq!(v.req_str("name").unwrap(), "swan");
        assert_eq!(v.req_usize("n").unwrap(), 3);
        assert!(v.req("missing").is_err());
    }

    #[test]
    fn reads_real_artifact_meta_if_present() {
        // integration-ish: if artifacts are built, our parser must read them
        let p = std::path::Path::new("artifacts/meta/resnet_s.json");
        if p.exists() {
            let v = parse_file(p).unwrap();
            assert_eq!(v.req_str("name").unwrap(), "resnet_s");
            assert!(v.req_usize("param_scalars").unwrap() > 10_000);
        }
    }
}
