//! Zero-dependency substrates.
//!
//! The offline crate registry has no `rand`, `serde`, `serde_json`,
//! `proptest` or `criterion`, so this module provides the small slices of
//! each that the rest of the crate needs: a seedable PRNG ([`rng`]), a
//! JSON parser/writer ([`json`]), the PCHIP monotone-cubic interpolator
//! the paper's trace pipeline uses ([`pchip`]), summary statistics
//! ([`stats`]), a randomized property-test harness ([`check`]), a
//! wall-clock bench harness ([`bench`]), table/CSV emitters
//! ([`table`]), the FNV-1a determinism-digest fold ([`fnv`]) and a
//! zero-dependency `sched_setaffinity` wrapper for core-pinning the
//! fleet kernel's shard workers ([`affinity`]).

pub mod affinity;
pub mod bench;
pub mod check;
pub mod fnv;
pub mod json;
pub mod pchip;
pub mod rng;
pub mod stats;
pub mod table;
