//! Wall-clock bench harness (criterion is not in the offline crate set).
//!
//! Each `rust/benches/*.rs` binary (`harness = false`) builds a
//! [`BenchSet`], times its closures with warmup + repeated measurement,
//! and prints both human-readable rows and machine-readable CSV. The
//! paper-table benches additionally emit their table rows through
//! `crate::report`.

use std::time::Instant;

use super::stats;

/// One timed measurement series.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// seconds per iteration, one entry per sample
    pub samples: Vec<f64>,
}

impl Measurement {
    /// Wrap externally collected samples so they flow through the same
    /// percentile/CSV reporting as timed closures.
    pub fn from_samples(name: &str, samples: Vec<f64>) -> Measurement {
        Measurement {
            name: name.to_string(),
            samples,
        }
    }

    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples)
    }

    pub fn std(&self) -> f64 {
        stats::std(&self.samples)
    }

    pub fn p50(&self) -> f64 {
        stats::percentile(&self.samples, 50.0)
    }

    /// Tail latency: the 90th-percentile sample.
    pub fn p90(&self) -> f64 {
        stats::percentile(&self.samples, 90.0)
    }

    pub fn min(&self) -> f64 {
        stats::min(&self.samples)
    }

    /// Throughput for a measurement whose iteration processes `events`
    /// items: events per mean-iteration second.
    /// `benches/fleet_throughput.rs` reports devices-stepped/sec
    /// through this.
    pub fn per_sec(&self, events: f64) -> f64 {
        let m = self.mean();
        if m > 0.0 {
            events / m
        } else {
            0.0
        }
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

pub struct BenchSet {
    pub title: String,
    pub results: Vec<Measurement>,
    warmup_iters: u32,
    sample_count: u32,
}

impl BenchSet {
    pub fn new(title: &str) -> Self {
        println!("\n=== bench: {title} ===");
        BenchSet {
            title: title.to_string(),
            results: Vec::new(),
            warmup_iters: 2,
            sample_count: 10,
        }
    }

    pub fn with_samples(mut self, warmup: u32, samples: u32) -> Self {
        self.warmup_iters = warmup;
        self.sample_count = samples;
        self
    }

    /// Time `f` (one call = one iteration).
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Measurement {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.sample_count as usize);
        for _ in 0..self.sample_count {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let m = Measurement {
            name: name.to_string(),
            samples,
        };
        println!(
            "{:40} {:>12} ± {:>10}  (min {}, p90 {})",
            m.name,
            fmt_secs(m.mean()),
            fmt_secs(m.std()),
            fmt_secs(m.min()),
            fmt_secs(m.p90()),
        );
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Record an externally produced metric (e.g. simulated seconds) so
    /// non-wall-clock results flow through the same reporting.
    pub fn record(&mut self, name: &str, value: f64, unit: &str) {
        println!("{:40} {value:>12.4} {unit}", name);
        self.results.push(Measurement {
            name: format!("{name} [{unit}]"),
            samples: vec![value],
        });
    }

    /// Dump CSV (name, mean_s, std_s, min_s, p90_s) to `target/bench_csv/`.
    pub fn write_csv(&self) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("target/bench_csv");
        std::fs::create_dir_all(dir)?;
        let safe: String = self
            .title
            .chars()
            .map(|c| if c.is_alphanumeric() { c } else { '_' })
            .collect();
        let path = dir.join(format!("{safe}.csv"));
        let mut out = String::from("name,mean_s,std_s,min_s,p90_s\n");
        for m in &self.results {
            out.push_str(&format!(
                "{},{},{},{},{}\n",
                m.name.replace(',', ";"),
                m.mean(),
                m.std(),
                m.min(),
                m.p90()
            ));
        }
        std::fs::write(&path, out)?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_samples() {
        let mut set = BenchSet::new("test").with_samples(1, 5);
        let mut n = 0u64;
        set.bench("noop-ish", || {
            n = n.wrapping_add(1);
            std::hint::black_box(n);
        });
        assert_eq!(set.results.len(), 1);
        assert_eq!(set.results[0].samples.len(), 5);
        assert!(set.results[0].mean() >= 0.0);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_secs(2.0).ends_with(" s"));
        assert!(fmt_secs(2e-3).ends_with(" ms"));
        assert!(fmt_secs(2e-6).ends_with(" µs"));
        assert!(fmt_secs(2e-9).ends_with(" ns"));
    }

    #[test]
    fn record_external_metric() {
        let mut set = BenchSet::new("test2");
        set.record("simulated_latency", 1.25, "s(sim)");
        assert_eq!(set.results[0].samples, vec![1.25]);
    }

    #[test]
    fn p90_and_throughput() {
        let m = Measurement {
            name: "t".to_string(),
            samples: (1..=10).map(|i| i as f64).collect(),
        };
        assert!((m.p90() - 9.1).abs() < 1e-9, "p90={}", m.p90());
        // mean is 5.5 s/iter; 11 events per iter → 2 events/s
        assert!((m.per_sec(11.0) - 2.0).abs() < 1e-12);
        let empty = Measurement {
            name: "e".to_string(),
            samples: vec![],
        };
        assert_eq!(empty.per_sec(100.0), 0.0);
    }

    #[test]
    fn empty_measurement_stays_finite() {
        // a bench that never sampled must not write inf/NaN into the
        // CSV or JSON snapshots
        let m = Measurement::from_samples("empty", vec![]);
        assert_eq!(m.mean(), 0.0);
        assert_eq!(m.std(), 0.0);
        assert_eq!(m.p50(), 0.0);
        assert_eq!(m.p90(), 0.0);
        assert_eq!(m.min(), 0.0);
        for v in [m.mean(), m.std(), m.p50(), m.p90(), m.min()] {
            assert!(v.is_finite());
        }
    }
}
