//! Stub of the `xla` PJRT bindings.
//!
//! The offline crate set does not carry the real `xla` crate, but the
//! [`runtime`](crate::runtime) layer is written against its API so the
//! code drops onto the real bindings unchanged when they are available.
//! This module provides the same surface with no backend: building a
//! client fails with a clear message, so every artifact-driven path
//! (integration tests, numerics benches, examples) degrades to an error
//! or a skip, while the whole simulator/fleet stack — which never touches
//! PJRT — runs at full fidelity.
//!
//! Kept deliberately dependency-free and small: types are unconstructible
//! outside a successful `PjRtClient::cpu()`, so the unreachable methods
//! only need to typecheck.

use std::fmt;

/// Error type mirroring the binding's displayable errors.
#[derive(Clone, Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable() -> XlaError {
    XlaError(
        "the `xla` PJRT bindings are not present in this build; \
         runtime numerics are unavailable (simulator-only mode)"
            .to_string(),
    )
}

/// Scalar element types a [`Literal`] can be read as.
pub trait NativeType: Copy + Default {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Cheap cloneable handle to the (absent) PJRT CPU client.
#[derive(Clone)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self, XlaError> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable())
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, XlaError> {
        Err(unavailable())
    }
}

/// Parsed HLO module (text form).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(
        path: impl AsRef<std::path::Path>,
    ) -> Result<Self, XlaError> {
        // Read the file so missing-artifact errors surface as such even
        // in stub builds (the caller's error message names the path).
        match std::fs::read_to_string(path.as_ref()) {
            Ok(_) => Err(unavailable()),
            Err(e) => Err(XlaError(e.to_string())),
        }
    }
}

/// An HLO computation ready to compile.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute on device buffers; outputs per replica.
    pub fn execute_b(
        &self,
        _args: &[&PjRtBuffer],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable())
    }
}

/// A device-resident tensor.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable())
    }
}

/// A host-resident tensor value.
pub struct Literal(());

impl Literal {
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        Err(unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        Err(unavailable())
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal), XlaError> {
        Err(unavailable())
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T, XlaError> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().err().expect("stub must not build");
        assert!(e.to_string().contains("not present"), "{e}");
    }

    #[test]
    fn hlo_text_load_reports_missing_file() {
        let e = HloModuleProto::from_text_file("/nonexistent/x.hlo.txt")
            .err()
            .unwrap();
        // missing-file error, not the generic stub message
        assert!(!e.to_string().contains("simulator-only"), "{e}");
    }
}
