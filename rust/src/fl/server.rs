//! FedAvg aggregation (§5.1: "We use the Fed-Avg averaging algorithm to
//! combine model updates").

/// Weighted average of client parameter sets.
///
/// `updates` pairs each client's full parameter list (leaf-major, same
/// order as the metadata) with its sample-count weight. Returns the
/// aggregated parameter list, or an error on an empty/degenerate input
/// — aggregation runs inside the serve coordinator's round machinery,
/// where a panic would poison the round lock instead of surfacing
/// through `error.rs`.
pub fn fedavg(
    updates: &[(Vec<Vec<f32>>, f64)],
) -> crate::Result<Vec<Vec<f32>>> {
    crate::ensure!(!updates.is_empty(), "fedavg over zero clients");
    let total_w: f64 = updates.iter().map(|(_, w)| *w).sum();
    crate::ensure!(total_w > 0.0, "fedavg over zero total weight");
    let n_leaves = updates[0].0.len();
    let mut out: Vec<Vec<f32>> = updates[0]
        .0
        .iter()
        .map(|leaf| vec![0.0f32; leaf.len()])
        .collect();
    for (params, w) in updates {
        crate::ensure!(
            params.len() == n_leaves,
            "leaf count mismatch: {} vs {n_leaves}",
            params.len()
        );
        let scale = (w / total_w) as f32;
        for (acc, leaf) in out.iter_mut().zip(params) {
            crate::ensure!(
                acc.len() == leaf.len(),
                "leaf shape mismatch: {} vs {}",
                leaf.len(),
                acc.len()
            );
            for (a, v) in acc.iter_mut().zip(leaf) {
                *a += scale * v;
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_weights_is_mean() {
        let a = vec![vec![1.0f32, 2.0], vec![10.0]];
        let b = vec![vec![3.0f32, 6.0], vec![30.0]];
        let avg = fedavg(&[(a, 1.0), (b, 1.0)]).unwrap();
        assert_eq!(avg, vec![vec![2.0, 4.0], vec![20.0]]);
    }

    #[test]
    fn weights_respected() {
        let a = vec![vec![0.0f32]];
        let b = vec![vec![10.0f32]];
        let avg = fedavg(&[(a, 1.0), (b, 3.0)]).unwrap();
        assert!((avg[0][0] - 7.5).abs() < 1e-6);
    }

    #[test]
    fn single_client_identity() {
        let a = vec![vec![1.5f32, -2.5]];
        let avg = fedavg(&[(a.clone(), 123.0)]).unwrap();
        assert_eq!(avg, a);
    }

    #[test]
    fn empty_is_an_error_not_a_panic() {
        let err = fedavg(&[]).unwrap_err();
        assert!(err.to_string().contains("zero clients"), "{err}");
    }

    #[test]
    fn zero_weight_is_an_error() {
        let a = vec![vec![1.0f32]];
        let err = fedavg(&[(a, 0.0)]).unwrap_err();
        assert!(err.to_string().contains("zero total weight"), "{err}");
    }

    #[test]
    fn mismatched_leaves_error() {
        let a = vec![vec![1.0f32], vec![2.0]];
        let b = vec![vec![1.0f32]];
        assert!(fedavg(&[(a, 1.0), (b, 1.0)]).is_err());
        let c = vec![vec![1.0f32, 2.0]];
        let d = vec![vec![1.0f32]];
        assert!(fedavg(&[(c, 1.0), (d, 1.0)]).is_err());
    }
}
