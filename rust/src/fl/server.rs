//! FedAvg aggregation (§5.1: "We use the Fed-Avg averaging algorithm to
//! combine model updates").

/// Weighted average of client parameter sets.
///
/// `updates` pairs each client's full parameter list (leaf-major, same
/// order as the metadata) with its sample-count weight. Returns the
/// aggregated parameter list.
pub fn fedavg(updates: &[(Vec<Vec<f32>>, f64)]) -> Vec<Vec<f32>> {
    assert!(!updates.is_empty(), "fedavg over zero clients");
    let total_w: f64 = updates.iter().map(|(_, w)| *w).sum();
    assert!(total_w > 0.0, "zero total weight");
    let n_leaves = updates[0].0.len();
    let mut out: Vec<Vec<f32>> = updates[0]
        .0
        .iter()
        .map(|leaf| vec![0.0f32; leaf.len()])
        .collect();
    for (params, w) in updates {
        assert_eq!(params.len(), n_leaves, "leaf count mismatch");
        let scale = (w / total_w) as f32;
        for (acc, leaf) in out.iter_mut().zip(params) {
            assert_eq!(acc.len(), leaf.len(), "leaf shape mismatch");
            for (a, v) in acc.iter_mut().zip(leaf) {
                *a += scale * v;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_weights_is_mean() {
        let a = vec![vec![1.0f32, 2.0], vec![10.0]];
        let b = vec![vec![3.0f32, 6.0], vec![30.0]];
        let avg = fedavg(&[(a, 1.0), (b, 1.0)]);
        assert_eq!(avg, vec![vec![2.0, 4.0], vec![20.0]]);
    }

    #[test]
    fn weights_respected() {
        let a = vec![vec![0.0f32]];
        let b = vec![vec![10.0f32]];
        let avg = fedavg(&[(a, 1.0), (b, 3.0)]);
        assert!((avg[0][0] - 7.5).abs() < 1e-6);
    }

    #[test]
    fn single_client_identity() {
        let a = vec![vec![1.5f32, -2.5]];
        let avg = fedavg(&[(a.clone(), 123.0)]);
        assert_eq!(avg, a);
    }

    #[test]
    #[should_panic(expected = "zero clients")]
    fn empty_panics() {
        fedavg(&[]);
    }
}
