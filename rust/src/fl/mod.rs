//! Federated-learning simulation (§5.3): FedAvg over trace-driven
//! clients, with the paper's energy-loan availability model.
//!
//! Numerics are real — every selected client runs actual SGD steps
//! from the current global model through a [`engine`] backend (the
//! PJRT executor or the zero-dependency softmax probe) — while
//! per-client time and energy come from the SoC simulator under the
//! client's policy (Swan vs greedy baseline). Time-to-accuracy is
//! measured on the virtual clock, exactly like the paper's FedScale
//! emulation.
//!
//! [`engine`] is the ONE round state machine behind every wiring:
//! `run_direct` (the in-process bit-exactness oracle) and `run_serve`
//! (real SGD routed through the `serve` coordinator over in-process or
//! TCP lanes) must produce bit-identical final weights and digests.

pub mod availability;
pub mod energy_loan;
pub mod engine;
pub mod selection;
pub mod server;
pub mod sim;

pub use availability::FlClient;
pub use energy_loan::EnergyLoan;
pub use engine::{
    run_direct, run_serve, serve_config, step_order, ClientLanes,
};
pub use selection::{select_uniform, select_uniform_into};
pub use server::fedavg;
pub use sim::{FlArm, FlConfig, FlOutcome, FlSim};
