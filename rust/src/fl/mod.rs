//! Federated-learning simulation (§5.3): FedAvg over trace-driven
//! clients, with the paper's energy-loan availability model.
//!
//! Numerics are real — every selected client runs actual SGD steps
//! through the PJRT executor from the current global model — while
//! per-client time and energy come from the SoC simulator under the
//! client's policy (Swan vs greedy baseline). Time-to-accuracy is
//! measured on the virtual clock, exactly like the paper's FedScale
//! emulation.

pub mod availability;
pub mod energy_loan;
pub mod selection;
pub mod server;
pub mod sim;

pub use availability::FlClient;
pub use energy_loan::EnergyLoan;
pub use selection::{select_uniform, select_uniform_into};
pub use server::fedavg;
pub use sim::{FlArm, FlConfig, FlOutcome, FlSim};
