//! The end-to-end FL simulation (§5.3, Figs 5–7, Table 4).
//!
//! Per round, on the virtual clock:
//! 1. tick every client's trace/energy-loan → the **online set**
//!    (Figs 5b/6b/7b series);
//! 2. uniformly select K participants;
//! 3. each participant pulls the global model, runs `local_steps` REAL
//!    SGD steps through the PJRT executor on its own non-IID partition,
//!    and pays the simulated time/energy of its policy's execution
//!    choice (Swan: best pruned choice, coordinator-amortized
//!    exploration per §4.2; baseline: PyTorch greedy);
//! 4. FedAvg; the round costs `max` participant time (synchronous FL,
//!    stragglers pace the round, as in FedScale);
//! 5. periodically evaluate the global model on held-out batches →
//!    accuracy-vs-time curve (Figs 5a/6a/7a).
//!
//! The systems-only path (`run_systems_only*`) delegates its round
//! scheduling to `fleet::ShardedEventLoop`, the same kernel the fleet
//! CLI and bench drive at 100k–1M devices; the numerics path keeps its
//! serial loop because the PJRT executor is not thread-safe.

use crate::fleet::coordinator::{
    FleetPolicy, ProfileCoordinator, ResolvedCost, StepCost,
};
use crate::fleet::engine::{DriveConfig, ShardedEventLoop};
use crate::runtime::ModelExecutor;
use crate::soc::device::{all_devices, Device, DeviceId};
use crate::trace::augment::augment_shifts;
use crate::train::data::SyntheticDataset;
use crate::train::metrics::LossCurve;
use crate::train::softmax::{ExecutorSgd, LocalSgd};
use crate::util::rng::Rng;
use crate::workload::Workload;
use crate::Result;

use super::availability::FlClient;
use super::engine::{run_direct, ClientLanes};

/// Which policy the fleet runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlArm {
    Swan,
    Baseline,
}

impl FlArm {
    pub fn name(&self) -> &'static str {
        match self {
            FlArm::Swan => "swan",
            FlArm::Baseline => "baseline",
        }
    }
}

#[derive(Clone, Debug)]
pub struct FlConfig {
    pub seed: u64,
    /// Raw traces to synthesize before filtering (paper: 300k → 100).
    pub raw_traces: usize,
    /// Quality traces to keep (paper: 100). Each becomes 24 clients.
    pub quality_traces: usize,
    /// Participants per round.
    pub clients_per_round: usize,
    /// Local SGD steps per participant per round.
    pub local_steps: usize,
    pub rounds: usize,
    /// Evaluate the global model every this many rounds.
    pub eval_every: usize,
    /// Held-out eval batches per evaluation.
    pub eval_batches: usize,
    /// Charger credit available to FL, joules/day (§5.1 fixed budget).
    pub daily_credit_j: f64,
    /// Server-side per-round overhead, seconds.
    pub server_overhead_s: f64,
}

impl Default for FlConfig {
    fn default() -> Self {
        FlConfig {
            seed: 0,
            raw_traces: 12,
            quality_traces: 8,
            clients_per_round: 5,
            local_steps: 5,
            rounds: 40,
            eval_every: 2,
            eval_batches: 4,
            daily_credit_j: 3_000.0,
            server_overhead_s: 0.5,
        }
    }
}

/// Everything the paper reports about one FL run.
#[derive(Clone, Debug, Default)]
pub struct FlOutcome {
    pub arm: &'static str,
    /// (virtual seconds, eval accuracy) — Figs 5a/6a/7a.
    pub accuracy_curve: LossCurve,
    /// (virtual seconds, eval loss).
    pub loss_curve: LossCurve,
    /// (round, #online) — Figs 5b/6b/7b.
    pub online_per_round: Vec<(usize, usize)>,
    /// Total FL energy borrowed across the fleet, joules.
    pub total_energy_j: f64,
    /// Total virtual time, seconds.
    pub total_time_s: f64,
    pub rounds_run: usize,
    /// Parity digest over the round stream (`serve-<16 hex>`): the
    /// exact field sequence the serve coordinator folds, so a direct
    /// run and a serve-routed run of the same config must report one
    /// digest. Empty for the systems-only paths.
    pub digest: String,
    /// Final global model (flat f32). Bit-identical across every
    /// wiring of the same run. Empty for the systems-only paths.
    pub final_model: Vec<f32>,
}

impl FlOutcome {
    /// Virtual time to reach `acc` (None if never).
    pub fn time_to_accuracy(&self, acc: f64) -> Option<f64> {
        self.accuracy_curve.time_to(acc, true)
    }

    /// Fleet energy spent by the time `acc` was reached (linear
    /// interpolation over the energy-vs-time record is overkill: we
    /// track energy at eval points).
    pub fn best_accuracy(&self) -> f64 {
        self.accuracy_curve.best(true).unwrap_or(0.0)
    }
}

/// Per-device-model step cost under each arm, computed once (the
/// coordinator amortizes exploration across same-model devices, §4.2).
/// Built through the fleet [`ProfileCoordinator`] so the FL harness and
/// the fleet kernel share one exploration/pruning path.
pub struct PolicyTable {
    /// device-model → (swan best-choice cost, greedy baseline cost)
    entries: Vec<(DeviceId, StepCost, StepCost)>,
}

impl PolicyTable {
    pub fn build(workload: &crate::workload::Workload) -> PolicyTable {
        let mut coord = ProfileCoordinator::new(workload.clone());
        let mut entries = Vec::new();
        for d in all_devices() {
            let swan = coord.resolve(d.id, 0, FlArm::Swan).cost;
            let greedy = coord.resolve(d.id, 0, FlArm::Baseline).cost;
            entries.push((d.id, swan, greedy));
        }
        PolicyTable { entries }
    }

    /// (step latency, step energy) for `device` under `arm`.
    pub fn step_cost(&self, device: &Device, arm: FlArm) -> (f64, f64) {
        self.step_cost_by_id(device.id, arm)
    }

    /// Same, by SoC model id (what the fleet kernel resolves by).
    pub fn step_cost_by_id(&self, id: DeviceId, arm: FlArm) -> (f64, f64) {
        let (_, swan, greedy) = self
            .entries
            .iter()
            .find(|(d, _, _)| *d == id)
            .expect("device in table");
        let c = match arm {
            FlArm::Swan => swan,
            FlArm::Baseline => greedy,
        };
        (c.latency_s, c.energy_j)
    }
}

/// The FL simulator for one (model, arm) pair.
pub struct FlSim {
    pub cfg: FlConfig,
    pub arm: FlArm,
    pub dataset: SyntheticDataset,
    pub clients: Vec<FlClient>,
    policy: PolicyTable,
    workload: Workload,
}

impl FlSim {
    /// Build the fleet: synthesize → filter → resample → augment traces
    /// (Appendix A), assign device models round-robin, partition data.
    pub fn new(
        cfg: FlConfig,
        arm: FlArm,
        dataset: SyntheticDataset,
        workload: &crate::workload::Workload,
    ) -> Result<FlSim> {
        let quality = crate::trace::synthesize_quality_pool(
            cfg.seed,
            cfg.quality_traces,
            cfg.raw_traces * 20,
        )?;
        crate::ensure!(
            quality.len() >= cfg.quality_traces.min(1),
            "no quality traces generated"
        );
        let augmented = augment_shifts(&quality);
        let devices = all_devices();
        let mut rng = Rng::new(cfg.seed ^ 0xF1);
        let clients = augmented
            .into_iter()
            .enumerate()
            .map(|(i, trace)| {
                let device = devices[i % devices.len()].clone();
                let partition = dataset.partition(i);
                // §5.1: daily charger budget unique per device
                let credit =
                    cfg.daily_credit_j * rng.range(0.6, 1.6);
                FlClient::new(i, device, trace, partition, credit)
            })
            .collect();
        let policy = PolicyTable::build(workload);
        Ok(FlSim {
            cfg,
            arm,
            dataset,
            clients,
            policy,
            workload: workload.clone(),
        })
    }

    /// Systems-only horizon: availability + energy-loan dynamics over
    /// many rounds WITHOUT numerics. Valid because client availability
    /// is independent of model values (selection is uniform; energy per
    /// participation depends only on device, policy and epoch size) —
    /// this is how Figs 5b/6b/7b's week-scale decline is reproduced
    /// without paying week-scale compute. Runs on the fleet kernel
    /// (single shard). A dead kernel shard surfaces as `Err`.
    pub fn run_systems_only(&mut self, rounds: usize) -> Result<FlOutcome> {
        self.run_systems_only_sharded(rounds, 1)
    }

    /// Same, with an explicit worker-shard count. The round scheduler is
    /// `fleet::ShardedEventLoop` — the one the fleet CLI/bench drive —
    /// so aggregates are bit-identical for any `n_shards`.
    pub fn run_systems_only_sharded(
        &mut self,
        rounds: usize,
        n_shards: usize,
    ) -> Result<FlOutcome> {
        struct TablePolicy<'a> {
            table: &'a PolicyTable,
            arm: FlArm,
        }
        impl FleetPolicy for TablePolicy<'_> {
            fn step_cost(
                &mut self,
                model: DeviceId,
                _requester: usize,
            ) -> ResolvedCost {
                let (latency_s, energy_j) =
                    self.table.step_cost_by_id(model, self.arm);
                ResolvedCost {
                    cost: StepCost {
                        latency_s,
                        energy_j,
                    },
                    ..Default::default()
                }
            }
        }

        let clients = std::mem::take(&mut self.clients);
        let mut engine = ShardedEventLoop::new(clients, n_shards);
        let cfg = DriveConfig {
            scenario: "fl-systems-only".to_string(),
            arm: self.arm,
            seed: self.cfg.seed,
            rounds,
            clients_per_round: self.cfg.clients_per_round,
            server_overhead_s: self.cfg.server_overhead_s,
            obs: crate::obs::Obs::off(),
        };
        let mut policy = TablePolicy {
            table: &self.policy,
            arm: self.arm,
        };
        let drive_result = engine.drive(&mut policy, &cfg);
        // recover the clients before reporting a drive error, so a
        // failed run doesn't also strand the simulator with an empty
        // population
        self.clients = engine.into_nodes()?;
        let out = drive_result?;
        Ok(FlOutcome {
            arm: self.arm.name(),
            online_per_round: out.online_per_round,
            total_energy_j: out.total_energy_j,
            total_time_s: out.total_time_s,
            rounds_run: out.rounds_run,
            ..Default::default()
        })
    }

    /// Run the configured number of rounds with real numerics through
    /// `exec` (the PJRT path). Delegates to the unified engine
    /// (`fl::engine::run_direct`) — the same round state machine the
    /// serve control plane replays — through the [`ExecutorSgd`]
    /// flat-model adapter. Returns the full outcome record.
    pub fn run(&mut self, exec: &ModelExecutor) -> Result<FlOutcome> {
        let backend = ExecutorSgd::new(exec, self.dataset.clone());
        self.run_with(&backend)
    }

    /// Run through any [`LocalSgd`] backend (e.g. the zero-dependency
    /// `SoftmaxProbe`, which needs no PJRT plugin). The engine
    /// decomposes the clients into SoA lanes, drives the unified round
    /// machine, and writes the mutated loan/participation state back.
    pub fn run_with<B: LocalSgd>(&mut self, backend: &B) -> Result<FlOutcome> {
        let mut lanes = ClientLanes::new(&self.clients, self.cfg.seed);
        let out = run_direct(
            &self.cfg,
            self.arm,
            &mut lanes,
            backend,
            &self.workload,
        )?;
        lanes.write_back(&mut self.clients);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{builtin, WorkloadName};

    #[test]
    fn policy_table_swan_never_slower_than_greedy() {
        // Swan picks the fastest explored choice; greedy is one of the
        // explored choices, so Swan's latency ≤ greedy's on every device
        for wl in [
            WorkloadName::Resnet34,
            WorkloadName::MobilenetV2,
            WorkloadName::ShufflenetV2,
        ] {
            let w = builtin(wl);
            let table = PolicyTable::build(&w);
            for d in all_devices() {
                let (swan_t, _) = table.step_cost(&d, FlArm::Swan);
                let (base_t, _) = table.step_cost(&d, FlArm::Baseline);
                assert!(
                    swan_t <= base_t * 1.0 + 1e-12,
                    "{:?} {:?}: swan {swan_t} > greedy {base_t}",
                    d.id,
                    wl
                );
            }
        }
    }

    #[test]
    fn policy_table_huge_wins_on_depthwise_models() {
        let w = builtin(WorkloadName::ShufflenetV2);
        let table = PolicyTable::build(&w);
        let s10e = crate::soc::device::device(crate::soc::device::DeviceId::S10e);
        let (swan_t, swan_e) = table.step_cost(&s10e, FlArm::Swan);
        let (base_t, base_e) = table.step_cost(&s10e, FlArm::Baseline);
        assert!(base_t / swan_t > 10.0, "speedup {}", base_t / swan_t);
        assert!(base_e / swan_e > 5.0, "energy eff {}", base_e / swan_e);
    }

    #[test]
    fn fleet_construction() {
        let cfg = FlConfig {
            raw_traces: 6,
            quality_traces: 2,
            ..Default::default()
        };
        let ds = SyntheticDataset::vision(1);
        let w = builtin(WorkloadName::ShufflenetV2);
        let sim = FlSim::new(cfg, FlArm::Swan, ds, &w).unwrap();
        assert_eq!(sim.clients.len(), 48); // 2 traces × 24 shifts
        // all five device models represented
        let kinds: std::collections::HashSet<_> =
            sim.clients.iter().map(|c| c.device.id).collect();
        assert_eq!(kinds.len(), 5);
    }

    // full run covered by rust/tests/fl_integration.rs (needs artifacts)
}
