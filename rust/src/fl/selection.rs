//! Participant selection: uniform over the online set (the paper uses
//! random selection; Oort-style guided selection is cited as related
//! work, not used).

use std::collections::HashMap;

use crate::util::rng::Rng;

/// Pick up to `k` distinct indices uniformly from `online`.
pub fn select_uniform(online: &[usize], k: usize, rng: &mut Rng) -> Vec<usize> {
    if online.len() <= k {
        return online.to_vec();
    }
    let picks = rng.sample_indices(online.len(), k);
    picks.into_iter().map(|i| online[i]).collect()
}

/// Exactly [`select_uniform`] — same RNG draw sequence, same picks in
/// the same order — but allocation-free at steady state: the virtual
/// Fisher–Yates array is kept sparse (only displaced slots live in
/// `scratch`), so a round costs O(k) instead of materializing an
/// O(online) index vector. The fleet kernel reuses `scratch`/`out`
/// across rounds.
pub fn select_uniform_into(
    online: &[usize],
    k: usize,
    rng: &mut Rng,
    scratch: &mut HashMap<usize, usize>,
    out: &mut Vec<usize>,
) {
    out.clear();
    if online.len() <= k {
        out.extend_from_slice(online);
        return;
    }
    scratch.clear();
    let n = online.len();
    for i in 0..k {
        // mirror `Rng::sample_indices`: j = i + index(n - i), swap(i, j).
        // position i is never revisited after iteration i (j >= i), so
        // its post-swap value is final and can be emitted immediately.
        let j = i + rng.index(n - i);
        let vi = scratch.get(&i).copied().unwrap_or(i);
        let vj = scratch.get(&j).copied().unwrap_or(j);
        scratch.insert(j, vi);
        out.push(online[vj]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn takes_all_when_few_online() {
        let mut rng = Rng::new(0);
        assert_eq!(select_uniform(&[3, 7], 5, &mut rng), vec![3, 7]);
    }

    #[test]
    fn selects_k_distinct_members() {
        let online: Vec<usize> = (100..200).collect();
        let mut rng = Rng::new(1);
        let sel = select_uniform(&online, 10, &mut rng);
        assert_eq!(sel.len(), 10);
        let mut s = sel.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
        assert!(sel.iter().all(|c| online.contains(c)));
    }

    #[test]
    fn sparse_selection_identical_to_dense() {
        // the SoA kernel's allocation-free path must replay the exact
        // picks (values AND order) of the PR 1 dense path
        let mut scratch = HashMap::new();
        let mut out = Vec::new();
        for seed in 0..20u64 {
            for (n, k) in [(5usize, 5usize), (10, 3), (100, 7), (997, 50)]
            {
                let online: Vec<usize> = (0..n).map(|i| i * 3 + 1).collect();
                let mut a = Rng::new(seed);
                let mut b = Rng::new(seed);
                let dense = select_uniform(&online, k, &mut a);
                select_uniform_into(
                    &online,
                    k,
                    &mut b,
                    &mut scratch,
                    &mut out,
                );
                assert_eq!(dense, out, "seed={seed} n={n} k={k}");
                // both paths must leave the RNG in the same state
                assert_eq!(a.next_u64(), b.next_u64());
            }
        }
    }

    #[test]
    fn sparse_selection_takes_all_when_few_online() {
        let mut scratch = HashMap::new();
        let mut out = vec![99, 98]; // stale content must be cleared
        let mut rng = Rng::new(0);
        select_uniform_into(&[3, 7], 5, &mut rng, &mut scratch, &mut out);
        assert_eq!(out, vec![3, 7]);
    }

    #[test]
    fn roughly_uniform_over_many_rounds() {
        let online: Vec<usize> = (0..50).collect();
        let mut rng = Rng::new(2);
        let mut counts = vec![0usize; 50];
        for _ in 0..2000 {
            for c in select_uniform(&online, 5, &mut rng) {
                counts[c] += 1;
            }
        }
        let min = *counts.iter().min().unwrap() as f64;
        let max = *counts.iter().max().unwrap() as f64;
        assert!(max / min < 1.6, "selection skew: {min}..{max}");
    }
}
