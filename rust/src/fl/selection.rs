//! Participant selection: uniform over the online set (the paper uses
//! random selection; Oort-style guided selection is cited as related
//! work, not used).

use crate::util::rng::Rng;

/// Pick up to `k` distinct indices uniformly from `online`.
pub fn select_uniform(online: &[usize], k: usize, rng: &mut Rng) -> Vec<usize> {
    if online.len() <= k {
        return online.to_vec();
    }
    let picks = rng.sample_indices(online.len(), k);
    picks.into_iter().map(|i| online[i]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn takes_all_when_few_online() {
        let mut rng = Rng::new(0);
        assert_eq!(select_uniform(&[3, 7], 5, &mut rng), vec![3, 7]);
    }

    #[test]
    fn selects_k_distinct_members() {
        let online: Vec<usize> = (100..200).collect();
        let mut rng = Rng::new(1);
        let sel = select_uniform(&online, 10, &mut rng);
        assert_eq!(sel.len(), 10);
        let mut s = sel.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
        assert!(sel.iter().all(|c| online.contains(c)));
    }

    #[test]
    fn roughly_uniform_over_many_rounds() {
        let online: Vec<usize> = (0..50).collect();
        let mut rng = Rng::new(2);
        let mut counts = vec![0usize; 50];
        for _ in 0..2000 {
            for c in select_uniform(&online, 5, &mut rng) {
                counts[c] += 1;
            }
        }
        let min = *counts.iter().min().unwrap() as f64;
        let max = *counts.iter().max().unwrap() as f64;
        assert!(max / min < 1.6, "selection skew: {min}..{max}");
    }
}
