//! The paper's "real-world energy budget" (§5.1): FL neither gets an
//! infinite energy budget nor a static one. Each device has a fixed
//! daily charger credit; FL's energy use is tracked as a *loan* that the
//! charger repays while the trace says the device charges. A device is
//! unavailable whenever reflecting the outstanding loan onto the traced
//! battery level would push it to the critical level.

#[derive(Clone, Debug)]
pub struct EnergyLoan {
    /// Battery capacity in joules (mAh × 3.6 × nominal V).
    pub capacity_j: f64,
    /// Outstanding FL energy debt, joules.
    pub loan_j: f64,
    /// Charger credit available to FL repayment, joules/day.
    pub daily_credit_j: f64,
    /// Critical battery level (fraction) below which the device dies.
    pub critical_level: f64,
    /// Cumulative FL energy ever borrowed (evaluation metric).
    pub total_borrowed_j: f64,
    last_update_s: f64,
}

impl EnergyLoan {
    pub fn new(capacity_mah: f64, daily_credit_j: f64) -> Self {
        let capacity_j = capacity_mah * 3.6 * 3.85; // nominal pack voltage
        EnergyLoan {
            capacity_j,
            loan_j: 0.0,
            daily_credit_j,
            critical_level: 0.10,
            total_borrowed_j: 0.0,
            last_update_s: 0.0,
        }
    }

    /// FL spends `j` joules on this device.
    pub fn borrow(&mut self, j: f64) {
        debug_assert!(j >= 0.0);
        self.loan_j += j;
        self.total_borrowed_j += j;
    }

    /// Advance to `now_s`; if the device is charging per its trace, the
    /// charger repays the loan at the daily-credit rate.
    pub fn tick(&mut self, now_s: f64, is_charging: bool) {
        let dt = (now_s - self.last_update_s).max(0.0);
        self.last_update_s = now_s;
        if is_charging && self.loan_j > 0.0 {
            let repay = self.daily_credit_j * dt / 86_400.0;
            self.loan_j = (self.loan_j - repay).max(0.0);
        }
    }

    /// Battery level (fraction) after reflecting the outstanding loan.
    pub fn effective_level(&self, traced_level_frac: f64) -> f64 {
        traced_level_frac - self.loan_j / self.capacity_j
    }

    /// §5.1: unavailable if the loan would push the battery critical.
    pub fn allows_participation(&self, traced_level_frac: f64) -> bool {
        self.effective_level(traced_level_frac) > self.critical_level
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn borrowing_reduces_effective_level() {
        let mut l = EnergyLoan::new(3000.0, 10_000.0);
        assert!(l.allows_participation(0.5));
        let half_pack = l.capacity_j / 2.0;
        l.borrow(half_pack);
        assert!((l.effective_level(0.5) - 0.0).abs() < 1e-9);
        assert!(!l.allows_participation(0.5));
        assert_eq!(l.total_borrowed_j, half_pack);
    }

    #[test]
    fn charging_repays_at_daily_rate() {
        let mut l = EnergyLoan::new(3000.0, 20_000.0);
        l.borrow(10_000.0);
        l.tick(0.0, true);
        l.tick(43_200.0, true); // half a day charging
        assert!((l.loan_j - 0.0).abs() < 1e-6, "loan {}", l.loan_j);
    }

    #[test]
    fn no_repayment_while_discharging() {
        let mut l = EnergyLoan::new(3000.0, 20_000.0);
        l.borrow(5_000.0);
        l.tick(0.0, false);
        l.tick(86_400.0, false);
        assert_eq!(l.loan_j, 5_000.0);
    }

    #[test]
    fn loan_never_negative() {
        let mut l = EnergyLoan::new(3000.0, 1e9);
        l.borrow(1.0);
        l.tick(0.0, true);
        l.tick(86_400.0, true);
        assert_eq!(l.loan_j, 0.0);
    }

    #[test]
    fn heavier_spender_dies_first() {
        // the Fig 5b/6b mechanism in miniature
        let mut cheap = EnergyLoan::new(3000.0, 5_000.0);
        let mut costly = EnergyLoan::new(3000.0, 5_000.0);
        let mut cheap_dead = None;
        let mut costly_dead = None;
        for day in 0..200 {
            let t = day as f64 * 86_400.0;
            cheap.tick(t, true);
            costly.tick(t, true);
            cheap.borrow(4_000.0);
            costly.borrow(30_000.0);
            if costly_dead.is_none() && !costly.allows_participation(0.6) {
                costly_dead = Some(day);
            }
            if cheap_dead.is_none() && !cheap.allows_participation(0.6) {
                cheap_dead = Some(day);
            }
        }
        assert!(costly_dead.is_some(), "heavy spender must exhaust budget");
        assert!(
            cheap_dead.is_none() || cheap_dead > costly_dead,
            "cheap {cheap_dead:?} vs costly {costly_dead:?}"
        );
    }
}
