//! The paper's "real-world energy budget" (§5.1): FL neither gets an
//! infinite energy budget nor a static one. Each device has a fixed
//! daily charger credit; FL's energy use is tracked as a *loan* that the
//! charger repays while the trace says the device charges. A device is
//! unavailable whenever reflecting the outstanding loan onto the traced
//! battery level would push it to the critical level.

#[derive(Clone, Debug)]
pub struct EnergyLoan {
    /// Battery capacity in joules (mAh × 3.6 × nominal V).
    pub capacity_j: f64,
    /// Outstanding FL energy debt, joules.
    pub loan_j: f64,
    /// Charger credit available to FL repayment, joules/day.
    pub daily_credit_j: f64,
    /// Critical battery level (fraction) below which the device dies.
    pub critical_level: f64,
    /// Cumulative FL energy ever borrowed (evaluation metric).
    pub total_borrowed_j: f64,
    last_update_s: f64,
}

impl EnergyLoan {
    pub fn new(capacity_mah: f64, daily_credit_j: f64) -> Self {
        let capacity_j = capacity_mah * 3.6 * 3.85; // nominal pack voltage
        EnergyLoan {
            capacity_j,
            loan_j: 0.0,
            daily_credit_j,
            critical_level: 0.10,
            total_borrowed_j: 0.0,
            last_update_s: 0.0,
        }
    }

    /// FL spends `j` joules on this device.
    pub fn borrow(&mut self, j: f64) {
        debug_assert!(j >= 0.0);
        self.loan_j += j;
        self.total_borrowed_j += j;
    }

    /// Advance to `now_s`; if the device is charging per its trace, the
    /// charger repays the loan at the daily-credit rate.
    pub fn tick(&mut self, now_s: f64, is_charging: bool) {
        let dt = (now_s - self.last_update_s).max(0.0);
        self.last_update_s = now_s;
        if is_charging && self.loan_j > 0.0 {
            let repay = self.daily_credit_j * dt / 86_400.0;
            self.loan_j = (self.loan_j - repay).max(0.0);
        }
    }

    /// Battery level (fraction) after reflecting the outstanding loan.
    pub fn effective_level(&self, traced_level_frac: f64) -> f64 {
        traced_level_frac - self.loan_j / self.capacity_j
    }

    /// §5.1: unavailable if the loan would push the battery critical.
    pub fn allows_participation(&self, traced_level_frac: f64) -> bool {
        self.effective_level(traced_level_frac) > self.critical_level
    }
}

/// Structure-of-arrays twin of [`EnergyLoan`] for the fleet kernel's
/// batch passes: one `Vec<f64>` per field so the per-round tick runs as
/// a straight-line loop over flat slices instead of chasing one struct
/// per device.
///
/// [`tick_all`](LoanBank::tick_all) is the SIMD-izable rewrite of
/// [`EnergyLoan::tick`]: the plan (`dt`, `repay`, clamped remainder) is
/// computed unconditionally and the charging branch becomes a select,
/// with no early-outs and no `&mut` aliasing between slices. This is
/// bit-identical to the scalar branch: when `loan_j == +0.0` and the
/// device is charging, `(0.0 - repay).max(0.0)` is `+0.0` — the same
/// bits the skipped branch would have left — and `loan_j` can never be
/// `-0.0` or NaN (borrow adds non-negative amounts to `+0.0`, and the
/// clamp floor is `+0.0`).
#[derive(Clone, Debug, Default)]
pub struct LoanBank {
    pub capacity_j: Vec<f64>,
    pub loan_j: Vec<f64>,
    pub daily_credit_j: Vec<f64>,
    pub critical_level: Vec<f64>,
    pub total_borrowed_j: Vec<f64>,
    last_update_s: Vec<f64>,
}

impl LoanBank {
    pub fn with_capacity(n: usize) -> Self {
        LoanBank {
            capacity_j: Vec::with_capacity(n),
            loan_j: Vec::with_capacity(n),
            daily_credit_j: Vec::with_capacity(n),
            critical_level: Vec::with_capacity(n),
            total_borrowed_j: Vec::with_capacity(n),
            last_update_s: Vec::with_capacity(n),
        }
    }

    pub fn len(&self) -> usize {
        self.loan_j.len()
    }

    pub fn is_empty(&self) -> bool {
        self.loan_j.is_empty()
    }

    /// Append a device's loan state (column-wise copy of `l`).
    pub fn push(&mut self, l: &EnergyLoan) {
        self.capacity_j.push(l.capacity_j);
        self.loan_j.push(l.loan_j);
        self.daily_credit_j.push(l.daily_credit_j);
        self.critical_level.push(l.critical_level);
        self.total_borrowed_j.push(l.total_borrowed_j);
        self.last_update_s.push(l.last_update_s);
    }

    /// Reassemble row `k` as a scalar [`EnergyLoan`] (round-trip path
    /// for `SoaFleet::into_devices`).
    pub fn get(&self, k: usize) -> EnergyLoan {
        EnergyLoan {
            capacity_j: self.capacity_j[k],
            loan_j: self.loan_j[k],
            daily_credit_j: self.daily_credit_j[k],
            critical_level: self.critical_level[k],
            total_borrowed_j: self.total_borrowed_j[k],
            last_update_s: self.last_update_s[k],
        }
    }

    /// Row-wise [`EnergyLoan::borrow`].
    pub fn borrow(&mut self, k: usize, j: f64) {
        debug_assert!(j >= 0.0);
        self.loan_j[k] += j;
        self.total_borrowed_j[k] += j;
    }

    /// Bank-wide [`EnergyLoan::tick`]: advance every row to `now_s`,
    /// repaying rows whose trace says they charge. Branch-free body
    /// (see the type docs for the bit-identity argument).
    pub fn tick_all(&mut self, now_s: f64, charging: &[bool]) {
        let n = self.len();
        debug_assert_eq!(charging.len(), n);
        let loan = &mut self.loan_j[..n];
        let last = &mut self.last_update_s[..n];
        let credit = &self.daily_credit_j[..n];
        let charging = &charging[..n];
        for k in 0..n {
            let dt = (now_s - last[k]).max(0.0);
            last[k] = now_s;
            let repay = credit[k] * dt / 86_400.0;
            let repaid = (loan[k] - repay).max(0.0);
            loan[k] = if charging[k] { repaid } else { loan[k] };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn borrowing_reduces_effective_level() {
        let mut l = EnergyLoan::new(3000.0, 10_000.0);
        assert!(l.allows_participation(0.5));
        let half_pack = l.capacity_j / 2.0;
        l.borrow(half_pack);
        assert!((l.effective_level(0.5) - 0.0).abs() < 1e-9);
        assert!(!l.allows_participation(0.5));
        assert_eq!(l.total_borrowed_j, half_pack);
    }

    #[test]
    fn charging_repays_at_daily_rate() {
        let mut l = EnergyLoan::new(3000.0, 20_000.0);
        l.borrow(10_000.0);
        l.tick(0.0, true);
        l.tick(43_200.0, true); // half a day charging
        assert!((l.loan_j - 0.0).abs() < 1e-6, "loan {}", l.loan_j);
    }

    #[test]
    fn no_repayment_while_discharging() {
        let mut l = EnergyLoan::new(3000.0, 20_000.0);
        l.borrow(5_000.0);
        l.tick(0.0, false);
        l.tick(86_400.0, false);
        assert_eq!(l.loan_j, 5_000.0);
    }

    #[test]
    fn loan_never_negative() {
        let mut l = EnergyLoan::new(3000.0, 1e9);
        l.borrow(1.0);
        l.tick(0.0, true);
        l.tick(86_400.0, true);
        assert_eq!(l.loan_j, 0.0);
    }

    #[test]
    fn bank_tick_all_bit_identical_to_scalar_tick() {
        use crate::util::rng::Rng;
        // random interleavings of tick/borrow across a mixed bank must
        // leave every field bit-identical to per-device scalar loans —
        // this is the contract the fleet kernel's batch pass rides
        let mut rng = Rng::new(0xBA_4C0FFEE);
        let mut scalars: Vec<EnergyLoan> = (0..64)
            .map(|i| {
                EnergyLoan::new(
                    1500.0 + 50.0 * i as f64,
                    rng.range(1_000.0, 30_000.0),
                )
            })
            .collect();
        let mut bank = LoanBank::with_capacity(scalars.len());
        for l in &scalars {
            bank.push(l);
        }
        let mut now = 0.0;
        let mut charging = vec![false; scalars.len()];
        for _ in 0..40 {
            now += rng.range(0.0, 20_000.0);
            for c in &mut charging {
                *c = rng.bool(0.5);
            }
            for (k, l) in scalars.iter_mut().enumerate() {
                l.tick(now, charging[k]);
            }
            bank.tick_all(now, &charging);
            // sprinkle borrows on a random subset, both representations
            for _ in 0..8 {
                let k = rng.index(scalars.len());
                let j = rng.range(0.0, 5_000.0);
                scalars[k].borrow(j);
                bank.borrow(k, j);
            }
        }
        for (k, l) in scalars.iter().enumerate() {
            let b = bank.get(k);
            assert_eq!(b.loan_j.to_bits(), l.loan_j.to_bits(), "row {k}");
            assert_eq!(
                b.total_borrowed_j.to_bits(),
                l.total_borrowed_j.to_bits()
            );
            assert_eq!(
                b.last_update_s.to_bits(),
                l.last_update_s.to_bits()
            );
            assert_eq!(b.capacity_j.to_bits(), l.capacity_j.to_bits());
        }
    }

    #[test]
    fn bank_zero_loan_charging_tick_keeps_positive_zero() {
        // the one case where the branch-free select takes a different
        // path from the scalar branch: both must produce +0.0 bits
        let l = EnergyLoan::new(3000.0, 20_000.0);
        let mut bank = LoanBank::with_capacity(1);
        bank.push(&l);
        bank.tick_all(86_400.0, &[true]);
        assert_eq!(bank.loan_j[0].to_bits(), 0.0f64.to_bits());
    }

    #[test]
    #[ignore] // microbench: cargo test -- --ignored --nocapture
    fn bank_tick_microbench() {
        // criterion-free check that the batched tick stays in the
        // nanoseconds-per-row regime (plan/commit with no branches)
        let n = 100_000;
        let proto = EnergyLoan::new(3000.0, 10_000.0);
        let mut bank = LoanBank::with_capacity(n);
        for _ in 0..n {
            bank.push(&proto);
        }
        let charging: Vec<bool> = (0..n).map(|k| k % 3 == 0).collect();
        let reps = 200;
        let start = std::time::Instant::now();
        for r in 0..reps {
            bank.tick_all(600.0 * (r + 1) as f64, &charging);
        }
        let ns_per_row =
            start.elapsed().as_nanos() as f64 / (reps * n) as f64;
        println!("LoanBank::tick_all: {ns_per_row:.2} ns/row");
        assert!(bank.loan_j.iter().all(|l| *l == 0.0));
    }

    #[test]
    fn heavier_spender_dies_first() {
        // the Fig 5b/6b mechanism in miniature
        let mut cheap = EnergyLoan::new(3000.0, 5_000.0);
        let mut costly = EnergyLoan::new(3000.0, 5_000.0);
        let mut cheap_dead = None;
        let mut costly_dead = None;
        for day in 0..200 {
            let t = day as f64 * 86_400.0;
            cheap.tick(t, true);
            costly.tick(t, true);
            cheap.borrow(4_000.0);
            costly.borrow(30_000.0);
            if costly_dead.is_none() && !costly.allows_participation(0.6) {
                costly_dead = Some(day);
            }
            if cheap_dead.is_none() && !cheap.allows_participation(0.6) {
                cheap_dead = Some(day);
            }
        }
        assert!(costly_dead.is_some(), "heavy spender must exhaust budget");
        assert!(
            cheap_dead.is_none() || cheap_dead > costly_dead,
            "cheap {cheap_dead:?} vs costly {costly_dead:?}"
        );
    }
}
