//! The unified FL training engine: ONE round state machine behind both
//! the direct simulator ([`run_direct`]) and the serve control plane
//! ([`run_serve`]).
//!
//! Historically the repo had two training paths that could drift: the
//! trait-object numerics loop in `FlSim::run` and the systems-only SoA
//! fleet kernel. This module closes that split the same way the fleet
//! kernel did — decompose the client population into dense per-client
//! lanes ([`ClientLanes`], keyed by dense sequential ids), and make
//! every round driver replay the identical decision sequence:
//!
//! 1. sweep the availability gate over the lanes (the same
//!    [`sweep_gate`](super::availability::sweep_gate) pass the SoA
//!    fleet kernel runs) → the online set, in ascending id order;
//! 2. select K via `round_rng(seed, round)` — a pure function of
//!    (seed, round), so selection cannot depend on which wiring runs it;
//! 3. resolve each pick's systems cost from
//!    [`plan_cost_for_arm`](crate::serve::cache::plan_cost_for_arm)
//!    (a pure function of (workload, model, band, charging, arm) —
//!    the coordinator's LRU cache memoizes exactly this function, so
//!    caching cannot change a single bit);
//! 4. run real local SGD through a [`LocalSgd`] backend over a
//!    (seed, client, round)-keyed step order;
//! 5. FedAvg the weighted updates in picked (= lease seq) order;
//! 6. fold the parity digest in the coordinator's exact field sequence
//!    and advance the straggler-paced virtual clock.
//!
//! [`run_direct`] executes all six stages in-process and is the
//! **bit-exactness oracle**. [`run_serve`] routes stages 2/3/5/6
//! through a [`Coordinator`](crate::serve::coordinator::Coordinator)
//! behind any [`ServeClient`] wiring (in-process or loopback TCP, any
//! lane count) and must reproduce the oracle's final weights and digest
//! bit-for-bit — the property `rust/tests/numerics_parity.rs` and the
//! CI numerics-smoke job pin.

use crate::fleet::engine::{round_rng, EMPTY_ROUND_WAIT_S};
use crate::serve::cache::plan_cost_for_arm;
use crate::serve::client::{LeaseReply, ServeClient};
use crate::serve::coordinator::{digest_hex, DigestFold, ServeConfig};
use crate::serve::loadgen::thermal_band;
use crate::serve::wire::{model_code, Ack, CheckIn, PlanLease, UpdatePush};
use crate::soc::device::DeviceId;
use crate::trace::resample::ResampledTrace;
use crate::train::data::Partition;
use crate::train::softmax::LocalSgd;
use crate::util::rng::Rng;
use crate::workload::Workload;

use super::availability::{sweep_gate, FlClient, MIN_LEVEL_PCT};
use super::energy_loan::LoanBank;
use super::selection::select_uniform;
use super::server::fedavg;
use super::sim::{FlArm, FlConfig, FlOutcome};

/// Salt for the per-client thermal-band seed stream.
const BAND_SEED_SALT: u64 = 0xBA2D_5EED;

/// Salt for the global-model init (kept from the historical
/// `FlSim::run` so seeds stay comparable across PRs).
const INIT_SALT: u64 = 0x60BA1;

/// SoA decomposition of an FL client population: one dense lane per
/// client, keyed by sequential ids (`0..n`), mirroring `fleet::soa`.
/// The id doubles as the wire `device` id, the partition index and the
/// `LoanBank` row, so every wiring addresses one client identically.
pub struct ClientLanes {
    pub n: usize,
    traces: Vec<ResampledTrace>,
    pub bank: LoanBank,
    pub models: Vec<DeviceId>,
    /// Per-client seed for the (seed, round)-keyed thermal-band draw.
    pub band_seeds: Vec<u64>,
    /// Steps in one full local epoch (the systems cost basis AND the
    /// `CheckIn::steps` the lease bills).
    pub epoch_steps: Vec<u32>,
    /// FedAvg weight (`n_samples`), fixed per client.
    pub weights: Vec<f64>,
    pub partitions: Vec<Partition>,
    min_level: Vec<f64>,
    // scratch columns refreshed by `poll`
    level: Vec<f64>,
    pub charging: Vec<bool>,
    mask: Vec<bool>,
    // participation bookkeeping, written back into `FlClient`s
    pub train_time_s: Vec<f64>,
    pub participations: Vec<usize>,
}

impl ClientLanes {
    /// Decompose `clients` into lanes. `seed` keys the per-client
    /// thermal-band seed stream (one `next_u64` per client, in id
    /// order) — the single RNG fork site of the lane state.
    pub fn new(clients: &[FlClient], seed: u64) -> ClientLanes {
        let n = clients.len();
        let mut band_rng = Rng::new(seed ^ BAND_SEED_SALT);
        let mut lanes = ClientLanes {
            n,
            traces: Vec::with_capacity(n),
            bank: LoanBank::with_capacity(n),
            models: Vec::with_capacity(n),
            band_seeds: Vec::with_capacity(n),
            epoch_steps: Vec::with_capacity(n),
            weights: Vec::with_capacity(n),
            partitions: Vec::with_capacity(n),
            min_level: vec![MIN_LEVEL_PCT; n],
            level: vec![0.0; n],
            charging: vec![false; n],
            mask: Vec::with_capacity(n),
            train_time_s: Vec::with_capacity(n),
            participations: Vec::with_capacity(n),
        };
        for c in clients {
            lanes.traces.push(c.trace.clone());
            lanes.bank.push(&c.loan);
            lanes.models.push(c.device.id);
            lanes.band_seeds.push(band_rng.next_u64());
            lanes.epoch_steps.push(c.epoch_steps() as u32);
            lanes.weights.push(c.partition.n_samples as f64);
            lanes.partitions.push(c.partition.clone());
            lanes.train_time_s.push(c.train_time_s);
            lanes.participations.push(c.participations);
        }
        lanes
    }

    /// Advance every lane to `now_s` and refresh the availability mask
    /// — the scalar-sample + [`sweep_gate`] pass shared with the SoA
    /// fleet kernel (same tick→gate call order, so loan bits evolve
    /// identically).
    pub fn poll(&mut self, now_s: f64) {
        for i in 0..self.n {
            let t = self.traces[i].wrap(now_s);
            let (lv, ch) = self.traces[i].sample(t);
            self.level[i] = lv;
            self.charging[i] = ch;
        }
        sweep_gate(
            &mut self.bank,
            now_s,
            &self.level,
            &self.charging,
            &self.min_level,
            &mut self.mask,
        );
    }

    /// Online client ids after the last [`poll`](ClientLanes::poll),
    /// ascending (the order the coordinator's sorted admitted set
    /// reproduces, so selection sees identical candidate lists).
    pub fn online_ids(&self) -> Vec<usize> {
        (0..self.n).filter(|&i| self.mask[i]).collect()
    }

    /// Bill one participation to lane `gid`.
    pub fn charge(&mut self, gid: usize, time_s: f64, energy_j: f64) {
        self.train_time_s[gid] += time_s;
        self.bank.borrow(gid, energy_j);
        self.participations[gid] += 1;
    }

    /// Restore the mutated lane state (loans, participation counters)
    /// into the scalar clients a run was decomposed from.
    pub fn write_back(&self, clients: &mut [FlClient]) {
        for (i, c) in clients.iter_mut().enumerate() {
            c.loan = self.bank.get(i);
            c.train_time_s = self.train_time_s[i];
            c.participations = self.participations[i];
        }
    }
}

/// The shuffled batch-step indices client `client` trains in `round`.
/// Keyed on (seed, client, round) — NOT drawn from a sequential stream
/// — so the direct engine and every serve lane compute the identical
/// order without sharing RNG state.
pub fn step_order(
    seed: u64,
    client: usize,
    round: usize,
    local_steps: usize,
) -> Vec<usize> {
    let mut steps: Vec<usize> = (0..local_steps)
        .map(|s| round * local_steps + s)
        .collect();
    let mut rng = Rng::new(
        seed ^ (client as u64).wrapping_mul(0xA076_1D64_78BD_642F)
            ^ (round as u64).wrapping_mul(0xE703_7ED1_A0B4_28DB),
    );
    rng.shuffle(&mut steps);
    steps
}

/// The [`ServeConfig`] under which a coordinator replays exactly the
/// rounds [`run_direct`] simulates: unbounded admission (a deferral
/// would drop an online client the oracle trains), the fleet batch
/// size, and the backend's model dimension.
pub fn serve_config(
    cfg: &FlConfig,
    arm: FlArm,
    workload: crate::workload::WorkloadName,
    update_dim: usize,
) -> ServeConfig {
    ServeConfig {
        seed: cfg.seed,
        clients_per_round: cfg.clients_per_round,
        server_overhead_s: cfg.server_overhead_s,
        batch_size: 256,
        admit_capacity: 0,
        cache_capacity: 64,
        update_dim,
        workload,
        arm,
    }
}

/// The direct (in-process, serial) engine — the bit-exactness oracle
/// every serve wiring must reproduce. `workload` must be the workload
/// the paired coordinator resolves costs from (i.e. the result of the
/// same `load_or_builtin(name, "artifacts")` call) for digest parity.
pub fn run_direct<B: LocalSgd>(
    cfg: &FlConfig,
    arm: FlArm,
    lanes: &mut ClientLanes,
    backend: &B,
    workload: &Workload,
) -> crate::Result<FlOutcome> {
    let mut global = backend.init_global(cfg.seed ^ INIT_SALT);
    crate::ensure!(
        global.len() == backend.dim(),
        "fl: init model carries {} params, backend dim is {}",
        global.len(),
        backend.dim()
    );
    let mut outcome = FlOutcome {
        arm: arm.name(),
        ..Default::default()
    };
    let mut fold = DigestFold::default();
    let mut now_s = 0.0f64;
    let mut total_energy = 0.0f64;

    for round in 0..cfg.rounds {
        // 1. availability sweep (ids ascending == the coordinator's
        //    sorted/deduped admitted order)
        lanes.poll(now_s);
        let online = lanes.online_ids();
        outcome.online_per_round.push((round, online.len()));
        fold.push(round as u64);
        fold.push(online.len() as u64);

        // 2. (seed, round)-keyed selection — the coordinator's RNG
        let mut rng = round_rng(cfg.seed, round);
        let picked =
            select_uniform(&online, cfg.clients_per_round, &mut rng);
        for &gid in &picked {
            fold.push(gid as u64);
        }

        // 3.+4. systems cost + real local SGD, in picked (= seq) order
        let mut round_time = 0.0f64;
        let mut round_energy = 0.0f64;
        let mut updates: Vec<(Vec<Vec<f32>>, f64)> =
            Vec::with_capacity(picked.len());
        for &gid in &picked {
            let band = thermal_band(lanes.band_seeds[gid], round);
            let cost = plan_cost_for_arm(
                workload,
                lanes.models[gid],
                band,
                lanes.charging[gid],
                arm,
            );
            let steps = lanes.epoch_steps[gid] as f64;
            let latency = cost.latency_s * steps;
            let energy = cost.energy_j * steps;
            round_time = round_time.max(latency);
            round_energy += energy;
            lanes.charge(gid, latency, energy);
            let order = step_order(cfg.seed, gid, round, cfg.local_steps);
            let local =
                backend.local_update(&global, &lanes.partitions[gid], &order)?;
            updates.push((vec![local], lanes.weights[gid]));
        }
        fold.push_f64(round_time);
        fold.push_f64(round_energy);

        // 5. FedAvg in seq order; the aggregate IS the next global
        if !updates.is_empty() {
            let agg = fedavg(&updates)?;
            for v in &agg[0] {
                fold.push_f32(*v);
            }
            global = agg.into_iter().next().ok_or_else(|| {
                crate::err!("fl: fedavg returned no leaves")
            })?;
        }

        // 6. straggler-paced clock (empty rounds idle-wait)
        total_energy += round_energy;
        now_s += if online.is_empty() {
            EMPTY_ROUND_WAIT_S
        } else {
            round_time + cfg.server_overhead_s
        };

        if round % cfg.eval_every.max(1) == 0 || round + 1 == cfg.rounds {
            let ev = backend.eval(&global, cfg.eval_batches)?;
            outcome.accuracy_curve.push(now_s, ev.accuracy);
            outcome.loss_curve.push(now_s, ev.loss);
        }
        outcome.rounds_run = round + 1;
    }
    outcome.total_energy_j = total_energy;
    outcome.total_time_s = now_s;
    outcome.digest = digest_hex(fold.h);
    outcome.final_model = global;
    Ok(outcome)
}

/// The serve-routed engine: the same rounds as [`run_direct`], but
/// selection, lease resolution, aggregation and the parity digest all
/// happen inside the coordinator behind `clients` (one [`ServeClient`]
/// per lane thread — in-process handles or TCP connections). Clients
/// partition the fleet by `id % n_lanes`; lane 0 paces the round.
///
/// The coordinator must have been built from
/// [`serve_config`]`(cfg, arm, workload, backend.dim())` — parity is
/// against the oracle run with the identically-loaded workload.
pub fn run_serve<B: LocalSgd + Sync>(
    cfg: &FlConfig,
    arm: FlArm,
    lanes_state: &mut ClientLanes,
    backend: &B,
    mut clients: Vec<Box<dyn ServeClient>>,
) -> crate::Result<FlOutcome> {
    crate::ensure!(
        !clients.is_empty(),
        "fl: run_serve needs at least one lane client"
    );
    let n_lanes = clients.len();
    let init = backend.init_global(cfg.seed ^ INIT_SALT);
    crate::ensure!(
        init.len() == backend.dim(),
        "fl: init model carries {} params, backend dim is {}",
        init.len(),
        backend.dim()
    );
    clients[0].model_init(init)?;
    let (first_round, mut global) = clients[0].model_pull()?;
    crate::ensure!(
        first_round == 0,
        "fl: coordinator already ran {first_round} rounds"
    );

    let mut outcome = FlOutcome {
        arm: arm.name(),
        ..Default::default()
    };
    let mut now_s = 0.0f64;
    let mut total_energy = 0.0f64;
    let mut last_digest = DigestFold::default().h;

    for round in 0..cfg.rounds {
        lanes_state.poll(now_s);
        let online = lanes_state.online_ids();
        outcome.online_per_round.push((round, online.len()));

        // the lane partition: client i talks through lane i % n_lanes
        let mut lane_reqs: Vec<Vec<CheckIn>> = vec![Vec::new(); n_lanes];
        for &i in &online {
            lane_reqs[i % n_lanes].push(CheckIn {
                device: i as u64,
                model: model_code(lanes_state.models[i]),
                band: thermal_band(lanes_state.band_seeds[i], round),
                charging: lanes_state.charging[i],
                steps: lanes_state.epoch_steps[i],
            });
        }

        // check-in phase: every online client must be admitted (the
        // engine configures unbounded admission; anything else would
        // silently drop a client the oracle trains)
        std::thread::scope(|s| -> crate::Result<()> {
            let mut handles = Vec::with_capacity(n_lanes);
            for (client, reqs) in clients.iter_mut().zip(&lane_reqs) {
                handles.push(s.spawn(move || -> crate::Result<()> {
                    for ack in client.check_in_batch(reqs)? {
                        crate::ensure!(
                            ack == Ack::Admitted,
                            "fl: check-in answered {ack:?}, not Admitted"
                        );
                    }
                    Ok(())
                }));
            }
            for h in handles {
                h.join().map_err(|_| {
                    crate::err!("fl: a check-in lane panicked")
                })??;
            }
            Ok(())
        })?;

        let picked_n = clients[0].round_close(round as u32)?;

        // update phase: poll leases, train, push updates — each lane
        // independently; the coordinator's dense seq slots make the
        // aggregation order arrival-independent
        let seed = cfg.seed;
        let local_steps = cfg.local_steps;
        let partitions = &lanes_state.partitions;
        let weights = &lanes_state.weights;
        let global_ref = &global;
        let leases =
            std::thread::scope(|s| -> crate::Result<Vec<PlanLease>> {
                let mut handles = Vec::with_capacity(n_lanes);
                for (client, reqs) in clients.iter_mut().zip(&lane_reqs) {
                    handles.push(s.spawn(
                        move || -> crate::Result<Vec<PlanLease>> {
                            let devices: Vec<u64> = reqs
                                .iter()
                                .map(|ci| ci.device)
                                .collect();
                            let mut leases = Vec::new();
                            let mut pushes = Vec::new();
                            for reply in
                                client.lease_poll_batch(&devices)?
                            {
                                let LeaseReply::Lease(l) = reply else {
                                    continue;
                                };
                                let gid = l.device as usize;
                                let order = step_order(
                                    seed,
                                    gid,
                                    round,
                                    local_steps,
                                );
                                let local = backend.local_update(
                                    global_ref,
                                    &partitions[gid],
                                    &order,
                                )?;
                                pushes.push(UpdatePush {
                                    device: l.device,
                                    round: l.round,
                                    seq: l.seq,
                                    weight: weights[gid],
                                    params: local,
                                });
                                leases.push(l);
                            }
                            for ack in
                                client.push_update_batch(pushes)?
                            {
                                crate::ensure!(
                                    ack == Ack::Accepted,
                                    "fl: update answered {ack:?}, \
                                     not Accepted"
                                );
                            }
                            Ok(leases)
                        },
                    ));
                }
                let mut all = Vec::new();
                for h in handles {
                    all.extend(h.join().map_err(|_| {
                        crate::err!("fl: an update lane panicked")
                    })??);
                }
                Ok(all)
            })?;
        crate::ensure!(
            leases.len() == picked_n as usize,
            "fl: round {round} leased {} of {picked_n} picked",
            leases.len()
        );

        // bill participations in seq (= picked) order, like the oracle
        let mut leases = leases;
        leases.sort_by_key(|l| l.seq);
        for l in &leases {
            lanes_state.charge(l.device as usize, l.latency_s, l.energy_j);
        }

        let summary = clients[0].round_finish(round as u32)?;
        crate::ensure!(
            summary.participants == picked_n,
            "fl: round {round} summary reports {} participants, \
             expected {picked_n}",
            summary.participants
        );
        total_energy += summary.round_energy_j;
        now_s += if summary.admitted == 0 {
            EMPTY_ROUND_WAIT_S
        } else {
            summary.round_time_s + cfg.server_overhead_s
        };
        last_digest = summary.digest;

        // the aggregate IS the next global model — pull it back
        let (next_round, g) = clients[0].model_pull()?;
        crate::ensure!(
            next_round as usize == round + 1,
            "fl: model pull reports round {next_round}, expected {}",
            round + 1
        );
        global = g;

        if round % cfg.eval_every.max(1) == 0 || round + 1 == cfg.rounds {
            let ev = backend.eval(&global, cfg.eval_batches)?;
            outcome.accuracy_curve.push(now_s, ev.accuracy);
            outcome.loss_curve.push(now_s, ev.loss);
        }
        outcome.rounds_run = round + 1;
    }
    outcome.total_energy_j = total_energy;
    outcome.total_time_s = now_s;
    outcome.digest = digest_hex(last_digest);
    outcome.final_model = global;
    Ok(outcome)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::FlSim;
    use crate::serve::client::InProcClient;
    use crate::serve::coordinator::Coordinator;
    use crate::train::data::SyntheticDataset;
    use crate::train::softmax::SoftmaxProbe;
    use crate::workload::{load_or_builtin, WorkloadName};
    use std::sync::Arc;

    fn tiny_cfg() -> FlConfig {
        FlConfig {
            seed: 5,
            raw_traces: 6,
            quality_traces: 2, // × 24 shifts = 48 clients
            clients_per_round: 3,
            local_steps: 2,
            rounds: 4,
            eval_every: 2,
            eval_batches: 1,
            daily_credit_j: 3_000.0,
            server_overhead_s: 0.5,
        }
    }

    fn fleet(cfg: &FlConfig) -> (Vec<FlClient>, SoftmaxProbe) {
        let ds = SyntheticDataset::speech(cfg.seed);
        let w = load_or_builtin(WorkloadName::ShufflenetV2, "artifacts");
        let sim =
            FlSim::new(cfg.clone(), FlArm::Swan, ds.clone(), &w).unwrap();
        (sim.clients, SoftmaxProbe::new(ds))
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn step_order_is_keyed_and_deterministic() {
        let a = step_order(7, 3, 2, 5);
        let b = step_order(7, 3, 2, 5);
        assert_eq!(a, b);
        // the underlying step ids are the round's contiguous window
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![10, 11, 12, 13, 14]);
        // different client / round → different key → (almost surely)
        // different order; at minimum a different window
        let c = step_order(7, 3, 3, 5);
        assert!(c.iter().all(|&s| s >= 15 && s < 20));
    }

    #[test]
    fn direct_engine_is_deterministic() {
        let cfg = tiny_cfg();
        let (clients, probe) = fleet(&cfg);
        let w = load_or_builtin(WorkloadName::ShufflenetV2, "artifacts");
        let run = || {
            let mut lanes = ClientLanes::new(&clients, cfg.seed);
            run_direct(&cfg, FlArm::Swan, &mut lanes, &probe, &w).unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.digest, b.digest);
        assert_eq!(bits(&a.final_model), bits(&b.final_model));
        assert_eq!(a.total_time_s.to_bits(), b.total_time_s.to_bits());
        assert_eq!(a.rounds_run, cfg.rounds);
    }

    #[test]
    fn serve_routed_training_matches_the_direct_oracle() {
        let cfg = tiny_cfg();
        let (clients, probe) = fleet(&cfg);
        let w = load_or_builtin(WorkloadName::ShufflenetV2, "artifacts");
        let mut lanes = ClientLanes::new(&clients, cfg.seed);
        let direct = run_direct(&cfg, FlArm::Swan, &mut lanes, &probe, &w)
            .unwrap();
        assert!(!direct.final_model.is_empty());

        for n_lanes in [1usize, 3] {
            let coord = Arc::new(
                Coordinator::new(serve_config(
                    &cfg,
                    FlArm::Swan,
                    WorkloadName::ShufflenetV2,
                    probe.dim(),
                ))
                .unwrap(),
            );
            let lane_clients: Vec<Box<dyn ServeClient>> = (0..n_lanes)
                .map(|_| {
                    Box::new(InProcClient::new(coord.clone()))
                        as Box<dyn ServeClient>
                })
                .collect();
            let mut lanes2 = ClientLanes::new(&clients, cfg.seed);
            let served = run_serve(
                &cfg,
                FlArm::Swan,
                &mut lanes2,
                &probe,
                lane_clients,
            )
            .unwrap();
            assert_eq!(direct.digest, served.digest, "lanes={n_lanes}");
            assert_eq!(
                bits(&direct.final_model),
                bits(&served.final_model),
                "lanes={n_lanes}"
            );
            assert_eq!(
                direct.total_time_s.to_bits(),
                served.total_time_s.to_bits()
            );
            assert_eq!(
                direct.total_energy_j.to_bits(),
                served.total_energy_j.to_bits()
            );
            assert_eq!(direct.online_per_round, served.online_per_round);
            // loan state evolved identically on both sides
            for k in 0..lanes.n {
                assert_eq!(
                    lanes.bank.loan_j[k].to_bits(),
                    lanes2.bank.loan_j[k].to_bits(),
                    "loan row {k}"
                );
                assert_eq!(
                    lanes.participations[k],
                    lanes2.participations[k]
                );
            }
        }
    }

    #[test]
    fn write_back_restores_scalar_clients() {
        let cfg = tiny_cfg();
        let (mut clients, probe) = fleet(&cfg);
        let w = load_or_builtin(WorkloadName::ShufflenetV2, "artifacts");
        let mut lanes = ClientLanes::new(&clients, cfg.seed);
        run_direct(&cfg, FlArm::Swan, &mut lanes, &probe, &w).unwrap();
        lanes.write_back(&mut clients);
        let parts: usize = clients.iter().map(|c| c.participations).sum();
        let lane_parts: usize = lanes.participations.iter().sum();
        assert_eq!(parts, lane_parts);
        for (k, c) in clients.iter().enumerate() {
            assert_eq!(
                c.loan.loan_j.to_bits(),
                lanes.bank.loan_j[k].to_bits()
            );
        }
    }
}
