//! One FL client: a traced device + energy loan + data partition handle.

use crate::soc::device::{Device, DeviceId};
use crate::trace::resample::ResampledTrace;
use crate::train::data::Partition;

use super::energy_loan::{EnergyLoan, LoanBank};

/// Minimum traced battery level (%) for participation when not charging
/// (the same §4.1 gate local admission uses).
pub const MIN_LEVEL_PCT: f64 = 20.0;

/// The §4.1/§5.1 availability gate shared by [`FlClient`] and the fleet
/// kernel's light devices: (charging ∨ level ≥ minimum) ∧ the energy
/// loan hasn't exhausted the budget. Advances the loan to `now_s`;
/// `trace_offset_s` applies the A.2 hourly-shift augmentation.
pub fn availability_gate(
    trace: &ResampledTrace,
    loan: &mut EnergyLoan,
    now_s: f64,
    trace_offset_s: f64,
    min_level_pct: f64,
) -> bool {
    let t = trace.wrap(now_s + trace_offset_s);
    // fused lookup: one grid-index computation yields both reads (this
    // gate runs once per device per round — the fleet's hottest path)
    let (level_pct, charging) = trace.sample(t);
    availability_gate_sampled(loan, now_s, level_pct, charging, min_level_pct)
}

/// The gate decision given an already-sampled `(level, charging)` — the
/// shared tail of [`availability_gate`]. The SoA fleet kernel feeds
/// this from its per-`(trace, shift)` sample cache, so both kernels
/// gate through one definition and cross-kernel bit-parity holds by
/// construction.
pub fn availability_gate_sampled(
    loan: &mut EnergyLoan,
    now_s: f64,
    level_pct: f64,
    charging: bool,
    min_level_pct: f64,
) -> bool {
    loan.tick(now_s, charging);
    let gate = charging || level_pct >= min_level_pct;
    gate && loan.allows_participation(level_pct / 100.0)
}

/// Batch twin of [`availability_gate_sampled`] over a [`LoanBank`]:
/// evaluates the gate for every row into `mask` (cleared, then
/// refilled). The caller must have already advanced the bank with
/// `bank.tick_all(now_s, charging)` — splitting tick from gate keeps
/// each loop branch-free. Uses non-short-circuiting `&`/`|` so every
/// lane does identical work; this is decision-identical to the scalar
/// gate because `allows_participation` is pure (evaluating it when the
/// level gate already failed cannot change state), and the effective-
/// level comparison is written with the exact same operation order
/// (`level/100 − loan/capacity > critical`).
pub fn availability_gate_many(
    bank: &LoanBank,
    level_pct: &[f64],
    charging: &[bool],
    min_level_pct: &[f64],
    mask: &mut Vec<bool>,
) {
    mask.clear();
    let n = bank.len();
    debug_assert_eq!(level_pct.len(), n);
    debug_assert_eq!(charging.len(), n);
    debug_assert_eq!(min_level_pct.len(), n);
    let loan = &bank.loan_j[..n];
    let cap = &bank.capacity_j[..n];
    let crit = &bank.critical_level[..n];
    let level_pct = &level_pct[..n];
    let charging = &charging[..n];
    let min_level_pct = &min_level_pct[..n];
    for k in 0..n {
        let gate = charging[k] | (level_pct[k] >= min_level_pct[k]);
        let allow = level_pct[k] / 100.0 - loan[k] / cap[k] > crit[k];
        mask.push(gate & allow);
    }
}

/// One availability sweep over a whole [`LoanBank`]: advance every
/// loan to `now_s` (`tick_all`), then refresh `mask` via
/// [`availability_gate_many`]. This tick→gate call order is the batch
/// twin of [`availability_gate`]'s scalar tick→gate, and it is shared
/// by the SoA fleet kernel (`fleet::soa`) and the unified FL engine
/// (`fl::engine::ClientLanes::poll`), so the two round drivers evolve
/// loan bits identically by construction.
pub fn sweep_gate(
    bank: &mut LoanBank,
    now_s: f64,
    level_pct: &[f64],
    charging: &[bool],
    min_level_pct: &[f64],
    mask: &mut Vec<bool>,
) {
    bank.tick_all(now_s, charging);
    availability_gate_many(bank, level_pct, charging, min_level_pct, mask);
}

pub struct FlClient {
    pub id: usize,
    pub device: Device,
    pub trace: ResampledTrace,
    pub loan: EnergyLoan,
    pub partition: Partition,
    /// Cumulative simulated seconds spent training (metrics).
    pub train_time_s: f64,
    /// Rounds this client participated in.
    pub participations: usize,
}

impl FlClient {
    pub fn new(
        id: usize,
        device: Device,
        trace: ResampledTrace,
        partition: Partition,
        daily_credit_j: f64,
    ) -> Self {
        let loan = EnergyLoan::new(device.battery_mah, daily_credit_j);
        FlClient {
            id,
            device,
            trace,
            loan,
            partition,
            train_time_s: 0.0,
            participations: 0,
        }
    }

    pub fn device_id(&self) -> DeviceId {
        self.device.id
    }

    /// Paper §4.1/§5.1 availability (see [`availability_gate`]).
    /// `now_s` is virtual time, wrapped around the trace length.
    pub fn online(&mut self, now_s: f64) -> bool {
        availability_gate(&self.trace, &mut self.loan, now_s, 0.0, MIN_LEVEL_PCT)
    }

    /// Steps in one full local epoch (paper §5.1: one pass over the
    /// client's samples at batch 16, == `ModelMeta::batch`).
    pub fn epoch_steps(&self) -> usize {
        const BATCH: usize = 16;
        (self.partition.n_samples + BATCH - 1) / BATCH
    }

    /// Record one participation's systems cost.
    pub fn charge_participation(&mut self, time_s: f64, energy_j: f64) {
        self.train_time_s += time_s;
        self.loan.borrow(energy_j);
        self.participations += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::device::{device, DeviceId};
    use crate::trace::greenhub::TraceGenerator;
    use crate::trace::resample::resample_trace;
    use crate::train::data::SyntheticDataset;

    fn client(credit: f64) -> FlClient {
        let tr =
            resample_trace(&TraceGenerator::default().generate(1, 0)).unwrap();
        let ds = SyntheticDataset::vision(0);
        FlClient::new(0, device(DeviceId::Pixel3), tr, ds.partition(0), credit)
    }

    #[test]
    fn gate_many_matches_scalar_gate_over_random_streams() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x6A7E_BA9);
        let n = 96;
        let mut scalars: Vec<EnergyLoan> = (0..n)
            .map(|i| {
                let mut l = EnergyLoan::new(
                    1500.0 + 40.0 * i as f64,
                    rng.range(1_000.0, 30_000.0),
                );
                l.borrow(rng.range(0.0, l.capacity_j * 0.3));
                l
            })
            .collect();
        let mut bank = LoanBank::with_capacity(n);
        for l in &scalars {
            bank.push(l);
        }
        let level: Vec<f64> =
            (0..n).map(|_| rng.range(0.0, 100.0)).collect();
        let charging: Vec<bool> =
            (0..n).map(|_| rng.bool(0.4)).collect();
        let min_level: Vec<f64> =
            (0..n).map(|_| rng.range(5.0, 60.0)).collect();
        let mut now = 0.0;
        let mut mask = Vec::new();
        for _ in 0..25 {
            now += rng.range(0.0, 10_000.0);
            bank.tick_all(now, &charging);
            availability_gate_many(
                &bank, &level, &charging, &min_level, &mut mask,
            );
            for k in 0..n {
                let want = availability_gate_sampled(
                    &mut scalars[k],
                    now,
                    level[k],
                    charging[k],
                    min_level[k],
                );
                assert_eq!(mask[k], want, "row {k} at now={now}");
                assert_eq!(
                    bank.loan_j[k].to_bits(),
                    scalars[k].loan_j.to_bits()
                );
            }
        }
    }

    #[test]
    fn availability_varies_over_a_day() {
        let mut c = client(50_000.0);
        let mut states = Vec::new();
        for i in 0..144 {
            states.push(c.online(i as f64 * 600.0));
        }
        assert!(states.iter().any(|&s| s), "never online in a day");
    }

    #[test]
    fn heavy_borrowing_takes_client_offline() {
        let mut c = client(1_000.0); // tiny daily credit
        // find an online moment
        let mut t = 0.0;
        while !c.online(t) {
            t += 600.0;
        }
        c.charge_participation(100.0, c.loan.capacity_j);
        assert!(!c.online(t), "loan of a full pack must kill availability");
        assert_eq!(c.participations, 1);
    }

    #[test]
    fn generous_charger_revives_client() {
        let mut c = client(1e6); // very generous daily credit
        let mut t = 0.0;
        while !c.online(t) {
            t += 600.0;
        }
        c.charge_participation(100.0, c.loan.capacity_j * 0.5);
        // a few days of charging later the loan is repaid
        let mut revived = false;
        for d in 1..8 {
            if c.online(t + d as f64 * 86_400.0) {
                revived = true;
                break;
            }
        }
        assert!(revived);
    }
}
