//! Workload descriptor types + JSON loading.

use crate::util::json::Value;

/// Operator classes, mirroring `workloads.py`. The class determines the
/// roofline behaviour (compute- vs memory-bound) and the cache-contention
/// severity (`soc::cache`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Standard convolution (im2col + MXU matmul) — compute-bound.
    Conv,
    /// 1×1 pointwise convolution — matmul-shaped, moderate AI.
    Pw,
    /// Depthwise convolution — memory-bound, thrash-prone (§3.1).
    Dw,
    /// Normalization (GroupNorm here, BatchNorm in the paper's models).
    Norm,
    /// Elementwise activation.
    Act,
    /// Pooling (avg/max/global).
    Pool,
    /// Residual add / concat+shuffle glue.
    Add,
    /// Dense head.
    Linear,
    /// Fused SGD parameter update.
    Update,
}

impl OpKind {
    pub const ALL: [OpKind; 9] = [
        OpKind::Conv,
        OpKind::Pw,
        OpKind::Dw,
        OpKind::Norm,
        OpKind::Act,
        OpKind::Pool,
        OpKind::Add,
        OpKind::Linear,
        OpKind::Update,
    ];

    pub fn parse(s: &str) -> Option<OpKind> {
        Some(match s {
            "conv" => OpKind::Conv,
            "pw" => OpKind::Pw,
            "dw" => OpKind::Dw,
            "norm" => OpKind::Norm,
            "act" => OpKind::Act,
            "pool" => OpKind::Pool,
            "add" => OpKind::Add,
            "linear" => OpKind::Linear,
            "update" => OpKind::Update,
            _ => return None,
        })
    }

    /// Memory-bound op classes hit the bandwidth wall before the FLOP
    /// wall on every device we model.
    pub fn is_memory_bound(&self) -> bool {
        matches!(
            self,
            OpKind::Dw
                | OpKind::Norm
                | OpKind::Act
                | OpKind::Pool
                | OpKind::Add
                | OpKind::Update
        )
    }
}

/// One operator of a training step.
#[derive(Clone, Debug)]
pub struct Op {
    pub name: String,
    pub kind: OpKind,
    pub flops: f64,
    pub bytes: f64,
}

impl Op {
    pub fn arithmetic_intensity(&self) -> f64 {
        self.flops / self.bytes.max(1.0)
    }
}

/// A full training-step workload (fwd + bwd + update ops, in order).
#[derive(Clone, Debug)]
pub struct Workload {
    pub name: String,
    pub batch: usize,
    pub ops: Vec<Op>,
    pub param_scalars: f64,
}

impl Workload {
    pub fn total_flops(&self) -> f64 {
        self.ops.iter().map(|o| o.flops).sum()
    }

    pub fn total_bytes(&self) -> f64 {
        self.ops.iter().map(|o| o.bytes).sum()
    }

    pub fn arithmetic_intensity(&self) -> f64 {
        self.total_flops() / self.total_bytes().max(1.0)
    }

    /// Fraction of total bytes moved by memory-bound op classes — the
    /// §3.1 "how thrashable is this model" scalar.
    pub fn memory_bound_fraction(&self) -> f64 {
        let mb: f64 = self
            .ops
            .iter()
            .filter(|o| o.kind.is_memory_bound())
            .map(|o| o.bytes)
            .sum();
        mb / self.total_bytes().max(1.0)
    }

    /// Parse a `workload_*.json` emitted by `workloads.py`.
    pub fn from_json(v: &Value) -> crate::Result<Workload> {
        let name = v.req_str("name")?.to_string();
        let batch = v.req_usize("batch")?;
        let param_scalars = v.req_f64("param_scalars")?;
        let mut ops = Vec::new();
        for o in v.req_arr("ops")? {
            let kind_s = o.req_str("kind")?;
            let kind = OpKind::parse(kind_s)
                .ok_or_else(|| crate::err!("unknown op kind '{kind_s}'"))?;
            ops.push(Op {
                name: o.req_str("name")?.to_string(),
                kind,
                flops: o.req_f64("flops")?,
                bytes: o.req_f64("bytes")?,
            });
        }
        crate::ensure!(!ops.is_empty(), "workload '{name}' has no ops");
        Ok(Workload {
            name,
            batch,
            ops,
            param_scalars,
        })
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> crate::Result<Workload> {
        let v = crate::util::json::parse_file(path)?;
        Workload::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> &'static str {
        r#"{
            "name": "toy", "batch": 16, "param_scalars": 1000,
            "ops": [
                {"name": "c1", "kind": "conv", "flops": 1e9, "bytes": 1e7},
                {"name": "d1", "kind": "dw", "flops": 1e7, "bytes": 1e7},
                {"name": "u", "kind": "update", "flops": 2e3, "bytes": 1.2e4}
            ]
        }"#
    }

    #[test]
    fn parses_sample() {
        let v = crate::util::json::parse(sample_json()).unwrap();
        let w = Workload::from_json(&v).unwrap();
        assert_eq!(w.name, "toy");
        assert_eq!(w.ops.len(), 3);
        assert_eq!(w.ops[1].kind, OpKind::Dw);
        assert!((w.total_flops() - 1.010002e9).abs() / 1e9 < 1e-6);
    }

    #[test]
    fn memory_bound_fraction_sane() {
        let v = crate::util::json::parse(sample_json()).unwrap();
        let w = Workload::from_json(&v).unwrap();
        let f = w.memory_bound_fraction();
        assert!(f > 0.4 && f < 0.6, "{f}"); // dw+update ≈ half the bytes
    }

    #[test]
    fn rejects_unknown_kind() {
        let src = r#"{"name":"x","batch":1,"param_scalars":0,
            "ops":[{"name":"a","kind":"warp_shuffle","flops":1,"bytes":1}]}"#;
        let v = crate::util::json::parse(src).unwrap();
        assert!(Workload::from_json(&v).is_err());
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in OpKind::ALL {
            let s = match k {
                OpKind::Conv => "conv",
                OpKind::Pw => "pw",
                OpKind::Dw => "dw",
                OpKind::Norm => "norm",
                OpKind::Act => "act",
                OpKind::Pool => "pool",
                OpKind::Add => "add",
                OpKind::Linear => "linear",
                OpKind::Update => "update",
            };
            assert_eq!(OpKind::parse(s), Some(k));
        }
        assert_eq!(OpKind::parse("nope"), None);
    }
}
