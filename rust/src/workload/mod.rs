//! Op-level training-step workload descriptors.
//!
//! Produced by `python/compile/workloads.py` at artifact-build time
//! (`artifacts/meta/workload_*.json`) for the paper-scale models and the
//! small trainable variants; the SoC simulator times a training step by
//! walking these ops through its roofline (see `soc::exec_model`).

pub mod descriptor;
pub mod models;

pub use descriptor::{Op, OpKind, Workload};
pub use models::{builtin, load_or_builtin, WorkloadName};
