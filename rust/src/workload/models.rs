//! Named workloads: load the JSON emitted by `workloads.py`, with exact
//! built-in fallbacks so the simulator-only paths (unit tests, benches
//! that don't touch the runtime) work without `make artifacts`.

use super::descriptor::{Op, OpKind, Workload};

/// The workloads the evaluation uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WorkloadName {
    /// Paper-scale models (systems metrics, Tables 2/3, Figs 2/3).
    Resnet34,
    MobilenetV2,
    ShufflenetV2,
    /// Fig 1b microbenchmark.
    Matmul512,
    /// Trainable small variants (what the PJRT runtime really executes).
    ResnetS,
    MobilenetS,
    ShufflenetS,
}

impl WorkloadName {
    pub fn key(&self) -> &'static str {
        match self {
            WorkloadName::Resnet34 => "resnet34",
            WorkloadName::MobilenetV2 => "mobilenet_v2",
            WorkloadName::ShufflenetV2 => "shufflenet_v2",
            WorkloadName::Matmul512 => "matmul512",
            WorkloadName::ResnetS => "resnet_s",
            WorkloadName::MobilenetS => "mobilenet_s",
            WorkloadName::ShufflenetS => "shufflenet_s",
        }
    }

    pub fn parse(s: &str) -> Option<WorkloadName> {
        Some(match s {
            "resnet34" => WorkloadName::Resnet34,
            "mobilenet_v2" | "mobilenet" => WorkloadName::MobilenetV2,
            "shufflenet_v2" | "shufflenet" => WorkloadName::ShufflenetV2,
            "matmul512" => WorkloadName::Matmul512,
            "resnet_s" => WorkloadName::ResnetS,
            "mobilenet_s" => WorkloadName::MobilenetS,
            "shufflenet_s" => WorkloadName::ShufflenetS,
            _ => return None,
        })
    }

    /// Paper-scale descriptor for each small trainable variant.
    pub fn paper_scale_of(small: WorkloadName) -> WorkloadName {
        match small {
            WorkloadName::ResnetS => WorkloadName::Resnet34,
            WorkloadName::MobilenetS => WorkloadName::MobilenetV2,
            WorkloadName::ShufflenetS => WorkloadName::ShufflenetV2,
            other => other,
        }
    }
}

/// Load `artifacts/meta/workload_<name>.json`, falling back to the
/// built-in analytical model when artifacts aren't built.
pub fn load_or_builtin(name: WorkloadName, artifacts_dir: &str) -> Workload {
    let path = std::path::Path::new(artifacts_dir)
        .join("meta")
        .join(format!("workload_{}.json", name.key()));
    if path.exists() {
        if let Ok(w) = Workload::load(&path) {
            return w;
        }
    }
    builtin(name)
}

/// Built-in coarse descriptors. These reproduce the *totals and op mix*
/// of `workloads.py` (same accounting rules) at cluster granularity: one
/// op entry per (kind, phase) with the summed flops/bytes. The roofline
/// only looks at per-op kind/flops/bytes, so cluster granularity gives
/// identical step latency to within the contention model's resolution.
pub fn builtin(name: WorkloadName) -> Workload {
    // (kind, fwd_flops, fwd_bytes) clusters; bwd = 2× each; update from params
    let (batch, params, clusters): (usize, f64, Vec<(OpKind, f64, f64)>) =
        match name {
            WorkloadName::Resnet34 => (
                16,
                21.3e6,
                vec![
                    (OpKind::Conv, 36.2e9, 0.28e9),
                    (OpKind::Pw, 0.45e9, 0.03e9),
                    (OpKind::Norm, 0.10e9, 0.10e9),
                    (OpKind::Act, 0.02e9, 0.09e9),
                    (OpKind::Add, 0.01e9, 0.07e9),
                    (OpKind::Linear, 0.02e9, 0.01e9),
                ],
            ),
            WorkloadName::MobilenetV2 => (
                16,
                3.0e6,
                vec![
                    (OpKind::Conv, 0.16e9, 0.01e9),
                    (OpKind::Pw, 0.60e9, 0.09e9),
                    (OpKind::Dw, 0.05e9, 0.06e9),
                    (OpKind::Norm, 0.05e9, 0.05e9),
                    (OpKind::Act, 0.01e9, 0.04e9),
                    (OpKind::Add, 0.003e9, 0.02e9),
                    (OpKind::Linear, 0.025e9, 0.01e9),
                ],
            ),
            WorkloadName::ShufflenetV2 => (
                16,
                1.9e6,
                vec![
                    (OpKind::Conv, 0.05e9, 0.005e9),
                    (OpKind::Pw, 0.30e9, 0.05e9),
                    (OpKind::Dw, 0.02e9, 0.03e9),
                    (OpKind::Norm, 0.03e9, 0.03e9),
                    (OpKind::Act, 0.005e9, 0.02e9),
                    (OpKind::Add, 0.004e9, 0.02e9),
                    (OpKind::Linear, 0.02e9, 0.008e9),
                ],
            ),
            WorkloadName::Matmul512 => {
                return Workload {
                    name: "matmul512".into(),
                    batch: 1,
                    param_scalars: 0.0,
                    ops: vec![Op {
                        name: "mm".into(),
                        kind: OpKind::Conv,
                        flops: 2.0 * 512f64.powi(3),
                        bytes: 4.0 * 3.0 * 512.0 * 512.0,
                    }],
                };
            }
            WorkloadName::ResnetS => (
                16,
                79.2e3,
                vec![
                    (OpKind::Conv, 0.30e9, 0.012e9),
                    (OpKind::Norm, 0.004e9, 0.004e9),
                    (OpKind::Act, 0.001e9, 0.004e9),
                    (OpKind::Add, 0.0005e9, 0.003e9),
                    (OpKind::Linear, 0.0001e9, 0.0001e9),
                ],
            ),
            WorkloadName::MobilenetS => (
                16,
                65.1e3,
                vec![
                    (OpKind::Conv, 0.01e9, 0.001e9),
                    (OpKind::Pw, 0.10e9, 0.008e9),
                    (OpKind::Dw, 0.01e9, 0.012e9),
                    (OpKind::Norm, 0.006e9, 0.006e9),
                    (OpKind::Act, 0.002e9, 0.005e9),
                    (OpKind::Add, 0.0002e9, 0.001e9),
                    (OpKind::Linear, 0.0001e9, 0.0001e9),
                ],
            ),
            WorkloadName::ShufflenetS => (
                16,
                24.4e3,
                vec![
                    (OpKind::Conv, 0.01e9, 0.001e9),
                    (OpKind::Pw, 0.03e9, 0.004e9),
                    (OpKind::Dw, 0.004e9, 0.005e9),
                    (OpKind::Norm, 0.004e9, 0.004e9),
                    (OpKind::Act, 0.001e9, 0.003e9),
                    (OpKind::Add, 0.001e9, 0.002e9),
                    (OpKind::Linear, 0.0001e9, 0.0001e9),
                ],
            ),
        };
    let mut ops = Vec::new();
    for (kind, f, b) in &clusters {
        ops.push(Op {
            name: format!("{kind:?}#fwd"),
            kind: *kind,
            flops: *f,
            bytes: *b,
        });
    }
    for (kind, f, b) in clusters.iter().rev() {
        ops.push(Op {
            name: format!("{kind:?}#bwd"),
            kind: *kind,
            flops: 2.0 * f,
            bytes: 2.0 * b,
        });
    }
    ops.push(Op {
        name: "sgd_update".into(),
        kind: OpKind::Update,
        flops: 2.0 * params,
        bytes: 12.0 * params,
    });
    Workload {
        name: name.key().into(),
        batch,
        ops,
        param_scalars: params,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_well_formed() {
        for n in [
            WorkloadName::Resnet34,
            WorkloadName::MobilenetV2,
            WorkloadName::ShufflenetV2,
            WorkloadName::Matmul512,
            WorkloadName::ResnetS,
            WorkloadName::MobilenetS,
            WorkloadName::ShufflenetS,
        ] {
            let w = builtin(n);
            assert!(w.total_flops() > 0.0, "{n:?}");
            assert!(w.total_bytes() > 0.0, "{n:?}");
        }
    }

    #[test]
    fn resnet34_compute_bound_shufflenet_not() {
        let rn = builtin(WorkloadName::Resnet34);
        let sn = builtin(WorkloadName::ShufflenetV2);
        assert!(rn.arithmetic_intensity() > 5.0 * sn.arithmetic_intensity());
        assert!(sn.memory_bound_fraction() > rn.memory_bound_fraction());
    }

    #[test]
    fn json_overrides_builtin_when_present() {
        // with artifacts built, loader must prefer python-emitted numbers
        let w = load_or_builtin(WorkloadName::Resnet34, "artifacts");
        assert_eq!(w.name, "resnet34");
        let meta = std::path::Path::new("artifacts/meta/workload_resnet34.json");
        if meta.exists() {
            // python walker has per-layer ops, far more than the clusters
            assert!(w.ops.len() > 20, "expected python descriptor");
        }
    }

    #[test]
    fn paper_scale_mapping() {
        assert_eq!(
            WorkloadName::paper_scale_of(WorkloadName::ShufflenetS),
            WorkloadName::ShufflenetV2
        );
        assert_eq!(
            WorkloadName::paper_scale_of(WorkloadName::Matmul512),
            WorkloadName::Matmul512
        );
    }

    #[test]
    fn parse_keys() {
        for n in [
            WorkloadName::Resnet34,
            WorkloadName::MobilenetV2,
            WorkloadName::ShufflenetV2,
            WorkloadName::Matmul512,
            WorkloadName::ResnetS,
            WorkloadName::MobilenetS,
            WorkloadName::ShufflenetS,
        ] {
            assert_eq!(WorkloadName::parse(n.key()), Some(n));
        }
    }
}
