//! Battery model: coulomb-counted state of charge + Li-ion voltage curve.

/// Charging state as Android reports it (paper Appendix A.2 uses the
/// same three-valued signal derived from SoC deltas).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatteryState {
    Charging,
    NotDischarging, // full / maintenance
    Discharging,
}

/// A simulated Li-ion pack.
#[derive(Clone, Debug)]
pub struct Battery {
    /// Capacity in coulombs (mAh × 3.6).
    pub capacity_c: f64,
    /// Remaining charge in coulombs.
    pub charge_c: f64,
    state: BatteryState,
}

impl Battery {
    pub fn new(capacity_mah: f64, initial_soc: f64) -> Self {
        let capacity_c = capacity_mah * 3.6;
        Battery {
            capacity_c,
            charge_c: capacity_c * initial_soc.clamp(0.0, 1.0),
            state: BatteryState::Discharging,
        }
    }

    /// State of charge in [0, 1].
    pub fn soc(&self) -> f64 {
        (self.charge_c / self.capacity_c).clamp(0.0, 1.0)
    }

    /// Battery level as Android exposes it: integer percent. The paper's
    /// meter only sees this quantized signal.
    pub fn level_percent(&self) -> u32 {
        (self.soc() * 100.0).floor() as u32
    }

    /// Open-circuit voltage: piecewise-linear Li-ion curve 3.3–4.35 V.
    pub fn voltage(&self) -> f64 {
        voltage_curve(self.soc())
    }

    pub fn state(&self) -> BatteryState {
        self.state
    }

    /// Drain `power_w` for `dt_s` seconds. Returns the energy actually
    /// removed (joules) — less than requested if the pack empties.
    pub fn drain(&mut self, power_w: f64, dt_s: f64) -> f64 {
        debug_assert!(power_w >= 0.0 && dt_s >= 0.0);
        self.state = BatteryState::Discharging;
        let current_a = power_w / self.voltage();
        let want_c = current_a * dt_s;
        let got_c = want_c.min(self.charge_c);
        self.charge_c -= got_c;
        got_c * self.voltage()
    }

    /// Charge with `power_w` for `dt_s` (charger inefficiency applied by
    /// the caller).
    pub fn charge(&mut self, power_w: f64, dt_s: f64) {
        debug_assert!(power_w >= 0.0 && dt_s >= 0.0);
        let current_a = power_w / self.voltage();
        self.charge_c = (self.charge_c + current_a * dt_s).min(self.capacity_c);
        self.state = if self.soc() >= 0.999 {
            BatteryState::NotDischarging
        } else {
            BatteryState::Charging
        };
    }

    /// Force the SoC (used when replaying recorded traces).
    pub fn set_soc(&mut self, soc: f64) {
        self.charge_c = self.capacity_c * soc.clamp(0.0, 1.0);
    }

    pub fn set_state(&mut self, state: BatteryState) {
        self.state = state;
    }

    pub fn is_empty(&self) -> bool {
        self.charge_c <= 0.0
    }
}

/// The Li-ion OCV curve as a free function of SoC, shared by
/// [`Battery::voltage`] and the [`BatteryBank`] batch passes so both
/// representations read the exact same piecewise-linear curve (steep
/// knee below 10%, plateau 3.7–3.9, fast rise above 90%). The if-chain
/// lowers to selects — every arm is pure arithmetic.
#[inline]
pub fn voltage_curve(s: f64) -> f64 {
    if s < 0.10 {
        3.30 + s / 0.10 * 0.35
    } else if s < 0.90 {
        3.65 + (s - 0.10) / 0.80 * 0.35
    } else {
        4.00 + (s - 0.90) / 0.10 * 0.35
    }
}

/// Structure-of-arrays twin of [`Battery`] for batch simulation: the
/// per-device drain/charge updates become split plan/commit loops over
/// flat `f64` slices — the plan pass derives each row's transferred
/// charge from pre-update voltage into a private scratch column, the
/// commit pass applies it — so no loop carries a branch or `&mut`
/// aliasing between columns, and each pass auto-vectorizes. Rows are
/// independent, and within a row the plan→commit order is exactly the
/// statement order of the scalar methods, so results are bit-identical
/// to calling [`Battery::drain`]/[`Battery::charge`] per device.
#[derive(Clone, Debug, Default)]
pub struct BatteryBank {
    pub capacity_c: Vec<f64>,
    pub charge_c: Vec<f64>,
    state: Vec<BatteryState>,
    plan_c: Vec<f64>, // per-row transferred charge, plan → commit
}

impl BatteryBank {
    pub fn with_capacity(n: usize) -> Self {
        BatteryBank {
            capacity_c: Vec::with_capacity(n),
            charge_c: Vec::with_capacity(n),
            state: Vec::with_capacity(n),
            plan_c: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.charge_c.len()
    }

    pub fn is_empty(&self) -> bool {
        self.charge_c.is_empty()
    }

    /// Append a pack (column-wise copy of `b`).
    pub fn push(&mut self, b: &Battery) {
        self.capacity_c.push(b.capacity_c);
        self.charge_c.push(b.charge_c);
        self.state.push(b.state);
    }

    /// Reassemble row `k` as a scalar [`Battery`].
    pub fn get(&self, k: usize) -> Battery {
        Battery {
            capacity_c: self.capacity_c[k],
            charge_c: self.charge_c[k],
            state: self.state[k],
        }
    }

    pub fn soc(&self, k: usize) -> f64 {
        (self.charge_c[k] / self.capacity_c[k]).clamp(0.0, 1.0)
    }

    pub fn state(&self, k: usize) -> BatteryState {
        self.state[k]
    }

    /// Bank-wide [`Battery::drain`]: drain `power_w[k]` for `dt_s[k]`
    /// on every row, writing the energy actually removed (joules) into
    /// `energy_out`. Three passes: plan (transferred charge from
    /// pre-update voltage), commit (subtract), energy (post-update
    /// voltage × charge) — mirroring the scalar method's
    /// voltage-before / voltage-after statement order exactly.
    pub fn drain_all(
        &mut self,
        power_w: &[f64],
        dt_s: &[f64],
        energy_out: &mut Vec<f64>,
    ) {
        let n = self.len();
        debug_assert_eq!(power_w.len(), n);
        debug_assert_eq!(dt_s.len(), n);
        self.plan_c.clear();
        self.plan_c.resize(n, 0.0);
        energy_out.clear();
        {
            let plan = &mut self.plan_c[..n];
            let charge = &self.charge_c[..n];
            let cap = &self.capacity_c[..n];
            for k in 0..n {
                let v = voltage_curve((charge[k] / cap[k]).clamp(0.0, 1.0));
                let want_c = power_w[k] / v * dt_s[k];
                plan[k] = want_c.min(charge[k]);
            }
        }
        for k in 0..n {
            self.charge_c[k] -= self.plan_c[k];
            self.state[k] = BatteryState::Discharging;
        }
        {
            let plan = &self.plan_c[..n];
            let charge = &self.charge_c[..n];
            let cap = &self.capacity_c[..n];
            energy_out.extend((0..n).map(|k| {
                plan[k]
                    * voltage_curve((charge[k] / cap[k]).clamp(0.0, 1.0))
            }));
        }
    }

    /// Bank-wide [`Battery::charge`]: plan the added charge from
    /// pre-update voltage, then commit with the capacity cap and the
    /// full/maintenance state select.
    pub fn charge_all(&mut self, power_w: &[f64], dt_s: &[f64]) {
        let n = self.len();
        debug_assert_eq!(power_w.len(), n);
        debug_assert_eq!(dt_s.len(), n);
        self.plan_c.clear();
        self.plan_c.resize(n, 0.0);
        {
            let plan = &mut self.plan_c[..n];
            let charge = &self.charge_c[..n];
            let cap = &self.capacity_c[..n];
            for k in 0..n {
                let v = voltage_curve((charge[k] / cap[k]).clamp(0.0, 1.0));
                plan[k] = power_w[k] / v * dt_s[k];
            }
        }
        for k in 0..n {
            self.charge_c[k] =
                (self.charge_c[k] + self.plan_c[k]).min(self.capacity_c[k]);
            let soc =
                (self.charge_c[k] / self.capacity_c[k]).clamp(0.0, 1.0);
            self.state[k] = if soc >= 0.999 {
                BatteryState::NotDischarging
            } else {
                BatteryState::Charging
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    #[test]
    fn soc_and_percent() {
        let b = Battery::new(3000.0, 0.5);
        assert!((b.soc() - 0.5).abs() < 1e-12);
        assert_eq!(b.level_percent(), 50);
    }

    #[test]
    fn voltage_monotone_in_soc() {
        let mut prev = 0.0;
        for i in 0..=100 {
            let mut b = Battery::new(3000.0, 1.0);
            b.set_soc(i as f64 / 100.0);
            let v = b.voltage();
            assert!(v >= prev, "voltage not monotone at {i}%");
            assert!((3.2..=4.4).contains(&v));
            prev = v;
        }
    }

    #[test]
    fn drain_conserves_energy() {
        let mut b = Battery::new(3000.0, 1.0);
        let before = b.charge_c;
        let e = b.drain(2.0, 3600.0); // 2 W for an hour
        let used_c = before - b.charge_c;
        // E = Q × V (voltage varies little over one hour at 2 W)
        assert!((e - used_c * b.voltage()).abs() < 0.02 * e);
        assert!(b.soc() < 1.0);
    }

    #[test]
    fn drain_cannot_go_negative() {
        let mut b = Battery::new(100.0, 0.01);
        for _ in 0..100 {
            b.drain(50.0, 3600.0);
        }
        assert!(b.charge_c >= 0.0);
        assert!(b.is_empty());
    }

    #[test]
    fn charge_caps_at_capacity() {
        let mut b = Battery::new(1000.0, 0.95);
        for _ in 0..100 {
            b.charge(18.0, 600.0);
        }
        assert!((b.soc() - 1.0).abs() < 1e-9);
        assert_eq!(b.state(), BatteryState::NotDischarging);
    }

    #[test]
    fn bank_drain_and_charge_bit_identical_to_scalar() {
        check(25, |rng| {
            let n = 1 + rng.index(40);
            let mut scalars: Vec<Battery> = (0..n)
                .map(|_| {
                    Battery::new(
                        rng.range(800.0, 5000.0),
                        rng.range(0.02, 1.0),
                    )
                })
                .collect();
            let mut bank = BatteryBank::with_capacity(n);
            for b in &scalars {
                bank.push(b);
            }
            let mut energy = Vec::new();
            for _ in 0..12 {
                let power: Vec<f64> =
                    (0..n).map(|_| rng.range(0.1, 8.0)).collect();
                let dt: Vec<f64> =
                    (0..n).map(|_| rng.range(1.0, 4000.0)).collect();
                if rng.bool(0.5) {
                    bank.drain_all(&power, &dt, &mut energy);
                    for (k, b) in scalars.iter_mut().enumerate() {
                        let want = b.drain(power[k], dt[k]);
                        crate::prop_assert!(
                            energy[k].to_bits() == want.to_bits(),
                            "drain energy row {k}: {} vs {want}",
                            energy[k]
                        );
                    }
                } else {
                    bank.charge_all(&power, &dt);
                    for (k, b) in scalars.iter_mut().enumerate() {
                        b.charge(power[k], dt[k]);
                    }
                }
                for (k, b) in scalars.iter().enumerate() {
                    let row = bank.get(k);
                    crate::prop_assert!(
                        row.charge_c.to_bits() == b.charge_c.to_bits(),
                        "charge_c row {k}: {} vs {}",
                        row.charge_c,
                        b.charge_c
                    );
                    crate::prop_assert!(
                        row.state() == b.state(),
                        "state row {k}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn drain_then_charge_roundtrip() {
        check(50, |rng| {
            let mut b = Battery::new(4000.0, rng.range(0.3, 0.9));
            let s0 = b.soc();
            let p = rng.range(0.5, 6.0);
            let t = rng.range(10.0, 3000.0);
            b.drain(p, t);
            crate::prop_assert!(b.soc() <= s0, "drain raised soc");
            b.charge(p, t * 1.1);
            crate::prop_assert!(
                b.soc() >= s0 - 0.02,
                "roundtrip lost too much: {} -> {}",
                s0,
                b.soc()
            );
            Ok(())
        });
    }
}
