//! Battery model: coulomb-counted state of charge + Li-ion voltage curve.

/// Charging state as Android reports it (paper Appendix A.2 uses the
/// same three-valued signal derived from SoC deltas).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatteryState {
    Charging,
    NotDischarging, // full / maintenance
    Discharging,
}

/// A simulated Li-ion pack.
#[derive(Clone, Debug)]
pub struct Battery {
    /// Capacity in coulombs (mAh × 3.6).
    pub capacity_c: f64,
    /// Remaining charge in coulombs.
    pub charge_c: f64,
    state: BatteryState,
}

impl Battery {
    pub fn new(capacity_mah: f64, initial_soc: f64) -> Self {
        let capacity_c = capacity_mah * 3.6;
        Battery {
            capacity_c,
            charge_c: capacity_c * initial_soc.clamp(0.0, 1.0),
            state: BatteryState::Discharging,
        }
    }

    /// State of charge in [0, 1].
    pub fn soc(&self) -> f64 {
        (self.charge_c / self.capacity_c).clamp(0.0, 1.0)
    }

    /// Battery level as Android exposes it: integer percent. The paper's
    /// meter only sees this quantized signal.
    pub fn level_percent(&self) -> u32 {
        (self.soc() * 100.0).floor() as u32
    }

    /// Open-circuit voltage: piecewise-linear Li-ion curve 3.3–4.35 V.
    pub fn voltage(&self) -> f64 {
        let s = self.soc();
        // steep knee below 10%, plateau 3.7–3.9, fast rise above 90%
        if s < 0.10 {
            3.30 + s / 0.10 * 0.35
        } else if s < 0.90 {
            3.65 + (s - 0.10) / 0.80 * 0.35
        } else {
            4.00 + (s - 0.90) / 0.10 * 0.35
        }
    }

    pub fn state(&self) -> BatteryState {
        self.state
    }

    /// Drain `power_w` for `dt_s` seconds. Returns the energy actually
    /// removed (joules) — less than requested if the pack empties.
    pub fn drain(&mut self, power_w: f64, dt_s: f64) -> f64 {
        debug_assert!(power_w >= 0.0 && dt_s >= 0.0);
        self.state = BatteryState::Discharging;
        let current_a = power_w / self.voltage();
        let want_c = current_a * dt_s;
        let got_c = want_c.min(self.charge_c);
        self.charge_c -= got_c;
        got_c * self.voltage()
    }

    /// Charge with `power_w` for `dt_s` (charger inefficiency applied by
    /// the caller).
    pub fn charge(&mut self, power_w: f64, dt_s: f64) {
        debug_assert!(power_w >= 0.0 && dt_s >= 0.0);
        let current_a = power_w / self.voltage();
        self.charge_c = (self.charge_c + current_a * dt_s).min(self.capacity_c);
        self.state = if self.soc() >= 0.999 {
            BatteryState::NotDischarging
        } else {
            BatteryState::Charging
        };
    }

    /// Force the SoC (used when replaying recorded traces).
    pub fn set_soc(&mut self, soc: f64) {
        self.charge_c = self.capacity_c * soc.clamp(0.0, 1.0);
    }

    pub fn set_state(&mut self, state: BatteryState) {
        self.state = state;
    }

    pub fn is_empty(&self) -> bool {
        self.charge_c <= 0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    #[test]
    fn soc_and_percent() {
        let b = Battery::new(3000.0, 0.5);
        assert!((b.soc() - 0.5).abs() < 1e-12);
        assert_eq!(b.level_percent(), 50);
    }

    #[test]
    fn voltage_monotone_in_soc() {
        let mut prev = 0.0;
        for i in 0..=100 {
            let mut b = Battery::new(3000.0, 1.0);
            b.set_soc(i as f64 / 100.0);
            let v = b.voltage();
            assert!(v >= prev, "voltage not monotone at {i}%");
            assert!((3.2..=4.4).contains(&v));
            prev = v;
        }
    }

    #[test]
    fn drain_conserves_energy() {
        let mut b = Battery::new(3000.0, 1.0);
        let before = b.charge_c;
        let e = b.drain(2.0, 3600.0); // 2 W for an hour
        let used_c = before - b.charge_c;
        // E = Q × V (voltage varies little over one hour at 2 W)
        assert!((e - used_c * b.voltage()).abs() < 0.02 * e);
        assert!(b.soc() < 1.0);
    }

    #[test]
    fn drain_cannot_go_negative() {
        let mut b = Battery::new(100.0, 0.01);
        for _ in 0..100 {
            b.drain(50.0, 3600.0);
        }
        assert!(b.charge_c >= 0.0);
        assert!(b.is_empty());
    }

    #[test]
    fn charge_caps_at_capacity() {
        let mut b = Battery::new(1000.0, 0.95);
        for _ in 0..100 {
            b.charge(18.0, 600.0);
        }
        assert!((b.soc() - 1.0).abs() < 1e-9);
        assert_eq!(b.state(), BatteryState::NotDischarging);
    }

    #[test]
    fn drain_then_charge_roundtrip() {
        check(50, |rng| {
            let mut b = Battery::new(4000.0, rng.range(0.3, 0.9));
            let s0 = b.soc();
            let p = rng.range(0.5, 6.0);
            let t = rng.range(10.0, 3000.0);
            b.drain(p, t);
            crate::prop_assert!(b.soc() <= s0, "drain raised soc");
            b.charge(p, t * 1.1);
            crate::prop_assert!(
                b.soc() >= s0 - 0.02,
                "roundtrip lost too much: {} -> {}",
                s0,
                b.soc()
            );
            Ok(())
        });
    }
}
