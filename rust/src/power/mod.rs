//! Battery, charger, thermal and energy-metering models.
//!
//! Swan never reads ground-truth power: like the paper (Appendix B), it
//! estimates energy from battery state-of-charge drops through
//! [`meter::EnergyMeter`]. The battery/charger/thermal models below are
//! the simulated physical substrate those estimates are taken against.

pub mod battery;
pub mod charger;
pub mod meter;
pub mod thermal;

pub use battery::{Battery, BatteryState};
pub use charger::Charger;
pub use meter::EnergyMeter;
pub use thermal::Thermal;
