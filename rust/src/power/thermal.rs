//! One-node thermal RC model for battery/skin temperature.
//!
//! The paper gates training on battery temperature ≤ 35 °C (§4.1, citing
//! Li-ion aging and thermal-comfort studies). We model the battery node
//! with a first-order RC circuit driven by dissipated SoC power:
//!
//! ```text
//! C·dT/dt = κ·P − (T − T_ambient)/R
//! ```
//!
//! which gives the familiar exponential approach to `T_amb + κ·P·R`.

#[derive(Clone, Debug)]
pub struct Thermal {
    /// Battery/skin temperature, °C.
    pub temp_c: f64,
    /// Ambient, °C.
    pub ambient_c: f64,
    /// Thermal resistance, K/W (battery sees a fraction of SoC heat).
    pub r_k_per_w: f64,
    /// Thermal capacitance, J/K.
    pub c_j_per_k: f64,
    /// Fraction of SoC power that heats the battery node.
    pub coupling: f64,
}

impl Thermal {
    pub fn new(ambient_c: f64) -> Self {
        Thermal {
            temp_c: ambient_c,
            ambient_c,
            // steady state at 6 W sustained ≈ ambient + 6·0.62·3.4 ≈ +12.6 K
            r_k_per_w: 3.4,
            c_j_per_k: 45.0,
            coupling: 0.62,
        }
    }

    /// Advance by `dt_s` seconds with `power_w` dissipated in the SoC.
    pub fn step(&mut self, power_w: f64, dt_s: f64) {
        // exact discretization of the linear ODE over the interval
        let t_inf = self.ambient_c + self.coupling * power_w * self.r_k_per_w;
        let tau = self.r_k_per_w * self.c_j_per_k;
        let a = (-dt_s / tau).exp();
        self.temp_c = t_inf + (self.temp_c - t_inf) * a;
    }

    /// The paper's admission gate (§4.1).
    pub fn too_hot(&self) -> bool {
        self.temp_c > 35.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_stays_ambient() {
        let mut t = Thermal::new(24.0);
        for _ in 0..1000 {
            t.step(0.0, 10.0);
        }
        assert!((t.temp_c - 24.0).abs() < 1e-6);
        assert!(!t.too_hot());
    }

    #[test]
    fn sustained_load_heats_to_steady_state() {
        let mut t = Thermal::new(24.0);
        for _ in 0..10_000 {
            t.step(6.0, 10.0);
        }
        let expect = 24.0 + 0.62 * 6.0 * 3.4;
        assert!((t.temp_c - expect).abs() < 0.01, "{}", t.temp_c);
        assert!(t.too_hot(), "6 W sustained should cross 35°C from 24°C");
    }

    #[test]
    fn cools_back_down() {
        let mut t = Thermal::new(24.0);
        for _ in 0..10_000 {
            t.step(6.0, 10.0);
        }
        let hot = t.temp_c;
        for _ in 0..10_000 {
            t.step(0.0, 10.0);
        }
        assert!(t.temp_c < hot && (t.temp_c - 24.0).abs() < 0.1);
    }

    #[test]
    fn heating_is_monotone_under_constant_load() {
        let mut t = Thermal::new(20.0);
        let mut prev = t.temp_c;
        for _ in 0..100 {
            t.step(4.0, 30.0);
            assert!(t.temp_c >= prev);
            prev = t.temp_c;
        }
    }

    #[test]
    fn step_size_invariance() {
        // exact discretization: 1×600 s must equal 600×1 s
        let mut a = Thermal::new(22.0);
        let mut b = Thermal::new(22.0);
        a.step(5.0, 600.0);
        for _ in 0..600 {
            b.step(5.0, 1.0);
        }
        assert!((a.temp_c - b.temp_c).abs() < 1e-9);
    }
}
