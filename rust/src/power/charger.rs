//! Charger model with taper near full charge.
//!
//! §5.1 "Real-world energy budget": charging speeds vary with charger
//! power output and throttle to reduce battery wear. We model a fixed
//! rated power with a linear taper above 80% SoC — enough structure for
//! the energy-loan accounting without pretending to know each user's
//! brick.

use super::battery::Battery;

#[derive(Clone, Copy, Debug)]
pub struct Charger {
    /// Rated output, watts (5 W legacy … 30 W fast charge).
    pub rated_w: f64,
    /// Conversion efficiency into the pack.
    pub efficiency: f64,
}

impl Charger {
    pub fn new(rated_w: f64) -> Self {
        Charger {
            rated_w,
            efficiency: 0.85,
        }
    }

    /// Power delivered into the pack at the battery's current SoC.
    pub fn delivered_w(&self, battery: &Battery) -> f64 {
        let soc = battery.soc();
        let taper = if soc <= 0.80 {
            1.0
        } else {
            // linear taper 100% → 15% of rated over the last 20% SoC
            1.0 - 0.85 * (soc - 0.80) / 0.20
        };
        self.rated_w * self.efficiency * taper.max(0.0)
    }

    /// Advance charging by `dt_s`, net of a concurrent load drawing
    /// `load_w` from the rail. Returns true if still charging.
    pub fn step(&self, battery: &mut Battery, load_w: f64, dt_s: f64) -> bool {
        let p = self.delivered_w(battery) - load_w;
        if p >= 0.0 {
            battery.charge(p, dt_s);
            true
        } else {
            battery.drain(-p, dt_s);
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_power_below_80_percent() {
        let c = Charger::new(18.0);
        let b = Battery::new(4000.0, 0.5);
        assert!((c.delivered_w(&b) - 18.0 * 0.85).abs() < 1e-9);
    }

    #[test]
    fn tapers_above_80_percent() {
        let c = Charger::new(18.0);
        let mut prev = f64::INFINITY;
        for soc in [0.82, 0.88, 0.94, 0.99] {
            let mut b = Battery::new(4000.0, 1.0);
            b.set_soc(soc);
            let p = c.delivered_w(&b);
            assert!(p < prev && p > 0.0, "taper at {soc}");
            prev = p;
        }
    }

    #[test]
    fn heavy_load_wins_over_weak_charger() {
        let c = Charger::new(5.0);
        let mut b = Battery::new(3000.0, 0.5);
        let charging = c.step(&mut b, 8.0, 600.0);
        assert!(!charging);
        assert!(b.soc() < 0.5, "battery must drain under net-negative power");
    }

    #[test]
    fn charges_battery_over_time() {
        let c = Charger::new(18.0);
        let mut b = Battery::new(3000.0, 0.2);
        for _ in 0..60 {
            c.step(&mut b, 0.5, 60.0);
        }
        assert!(b.soc() > 0.5, "soc after an hour: {}", b.soc());
    }
}
