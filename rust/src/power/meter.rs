//! Appendix-B energy meter: estimate power from battery-level drops.
//!
//! The paper computes average power over each 1% SoC-drop interval as
//!
//! ```text
//! P = (V_start + V_end)/2 × (battery_capacity/100) / ΔT
//! ```
//!
//! and sums piecewise over intervals overlapping the benchmark. Swan
//! only ever sees this quantized, background-contaminated estimate —
//! never the simulator's ground truth — so the explorer inherits the
//! same measurement noise the real system has.

use super::battery::Battery;

/// One completed 1%-drop interval.
#[derive(Clone, Copy, Debug)]
pub struct DropInterval {
    pub t_start_s: f64,
    pub t_end_s: f64,
    pub v_start: f64,
    pub v_end: f64,
    /// Charge per percent, coulombs.
    pub coulombs: f64,
}

impl DropInterval {
    /// Appendix-B average power over the interval, watts.
    pub fn avg_power_w(&self) -> f64 {
        let dt = (self.t_end_s - self.t_start_s).max(1e-9);
        (self.v_start + self.v_end) / 2.0 * self.coulombs / dt
    }

    pub fn energy_j(&self) -> f64 {
        self.avg_power_w() * (self.t_end_s - self.t_start_s)
    }
}

/// Watches a battery's integer level and closes an interval each time
/// the percent counter drops.
#[derive(Clone, Debug)]
pub struct EnergyMeter {
    last_level: u32,
    interval_start_s: f64,
    interval_start_v: f64,
    /// The meter starts somewhere *inside* a percent, so the first
    /// boundary crossing closes a partial interval of unknown charge —
    /// it must be discarded, not averaged (a near-boundary start would
    /// otherwise read as a multi-kilowatt draw). Metering is "primed"
    /// only after that first crossing.
    primed: bool,
    pub intervals: Vec<DropInterval>,
}

impl EnergyMeter {
    pub fn start(battery: &Battery, now_s: f64) -> Self {
        EnergyMeter {
            last_level: battery.level_percent(),
            interval_start_s: now_s,
            interval_start_v: battery.voltage(),
            primed: false,
            intervals: Vec::new(),
        }
    }

    /// Poll the battery at time `now_s`; records intervals on 1% drops.
    pub fn poll(&mut self, battery: &Battery, now_s: f64) {
        let level = battery.level_percent();
        while level < self.last_level {
            self.last_level -= 1;
            if self.primed {
                self.intervals.push(DropInterval {
                    t_start_s: self.interval_start_s,
                    t_end_s: now_s,
                    v_start: self.interval_start_v,
                    v_end: battery.voltage(),
                    coulombs: battery.capacity_c / 100.0,
                });
            }
            self.primed = true;
            self.interval_start_s = now_s;
            self.interval_start_v = battery.voltage();
        }
        if level > self.last_level {
            // charging jumped the counter up; restart the measurement
            self.last_level = level;
            self.primed = false;
            self.interval_start_s = now_s;
            self.interval_start_v = battery.voltage();
        }
    }

    /// Piecewise total energy between `t0` and `t1` (Appendix B):
    /// intervals are clipped proportionally at the window edges.
    pub fn energy_between(&self, t0: f64, t1: f64) -> f64 {
        let mut total = 0.0;
        for iv in &self.intervals {
            let lo = iv.t_start_s.max(t0);
            let hi = iv.t_end_s.min(t1);
            if hi > lo {
                total += iv.avg_power_w() * (hi - lo);
            }
        }
        total
    }

    /// Mean estimated power over all recorded intervals.
    pub fn mean_power_w(&self) -> Option<f64> {
        if self.intervals.is_empty() {
            return None;
        }
        let e: f64 = self.intervals.iter().map(|iv| iv.energy_j()).sum();
        let t: f64 = self
            .intervals
            .iter()
            .map(|iv| iv.t_end_s - iv.t_start_s)
            .sum();
        Some(e / t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drain at a constant known power and check the meter recovers it.
    #[test]
    fn recovers_constant_power_within_quantization() {
        let mut b = Battery::new(3000.0, 0.80);
        let mut m = EnergyMeter::start(&b, 0.0);
        let p_true = 3.0;
        let dt = 10.0;
        let mut t = 0.0;
        for _ in 0..2000 {
            b.drain(p_true, dt);
            t += dt;
            m.poll(&b, t);
        }
        assert!(m.intervals.len() >= 3, "need several 1% drops");
        let p_est = m.mean_power_w().unwrap();
        assert!(
            (p_est - p_true).abs() / p_true < 0.05,
            "estimated {p_est} vs true {p_true}"
        );
    }

    #[test]
    fn energy_between_clips_window() {
        let iv = DropInterval {
            t_start_s: 0.0,
            t_end_s: 100.0,
            v_start: 3.8,
            v_end: 3.8,
            coulombs: 108.0,
        };
        let m = EnergyMeter {
            last_level: 50,
            interval_start_s: 100.0,
            interval_start_v: 3.8,
            primed: true,
            intervals: vec![iv],
        };
        let full = m.energy_between(0.0, 100.0);
        let half = m.energy_between(25.0, 75.0);
        assert!((half - full / 2.0).abs() < 1e-9);
        assert_eq!(m.energy_between(200.0, 300.0), 0.0);
    }

    #[test]
    fn first_partial_interval_discarded() {
        // start the meter a hair above a percent boundary: the first
        // crossing must NOT produce a (huge-power) interval
        let mut b = Battery::new(3000.0, 0.85001);
        let mut m = EnergyMeter::start(&b, 0.0);
        b.drain(3.0, 10.0); // crosses into 84% almost immediately
        m.poll(&b, 10.0);
        assert!(m.intervals.is_empty(), "partial interval was recorded");
        // the NEXT full percent is recorded with a sane power
        let mut t = 10.0;
        while m.intervals.is_empty() {
            b.drain(3.0, 10.0);
            t += 10.0;
            m.poll(&b, t);
        }
        let p = m.intervals[0].avg_power_w();
        assert!((p - 3.0).abs() < 0.5, "power {p}");
    }

    #[test]
    fn no_intervals_no_power() {
        let b = Battery::new(3000.0, 0.5);
        let m = EnergyMeter::start(&b, 0.0);
        assert!(m.mean_power_w().is_none());
    }

    #[test]
    fn charging_resets_interval() {
        let mut b = Battery::new(3000.0, 0.50);
        let mut m = EnergyMeter::start(&b, 0.0);
        b.drain(5.0, 2000.0);
        m.poll(&b, 2000.0);
        let n_before = m.intervals.len();
        b.charge(10.0, 4000.0);
        m.poll(&b, 6000.0);
        b.drain(5.0, 2000.0);
        m.poll(&b, 8000.0);
        // intervals recorded after the charge restart must not span it
        for iv in &m.intervals[n_before..] {
            assert!(iv.t_start_s >= 6000.0);
        }
    }
}
