//! `swan lint` self-application: the shipped tree must be clean under
//! `--deny-all`, and the known-bad fixture tree must light up every
//! rule family. Together these pin both directions of the analyzer —
//! no false positives on real code, no false negatives on planted
//! violations — so a lexer or scope regression fails CI before it can
//! rot the determinism/panic-safety guarantees.

use swan::lint::{failing, lint_paths, Finding};

fn repo_path(rel: &str) -> String {
    // cargo runs integration tests with cwd = package root
    format!("{}/{}", env!("CARGO_MANIFEST_DIR"), rel)
}

fn rule_count(findings: &[Finding], rule: &str) -> usize {
    findings.iter().filter(|f| f.rule == rule).count()
}

#[test]
fn shipped_tree_is_clean_under_deny_all() {
    let findings = lint_paths(&[repo_path("rust/src")]).unwrap();
    let failures: Vec<String> = findings
        .iter()
        .map(|f| format!("{}:{} [{}] {}", f.file, f.line, f.rule, f.message))
        .collect();
    assert_eq!(
        failing(&findings, true),
        0,
        "shipped tree has lint findings:\n{}",
        failures.join("\n")
    );
}

#[test]
fn fixture_tree_fails_in_every_rule_family() {
    let findings =
        lint_paths(&[repo_path("rust/lint-fixtures")]).unwrap();
    // fleet/soa.rs fixture: wall clock + 2 hash iterations, 3 panic
    // sites, 1 bare unsafe
    assert_eq!(rule_count(&findings, "determinism"), 3);
    assert_eq!(rule_count(&findings, "panic"), 3);
    assert_eq!(rule_count(&findings, "unsafe"), 1);
    // fl/selection.rs fixture: 2 unregistered RNG sites; the third is
    // suppressed by the reason-less pragma, which is itself a finding
    assert_eq!(rule_count(&findings, "rng"), 2);
    assert!(
        rule_count(&findings, "pragma") >= 3,
        "unused + reason-less + unknown-rule pragmas must all fire: {:?}",
        findings
            .iter()
            .filter(|f| f.rule == "pragma")
            .collect::<Vec<_>>()
    );
    // fixture paths map onto module-relative names, so scopes applied
    assert!(findings.iter().any(|f| f.file.ends_with("fleet/soa.rs")));
    assert!(
        findings.iter().any(|f| f.file.ends_with("fl/selection.rs"))
    );
    // deny-only findings fail even without --deny-all; panic warns
    // need the strict flag
    let strict = failing(&findings, true);
    let lax = failing(&findings, false);
    assert!(strict > lax, "panic findings must be warn-severity");
    assert!(lax > 0, "deny findings must fail a default run");
}

#[test]
fn single_file_paths_work_too() {
    let findings = lint_paths(&[repo_path(
        "rust/lint-fixtures/fleet/soa.rs",
    )])
    .unwrap();
    assert!(rule_count(&findings, "determinism") > 0);
    assert_eq!(rule_count(&findings, "rng"), 0);
}

#[test]
fn missing_path_is_an_error_not_a_clean_pass() {
    assert!(lint_paths(&[repo_path("rust/no-such-dir")]).is_err());
}
