//! Telemetry-spine acceptance tests.
//!
//! Two contracts are pinned here:
//!
//! 1. **NDJSON well-formedness** — every line the fleet drive emits
//!    must parse back through `util::json`, carry a non-empty string
//!    `reason` and a monotonically increasing numeric `seq`, and stay
//!    one physical line even when scenario names contain quotes,
//!    newlines or backslashes (property-style over random specs,
//!    matching the `swan_properties` idiom).
//! 2. **Digest neutrality** — turning telemetry on must not perturb a
//!    single bit of any aggregate, at 1 and 4 shards/lanes, on both
//!    the fleet and serve paths — including with per-device causal
//!    tracing (`with_traces`) enabled. Telemetry only observes
//!    existing barriers; it never draws RNG or reorders folds.
//!
//! Plus the bench contract: the `bench-result` event nested in the
//! stream must agree with the `BENCH_fleet.json` snapshot the same run
//! writes.

use swan::fl::FlArm;
use swan::fleet::{
    run_fleet_bench, run_scenario, run_scenario_obs, ScenarioSpec,
};
use swan::obs::Obs;
use swan::prop_assert;
use swan::serve::{run_inproc, run_inproc_with, ServeConfig};
use swan::util::check::check;
use swan::util::json;

fn tiny_spec(name: &str, devices: usize, rounds: usize) -> ScenarioSpec {
    ScenarioSpec {
        name: name.to_string(),
        devices,
        rounds,
        clients_per_round: 8,
        trace_users: 2,
        ..ScenarioSpec::default()
    }
}

#[test]
fn every_emitted_line_is_well_formed_ndjson() {
    // hostile names exercise the writer's escaping: embedded quotes,
    // newlines, tabs, backslashes and braces must all stay inside one
    // escaped JSON string on one physical line
    const NAMES: [&str; 4] = [
        "plain",
        "qu\"ote{d}",
        "new\nline\twith\\slash",
        "µ-unicode",
    ];
    check(6, |rng| {
        let spec = ScenarioSpec {
            name: NAMES[rng.index(NAMES.len())].to_string(),
            devices: 12 + rng.index(37),
            rounds: 1 + rng.index(3),
            clients_per_round: 4,
            trace_users: 1 + rng.index(2),
            seed: rng.next_u64(),
            ..ScenarioSpec::default()
        };
        let shards = 1 + rng.index(3);
        let arm = if rng.bool(0.5) {
            FlArm::Swan
        } else {
            FlArm::Baseline
        };
        let obs = Obs::capture();
        run_scenario_obs(&spec, shards, arm, &obs)
            .map_err(|e| e.to_string())?;
        let lines = obs.captured_lines();
        prop_assert!(!lines.is_empty(), "run emitted no events");
        let mut last_seq = -1.0f64;
        let mut reasons: Vec<String> = Vec::new();
        for line in &lines {
            prop_assert!(
                !line.contains('\n'),
                "NDJSON record spans lines: {line:?}"
            );
            let v = json::parse(line)
                .map_err(|e| format!("bad JSON ({e}): {line}"))?;
            let reason =
                v.req_str("reason").map_err(|e| e.to_string())?;
            prop_assert!(!reason.is_empty(), "empty reason: {line}");
            reasons.push(reason.to_string());
            let seq = v.req_f64("seq").map_err(|e| e.to_string())?;
            prop_assert!(
                seq > last_seq,
                "seq not increasing: {seq} after {last_seq}"
            );
            last_seq = seq;
            // events that carry the scenario name must round-trip it
            if let Some(s) = v.get("scenario").and_then(|s| s.as_str())
            {
                prop_assert!(
                    s == spec.name,
                    "scenario name mangled: {s:?} vs {:?}",
                    spec.name
                );
            }
        }
        // the stream must carry the round lifecycle + terminal rollup
        for want in ["round-start", "round-end", "span-summary"] {
            prop_assert!(
                reasons.iter().any(|r| r == want),
                "missing '{want}' event in {reasons:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn fleet_telemetry_is_digest_neutral() {
    let spec = tiny_spec("obs-neutral", 240, 4);
    for shards in [1usize, 4] {
        let off = run_scenario(&spec, shards, FlArm::Swan)
            .expect("telemetry-off run");
        let obs = Obs::capture();
        let on = run_scenario_obs(&spec, shards, FlArm::Swan, &obs)
            .expect("telemetry-on run");
        assert!(!obs.captured_lines().is_empty(), "capture saw events");
        assert_eq!(off.digest(), on.digest(), "{shards} shards");
        assert_eq!(
            off.total_time_s.to_bits(),
            on.total_time_s.to_bits(),
            "{shards} shards: virtual time"
        );
        assert_eq!(
            off.total_energy_j.to_bits(),
            on.total_energy_j.to_bits(),
            "{shards} shards: energy"
        );
        assert_eq!(off.total_steps, on.total_steps);
        assert_eq!(off.participations, on.participations);
        assert_eq!(off.online_per_round, on.online_per_round);

        // full causal tracing is still a pure observer
        let tobs = Obs::capture().with_traces();
        let traced = run_scenario_obs(&spec, shards, FlArm::Swan, &tobs)
            .expect("traced run");
        assert_eq!(off.digest(), traced.digest(), "{shards} shards traced");
        assert_eq!(
            off.total_time_s.to_bits(),
            traced.total_time_s.to_bits(),
            "{shards} shards traced: virtual time"
        );
        assert_eq!(
            off.total_energy_j.to_bits(),
            traced.total_energy_j.to_bits(),
            "{shards} shards traced: energy"
        );
        let edges = tobs
            .captured_lines()
            .iter()
            .filter(|l| l.contains("\"trace-edge\""))
            .count();
        assert!(
            edges > 0,
            "{shards} shards: traced fleet run emitted no trace edges"
        );
    }
}

#[test]
fn serve_telemetry_is_digest_neutral() {
    let spec = tiny_spec("obs-serve-neutral", 240, 4);
    let cfg = ServeConfig::for_scenario(&spec);
    for lanes in [1usize, 4] {
        let (off, _) =
            run_inproc(&spec, lanes, &cfg).expect("telemetry-off run");
        let obs = Obs::capture();
        let (on, _) = run_inproc_with(&spec, lanes, &cfg, &obs)
            .expect("telemetry-on run");
        assert_eq!(off.digest, on.digest, "{lanes} lanes");
        assert_eq!(off.participations, on.participations);
        assert_eq!(off.rounds_run, on.rounds_run);
        assert_eq!(
            off.total_time_s.to_bits(),
            on.total_time_s.to_bits(),
            "{lanes} lanes: virtual time"
        );
        assert_eq!(
            off.total_energy_j.to_bits(),
            on.total_energy_j.to_bits(),
            "{lanes} lanes: energy"
        );
        // the serve stream carries admission + cache telemetry
        let reasons: Vec<String> = obs
            .captured_lines()
            .iter()
            .map(|l| {
                json::parse(l)
                    .expect("well-formed line")
                    .req_str("reason")
                    .expect("reason present")
                    .to_string()
            })
            .collect();
        for want in ["checkin-batch", "round-end", "cache-hit-miss"] {
            assert!(
                reasons.iter().any(|r| r == want),
                "{lanes} lanes: missing '{want}' in {reasons:?}"
            );
        }

        // full causal tracing is still a pure observer
        let tobs = Obs::capture().with_traces();
        let (traced, _) = run_inproc_with(&spec, lanes, &cfg, &tobs)
            .expect("traced run");
        assert_eq!(off.digest, traced.digest, "{lanes} lanes traced");
        assert_eq!(
            off.total_time_s.to_bits(),
            traced.total_time_s.to_bits(),
            "{lanes} lanes traced: virtual time"
        );
        assert_eq!(
            off.total_energy_j.to_bits(),
            traced.total_energy_j.to_bits(),
            "{lanes} lanes traced: energy"
        );
        assert!(
            tobs.captured_lines()
                .iter()
                .any(|l| l.contains("\"trace-edge\"")),
            "{lanes} lanes: traced serve run emitted no trace edges"
        );
    }
}

#[test]
fn traced_serve_stream_reconstructs_complete_lifecycles() {
    use swan::obs::analyze::{self, lifecycles};

    let spec = tiny_spec("obs-lifecycle", 240, 3);
    let cfg = ServeConfig::for_scenario(&spec);
    let obs = Obs::capture().with_traces();
    let (out, _) = run_inproc_with(&spec, 2, &cfg, &obs)
        .expect("traced serve run");
    assert!(out.participations > 0, "run selected no participants");

    let events: Vec<_> = obs
        .captured_lines()
        .iter()
        .map(|l| json::parse(l).expect("well-formed line"))
        .collect();
    let lcs = lifecycles(&events);
    assert!(!lcs.is_empty(), "no lifecycles reconstructed");
    // at least one device rode the full happy path: checkin →
    // admitted → selected → lease-sent → update-received → aggregated,
    // with monotone timestamps
    let complete: Vec<_> = lcs
        .iter()
        .filter(|lc| lc.is_complete_admitted())
        .collect();
    assert!(
        !complete.is_empty(),
        "no complete admitted lifecycle among {} lifecycles",
        lcs.len()
    );
    // attribution + rates run off the same reconstruction
    let stages = analyze::top_stages(&lcs);
    assert!(
        stages
            .iter()
            .any(|(k, _)| k == "checkin\u{2192}admitted"),
        "checkin→admitted stage missing from {stages:?}"
    );
    let rates = analyze::windowed_rates(&events, 1.0);
    let checkins: u64 = rates.iter().map(|r| r.checkins).sum();
    assert!(checkins > 0, "windowed rates saw no check-ins");
}

#[test]
fn bench_result_event_agrees_with_the_written_snapshot() {
    let spec = tiny_spec("obs-bench-agree", 240, 4);
    let obs = Obs::capture();
    let report = run_fleet_bench(&spec, &[2], FlArm::Swan, false, &obs)
        .expect("fleet bench");
    let path = std::env::temp_dir().join(format!(
        "obs_stream_BENCH_fleet_{}.json",
        std::process::id()
    ));
    report.write_json(&path).expect("write snapshot");
    let from_file = json::parse_file(&path).expect("snapshot parses");
    std::fs::remove_file(&path).ok();

    let mut records = Vec::new();
    for line in obs.captured_lines() {
        let v = json::parse(&line).expect("well-formed line");
        if v.req_str("reason").unwrap() == "bench-result" {
            assert_eq!(v.req_str("bench").unwrap(), "fleet");
            records.push(v.req("record").unwrap().clone());
        }
    }
    assert_eq!(records.len(), 1, "exactly one bench-result event");
    // the nested record and the BENCH_fleet.json snapshot are the same
    // report: value-identical after the file round-trip
    assert_eq!(records[0], from_file);
    assert_eq!(records[0].req_str("digest").unwrap(), report.digest);
    assert_eq!(
        from_file.req_f64("best_devices_stepped_per_sec").unwrap(),
        report.best_soa().devices_stepped_per_sec()
    );
}
