//! Cross-module property suite (no artifacts required): randomized
//! invariants over the simulator, the Swan engine, and the trace
//! pipeline — the places where a silent modeling bug would quietly
//! invalidate the paper tables.

use swan::prop_assert;
use swan::sim::interference::SessionGenerator;
use swan::sim::pcmark::pcmark_score;
use swan::sim::SimPhone;
use swan::soc::device::{all_devices, device, DeviceId};
use swan::soc::exec_model::{estimate, ExecutionContext};
use swan::swan::choice::enumerate_choices;
use swan::swan::cost::cost_key;
use swan::swan::explorer::Explorer;
use swan::swan::prune::prune_dominated;
use swan::swan::{SwanConfig, SwanEngine};
use swan::trace::augment::augment_shifts;
use swan::trace::greenhub::TraceGenerator;
use swan::trace::resample::resample_trace;
use swan::util::check::check;
use swan::workload::{builtin, WorkloadName};

const DEVICES: [DeviceId; 5] = [
    DeviceId::Pixel3,
    DeviceId::S10e,
    DeviceId::OnePlus8,
    DeviceId::TabS6,
    DeviceId::Mi10,
];

const WORKLOADS: [WorkloadName; 3] = [
    WorkloadName::Resnet34,
    WorkloadName::MobilenetV2,
    WorkloadName::ShufflenetV2,
];

/// The explorer's measured ordering must agree with the ground-truth
/// model's ordering on an idle phone — otherwise Swan's decisions would
/// be artifacts of the measurement pipeline, not the hardware.
#[test]
fn exploration_ranking_matches_ground_truth_everywhere() {
    for dev in DEVICES {
        for wl in WORKLOADS {
            let d = device(dev);
            let w = builtin(wl);
            let mut phone = SimPhone::new(d.clone(), 99);
            let profiles = Explorer::default().explore_all(&mut phone, &w);
            let ctx = ExecutionContext::exclusive(d.n_cores());
            let mut truth: Vec<(String, f64)> = enumerate_choices(&d)
                .into_iter()
                .map(|ch| {
                    (ch.label(), estimate(&d, &w, &ch.cores, &ctx).latency_s)
                })
                .collect();
            truth.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            let mut measured: Vec<(String, f64)> = profiles
                .iter()
                .map(|p| (p.choice.label(), p.latency_s))
                .collect();
            measured.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            let t_order: Vec<&String> = truth.iter().map(|x| &x.0).collect();
            let m_order: Vec<&String> =
                measured.iter().map(|x| &x.0).collect();
            assert_eq!(t_order, m_order, "{dev:?}/{wl:?}");
        }
    }
}

/// Pruned chains are strict Pareto frontiers for every device × model.
#[test]
fn pruned_chains_are_pareto_frontiers() {
    for dev in DEVICES {
        for wl in WORKLOADS {
            let d = device(dev);
            let w = builtin(wl);
            let ctx = ExecutionContext::exclusive(d.n_cores());
            let profiles: Vec<_> = enumerate_choices(&d)
                .into_iter()
                .map(|ch| {
                    let est = estimate(&d, &w, &ch.cores, &ctx);
                    swan::swan::profile::ChoiceProfile {
                        choice: ch,
                        latency_s: est.latency_s,
                        energy_j: est.energy_j,
                        power_w: est.avg_power_w,
                        steps_measured: 1,
                    }
                })
                .collect();
            let chain = prune_dominated(profiles.clone());
            // every kept choice: nothing in the FULL set is both faster
            // and not-costlier
            for kept in &chain {
                for other in &profiles {
                    let faster = other.latency_s < kept.latency_s - 1e-12;
                    let not_costlier =
                        cost_key(&other.choice) <= cost_key(&kept.choice);
                    assert!(
                        !(faster && not_costlier
                            && other.choice.label() != kept.choice.label()),
                        "{dev:?}/{wl:?}: {} dominated by {}",
                        kept.choice.label(),
                        other.choice.label()
                    );
                }
            }
        }
    }
}

/// Anti-scaling is a depthwise phenomenon: on every device, ShuffleNet's
/// greedy choice loses to the best single core, while ResNet-34's greedy
/// choice is at worst mildly suboptimal.
#[test]
fn antiscaling_depthwise_only() {
    for dev in DEVICES {
        let d = device(dev);
        let ctx = ExecutionContext::exclusive(d.n_cores());
        let greedy = d.low_latency_cores();
        let best_single = |w: &swan::workload::Workload| {
            (4..d.n_cores())
                .map(|c| estimate(&d, w, &[c], &ctx).latency_s)
                .fold(f64::INFINITY, f64::min)
        };
        let sn = builtin(WorkloadName::ShufflenetV2);
        let rn = builtin(WorkloadName::Resnet34);
        let sn_greedy = estimate(&d, &sn, &greedy, &ctx).latency_s;
        let rn_greedy = estimate(&d, &rn, &greedy, &ctx).latency_s;
        assert!(
            sn_greedy > best_single(&sn),
            "{dev:?}: shufflenet must anti-scale"
        );
        assert!(
            rn_greedy < 1.05 * best_single(&rn) * 4.0,
            "{dev:?}: resnet greedy should be near-linear"
        );
    }
}

/// PCMark scores degrade monotonically as training occupies more of the
/// cores the foreground uses.
#[test]
fn pcmark_monotone_in_contention() {
    for dev in DEVICES {
        let d = device(dev);
        let ll = d.low_latency_cores();
        let mut prev = f64::INFINITY;
        for k in 0..=ll.len() {
            let score = pcmark_score(&d, &ll[..k]);
            assert!(
                score <= prev + 1e-9,
                "{dev:?}: score rose when adding training threads"
            );
            prev = score;
        }
    }
}

/// Randomized engine fuzz: arbitrary session patterns and step counts
/// never panic, never leave the chain, and the device's battery/thermal
/// state stays physical.
#[test]
fn engine_fuzz_under_random_sessions() {
    check(12, |rng| {
        let dev = DEVICES[rng.index(5)];
        let wl = WORKLOADS[rng.index(3)];
        let d = device(dev);
        let mut phone = SimPhone::new(d.clone(), rng.next_u64());
        let mut engine = SwanEngine::explore_and_build(
            &mut phone,
            builtin(wl),
            SwanConfig::default(),
        );
        phone.sessions = SessionGenerator::new(
            rng.next_u64(),
            rng.range(50.0, 2000.0),
            rng.range(30.0, 600.0),
            rng.f64(),
        );
        for _ in 0..40 {
            let rep = engine.run_local_step(&mut phone, || {});
            prop_assert!(rep.latency_s > 0.0, "nonpositive latency");
            prop_assert!(
                phone.battery.soc() >= 0.0 && phone.battery.soc() <= 1.0,
                "soc out of range"
            );
            prop_assert!(
                phone.thermal.temp_c > 0.0 && phone.thermal.temp_c < 90.0,
                "temperature absurd: {}",
                phone.thermal.temp_c
            );
        }
        Ok(())
    });
}

/// Trace pipeline invariants over a random population.
#[test]
fn trace_pipeline_invariants() {
    let gen = TraceGenerator::default();
    let traces = gen.population(123, 6);
    let resampled: Vec<_> = traces
        .iter()
        .filter(|t| swan::trace::filter::passes_quality_filters(t))
        .map(|t| resample_trace(t).unwrap())
        .collect();
    assert!(!resampled.is_empty());
    for rs in &resampled {
        for &s in &rs.state {
            assert!((-1..=1).contains(&(s as i32)));
        }
        for &l in &rs.level {
            assert!((0.0..=100.0).contains(&l));
        }
        // availability exists: some charging samples in 28+ days
        assert!(rs.state.iter().any(|&s| s > 0));
        assert!(rs.state.iter().any(|&s| s < 0));
    }
    let aug = augment_shifts(&resampled);
    assert_eq!(aug.len(), resampled.len() * 24);
    // augmentation preserves each trace's level multiset
    let sum0: f64 = resampled[0].level.iter().sum();
    for k in 0..24 {
        let sum_k: f64 = aug[k].level.iter().sum();
        assert!((sum_k - sum0).abs() < 1e-6);
    }
}

/// Exploration must leave the battery able to explain the energy it
/// reports: per-choice energies are positive and the battery lost at
/// least the sum of what the profiles claim (background services only
/// add on top).
#[test]
fn exploration_energy_accounting_consistent() {
    for dev in [DeviceId::Pixel3, DeviceId::S10e] {
        let d = device(dev);
        let w = builtin(WorkloadName::MobilenetV2);
        let mut phone = SimPhone::new(d.clone(), 5);
        let q0 = phone.battery.charge_c;
        let profiles = Explorer::default().explore_all(&mut phone, &w);
        let v = phone.battery.voltage();
        let battery_spent = (q0 - phone.battery.charge_c) * v;
        let claimed: f64 = profiles
            .iter()
            .map(|p| p.energy_j * p.steps_measured as f64)
            .sum();
        assert!(claimed > 0.0);
        assert!(
            claimed <= battery_spent * 1.10,
            "{dev:?}: profiles claim {claimed} J but battery lost only \
             {battery_spent} J"
        );
    }
}

/// All devices: greedy baseline power is the highest of any choice's
/// power (it lights every low-latency core), so Table 3's premise — the
/// baseline maximally contends — holds by construction.
#[test]
fn greedy_is_peak_power_choice() {
    for dev in DEVICES {
        let d = device(dev);
        let w = builtin(WorkloadName::Resnet34);
        let ctx = ExecutionContext::exclusive(d.n_cores());
        let greedy_p =
            estimate(&d, &w, &d.low_latency_cores(), &ctx).avg_power_w;
        for ch in enumerate_choices(&d) {
            let p = estimate(&d, &w, &ch.cores, &ctx).avg_power_w;
            assert!(
                p <= greedy_p + 1e-9,
                "{dev:?}: {} draws more power than greedy",
                ch.label()
            );
        }
    }
}

/// Device database consistency with the choice space: the number of
/// enumerable choices is (nb+1)(np+1)-1 + nl.
#[test]
fn choice_space_cardinality() {
    for d in all_devices() {
        let nb = d
            .cores_of_kind(swan::soc::core::CoreKind::Big)
            .len();
        let np = d
            .cores_of_kind(swan::soc::core::CoreKind::Prime)
            .len();
        let nl = d
            .cores_of_kind(swan::soc::core::CoreKind::Little)
            .len();
        let expect = (nb + 1) * (np + 1) - 1 + nl;
        assert_eq!(enumerate_choices(&d).len(), expect, "{:?}", d.id);
    }
}
