//! Integration: the FL simulator end to end with real PJRT numerics —
//! a miniature of the §5.3 evaluation (small fleet, short horizon).
//!
//! QUARANTINE: every test touching the PJRT runtime is `#[ignore]`d —
//! the artifacts (`artifacts/*.hlo.txt`) are not checked in and the
//! offline build links the `src/xla.rs` stub instead of the real
//! bindings. Run `make artifacts` and build with the real `xla` crate,
//! then `cargo test -- --ignored`, to exercise them.

use swan::fl::{FlArm, FlConfig, FlSim};
use swan::runtime::{ModelExecutor, Registry, RuntimeClient};
use swan::train::data::SyntheticDataset;
use swan::workload::{load_or_builtin, WorkloadName};

fn registry_or_skip() -> Option<Registry> {
    match Registry::discover() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

fn tiny_cfg(rounds: usize) -> FlConfig {
    FlConfig {
        seed: 3,
        raw_traces: 8,
        quality_traces: 2, // × 24 shifts = 48 clients
        clients_per_round: 3,
        local_steps: 5,
        rounds,
        eval_every: 3,
        eval_batches: 2,
        daily_credit_j: 2_000.0,
        server_overhead_s: 0.5,
    }
}

#[test]
#[ignore = "needs artifacts/*.hlo.txt (`make artifacts`) + real xla PJRT bindings; the offline build ships the stub in src/xla.rs"]
fn fl_swan_beats_baseline_on_time_and_energy() {
    let Some(reg) = registry_or_skip() else { return };
    let client = RuntimeClient::cpu().unwrap();
    let exec =
        ModelExecutor::load(&client, &reg.dir, "shufflenet_s").unwrap();
    let workload = load_or_builtin(WorkloadName::ShufflenetV2, "artifacts");

    let mut run = |arm: FlArm| {
        let ds = SyntheticDataset::vision(2);
        let mut sim = FlSim::new(tiny_cfg(12), arm, ds, &workload).unwrap();
        sim.run(&exec).unwrap()
    };
    let swan = run(FlArm::Swan);
    let base = run(FlArm::Baseline);

    assert_eq!(swan.rounds_run, 12);
    assert_eq!(base.rounds_run, 12);
    // same number of learning steps → similar best accuracy, but Swan's
    // virtual clock advanced far less (Table 4's time-to-accuracy win)
    assert!(
        base.total_time_s > 3.0 * swan.total_time_s,
        "swan {:.0}s vs baseline {:.0}s",
        swan.total_time_s,
        base.total_time_s
    );
    assert!(
        base.total_energy_j > 3.0 * swan.total_energy_j,
        "swan {:.0}J vs baseline {:.0}J",
        swan.total_energy_j,
        base.total_energy_j
    );
    // learning is real: eval loss improves from the first to the best
    // evaluation (accuracy on a 32-sample eval is too coarse to gate on)
    for out in [&swan, &base] {
        let first = out.loss_curve.points.first().unwrap().1;
        let best = out.loss_curve.best(false).unwrap();
        assert!(
            best < first - 0.05,
            "[{}] loss {first:.3} -> best {best:.3}",
            out.arm
        );
    }
}

#[test]
#[ignore = "needs artifacts/*.hlo.txt (`make artifacts`) + real xla PJRT bindings; the offline build ships the stub in src/xla.rs"]
fn fl_online_population_not_degenerate() {
    let Some(reg) = registry_or_skip() else { return };
    let client = RuntimeClient::cpu().unwrap();
    let exec =
        ModelExecutor::load(&client, &reg.dir, "shufflenet_s").unwrap();
    let workload = load_or_builtin(WorkloadName::ShufflenetV2, "artifacts");
    let ds = SyntheticDataset::vision(4);
    let mut sim =
        FlSim::new(tiny_cfg(6), FlArm::Swan, ds, &workload).unwrap();
    let out = sim.run(&exec).unwrap();
    assert_eq!(out.online_per_round.len(), 6);
    // some clients online in most rounds
    let nonzero = out
        .online_per_round
        .iter()
        .filter(|(_, n)| *n > 0)
        .count();
    assert!(nonzero >= 4, "online series: {:?}", out.online_per_round);
    // loss curve recorded and finite
    assert!(!out.loss_curve.points.is_empty());
    for (_, l) in &out.loss_curve.points {
        assert!(l.is_finite());
    }
}

#[test]
#[ignore = "needs artifacts/*.hlo.txt (`make artifacts`) + real xla PJRT bindings; the offline build ships the stub in src/xla.rs"]
fn fl_deterministic_given_seed() {
    let Some(reg) = registry_or_skip() else { return };
    let client = RuntimeClient::cpu().unwrap();
    let exec =
        ModelExecutor::load(&client, &reg.dir, "shufflenet_s").unwrap();
    let workload = load_or_builtin(WorkloadName::ShufflenetV2, "artifacts");
    let mut run = || {
        let ds = SyntheticDataset::vision(2);
        let mut sim =
            FlSim::new(tiny_cfg(4), FlArm::Swan, ds, &workload).unwrap();
        sim.run(&exec).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.total_time_s, b.total_time_s);
    assert_eq!(a.accuracy_curve.points, b.accuracy_curve.points);
    assert_eq!(a.online_per_round, b.online_per_round);
}

#[test]
fn fl_baseline_loses_clients_swan_keeps_them() {
    // Figs 5b/6b: over a long systems-only horizon the baseline's energy
    // loans exhaust devices while Swan's fleet stays online. (No
    // artifacts needed — availability is numerics-independent.)
    let workload = load_or_builtin(WorkloadName::ShufflenetV2, "artifacts");
    let cfg = FlConfig {
        seed: 9,
        raw_traces: 16,
        quality_traces: 4,
        clients_per_round: 20,
        local_steps: 5,
        rounds: 0,
        eval_every: 1,
        eval_batches: 1,
        daily_credit_j: 400.0,
        server_overhead_s: 0.5,
    };
    let run = |arm: FlArm| {
        let ds = SyntheticDataset::vision(cfg.seed);
        let mut sim = FlSim::new(cfg.clone(), arm, ds, &workload).unwrap();
        sim.run_systems_only(4000).unwrap()
    };
    let swan = run(FlArm::Swan);
    let base = run(FlArm::Baseline);
    let tail = |o: &swan::fl::FlOutcome| {
        let n = o.online_per_round.len();
        o.online_per_round[n - 200..]
            .iter()
            .map(|(_, c)| *c)
            .sum::<usize>() as f64
            / 200.0
    };
    let head = |o: &swan::fl::FlOutcome| {
        o.online_per_round[..200]
            .iter()
            .map(|(_, c)| *c)
            .sum::<usize>() as f64
            / 200.0
    };
    assert!(
        tail(&base) < 0.8 * head(&base),
        "baseline must lose clients: {} -> {}",
        head(&base),
        tail(&base)
    );
    assert!(
        tail(&swan) > 0.95 * head(&swan),
        "swan must keep clients: {} -> {}",
        head(&swan),
        tail(&swan)
    );
}
