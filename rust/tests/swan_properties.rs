//! Property suite (via `util::check`) for the two pieces of Swan the
//! whole scheduler stack leans on: the relinquish-cost **total order**
//! (§4.3) and `prune_dominated` (§4.3's Pareto chain). A silent bug in
//! either would skew every policy decision the FL/fleet harnesses make.

use swan::prop_assert;
use swan::soc::device::{device, DeviceId};
use swan::swan::choice::enumerate_choices;
use swan::swan::cost::{cost_key, costlier};
use swan::swan::profile::ChoiceProfile;
use swan::swan::prune::prune_dominated;
use swan::util::check::check;

const DEVICES: [DeviceId; 5] = [
    DeviceId::Pixel3,
    DeviceId::S10e,
    DeviceId::OnePlus8,
    DeviceId::TabS6,
    DeviceId::Mi10,
];

/// Random sub-population of a random device's choice space with random
/// measured latencies/energies — prune must behave for ANY profile set,
/// not just the exec-model's.
fn random_profiles(rng: &mut swan::util::rng::Rng) -> Vec<ChoiceProfile> {
    let d = device(DEVICES[rng.index(5)]);
    let mut profs = Vec::new();
    for ch in enumerate_choices(&d) {
        if rng.bool(0.75) {
            profs.push(ChoiceProfile {
                choice: ch,
                latency_s: rng.range(0.05, 10.0),
                energy_j: rng.range(0.05, 10.0),
                power_w: rng.range(0.5, 10.0),
                steps_measured: 1 + rng.index(10),
            });
        }
    }
    profs
}

#[test]
fn cost_order_is_total_and_antisymmetric() {
    check(300, |rng| {
        let d = device(DEVICES[rng.index(5)]);
        let all = enumerate_choices(&d);
        let a = &all[rng.index(all.len())];
        let b = &all[rng.index(all.len())];
        if a.label() == b.label() {
            prop_assert!(
                !costlier(a, b) && !costlier(b, a),
                "irreflexivity violated on {}",
                a.label()
            );
        } else {
            // totality: exactly one of the strict comparisons holds
            prop_assert!(
                costlier(a, b) ^ costlier(b, a),
                "totality violated: {} vs {}",
                a.label(),
                b.label()
            );
        }
        Ok(())
    });
}

#[test]
fn cost_order_is_transitive() {
    check(500, |rng| {
        let d = device(DEVICES[rng.index(5)]);
        let all = enumerate_choices(&d);
        let a = &all[rng.index(all.len())];
        let b = &all[rng.index(all.len())];
        let c = &all[rng.index(all.len())];
        if costlier(a, b) && costlier(b, c) {
            prop_assert!(
                costlier(a, c),
                "transitivity violated: {} > {} > {} but not {} > {}",
                a.label(),
                b.label(),
                c.label(),
                a.label(),
                c.label()
            );
        }
        Ok(())
    });
}

#[test]
fn cost_order_agrees_with_key_comparison() {
    check(200, |rng| {
        let d = device(DEVICES[rng.index(5)]);
        let all = enumerate_choices(&d);
        let a = &all[rng.index(all.len())];
        let b = &all[rng.index(all.len())];
        prop_assert!(
            costlier(a, b) == (cost_key(a) > cost_key(b)),
            "costlier() and cost_key() disagree on {} vs {}",
            a.label(),
            b.label()
        );
        Ok(())
    });
}

#[test]
fn pruned_chain_is_a_strict_tradeoff_chain() {
    // the chain must be antichain-free under (latency ↑, cost ↓): every
    // adjacent pair trades latency for relinquished compute, so no kept
    // choice dominates another
    check(300, |rng| {
        let profs = random_profiles(rng);
        if profs.is_empty() {
            return Ok(());
        }
        let chain = prune_dominated(profs);
        prop_assert!(!chain.is_empty(), "chain empty on nonempty input");
        for w in chain.windows(2) {
            prop_assert!(
                w[0].latency_s <= w[1].latency_s,
                "chain not latency-sorted: {} then {}",
                w[0].latency_s,
                w[1].latency_s
            );
            prop_assert!(
                cost_key(&w[1].choice) < cost_key(&w[0].choice),
                "chain not strictly cheaper: {} then {}",
                w[0].choice.label(),
                w[1].choice.label()
            );
        }
        Ok(())
    });
}

#[test]
fn pruned_chain_is_pareto_no_kept_choice_dominated() {
    check(300, |rng| {
        let profs = random_profiles(rng);
        if profs.is_empty() {
            return Ok(());
        }
        let chain = prune_dominated(profs.clone());
        for kept in &chain {
            for other in &profs {
                let strictly_faster =
                    other.latency_s < kept.latency_s - 1e-12;
                let not_costlier =
                    cost_key(&other.choice) <= cost_key(&kept.choice);
                prop_assert!(
                    !(strictly_faster && not_costlier),
                    "kept {} is dominated by {}",
                    kept.choice.label(),
                    other.choice.label()
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prune_keeps_the_fastest_and_only_input_choices() {
    check(300, |rng| {
        let profs = random_profiles(rng);
        if profs.is_empty() {
            return Ok(());
        }
        let fastest = profs
            .iter()
            .map(|p| p.latency_s)
            .fold(f64::INFINITY, f64::min);
        let labels: Vec<String> =
            profs.iter().map(|p| p.choice.label()).collect();
        let chain = prune_dominated(profs);
        prop_assert!(
            (chain[0].latency_s - fastest).abs() < 1e-12,
            "head of chain is not the fastest profile"
        );
        prop_assert!(
            chain.len() <= labels.len(),
            "prune invented profiles"
        );
        for p in &chain {
            prop_assert!(
                labels.contains(&p.choice.label()),
                "prune invented choice {}",
                p.choice.label()
            );
        }
        Ok(())
    });
}

#[test]
fn prune_is_idempotent() {
    check(200, |rng| {
        let profs = random_profiles(rng);
        if profs.is_empty() {
            return Ok(());
        }
        let once = prune_dominated(profs);
        let twice = prune_dominated(once.clone());
        prop_assert!(
            once.len() == twice.len(),
            "pruning a pruned chain changed it: {} -> {}",
            once.len(),
            twice.len()
        );
        for (a, b) in once.iter().zip(&twice) {
            prop_assert!(
                a.choice.label() == b.choice.label(),
                "idempotence order broke at {} vs {}",
                a.choice.label(),
                b.choice.label()
            );
        }
        Ok(())
    });
}
