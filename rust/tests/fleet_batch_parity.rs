//! Integration: the batch-vectorized SoA passes (batched envelope RNG,
//! lane-friendly availability sweep, split plan/commit energy tick)
//! must stay bit-identical to the scalar PR 1 reference kernel over
//! *randomly generated* scenarios, not just the committed builtins —
//! the property that makes a vectorization bug fail as a parity error.

use swan::fleet::{run_scenario, run_scenario_reference, ScenarioSpec};
use swan::prop_assert;
use swan::util::check::check;
use swan::util::rng::Rng;

fn random_spec(rng: &mut Rng, case: usize) -> ScenarioSpec {
    ScenarioSpec {
        name: format!("batch-parity-{case}"),
        seed: rng.next_u64(),
        devices: 40 + rng.index(160),
        rounds: 3 + rng.index(8),
        clients_per_round: 5 + rng.index(20),
        trace_users: 1 + rng.index(3),
        daily_credit_j: rng.range(1_000.0, 30_000.0),
        min_level_pct: rng.range(10.0, 60.0),
        interference_p: rng.range(0.0, 0.5),
        interference_slowdown: rng.range(1.0, 3.0),
        thermal_throttle_p: rng.range(0.0, 0.3),
        thermal_derate: rng.range(1.0, 2.0),
        ..ScenarioSpec::default()
    }
}

#[test]
fn batched_passes_match_scalar_reference_on_random_scenarios() {
    let mut case = 0usize;
    check(6, |rng| {
        let spec = random_spec(rng, case);
        case += 1;
        let golden = run_scenario_reference(&spec, 1, swan::fl::FlArm::Swan)
            .map_err(|e| format!("reference run failed: {e}"))?;
        for shards in [1usize, 3, 8] {
            let soa = run_scenario(&spec, shards, swan::fl::FlArm::Swan)
                .map_err(|e| format!("soa run failed: {e}"))?;
            prop_assert!(
                soa.digest() == golden.digest(),
                "{}: soa@{shards} digest {} != reference {}",
                spec.name,
                soa.digest(),
                golden.digest()
            );
            prop_assert!(
                soa.online_per_round == golden.online_per_round,
                "{}: online-per-round diverged at {shards} shards",
                spec.name
            );
            prop_assert!(
                soa.total_time_s.to_bits() == golden.total_time_s.to_bits(),
                "{}: total_time_s bits diverged at {shards} shards",
                spec.name
            );
            prop_assert!(
                soa.total_energy_j.to_bits()
                    == golden.total_energy_j.to_bits(),
                "{}: total_energy_j bits diverged at {shards} shards",
                spec.name
            );
        }
        Ok(())
    });
}
