//! Serve control-plane parity: the acceptance contract of the serve
//! subsystem.
//!
//! 1. The **in-process** serve path (fleet devices checking in through
//!    the coordinator with no sockets) must produce bit-identical round
//!    aggregates to a machinery-free replay that aggregates with
//!    `fl::server::fedavg` — wire structs, batching, admission, the
//!    LRU profile cache and dense-seq aggregation must all be
//!    value-transparent.
//! 2. The **loopback-TCP** path must reproduce the in-process digest —
//!    the binary wire format and the pipelined server round-trip every
//!    bit (the CI `serve-smoke` job asserts the same at 2k devices).
//!
//! The full `smoke` preset runs here; `city` (100k devices) carries
//! `#[ignore]` because debug-mode builds make it minutes-slow — run it
//! with `cargo test --release -- --ignored`, or via
//! `swan bench serve --scenario city --no-tcp`, which performs the
//! identical assertion in release mode.

use swan::fleet::{run_serve_bench, ScenarioSpec};
use swan::serve::{
    run_inproc, run_inproc_with, run_oracle, ServeConfig, RETRY_AFTER_S,
};

#[test]
fn smoke_scenario_inproc_matches_fl_server_oracle() {
    // the full `smoke` builtin (2k devices × 25 rounds), not a
    // miniature: this is acceptance criterion #1 at its stated scale
    let spec = ScenarioSpec::builtin("smoke").expect("builtin");
    let cfg = ServeConfig::for_scenario(&spec);
    let oracle = run_oracle(&spec, &cfg).expect("oracle replay");
    let (out, coord) = run_inproc(&spec, 4, &cfg).expect("inproc serve");
    assert_eq!(out.digest, oracle.digest, "smoke: serve vs fl::server");
    assert_eq!(out.participations, oracle.participations);
    assert_eq!(
        out.total_energy_j.to_bits(),
        oracle.total_energy_j.to_bits()
    );
    assert_eq!(out.total_time_s.to_bits(), oracle.total_time_s.to_bits());
    assert_eq!(out.rounds_run, spec.rounds);
    assert!(out.participations > 0, "smoke must select participants");
    // §4.2 sharing: a 2k-device run explores at most the full context
    // space (5 models × 3 bands × 2 charger states), never per-device
    let stats = coord.stats();
    assert!(
        stats.cache_misses <= 30,
        "explorations {} exceed the context space",
        stats.cache_misses
    );
    assert!(stats.cache_hits > stats.cache_misses * 10);
}

#[test]
fn loopback_tcp_matches_the_inproc_digest() {
    // small scale: this test pins the wire format + server round-trip,
    // CI's serve-smoke job covers the 2k-device version in release
    let spec = ScenarioSpec {
        name: "serve-tcp-unit".to_string(),
        devices: 240,
        rounds: 4,
        clients_per_round: 16,
        trace_users: 2,
        ..ScenarioSpec::default()
    };
    let report =
        run_serve_bench(&spec, 2, true, 0, &swan::obs::Obs::off())
            .expect("serve bench with TCP");
    let tcp = report.tcp.expect("TCP run present");
    assert_eq!(tcp.digest, report.inproc.digest);
    assert_eq!(
        report.oracle_digest.as_deref(),
        Some(report.inproc.digest.as_str())
    );
    assert_eq!(tcp.participations, report.inproc.participations);
    assert_eq!(tcp.checkins, report.inproc.checkins);
    assert_eq!(tcp.deferred, 0);
}

#[test]
fn deferral_events_carry_retry_after_and_batch_size() {
    // force backpressure: a tiny admission bound against a fleet big
    // enough to overflow it every round
    let spec = ScenarioSpec {
        name: "serve-deferral-unit".to_string(),
        devices: 300,
        rounds: 3,
        clients_per_round: 8,
        trace_users: 2,
        ..ScenarioSpec::default()
    };
    let mut cfg = ServeConfig::for_scenario(&spec);
    cfg.admit_capacity = 8;
    let obs = swan::obs::Obs::capture();
    let (out, _) =
        run_inproc_with(&spec, 2, &cfg, &obs).expect("inproc serve");
    assert!(out.deferred > 0, "admission bound never tripped");
    let deferrals: Vec<_> = obs
        .captured_lines()
        .iter()
        .map(|l| swan::util::json::parse(l).expect("well-formed line"))
        .filter(|v| v.req_str("reason").unwrap() == "deferral")
        .collect();
    assert!(!deferrals.is_empty(), "no deferral events in the stream");
    for d in &deferrals {
        // the record reports the policy the clients were actually
        // told: the coordinator's Retry-After and coalescing batch
        assert_eq!(
            d.req_f64("retry_after_s").unwrap(),
            RETRY_AFTER_S as f64
        );
        assert_eq!(
            d.req_f64("batch_size").unwrap(),
            cfg.batch_size as f64
        );
        assert!(d.req_f64("deferred").unwrap() > 0.0);
    }
}

#[test]
fn lane_count_cannot_perturb_the_digest() {
    let spec = ScenarioSpec {
        name: "serve-lanes-unit".to_string(),
        devices: 300,
        rounds: 5,
        clients_per_round: 20,
        trace_users: 2,
        ..ScenarioSpec::default()
    };
    let cfg = ServeConfig::for_scenario(&spec);
    let (one, _) = run_inproc(&spec, 1, &cfg).expect("1 lane");
    let (eight, _) = run_inproc(&spec, 8, &cfg).expect("8 lanes");
    assert_eq!(one.digest, eight.digest, "1 vs 8 lanes");
    assert_eq!(one.participations, eight.participations);
}

#[test]
#[ignore = "city = 100k devices; minutes-slow in debug builds — run with \
            --release -- --ignored, or `swan bench serve --scenario city \
            --no-tcp` which asserts the same parity"]
fn city_scenario_inproc_matches_fl_server_oracle() {
    let spec = ScenarioSpec::builtin("city").expect("builtin");
    let cfg = ServeConfig::for_scenario(&spec);
    let oracle = run_oracle(&spec, &cfg).expect("oracle replay");
    let (out, _) = run_inproc(&spec, 8, &cfg).expect("inproc serve");
    assert_eq!(out.digest, oracle.digest, "city: serve vs fl::server");
    assert_eq!(out.participations, oracle.participations);
    assert_eq!(
        out.total_energy_j.to_bits(),
        oracle.total_energy_j.to_bits()
    );
}
