//! Numerics-loop parity: the acceptance contract of the unified FL
//! engine (`fl::engine`).
//!
//! 1. **Serve-routed training is the direct run.** `run_serve` — real
//!    local SGD whose selection, lease resolution, FedAvg aggregation
//!    and parity digest all happen inside the `serve` coordinator —
//!    must produce bit-identical final weights, digests, virtual-clock
//!    totals and loan-state evolution to `run_direct`, the in-process
//!    oracle, at ANY lane count.
//! 2. **The wire is value-transparent.** The same holds over loopback
//!    TCP: every f32 gradient and f64 lease field round-trips exactly
//!    through the length-prefixed binary framing.
//!
//! Configs are drawn from the repo's deterministic RNG, so "random"
//! here means "a different corner of the config space every edit of
//! the draw seed", not flaky.

use std::sync::Arc;

use swan::fl::{
    run_direct, run_serve, serve_config, ClientLanes, FlArm, FlClient,
    FlConfig, FlSim,
};
use swan::serve::{serve_tcp, Coordinator, InProcClient, ServeClient, TcpClient};
use swan::train::{SoftmaxProbe, SyntheticDataset};
use swan::util::rng::Rng;
use swan::workload::{load_or_builtin, Workload, WorkloadName};

const WORKLOAD: WorkloadName = WorkloadName::ShufflenetV2;

/// Draw one small-but-not-degenerate config from the repo RNG.
fn draw_cfg(rng: &mut Rng) -> FlConfig {
    FlConfig {
        seed: rng.next_u64(),
        raw_traces: 6,
        quality_traces: 2, // × 24 shifts = 48 clients
        clients_per_round: 2 + rng.index(4), // 2..=5
        local_steps: 1 + rng.index(3),       // 1..=3
        rounds: 3 + rng.index(3),            // 3..=5
        eval_every: 2,
        eval_batches: 1,
        daily_credit_j: rng.range(2_000.0, 6_000.0),
        server_overhead_s: rng.range(0.1, 2.0),
    }
}

fn fleet(
    cfg: &FlConfig,
    arm: FlArm,
) -> (Vec<FlClient>, SoftmaxProbe, Workload) {
    let ds = SyntheticDataset::speech(cfg.seed);
    let w = load_or_builtin(WORKLOAD, "artifacts");
    let sim = FlSim::new(cfg.clone(), arm, ds.clone(), &w)
        .expect("fleet construction");
    (sim.clients, SoftmaxProbe::new(ds), w)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Assert the full bit-identity contract between an oracle run and a
/// serve-routed run, including the lane state both mutated.
fn assert_parity(
    tag: &str,
    direct: &swan::fl::FlOutcome,
    direct_lanes: &ClientLanes,
    served: &swan::fl::FlOutcome,
    served_lanes: &ClientLanes,
) {
    assert_eq!(direct.digest, served.digest, "{tag}: digest");
    assert!(
        direct.digest.starts_with("serve-"),
        "{tag}: digest missing its namespace: {}",
        direct.digest
    );
    assert_eq!(
        bits(&direct.final_model),
        bits(&served.final_model),
        "{tag}: final weights"
    );
    assert_eq!(
        direct.total_time_s.to_bits(),
        served.total_time_s.to_bits(),
        "{tag}: virtual clock"
    );
    assert_eq!(
        direct.total_energy_j.to_bits(),
        served.total_energy_j.to_bits(),
        "{tag}: fleet energy"
    );
    assert_eq!(
        direct.online_per_round, served.online_per_round,
        "{tag}: availability stream"
    );
    assert_eq!(direct.rounds_run, served.rounds_run);
    for k in 0..direct_lanes.n {
        assert_eq!(
            direct_lanes.bank.loan_j[k].to_bits(),
            served_lanes.bank.loan_j[k].to_bits(),
            "{tag}: loan row {k}"
        );
        assert_eq!(
            direct_lanes.participations[k], served_lanes.participations[k],
            "{tag}: participation row {k}"
        );
        assert_eq!(
            direct_lanes.train_time_s[k].to_bits(),
            served_lanes.train_time_s[k].to_bits(),
            "{tag}: train-time row {k}"
        );
    }
}

#[test]
fn inproc_serve_matches_the_direct_oracle_over_random_configs() {
    let mut draw = Rng::new(0xF1_C0DE);
    for case in 0..3 {
        let cfg = draw_cfg(&mut draw);
        let arm = if case % 2 == 0 { FlArm::Swan } else { FlArm::Baseline };
        let (clients, probe, w) = fleet(&cfg, arm);
        let mut oracle_lanes = ClientLanes::new(&clients, cfg.seed);
        let direct =
            run_direct(&cfg, arm, &mut oracle_lanes, &probe, &w)
                .expect("oracle run");
        assert!(
            !direct.final_model.is_empty(),
            "case {case}: oracle trained nothing"
        );

        for n_lanes in [1usize, 4] {
            let coord = Arc::new(
                Coordinator::new(serve_config(
                    &cfg,
                    arm,
                    WORKLOAD,
                    probe.dim(),
                ))
                .expect("coordinator"),
            );
            let lane_clients: Vec<Box<dyn ServeClient>> = (0..n_lanes)
                .map(|_| {
                    Box::new(InProcClient::new(coord.clone()))
                        as Box<dyn ServeClient>
                })
                .collect();
            let mut lanes = ClientLanes::new(&clients, cfg.seed);
            let served =
                run_serve(&cfg, arm, &mut lanes, &probe, lane_clients)
                    .expect("serve-routed run");
            assert_parity(
                &format!("case {case} inproc lanes={n_lanes}"),
                &direct,
                &oracle_lanes,
                &served,
                &lanes,
            );
        }
    }
}

#[test]
fn loopback_tcp_serve_matches_the_direct_oracle() {
    let mut draw = Rng::new(0x7C9_B00F);
    let cfg = draw_cfg(&mut draw);
    let arm = FlArm::Swan;
    let (clients, probe, w) = fleet(&cfg, arm);
    let mut oracle_lanes = ClientLanes::new(&clients, cfg.seed);
    let direct = run_direct(&cfg, arm, &mut oracle_lanes, &probe, &w)
        .expect("oracle run");

    for n_lanes in [1usize, 4] {
        let coord = Arc::new(
            Coordinator::new(serve_config(&cfg, arm, WORKLOAD, probe.dim()))
                .expect("coordinator"),
        );
        let handle = serve_tcp(coord.clone(), "127.0.0.1:0", 2)
            .expect("tcp listener");
        let lane_clients: Vec<Box<dyn ServeClient>> = (0..n_lanes)
            .map(|_| {
                Box::new(
                    TcpClient::connect(handle.addr).expect("tcp connect"),
                ) as Box<dyn ServeClient>
            })
            .collect();
        let mut lanes = ClientLanes::new(&clients, cfg.seed);
        let served = run_serve(&cfg, arm, &mut lanes, &probe, lane_clients)
            .expect("tcp serve-routed run");
        // run_serve consumed (and dropped) every client connection, so
        // the workers are idle and shutdown joins cleanly
        handle.shutdown();
        assert_parity(
            &format!("tcp lanes={n_lanes}"),
            &direct,
            &oracle_lanes,
            &served,
            &lanes,
        );
    }
}

#[test]
fn flsim_run_with_probe_is_the_engine_oracle() {
    // `FlSim::run_with` is sugar over ClientLanes + run_direct +
    // write_back; pin that it reports the engine's digest and restores
    // participation state into the scalar clients.
    let cfg = FlConfig {
        seed: 11,
        raw_traces: 6,
        quality_traces: 2,
        clients_per_round: 3,
        local_steps: 2,
        rounds: 4,
        eval_every: 2,
        eval_batches: 1,
        daily_credit_j: 3_000.0,
        server_overhead_s: 0.5,
    };
    let ds = SyntheticDataset::speech(cfg.seed);
    let w = load_or_builtin(WORKLOAD, "artifacts");
    let probe = SoftmaxProbe::new(ds.clone());
    let mut sim = FlSim::new(cfg.clone(), FlArm::Swan, ds, &w)
        .expect("fleet construction");
    let out = sim.run_with(&probe).expect("sim run");

    let (clients, probe2, w2) = fleet(&cfg, FlArm::Swan);
    let mut lanes = ClientLanes::new(&clients, cfg.seed);
    let direct = run_direct(&cfg, FlArm::Swan, &mut lanes, &probe2, &w2)
        .expect("engine oracle");
    assert_eq!(out.digest, direct.digest);
    assert_eq!(bits(&out.final_model), bits(&direct.final_model));
    let sim_parts: usize =
        sim.clients.iter().map(|c| c.participations).sum();
    let lane_parts: usize = lanes.participations.iter().sum();
    assert_eq!(sim_parts, lane_parts, "write_back lost participations");
}
